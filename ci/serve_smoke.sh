#!/usr/bin/env bash
# CI smoke: replay one I/O-heavy Table 2 row through the serve loop
# (EchoExecutor, PoolSim clock) while a boot storm runs on the same
# clock, and gate on the deterministic `serve.*` / `fabric.*` / `sim.*`
# counters (plus `chaos.*` / `heal.*` when a fault schedule is active):
#
#   1. determinism — two same-seed runs must emit byte-identical
#      counter lines (always enforced);
#   2. golden — the counters must match the committed
#      ci/golden/serve_smoke.txt byte-for-byte.  If no golden is
#      committed yet, the fresh counters are printed for seeding (the
#      workflow also uploads them as an artifact) and only gate 1
#      applies, mirroring benchdiff's "new bench — not compared" rule.
#
# The device-to-device streaming counters (`serve.host_bytes_per_token`,
# `fabric.bytes_p2p`, `fabric.stream_quanta`, `fabric.stream_overlap_ns`)
# ride the existing `serve.`/`fabric.` grep prefixes below — no golden
# protocol change; they appear as new rows the next time the golden is
# seeded or refreshed.
#
# Refresh the golden after an intentional scheduling change with
#   UPDATE_GOLDEN=1 cargo test --test golden
# (rust/tests/golden.rs re-derives the same lines in-process through
# dockerssd::smoke) — or by copying the uploaded artifact over
# ci/golden/serve_smoke.txt.  The CI smoke job cross-diffs the two
# derivations, so the binary and the test cannot drift apart.
set -euo pipefail
cd "$(dirname "$0")/.."

golden=ci/golden/serve_smoke.txt
out=${SMOKE_OUT:-/tmp/serve_smoke}
mkdir -p "$out"

run() {
  cargo run --release --bin repro -- serve \
    --workload nginx-filedown --nodes 4 --scale 2000 --seed 42 --boot-storm 2 \
    | grep -E '^(serve|fabric|sim|chaos|heal)\.'
}

run > "$out/counters_a.txt"
run > "$out/counters_b.txt"

echo "== gate 1: same-seed determinism =="
diff -u "$out/counters_a.txt" "$out/counters_b.txt"
echo "ok: two same-seed replays are byte-identical"

echo "== gate 2: committed golden =="
if [ -f "$golden" ]; then
  diff -u "$golden" "$out/counters_a.txt"
  echo "ok: counters match $golden"
else
  echo "no committed golden at $golden — seed it with these counters:"
  echo "----------------------------------------------------------------"
  cat "$out/counters_a.txt"
  echo "----------------------------------------------------------------"
fi
