//! Minimal offline substitute for the `anyhow` crate (DESIGN.md §4).
//!
//! The build environment has no crates.io access, so the subset of the
//! anyhow API this repository uses is reimplemented here: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] macros, and the [`Context`]
//! extension trait.  Errors are a flat message chain (context entries
//! prepended, `": "`-joined), which matches how the callers format them
//! (`{e}` and `{e:#}` both print the chain).

use std::fmt;

/// A boxed-up, context-carrying error.  Like `anyhow::Error`, this type
/// deliberately does **not** implement `std::error::Error`, so the
/// blanket `From` below stays coherent.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Prepend a context entry (outermost first, like anyhow).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` prints the outermost message; `{e:#}` the whole chain.
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Attach context to `Result`/`Option` values, like `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_err()?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_prepends() {
        let e = io_err().with_context(|| "reading weights.bin").unwrap_err();
        assert_eq!(format!("{e}"), "reading weights.bin");
        assert_eq!(format!("{e:#}"), "reading weights.bin: gone");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad {} at {}", "value", 7);
        assert_eq!(format!("{e}"), "bad value at 7");
        fn bails() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "nope 1");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
    }
}
