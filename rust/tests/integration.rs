//! Cross-module integration: the full docker lifecycle over the
//! NVMe/Ether-oN/λFS/firmware substrates, host-to-container TCP over the
//! Ether-oN intranet, and the orchestrated pool.

use std::net::Ipv4Addr;

use dockerssd::config::SystemConfig;
use dockerssd::coordinator::{serve, EchoExecutor, InferenceRequest, ServeParams};
use dockerssd::docker::{DockerCmd, MiniDocker, Registry};
use dockerssd::etheron::{EtherOnDriver, MacAddr, TcpStack};
use dockerssd::etheron::frame::{tcp_frame, EthFrame, Ipv4Packet, TcpSegment};
use dockerssd::fabric::{Endpoint, Fabric, LinkClass};
use dockerssd::firmware::VirtualFw;
use dockerssd::lambdafs::{LambdaFs, LockSide};
use dockerssd::layerstore::{FetchSource, LayerStore, PoolLayerCache};
use dockerssd::llm::{all_llms, Parallelism};
use dockerssd::llm::disagg::{pool_step_time, step_traffic};
use dockerssd::metrics::{names, Counters};
use dockerssd::nvme::{NvmeController, NvmeSubsystem, PcieFunction, QueuePair};
use dockerssd::pool::{
    DeploymentSpec, FtlBank, Orchestrator, PoolTopology, RestartPolicy, WireCtx, WireRig,
};
use dockerssd::sim::PoolSim;
use dockerssd::ssd::SsdDevice;
use dockerssd::util::{Rng, SimTime};

fn rig() -> (MiniDocker, VirtualFw, LambdaFs, SsdDevice, Registry, WireRig) {
    let cfg = SystemConfig::default();
    let dev = SsdDevice::new(cfg.ssd.clone());
    let fs = LambdaFs::over_device(&dev);
    let fw = VirtualFw::new(&cfg.ssd);
    let wire = WireRig::new(&cfg.pool, &cfg.etheron);
    (MiniDocker::new(), fw, fs, dev, Registry::with_benchmark_images(), wire)
}

#[test]
fn docker_lifecycle_over_simulated_ssd() {
    let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = rig();
    // pull every benchmark image, run one container each
    for img in ["embed", "mariadb", "rocksdb", "pattern", "nginx", "vsftpd"] {
        md.pull(&mut fw, &mut fs, &mut dev, &reg, &mut fab.ctx(SimTime::ZERO), 0, img).unwrap();
        let id = md.run(&mut fw, &mut fs, &mut dev, SimTime::ZERO, img).unwrap().output;
        md.log_line(&mut fs, &mut dev, SimTime::ZERO, &id, "ready").unwrap();
    }
    assert_eq!(md.containers().len(), 6);
    assert_eq!(fw.thread.running(), 6);
    // the blobs landed in the private namespace: invisible to the host
    let blobs = fs.list("/images/blobs").unwrap();
    assert!(blobs.len() >= 6);
    for b in &blobs {
        let ino = fs.walk(&format!("/images/blobs/{b}")).unwrap();
        assert!(!fs.host_visible(ino), "blob {b} leaked to host namespace");
    }
    // flash actually saw traffic (write-back ICL: flush forces programs)
    use dockerssd::nvme::BlockBackend;
    dev.flush(SimTime::ZERO);
    assert!(dev.flash.programs > 0);
}

#[test]
fn isp_processing_respects_inode_locks_end_to_end() {
    let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = rig();
    md.pull(&mut fw, &mut fs, &mut dev, &reg, &mut fab.ctx(SimTime::ZERO), 0, "pattern").unwrap();
    let id = md.run(&mut fw, &mut fs, &mut dev, SimTime::ZERO, "pattern").unwrap().output;

    // host stages data
    fs.write_file(&mut dev, SimTime::ZERO, "/data/docs.txt", b"needle haystack needle", LockSide::Host)
        .unwrap();
    let ino = fs.walk("/data/docs.txt").unwrap();

    // container binds -> host shut out
    assert!(fs.locks.acquire(ino, LockSide::Isp));
    assert!(fs
        .write_file(&mut dev, SimTime::ZERO, "/data/docs.txt", b"clobber", LockSide::Host)
        .is_err());

    // ISP processes + writes result
    let (data, t) = fw.isp_read(&mut fs, &mut dev, SimTime::ZERO, "/data/docs.txt").unwrap();
    let hits = String::from_utf8_lossy(&data).matches("needle").count();
    fw.isp_write(&mut fs, &mut dev, t, "/data/result", format!("{hits}").as_bytes())
        .unwrap();
    fs.locks.release(ino, LockSide::Isp);

    // host reads result from the sharable namespace
    let r = fs.read_file(&mut dev, t, "/data/result", LockSide::Host).unwrap();
    assert_eq!(r.value, b"2");
    md.stop(&mut fw, &mut fs, &mut dev, t, &id).unwrap();
}

#[test]
fn docker_cli_over_etheron_tcp_http() {
    // host docker-cli -> TCP over Ether-oN -> mini-docker HTTP parse
    let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = rig();
    md.pull(&mut fw, &mut fs, &mut dev, &reg, &mut fab.ctx(SimTime::ZERO), 0, "nginx").unwrap();

    let mut host = TcpStack::new();
    fw.tcp().listen(2375);
    let host_ip = Ipv4Addr::new(10, 77, 0, 1);
    let ssd_ip = Ipv4Addr::new(10, 77, 0, 2);

    // three-way handshake across the two stacks
    let syn = host.connect(49152, ssd_ip, 2375);
    let syn_ack = fw.tcp().process(host_ip, &syn);
    let ack = host.process(ssd_ip, &syn_ack[0]);
    fw.tcp().process(host_ip, &ack[0]);

    // send the HTTP command as a TCP payload wrapped in a real frame
    let req = b"POST /containers/nginx/run HTTP/1.1\r\n".to_vec();
    let seg = host.send((49152, ssd_ip, 2375), req).unwrap();
    let f = tcp_frame(MacAddr::for_node(0), MacAddr::for_node(1), host_ip, ssd_ip, &seg);
    // frame crosses NVMe as a TransmitFrame command payload
    let decoded = EthFrame::decode(&f.encode()).unwrap();
    let ip = Ipv4Packet::decode(&decoded.payload).unwrap();
    let seg2 = TcpSegment::decode(&ip.payload).unwrap();
    fw.tcp().process(ip.src, &seg2);
    let payload = fw.tcp().recv((2375, host_ip, 49152));

    // mini-docker parses and executes
    let line = String::from_utf8_lossy(&payload);
    let cmd = DockerCmd::from_http(line.lines().next().unwrap()).expect("parse http");
    assert_eq!(cmd, DockerCmd::Run("nginx".into()));
    let id = md.run(&mut fw, &mut fs, &mut dev, SimTime::ZERO, "nginx").unwrap().output;
    assert!(md.ps().output.contains(&id));
}

#[test]
fn etheron_upcall_flow_with_nvme_controller() {
    let cfg = SystemConfig::default();
    let mut dev = SsdDevice::new(cfg.ssd.clone());
    let mut fw = VirtualFw::new(&cfg.ssd);
    let mut ctl = NvmeController::new(NvmeSubsystem::standard(1_000_000, 0.3));
    let mut qp = QueuePair::new(1, 64);
    let mut drv = EtherOnDriver::new(cfg.etheron.clone());

    assert_eq!(drv.arm_upcalls(&mut qp), 4);
    ctl.service_queue(SimTime::ZERO, &mut qp, PcieFunction::Host, &mut dev, &mut fw);
    assert_eq!(ctl.upcall_slots_free(), 4);

    // device (container) emits 10 frames toward the host; the 4-slot pool
    // must never deadlock as long as the driver keeps re-arming
    let mut received = 0;
    for i in 0..10u8 {
        let f = EthFrame {
            dst: MacAddr::for_node(0),
            src: MacAddr::for_node(1),
            ethertype: dockerssd::etheron::EtherType::Ipv4,
            payload: vec![i; 100],
        };
        assert!(ctl.upcall(&mut qp, f.encode()), "slot available");
        received += drv.poll_rx(&mut qp).len();
        ctl.service_queue(SimTime::ZERO, &mut qp, PcieFunction::Host, &mut dev, &mut fw);
    }
    assert_eq!(received, 10);
    assert_eq!(drv.stats.rearm_count, 10);
}

#[test]
fn pool_deployment_survives_node_failure() {
    let cfg = SystemConfig::default();
    let mut topo = PoolTopology::build(&cfg.pool);
    let mut orch = Orchestrator::new();
    let spec = DeploymentSpec {
        name: "llm-infer".into(),
        image: "embed".into(),
        replicas: 8,
        restart: RestartPolicy::Always,
    };
    let placed = orch.deploy(&topo, &spec).unwrap();
    assert_eq!(placed.len(), 8);
    assert_eq!(orch.running_count("llm-infer"), 8);

    // kill a node; its replicas must restart elsewhere
    let victim = placed[0];
    topo.node_mut(victim).unwrap().healthy = false;
    for (i, node) in placed.iter().enumerate() {
        if *node == victim {
            assert!(orch.replica_failed(&topo, "llm-infer", i as u32, RestartPolicy::Always));
        }
    }
    assert_eq!(orch.running_count("llm-infer"), 8);
    for p in orch.placements("llm-infer") {
        assert_ne!(p.node, victim, "replica still on dead node");
    }
}

/// The ISSUE 1 acceptance criterion as a tier-1 gate: booting N=4
/// replicas of one image across the pool via the layerstore moves at
/// least 2x fewer registry-WAN bytes than the registry-only path, and
/// the dedup/CoW counters are visible in metrics.  Since ISSUE 2, every
/// byte rides the shared fabric and placement prefetches missing layers
/// in the background, so the boot-path fetch hits locally.
#[test]
fn replica_boot_scales_with_unique_bytes_not_replicas() {
    let cfg = SystemConfig::default();
    let scfg = cfg.ssd.clone();
    let pcfg = dockerssd::config::PoolConfig {
        nodes_per_array: 4,
        arrays: 1,
        ..Default::default()
    };
    let topo = PoolTopology::build(&pcfg);
    let mut fabric = Fabric::new(&pcfg, &cfg.etheron);
    let reg = Registry::with_benchmark_images();
    let (manifest, blobs) = reg.fetch("nginx").unwrap();
    let image_bytes: u64 = blobs.iter().map(|b| b.bytes.len() as u64).sum();
    let replicas = 4u32;

    // registry-only baseline: every replica re-pulls the whole image
    let baseline_wan_bytes = replicas as u64 * image_bytes;

    // layerstore path: one stack per node, shared presence cache
    let mut nodes: Vec<_> = (0..replicas)
        .map(|_| {
            let dev = SsdDevice::new(scfg.clone());
            let fs = LambdaFs::over_device(&dev);
            (dev, fs, VirtualFw::new(&scfg), MiniDocker::new(), LayerStore::default())
        })
        .collect();
    let mut orch = Orchestrator::new();
    let mut cache = PoolLayerCache::new();
    let layers: Vec<(u64, u64)> = blobs
        .iter()
        .map(|b| (b.digest, b.bytes.len() as u64))
        .collect();
    let spec = DeploymentSpec {
        name: "web".into(),
        image: "nginx".into(),
        replicas,
        restart: RestartPolicy::OnFailure,
    };
    let mut bank = FtlBank::default();
    let placed = orch
        .deploy_with_layers(
            &mut WireCtx::at(&mut fabric, &topo, &mut bank, SimTime::ZERO),
            &spec,
            &mut cache,
            &layers,
        )
        .unwrap();
    assert_eq!(placed.len(), replicas as usize);
    // placement prefetched every missing layer over the background lane:
    // the cold node pulled from the registry, the rest from peers
    assert!(cache.peer_fetches > 0, "warm replicas must prefetch from peers");

    let mut sources = Vec::new();
    for nid in placed {
        let (dev, fs, fw, md, store) = &mut nodes[nid as usize];
        let mut t = SimTime::ZERO;
        for blob in blobs {
            let (src, xfer) = cache.fetch(
                &mut WireCtx::at(&mut fabric, &topo, &mut bank, t),
                nid,
                blob.digest,
                blob.bytes.len() as u64,
            );
            sources.push(src);
            t += xfer;
            let r = fw.install.install_blob(fs, dev, store, t, &blob.bytes).unwrap();
            t = r.done;
        }
        let m = fs
            .write_file(
                dev,
                t,
                &format!("/images/manifest/{}", manifest.name),
                manifest.to_json().dump().as_bytes(),
                LockSide::Isp,
            )
            .unwrap();
        let ran = md.run_cow(fw, fs, dev, store, m.done, "nginx").unwrap();
        // dirty one page so the CoW counter moves
        let layer = md.cow_layer_of(&ran.output).unwrap();
        md.cow
            .write_at(store, fs, dev, ran.done, layer, 0, &[0xAB; 512])
            .unwrap();
    }

    // only the first (cold) node's prefetch crossed the WAN
    assert_eq!(cache.bytes_from_registry, image_bytes);
    assert!(
        baseline_wan_bytes >= 2 * cache.bytes_from_registry,
        "acceptance: >=2x reduction, got {baseline_wan_bytes} vs {}",
        cache.bytes_from_registry
    );
    // prefetch made every boot-path fetch a local hit
    assert!(
        sources.iter().all(|s| matches!(s, FetchSource::Local)),
        "prefetched layers must be resident at boot: {sources:?}"
    );

    // dedup/CoW/peer/fabric counters visible in metrics
    let mut counters = Counters::new();
    for (_, _, _, md, store) in &nodes {
        store.export_counters(&mut counters);
        md.cow.export_counters(&mut counters);
    }
    cache.export_counters(&mut counters);
    fabric.export_counters(&mut counters);
    assert_eq!(counters.get(names::REGISTRY_FETCHES), blobs.len() as u64);
    assert_eq!(counters.get(names::PEER_FETCHES), (replicas as u64 - 1) * blobs.len() as u64);
    assert_eq!(counters.get(names::COW_BREAKS), replicas as u64);
    // N-1 replicas' bytes stayed on the intranet; the boot-path local
    // hits of prefetched layers are not counted a second time
    assert_eq!(
        counters.get(names::BYTES_NOT_TRANSFERRED),
        (replicas as u64 - 1) * image_bytes
    );
    assert_eq!(
        counters.get(names::BYTES_WRITTEN),
        replicas as u64 * image_bytes + replicas as u64 * (64 << 10),
        "each node writes the image once (dedup'd) plus one CoW chunk copy"
    );
    assert_eq!(counters.get(names::FABRIC_BYTES_WAN), image_bytes);
    assert_eq!(
        counters.get(names::FABRIC_PREFETCH_BYTES),
        replicas as u64 * image_bytes,
        "every layer byte arrived via background prefetch"
    );
}

/// ISSUE 5 acceptance: chunk-granular peer fetch.  A node holding half a
/// layer's chunks (degraded / mid-pull) serves exactly those chunks over
/// Array links while the registry serves the rest over RegistryWan; the
/// byte split is visible in the new `layerstore.chunk_*` counters, total
/// WAN bytes are strictly fewer than the whole-blob refetch the old
/// blob-granular path would move, and two same-seed runs are
/// byte-identical.
#[test]
fn degraded_peer_serves_only_chunks_it_holds() {
    let layer = 0x1A7E4u64;
    let layer_bytes = 8u64 << 20;
    let recipe: Vec<(u64, u64)> = (0..8u64).map(|i| (0xC40 + i, 1 << 20)).collect();

    let run = || {
        let pcfg = dockerssd::config::PoolConfig {
            nodes_per_array: 4,
            arrays: 1,
            ..Default::default()
        };
        let topo = PoolTopology::build(&pcfg);
        let mut fabric = Fabric::new(&pcfg, &dockerssd::config::EtherOnConfig::default());
        let mut bank = FtlBank::default();
        let mut cache = PoolLayerCache::new();
        assert!(cache.describe_chunks(layer, &recipe));
        // node 1 holds only the first half of the layer's chunks — with
        // the blob-granular map it would not be a holder at all and the
        // whole layer would re-cross the WAN
        for (c, _) in &recipe[..4] {
            cache.register_chunk(1, layer, *c);
        }
        assert!(!cache.node_has(1, layer), "a partial holder is not a full holder");
        let (src, lat) = cache.fetch(
            &mut WireCtx::at(&mut fabric, &topo, &mut bank, SimTime::ZERO),
            2,
            layer,
            layer_bytes,
        );
        assert_eq!(src, dockerssd::layerstore::FetchSource::Mixed);
        assert!(lat > SimTime::ZERO);
        assert!(cache.node_has(2, layer), "the fetcher assembled the full layer");
        // boot two more replicas: every chunk now has a pool holder, so
        // nothing more crosses the WAN
        for node in [3u32, 0] {
            let (src, _) = cache.fetch(
                &mut WireCtx::at(&mut fabric, &topo, &mut bank, SimTime::ZERO),
                node,
                layer,
                layer_bytes,
            );
            assert!(
                !matches!(src, FetchSource::Registry),
                "warm chunks must come from peers, got {src:?}"
            );
        }
        let mut c = Counters::new();
        cache.export_counters(&mut c);
        fabric.export_counters(&mut c);
        (c, lat)
    };

    let (c, lat) = run();
    let (c2, lat2) = run();
    assert_eq!(c, c2, "same-seed chunk-granular boots must be byte-identical");
    assert_eq!(lat, lat2);

    // the degraded fetch split the layer: half over the intranet from
    // the partial peer, half over the WAN from the registry
    assert_eq!(c.get(names::CHUNK_BYTES_REGISTRY), 4 << 20);
    assert!(c.get(names::PARTIAL_HOLDERS_USED) > 0, "partial holders served");
    assert_eq!(
        c.get(names::FABRIC_BYTES_WAN),
        4 << 20,
        "only the chunks no peer held crossed the WAN"
    );
    assert!(
        c.get(names::FABRIC_BYTES_WAN) < layer_bytes,
        "strictly fewer WAN bytes than a whole-blob refetch"
    );
    // node 2's fetch: 4 MiB from the peer; replicas 3 and 0: 8 MiB each
    // from peers
    assert_eq!(c.get(names::CHUNK_BYTES_PEER), (4 << 20) + 2 * layer_bytes);
    assert_eq!(c.get(names::CHUNK_FETCHES), 8 + 2 * 8);
    assert_eq!(c.get(names::BYTES_FROM_REGISTRY), 4 << 20);
}

#[test]
fn pool_fabric_latency_model_consistency() {
    let cfg = SystemConfig::default();
    let fabric = Fabric::of(&cfg);
    // transferring a KV page between neighbors is cheaper than bouncing
    // it through the host path
    let near = fabric.estimate(Endpoint::Node(0), Endpoint::Node(1), 4096);
    let via_host = fabric.estimate(Endpoint::Node(0), Endpoint::Host, 4096)
        + fabric.estimate(Endpoint::Host, Endpoint::Node(1), 4096);
    assert!(near < via_host);
    // and the registry is the dearest source of all
    let wan = fabric.estimate(Endpoint::Registry, Endpoint::Node(1), 4096);
    assert!(via_host < wan);
}

/// ISSUE 2 acceptance: booting N replicas over one shared link is
/// measurably slower than over N disjoint links, with `fabric.*`
/// counters exported.  The storm goes through the real layerstore fetch
/// path, so this also pins the poolcache -> fabric integration.
#[test]
fn fabric_contention_replica_boot_storm() {
    let n = 4u32;
    let bytes = 8 << 20;
    let digest = 0xB007;

    // shared: one array, node 0 seeds n replicas over one backplane
    let shared_cfg = dockerssd::config::PoolConfig {
        nodes_per_array: n + 1,
        arrays: 1,
        ..Default::default()
    };
    let shared_topo = PoolTopology::build(&shared_cfg);
    let mut shared_fabric = Fabric::new(&shared_cfg, &dockerssd::config::EtherOnConfig::default());
    let single = shared_fabric.estimate(Endpoint::Node(0), Endpoint::Node(1), bytes);
    let mut bank = FtlBank::default();
    let mut cache = PoolLayerCache::new();
    cache.register(0, digest);
    let mut shared_makespan = SimTime::ZERO;
    for nid in 1..=n {
        let (src, lat) = cache.fetch(
            &mut WireCtx::at(&mut shared_fabric, &shared_topo, &mut bank, SimTime::ZERO),
            nid,
            digest,
            bytes,
        );
        assert!(matches!(src, FetchSource::Peer(_)));
        shared_makespan = shared_makespan.max(lat);
    }

    // disjoint: n arrays of 2, each pair boots over its own backplane
    let disjoint_cfg = dockerssd::config::PoolConfig {
        nodes_per_array: 2,
        arrays: n,
        ..Default::default()
    };
    let disjoint_topo = PoolTopology::build(&disjoint_cfg);
    let mut disjoint_fabric =
        Fabric::new(&disjoint_cfg, &dockerssd::config::EtherOnConfig::default());
    let mut cache2 = PoolLayerCache::new();
    let mut disjoint_makespan = SimTime::ZERO;
    for a in 0..n {
        cache2.register(2 * a, digest);
        let to = 2 * a + 1;
        let (src, lat) = cache2.fetch(
            &mut WireCtx::at(&mut disjoint_fabric, &disjoint_topo, &mut bank, SimTime::ZERO),
            to,
            digest,
            bytes,
        );
        assert!(matches!(src, FetchSource::Peer(_)));
        disjoint_makespan = disjoint_makespan.max(lat);
    }

    let ratio = shared_makespan.as_ns() as f64 / single.as_ns() as f64;
    assert!(
        (3.5..=4.5).contains(&ratio),
        "N concurrent same-link transfers should take ~Nx one transfer: {ratio:.2}x"
    );
    assert!(
        disjoint_makespan.as_ns() as f64 / single.as_ns() as f64 <= 1.1,
        "disjoint links must overlap: {disjoint_makespan} vs single {single}"
    );
    assert!(
        shared_makespan > disjoint_makespan.scale(2.0),
        "shared-link boot storm must be measurably slower"
    );

    // background prefetch on the contended link never delays a
    // foreground fetch by more than one frame quantum
    let mut pf_fabric = Fabric::new(&shared_cfg, &dockerssd::config::EtherOnConfig::default());
    let mut pf_cache = PoolLayerCache::new();
    pf_cache.register(0, digest);
    pf_cache.prefetch(
        &mut WireCtx::at(&mut pf_fabric, &shared_topo, &mut bank, SimTime::ZERO),
        1,
        digest,
        64 << 20,
    );
    pf_fabric.advance_to(SimTime::ZERO); // grant the engine-scheduled prefetch the wire
    pf_cache.register(2, 0xFEED);
    let (_, fg_lat) = pf_cache.fetch(
        &mut WireCtx::at(&mut pf_fabric, &shared_topo, &mut bank, SimTime::ZERO),
        3,
        0xFEED,
        bytes,
    );
    let idle = pf_fabric.estimate(Endpoint::Node(2), Endpoint::Node(3), bytes);
    let mtu = dockerssd::config::EtherOnConfig::default().mtu;
    let quantum = pf_fabric.link(LinkClass::Array(0)).unwrap().frame_quantum(mtu);
    assert!(
        fg_lat <= idle + quantum,
        "foreground {fg_lat} exceeded idle {idle} + frame quantum {quantum}"
    );

    // fabric.* counters exported
    let mut counters = Counters::new();
    shared_fabric.export_counters(&mut counters);
    assert_eq!(counters.get(names::FABRIC_BYTES_ARRAY), n as u64 * bytes);
    assert!(counters.get(names::FABRIC_QUEUE_WAIT_NS) > 0, "contention must be visible");
    assert_eq!(counters.get(names::FABRIC_TRANSFERS), n as u64);
    assert!(counters.get(names::FABRIC_FRAMES) > 0, "intranet traffic charges Ether-oN frames");
    let mut c2 = Counters::new();
    disjoint_fabric.export_counters(&mut c2);
    assert_eq!(c2.get(names::FABRIC_QUEUE_WAIT_NS), 0, "disjoint links never queue");
}

/// ISSUE 3 acceptance, part 1: `coordinator::serve` is a deterministic
/// simulated-time loop — a serve storm run twice with the same seed
/// produces byte-identical `serve.*` and `fabric.*` counters and
/// identical per-request simulated latencies.
#[test]
fn serve_storm_same_seed_is_byte_identical() {
    let storm = |seed: u64| {
        let mut sim = PoolSim::with_pool(
            &dockerssd::config::PoolConfig {
                nodes_per_array: 4,
                arrays: 1,
                ..Default::default()
            },
            &dockerssd::config::EtherOnConfig::default(),
        );
        let mut rng = Rng::new(seed);
        let requests: Vec<(SimTime, InferenceRequest)> = (0..32u64)
            .map(|id| {
                (
                    SimTime::us(rng.below(2_000)),
                    InferenceRequest {
                        id,
                        prompt: vec![(rng.next_u64() & 0x7FFF) as i32; 8],
                        max_new_tokens: 1 + rng.below(4) as usize,
                    },
                )
            })
            .collect();
        let factories: Vec<_> = (0..4)
            .map(|_| || Ok::<_, anyhow::Error>(EchoExecutor))
            .collect();
        let params = ServeParams {
            batch_width: 4,
            prompt_len: 8,
            batch_window: SimTime::us(150),
            ..Default::default()
        };
        let report = serve(&mut sim, factories, requests, &params);
        let mut c = Counters::new();
        report.export_counters(&mut c);
        sim.export_counters(&mut c);
        let lats: Vec<(u64, SimTime)> =
            report.responses.iter().map(|r| (r.id, r.latency)).collect();
        (c, lats)
    };
    let (c1, l1) = storm(42);
    let (c2, l2) = storm(42);
    assert_eq!(c1, c2, "serve.* and fabric.* counters must be byte-identical");
    assert_eq!(l1, l2, "per-request simulated latencies must be identical");
    assert_eq!(c1.get(names::SERVE_RESPONSES), 32, "every request served");
    assert!(c1.get(names::SERVE_BATCHES) >= 8, "storm formed real batches");
    assert!(
        c1.get(names::FABRIC_BYTES_HOST_UPLINK) > 0,
        "dispatch/response traffic is visible to fabric.* counters"
    );
    assert!(c1.get(names::SERVE_MAKESPAN_NS) > 0);
}

/// ISSUE 4 acceptance: serving a Table 2 trace while a deployment boots
/// on the same clock.  The storm's cold registry pulls (foreground,
/// RegistryWan + HostUplink + Array) and warm peer prefetches
/// (background) overlap dispatch/response traffic on the host uplink,
/// so serve p99 and `fabric.queue_wait_ns` must measurably inflate
/// versus the same replay on a quiet pool.
#[test]
fn boot_storm_inflates_serve_p99_via_host_uplink_contention() {
    use dockerssd::workloads::{trace_arrivals, workload_named, ArrivalParams};

    let spec = workload_named("nginx-filedown").unwrap();
    let run = |storm: u32| {
        let pcfg = dockerssd::config::PoolConfig {
            nodes_per_array: 8,
            arrays: 1,
            ..Default::default()
        };
        let mut sim = PoolSim::with_pool(&pcfg, &dockerssd::config::EtherOnConfig::default());
        if storm > 0 {
            let topo = PoolTopology::build(&pcfg);
            let mut orch = Orchestrator::new();
            let mut cache = PoolLayerCache::new();
            let layers: Vec<(u64, u64)> = (0..2u64).map(|i| (0xB007 + i, 24 << 20)).collect();
            let rep = orch
                .boot_storm_sim(
                    &mut sim,
                    &topo,
                    &DeploymentSpec {
                        name: "storm".into(),
                        image: "llm-worker".into(),
                        replicas: storm,
                        restart: RestartPolicy::OnFailure,
                    },
                    &mut cache,
                    &layers,
                )
                .unwrap();
            assert_eq!(rep.registry_pulls, 2, "one cold pull per layer");
            assert!(rep.peer_prefetches >= 1, "later replicas prefetch from the pool");
        }
        let ap = ArrivalParams { scale: 2_000, ..Default::default() };
        let arr = trace_arrivals(&spec, 42, &ap);
        assert!(arr.requests.len() >= 20, "replay must carry a real request stream");
        let factories: Vec<_> = (0..4)
            .map(|_| || Ok::<_, anyhow::Error>(EchoExecutor))
            .collect();
        let params = ServeParams {
            batch_width: 4,
            prompt_len: ap.engine_prompt_len(),
            batch_window: SimTime::us(200),
            ..Default::default()
        };
        let report = serve(&mut sim, factories, arr.requests, &params);
        let mut c = Counters::new();
        report.export_counters(&mut c);
        sim.export_counters(&mut c);
        (report, c)
    };

    let (quiet, cq) = run(0);
    let (stormy, cs) = run(2);
    assert_eq!(
        quiet.responses.len(),
        stormy.responses.len(),
        "the storm must not drop requests"
    );
    // the pull crossed the WAN and occupied the host uplink foreground
    assert_eq!(cq.get(names::FABRIC_BYTES_WAN), 0);
    assert_eq!(cs.get(names::FABRIC_BYTES_WAN), 2 * (24 << 20));
    assert!(
        cs.get(names::FABRIC_BYTES_HOST_UPLINK)
            > cq.get(names::FABRIC_BYTES_HOST_UPLINK) + 2 * (24 << 20) - 1,
        "pull bytes must show on the uplink on top of serve traffic"
    );
    // dispatches queued behind the pull: contention is visible in both
    // the fabric's queue-wait accounting and the latency tail
    assert!(
        cs.get(names::FABRIC_QUEUE_WAIT_NS) > cq.get(names::FABRIC_QUEUE_WAIT_NS),
        "storm queue wait {} must exceed quiet {}",
        cs.get(names::FABRIC_QUEUE_WAIT_NS),
        cq.get(names::FABRIC_QUEUE_WAIT_NS)
    );
    let p99_quiet = quiet.latency.quantile(0.99);
    let p99_storm = stormy.latency.quantile(0.99);
    assert!(
        p99_storm > p99_quiet,
        "boot storm must inflate serve p99: {p99_storm} !> {p99_quiet}"
    );
    assert!(stormy.makespan > quiet.makespan, "delayed dispatches stretch the makespan");
}

/// ISSUE 3 acceptance, part 2: concurrent docker pulls and LLM
/// collective steps contend on a shared link — the combined makespan
/// exceeds the larger of either running alone, because both now price
/// their bytes on the one pool fabric.
#[test]
fn docker_pull_and_llm_step_contend_on_shared_link() {
    let cfg = SystemConfig::default(); // 16 nodes, one array
    let llm = all_llms().remove(0);
    let par = Parallelism { dp: 1, tp: 8, pp: 1 };
    let traffic = step_traffic(&llm, par, 32_768, 1, true, false); // ring on nodes 0..7

    let node_stack = || {
        let dev = SsdDevice::new(cfg.ssd.clone());
        let fs = LambdaFs::over_device(&dev);
        (MiniDocker::new(), VirtualFw::new(&cfg.ssd), fs, dev)
    };
    let reg = Registry::with_benchmark_images();
    let image_bytes: u64 = reg
        .fetch("mariadb")
        .unwrap()
        .1
        .iter()
        .map(|b| b.bytes.len() as u64)
        .sum();

    // pull alone on an idle fabric
    let topo = PoolTopology::build(&cfg.pool);
    let mut bank = FtlBank::default();
    let mut fa = Fabric::of(&cfg);
    let (mut md, mut fw, mut fs, mut dev) = node_stack();
    let pull_alone = md
        .pull(
            &mut fw,
            &mut fs,
            &mut dev,
            &reg,
            &mut WireCtx::at(&mut fa, &topo, &mut bank, SimTime::ZERO),
            0,
            "mariadb",
        )
        .unwrap()
        .done;

    // collective step alone on an idle fabric
    let mut fb = Fabric::of(&cfg);
    let step_alone = pool_step_time(&mut fb, SimTime::ZERO, &traffic);

    // combined on ONE fabric: the step occupies the array backplane,
    // the pull (same instant, node 0 on that array) queues behind it
    let mut fc = Fabric::of(&cfg);
    let step_combined = pool_step_time(&mut fc, SimTime::ZERO, &traffic);
    let (mut md2, mut fw2, mut fs2, mut dev2) = node_stack();
    let pull_combined = md2
        .pull(
            &mut fw2,
            &mut fs2,
            &mut dev2,
            &reg,
            &mut WireCtx::at(&mut fc, &topo, &mut bank, SimTime::ZERO),
            0,
            "mariadb",
        )
        .unwrap()
        .done;
    let combined = step_combined.max(pull_combined);

    assert_eq!(step_combined, step_alone, "the step was issued first and is undisturbed");
    assert!(
        pull_combined > pull_alone,
        "the pull must queue behind the collective: {pull_combined} !> {pull_alone}"
    );
    assert!(
        combined > pull_alone.max(step_alone),
        "combined {combined} must exceed max(pull alone {pull_alone}, step alone {step_alone})"
    );

    // and the pull's registry bytes are no longer invisible to fabric.*
    let mut c = Counters::new();
    fc.export_counters(&mut c);
    assert_eq!(
        c.get(names::FABRIC_BYTES_WAN),
        image_bytes,
        "the whole mariadb image crossed the WAN"
    );
}

/// ISSUE 8 acceptance: on Table 2 LLM serving rows, the streamed wire
/// policy cuts `fabric.bytes_host_uplink` per served token by >= 3x
/// against the pre-PR hairpin baseline, at equal-or-better simulated
/// p99, serving byte-identical token content — and the streamed run
/// replays byte-identically under the same seed.
///
/// (rocksdb-write is deliberately not pinned: its prompts carry the
/// full write payload, which is genuine ingress no wire policy can
/// remove.)
#[test]
fn streamed_wire_cuts_uplink_3x_on_table2_rows() {
    use dockerssd::coordinator::WirePolicy;
    use dockerssd::workloads::{trace_arrivals, workload_named, ArrivalParams};

    for row in ["mariadb-tpch4", "nginx-filedown"] {
        let spec = workload_named(row).unwrap();
        let run = |wire: WirePolicy| {
            let pcfg = dockerssd::config::PoolConfig {
                nodes_per_array: 8,
                arrays: 1,
                ..Default::default()
            };
            let mut sim = PoolSim::with_pool(&pcfg, &dockerssd::config::EtherOnConfig::default());
            let ap = ArrivalParams { scale: 2_000, ..Default::default() };
            let arr = trace_arrivals(&spec, 42, &ap);
            let factories: Vec<_> = (0..4)
                .map(|_| || Ok::<_, anyhow::Error>(EchoExecutor))
                .collect();
            let params = ServeParams {
                batch_width: 4,
                prompt_len: ap.engine_prompt_len(),
                batch_window: SimTime::us(200),
                wire,
                ..Default::default()
            };
            let report = serve(&mut sim, factories, arr.requests, &params);
            let mut c = Counters::new();
            report.export_counters(&mut c);
            sim.export_counters(&mut c);
            (report, c)
        };
        let (hr, hc) = run(WirePolicy::Hairpin);
        let (sr, sc) = run(WirePolicy::Streamed);
        assert_eq!(sr.tokens_out, hr.tokens_out, "{row}: wire policy never changes content");
        let tokens = sr.tokens_out.max(1);
        let h_up = hc.get(names::FABRIC_BYTES_HOST_UPLINK) / tokens;
        let s_up = sc.get(names::FABRIC_BYTES_HOST_UPLINK) / tokens;
        assert!(
            h_up >= 3 * s_up.max(1),
            "{row}: hairpin {h_up} B/token vs streamed {s_up} B/token — need >= 3x"
        );
        // dispatch receipts can only move earlier (fewer uplink bytes at
        // identical instants) and the response wire is unchanged, but an
        // earlier KV release can cascade into different migration
        // instants — 1% slack absorbs that scheduling noise without
        // letting a real p99 regression through
        let hp99 = hr.latency.quantile(0.99);
        let sp99 = sr.latency.quantile(0.99);
        assert!(
            sp99 <= hp99 + SimTime::ns(hp99.as_ns() / 100),
            "{row}: streamed p99 {sp99} regressed past hairpin p99 {hp99}"
        );
        let (sr2, sc2) = run(WirePolicy::Streamed);
        assert_eq!(sc, sc2, "{row}: same-seed streamed counters diverged");
        assert_eq!(sr.host_bytes, sr2.host_bytes, "{row}: host-byte accounting diverged");
    }
}

/// ISSUE 9 acceptance: on the image behind the `rocksdb-write` Table 2
/// row, booting replicas through the dedup'd store with a CoW writable
/// layer per replica programs strictly less flash than whole-blob
/// copies — visible in `ftl.waf`/`ftl.wear_max`/`ftl.host_pages` — and
/// two same-seed runs of the priced path are byte-identical.
#[test]
fn rocksdb_write_dedup_cow_reduces_flash_writes_vs_whole_blob() {
    use dockerssd::workloads::workload_named;

    let image = workload_named("rocksdb-write").unwrap().benchmark.name();
    let cfg = SystemConfig::default();
    let topo = PoolTopology::build(&cfg.pool);
    let replicas = 4u32;

    // whole-blob baseline: every replica re-lands the full image, so the
    // node's FTL programs every byte N times over
    let mut plain_bank = FtlBank::default();
    {
        let mut fabric = Fabric::of(&cfg);
        let (mut md, mut fw, mut fs, mut dev, reg, _) = rig();
        for _ in 0..replicas {
            md.pull(
                &mut fw,
                &mut fs,
                &mut dev,
                &reg,
                &mut WireCtx::at(&mut fabric, &topo, &mut plain_bank, SimTime::ZERO),
                0,
                image,
            )
            .unwrap();
        }
    }

    // dedup + CoW path: the store lands the image once; later replicas
    // reuse the resident layers and dirty one CoW page each
    let priced = || {
        let mut bank = FtlBank::default();
        let mut fabric = Fabric::of(&cfg);
        let (mut md, mut fw, mut fs, mut dev, reg, _) = rig();
        let mut store = LayerStore::default();
        let mut t = SimTime::ZERO;
        for _ in 0..replicas {
            let pulled = md
                .pull_via_store(
                    &mut fw,
                    &mut fs,
                    &mut dev,
                    &reg,
                    &mut store,
                    &mut WireCtx::at(&mut fabric, &topo, &mut bank, t),
                    0,
                    image,
                    None,
                )
                .unwrap();
            let ran = md.run_cow(&mut fw, &mut fs, &mut dev, &mut store, pulled.done, image).unwrap();
            let layer = md.cow_layer_of(&ran.output).unwrap();
            md.cow
                .write_at(&mut store, &mut fs, &mut dev, ran.done, layer, 0, &[0xD8; 4096])
                .unwrap();
            t = ran.done;
        }
        let mut c = Counters::new();
        bank.export_counters(&mut c);
        c
    };
    let c = priced();
    let c2 = priced();
    assert_eq!(c, c2, "same-seed priced boots must be byte-identical");

    let mut plain = Counters::new();
    plain_bank.export_counters(&mut plain);
    assert!(
        c.get(names::FTL_HOST_PAGES) < plain.get(names::FTL_HOST_PAGES),
        "dedup + CoW must program strictly less flash: {} !< {}",
        c.get(names::FTL_HOST_PAGES),
        plain.get(names::FTL_HOST_PAGES)
    );
    // the store path lands the image exactly once; N whole-blob copies
    // land it N times
    assert_eq!(
        plain.get(names::FTL_HOST_PAGES),
        replicas as u64 * c.get(names::FTL_HOST_PAGES),
        "whole-blob copies re-program per replica"
    );
    // flash economics are exported under the canonical names
    assert!(c.get(names::FTL_WAF) >= 1000, "WAF can never drop below 1.0");
    assert!(plain.get(names::FTL_WAF) >= 1000);
    assert!(c.get(names::FTL_HOST_PAGES) > 0, "the cold pull must be priced");
    // wear is tracked (a boot this small need not complete an erase)
    let _ = c.get(names::FTL_WEAR_MAX);
}
