//! Figure/table reproduction gates: every headline claim of the paper's
//! evaluation, asserted within tolerance (EXPERIMENTS.md records achieved
//! values).  Tolerances are deliberately generous — the substrate is a
//! calibrated simulator, the *shape* must hold (who wins, by roughly what
//! factor, where crossovers fall).

use dockerssd::firmware::{fw_image, linux_image, CostModel};
use dockerssd::llm::all_llms;
use dockerssd::llm::disagg::{
    aggregate_ratio, batch_sweep, crossover_seq, fig12_sweep, seq_sweep, DisaggModel,
};
use dockerssd::llm::ParallelKind;
use dockerssd::models::{evaluate, geomean_ratio, ModelKind};
use dockerssd::workloads::all_workloads;

fn close(got: f64, want: f64, rel: f64) -> bool {
    (got / want).ln().abs() < rel.ln()
}

// --- Figure 3 ---------------------------------------------------------------

#[test]
fn fig3_host_storage_fraction_near_38pct() {
    let c = CostModel::calibrated();
    let ws = all_workloads();
    let mean: f64 = ws
        .iter()
        .map(|w| {
            let b = evaluate(ModelKind::Host, w, &c);
            b.storage / b.total()
        })
        .sum::<f64>()
        / ws.len() as f64;
    assert!((0.28..0.50).contains(&mean), "storage fraction {mean:.2} (paper 0.38)");
}

#[test]
fn fig3_pisp_slower_than_host_with_dominant_communicate() {
    let c = CostModel::calibrated();
    let r = geomean_ratio(ModelKind::PIspR, ModelKind::Host, &c);
    assert!((1.15..1.8).contains(&r), "P.ISP/Host {r:.2} (paper 1.4)");
    let ws = all_workloads();
    let comm: f64 = ws
        .iter()
        .map(|w| {
            let b = evaluate(ModelKind::PIspR, w, &c);
            b.communicate() / b.total()
        })
        .sum::<f64>()
        / ws.len() as f64;
    assert!((0.28..0.55).contains(&comm), "communicate fraction {comm:.2} (paper 0.43)");
}

#[test]
fn fig3_pisp_storage_half_of_host() {
    let c = CostModel::calibrated();
    let ws = all_workloads();
    let mean: f64 = ws
        .iter()
        .map(|w| {
            evaluate(ModelKind::PIspR, w, &c).storage / evaluate(ModelKind::Host, w, &c).storage
        })
        .sum::<f64>()
        / ws.len() as f64;
    assert!((0.35..0.70).contains(&mean), "P.ISP/Host storage {mean:.2} (paper 0.5)");
}

// --- Figure 10 ----------------------------------------------------------------

#[test]
fn fig10_image_size_reduction_near_83x() {
    let f = linux_image().total_bytes() as f64 / fw_image().total_bytes() as f64;
    assert!(close(f, 83.4, 1.35), "reduction {f:.1}x (paper 83.4x)");
}

// --- Figure 11 ----------------------------------------------------------------

#[test]
fn fig11_dvirtfw_beats_host_by_about_1_3x() {
    let c = CostModel::calibrated();
    let r = geomean_ratio(ModelKind::Host, ModelKind::DVirtFw, &c);
    assert!(close(r, 1.3, 1.25), "Host/D-VirtFW {r:.2} (paper 1.3)");
}

#[test]
fn fig11_dvirtfw_beats_pisp_by_1_6_to_1_8x() {
    let c = CostModel::calibrated();
    let r = geomean_ratio(ModelKind::PIspR, ModelKind::DVirtFw, &c);
    assert!((1.35..2.2).contains(&r), "P.ISP-R/D-VirtFW {r:.2} (paper ~1.6-1.8)");
    let v = geomean_ratio(ModelKind::PIspV, ModelKind::DVirtFw, &c);
    assert!((1.2..2.0).contains(&v), "P.ISP-V/D-VirtFW {v:.2}");
}

#[test]
fn fig11_dvirtfw_beats_dnaive_and_dfullos() {
    let c = CostModel::calibrated();
    let naive = geomean_ratio(ModelKind::DNaive, ModelKind::DVirtFw, &c);
    let fullos = geomean_ratio(ModelKind::DFullOs, ModelKind::DVirtFw, &c);
    assert!(close(naive, 1.8, 1.3), "D-Naive/D-VirtFW {naive:.2} (paper 1.8)");
    assert!(close(fullos, 1.6, 1.3), "D-FullOS/D-VirtFW {fullos:.2} (paper 1.6)");
    assert!(naive > fullos, "D-Naive must be slower than D-FullOS");
}

#[test]
fn fig11_secondary_orderings() {
    let c = CostModel::calibrated();
    // P.ISP-V ~13.7% faster than P.ISP-R
    let vr = geomean_ratio(ModelKind::PIspV, ModelKind::PIspR, &c);
    assert!((0.75..0.95).contains(&vr), "V/R {vr:.3} (paper 0.863)");
    // D-FullOS ~9.3% slower than P.ISP-V
    let fv = geomean_ratio(ModelKind::DFullOs, ModelKind::PIspV, &c);
    assert!((1.0..1.35).contains(&fv), "D-FullOS/P.ISP-V {fv:.3} (paper 1.093)");
    // D-Naive ~12.8% slower than D-FullOS
    let nf = geomean_ratio(ModelKind::DNaive, ModelKind::DFullOs, &c);
    assert!((1.03..1.35).contains(&nf), "D-Naive/D-FullOS {nf:.3} (paper 1.128)");
}

// --- Figure 12 -----------------------------------------------------------------

#[test]
fn fig12a_parallelism_pattern() {
    // NoCache -> pipeline-dominant; Cache -> tensor-dominant
    let rs = fig12_sweep(32_768, 1);
    let mut cache_tensor = 0;
    let mut cache_total = 0;
    let mut nocache_pipeline = 0;
    let mut nocache_total = 0;
    for r in &rs {
        if r.disagg.kv_cache() {
            cache_total += 1;
            if r.choice.par.dominant() == ParallelKind::Tensor {
                cache_tensor += 1;
            }
        } else {
            nocache_total += 1;
            if r.choice.par.dominant() == ParallelKind::Pipeline {
                nocache_pipeline += 1;
            }
        }
    }
    assert!(cache_tensor * 10 >= cache_total * 9, "{cache_tensor}/{cache_total} cache scenarios tensor-parallel");
    assert!(
        nocache_pipeline * 10 >= nocache_total * 8,
        "{nocache_pipeline}/{nocache_total} nocache scenarios pipeline-parallel"
    );
}

#[test]
fn fig12b_kv_cache_gains() {
    let h = aggregate_ratio(DisaggModel::HostNoCache, DisaggModel::HostCache, 32_768, 1);
    assert!((100.0..1500.0).contains(&h), "H-NoCache/H-Cache {h:.0} (paper 421)");
    let d = aggregate_ratio(DisaggModel::DockerNoCache, DisaggModel::DockerCache, 32_768, 1);
    assert!((1000.0..15000.0).contains(&d), "D-NoCache/D-Cache {d:.0} (paper 4600)");
    assert!(d > h, "flash-local KV must gain more than swap KV");
}

#[test]
fn fig12b_dcache_beats_hcache_by_about_7_9x() {
    let r = aggregate_ratio(DisaggModel::HostCache, DisaggModel::DockerCache, 32_768, 1);
    assert!(close(r, 7.9, 1.45), "H-Cache/D-Cache {r:.1} (paper 7.9)");
}

#[test]
fn fig12b_dnocache_1_7x_slower_than_hnocache() {
    let r = aggregate_ratio(DisaggModel::DockerNoCache, DisaggModel::HostNoCache, 32_768, 1);
    assert!(close(r, 1.7, 1.2), "D-NoCache/H-NoCache {r:.2} (paper 1.7)");
}

#[test]
fn fig12b_dcache_vs_hnocache_3_2kx() {
    let r = aggregate_ratio(DisaggModel::HostNoCache, DisaggModel::DockerCache, 32_768, 1);
    assert!((800.0..8000.0).contains(&r), "H-NoCache/D-Cache {r:.0} (paper 3200)");
}

// --- Figure 13 -----------------------------------------------------------------

#[test]
fn fig13a_crossovers_at_256_and_1024() {
    let llms = all_llms();
    let x_lamda = crossover_seq(&llms[0], 16).expect("lamda crossover");
    let x_megatron = crossover_seq(&llms[7], 128).expect("megatron crossover");
    assert!((128..=512).contains(&x_lamda), "lamda crossover {x_lamda} (paper 256)");
    assert!((512..=2048).contains(&x_megatron), "megatron crossover {x_megatron} (paper 1024)");
    assert!(x_megatron > x_lamda, "larger model crosses later");
}

#[test]
fn fig13b_speedup_converges_toward_9_5x() {
    let llms = all_llms();
    let pts = seq_sweep(&llms[0], 16, &[1 << 17], 1);
    let converged = pts[0].1;
    assert!(close(converged, 9.5, 1.25), "long-seq speedup {converged:.1} (paper ~9.5)");
}

#[test]
fn fig13b_short_sequences_run_at_60pct_of_host() {
    let llms = all_llms();
    let pts = seq_sweep(&llms[0], 16, &[64], 1);
    let speedup = pts[0].1; // D/H speedup < 1 at short seq
    assert!((0.45..0.9).contains(&speedup), "short-seq relative perf {speedup:.2} (paper ~0.6)");
}

#[test]
fn fig13cd_batch_gain_is_modest() {
    let llms = all_llms();
    for (llm, nodes) in [(&llms[0], 16u32), (&llms[7], 128u32)] {
        let pts = batch_sweep(llm, nodes, 512, &[1, 8, 64, 512]);
        for (b, sp) in pts {
            assert!(sp < 1.8, "{} batch {b}: speedup {sp:.2} (paper max ~1.3)", llm.name);
        }
    }
}

// --- Table 2 -------------------------------------------------------------------

#[test]
fn table2_counts_transcribed() {
    let ws = all_workloads();
    assert_eq!(ws.len(), 13);
    let tpch4 = ws.iter().find(|w| w.full_name() == "mariadb-tpch4").unwrap();
    assert_eq!(tpch4.io_count, 1_100_000);
    assert_eq!(tpch4.path_walks, 37_000);
    let fileup = ws.iter().find(|w| w.full_name() == "vsftpd-fileup").unwrap();
    assert_eq!(fileup.syscalls, 5_400_000);
    assert_eq!(fileup.tcp_packets, 1_200_000);
}
