//! End-to-end PJRT tests: the real three-layer path (Pallas -> HLO text
//! -> Rust PJRT execution).  These skip gracefully when `make artifacts`
//! has not run (e.g. a bare `cargo test` in a fresh checkout).

use std::path::PathBuf;

use dockerssd::coordinator::{serve, InferenceRequest, ServeParams};
use dockerssd::runtime::Engine;
use dockerssd::sim::PoolSim;
use dockerssd::util::SimTime;

fn art_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    art_dir().join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

#[test]
fn engine_loads_and_generates_deterministically() {
    require_artifacts!();
    let mut e = Engine::load(&art_dir()).expect("engine");
    let b = e.batch();
    let p = e.prompt_len();
    let vocab = e.manifest.config.vocab as i32;
    let prompt: Vec<Vec<i32>> = (0..b)
        .map(|r| (0..p as i32).map(|i| (r as i32 * 31 + i * 7) % vocab).collect())
        .collect();
    let gen1 = e.generate(&prompt, 8).expect("generate");
    assert_eq!(gen1.len(), b);
    assert!(gen1.iter().all(|row| row.len() == 8));
    assert!(gen1.iter().flatten().all(|&t| t >= 0 && t < vocab));

    // determinism across a fresh engine
    let mut e2 = Engine::load(&art_dir()).expect("engine2");
    let gen2 = e2.generate(&prompt, 8).expect("generate2");
    assert_eq!(gen1, gen2, "greedy decode must be deterministic");
}

#[test]
fn decode_depends_on_prompt() {
    require_artifacts!();
    let mut e = Engine::load(&art_dir()).expect("engine");
    let b = e.batch();
    let p = e.prompt_len();
    let prompt_a: Vec<Vec<i32>> = vec![vec![1; p]; b];
    let prompt_b: Vec<Vec<i32>> = vec![vec![2; p]; b];
    let ga = e.generate(&prompt_a, 6).unwrap();
    let mut e2 = Engine::load(&art_dir()).unwrap();
    let gb = e2.generate(&prompt_b, 6).unwrap();
    assert_ne!(ga, gb, "different prompts must generate differently");
}

#[test]
fn prefill_then_stepwise_decode_positions_advance() {
    require_artifacts!();
    let mut e = Engine::load(&art_dir()).expect("engine");
    let b = e.batch();
    let p = e.prompt_len();
    let prompt: Vec<Vec<i32>> = vec![(0..p as i32).collect(); b];
    let out = e.prefill(&prompt).unwrap();
    assert_eq!(e.pos, p);
    let toks = out.argmax();
    e.decode_step(&toks).unwrap();
    assert_eq!(e.pos, p + 1);
    assert_eq!(e.decode_steps, 1);
}

#[test]
fn pool_serving_over_two_engines() {
    require_artifacts!();
    let dir = art_dir();
    let manifest = dockerssd::runtime::Manifest::load(&dir).unwrap();
    let c = manifest.config;
    let requests: Vec<(SimTime, InferenceRequest)> = (0..6u64)
        .map(|id| {
            (
                SimTime::us(id * 100),
                InferenceRequest {
                    id,
                    prompt: (0..c.prompt_len)
                        .map(|i| ((id as usize * 13 + i) % c.vocab) as i32)
                        .collect(),
                    max_new_tokens: 4,
                },
            )
        })
        .collect();
    let factories: Vec<_> = (0..2)
        .map(|_| {
            let dir = dir.clone();
            move || Engine::load(&dir)
        })
        .collect();
    let params = ServeParams {
        batch_width: c.batch,
        prompt_len: c.prompt_len,
        ..Default::default()
    };
    let mut sim = PoolSim::new(&dockerssd::config::SystemConfig::default());
    let report = serve(&mut sim, factories, requests, &params);
    assert_eq!(report.responses.len(), 6);
    let mut ids: Vec<u64> = report.responses.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(ids, (0..6).collect::<Vec<u64>>());
    assert!(report.tokens_out >= 6 * 4);
    assert!(report.throughput_tok_s() > 0.0);
}
