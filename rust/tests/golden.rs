//! Golden-file gate for the CI serve-smoke scenario (ISSUE 5).
//!
//! `ci/serve_smoke.sh` runs `repro serve --workload nginx-filedown
//! --nodes 4 --scale 2000 --seed 42 --boot-storm 2` and greps the
//! deterministic `serve.*`/`fabric.*`/`sim.*` counter lines; this test
//! re-derives exactly those lines in-process through the shared
//! [`dockerssd::smoke`] module, so the committed golden at
//! `ci/golden/serve_smoke.txt` is gated from two independent directions
//! (binary replay and library replay) and can be (re)seeded from a
//! local deterministic run:
//!
//! ```sh
//! UPDATE_GOLDEN=1 cargo test --test golden
//! ```
//!
//! Env knobs: `UPDATE_GOLDEN=1` rewrites the committed golden;
//! `GOLDEN_OUT=<path>` additionally writes the fresh lines to `<path>`
//! (CI uses it to diff against the binary's grep output).

use dockerssd::smoke::{self, SmokeParams};

fn golden_path() -> String {
    format!("{}/ci/golden/serve_smoke.txt", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn golden_serve_smoke_is_rederivable_and_deterministic() {
    let p = SmokeParams::ci();
    let a = smoke::run(&p).expect("the CI workload row exists");
    let b = smoke::run(&p).expect("the CI workload row exists");
    assert_eq!(a.counters, b.counters, "same-seed smoke replays diverged");
    assert_eq!(
        a.report.responses.len() as u64,
        a.report.requests,
        "the smoke replay must serve every request"
    );
    let storm = a.storm.as_ref().expect("the CI scenario boots a storm");
    assert!(storm.registry_pulls > 0, "a cold pool pulls at least one layer");

    let lines = smoke::counter_lines(&a.counters);
    assert!(
        lines.lines().count() >= 10,
        "expected a full serve./fabric./sim. counter block, got:\n{lines}"
    );
    for must in ["serve.responses", "fabric.bytes_wan", "sim.events_processed"] {
        assert!(lines.contains(must), "missing {must} in:\n{lines}");
    }

    if let Ok(out) = std::env::var("GOLDEN_OUT") {
        std::fs::write(&out, &lines).expect("write GOLDEN_OUT");
        eprintln!("fresh smoke counters written to {out}");
    }
    let path = golden_path();
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::write(&path, &lines).expect("write golden");
        eprintln!("golden refreshed at {path}");
        return;
    }
    match std::fs::read_to_string(&path) {
        Ok(golden) => assert_eq!(
            golden, lines,
            "counters diverged from the committed golden — if the scheduling change is \
             intentional, refresh with `UPDATE_GOLDEN=1 cargo test --test golden`"
        ),
        // Not yet committed: determinism and the binary cross-check still
        // gate; the golden arm arms itself the moment the file lands.
        Err(_) => eprintln!(
            "no golden committed at {path}; seed it with `UPDATE_GOLDEN=1 cargo test --test golden`"
        ),
    }
}
