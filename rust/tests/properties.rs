//! Property-based tests over substrate and coordinator invariants.
//!
//! Offline-build substitution (DESIGN.md §4): proptest is unavailable, so
//! properties are driven by the deterministic in-crate PRNG across many
//! random cases per property (seeded, reproducible).  Each test states
//! its invariant explicitly.
//!
//! Case counts honor the `PROPTEST_CASES` env var (proptest's knob, kept
//! for CI muscle memory): the deep CI job runs the suite with
//! `PROPTEST_CASES=1024`, scaling every property's case count
//! proportionally.  Failures name their base seed and case index, so a
//! deep-run counterexample reproduces locally with the same env.

use dockerssd::config::SsdConfig;
use dockerssd::coordinator::{Batcher, InferenceRequest, Router};
use dockerssd::etheron::frame::{EthFrame, EtherType, Ipv4Packet, MacAddr, TcpSegment, TcpFlags};
use dockerssd::lambdafs::{InodeLockTable, LambdaFs, LockSide};
use dockerssd::layerstore::{CowStore, LayerStore};
use dockerssd::llm::{all_llms, sequence_time, DeviceProfile, Parallelism};
use dockerssd::nvme::{NvmeCommand, SubmissionQueue};
use dockerssd::ssd::{Ftl, SsdDevice};
use dockerssd::util::{fnv1a, Rng, SimTime};

/// Base case count at the default budget (`PROPTEST_CASES` unset = 200).
fn cases() -> u64 {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200)
}

/// A property whose default budget is `base` cases, scaled by the same
/// `PROPTEST_CASES / 200` factor as the 200-case properties.
fn scaled(base: u64) -> u64 {
    (base.saturating_mul(cases()) / 200).max(1)
}

/// NVMe SQ: commands are never lost, duplicated, or reordered.
#[test]
fn prop_nvme_queue_preserves_commands() {
    let mut rng = Rng::new(1);
    for case in 0..cases() {
        let depth = 2 + rng.below(62) as usize;
        let mut sq = SubmissionQueue::new(depth);
        let n = rng.below(depth as u64 * 2) as u16;
        let mut submitted = Vec::new();
        for cid in 0..n {
            if sq.submit(NvmeCommand::read(cid, 1, cid as u64, 0)).is_ok() {
                submitted.push(cid);
            }
        }
        let mut fetched = Vec::new();
        while let Some(cmd) = sq.fetch() {
            fetched.push(cmd.cid);
        }
        assert_eq!(submitted, fetched, "case {case} depth {depth}");
    }
}

/// Ethernet/IP/TCP frames round-trip byte-exactly for arbitrary payloads.
#[test]
fn prop_frame_codecs_round_trip() {
    let mut rng = Rng::new(2);
    for _ in 0..cases() {
        let len = rng.below(1400) as usize;
        let payload: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let seg = TcpSegment {
            src_port: rng.next_u64() as u16,
            dst_port: rng.next_u64() as u16,
            seq: rng.next_u64() as u32,
            ack: rng.next_u64() as u32,
            flags: TcpFlags::ACK,
            window: rng.next_u64() as u16,
            payload: payload.clone(),
        };
        assert_eq!(TcpSegment::decode(&seg.encode()), Some(seg.clone()));
        let ip = Ipv4Packet {
            src: std::net::Ipv4Addr::new(10, 77, 0, 1),
            dst: std::net::Ipv4Addr::new(10, 77, 0, 2),
            protocol: 6,
            payload: seg.encode(),
        };
        assert_eq!(Ipv4Packet::decode(&ip.encode()), Some(ip.clone()));
        let eth = EthFrame {
            dst: MacAddr::for_node(rng.next_u64() as u32),
            src: MacAddr::for_node(rng.next_u64() as u32),
            ethertype: EtherType::Ipv4,
            payload: ip.encode(),
        };
        assert_eq!(EthFrame::decode(&eth.encode()), Some(eth));
    }
}

/// FTL: after any interleaving of writes/overwrites, every mapped LPN
/// translates to a unique PPA (no aliasing).
#[test]
fn prop_ftl_mappings_never_alias() {
    let mut rng = Rng::new(3);
    let cfg = SsdConfig {
        channels: 2,
        packages_per_channel: 2,
        blocks_per_package: 32,
        pages_per_block: 32,
        ..Default::default()
    };
    for _ in 0..scaled(40) {
        let mut ftl = Ftl::new(&cfg);
        let universe = 256u64;
        for _ in 0..1500 {
            ftl.map_write(rng.below(universe));
            if ftl.needs_gc() {
                if let Some((victim, valid)) = ftl.pick_gc_victim() {
                    for lpn in valid {
                        ftl.map_write(lpn);
                    }
                    ftl.finish_gc(victim);
                }
            }
        }
        // all mapped LPNs resolve to distinct PPAs
        let mut seen = std::collections::HashSet::new();
        for lpn in 0..universe {
            let before = ftl.mapped_pages();
            let ppa = ftl.translate_or_map(lpn);
            let _ = before;
            assert!(seen.insert(ppa), "PPA aliased for lpn {lpn}");
        }
    }
}

/// SSD device: read-after-write returns the written bytes, regardless of
/// cache state and GC activity.
#[test]
fn prop_ssd_read_after_write() {
    use dockerssd::nvme::BlockBackend;
    let mut rng = Rng::new(4);
    let cfg = SsdConfig {
        blocks_per_package: 64,
        icl_fraction: 0.01,
        ..Default::default()
    };
    let mut dev = SsdDevice::new(cfg);
    let mut shadow: std::collections::HashMap<u64, Vec<u8>> = Default::default();
    for _ in 0..400 {
        let lba = rng.below(4096) * 8;
        if rng.chance(0.6) || !shadow.contains_key(&lba) {
            let val = vec![rng.next_u64() as u8; 4096];
            dev.write(SimTime::ZERO, lba, &val);
            shadow.insert(lba, val);
        } else {
            let (_, data) = dev.read(SimTime::ZERO, lba, 8);
            assert_eq!(&data[..], &shadow[&lba][..], "lba {lba}");
        }
    }
}

/// Inode lock: mutual exclusion holds under arbitrary acquire/release
/// sequences, and counters never go negative.
#[test]
fn prop_inode_lock_mutual_exclusion() {
    let mut rng = Rng::new(5);
    for _ in 0..cases() {
        let mut t = InodeLockTable::new();
        let mut host_refs = 0i64;
        let mut isp_refs = 0i64;
        for _ in 0..100 {
            let side = if rng.chance(0.5) { LockSide::Host } else { LockSide::Isp };
            if rng.chance(0.6) {
                if t.acquire(7, side) {
                    match side {
                        LockSide::Host => host_refs += 1,
                        LockSide::Isp => isp_refs += 1,
                    }
                }
            } else {
                t.release(7, side);
                match side {
                    LockSide::Host => host_refs = (host_refs - 1).max(0),
                    LockSide::Isp => isp_refs = (isp_refs - 1).max(0),
                }
            }
            // invariant: never both sides holding
            assert!(!(host_refs > 0 && isp_refs > 0), "both sides hold the inode");
            // model agrees with table
            assert_eq!(t.may_access(7, LockSide::Host), isp_refs == 0);
            assert_eq!(t.may_access(7, LockSide::Isp), host_refs == 0);
        }
    }
}

/// Batcher: every pushed request appears in exactly one formed batch.
#[test]
fn prop_batcher_conservation() {
    let mut rng = Rng::new(6);
    for _ in 0..cases() {
        let width = 1 + rng.below(8) as usize;
        let n = rng.below(50);
        let mut b = Batcher::new(width, 16, SimTime::ZERO);
        for id in 0..n {
            b.push(
                InferenceRequest {
                    id,
                    prompt: vec![1; rng.below(40) as usize],
                    max_new_tokens: 1 + rng.below(8) as usize,
                },
                SimTime::ns(id),
            );
        }
        let mut seen = Vec::new();
        while let Some(batch) = b.form(SimTime::ns(n), true) {
            assert!(batch.live <= width);
            assert_eq!(batch.prompts.len(), width);
            for p in &batch.prompts {
                assert_eq!(p.len(), 16, "prompt normalized");
            }
            seen.extend(batch.requests.iter().map(|r| r.id));
        }
        seen.sort();
        assert_eq!(seen, (0..n).collect::<Vec<u64>>());
    }
}

/// Router: outstanding counts stay bounded by picks minus completes, and
/// dispatch imbalance never exceeds 1 when all batches complete promptly.
#[test]
fn prop_router_balance() {
    let mut rng = Rng::new(7);
    for _ in 0..cases() {
        let nodes = 1 + rng.below(16) as usize;
        let mut r = Router::new(nodes);
        let picks = rng.below(200);
        for _ in 0..picks {
            let n = r.pick();
            r.complete(n);
        }
        let counts: Vec<u64> = (0..nodes as u32).map(|n| r.dispatched_of(n)).collect();
        let min = counts.iter().min().unwrap();
        let max = counts.iter().max().unwrap();
        assert!(max - min <= 1, "imbalance {counts:?}");
    }
}

/// LLM simulator monotonicity: total time grows with sequence length and
/// with batch size; memory requirement grows with KV.
#[test]
fn prop_llm_monotonicity() {
    let mut rng = Rng::new(8);
    let llms = all_llms();
    for _ in 0..60 {
        let llm = &llms[rng.below(llms.len() as u64) as usize];
        let dev = DeviceProfile::dockerssd();
        let tp = 1 << rng.below(5);
        let par = Parallelism { dp: 1, tp, pp: 1 };
        let s1 = 64 << rng.below(6);
        let s2 = s1 * 2;
        let t1 = sequence_time(llm, &dev, par, s1, 1, true).total();
        let t2 = sequence_time(llm, &dev, par, s2, 1, true).total();
        assert!(t2 > t1, "{}: seq {s1}->{s2} time {t1}->{t2}", llm.name);
        let b1 = sequence_time(llm, &dev, par, s1, 1, true).total();
        let b4 = sequence_time(llm, &dev, par, s1, 4, true).total();
        assert!(b4 >= b1, "{}: batch must not speed up fixed parallelism", llm.name);
    }
}

// --- layerstore invariants --------------------------------------------------

fn layerstore_rig(chunk_bytes: usize) -> (LayerStore, LambdaFs, SsdDevice) {
    let dev = SsdDevice::new(SsdConfig::default());
    let fs = LambdaFs::over_device(&dev);
    (LayerStore::new(chunk_bytes), fs, dev)
}

/// LayerStore: store/retrieve round-trips both bytes and digest for
/// arbitrary content and sizes (including chunk-boundary straddlers).
#[test]
fn prop_layerstore_round_trips_digests() {
    let mut rng = Rng::new(21);
    let (mut st, mut fs, mut dev) = layerstore_rig(4 << 10);
    for case in 0..scaled(60) {
        let len = rng.below(40_000) as usize;
        let body: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let w = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &body).unwrap();
        assert_eq!(w.value, fnv1a(&body), "case {case}: digest is content hash");
        let r = st.get_blob(&mut fs, &mut dev, w.done, w.value).unwrap();
        assert_eq!(r.value, body, "case {case}");
    }
}

/// Dedup never changes read-back bytes: blobs assembled from a small
/// shared chunk pool dedup heavily, yet every blob reads back exactly,
/// and unique bytes never exceed logical bytes.
#[test]
fn prop_dedup_preserves_readback() {
    let mut rng = Rng::new(22);
    const CHUNK: usize = 4 << 10;
    let (mut st, mut fs, mut dev) = layerstore_rig(CHUNK);
    // pool of 6 distinct chunk contents shared across all blobs
    let pool: Vec<Vec<u8>> = (0..6)
        .map(|s| {
            let mut c = vec![0u8; CHUNK];
            for b in c.iter_mut() {
                *b = (rng.next_u64() as u8).wrapping_add(s);
            }
            c
        })
        .collect();
    let mut shadow = Vec::new();
    for _ in 0..scaled(40) {
        let nchunks = 1 + rng.below(5) as usize;
        let mut body = Vec::new();
        for _ in 0..nchunks {
            body.extend_from_slice(&pool[rng.below(pool.len() as u64) as usize]);
        }
        let d = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &body).unwrap().value;
        shadow.push((d, body));
    }
    for (d, body) in &shadow {
        let r = st.get_blob(&mut fs, &mut dev, SimTime::ZERO, *d).unwrap();
        assert_eq!(&r.value, body);
    }
    assert!(st.unique_bytes() <= st.dedup.logical_bytes());
    assert!(
        st.unique_bytes() <= (pool.len() * CHUNK) as u64,
        "at most the chunk pool is ever stored"
    );
    assert!(st.stats.dedup_hits > 0, "composition must have dedup'd");
}

/// CoW: clone + arbitrary writes never mutate the parent blob, and the
/// layer tracks a shadow model byte-for-byte.
#[test]
fn prop_cow_writes_never_mutate_parent() {
    let mut rng = Rng::new(23);
    for case in 0..scaled(15) {
        let (mut st, mut fs, mut dev) = layerstore_rig(4 << 10);
        let mut cow = CowStore::new();
        let len = (8_000 + rng.below(30_000)) as usize;
        let parent: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let d = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &parent).unwrap().value;
        let layer = cow.fork_from_blobs(&mut st, &[d]).unwrap();
        let clone = cow.clone_layer(&mut st, layer).unwrap();
        let mut shadow = parent.clone();
        for _ in 0..12 {
            let wlen = (1 + rng.below(5_000)) as usize;
            let off = rng.below((len - wlen) as u64 + 1);
            let data: Vec<u8> = (0..wlen).map(|_| rng.next_u64() as u8).collect();
            cow.write_at(&mut st, &mut fs, &mut dev, SimTime::ZERO, clone, off, &data)
                .unwrap();
            shadow[off as usize..off as usize + wlen].copy_from_slice(&data);
        }
        let parent_back = st.get_blob(&mut fs, &mut dev, SimTime::ZERO, d).unwrap();
        assert_eq!(parent_back.value, parent, "case {case}: parent blob mutated");
        let sibling = cow.read(&mut st, &mut fs, &mut dev, SimTime::ZERO, layer).unwrap();
        assert_eq!(sibling.value, parent, "case {case}: sibling layer mutated");
        let written = cow.read(&mut st, &mut fs, &mut dev, SimTime::ZERO, clone).unwrap();
        assert_eq!(written.value, shadow, "case {case}: clone diverged from model");
    }
}

/// Refcounts hitting zero reclaim chunks: after dropping every layer
/// and blob reference — in random order — the store is empty and the
/// λFS chunk directory holds no files.
#[test]
fn prop_refcount_zero_reclaims_chunks() {
    let mut rng = Rng::new(24);
    for case in 0..scaled(15) {
        let (mut st, mut fs, mut dev) = layerstore_rig(4 << 10);
        let mut cow = CowStore::new();
        let mut blobs = Vec::new();
        for _ in 0..(2 + rng.below(4)) {
            let len = (1 + rng.below(20_000)) as usize;
            let body: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            blobs.push(st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &body).unwrap().value);
        }
        let mut layers = Vec::new();
        for _ in 0..rng.below(5) {
            let base = blobs[rng.below(blobs.len() as u64) as usize];
            let l = cow.fork_from_blobs(&mut st, &[base]).unwrap();
            let maxw = cow.len_of(l).unwrap().min(64) as usize;
            if maxw > 0 && rng.chance(0.5) {
                let data: Vec<u8> = (0..maxw).map(|_| rng.next_u64() as u8).collect();
                cow.write_at(&mut st, &mut fs, &mut dev, SimTime::ZERO, l, 0, &data)
                    .unwrap();
            }
            layers.push(l);
        }
        // tear everything down in random order
        while !layers.is_empty() || !blobs.is_empty() {
            if !layers.is_empty() && (blobs.is_empty() || rng.chance(0.5)) {
                let l = layers.swap_remove(rng.below(layers.len() as u64) as usize);
                cow.drop_layer(&mut st, &mut fs, l).unwrap();
            } else {
                let b = blobs.swap_remove(rng.below(blobs.len() as u64) as usize);
                st.unref_blob(&mut fs, b).unwrap();
            }
        }
        assert_eq!(st.unique_bytes(), 0, "case {case}");
        assert_eq!(st.dedup.chunk_count(), 0, "case {case}");
        assert!(
            fs.list("/images/chunks").unwrap().is_empty(),
            "case {case}: chunk files must be unlinked"
        );
    }
}

/// λFS: writing k files and reading them back yields identical bytes,
/// for random sizes spanning page boundaries.
#[test]
fn prop_lambdafs_durability() {
    use dockerssd::lambdafs::LambdaFs;
    let mut rng = Rng::new(9);
    let cfg = SsdConfig::default();
    let mut dev = SsdDevice::new(cfg);
    let mut fs = LambdaFs::over_device(&dev);
    let mut shadow = Vec::new();
    for i in 0..60 {
        let len = (rng.below(20_000) + 1) as usize;
        let body: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let path = format!("/data/p{i}");
        fs.write_file(&mut dev, SimTime::ZERO, &path, &body, LockSide::Host).unwrap();
        shadow.push((path, body));
    }
    for (path, body) in &shadow {
        let r = fs.read_file(&mut dev, SimTime::ZERO, path, LockSide::Host).unwrap();
        assert_eq!(&r.value, body, "{path}");
    }
}

// --- fabric invariants ------------------------------------------------------

/// Fabric: for random transfer mixes, receipts are causally ordered
/// (issued <= begin <= finish), per-link byte accounting conserves the
/// bytes offered, and same-lane traffic on one link never overlaps.
#[test]
fn prop_fabric_receipts_causal_and_conserving() {
    use dockerssd::config::{EtherOnConfig, PoolConfig};
    use dockerssd::fabric::{Endpoint, Fabric, LinkClass, Priority};

    let mut rng = Rng::new(77);
    for case in 0..scaled(50) {
        let cfg = PoolConfig {
            nodes_per_array: 4,
            arrays: 1,
            ..Default::default()
        };
        let mut fabric = Fabric::new(&cfg, &EtherOnConfig::default());
        let mut offered = 0u64;
        let mut prev_fg_finish = SimTime::ZERO;
        let mut now = SimTime::ZERO;
        for _ in 0..20 {
            now += SimTime::ns(rng.below(1000));
            let from = rng.below(4) as u32;
            let mut to = rng.below(4) as u32;
            if to == from {
                to = (to + 1) % 4;
            }
            let bytes = rng.below(1 << 20) + 1;
            let pri = if rng.chance(0.3) {
                Priority::Background
            } else {
                Priority::Foreground
            };
            let r = fabric.transfer(now, Endpoint::Node(from), Endpoint::Node(to), bytes, pri);
            assert!(r.issued <= r.begin && r.begin <= r.finish, "case {case}: causality");
            offered += bytes;
            if pri == Priority::Foreground {
                // single array: every foreground transfer serializes on
                // the one backplane, so wire grants never regress
                assert!(r.begin >= prev_fg_finish.saturating_sub(SimTime::ns(300)), "case {case}");
                prev_fg_finish = r.finish;
            }
        }
        let q = fabric.link(LinkClass::Array(0)).unwrap();
        assert_eq!(q.bytes, offered, "case {case}: all bytes serialized on the backplane");
    }
}

/// Event-driven re-timing (ISSUE 3): a background transfer preempted by
/// later-arriving foreground traffic never completes *earlier* than the
/// old optimistic busy-until receipt would have claimed, and strictly
/// later whenever the foreground burst actually cut in before the
/// optimistic finish.
#[test]
fn prop_retimed_background_never_beats_optimistic_receipt() {
    use dockerssd::config::{EtherOnConfig, PoolConfig};
    use dockerssd::fabric::{Endpoint, Fabric, LinkClass, Priority};

    let mut rng = Rng::new(79);
    for case in 0..scaled(100) {
        let cfg = PoolConfig {
            nodes_per_array: 4,
            arrays: 1,
            ..Default::default()
        };
        let mut fabric = Fabric::new(&cfg, &EtherOnConfig::default());
        let bytes = rng.below(32 << 20) + 4096;
        // what the sync path would have promised on the idle wire
        let optimistic = fabric.estimate(Endpoint::Node(0), Endpoint::Node(1), bytes);
        let bg = fabric.schedule(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            bytes,
            Priority::Background,
        );
        // foreground traffic lands later on the same backplane
        let mut t = SimTime::ZERO;
        let mut first_fg = None;
        for _ in 0..(1 + rng.below(3)) {
            t += SimTime::ns(rng.below(10_000_000));
            first_fg.get_or_insert(t);
            fabric.schedule(
                t,
                Endpoint::Node(2),
                Endpoint::Node(3),
                rng.below(8 << 20) + 1,
                Priority::Foreground,
            );
        }
        fabric.run_to_idle();
        let r = fabric.receipt_of(bg).expect("engine drained");
        assert!(
            r.finish >= optimistic,
            "case {case}: re-timed finish {} beat the optimistic receipt {optimistic}",
            r.finish
        );
        let quantum = fabric.link(LinkClass::Array(0)).unwrap().frame_quantum(1500);
        // strictness only when the quantum cut lands before the wire
        // release (optimistic minus the switch-hop tail)
        let wire_release = optimistic.saturating_sub(SimTime::ns(300));
        if first_fg.expect("at least one fg") + quantum < wire_release {
            assert!(
                r.finish > optimistic,
                "case {case}: a mid-flight preemption must push the finish out"
            );
            assert!(fabric.stats.retimed_transfers >= 1, "case {case}");
        }
    }
}

/// Serve determinism (ISSUE 3): two serve storms with the same seed
/// produce identical simulated latencies and byte-identical
/// `serve.*`/`fabric.*`/`sim.*` counters.
#[test]
fn prop_serve_same_seed_same_schedule() {
    use dockerssd::config::{EtherOnConfig, PoolConfig};
    use dockerssd::coordinator::{serve, EchoExecutor, ServeParams};
    use dockerssd::metrics::Counters;
    use dockerssd::sim::PoolSim;

    for seed in [1u64, 7, 42] {
        let run = |seed: u64| {
            let mut sim = PoolSim::with_pool(
                &PoolConfig {
                    nodes_per_array: 4,
                    arrays: 1,
                    ..Default::default()
                },
                &EtherOnConfig::default(),
            );
            let mut rng = Rng::new(seed);
            let requests: Vec<_> = (0..24u64)
                .map(|id| {
                    (
                        SimTime::us(rng.below(3_000)),
                        InferenceRequest {
                            id,
                            prompt: vec![rng.next_u64() as i32 & 0x7FFF; 8],
                            max_new_tokens: 1 + rng.below(4) as usize,
                        },
                    )
                })
                .collect();
            let factories: Vec<_> = (0..3)
                .map(|_| || Ok::<_, anyhow::Error>(EchoExecutor))
                .collect();
            let params = ServeParams {
                batch_width: 4,
                prompt_len: 8,
                batch_window: SimTime::us(200),
                ..Default::default()
            };
            let report = serve(&mut sim, factories, requests, &params);
            let mut c = Counters::new();
            report.export_counters(&mut c);
            sim.export_counters(&mut c);
            let lats: Vec<(u64, SimTime)> =
                report.responses.iter().map(|r| (r.id, r.latency)).collect();
            (c, lats)
        };
        let (c1, l1) = run(seed);
        let (c2, l2) = run(seed);
        assert_eq!(c1, c2, "seed {seed}: counters diverged");
        assert_eq!(l1, l2, "seed {seed}: latencies diverged");
        assert_eq!(l1.len(), 24, "seed {seed}: all requests served");
    }
}

/// Trace-replay determinism (ISSUE 4): for *every* Table 2 row, two
/// same-seed replays of the trace-driven arrival stream through the
/// serve loop yield a byte-identical `ServeReport` — identical
/// counters (per-request KV reservations included), identical response
/// tokens, identical per-request simulated latencies.
#[test]
fn prop_trace_replay_same_seed_byte_identical_for_every_row() {
    use dockerssd::config::{EtherOnConfig, PoolConfig};
    use dockerssd::coordinator::{serve, EchoExecutor, ServeParams};
    use dockerssd::metrics::Counters;
    use dockerssd::sim::PoolSim;
    use dockerssd::workloads::{all_workloads, trace_arrivals, ArrivalParams};

    for spec in all_workloads() {
        let run = || {
            let mut sim = PoolSim::with_pool(
                &PoolConfig {
                    nodes_per_array: 4,
                    arrays: 1,
                    ..Default::default()
                },
                &EtherOnConfig::default(),
            );
            let ap = ArrivalParams {
                scale: 20_000,
                ..Default::default()
            };
            let arr = trace_arrivals(&spec, 42, &ap);
            let factories: Vec<_> = (0..4)
                .map(|_| || Ok::<_, anyhow::Error>(EchoExecutor))
                .collect();
            let params = ServeParams {
                batch_width: 4,
                prompt_len: ap.engine_prompt_len(),
                batch_window: SimTime::us(200),
                ..Default::default()
            };
            let report = serve(&mut sim, factories, arr.requests, &params);
            let mut c = Counters::new();
            report.export_counters(&mut c);
            sim.export_counters(&mut c);
            let responses: Vec<(u64, Vec<i32>, u32, SimTime)> = report
                .responses
                .iter()
                .map(|r| (r.id, r.tokens.clone(), r.node, r.latency))
                .collect();
            (c, responses, report.requests, report.kv_reserved_bytes)
        };
        let (c1, r1, n1, kv1) = run();
        let (c2, r2, n2, kv2) = run();
        assert_eq!(c1, c2, "{}: counters diverged", spec.full_name());
        assert_eq!(r1, r2, "{}: responses diverged", spec.full_name());
        assert_eq!((n1, kv1), (n2, kv2), "{}", spec.full_name());
        assert_eq!(r1.len() as u64, n1, "{}: every request served", spec.full_name());
        assert!(kv1 > 0, "{}: per-request KV must be accounted", spec.full_name());
    }
}

/// Fabric: a foreground transfer is never delayed by background traffic
/// by more than one frame quantum, for random prefetch loads.
#[test]
fn prop_fabric_foreground_isolation() {
    use dockerssd::config::{EtherOnConfig, PoolConfig};
    use dockerssd::fabric::{Endpoint, Fabric, LinkClass, Priority};

    let mut rng = Rng::new(78);
    for case in 0..cases() {
        let cfg = PoolConfig {
            nodes_per_array: 4,
            arrays: 1,
            ..Default::default()
        };
        let mut fabric = Fabric::new(&cfg, &EtherOnConfig::default());
        // random background load, all issued at t=0
        for _ in 0..(1 + rng.below(4)) {
            let bytes = rng.below(32 << 20) + 1;
            fabric.transfer(SimTime::ZERO, Endpoint::Node(0), Endpoint::Node(1), bytes,
                Priority::Background);
        }
        let r = fabric.transfer(
            SimTime::ZERO,
            Endpoint::Node(2),
            Endpoint::Node(3),
            4096,
            Priority::Foreground,
        );
        let quantum = fabric
            .link(LinkClass::Array(0))
            .unwrap()
            .frame_quantum(EtherOnConfig::default().mtu);
        assert!(
            r.queue_wait() <= quantum,
            "case {case}: foreground waited {} behind prefetch (quantum {quantum})",
            r.queue_wait()
        );
    }
}

// --- chunk-granular poolcache invariants (ISSUE 5) --------------------------

/// Chunk/blob presence consistency: after any sequence of blob
/// registrations, partial (mid-pull) chunk registrations, fetches,
/// prefetches, evictions, and GC passes, a node "has" a blob exactly
/// when it holds every chunk of the blob's recipe — and GC never drops
/// any chunk below min(k, its pre-GC holder count).
#[test]
fn prop_chunk_presence_iff_all_chunks_held() {
    use dockerssd::config::{EtherOnConfig, PoolConfig};
    use dockerssd::fabric::Fabric;
    use dockerssd::layerstore::PoolLayerCache;
    use dockerssd::pool::{FtlBank, PoolTopology, WireCtx};

    let mut rng = Rng::new(31);
    for case in 0..scaled(40) {
        let pcfg = PoolConfig {
            nodes_per_array: 4,
            arrays: 1,
            ..Default::default()
        };
        let topo = PoolTopology::build(&pcfg);
        let mut fabric = Fabric::new(&pcfg, &EtherOnConfig::default());
        let mut bank = FtlBank::default();
        let mut pc = PoolLayerCache::new();
        // three blobs drawing on a shared pool of six chunks
        let chunk_pool: Vec<(u64, u64)> = (0..6u64).map(|i| (0xC00 + i, 64 << 10)).collect();
        let mut blobs = Vec::new();
        for b in 0..3u64 {
            let n = 1 + rng.below(4) as usize;
            let recipe: Vec<(u64, u64)> = (0..n)
                .map(|_| chunk_pool[rng.below(6) as usize])
                .collect();
            let blob = 0xB10B_0000 + b;
            assert!(pc.describe_chunks(blob, &recipe));
            blobs.push(blob);
        }
        let check = |pc: &PoolLayerCache, when: &str| {
            for &b in &blobs {
                let recipe = pc.chunk_recipe(b).expect("described").to_vec();
                for n in 0..4u32 {
                    let all = recipe.iter().all(|(c, _)| pc.node_has_chunk(n, *c));
                    assert_eq!(
                        pc.node_has(n, b),
                        all,
                        "case {case} ({when}): blob {b:#x} node {n}: presence != all-chunks-held"
                    );
                }
            }
        };
        for _ in 0..40 {
            let node = rng.below(4) as u32;
            let blob = blobs[rng.below(3) as usize];
            match rng.below(5) {
                0 => pc.register(node, blob),
                1 => {
                    let recipe = pc.chunk_recipe(blob).expect("described").to_vec();
                    let (c, _) = recipe[rng.below(recipe.len() as u64) as usize];
                    pc.register_chunk(node, blob, c);
                }
                2 => {
                    pc.fetch(
                        &mut WireCtx::at(&mut fabric, &topo, &mut bank, SimTime::ZERO),
                        node,
                        blob,
                        256 << 10,
                    );
                }
                3 => {
                    pc.prefetch(
                        &mut WireCtx::at(&mut fabric, &topo, &mut bank, SimTime::ZERO),
                        node,
                        blob,
                        256 << 10,
                    );
                }
                _ => pc.evict(node, blob),
            }
            check(&pc, "after op");
        }
        let before: std::collections::HashMap<u64, usize> = chunk_pool
            .iter()
            .map(|(c, _)| (*c, pc.chunk_holders_of(*c).len()))
            .collect();
        pc.gc(2, |n| n as u64, |_| 0);
        check(&pc, "after gc");
        for (c, _) in &chunk_pool {
            let after = pc.chunk_holders_of(*c).len();
            assert!(
                after >= before[c].min(2),
                "case {case}: gc dropped chunk {c:#x} below k ({} -> {after})",
                before[c]
            );
        }
    }
}

/// Chunk-granular fetch never moves more bytes than blob-granular fetch
/// for the same miss set — on the intranet *or* on the WAN.  (The
/// blob-granular baseline re-fetches the whole layer from a full holder
/// or the registry; the chunk path moves only the missing chunks.)
#[test]
fn prop_chunk_fetch_never_moves_more_than_blob_fetch() {
    use dockerssd::config::{EtherOnConfig, PoolConfig};
    use dockerssd::fabric::Fabric;
    use dockerssd::layerstore::PoolLayerCache;
    use dockerssd::pool::{FtlBank, PoolTopology, WireCtx};

    let mut rng = Rng::new(32);
    const NCHUNKS: u64 = 8;
    const CHUNK: u64 = 256 << 10;
    for case in 0..scaled(100) {
        let pcfg = PoolConfig {
            nodes_per_array: 4,
            arrays: 1,
            ..Default::default()
        };
        let topo = PoolTopology::build(&pcfg);
        let blob = 0xB10B;
        let recipe: Vec<(u64, u64)> = (0..NCHUNKS).map(|i| (0xC00 + i, CHUNK)).collect();
        let bytes = NCHUNKS * CHUNK;

        // random chunk-level presence on nodes 0..=3 (node 0 fetches, so
        // its own partial holdings shrink the chunk-path miss set)
        let mut chunked = PoolLayerCache::new();
        assert!(chunked.describe_chunks(blob, &recipe));
        let mut blobbed = PoolLayerCache::new(); // blob-granular twin
        for n in 0..=3u32 {
            let mut held_all = true;
            let hold_p = if n == 0 { 0.3 } else { 0.4 };
            for (c, _) in &recipe {
                if rng.chance(hold_p) {
                    chunked.register_chunk(n, blob, *c);
                } else {
                    held_all = false;
                }
            }
            if held_all && n != 0 {
                blobbed.register(n, blob); // only full holders exist blob-granularly
            }
        }
        if chunked.node_has(0, blob) {
            continue; // degenerate: nothing to fetch on the chunk path
        }

        let mut fab_c = Fabric::new(&pcfg, &EtherOnConfig::default());
        let mut bank_c = FtlBank::default();
        chunked.fetch(
            &mut WireCtx::at(&mut fab_c, &topo, &mut bank_c, SimTime::ZERO),
            0,
            blob,
            bytes,
        );
        let moved_chunk = chunked.bytes_from_peers + chunked.bytes_from_registry;
        let wan_chunk = chunked.bytes_from_registry;

        let mut fab_b = Fabric::new(&pcfg, &EtherOnConfig::default());
        let mut bank_b = FtlBank::default();
        blobbed.fetch(
            &mut WireCtx::at(&mut fab_b, &topo, &mut bank_b, SimTime::ZERO),
            0,
            blob,
            bytes,
        );
        let moved_blob = blobbed.bytes_from_peers + blobbed.bytes_from_registry;
        let wan_blob = blobbed.bytes_from_registry;

        assert!(
            moved_chunk <= moved_blob,
            "case {case}: chunk path moved {moved_chunk} > blob path {moved_blob}"
        );
        assert!(
            wan_chunk <= wan_blob,
            "case {case}: chunk path put {wan_chunk} on the WAN > blob path {wan_blob}"
        );
        assert_eq!(moved_blob, bytes, "blob-granular always re-moves the whole layer");
    }
}

// --- chaos + self-healing invariants (ISSUE 6) ------------------------------

/// Chaos healing (ISSUE 6): for any seeded fault schedule replayed
/// against the CI trace scenario, the post-run pool holds every live
/// chunk on at least min(k, healthy-nodes) holders — node deaths, array
/// losses, brownouts, and registry stalls included.
#[test]
fn prop_chaos_any_schedule_heals_back_to_k() {
    use dockerssd::smoke::{run, SmokeParams, CHAOS_HEAL_K};

    for seed in 0..scaled(8) {
        let out = run(&SmokeParams {
            chaos: Some(seed),
            ..SmokeParams::ci()
        })
        .unwrap();
        let ch = out.chaos.expect("chaos outcome present");
        assert!(ch.report.faults_injected > 0, "seed {seed}: schedule fired");
        assert!(
            ch.healed_to_k(CHAOS_HEAL_K),
            "seed {seed}: a live chunk is below the k-holder invariant after healing"
        );
    }
}

/// Chaos serving (ISSUE 6): churn never loses a request and never
/// serves one twice — the response set is exactly the arrival set, with
/// unique ids, for any seeded fault schedule.
#[test]
fn prop_chaos_never_loses_or_duplicates_a_request() {
    use dockerssd::smoke::{run, SmokeParams};

    for seed in 0..scaled(8) {
        let out = run(&SmokeParams {
            chaos: Some(0xFA17 + seed),
            ..SmokeParams::ci()
        })
        .unwrap();
        let mut ids: Vec<u64> = out.report.responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        let before = ids.len();
        ids.dedup();
        assert_eq!(ids.len(), before, "seed {seed}: a request was served twice");
        assert_eq!(
            ids.len(),
            out.arrivals.requests,
            "seed {seed}: churn lost a request"
        );
    }
}

/// Chaos determinism (ISSUE 6): the same chaos seed replays to
/// byte-identical counters — faults, healing traffic, and availability
/// ppm included — across independent runs.
#[test]
fn prop_chaos_same_seed_byte_identical_counters() {
    use dockerssd::smoke::{counter_lines, run, SmokeParams};

    for seed in 0..scaled(4) {
        let p = SmokeParams {
            chaos: Some(0xC4A0 + seed),
            ..SmokeParams::ci()
        };
        let a = run(&p).unwrap();
        let b = run(&p).unwrap();
        assert_eq!(a.counters, b.counters, "seed {seed}: counters diverged");
        assert_eq!(
            counter_lines(&a.counters),
            counter_lines(&b.counters),
            "seed {seed}: rendered counter table diverged"
        );
    }
}

/// Engine-scheduled prefetch re-timing (ISSUE 5, extending
/// `prop_retimed_background_never_beats_optimistic_receipt` to the
/// *prefetch path*): a placement-time prefetch scheduled through
/// `PoolLayerCache::prefetch` and preempted by later foreground traffic
/// settles no earlier than the optimistic idle-wire receipt, strictly
/// later (and counted in `fabric.retimed_transfers`) whenever the
/// foreground burst cut in before the optimistic finish.
#[test]
fn prop_engine_prefetch_settles_no_earlier_than_optimistic() {
    use dockerssd::config::{EtherOnConfig, PoolConfig};
    use dockerssd::fabric::{Endpoint, Fabric, LinkClass, Priority};
    use dockerssd::layerstore::PoolLayerCache;
    use dockerssd::pool::{FtlBank, PoolTopology, WireCtx};

    let mut rng = Rng::new(33);
    for case in 0..scaled(100) {
        let pcfg = PoolConfig {
            nodes_per_array: 4,
            arrays: 1,
            ..Default::default()
        };
        let topo = PoolTopology::build(&pcfg);
        let mut fabric = Fabric::new(&pcfg, &EtherOnConfig::default());
        let mut cache = PoolLayerCache::new();
        cache.register(0, 0xFE7C);
        let bytes = rng.below(32 << 20) + 4096;
        let optimistic = fabric.estimate(Endpoint::Node(0), Endpoint::Node(1), bytes);
        let mut bank = FtlBank::default();
        let (_, handle) = cache.prefetch(
            &mut WireCtx::at(&mut fabric, &topo, &mut bank, SimTime::ZERO),
            1,
            0xFE7C,
            bytes,
        );
        assert!(!handle.ids().is_empty(), "case {case}: prefetch rides the engine");
        fabric.advance_to(SimTime::ZERO); // grant the background flight
        // foreground traffic lands later on the same backplane
        let mut t = SimTime::ZERO;
        let mut first_fg = None;
        for _ in 0..(1 + rng.below(3)) {
            t += SimTime::ns(rng.below(10_000_000));
            first_fg.get_or_insert(t);
            fabric.schedule(
                t,
                Endpoint::Node(2),
                Endpoint::Node(3),
                rng.below(8 << 20) + 1,
                Priority::Foreground,
            );
        }
        let finish = handle.settle(&mut fabric);
        assert!(
            finish >= optimistic,
            "case {case}: settled prefetch {finish} beat the optimistic receipt {optimistic}"
        );
        let quantum = fabric.link(LinkClass::Array(0)).unwrap().frame_quantum(1500);
        // strictness only when the quantum cut lands before the wire
        // release (optimistic minus the switch-hop tail)
        let wire_release = optimistic.saturating_sub(SimTime::ns(300));
        if first_fg.expect("at least one fg") + quantum < wire_release {
            assert!(
                finish > optimistic,
                "case {case}: a mid-flight preemption must push the prefetch's finish out"
            );
            assert!(
                fabric.stats.retimed_transfers >= 1,
                "case {case}: the re-time must be counted"
            );
        }
    }
}

/// Calendar event queue equivalence (ISSUE 7 tentpole): under randomized
/// schedules mixing dense near-future times (bucket collisions and FIFO
/// ties), far-future times (the overflow heap), scheduling into the past
/// (clamped to `now`), and interleaved pops, the calendar queue pops the
/// exact (time, seq, tag) sequence of the old single `BinaryHeap` — and
/// counts the same number of clamped events.
#[test]
fn prop_calendar_queue_matches_reference_heap() {
    use dockerssd::sim::EventQueue;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    /// The pre-calendar implementation, verbatim: one min-heap ordered
    /// by (time, insertion seq), clock advancing on pop, past schedules
    /// clamped to `now`.
    struct RefHeap {
        heap: BinaryHeap<Reverse<(SimTime, u64, u64)>>,
        now: SimTime,
        next_seq: u64,
        clamped: u64,
    }
    impl RefHeap {
        fn schedule_at(&mut self, at: SimTime, tag: u64) {
            let at = if at < self.now {
                self.clamped += 1;
                self.now
            } else {
                at
            };
            self.heap.push(Reverse((at, self.next_seq, tag)));
            self.next_seq += 1;
        }
        fn pop(&mut self) -> Option<(SimTime, u64, u64)> {
            let Reverse(e) = self.heap.pop()?;
            self.now = e.0;
            Some(e)
        }
    }

    let mut rng = Rng::new(44);
    for case in 0..scaled(100) {
        let mut q = EventQueue::new();
        let mut r = RefHeap {
            heap: BinaryHeap::new(),
            now: SimTime::ZERO,
            next_seq: 0,
            clamped: 0,
        };
        let ops = 200 + rng.below(800);
        for _ in 0..ops {
            match rng.below(10) {
                // dense near future: same-bucket pileups and (at, seq) ties
                0..=4 => {
                    let at = q.now() + SimTime::ns(rng.below(20_000));
                    let tag = rng.next_u64();
                    q.schedule_at(at, tag);
                    r.schedule_at(at, tag);
                }
                // far future: beyond the ring span, lands in overflow
                5..=6 => {
                    let at = q.now() + SimTime::ns(5_000_000 + rng.below(500_000_000));
                    let tag = rng.next_u64();
                    q.schedule_at(at, tag);
                    r.schedule_at(at, tag);
                }
                // the past: clamped to now, identically counted
                7 => {
                    let back = rng.below(1 + q.now().as_ns());
                    let at = SimTime::ns(q.now().as_ns() - back);
                    let tag = rng.next_u64();
                    q.schedule_at(at, tag);
                    r.schedule_at(at, tag);
                }
                // interleaved pops advance the clock mid-schedule
                _ => {
                    let got = q.pop().map(|e| (e.at, e.seq, e.tag));
                    assert_eq!(got, r.pop(), "case {case}: mid-drain pop diverged");
                }
            }
        }
        loop {
            let got = q.pop().map(|e| (e.at, e.seq, e.tag));
            let want = r.pop();
            assert_eq!(got, want, "case {case}: drain diverged");
            if got.is_none() {
                break;
            }
        }
        assert_eq!(q.clamped(), r.clamped, "case {case}: clamped count diverged");
        assert_eq!(q.len(), 0);
    }
}

// --- device-to-device stream invariants (ISSUE 8) ---------------------------

/// Stream conservation (ISSUE 8): a pipelined stream is a *schedule* of
/// the same bytes, not a discount — under arbitrary competing traffic
/// it never completes earlier than the equivalent monolithic transfer
/// on an identically loaded twin fabric.  Tolerance: `wire_time`
/// truncates to whole ns per quantum per link, so a stream may
/// legitimately land up to `path_len x quanta` ns early.
#[test]
fn prop_stream_never_beats_monolithic_under_contention() {
    use dockerssd::config::{EtherOnConfig, PoolConfig};
    use dockerssd::fabric::{Endpoint, Fabric, Priority};

    let mut rng = Rng::new(88);
    for case in 0..scaled(100) {
        let pcfg = PoolConfig {
            nodes_per_array: 4,
            arrays: 2,
            ..Default::default()
        };
        let mut fs = Fabric::new(&pcfg, &EtherOnConfig::default());
        let mut fm = Fabric::new(&pcfg, &EtherOnConfig::default());
        // identical competing traffic lands on both fabrics
        for _ in 0..rng.below(5) {
            let at = SimTime::ns(rng.below(2_000_000));
            let (a, b) = (rng.below(8) as u32, rng.below(8) as u32);
            let bytes = rng.below(16 << 20) + 1;
            let pri = match rng.below(3) {
                0 => Priority::Foreground,
                1 => Priority::Background,
                _ => Priority::Tenant {
                    id: rng.below(4) as u8,
                    weight: 1 + rng.below(8) as u8,
                },
            };
            fs.schedule(at, Endpoint::Node(a), Endpoint::Node(b), bytes, pri);
            fm.schedule(at, Endpoint::Node(a), Endpoint::Node(b), bytes, pri);
        }
        let bytes = rng.below(8 << 20) + 1;
        let quantum = 1 + rng.below(1 << 20);
        // cross-array: the longest (3-link) path
        let (from, to) = (Endpoint::Node(0), Endpoint::Node(5));
        let h = fs.stream(SimTime::ZERO, from, to, bytes, quantum, Priority::Foreground);
        let r = fs.settle_stream(&h);
        let id = fm.schedule(SimTime::ZERO, from, to, bytes, Priority::Foreground);
        let m = fm.settle(id).expect("freshly scheduled id settles");
        let tolerance = SimTime::ns(3 * r.quanta);
        assert!(
            r.finish + tolerance >= m.finish,
            "case {case}: stream finished {} vs monolithic {} (bytes {bytes}, quantum \
             {quantum}, {} quanta) — pipelining must not create bandwidth",
            r.finish,
            m.finish,
            r.quanta
        );
    }
}

/// Stream determinism (ISSUE 8): a serve run whose KV skew forces
/// streamed migrations replays byte-identically — `fabric.bytes_p2p`,
/// `fabric.stream_quanta`, `fabric.stream_overlap_ns`, and
/// `serve.host_bytes_per_token` included — and the streams verifiably
/// ran (quanta on the wire, zero uplink bytes beyond dispatch/response
/// control).
#[test]
fn prop_streamed_serve_same_seed_byte_identical() {
    use dockerssd::config::{EtherOnConfig, PoolConfig};
    use dockerssd::coordinator::{serve, EchoExecutor, ServeParams};
    use dockerssd::metrics::{names, Counters};
    use dockerssd::sim::PoolSim;

    for seed in [3u64, 11, 77] {
        let run = |seed: u64| {
            let mut sim = PoolSim::with_pool(
                &PoolConfig {
                    nodes_per_array: 4,
                    arrays: 1,
                    ..Default::default()
                },
                &EtherOnConfig::default(),
            );
            let mut rng = Rng::new(seed);
            // one KV-heavy request leaves a multi-quantum resident
            // session; the short tail skews residency and triggers
            // streamed migrations
            let mut requests = vec![(
                SimTime::ZERO,
                InferenceRequest { id: 0, prompt: vec![1; 8], max_new_tokens: 400 },
            )];
            for k in 1..=6u64 {
                requests.push((
                    SimTime::us(k * 7_000 + rng.below(1_000)),
                    InferenceRequest {
                        id: k,
                        prompt: vec![rng.next_u64() as i32 & 0x7FFF; 8],
                        max_new_tokens: 1 + rng.below(3) as usize,
                    },
                ));
            }
            let factories: Vec<_> = (0..2)
                .map(|_| || Ok::<_, anyhow::Error>(EchoExecutor))
                .collect();
            let params = ServeParams {
                batch_width: 1,
                prompt_len: 8,
                batch_window: SimTime::us(10),
                ..Default::default()
            };
            let report = serve(&mut sim, factories, requests, &params);
            let mut c = Counters::new();
            report.export_counters(&mut c);
            sim.export_counters(&mut c);
            (c, report.kv_migrations)
        };
        let (c1, mig1) = run(seed);
        let (c2, mig2) = run(seed);
        assert_eq!(c1, c2, "seed {seed}: streamed counters diverged");
        assert_eq!(mig1, mig2, "seed {seed}: migration count diverged");
        assert!(mig1 >= 1, "seed {seed}: the skew must force a migration");
        assert!(
            c1.get(names::FABRIC_STREAM_QUANTA) > 1,
            "seed {seed}: the migration must pipeline into quanta"
        );
        assert!(c1.get(names::FABRIC_BYTES_P2P) > 0, "seed {seed}");
        assert!(c1.get(names::SERVE_HOST_BYTES_PER_TOKEN) > 0, "seed {seed}");
    }
}

/// Chaos mid-stream (ISSUE 8): a node death landing while session KV is
/// migrating as stream quanta neither loses nor double-delivers any
/// session's response, for random death times and victims — and the
/// streamed migration path verifiably ran.
#[test]
fn prop_chaos_node_death_mid_stream_never_loses_a_session() {
    use dockerssd::chaos::{ChaosInjector, ChaosSchedule, Fault, FaultKind};
    use dockerssd::config::{EtherOnConfig, PoolConfig};
    use dockerssd::coordinator::{serve_with_hook, EchoExecutor, ServeParams};
    use dockerssd::layerstore::PoolLayerCache;
    use dockerssd::metrics::{names, Counters};
    use dockerssd::pool::{Orchestrator, PoolTopology, RestartPolicy};
    use dockerssd::sim::PoolSim;

    let mut rng = Rng::new(0x5EED);
    for case in 0..scaled(8) {
        let pcfg = PoolConfig {
            nodes_per_array: 4,
            arrays: 1,
            ..Default::default()
        };
        let topo = PoolTopology::build(&pcfg);
        let mut sim = PoolSim::with_pool(&pcfg, &EtherOnConfig::default());
        // same KV-pressure shape as the determinism property: the big
        // session streams between nodes while the fault fires
        let mut requests = vec![(
            SimTime::ZERO,
            InferenceRequest { id: 0, prompt: vec![1; 8], max_new_tokens: 400 },
        )];
        for k in 1..=6u64 {
            requests.push((
                SimTime::us(k * 7_000),
                InferenceRequest {
                    id: k,
                    prompt: vec![k as i32; 8],
                    max_new_tokens: 1 + rng.below(3) as usize,
                },
            ));
        }
        let n = requests.len();
        // death lands inside the serve window, on a random victim
        let schedule = ChaosSchedule {
            seed: case,
            faults: vec![Fault {
                at: SimTime::us(15_000 + rng.below(30_000)),
                kind: FaultKind::NodeDeath { node: rng.below(4) as u32 },
            }],
        };
        let mut inj = ChaosInjector::new(
            schedule,
            topo,
            Orchestrator::new(),
            PoolLayerCache::new(),
            2,
            RestartPolicy::OnFailure,
        );
        inj.arm(&mut sim);
        let factories: Vec<_> = (0..2)
            .map(|_| || Ok::<_, anyhow::Error>(EchoExecutor))
            .collect();
        let params = ServeParams {
            batch_width: 1,
            prompt_len: 8,
            batch_window: SimTime::us(10),
            ..Default::default()
        };
        let report = serve_with_hook(&mut sim, factories, requests, &params, &mut inj);
        let out = inj.finish(&mut sim);
        assert_eq!(out.report.node_deaths, 1, "case {case}: the fault fired");
        let mut ids: Vec<u64> = report.responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        let before = ids.len();
        ids.dedup();
        assert_eq!(ids.len(), before, "case {case}: a session was double-delivered");
        assert_eq!(ids.len(), n, "case {case}: the death lost a session");
        assert!(report.kv_migrations >= 1, "case {case}: the skew must force a migration");
        let mut c = Counters::new();
        sim.export_counters(&mut c);
        assert!(
            c.get(names::FABRIC_STREAM_QUANTA) > 1,
            "case {case}: the migration must have streamed"
        );
    }
}

// --- FTL write-path invariants (ISSUE 9) ------------------------------------

/// Write-path pricing (ISSUE 9): for arbitrary interleavings of write
/// sizes, nodes, and inter-arrival gaps, the per-node flash ledger obeys
/// physics — WAF never drops below 1.0 (GC can only add writes, never
/// erase the host's), `wear_max` is monotone non-decreasing, every
/// receipt completes at or after its submission time, and receipts in
/// sum account for every host page charged.
#[test]
fn prop_ftl_write_path_waf_and_wear_obey_physics() {
    use dockerssd::metrics::{names, Counters};
    use dockerssd::pool::FtlBank;

    let mut rng = Rng::new(0x9F71);
    for case in 0..scaled(20) {
        let mut bank = FtlBank::default();
        let nodes = 1 + rng.below(4) as u32;
        let mut t = SimTime::ZERO;
        let mut wear_floor = vec![0u64; nodes as usize];
        let mut pages_by_receipt = vec![0u64; nodes as usize];
        for op in 0..300 {
            let node = rng.below(nodes as u64) as u32;
            // sizes from sub-page dirties to multi-MiB layer installs
            let bytes = 1 + rng.below(8 << 20);
            t += SimTime::ns(rng.below(50_000));
            let r = bank.write(node, t, bytes);
            assert!(r.pages >= 1, "case {case} op {op}: every write programs a page");
            assert!(
                r.done >= t,
                "case {case} op {op}: receipt completes before submission"
            );
            pages_by_receipt[node as usize] += r.pages;
            let waf = bank.waf_milli_of(node);
            assert!(
                waf >= 1000,
                "case {case} op {op}: WAF {waf} below 1.0 — GC deleted host writes"
            );
            let wear = bank.wear_max_of(node);
            assert!(
                wear >= wear_floor[node as usize],
                "case {case} op {op}: wear_max regressed {} -> {wear}",
                wear_floor[node as usize]
            );
            wear_floor[node as usize] = wear;
        }
        let mut c = Counters::new();
        bank.export_counters(&mut c);
        assert_eq!(
            c.get(names::FTL_HOST_PAGES),
            pages_by_receipt.iter().sum::<u64>(),
            "case {case}: exported host pages disagree with the sum of receipts"
        );
        assert!(c.get(names::FTL_WAF) >= 1000, "case {case}: pooled WAF below 1.0");
    }
}
