//! Property-based tests over substrate and coordinator invariants.
//!
//! Offline-build substitution (DESIGN.md §4): proptest is unavailable, so
//! properties are driven by the deterministic in-crate PRNG across many
//! random cases per property (seeded, reproducible).  Each test states
//! its invariant explicitly.

use dockerssd::config::SsdConfig;
use dockerssd::coordinator::{Batcher, InferenceRequest, Router};
use dockerssd::etheron::frame::{EthFrame, EtherType, Ipv4Packet, MacAddr, TcpSegment, TcpFlags};
use dockerssd::lambdafs::{InodeLockTable, LockSide};
use dockerssd::llm::{all_llms, sequence_time, DeviceProfile, Parallelism};
use dockerssd::nvme::{NvmeCommand, SubmissionQueue};
use dockerssd::ssd::{Ftl, SsdDevice};
use dockerssd::util::{Rng, SimTime};

const CASES: u64 = 200;

/// NVMe SQ: commands are never lost, duplicated, or reordered.
#[test]
fn prop_nvme_queue_preserves_commands() {
    let mut rng = Rng::new(1);
    for case in 0..CASES {
        let depth = 2 + rng.below(62) as usize;
        let mut sq = SubmissionQueue::new(depth);
        let n = rng.below(depth as u64 * 2) as u16;
        let mut submitted = Vec::new();
        for cid in 0..n {
            if sq.submit(NvmeCommand::read(cid, 1, cid as u64, 0)).is_ok() {
                submitted.push(cid);
            }
        }
        let mut fetched = Vec::new();
        while let Some(cmd) = sq.fetch() {
            fetched.push(cmd.cid);
        }
        assert_eq!(submitted, fetched, "case {case} depth {depth}");
    }
}

/// Ethernet/IP/TCP frames round-trip byte-exactly for arbitrary payloads.
#[test]
fn prop_frame_codecs_round_trip() {
    let mut rng = Rng::new(2);
    for _ in 0..CASES {
        let len = rng.below(1400) as usize;
        let payload: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let seg = TcpSegment {
            src_port: rng.next_u64() as u16,
            dst_port: rng.next_u64() as u16,
            seq: rng.next_u64() as u32,
            ack: rng.next_u64() as u32,
            flags: TcpFlags::ACK,
            window: rng.next_u64() as u16,
            payload: payload.clone(),
        };
        assert_eq!(TcpSegment::decode(&seg.encode()), Some(seg.clone()));
        let ip = Ipv4Packet {
            src: std::net::Ipv4Addr::new(10, 77, 0, 1),
            dst: std::net::Ipv4Addr::new(10, 77, 0, 2),
            protocol: 6,
            payload: seg.encode(),
        };
        assert_eq!(Ipv4Packet::decode(&ip.encode()), Some(ip.clone()));
        let eth = EthFrame {
            dst: MacAddr::for_node(rng.next_u64() as u32),
            src: MacAddr::for_node(rng.next_u64() as u32),
            ethertype: EtherType::Ipv4,
            payload: ip.encode(),
        };
        assert_eq!(EthFrame::decode(&eth.encode()), Some(eth));
    }
}

/// FTL: after any interleaving of writes/overwrites, every mapped LPN
/// translates to a unique PPA (no aliasing).
#[test]
fn prop_ftl_mappings_never_alias() {
    let mut rng = Rng::new(3);
    let cfg = SsdConfig {
        channels: 2,
        packages_per_channel: 2,
        blocks_per_package: 32,
        pages_per_block: 32,
        ..Default::default()
    };
    for _ in 0..40 {
        let mut ftl = Ftl::new(&cfg);
        let universe = 256u64;
        for _ in 0..1500 {
            ftl.map_write(rng.below(universe));
            if ftl.needs_gc() {
                if let Some((victim, valid)) = ftl.pick_gc_victim() {
                    for lpn in valid {
                        ftl.map_write(lpn);
                    }
                    ftl.finish_gc(victim);
                }
            }
        }
        // all mapped LPNs resolve to distinct PPAs
        let mut seen = std::collections::HashSet::new();
        for lpn in 0..universe {
            let before = ftl.mapped_pages();
            let ppa = ftl.translate_or_map(lpn);
            let _ = before;
            assert!(seen.insert(ppa), "PPA aliased for lpn {lpn}");
        }
    }
}

/// SSD device: read-after-write returns the written bytes, regardless of
/// cache state and GC activity.
#[test]
fn prop_ssd_read_after_write() {
    use dockerssd::nvme::BlockBackend;
    let mut rng = Rng::new(4);
    let cfg = SsdConfig {
        blocks_per_package: 64,
        icl_fraction: 0.01,
        ..Default::default()
    };
    let mut dev = SsdDevice::new(cfg);
    let mut shadow: std::collections::HashMap<u64, Vec<u8>> = Default::default();
    for _ in 0..400 {
        let lba = rng.below(4096) * 8;
        if rng.chance(0.6) || !shadow.contains_key(&lba) {
            let val = vec![rng.next_u64() as u8; 4096];
            dev.write(SimTime::ZERO, lba, &val);
            shadow.insert(lba, val);
        } else {
            let (_, data) = dev.read(SimTime::ZERO, lba, 8);
            assert_eq!(&data[..], &shadow[&lba][..], "lba {lba}");
        }
    }
}

/// Inode lock: mutual exclusion holds under arbitrary acquire/release
/// sequences, and counters never go negative.
#[test]
fn prop_inode_lock_mutual_exclusion() {
    let mut rng = Rng::new(5);
    for _ in 0..CASES {
        let mut t = InodeLockTable::new();
        let mut host_refs = 0i64;
        let mut isp_refs = 0i64;
        for _ in 0..100 {
            let side = if rng.chance(0.5) { LockSide::Host } else { LockSide::Isp };
            if rng.chance(0.6) {
                if t.acquire(7, side) {
                    match side {
                        LockSide::Host => host_refs += 1,
                        LockSide::Isp => isp_refs += 1,
                    }
                }
            } else {
                t.release(7, side);
                match side {
                    LockSide::Host => host_refs = (host_refs - 1).max(0),
                    LockSide::Isp => isp_refs = (isp_refs - 1).max(0),
                }
            }
            // invariant: never both sides holding
            assert!(!(host_refs > 0 && isp_refs > 0), "both sides hold the inode");
            // model agrees with table
            assert_eq!(t.may_access(7, LockSide::Host), isp_refs == 0);
            assert_eq!(t.may_access(7, LockSide::Isp), host_refs == 0);
        }
    }
}

/// Batcher: every pushed request appears in exactly one formed batch.
#[test]
fn prop_batcher_conservation() {
    let mut rng = Rng::new(6);
    for _ in 0..CASES {
        let width = 1 + rng.below(8) as usize;
        let n = rng.below(50);
        let mut b = Batcher::new(width, 16, std::time::Duration::ZERO);
        for id in 0..n {
            b.push(InferenceRequest {
                id,
                prompt: vec![1; rng.below(40) as usize],
                max_new_tokens: 1 + rng.below(8) as usize,
            });
        }
        let mut seen = Vec::new();
        while let Some(batch) = b.form(true) {
            assert!(batch.live <= width);
            assert_eq!(batch.prompts.len(), width);
            for p in &batch.prompts {
                assert_eq!(p.len(), 16, "prompt normalized");
            }
            seen.extend(batch.requests.iter().map(|r| r.id));
        }
        seen.sort();
        assert_eq!(seen, (0..n).collect::<Vec<u64>>());
    }
}

/// Router: outstanding counts stay bounded by picks minus completes, and
/// dispatch imbalance never exceeds 1 when all batches complete promptly.
#[test]
fn prop_router_balance() {
    let mut rng = Rng::new(7);
    for _ in 0..CASES {
        let nodes = 1 + rng.below(16) as usize;
        let mut r = Router::new(nodes);
        let picks = rng.below(200);
        for _ in 0..picks {
            let n = r.pick();
            r.complete(n);
        }
        let counts: Vec<u64> = (0..nodes as u32).map(|n| r.dispatched_of(n)).collect();
        let min = counts.iter().min().unwrap();
        let max = counts.iter().max().unwrap();
        assert!(max - min <= 1, "imbalance {counts:?}");
    }
}

/// LLM simulator monotonicity: total time grows with sequence length and
/// with batch size; memory requirement grows with KV.
#[test]
fn prop_llm_monotonicity() {
    let mut rng = Rng::new(8);
    let llms = all_llms();
    for _ in 0..60 {
        let llm = &llms[rng.below(llms.len() as u64) as usize];
        let dev = DeviceProfile::dockerssd();
        let tp = 1 << rng.below(5);
        let par = Parallelism { dp: 1, tp, pp: 1 };
        let s1 = 64 << rng.below(6);
        let s2 = s1 * 2;
        let t1 = sequence_time(llm, &dev, par, s1, 1, true).total();
        let t2 = sequence_time(llm, &dev, par, s2, 1, true).total();
        assert!(t2 > t1, "{}: seq {s1}->{s2} time {t1}->{t2}", llm.name);
        let b1 = sequence_time(llm, &dev, par, s1, 1, true).total();
        let b4 = sequence_time(llm, &dev, par, s1, 4, true).total();
        assert!(b4 >= b1, "{}: batch must not speed up fixed parallelism", llm.name);
    }
}

/// λFS: writing k files and reading them back yields identical bytes,
/// for random sizes spanning page boundaries.
#[test]
fn prop_lambdafs_durability() {
    use dockerssd::lambdafs::LambdaFs;
    let mut rng = Rng::new(9);
    let cfg = SsdConfig::default();
    let mut dev = SsdDevice::new(cfg);
    let mut fs = LambdaFs::over_device(&dev);
    let mut shadow = Vec::new();
    for i in 0..60 {
        let len = (rng.below(20_000) + 1) as usize;
        let body: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let path = format!("/data/p{i}");
        fs.write_file(&mut dev, SimTime::ZERO, &path, &body, LockSide::Host).unwrap();
        shadow.push((path, body));
    }
    for (path, body) in &shadow {
        let r = fs.read_file(&mut dev, SimTime::ZERO, path, LockSide::Host).unwrap();
        assert_eq!(&r.value, body, "{path}");
    }
}
