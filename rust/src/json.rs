//! Minimal JSON parser/serializer (in-crate substitute for serde_json —
//! this build environment is fully offline; DESIGN.md §4).
//!
//! Supports the complete JSON grammar except exotic number forms; good
//! enough for `artifacts/manifest.json`, config files, and mini-docker
//! image manifests.  Parsing is recursive-descent over bytes; numbers are
//! f64 (with an i64 fast path preserved for integers).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(n) => Some(*n),
            Json::Num(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|v| u64::try_from(v).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(n) => Some(*n as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- constructors ----------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- serializer ------------------------------------------------------
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(n) => {
                let _ = write!(out, "{}", n);
            }
            Json::Num(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{}", f);
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Errors carry a byte offset for debugging.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(format!("unexpected byte at {}", self.pos)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or("eof in \\u")? as char;
                            code = code * 16 + d.to_digit(16).ok_or("bad hex in \\u")?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(format!("bad escape at byte {}", self.pos)),
                },
                Some(b) if b >= 0x20 => {
                    // re-decode UTF-8: walk back and take the full char
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    let chunk = &self.bytes[start..self.pos.min(self.bytes.len())];
                    s.push_str(std::str::from_utf8(chunk).map_err(|_| "bad utf8")?);
                }
                _ => return Err(format!("unterminated string at byte {}", self.pos)),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if is_float {
            text.parse::<f64>()
                .map(Json::Num)
                .map_err(|e| format!("bad number '{text}': {e}"))
        } else {
            text.parse::<i64>()
                .map(Json::Int)
                .or_else(|_| text.parse::<f64>().map(Json::Num))
                .map_err(|e| format!("bad number '{text}': {e}"))
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("42").unwrap(), Json::Int(42));
        assert_eq!(parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": null}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d").unwrap().get("e").unwrap(), &Json::Null);
    }

    #[test]
    fn handles_escapes_and_unicode() {
        let v = parse(r#""line\nbreak \"q\" A café""#).unwrap();
        assert_eq!(v.as_str(), Some("line\nbreak \"q\" A café"));
        // raw UTF-8 in strings
        let v = parse("\"héllo → world\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → world"));
    }

    #[test]
    fn round_trips_through_dump() {
        let src = r#"{"config":{"batch":4,"d_model":256},"params":[{"name":"tok_emb","shape":[512,256],"offset_bytes":0}],"ok":true,"pi":3.25}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{'single': 1}").is_err());
    }

    #[test]
    fn accessor_helpers() {
        let v = parse(r#"{"n": 5, "f": 2.5, "s": "x", "b": false, "a": [1]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(5));
        assert_eq!(v.get("f").unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("n").unwrap().as_f64(), Some(5.0));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(v.get("missing"), None);
        assert_eq!(v.get("s").unwrap().as_i64(), None);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(parse("[]").unwrap().dump(), "[]");
    }

    #[test]
    fn large_manifest_like_document() {
        // shape of artifacts/manifest.json
        let mut params = String::from("[");
        for i in 0..16 {
            if i > 0 {
                params.push(',');
            }
            params.push_str(&format!(
                r#"{{"name":"p{i}","shape":[{i},256],"offset_bytes":{},"size_bytes":1024}}"#,
                i * 1024
            ));
        }
        params.push(']');
        let doc = format!(r#"{{"params":{params},"weights_bytes":16384}}"#);
        let v = parse(&doc).unwrap();
        assert_eq!(v.get("params").unwrap().as_arr().unwrap().len(), 16);
        assert_eq!(
            v.get("params").unwrap().idx(3).unwrap().get("offset_bytes").unwrap().as_u64(),
            Some(3072)
        );
    }
}
