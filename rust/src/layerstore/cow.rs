//! Copy-on-write writable layers over the chunk store.
//!
//! A writable layer is a container's private view of its image: a vector
//! of chunk references into [`super::LayerStore`], initialized by sharing
//! the image blobs' chunks (refcount++ each, zero bytes copied).  Writes
//! follow the nrfs rule (SNIPPETS.md): "if a write is made to an object
//! with a reference count higher than 1 a copy will be made first" — a
//! CoW break.  Chunks the layer holds exclusively are rewritten in place.

use std::collections::HashMap;

use super::LayerStore;
use crate::lambdafs::{FsError, FsResult, LambdaFs};
use crate::metrics::{names, Counters};
use crate::ssd::SsdDevice;
use crate::util::SimTime;

pub type LayerId = u64;

struct WritableLayer {
    chunks: Vec<u64>,
    len: u64,
}

/// All writable layers of one DockerSSD.
#[derive(Default)]
pub struct CowStore {
    layers: HashMap<LayerId, WritableLayer>,
    next_id: LayerId,
    /// Writes that had to copy a shared chunk first.
    pub cow_breaks: u64,
    /// Chunk rewrites of any kind (in-place + breaks).
    pub chunk_writes: u64,
}

impl CowStore {
    pub fn new() -> Self {
        CowStore {
            layers: HashMap::new(),
            next_id: 1,
            cow_breaks: 0,
            chunk_writes: 0,
        }
    }

    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }

    pub fn len_of(&self, layer: LayerId) -> Option<u64> {
        self.layers.get(&layer).map(|l| l.len)
    }

    /// Chunk digests currently backing a layer (for tests/diagnostics).
    pub fn chunks_of(&self, layer: LayerId) -> Option<&[u64]> {
        self.layers.get(&layer).map(|l| l.chunks.as_slice())
    }

    /// Create a writable layer over an image's blob chain (bottom-most
    /// first), sharing every chunk — no bytes move.  `None` if any blob
    /// is missing from the store.
    pub fn fork_from_blobs(&mut self, store: &mut LayerStore, blobs: &[u64]) -> Option<LayerId> {
        let mut chunks = Vec::new();
        let mut len = 0u64;
        for d in blobs {
            chunks.extend_from_slice(store.blob_chunks(*d)?);
            len += store.blob_len(*d)?;
        }
        for c in &chunks {
            store
                .incref_chunk(*c)
                .expect("blob recipe references live chunks");
        }
        let id = self.next_id;
        self.next_id += 1;
        self.layers.insert(id, WritableLayer { chunks, len });
        Some(id)
    }

    /// Clone a writable layer (container fork): shares all chunks.
    pub fn clone_layer(&mut self, store: &mut LayerStore, layer: LayerId) -> Option<LayerId> {
        let (chunks, len) = {
            let l = self.layers.get(&layer)?;
            (l.chunks.clone(), l.len)
        };
        for c in &chunks {
            store.incref_chunk(*c).expect("layer references live chunks");
        }
        let id = self.next_id;
        self.next_id += 1;
        self.layers.insert(id, WritableLayer { chunks, len });
        Some(id)
    }

    /// Read a layer's full contents, charging flash read time per chunk.
    pub fn read(
        &self,
        store: &mut LayerStore,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        layer: LayerId,
    ) -> Result<FsResult<Vec<u8>>, FsError> {
        let l = self.layers.get(&layer).ok_or(FsError::NotFound)?;
        let chunks = l.chunks.clone();
        let mut out = Vec::with_capacity(l.len as usize);
        let mut done = at;
        for c in chunks {
            let r = store.read_chunk(fs, dev, done, c)?;
            done = r.done;
            out.extend_from_slice(&r.value);
        }
        Ok(FsResult { value: out, done })
    }

    /// Write `data` at byte `offset` within the layer (read-modify-write
    /// at chunk granularity).  Shared chunks are copied first (CoW
    /// break); exclusive chunks are rewritten in place; a write that
    /// leaves a chunk's bytes unchanged is a no-op.  Writes must stay
    /// within the layer's length.
    pub fn write_at(
        &mut self,
        store: &mut LayerStore,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        layer: LayerId,
        offset: u64,
        data: &[u8],
    ) -> Result<FsResult<()>, FsError> {
        let l = self.layers.get(&layer).ok_or(FsError::NotFound)?;
        let end = offset + data.len() as u64;
        assert!(end <= l.len, "write [{offset}, {end}) beyond layer len {}", l.len);

        // chunk spans: (index, digest, start offset, length)
        let mut spans = Vec::new();
        let mut cursor = 0u64;
        for (i, &c) in l.chunks.iter().enumerate() {
            let clen = store.dedup.bytes_of(c).expect("layer chunk is live");
            if cursor < end && cursor + clen > offset {
                spans.push((i, c, cursor, clen));
            }
            cursor += clen;
        }

        let mut done = at;
        let mut replacements: Vec<(usize, u64)> = Vec::new();
        for (i, old, start, clen) in spans {
            let r = store.read_chunk(fs, dev, done, old)?;
            done = r.done;
            let mut bytes = r.value;
            debug_assert_eq!(bytes.len() as u64, clen);
            let lo = offset.max(start);
            let hi = end.min(start + clen);
            let src = &data[(lo - offset) as usize..(hi - offset) as usize];
            let dst = &mut bytes[(lo - start) as usize..(hi - start) as usize];
            if dst == src {
                continue; // identical content: no write, no break
            }
            dst.copy_from_slice(src);
            let shared = store.dedup.refs_of(old) > 1;
            let w = store.reference_chunk_data(fs, dev, done, &bytes)?;
            done = w.done;
            store.release_chunk(fs, old)?;
            if shared {
                self.cow_breaks += 1;
            }
            self.chunk_writes += 1;
            replacements.push((i, w.value));
        }
        let l = self.layers.get_mut(&layer).expect("checked above");
        for (i, digest) in replacements {
            l.chunks[i] = digest;
        }
        Ok(FsResult { value: (), done })
    }

    /// Destroy a layer, releasing its chunk references (unshared chunks
    /// are reclaimed from λFS).
    pub fn drop_layer(
        &mut self,
        store: &mut LayerStore,
        fs: &mut LambdaFs,
        layer: LayerId,
    ) -> Result<(), FsError> {
        let l = self.layers.remove(&layer).ok_or(FsError::NotFound)?;
        for c in l.chunks {
            store.release_chunk(fs, c)?;
        }
        Ok(())
    }

    pub fn export_counters(&self, c: &mut Counters) {
        c.add(names::COW_BREAKS, self.cow_breaks);
        c.add(names::COW_CHUNK_WRITES, self.chunk_writes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SsdConfig;

    const CHUNK: usize = 4 << 10;

    fn rig() -> (CowStore, LayerStore, LambdaFs, SsdDevice) {
        let dev = SsdDevice::new(SsdConfig::default());
        let fs = LambdaFs::over_device(&dev);
        (CowStore::new(), LayerStore::new(CHUNK), fs, dev)
    }

    fn body(seed: u8, len: usize) -> Vec<u8> {
        (0..len).map(|i| seed.wrapping_add((i % 247) as u8)).collect()
    }

    #[test]
    fn fork_shares_chunks_and_reads_back_image() {
        let (mut cow, mut st, mut fs, mut dev) = rig();
        let l0 = body(1, 2 * CHUNK);
        let l1 = body(2, CHUNK);
        let d0 = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &l0).unwrap().value;
        let d1 = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &l1).unwrap().value;
        let unique = st.unique_bytes();
        let layer = cow.fork_from_blobs(&mut st, &[d0, d1]).unwrap();
        assert_eq!(st.unique_bytes(), unique, "fork copies nothing");
        let r = cow.read(&mut st, &mut fs, &mut dev, SimTime::ZERO, layer).unwrap();
        let mut want = l0.clone();
        want.extend(&l1);
        assert_eq!(r.value, want);
    }

    #[test]
    fn write_to_shared_chunk_breaks_cow_and_preserves_parent() {
        let (mut cow, mut st, mut fs, mut dev) = rig();
        let blob = body(3, 3 * CHUNK);
        let d = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &blob).unwrap().value;
        let layer = cow.fork_from_blobs(&mut st, &[d]).unwrap();
        let patch = vec![0xEE; 100];
        cow.write_at(&mut st, &mut fs, &mut dev, SimTime::ZERO, layer, (CHUNK + 7) as u64, &patch)
            .unwrap();
        assert_eq!(cow.cow_breaks, 1);
        // parent blob is untouched
        let parent = st.get_blob(&mut fs, &mut dev, SimTime::ZERO, d).unwrap();
        assert_eq!(parent.value, blob);
        // layer sees the patch
        let r = cow.read(&mut st, &mut fs, &mut dev, SimTime::ZERO, layer).unwrap();
        assert_eq!(&r.value[CHUNK + 7..CHUNK + 107], &patch[..]);
        assert_eq!(r.value[..CHUNK], blob[..CHUNK]);
    }

    #[test]
    fn exclusive_chunk_rewrites_in_place_without_break() {
        let (mut cow, mut st, mut fs, mut dev) = rig();
        let blob = body(4, CHUNK);
        let d = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &blob).unwrap().value;
        let layer = cow.fork_from_blobs(&mut st, &[d]).unwrap();
        cow.write_at(&mut st, &mut fs, &mut dev, SimTime::ZERO, layer, 0, &[1, 2, 3])
            .unwrap();
        assert_eq!(cow.cow_breaks, 1, "first write copies off the blob");
        let chunks_before = st.dedup.chunk_count();
        cow.write_at(&mut st, &mut fs, &mut dev, SimTime::ZERO, layer, 0, &[9, 9, 9])
            .unwrap();
        assert_eq!(cow.cow_breaks, 1, "second write owns the chunk");
        assert_eq!(cow.chunk_writes, 2);
        assert_eq!(st.dedup.chunk_count(), chunks_before, "old private chunk reclaimed");
    }

    #[test]
    fn identical_write_is_noop() {
        let (mut cow, mut st, mut fs, mut dev) = rig();
        let blob = body(5, CHUNK);
        let d = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &blob).unwrap().value;
        let layer = cow.fork_from_blobs(&mut st, &[d]).unwrap();
        cow.write_at(&mut st, &mut fs, &mut dev, SimTime::ZERO, layer, 10, &blob[10..20].to_vec())
            .unwrap();
        assert_eq!(cow.cow_breaks, 0);
        assert_eq!(cow.chunk_writes, 0);
    }

    #[test]
    fn clone_isolates_siblings() {
        let (mut cow, mut st, mut fs, mut dev) = rig();
        let blob = body(6, 2 * CHUNK);
        let d = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &blob).unwrap().value;
        let a = cow.fork_from_blobs(&mut st, &[d]).unwrap();
        let b = cow.clone_layer(&mut st, a).unwrap();
        cow.write_at(&mut st, &mut fs, &mut dev, SimTime::ZERO, b, 0, &[7u8; 64])
            .unwrap();
        let ra = cow.read(&mut st, &mut fs, &mut dev, SimTime::ZERO, a).unwrap();
        assert_eq!(ra.value, blob, "sibling a unaffected by b's write");
        let rb = cow.read(&mut st, &mut fs, &mut dev, SimTime::ZERO, b).unwrap();
        assert_eq!(&rb.value[..64], &[7u8; 64]);
    }

    #[test]
    fn drop_layers_then_blob_reclaims_everything() {
        let (mut cow, mut st, mut fs, mut dev) = rig();
        let blob = body(7, 2 * CHUNK + 100);
        let d = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &blob).unwrap().value;
        let a = cow.fork_from_blobs(&mut st, &[d]).unwrap();
        let b = cow.clone_layer(&mut st, a).unwrap();
        cow.write_at(&mut st, &mut fs, &mut dev, SimTime::ZERO, b, 0, &[1u8; 32]).unwrap();
        cow.drop_layer(&mut st, &mut fs, a).unwrap();
        cow.drop_layer(&mut st, &mut fs, b).unwrap();
        st.unref_blob(&mut fs, d).unwrap();
        assert_eq!(st.unique_bytes(), 0);
        assert_eq!(st.dedup.chunk_count(), 0);
        assert!(fs.list("/images/chunks").unwrap().is_empty());
    }

    #[test]
    fn write_spanning_chunks_patches_both() {
        let (mut cow, mut st, mut fs, mut dev) = rig();
        let blob = body(8, 2 * CHUNK);
        let d = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &blob).unwrap().value;
        let layer = cow.fork_from_blobs(&mut st, &[d]).unwrap();
        let patch: Vec<u8> = (0..200).map(|i| i as u8 ^ 0xFF).collect();
        let off = (CHUNK - 100) as u64;
        cow.write_at(&mut st, &mut fs, &mut dev, SimTime::ZERO, layer, off, &patch).unwrap();
        assert_eq!(cow.cow_breaks, 2, "both spanned chunks were shared");
        let r = cow.read(&mut st, &mut fs, &mut dev, SimTime::ZERO, layer).unwrap();
        assert_eq!(&r.value[off as usize..off as usize + 200], &patch[..]);
        assert_eq!(st.get_blob(&mut fs, &mut dev, SimTime::ZERO, d).unwrap().value, blob);
    }

    #[test]
    fn fork_missing_blob_is_none() {
        let (mut cow, mut st, _, _) = rig();
        assert!(cow.fork_from_blobs(&mut st, &[0xBAD]).is_none());
    }
}
