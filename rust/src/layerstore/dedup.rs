//! Chunk-level dedup index: content digest -> reference-counted entry.
//!
//! The index is the single source of truth for chunk liveness.  Every
//! consumer of a chunk — a stored blob recipe, a writable CoW layer —
//! holds exactly one reference per use; a chunk whose count reaches zero
//! is reclaimable and its λFS backing file can be unlinked (the
//! nrfs-style "reference count of an object" rule, SNIPPETS.md).

use std::collections::HashMap;

/// A chunk's content digest (FNV-1a over the chunk bytes).  The same id
/// space is used device-locally by the [`DedupIndex`] and pool-wide by
/// [`crate::layerstore::PoolLayerCache`]'s per-node chunk presence map —
/// a chunk is the unit of dedup *and* the unit of peer transfer.
pub type ChunkId = u64;

/// Dense interner over the pool's chunk-id namespace: every chunk id
/// ever seen gets a stable slot, so per-chunk state can live in parallel
/// `Vec`s indexed by slot instead of maps hashed per access.  Slots are
/// never reclaimed — "no longer present" is expressed by the indexed
/// state (an empty holder list), not by forgetting the id.
#[derive(Default)]
pub(crate) struct ChunkInterner {
    idx: HashMap<ChunkId, u32>,
    ids: Vec<ChunkId>,
}

impl ChunkInterner {
    pub(crate) fn intern(&mut self, chunk: ChunkId) -> usize {
        match self.idx.get(&chunk) {
            Some(&i) => i as usize,
            None => {
                let i = self.ids.len() as u32;
                self.idx.insert(chunk, i);
                self.ids.push(chunk);
                i as usize
            }
        }
    }

    pub(crate) fn get(&self, chunk: ChunkId) -> Option<usize> {
        self.idx.get(&chunk).map(|&i| i as usize)
    }

    pub(crate) fn id(&self, slot: usize) -> ChunkId {
        self.ids[slot]
    }

    pub(crate) fn len(&self) -> usize {
        self.ids.len()
    }
}

/// One live chunk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkEntry {
    /// Outstanding references (blob recipes + writable layers).
    pub refs: u32,
    /// Content length in bytes.
    pub bytes: u64,
}

/// Outcome of dropping one reference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decref {
    /// Chunk still referenced; remaining count.
    Live(u32),
    /// Last reference dropped; the chunk's bytes are reclaimable.
    Reclaimed(u64),
}

/// The dedup index over all store chunks.
#[derive(Default)]
pub struct DedupIndex {
    chunks: HashMap<u64, ChunkEntry>,
    unique_bytes: u64,
    logical_bytes: u64,
}

impl DedupIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a reference on `digest`, creating the entry if the content is
    /// new.  Returns `true` exactly when the caller must persist the
    /// chunk (first reference), `false` on a dedup hit.
    pub fn reference(&mut self, digest: u64, bytes: u64) -> bool {
        self.logical_bytes += bytes;
        match self.chunks.get_mut(&digest) {
            Some(e) => {
                e.refs += 1;
                false
            }
            None => {
                self.chunks.insert(digest, ChunkEntry { refs: 1, bytes });
                self.unique_bytes += bytes;
                true
            }
        }
    }

    /// Take a reference on a chunk already known to the index.  Returns
    /// the new count, or `None` if the digest is unknown.
    pub fn incref(&mut self, digest: u64) -> Option<u32> {
        let e = self.chunks.get_mut(&digest)?;
        e.refs += 1;
        self.logical_bytes += e.bytes;
        Some(e.refs)
    }

    /// Drop one reference.  Panics if the digest is unknown — a release
    /// without a matching reference is a bookkeeping bug, not a runtime
    /// condition.
    pub fn release(&mut self, digest: u64) -> Decref {
        let e = self
            .chunks
            .get_mut(&digest)
            .unwrap_or_else(|| panic!("release of unknown chunk {digest:016x}"));
        e.refs -= 1;
        self.logical_bytes -= e.bytes;
        if e.refs == 0 {
            let bytes = e.bytes;
            self.chunks.remove(&digest);
            self.unique_bytes -= bytes;
            Decref::Reclaimed(bytes)
        } else {
            Decref::Live(self.chunks[&digest].refs)
        }
    }

    pub fn contains(&self, digest: u64) -> bool {
        self.chunks.contains_key(&digest)
    }

    pub fn refs_of(&self, digest: u64) -> u32 {
        self.chunks.get(&digest).map_or(0, |e| e.refs)
    }

    pub fn bytes_of(&self, digest: u64) -> Option<u64> {
        self.chunks.get(&digest).map(|e| e.bytes)
    }

    /// Bytes of distinct content currently stored.
    pub fn unique_bytes(&self) -> u64 {
        self.unique_bytes
    }

    /// Bytes as seen by consumers (every reference counts its length).
    pub fn logical_bytes(&self) -> u64 {
        self.logical_bytes
    }

    pub fn chunk_count(&self) -> usize {
        self.chunks.len()
    }

    /// logical / unique — 1.0 means no sharing, higher is better.
    pub fn dedup_ratio(&self) -> f64 {
        if self.unique_bytes == 0 {
            return 1.0;
        }
        self.logical_bytes as f64 / self.unique_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_reference_persists_later_ones_dedup() {
        let mut idx = DedupIndex::new();
        assert!(idx.reference(0xA, 100));
        assert!(!idx.reference(0xA, 100));
        assert!(idx.reference(0xB, 50));
        assert_eq!(idx.refs_of(0xA), 2);
        assert_eq!(idx.unique_bytes(), 150);
        assert_eq!(idx.logical_bytes(), 250);
    }

    #[test]
    fn release_reclaims_at_zero() {
        let mut idx = DedupIndex::new();
        idx.reference(0xA, 100);
        idx.incref(0xA).unwrap();
        assert_eq!(idx.release(0xA), Decref::Live(1));
        assert_eq!(idx.release(0xA), Decref::Reclaimed(100));
        assert!(!idx.contains(0xA));
        assert_eq!(idx.unique_bytes(), 0);
        assert_eq!(idx.logical_bytes(), 0);
    }

    #[test]
    fn incref_unknown_is_none() {
        let mut idx = DedupIndex::new();
        assert_eq!(idx.incref(0x123), None);
    }

    #[test]
    fn dedup_ratio_reflects_sharing() {
        let mut idx = DedupIndex::new();
        assert_eq!(idx.dedup_ratio(), 1.0);
        idx.reference(0xA, 100);
        idx.reference(0xA, 100);
        idx.reference(0xA, 100);
        assert!((idx.dedup_ratio() - 3.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn release_unknown_panics() {
        DedupIndex::new().release(0xDEAD);
    }
}
