//! LayerStore — content-addressed, deduplicated, copy-on-write layer
//! storage shared across the SSD pool.
//!
//! The seed reproduction moved every image blob onto each node's private
//! namespace verbatim, so booting N replicas cost N × image bytes.  This
//! subsystem makes container-boot cost scale with *unique* bytes instead
//! (the nrfs idiom from SNIPPETS.md — out-of-band dedup + CoW via
//! per-object reference counts):
//!
//! * [`LayerStore`] (this module): blobs are split into fixed-size
//!   chunks, each addressed by its FNV-1a digest and persisted as a λFS
//!   file under `/images/chunks/<digest>` — so every chunk read/write
//!   charges simulated flash time through [`crate::lambdafs`].
//! * [`dedup`]: the chunk refcount index; a chunk is stored once no
//!   matter how many blobs or writable layers reference it.
//! * [`cow`]: writable per-container layers.  A write to a chunk with
//!   refcount > 1 copies first (CoW break); exclusive chunks are
//!   rewritten in place.
//! * [`poolcache`]: pool-wide layer-presence map at *chunk* granularity.
//!   A node that needs a layer fetches only the chunks it misses, each
//!   from its nearest healthy holder (full or partial) over the Ether-oN
//!   intranet instead of re-crossing the registry WAN; every byte a
//!   fetch moves rides the shared [`crate::fabric`] link queues, and
//!   prefetch traffic is scheduled on the fabric's event-driven engine
//!   so its receipts are re-timed under contention.

pub mod cow;
pub mod dedup;
pub mod poolcache;

use std::collections::HashMap;

use crate::lambdafs::{FsError, FsResult, LambdaFs, LockSide};
use crate::metrics::{names, Counters};
use crate::ssd::SsdDevice;
use crate::util::{fnv1a, SimTime};

pub use cow::{CowStore, LayerId};
pub use dedup::{ChunkEntry, ChunkId, Decref, DedupIndex};
pub use poolcache::{
    ChunkPlan, FetchSource, HealStats, PoolLayerCache, PrefetchHandle, PurgeSummary,
};

/// Default chunk size: 64KiB, the nrfs embedded-data threshold — small
/// enough that single-file edits don't rewrite whole layers, large
/// enough that chunk metadata stays negligible.
pub const DEFAULT_CHUNK_BYTES: usize = 64 << 10;

/// How a stored blob is reassembled: its chunk digests, in order.
struct Recipe {
    chunks: Vec<u64>,
    len: u64,
    /// Blob-level references (images installed / pulls served).
    refs: u32,
}

/// Counters the store maintains; exported into [`Counters`] under the
/// canonical [`names`] keys.
#[derive(Clone, Debug, Default)]
pub struct StoreStats {
    /// put_blob calls that created a new recipe.
    pub blobs_stored: u64,
    /// put_blob / ref_blob calls satisfied by an existing recipe.
    pub blob_hits: u64,
    /// Chunk references satisfied without programming flash.
    pub dedup_hits: u64,
    pub chunks_written: u64,
    /// Cumulative bytes pushed through put_blob.
    pub bytes_logical: u64,
    /// Bytes actually programmed to flash.
    pub bytes_written: u64,
    /// Bytes avoided by chunk- or blob-level dedup.
    pub bytes_deduped: u64,
    pub chunks_reclaimed: u64,
    pub bytes_reclaimed: u64,
}

/// The content-addressed chunk store of one DockerSSD.
pub struct LayerStore {
    chunk_bytes: usize,
    pub dedup: DedupIndex,
    recipes: HashMap<u64, Recipe>,
    pub stats: StoreStats,
}

impl Default for LayerStore {
    fn default() -> Self {
        Self::new(DEFAULT_CHUNK_BYTES)
    }
}

impl LayerStore {
    pub fn new(chunk_bytes: usize) -> Self {
        assert!(chunk_bytes > 0, "chunk size must be positive");
        LayerStore {
            chunk_bytes,
            dedup: DedupIndex::new(),
            recipes: HashMap::new(),
            stats: StoreStats::default(),
        }
    }

    pub fn chunk_bytes(&self) -> usize {
        self.chunk_bytes
    }

    /// λFS backing file for a chunk.
    pub fn chunk_path(digest: u64) -> String {
        format!("/images/chunks/{digest:016x}")
    }

    pub fn has_blob(&self, digest: u64) -> bool {
        self.recipes.contains_key(&digest)
    }

    pub fn blob_len(&self, digest: u64) -> Option<u64> {
        self.recipes.get(&digest).map(|r| r.len)
    }

    pub fn blob_refs(&self, digest: u64) -> u32 {
        self.recipes.get(&digest).map_or(0, |r| r.refs)
    }

    /// Chunk digests of a stored blob, bottom-up order.
    pub fn blob_chunks(&self, digest: u64) -> Option<&[u64]> {
        self.recipes.get(&digest).map(|r| r.chunks.as_slice())
    }

    /// A stored blob's chunk recipe as (digest, bytes) pairs — the shape
    /// [`crate::layerstore::PoolLayerCache::describe_chunks`] takes, so
    /// a node can advertise its chunk-level presence pool-wide.
    pub fn blob_chunk_recipe(&self, digest: u64) -> Option<Vec<(ChunkId, u64)>> {
        let r = self.recipes.get(&digest)?;
        Some(
            r.chunks
                .iter()
                .map(|c| (*c, self.dedup.bytes_of(*c).unwrap_or(0)))
                .collect(),
        )
    }

    /// Bytes of distinct content on flash.
    pub fn unique_bytes(&self) -> u64 {
        self.dedup.unique_bytes()
    }

    // --- chunk-level operations (shared with the CoW layer) ---------------

    /// Reference chunk content: dedup-hit if the content exists, else
    /// persist it to λFS (charging program time).  Returns the digest.
    pub fn reference_chunk_data(
        &mut self,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        data: &[u8],
    ) -> Result<FsResult<u64>, FsError> {
        let digest = fnv1a(data);
        if self.dedup.reference(digest, data.len() as u64) {
            self.stats.chunks_written += 1;
            self.stats.bytes_written += data.len() as u64;
            let r = fs.write_file(dev, at, &Self::chunk_path(digest), data, LockSide::Isp)?;
            Ok(FsResult {
                value: digest,
                done: r.done,
            })
        } else {
            self.stats.dedup_hits += 1;
            self.stats.bytes_deduped += data.len() as u64;
            Ok(FsResult {
                value: digest,
                done: at,
            })
        }
    }

    /// Take an extra reference on an existing chunk.
    pub fn incref_chunk(&mut self, digest: u64) -> Result<(), FsError> {
        self.dedup.incref(digest).map(|_| ()).ok_or(FsError::NotFound)
    }

    /// Read one chunk back, charging flash read time.
    pub fn read_chunk(
        &mut self,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        digest: u64,
    ) -> Result<FsResult<Vec<u8>>, FsError> {
        fs.read_file(dev, at, &Self::chunk_path(digest), LockSide::Isp)
    }

    /// Drop one chunk reference; unlinks the λFS file when the count hits
    /// zero.  Returns `true` if the chunk was reclaimed.
    pub fn release_chunk(&mut self, fs: &mut LambdaFs, digest: u64) -> Result<bool, FsError> {
        match self.dedup.release(digest) {
            Decref::Live(_) => Ok(false),
            Decref::Reclaimed(bytes) => {
                self.stats.chunks_reclaimed += 1;
                self.stats.bytes_reclaimed += bytes;
                fs.unlink(&Self::chunk_path(digest))?;
                Ok(true)
            }
        }
    }

    // --- blob-level operations --------------------------------------------

    /// Store a blob: chunk it, dedup each chunk, persist the new ones.
    /// Storing content that is already present is a pure metadata hit
    /// (no flash traffic, no simulated time).  Returns the blob digest.
    pub fn put_blob(
        &mut self,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        bytes: &[u8],
    ) -> Result<FsResult<u64>, FsError> {
        let digest = fnv1a(bytes);
        self.stats.bytes_logical += bytes.len() as u64;
        if let Some(r) = self.recipes.get_mut(&digest) {
            r.refs += 1;
            self.stats.blob_hits += 1;
            self.stats.bytes_deduped += bytes.len() as u64;
            return Ok(FsResult {
                value: digest,
                done: at,
            });
        }
        let mut chunks = Vec::new();
        let mut done = at;
        if bytes.is_empty() {
            // zero-length blob: recipe with no chunks
        } else {
            for chunk in bytes.chunks(self.chunk_bytes) {
                let r = self.reference_chunk_data(fs, dev, done, chunk)?;
                done = r.done;
                chunks.push(r.value);
            }
        }
        self.recipes.insert(
            digest,
            Recipe {
                chunks,
                len: bytes.len() as u64,
                refs: 1,
            },
        );
        self.stats.blobs_stored += 1;
        Ok(FsResult {
            value: digest,
            done,
        })
    }

    /// Take an extra blob-level reference (an image pull served entirely
    /// from the store).  Returns `false` if the blob is absent.
    pub fn ref_blob(&mut self, digest: u64) -> bool {
        match self.recipes.get_mut(&digest) {
            Some(r) => {
                r.refs += 1;
                self.stats.blob_hits += 1;
                self.stats.bytes_deduped += r.len;
                true
            }
            None => false,
        }
    }

    /// Reassemble a blob, charging read time chunk by chunk.
    pub fn get_blob(
        &mut self,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        digest: u64,
    ) -> Result<FsResult<Vec<u8>>, FsError> {
        let (chunks, len) = {
            let r = self.recipes.get(&digest).ok_or(FsError::NotFound)?;
            (r.chunks.clone(), r.len)
        };
        let mut out = Vec::with_capacity(len as usize);
        let mut done = at;
        for c in chunks {
            let r = self.read_chunk(fs, dev, done, c)?;
            done = r.done;
            out.extend_from_slice(&r.value);
        }
        debug_assert_eq!(out.len() as u64, len, "recipe chunks must partition the blob");
        Ok(FsResult { value: out, done })
    }

    /// Drop one blob reference; at zero the recipe is removed and its
    /// chunk references released (reclaiming unshared chunks from λFS).
    pub fn unref_blob(&mut self, fs: &mut LambdaFs, digest: u64) -> Result<(), FsError> {
        let recipe = self.recipes.get_mut(&digest).ok_or(FsError::NotFound)?;
        recipe.refs -= 1;
        if recipe.refs > 0 {
            return Ok(());
        }
        let chunks = self.recipes.remove(&digest).expect("recipe present").chunks;
        for c in chunks {
            self.release_chunk(fs, c)?;
        }
        Ok(())
    }

    /// Export the store's counters under the canonical metric names.
    pub fn export_counters(&self, c: &mut Counters) {
        c.add(names::DEDUP_HITS, self.stats.dedup_hits);
        c.add(names::CHUNKS_WRITTEN, self.stats.chunks_written);
        c.add(names::BYTES_WRITTEN, self.stats.bytes_written);
        c.add(names::BYTES_DEDUPED, self.stats.bytes_deduped);
        c.add(names::CHUNKS_RECLAIMED, self.stats.chunks_reclaimed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SsdConfig;

    fn rig() -> (LayerStore, LambdaFs, SsdDevice) {
        let dev = SsdDevice::new(SsdConfig::default());
        let fs = LambdaFs::over_device(&dev);
        (LayerStore::new(4 << 10), fs, dev)
    }

    fn body(seed: u8, len: usize) -> Vec<u8> {
        (0..len).map(|i| seed.wrapping_add((i % 251) as u8)).collect()
    }

    #[test]
    fn put_get_round_trips_and_charges_time() {
        let (mut st, mut fs, mut dev) = rig();
        let data = body(1, 10_000);
        let w = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &data).unwrap();
        assert!(w.done > SimTime::ZERO, "chunk writes must take simulated time");
        let r = st.get_blob(&mut fs, &mut dev, w.done, w.value).unwrap();
        assert_eq!(r.value, data);
        assert!(r.done > w.done, "chunk reads must take simulated time");
    }

    #[test]
    fn duplicate_put_is_free_metadata_hit() {
        let (mut st, mut fs, mut dev) = rig();
        let data = body(2, 20_000);
        let w1 = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &data).unwrap();
        let written = st.stats.bytes_written;
        let w2 = st.put_blob(&mut fs, &mut dev, w1.done, &data).unwrap();
        assert_eq!(w1.value, w2.value);
        assert_eq!(w2.done, w1.done, "dedup'd put must not program flash");
        assert_eq!(st.stats.bytes_written, written);
        assert_eq!(st.stats.blob_hits, 1);
        assert_eq!(st.blob_refs(w1.value), 2);
    }

    #[test]
    fn shared_chunks_stored_once_across_blobs() {
        let (mut st, mut fs, mut dev) = rig();
        // two blobs sharing their first 8KiB (two 4KiB chunks)
        let mut a = body(3, 8 << 10);
        let mut b = a.clone();
        a.extend(body(4, 4 << 10));
        b.extend(body(5, 4 << 10));
        st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &a).unwrap();
        let before = st.stats.bytes_written;
        st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &b).unwrap();
        assert_eq!(
            st.stats.bytes_written - before,
            4 << 10,
            "only b's unique tail chunk hits flash"
        );
        assert_eq!(st.stats.dedup_hits, 2);
        assert_eq!(st.unique_bytes(), 12 << 10);
    }

    #[test]
    fn unref_reclaims_unshared_chunks_only() {
        let (mut st, mut fs, mut dev) = rig();
        let mut a = body(6, 4 << 10);
        let shared = body(7, 4 << 10);
        a.extend(&shared);
        let mut b = shared.clone();
        b.extend(body(8, 4 << 10));
        let da = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &a).unwrap().value;
        let db = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &b).unwrap().value;
        st.unref_blob(&mut fs, da).unwrap();
        assert!(!st.has_blob(da));
        assert_eq!(st.stats.chunks_reclaimed, 1, "only a's private chunk goes");
        assert_eq!(st.unique_bytes(), 8 << 10);
        // b still reads back intact
        let r = st.get_blob(&mut fs, &mut dev, SimTime::ZERO, db).unwrap();
        assert_eq!(r.value, b);
        st.unref_blob(&mut fs, db).unwrap();
        assert_eq!(st.unique_bytes(), 0);
        assert!(fs.list("/images/chunks").unwrap().is_empty());
    }

    #[test]
    fn unref_respects_blob_refcount() {
        let (mut st, mut fs, mut dev) = rig();
        let data = body(9, 6_000);
        let d = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &data).unwrap().value;
        assert!(st.ref_blob(d));
        st.unref_blob(&mut fs, d).unwrap();
        assert!(st.has_blob(d), "one reference remains");
        st.unref_blob(&mut fs, d).unwrap();
        assert!(!st.has_blob(d));
    }

    #[test]
    fn blob_chunk_recipe_partitions_the_blob() {
        let (mut st, mut fs, mut dev) = rig();
        let data = body(11, 10_000); // 4KiB chunks: 4096 + 4096 + 1808
        let d = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &data).unwrap().value;
        let recipe = st.blob_chunk_recipe(d).expect("stored blob has a recipe");
        assert_eq!(recipe.len(), 3);
        assert_eq!(recipe.iter().map(|(_, b)| *b).sum::<u64>(), 10_000);
        assert_eq!(
            recipe.iter().map(|(c, _)| *c).collect::<Vec<_>>(),
            st.blob_chunks(d).unwrap()
        );
        assert!(st.blob_chunk_recipe(0xBAD).is_none());
    }

    #[test]
    fn empty_blob_round_trips() {
        let (mut st, mut fs, mut dev) = rig();
        let d = st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &[]).unwrap().value;
        let r = st.get_blob(&mut fs, &mut dev, SimTime::ZERO, d).unwrap();
        assert!(r.value.is_empty());
    }

    #[test]
    fn missing_blob_errors() {
        let (mut st, mut fs, mut dev) = rig();
        assert_eq!(
            st.get_blob(&mut fs, &mut dev, SimTime::ZERO, 0xBAD).unwrap_err(),
            FsError::NotFound
        );
        assert_eq!(st.unref_blob(&mut fs, 0xBAD).unwrap_err(), FsError::NotFound);
        assert!(!st.ref_blob(0xBAD));
    }

    #[test]
    fn counters_export_under_canonical_names() {
        let (mut st, mut fs, mut dev) = rig();
        let data = body(10, 9_000);
        st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &data).unwrap();
        st.put_blob(&mut fs, &mut dev, SimTime::ZERO, &data).unwrap();
        let mut c = Counters::new();
        st.export_counters(&mut c);
        assert!(c.get(names::BYTES_WRITTEN) >= 9_000);
        assert_eq!(c.get(names::BYTES_DEDUPED), 9_000);
    }
}
