//! Pool-wide layer-presence map: which nodes hold which blob digests.
//!
//! In the seed flow every `docker pull` on every node re-crossed the
//! registry WAN (paper Figure 2b step 1).  With the presence map, a node
//! missing a layer fetches it from the nearest healthy *peer* over the
//! Ether-oN intranet — registry traffic scales with unique bytes in the
//! pool, not with replica count, which is the whole point of
//! disaggregation ("In-Storage Domain-Specific Acceleration for
//! Serverless Computing", PAPERS.md, makes the same cold-start
//! locality argument).
//!
//! Every byte a fetch moves is routed through [`Fabric::transfer`], so
//! concurrent fetches contend for the shared array/tray/WAN links
//! instead of each seeing an idle wire.  [`PoolLayerCache::prefetch`]
//! issues the same traffic at background priority — it yields the wire
//! to foreground fetches within one frame quantum.

use std::collections::{BTreeSet, HashMap};

use crate::fabric::{Endpoint, Fabric, Priority, TransferReceipt};
use crate::metrics::{names, Counters};
use crate::pool::topology::{NodeId, PoolTopology};
use crate::util::SimTime;

/// Where a needed layer comes from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FetchSource {
    /// Already resident on the requesting node.
    Local,
    /// Copied from a peer DockerSSD over the intranet.
    Peer(NodeId),
    /// Pulled across the WAN from the registry.
    Registry,
}

/// The presence map plus fetch accounting.
#[derive(Default)]
pub struct PoolLayerCache {
    presence: HashMap<u64, BTreeSet<NodeId>>,
    pub local_hits: u64,
    pub peer_fetches: u64,
    pub registry_fetches: u64,
    pub bytes_local: u64,
    pub bytes_from_peers: u64,
    pub bytes_from_registry: u64,
    /// Bytes moved by background prefetch (also counted in the
    /// peer/registry totals above).
    pub prefetch_bytes: u64,
    /// (node, digest) pairs dropped by pool-wide GC.
    pub gc_evictions: u64,
    /// Layers whose presence came from a prefetch and whose first
    /// boot-path fetch hasn't consumed it yet, mapped to the prefetch's
    /// fabric finish time.  The first local hit waits for that tail (the
    /// bytes may still be in flight) and must not re-count bytes the
    /// prefetch already accounted.
    prefetched: HashMap<(NodeId, u64), SimTime>,
}

impl PoolLayerCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record that `node` now holds `digest`.
    pub fn register(&mut self, node: NodeId, digest: u64) {
        self.presence.entry(digest).or_default().insert(node);
    }

    /// Record that `node` dropped `digest` (image removed / GC).
    pub fn evict(&mut self, node: NodeId, digest: u64) {
        if let Some(set) = self.presence.get_mut(&digest) {
            set.remove(&node);
            if set.is_empty() {
                self.presence.remove(&digest);
            }
        }
        // a dropped layer's prefetch marker must not suppress the byte
        // accounting of a later, genuine warm hit
        self.prefetched.remove(&(node, digest));
    }

    pub fn node_has(&self, node: NodeId, digest: u64) -> bool {
        self.presence.get(&digest).is_some_and(|s| s.contains(&node))
    }

    pub fn holders(&self, digest: u64) -> Vec<NodeId> {
        self.presence
            .get(&digest)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default()
    }

    /// Nodes in the pool holding at least one byte of the image —
    /// i.e. candidates for locality-aware placement.
    pub fn layers_present(&self, node: NodeId, digests: &[u64]) -> usize {
        digests.iter().filter(|d| self.node_has(node, **d)).count()
    }

    /// Nearest healthy holder of `digest` by idle-wire fabric estimate
    /// (ties broken by lowest node id via BTreeSet iteration order +
    /// strict `<`).
    pub fn nearest_peer(
        &self,
        fabric: &Fabric,
        topo: &PoolTopology,
        node: NodeId,
        digest: u64,
        bytes: u64,
    ) -> Option<(NodeId, SimTime)> {
        let holders = self.presence.get(&digest)?;
        let mut best: Option<(NodeId, SimTime)> = None;
        for &h in holders {
            if h == node || !topo.node(h).is_some_and(|n| n.healthy) {
                continue;
            }
            let t = fabric.estimate(Endpoint::Node(h), Endpoint::Node(node), bytes);
            if best.is_none_or(|(_, bt)| t < bt) {
                best = Some((h, t));
            }
        }
        best
    }

    /// Decide where `node` would get `digest` from, and the idle-wire
    /// transfer estimate.  Does not mutate state or occupy links.
    pub fn plan(
        &self,
        fabric: &Fabric,
        topo: &PoolTopology,
        node: NodeId,
        digest: u64,
        bytes: u64,
    ) -> (FetchSource, SimTime) {
        if self.node_has(node, digest) {
            return (FetchSource::Local, SimTime::ZERO);
        }
        if let Some((peer, t)) = self.nearest_peer(fabric, topo, node, digest, bytes) {
            return (FetchSource::Peer(peer), t);
        }
        (
            FetchSource::Registry,
            fabric.estimate(Endpoint::Registry, Endpoint::Node(node), bytes),
        )
    }

    /// Execute a foreground fetch over the shared fabric: account for
    /// it, mark `node` as a holder, and return the source + the latency
    /// the fabric actually granted (including queue wait behind other
    /// in-flight transfers).  Fetching a layer whose prefetch is still
    /// in flight waits for the prefetch's tail instead of being free.
    pub fn fetch(
        &mut self,
        fabric: &mut Fabric,
        topo: &PoolTopology,
        now: SimTime,
        node: NodeId,
        digest: u64,
        bytes: u64,
    ) -> (FetchSource, SimTime) {
        let (src, receipt) =
            self.transfer(fabric, topo, now, node, digest, bytes, Priority::Foreground);
        (src, receipt.latency())
    }

    /// Kick off a background prefetch of `digest` toward `node`: same
    /// source choice and accounting as [`PoolLayerCache::fetch`], but
    /// the bytes ride the background lane — they yield the wire to any
    /// foreground fetch within one frame quantum.
    pub fn prefetch(
        &mut self,
        fabric: &mut Fabric,
        topo: &PoolTopology,
        now: SimTime,
        node: NodeId,
        digest: u64,
        bytes: u64,
    ) -> (FetchSource, TransferReceipt) {
        let (src, receipt) =
            self.transfer(fabric, topo, now, node, digest, bytes, Priority::Background);
        if src != FetchSource::Local {
            self.prefetch_bytes += bytes;
        }
        (src, receipt)
    }

    #[allow(clippy::too_many_arguments)]
    fn transfer(
        &mut self,
        fabric: &mut Fabric,
        topo: &PoolTopology,
        now: SimTime,
        node: NodeId,
        digest: u64,
        bytes: u64,
        pri: Priority,
    ) -> (FetchSource, TransferReceipt) {
        let (src, _) = self.plan(fabric, topo, node, digest, bytes);
        let receipt = match src {
            FetchSource::Local => {
                if pri.is_background() {
                    // a background prefetch of a resident (or already
                    // in-flight) layer is a no-op: nothing moves, nothing
                    // is saved, and any live marker stays live
                    let ready = self.prefetched.get(&(node, digest)).copied();
                    TransferReceipt {
                        issued: now,
                        begin: now,
                        finish: ready.unwrap_or(now).max(now),
                        bytes: 0,
                        frames: 0,
                    }
                } else {
                    self.local_hits += 1;
                    // first hit on a prefetched layer: wait for the
                    // prefetch's in-flight tail, and don't re-count
                    // bytes the prefetch already accounted
                    match self.prefetched.remove(&(node, digest)) {
                        Some(ready) => TransferReceipt {
                            issued: now,
                            begin: now,
                            finish: ready.max(now),
                            bytes: 0,
                            frames: 0,
                        },
                        None => {
                            self.bytes_local += bytes;
                            TransferReceipt::immediate(now)
                        }
                    }
                }
            }
            FetchSource::Peer(peer) => {
                self.peer_fetches += 1;
                self.bytes_from_peers += bytes;
                // a peer whose own copy is still arriving (in-flight
                // prefetch) can only start serving once its bytes land
                let src_ready = self
                    .prefetched
                    .get(&(peer, digest))
                    .copied()
                    .unwrap_or(now)
                    .max(now);
                let mut receipt =
                    fabric.transfer(src_ready, Endpoint::Node(peer), Endpoint::Node(node), bytes, pri);
                receipt.issued = now;
                receipt
            }
            FetchSource::Registry => {
                self.registry_fetches += 1;
                self.bytes_from_registry += bytes;
                fabric.transfer(now, Endpoint::Registry, Endpoint::Node(node), bytes, pri)
            }
        };
        self.register(node, digest);
        if pri == Priority::Background && src != FetchSource::Local {
            self.prefetched.insert((node, digest), receipt.finish);
        }
        (src, receipt)
    }

    /// Pool-wide garbage collection (the placement-side half lives in
    /// the orchestrator): for every layer held by more than `k` nodes,
    /// drop copies from the most-loaded holders until exactly `k`
    /// remain — ties evict the higher node id, so the lowest-id holders
    /// survive deterministically.  Layers at or below `k` holders are
    /// untouched.  Returns the (node, digest) pairs evicted so callers
    /// can reclaim the bytes from each node's store.
    pub fn gc<L: Fn(NodeId) -> u64>(&mut self, k: usize, load: L) -> Vec<(NodeId, u64)> {
        let digests: Vec<u64> = self.presence.keys().copied().collect();
        let mut evicted = Vec::new();
        for digest in digests {
            let mut holders = self.holders(digest);
            if holders.len() <= k {
                continue;
            }
            let excess = holders.len() - k;
            // most-loaded first; ties evict the higher id
            holders.sort_by(|a, b| load(*b).cmp(&load(*a)).then(b.cmp(a)));
            for &node in holders.iter().take(excess) {
                self.evict(node, digest);
                evicted.push((node, digest));
            }
        }
        self.gc_evictions += evicted.len() as u64;
        evicted
    }

    /// Bytes that never crossed the registry WAN thanks to pool reuse.
    pub fn wan_bytes_saved(&self) -> u64 {
        self.bytes_local + self.bytes_from_peers
    }

    pub fn export_counters(&self, c: &mut Counters) {
        c.add(names::PEER_FETCHES, self.peer_fetches);
        c.add(names::REGISTRY_FETCHES, self.registry_fetches);
        c.add(names::BYTES_FROM_PEERS, self.bytes_from_peers);
        c.add(names::BYTES_FROM_REGISTRY, self.bytes_from_registry);
        c.add(names::BYTES_NOT_TRANSFERRED, self.wan_bytes_saved());
        c.add(names::GC_EVICTIONS, self.gc_evictions);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EtherOnConfig, PoolConfig};
    use crate::fabric::LinkClass;

    fn rig(nodes: u32, arrays: u32) -> (PoolTopology, Fabric) {
        let cfg = PoolConfig {
            nodes_per_array: nodes,
            arrays,
            ..Default::default()
        };
        (PoolTopology::build(&cfg), Fabric::new(&cfg, &EtherOnConfig::default()))
    }

    #[test]
    fn cold_pool_goes_to_registry_then_peers() {
        let (t, mut f) = rig(4, 1);
        let mut pc = PoolLayerCache::new();
        let (src, lat) = pc.fetch(&mut f, &t, SimTime::ZERO, 0, 0xD1, 1 << 20);
        assert_eq!(src, FetchSource::Registry);
        assert!(lat > SimTime::ZERO);
        let (src2, lat2) = pc.fetch(&mut f, &t, SimTime::ZERO, 1, 0xD1, 1 << 20);
        assert_eq!(src2, FetchSource::Peer(0));
        assert!(lat2 < lat, "intranet beats WAN even queued behind it");
        let (src3, _) = pc.fetch(&mut f, &t, SimTime::ZERO, 0, 0xD1, 1 << 20);
        assert_eq!(src3, FetchSource::Local);
        assert_eq!(pc.registry_fetches, 1);
        assert_eq!(pc.peer_fetches, 1);
        assert_eq!(pc.local_hits, 1);
        assert_eq!(pc.wan_bytes_saved(), 2 << 20);
    }

    #[test]
    fn nearest_peer_prefers_same_array() {
        let (t, f) = rig(2, 2); // nodes 0,1 in array 0; 2,3 in array 1
        let mut pc = PoolLayerCache::new();
        pc.register(1, 0xD2); // same array as 0
        pc.register(2, 0xD2); // cross array
        let (peer, _) = pc.nearest_peer(&f, &t, 0, 0xD2, 4096).unwrap();
        assert_eq!(peer, 1);
    }

    #[test]
    fn unhealthy_holders_are_skipped() {
        let (mut t, f) = rig(3, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(1, 0xD3);
        t.node_mut(1).unwrap().healthy = false;
        assert!(pc.nearest_peer(&f, &t, 0, 0xD3, 4096).is_none());
        let (src, _) = pc.plan(&f, &t, 0, 0xD3, 4096);
        assert_eq!(src, FetchSource::Registry);
    }

    #[test]
    fn evict_forgets_presence() {
        let (t, f) = rig(2, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0xD4);
        assert!(pc.node_has(0, 0xD4));
        pc.evict(0, 0xD4);
        assert!(!pc.node_has(0, 0xD4));
        let (src, _) = pc.plan(&f, &t, 1, 0xD4, 64);
        assert_eq!(src, FetchSource::Registry);
    }

    #[test]
    fn layers_present_counts_for_placement() {
        let mut pc = PoolLayerCache::new();
        pc.register(0, 1);
        pc.register(0, 2);
        pc.register(1, 2);
        assert_eq!(pc.layers_present(0, &[1, 2, 3]), 2);
        assert_eq!(pc.layers_present(1, &[1, 2, 3]), 1);
        assert_eq!(pc.layers_present(2, &[1, 2, 3]), 0);
    }

    #[test]
    fn concurrent_fetches_on_one_link_contend() {
        let (t, mut f) = rig(8, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0xEE);
        let bytes = 4 << 20;
        let mut lats = Vec::new();
        for n in 1..=4 {
            let (src, lat) = pc.fetch(&mut f, &t, SimTime::ZERO, n, 0xEE, bytes);
            assert!(matches!(src, FetchSource::Peer(_)));
            lats.push(lat);
        }
        // each later fetch queues behind the earlier ones on the shared
        // array backplane
        for w in lats.windows(2) {
            assert!(w[1] > w[0], "{lats:?}");
        }
        let ratio = lats[3].as_ns() as f64 / lats[0].as_ns() as f64;
        assert!(ratio > 3.0, "4th fetch should see ~4x latency, got {ratio:.2}x");
    }

    #[test]
    fn prefetch_registers_presence_without_blocking_foreground() {
        let (t, mut f) = rig(4, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0xAB);
        // large background prefetch toward node 1
        let (src, receipt) = pc.prefetch(&mut f, &t, SimTime::ZERO, 1, 0xAB, 64 << 20);
        assert_eq!(src, FetchSource::Peer(0));
        assert!(receipt.finish > SimTime::ZERO);
        assert!(pc.node_has(1, 0xAB), "prefetch registers the holder");
        assert_eq!(pc.prefetch_bytes, 64 << 20);
        // a foreground fetch on the same link is delayed by at most one
        // frame quantum
        pc.register(2, 0xCD);
        let (_, lat) = pc.fetch(&mut f, &t, SimTime::ZERO, 3, 0xCD, 1 << 20);
        let idle = f.estimate(Endpoint::Node(2), Endpoint::Node(3), 1 << 20);
        let mtu = EtherOnConfig::default().mtu;
        let quantum = f.link(LinkClass::Array(0)).unwrap().frame_quantum(mtu);
        assert!(
            lat <= idle + quantum,
            "foreground lat {lat} exceeds idle {idle} + quantum {quantum}"
        );
    }

    #[test]
    fn fetch_of_inflight_prefetch_waits_for_the_tail() {
        let (t, mut f) = rig(3, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0x33);
        let (_, receipt) = pc.prefetch(&mut f, &t, SimTime::ZERO, 1, 0x33, 16 << 20);
        // fetching before the prefetch lands waits exactly its tail
        let (src, lat) = pc.fetch(&mut f, &t, SimTime::ZERO, 1, 0x33, 16 << 20);
        assert_eq!(src, FetchSource::Local);
        assert_eq!(lat, receipt.finish, "boot blocks until the prefetched bytes arrive");
        // after the tail, the layer is simply resident
        let (_, lat2) = pc.fetch(&mut f, &t, receipt.finish, 1, 0x33, 16 << 20);
        assert_eq!(lat2, SimTime::ZERO);
    }

    #[test]
    fn prefetch_then_boot_fetch_counts_bytes_once() {
        let (t, mut f) = rig(3, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0x22);
        // prefetch moves the bytes (counted as a peer fetch) ...
        pc.prefetch(&mut f, &t, SimTime::ZERO, 1, 0x22, 1 << 20);
        assert_eq!(pc.wan_bytes_saved(), 1 << 20);
        // ... the boot-path local hit must not count them a second time
        let (src, _) = pc.fetch(&mut f, &t, SimTime::ZERO, 1, 0x22, 1 << 20);
        assert_eq!(src, FetchSource::Local);
        assert_eq!(pc.local_hits, 1);
        assert_eq!(pc.wan_bytes_saved(), 1 << 20, "no double count");
        // a later genuine warm hit is a real save again
        let (_, _) = pc.fetch(&mut f, &t, SimTime::ZERO, 1, 0x22, 1 << 20);
        assert_eq!(pc.wan_bytes_saved(), 2 << 20);
    }

    #[test]
    fn local_prefetch_is_free_and_uncounted() {
        let (t, mut f) = rig(2, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0x11);
        let (src, receipt) = pc.prefetch(&mut f, &t, SimTime::ZERO, 0, 0x11, 1 << 20);
        assert_eq!(src, FetchSource::Local);
        assert_eq!(receipt.latency(), SimTime::ZERO);
        assert_eq!(pc.prefetch_bytes, 0);
        assert_eq!(pc.local_hits, 0, "a redundant prefetch is a no-op, not a hit");
        assert_eq!(pc.wan_bytes_saved(), 0, "nothing moved, nothing saved");
    }

    #[test]
    fn peer_with_inflight_copy_cannot_serve_early() {
        let (mut t, mut f) = rig(3, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0x55);
        let (_, receipt) = pc.prefetch(&mut f, &t, SimTime::ZERO, 1, 0x55, 16 << 20);
        // only the in-flight copy remains reachable
        t.node_mut(0).unwrap().healthy = false;
        let (src, lat) = pc.fetch(&mut f, &t, SimTime::ZERO, 2, 0x55, 16 << 20);
        assert_eq!(src, FetchSource::Peer(1));
        assert!(
            lat > receipt.finish,
            "peer serves only after its own bytes land: {lat} vs {}",
            receipt.finish
        );
    }

    #[test]
    fn evict_clears_prefetch_marker() {
        let (t, mut f) = rig(3, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0x44);
        pc.prefetch(&mut f, &t, SimTime::ZERO, 1, 0x44, 1 << 20);
        pc.evict(1, 0x44);
        // re-fetched for real: the stale marker must not suppress the
        // byte accounting of this genuine warm hit chain
        pc.fetch(&mut f, &t, SimTime::ZERO, 1, 0x44, 1 << 20); // peer again
        let saved_before = pc.wan_bytes_saved();
        pc.fetch(&mut f, &t, SimTime::ZERO, 1, 0x44, 1 << 20); // local hit
        assert_eq!(pc.wan_bytes_saved(), saved_before + (1 << 20));
    }

    #[test]
    fn gc_keeps_k_holders_evicting_most_loaded() {
        let mut pc = PoolLayerCache::new();
        for n in 0..4 {
            pc.register(n, 0xF0);
        }
        pc.register(0, 0xF1); // at k holders already: untouched
        pc.register(1, 0xF1);
        let loads: HashMap<NodeId, u64> = [(0, 5), (1, 0), (2, 3), (3, 1)].into();
        let evicted = pc.gc(2, |n| loads.get(&n).copied().unwrap_or(0));
        assert_eq!(evicted.len(), 2);
        assert!(evicted.contains(&(0, 0xF0)), "most-loaded holder dropped");
        assert!(evicted.contains(&(2, 0xF0)), "next-most-loaded dropped");
        assert_eq!(pc.holders(0xF0), vec![1, 3], "k least-loaded holders survive");
        assert_eq!(pc.holders(0xF1), vec![0, 1], "layers at k holders untouched");
        assert_eq!(pc.gc_evictions, 2);
    }

    #[test]
    fn gc_ties_keep_lowest_ids() {
        let mut pc = PoolLayerCache::new();
        for n in 0..5 {
            pc.register(n, 0xF2);
        }
        let evicted = pc.gc(2, |_| 0);
        assert_eq!(evicted.len(), 3);
        assert_eq!(pc.holders(0xF2), vec![0, 1]);
    }

    #[test]
    fn gc_never_drops_below_k() {
        let mut pc = PoolLayerCache::new();
        for d in [0xA1u64, 0xA2, 0xA3] {
            for n in 0..6 {
                pc.register(n, d);
            }
        }
        pc.gc(3, |n| n as u64);
        for d in [0xA1u64, 0xA2, 0xA3] {
            assert_eq!(pc.holders(d).len(), 3, "invariant: >=k holders per layer");
        }
        // a second pass is a no-op
        assert!(pc.gc(3, |n| n as u64).is_empty());
    }
}
