//! Pool-wide layer-presence map: which nodes hold which blob digests.
//!
//! In the seed flow every `docker pull` on every node re-crossed the
//! registry WAN (paper Figure 2b step 1).  With the presence map, a node
//! missing a layer fetches it from the nearest healthy *peer* over the
//! Ether-oN intranet — registry traffic scales with unique bytes in the
//! pool, not with replica count, which is the whole point of
//! disaggregation ("In-Storage Domain-Specific Acceleration for
//! Serverless Computing", PAPERS.md, makes the same cold-start
//! locality argument).

use std::collections::{BTreeSet, HashMap};

use crate::metrics::{names, Counters};
use crate::pool::topology::{NodeId, PoolTopology};
use crate::util::SimTime;

/// Registry pulls leave the rack: host uplink time scaled by a WAN
/// factor (the registry is a "user-defined location" beyond the host).
pub const REGISTRY_WAN_FACTOR: f64 = 8.0;

/// Where a needed layer comes from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FetchSource {
    /// Already resident on the requesting node.
    Local,
    /// Copied from a peer DockerSSD over the intranet.
    Peer(NodeId),
    /// Pulled across the WAN from the registry.
    Registry,
}

/// The presence map plus fetch accounting.
#[derive(Default)]
pub struct PoolLayerCache {
    presence: HashMap<u64, BTreeSet<NodeId>>,
    pub local_hits: u64,
    pub peer_fetches: u64,
    pub registry_fetches: u64,
    pub bytes_local: u64,
    pub bytes_from_peers: u64,
    pub bytes_from_registry: u64,
}

impl PoolLayerCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record that `node` now holds `digest`.
    pub fn register(&mut self, node: NodeId, digest: u64) {
        self.presence.entry(digest).or_default().insert(node);
    }

    /// Record that `node` dropped `digest` (image removed / GC).
    pub fn evict(&mut self, node: NodeId, digest: u64) {
        if let Some(set) = self.presence.get_mut(&digest) {
            set.remove(&node);
            if set.is_empty() {
                self.presence.remove(&digest);
            }
        }
    }

    pub fn node_has(&self, node: NodeId, digest: u64) -> bool {
        self.presence.get(&digest).map_or(false, |s| s.contains(&node))
    }

    pub fn holders(&self, digest: u64) -> Vec<NodeId> {
        self.presence
            .get(&digest)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default()
    }

    /// Nodes in the pool holding at least one byte of the image —
    /// i.e. candidates for locality-aware placement.
    pub fn layers_present(&self, node: NodeId, digests: &[u64]) -> usize {
        digests.iter().filter(|d| self.node_has(node, **d)).count()
    }

    /// Nearest healthy holder of `digest` by link time (ties broken by
    /// lowest node id via BTreeSet iteration order + strict `<`).
    pub fn nearest_peer(
        &self,
        topo: &PoolTopology,
        node: NodeId,
        digest: u64,
        bytes: u64,
    ) -> Option<(NodeId, SimTime)> {
        let holders = self.presence.get(&digest)?;
        let mut best: Option<(NodeId, SimTime)> = None;
        for &h in holders {
            if h == node || !topo.node(h).map_or(false, |n| n.healthy) {
                continue;
            }
            let t = topo.link_time(h, node, bytes);
            if best.map_or(true, |(_, bt)| t < bt) {
                best = Some((h, t));
            }
        }
        best
    }

    /// Decide where `node` would get `digest` from, and the transfer
    /// latency. Does not mutate state.
    pub fn plan(
        &self,
        topo: &PoolTopology,
        node: NodeId,
        digest: u64,
        bytes: u64,
    ) -> (FetchSource, SimTime) {
        if self.node_has(node, digest) {
            return (FetchSource::Local, SimTime::ZERO);
        }
        if let Some((peer, t)) = self.nearest_peer(topo, node, digest, bytes) {
            return (FetchSource::Peer(peer), t);
        }
        (
            FetchSource::Registry,
            topo.host_link_time(node, bytes).scale(REGISTRY_WAN_FACTOR),
        )
    }

    /// Execute a fetch: account for it, mark `node` as a holder, and
    /// return the source + transfer latency.
    pub fn fetch(
        &mut self,
        topo: &PoolTopology,
        node: NodeId,
        digest: u64,
        bytes: u64,
    ) -> (FetchSource, SimTime) {
        let (src, t) = self.plan(topo, node, digest, bytes);
        match src {
            FetchSource::Local => {
                self.local_hits += 1;
                self.bytes_local += bytes;
            }
            FetchSource::Peer(_) => {
                self.peer_fetches += 1;
                self.bytes_from_peers += bytes;
            }
            FetchSource::Registry => {
                self.registry_fetches += 1;
                self.bytes_from_registry += bytes;
            }
        }
        self.register(node, digest);
        (src, t)
    }

    /// Bytes that never crossed the registry WAN thanks to pool reuse.
    pub fn wan_bytes_saved(&self) -> u64 {
        self.bytes_local + self.bytes_from_peers
    }

    pub fn export_counters(&self, c: &mut Counters) {
        c.add(names::PEER_FETCHES, self.peer_fetches);
        c.add(names::REGISTRY_FETCHES, self.registry_fetches);
        c.add(names::BYTES_FROM_PEERS, self.bytes_from_peers);
        c.add(names::BYTES_FROM_REGISTRY, self.bytes_from_registry);
        c.add(names::BYTES_NOT_TRANSFERRED, self.wan_bytes_saved());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PoolConfig;

    fn topo(nodes: u32, arrays: u32) -> PoolTopology {
        PoolTopology::build(&PoolConfig {
            nodes_per_array: nodes,
            arrays,
            ..Default::default()
        })
    }

    #[test]
    fn cold_pool_goes_to_registry_then_peers() {
        let t = topo(4, 1);
        let mut pc = PoolLayerCache::new();
        let (src, lat) = pc.fetch(&t, 0, 0xD1, 1 << 20);
        assert_eq!(src, FetchSource::Registry);
        assert!(lat > SimTime::ZERO);
        let (src2, lat2) = pc.fetch(&t, 1, 0xD1, 1 << 20);
        assert_eq!(src2, FetchSource::Peer(0));
        assert!(lat2 < lat, "intranet beats WAN");
        let (src3, _) = pc.fetch(&t, 0, 0xD1, 1 << 20);
        assert_eq!(src3, FetchSource::Local);
        assert_eq!(pc.registry_fetches, 1);
        assert_eq!(pc.peer_fetches, 1);
        assert_eq!(pc.local_hits, 1);
        assert_eq!(pc.wan_bytes_saved(), 2 << 20);
    }

    #[test]
    fn nearest_peer_prefers_same_array() {
        let t = topo(2, 2); // nodes 0,1 in array 0; 2,3 in array 1
        let mut pc = PoolLayerCache::new();
        pc.register(1, 0xD2); // same array as 0
        pc.register(2, 0xD2); // cross array
        let (peer, _) = pc.nearest_peer(&t, 0, 0xD2, 4096).unwrap();
        assert_eq!(peer, 1);
    }

    #[test]
    fn unhealthy_holders_are_skipped() {
        let mut t = topo(3, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(1, 0xD3);
        t.node_mut(1).unwrap().healthy = false;
        assert!(pc.nearest_peer(&t, 0, 0xD3, 4096).is_none());
        let (src, _) = pc.plan(&t, 0, 0xD3, 4096);
        assert_eq!(src, FetchSource::Registry);
    }

    #[test]
    fn evict_forgets_presence() {
        let t = topo(2, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0xD4);
        assert!(pc.node_has(0, 0xD4));
        pc.evict(0, 0xD4);
        assert!(!pc.node_has(0, 0xD4));
        let (src, _) = pc.plan(&t, 1, 0xD4, 64);
        assert_eq!(src, FetchSource::Registry);
    }

    #[test]
    fn layers_present_counts_for_placement() {
        let mut pc = PoolLayerCache::new();
        pc.register(0, 1);
        pc.register(0, 2);
        pc.register(1, 2);
        assert_eq!(pc.layers_present(0, &[1, 2, 3]), 2);
        assert_eq!(pc.layers_present(1, &[1, 2, 3]), 1);
        assert_eq!(pc.layers_present(2, &[1, 2, 3]), 0);
    }
}
