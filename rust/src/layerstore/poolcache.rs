//! Pool-wide layer-presence map: which nodes hold which blobs — and,
//! since the chunk-granular refactor, which *chunks* of each blob.
//!
//! In the seed flow every `docker pull` on every node re-crossed the
//! registry WAN (paper Figure 2b step 1).  With the presence map, a node
//! missing a layer fetches it from the nearest healthy *peer* over the
//! Ether-oN intranet — registry traffic scales with unique bytes in the
//! pool, not with replica count, which is the whole point of
//! disaggregation ("In-Storage Domain-Specific Acceleration for
//! Serverless Computing", PAPERS.md, makes the same cold-start
//! locality argument).
//!
//! Presence is tracked per chunk ([`crate::layerstore::ChunkId`]):
//! blob-level presence is *derived* — a node "has" a blob exactly when
//! it holds every chunk of the blob's recipe
//! ([`PoolLayerCache::describe_chunks`]; an undescribed blob is one
//! implicit chunk).  That makes three things possible that a blob-level
//! map cannot express:
//!
//! * a node missing one chunk re-fetches one chunk, not the layer;
//! * a *partial* holder (a node mid-pull, see
//!   [`PoolLayerCache::register_chunk`]) serves exactly the chunks it
//!   holds while the registry serves the rest;
//! * one fetch splits a layer across multiple peers — the nearest
//!   holder *per chunk* — so pulls from disjoint arrays overlap on
//!   disjoint links while same-link pulls contend.
//!
//! Every byte a foreground fetch moves is routed through
//! [`Fabric::transfer`] (exact for in-order foreground traffic), so
//! concurrent fetches contend for the shared array/tray/WAN links
//! instead of each seeing an idle wire.  [`PoolLayerCache::prefetch`]
//! schedules the same per-chunk traffic on the fabric's *event-driven
//! engine* ([`Fabric::schedule`], background lane): its receipts come
//! from [`Fabric::settle`]/[`Fabric::receipt_of`], so a prefetch
//! preempted by later foreground traffic is re-timed
//! (`fabric.retimed_transfers`) instead of keeping an optimistic
//! busy-until figure — closing the ROADMAP item that sync background
//! receipts were optimistic lower bounds.

use std::collections::{BTreeMap, BTreeSet, HashMap};

use super::dedup::ChunkInterner;
use crate::fabric::{Endpoint, Fabric, Priority, TransferId};
use crate::metrics::{names, Counters};
use crate::pool::devices::WireCtx;
use crate::pool::topology::{NodeId, PoolTopology};
use crate::util::SimTime;

pub use super::dedup::ChunkId;

/// Where a needed layer comes from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FetchSource {
    /// Already resident on the requesting node.
    Local,
    /// Copied from a peer DockerSSD over the intranet.
    Peer(NodeId),
    /// Pulled across the WAN from the registry.
    Registry,
    /// Chunk-granular split: served by more than one remote source
    /// (several peers, or peers plus the registry for the chunks no
    /// peer holds).
    Mixed,
}

/// One chunk's planned transfer (the unit [`PoolLayerCache::plan_chunks`]
/// returns).  `source` is never [`FetchSource::Mixed`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkPlan {
    pub chunk: ChunkId,
    pub bytes: u64,
    pub source: FetchSource,
}

/// What [`PoolLayerCache::purge_node`] removed for a dead node.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PurgeSummary {
    /// Blob-level registrations the node held.
    pub registrations_dropped: u64,
    /// Mid-pull partial registrations the node held.
    pub partials_dropped: u64,
    /// Chunks whose *last* holder was the purged node — gone from the
    /// pool entirely; healing must re-pull them across the registry WAN.
    pub orphaned_chunks: Vec<ChunkId>,
}

/// What one [`PoolLayerCache::rereplicate_chunks`] pass moved.
#[derive(Clone, Debug, Default)]
pub struct HealStats {
    /// Distinct chunks that were below `k` healthy holders.
    pub chunks_rereplicated: u64,
    /// Replica copies created (one per transfer issued).
    pub copies_made: u64,
    /// Bytes put on background lanes (chunks of unknown size register
    /// holders without wire traffic and contribute 0 here).
    pub bytes: u64,
    /// Chunks no healthy peer held — their first copy crossed the WAN.
    pub registry_chunks: u64,
    /// The engine-scheduled background transfers; settle them to learn
    /// the re-timed landing times (and which bytes were fully hidden
    /// behind foreground traffic).
    pub transfers: Vec<TransferId>,
}

/// Handle to an engine-scheduled prefetch: the per-chunk transfer ids
/// plus a floor time.  [`PrefetchHandle::settle`] pumps the fabric
/// engine just far enough to resolve every transfer and returns the
/// (possibly re-timed) time the last byte lands.
#[derive(Clone, Debug, Default)]
pub struct PrefetchHandle {
    ids: Vec<TransferId>,
    ready: SimTime,
}

impl PrefetchHandle {
    fn at(ready: SimTime) -> Self {
        PrefetchHandle { ids: Vec::new(), ready }
    }

    /// The engine transfers this prefetch issued (empty for a local
    /// no-op).
    pub fn ids(&self) -> &[TransferId] {
        &self.ids
    }

    /// Resolve every transfer on the engine and return when the last
    /// byte lands.  Idempotent; a no-op handle returns its floor time.
    pub fn settle(&self, fabric: &mut Fabric) -> SimTime {
        let mut t = self.ready;
        for id in &self.ids {
            if let Some(r) = fabric.settle(*id) {
                t = t.max(r.finish);
            }
        }
        t
    }
}

/// The presence map plus fetch accounting.
#[derive(Default)]
pub struct PoolLayerCache {
    /// blob -> nodes holding *every* chunk of it (derived view).
    presence: HashMap<u64, BTreeSet<NodeId>>,
    /// blob -> nodes that took a blob-level registration (the copies GC
    /// and [`PoolLayerCache::evict`] can drop).  A node can be present
    /// in `presence` but not here when other blobs' registrations pin
    /// all of this blob's chunks.
    registered: HashMap<u64, BTreeSet<NodeId>>,
    /// blob -> distinct chunk recipe, first-occurrence order.
    recipes: HashMap<u64, Vec<(ChunkId, u64)>>,
    /// The pool's chunk-id namespace interned to dense slots; the
    /// per-chunk `Vec`s below are indexed by slot, so the hot
    /// plan/fetch/heal paths index instead of hashing per chunk.
    chunks: ChunkInterner,
    /// slot -> (holder node, registration refcount), sorted by node id.
    /// A node referencing a shared chunk through two blobs holds two
    /// refs; the chunk stays present until both are dropped.  An empty
    /// list is the old map's absent entry.
    holder_refs: Vec<Vec<(NodeId, u32)>>,
    /// slot -> blobs whose recipe contains the chunk (for
    /// derived-presence updates).
    blobs_of: Vec<BTreeSet<u64>>,
    /// slot -> byte size, learned from recipes and from planned
    /// transfers.  The heal loop sizes re-replication traffic from this;
    /// a chunk that never moved and was never described heals with zero
    /// wire bytes (the holder is still registered).
    size_of: Vec<Option<u64>>,
    /// node -> live holder entries across all chunks, maintained on the
    /// 0->1 and 1->0 refcount transitions — the heal loop's spread
    /// signal, no longer rebuilt from the whole holder table per pass.
    node_load: Vec<u64>,
    /// (node, blob) -> chunks held via partial (mid-pull) registration.
    partial: HashMap<(NodeId, u64), BTreeSet<ChunkId>>,
    pub local_hits: u64,
    pub peer_fetches: u64,
    pub registry_fetches: u64,
    pub bytes_local: u64,
    pub bytes_from_peers: u64,
    pub bytes_from_registry: u64,
    /// Chunk transfers actually issued (fetch + prefetch).
    pub chunk_fetches: u64,
    /// Chunk bytes served by peers over the intranet.
    pub chunk_bytes_peer: u64,
    /// Chunk bytes that crossed the registry WAN.
    pub chunk_bytes_registry: u64,
    /// Distinct partial holders that served chunks, summed over ops.
    pub partial_holders_used: u64,
    /// Bytes moved by background prefetch (also counted in the
    /// peer/registry totals above).
    pub prefetch_bytes: u64,
    /// (node, digest) pairs dropped by pool-wide GC.
    pub gc_evictions: u64,
    /// Layers whose presence came from a prefetch and whose first
    /// boot-path fetch hasn't consumed it yet, mapped to the prefetch's
    /// in-flight engine transfers.  The first local hit settles that
    /// tail (the bytes may still be in flight) and must not re-count
    /// bytes the prefetch already accounted.
    prefetched: HashMap<(NodeId, u64), PrefetchHandle>,
}

impl PoolLayerCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern `chunk` and grow the parallel per-chunk columns to cover
    /// its slot.
    fn intern_chunk(&mut self, chunk: ChunkId) -> usize {
        let slot = self.chunks.intern(chunk);
        if self.holder_refs.len() <= slot {
            self.holder_refs.resize_with(slot + 1, Vec::new);
            self.blobs_of.resize_with(slot + 1, BTreeSet::new);
            self.size_of.resize(slot + 1, None);
        }
        slot
    }

    fn bump_node_load(&mut self, node: NodeId) {
        let n = node as usize;
        if self.node_load.len() <= n {
            self.node_load.resize(n + 1, 0);
        }
        self.node_load[n] += 1;
    }

    /// Live holder entries of `node` across all chunks (the heal loop's
    /// spread signal).
    fn node_load_of(&self, node: NodeId) -> u64 {
        self.node_load.get(node as usize).copied().unwrap_or(0)
    }

    /// Record `chunk`'s byte size if not already known (first writer
    /// wins, like the old `entry().or_insert`).
    fn learn_size(&mut self, chunk: ChunkId, bytes: u64) {
        let slot = self.intern_chunk(chunk);
        if self.size_of[slot].is_none() {
            self.size_of[slot] = Some(bytes);
        }
    }

    /// The chunk ids a blob decomposes into: its described recipe, or
    /// the blob digest itself as one implicit chunk.
    fn recipe_chunk_ids(&self, blob: u64) -> Vec<ChunkId> {
        match self.recipes.get(&blob) {
            Some(r) => r.iter().map(|(c, _)| *c).collect(),
            None => vec![blob],
        }
    }

    /// Whether `node` holds every chunk of `blob`.  O(recipe) per call —
    /// chunk registration is therefore O(recipe^2) per layer, fine at
    /// this simulation's chunk counts (a per-(node, blob) held-chunk
    /// counter would make it O(1) if layers ever grow to many thousands
    /// of chunks).
    fn holds_all_chunks(&self, node: NodeId, blob: u64) -> bool {
        match self.recipes.get(&blob) {
            Some(r) => r.iter().all(|(c, _)| self.node_has_chunk(node, *c)),
            None => self.node_has_chunk(node, blob),
        }
    }

    fn incref_chunk(&mut self, node: NodeId, chunk: ChunkId) {
        let slot = self.intern_chunk(chunk);
        let holders = &mut self.holder_refs[slot];
        match holders.binary_search_by_key(&node, |&(n, _)| n) {
            Ok(p) => holders[p].1 += 1,
            Err(p) => {
                holders.insert(p, (node, 1));
                self.bump_node_load(node);
            }
        }
        // re-derive presence for every blob containing this chunk — on
        // every ref add, not just the 0->1 transition: a registration
        // whose chunks were already pinned through *other* blobs (refs
        // going 1->2) still completes a blob here, and the backfill in
        // describe_chunks relies on this to restore presence it dropped
        let blobs: Vec<u64> = self.blobs_of[slot].iter().copied().collect();
        for b in blobs {
            if self.holds_all_chunks(node, b) {
                self.presence.entry(b).or_default().insert(node);
            }
        }
    }

    fn decref_chunk(&mut self, node: NodeId, chunk: ChunkId) {
        let Some(slot) = self.chunks.get(chunk) else {
            return;
        };
        let holders = &mut self.holder_refs[slot];
        let Ok(p) = holders.binary_search_by_key(&node, |&(n, _)| n) else {
            return;
        };
        holders[p].1 -= 1;
        if holders[p].1 > 0 {
            return;
        }
        holders.remove(p);
        self.node_load[node as usize] -= 1;
        // the node no longer holds this chunk, so it no longer holds any
        // blob whose recipe needs it
        let blobs: Vec<u64> = self.blobs_of[slot].iter().copied().collect();
        for b in blobs {
            if let Some(set) = self.presence.get_mut(&b) {
                set.remove(&node);
                if set.is_empty() {
                    self.presence.remove(&b);
                }
            }
        }
    }

    /// Declare `blob`'s chunk composition (digest + length per chunk, in
    /// blob order; duplicates dedup to their first occurrence).  Must be
    /// called before per-chunk operations on the blob; idempotent for
    /// the same recipe.  Nodes already registered blob-level are
    /// backfilled as holding every chunk.
    ///
    /// Returns whether the pool's recipe now matches the given one: a
    /// blob already described with a *different* recipe (e.g. two nodes
    /// chunking with different sizes) keeps the first — the pool's chunk
    /// ids must be one shared namespace — and the caller should fall
    /// back to blob-granular registration.
    #[must_use = "a false return means the recipe conflicted and per-chunk ops will not match"]
    pub fn describe_chunks(&mut self, blob: u64, recipe: &[(ChunkId, u64)]) -> bool {
        let mut seen = BTreeSet::new();
        let distinct: Vec<(ChunkId, u64)> = recipe
            .iter()
            .filter(|(c, _)| seen.insert(*c))
            .copied()
            .collect();
        if let Some(existing) = self.recipes.get(&blob) {
            return *existing == distinct;
        }
        let holders: Vec<NodeId> = self
            .registered
            .get(&blob)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default();
        // migrate existing holders' implicit single-chunk refs onto the
        // real recipe
        for &n in &holders {
            self.decref_chunk(n, blob);
        }
        if let Some(slot) = self.chunks.get(blob) {
            self.blobs_of[slot].remove(&blob);
        }
        for (c, b) in &distinct {
            let slot = self.intern_chunk(*c);
            self.blobs_of[slot].insert(blob);
            if self.size_of[slot].is_none() {
                self.size_of[slot] = Some(*b);
            }
        }
        self.recipes.insert(blob, distinct.clone());
        for &n in &holders {
            for (c, _) in &distinct {
                self.incref_chunk(n, *c);
            }
        }
        // nodes already holding every recipe chunk through *other* blobs
        // derive presence of this one immediately (a candidate must hold
        // the first chunk, so that holder set bounds the search)
        if let Some((c0, _)) = distinct.first() {
            let cands: Vec<NodeId> = self.chunk_holders_of(*c0);
            for n in cands {
                if self.holds_all_chunks(n, blob) {
                    self.presence.entry(blob).or_default().insert(n);
                }
            }
        }
        true
    }

    /// The described chunk recipe of `blob`, if any.
    pub fn chunk_recipe(&self, blob: u64) -> Option<&[(ChunkId, u64)]> {
        self.recipes.get(&blob).map(Vec::as_slice)
    }

    /// Record that `node` now holds all of `digest` (a blob-level
    /// registration; idempotent).  Any partial registration for the
    /// same (node, blob) is absorbed — its chunk refs carry over.
    pub fn register(&mut self, node: NodeId, digest: u64) {
        if !self.recipes.contains_key(&digest) {
            let slot = self.intern_chunk(digest);
            self.blobs_of[slot].insert(digest);
        }
        if !self.registered.entry(digest).or_default().insert(node) {
            return;
        }
        let part = self.partial.remove(&(node, digest)).unwrap_or_default();
        for c in self.recipe_chunk_ids(digest) {
            if !part.contains(&c) {
                self.incref_chunk(node, c);
            }
        }
    }

    /// Record that `node` holds one chunk of `digest` — a mid-pull
    /// partial registration ([`describe_chunks`](Self::describe_chunks)
    /// first).  The node becomes a chunk-level peer immediately; when
    /// its partial set covers the whole recipe it is promoted to a full
    /// blob-level registration.
    pub fn register_chunk(&mut self, node: NodeId, blob: u64, chunk: ChunkId) {
        {
            let recipe = self
                .recipes
                .get(&blob)
                .unwrap_or_else(|| panic!("describe_chunks({blob:016x}) before register_chunk"));
            debug_assert!(
                recipe.iter().any(|(c, _)| *c == chunk),
                "chunk {chunk:016x} is not in blob {blob:016x}'s recipe"
            );
        }
        if self.registered.get(&blob).is_some_and(|s| s.contains(&node)) {
            return; // already a full holder
        }
        let part = self.partial.entry((node, blob)).or_default();
        if !part.insert(chunk) {
            return;
        }
        self.incref_chunk(node, chunk);
        let complete = {
            let part = &self.partial[&(node, blob)];
            self.recipes[&blob].iter().all(|(c, _)| part.contains(c))
        };
        if complete {
            // promotion: the partial refs become the blob registration's
            self.partial.remove(&(node, blob));
            self.registered.entry(blob).or_default().insert(node);
        }
    }

    /// Record that `node` dropped `digest` (image removed / GC): drops
    /// the blob-level registration's chunk refs plus any partial refs.
    /// Chunks the node still references through *other* blobs stay
    /// present — and so does any blob presence they derive.
    pub fn evict(&mut self, node: NodeId, digest: u64) {
        let was_registered = self
            .registered
            .get_mut(&digest)
            .is_some_and(|s| s.remove(&node));
        if was_registered {
            for c in self.recipe_chunk_ids(digest) {
                self.decref_chunk(node, c);
            }
        }
        if self.registered.get(&digest).is_some_and(|s| s.is_empty()) {
            self.registered.remove(&digest);
        }
        if let Some(part) = self.partial.remove(&(node, digest)) {
            for c in part {
                self.decref_chunk(node, c);
            }
        }
        // a dropped layer's prefetch marker must not suppress the byte
        // accounting of a later, genuine warm hit
        self.prefetched.remove(&(node, digest));
    }

    pub fn node_has(&self, node: NodeId, digest: u64) -> bool {
        self.presence.get(&digest).is_some_and(|s| s.contains(&node))
    }

    pub fn node_has_chunk(&self, node: NodeId, chunk: ChunkId) -> bool {
        self.chunks.get(chunk).is_some_and(|slot| {
            self.holder_refs[slot]
                .binary_search_by_key(&node, |&(n, _)| n)
                .is_ok()
        })
    }

    pub fn holders(&self, digest: u64) -> Vec<NodeId> {
        self.presence
            .get(&digest)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default()
    }

    /// All holders of one chunk — full blob holders and partial
    /// (mid-pull) holders alike.
    pub fn chunk_holders_of(&self, chunk: ChunkId) -> Vec<NodeId> {
        match self.chunks.get(chunk) {
            Some(slot) => self.holder_refs[slot].iter().map(|&(n, _)| n).collect(),
            None => Vec::new(),
        }
    }

    /// Nodes in the pool holding at least one byte of the image —
    /// i.e. candidates for locality-aware placement.
    pub fn layers_present(&self, node: NodeId, digests: &[u64]) -> usize {
        digests.iter().filter(|d| self.node_has(node, **d)).count()
    }

    /// Nearest healthy *full* holder of `digest` by idle-wire fabric
    /// estimate (ties broken by lowest node id via BTreeSet iteration
    /// order + strict `<`).
    pub fn nearest_peer(
        &self,
        fabric: &Fabric,
        topo: &PoolTopology,
        node: NodeId,
        digest: u64,
        bytes: u64,
    ) -> Option<(NodeId, SimTime)> {
        let holders = self.presence.get(&digest)?;
        Self::best_holder(fabric, topo, node, bytes, holders.iter().copied())
    }

    /// Nearest healthy holder of one *chunk* — partial holders count.
    pub fn nearest_chunk_peer(
        &self,
        fabric: &Fabric,
        topo: &PoolTopology,
        node: NodeId,
        chunk: ChunkId,
        bytes: u64,
    ) -> Option<(NodeId, SimTime)> {
        let slot = self.chunks.get(chunk)?;
        Self::best_holder(
            fabric,
            topo,
            node,
            bytes,
            self.holder_refs[slot].iter().map(|&(n, _)| n),
        )
    }

    fn best_holder<I: Iterator<Item = NodeId>>(
        fabric: &Fabric,
        topo: &PoolTopology,
        node: NodeId,
        bytes: u64,
        holders: I,
    ) -> Option<(NodeId, SimTime)> {
        let mut best: Option<(NodeId, SimTime)> = None;
        for h in holders {
            if h == node || !topo.node(h).is_some_and(|n| n.healthy) {
                continue;
            }
            let t = fabric.estimate(Endpoint::Node(h), Endpoint::Node(node), bytes);
            if best.is_none_or(|(_, bt)| t < bt) {
                best = Some((h, t));
            }
        }
        best
    }

    /// Plan `digest`'s transfer chunk by chunk: for every chunk `node`
    /// is missing, the nearest healthy holder — full *or* partial — or
    /// the registry when no peer holds it.  Chunks the node already
    /// holds plan as `Local` (nothing moves).  Does not mutate state.
    pub fn plan_chunks(
        &self,
        fabric: &Fabric,
        topo: &PoolTopology,
        node: NodeId,
        digest: u64,
        bytes: u64,
    ) -> Vec<ChunkPlan> {
        let recipe: Vec<(ChunkId, u64)> = match self.recipes.get(&digest) {
            Some(r) => r.clone(),
            None => vec![(digest, bytes)],
        };
        recipe
            .into_iter()
            .map(|(chunk, b)| {
                let source = if self.node_has_chunk(node, chunk) {
                    FetchSource::Local
                } else {
                    match self.nearest_chunk_peer(fabric, topo, node, chunk, b) {
                        Some((p, _)) => FetchSource::Peer(p),
                        None => FetchSource::Registry,
                    }
                };
                ChunkPlan {
                    chunk,
                    bytes: b,
                    source,
                }
            })
            .collect()
    }

    /// Group a per-chunk plan by remote source: bytes per peer, registry
    /// bytes, and the one-source summary ([`FetchSource::Mixed`] when
    /// more than one remote source serves).  The single classification
    /// both [`PoolLayerCache::plan`] and the fetch/prefetch accounting
    /// report from.
    fn summarize_sources(plans: &[ChunkPlan]) -> (BTreeMap<NodeId, u64>, u64, FetchSource) {
        let mut peer_bytes: BTreeMap<NodeId, u64> = BTreeMap::new();
        let mut reg_bytes = 0u64;
        for p in plans {
            match p.source {
                FetchSource::Local => {}
                FetchSource::Peer(n) => *peer_bytes.entry(n).or_insert(0) += p.bytes,
                FetchSource::Registry => reg_bytes += p.bytes,
                FetchSource::Mixed => unreachable!("per-chunk plans are never Mixed"),
            }
        }
        let src = match (
            peer_bytes.len(),
            plans.iter().any(|p| p.source == FetchSource::Registry),
        ) {
            (0, false) => FetchSource::Local,
            (1, false) => FetchSource::Peer(*peer_bytes.keys().next().expect("one peer")),
            (0, true) => FetchSource::Registry,
            _ => FetchSource::Mixed,
        };
        (peer_bytes, reg_bytes, src)
    }

    /// Summarize a per-chunk plan into one source + the idle-wire
    /// estimate: bytes are grouped by source, per-source transfers are
    /// assumed to overlap (they serialize only where their paths share a
    /// link, which planning ignores just as it ignores queue occupancy).
    /// Planning never mutates: no wire traffic, no flash charge.
    pub fn plan(
        &self,
        wire: &WireCtx,
        node: NodeId,
        digest: u64,
        bytes: u64,
    ) -> (FetchSource, SimTime) {
        if self.node_has(node, digest) {
            return (FetchSource::Local, SimTime::ZERO);
        }
        let plans = self.plan_chunks(wire.fabric, wire.topo, node, digest, bytes);
        let (peer_bytes, reg_bytes, src) = Self::summarize_sources(&plans);
        let mut t = SimTime::ZERO;
        for (&p, &b) in &peer_bytes {
            t = t.max(wire.fabric.estimate(Endpoint::Node(p), Endpoint::Node(node), b));
        }
        if reg_bytes > 0 {
            t = t.max(wire.fabric.estimate(Endpoint::Registry, Endpoint::Node(node), reg_bytes));
        }
        (src, t)
    }

    /// Account one op's per-chunk plans — chunk counters, op-level
    /// peer/registry counters, partial-holder usage — and return the
    /// op's summary source.  Shared by [`PoolLayerCache::fetch`] and
    /// [`PoolLayerCache::prefetch`] so foreground and background byte
    /// accounting can never diverge.  Must run *before*
    /// `register(node, digest)` so partial holders are classified
    /// against pre-op presence.
    fn account_chunk_plans(&mut self, plans: &[ChunkPlan], digest: u64) -> FetchSource {
        let (peer_bytes, reg_bytes, src) = Self::summarize_sources(plans);
        self.chunk_fetches += plans
            .iter()
            .filter(|p| p.source != FetchSource::Local)
            .count() as u64;
        for (&peer, &b) in &peer_bytes {
            self.chunk_bytes_peer += b;
            self.bytes_from_peers += b;
            if !self.node_has(peer, digest) {
                self.partial_holders_used += 1;
            }
        }
        self.chunk_bytes_registry += reg_bytes;
        self.bytes_from_registry += reg_bytes;
        if !peer_bytes.is_empty() {
            self.peer_fetches += 1;
        }
        if plans.iter().any(|p| p.source == FetchSource::Registry) {
            self.registry_fetches += 1;
        }
        src
    }

    /// Settle the in-flight prefetch tail of `(node, digest)` if one
    /// exists, returning when that copy is fully landed (or `now`).
    fn source_ready(
        &self,
        fabric: &mut Fabric,
        now: SimTime,
        node: NodeId,
        digest: u64,
    ) -> SimTime {
        match self.prefetched.get(&(node, digest)) {
            Some(tail) => tail.settle(fabric).max(now),
            None => now,
        }
    }

    /// Execute a foreground fetch over the shared fabric, chunk by
    /// chunk: each missing chunk comes from its nearest holder (peer
    /// chunks over Array links, registry chunks over the WAN — one
    /// layer can split across several peers), `node` is marked a full
    /// holder, and the returned latency is when the *last* chunk lands
    /// (including queue wait behind other in-flight transfers).
    /// Fetching a layer whose prefetch is still in flight settles the
    /// prefetch's tail instead of being free.
    ///
    /// Every byte that lands installs as chunks on `node`'s flash: the
    /// moved total is charged to the node's FTL ledger (`wire.ftls`) on
    /// its write-back lane, so sustained pulls show up as WAF and wear
    /// without perturbing the wire latency returned here.
    pub fn fetch(
        &mut self,
        wire: &mut WireCtx,
        node: NodeId,
        digest: u64,
        bytes: u64,
    ) -> (FetchSource, SimTime) {
        let now = wire.now;
        if self.node_has(node, digest) {
            self.local_hits += 1;
            // first hit on a prefetched layer: wait for the prefetch's
            // in-flight tail, and don't re-count bytes the prefetch
            // already accounted
            let lat = match self.prefetched.remove(&(node, digest)) {
                Some(tail) => tail.settle(wire.fabric).max(now).saturating_sub(now),
                None => {
                    self.bytes_local += bytes;
                    SimTime::ZERO
                }
            };
            return (FetchSource::Local, lat);
        }
        let plans = self.plan_chunks(wire.fabric, wire.topo, node, digest, bytes);
        let src = self.account_chunk_plans(&plans, digest);
        for p in &plans {
            self.learn_size(p.chunk, p.bytes);
        }
        let mut finish = now;
        let mut moved = 0u64;
        for p in &plans {
            match p.source {
                FetchSource::Local => {}
                FetchSource::Peer(peer) => {
                    // a peer whose own copy is still arriving (in-flight
                    // prefetch) can only start serving once its bytes land
                    let src_ready = self.source_ready(wire.fabric, now, peer, digest);
                    let r = wire.fabric.transfer(
                        src_ready,
                        Endpoint::Node(peer),
                        Endpoint::Node(node),
                        p.bytes,
                        Priority::Foreground,
                    );
                    finish = finish.max(r.finish);
                    moved += p.bytes;
                }
                FetchSource::Registry => {
                    let r = wire.fabric.transfer(
                        now,
                        Endpoint::Registry,
                        Endpoint::Node(node),
                        p.bytes,
                        Priority::Foreground,
                    );
                    finish = finish.max(r.finish);
                    moved += p.bytes;
                }
                FetchSource::Mixed => unreachable!("per-chunk plans are never Mixed"),
            }
        }
        self.register(node, digest);
        if moved > 0 {
            wire.ftls.write(node, now, moved);
        }
        (src, finish.saturating_sub(now))
    }

    /// Kick off a background prefetch of `digest` toward `node`: the
    /// same per-chunk source choice and accounting as
    /// [`PoolLayerCache::fetch`], but every transfer is *scheduled on
    /// the fabric's event-driven engine* at background priority — the
    /// bytes yield the wire to foreground traffic within one frame
    /// quantum, and a preempted transfer's receipt is re-timed
    /// (`fabric.retimed_transfers`) rather than staying an optimistic
    /// lower bound.  Settle the returned handle (or let the boot-path
    /// fetch settle the marker) to observe the real landing time.
    pub fn prefetch(
        &mut self,
        wire: &mut WireCtx,
        node: NodeId,
        digest: u64,
        bytes: u64,
    ) -> (FetchSource, PrefetchHandle) {
        let now = wire.now;
        if self.node_has(node, digest) {
            // a background prefetch of a resident (or already in-flight)
            // layer is a no-op: nothing moves, nothing is saved, and any
            // live marker stays live
            let handle = self
                .prefetched
                .get(&(node, digest))
                .cloned()
                .unwrap_or_else(|| PrefetchHandle::at(now));
            return (FetchSource::Local, handle);
        }
        let plans = self.plan_chunks(wire.fabric, wire.topo, node, digest, bytes);
        let src = self.account_chunk_plans(&plans, digest);
        for p in &plans {
            self.learn_size(p.chunk, p.bytes);
        }
        let mut ids = Vec::new();
        let mut moved = 0u64;
        // Two phases: independent chunks first, marker-dependent chunks
        // after.  Settling a source's in-flight marker pins the engine
        // clock at its finish, and the engine cannot schedule into its
        // own past — issuing the independent transfers first keeps them
        // from being clamped behind a dependency they don't have.
        let independent = |p: &ChunkPlan, pc: &Self| match p.source {
            FetchSource::Peer(peer) => !pc.prefetched.contains_key(&(peer, digest)),
            _ => true,
        };
        for phase in [true, false] {
            for p in plans.iter().filter(|p| independent(p, self) == phase) {
                match p.source {
                    FetchSource::Local => {}
                    FetchSource::Peer(peer) => {
                        let src_ready = self.source_ready(wire.fabric, now, peer, digest);
                        ids.push(wire.fabric.schedule(
                            src_ready,
                            Endpoint::Node(peer),
                            Endpoint::Node(node),
                            p.bytes,
                            Priority::Background,
                        ));
                        moved += p.bytes;
                    }
                    FetchSource::Registry => {
                        ids.push(wire.fabric.schedule(
                            now,
                            Endpoint::Registry,
                            Endpoint::Node(node),
                            p.bytes,
                            Priority::Background,
                        ));
                        moved += p.bytes;
                    }
                    FetchSource::Mixed => unreachable!("per-chunk plans are never Mixed"),
                }
            }
        }
        self.prefetch_bytes += moved;
        self.register(node, digest);
        let handle = PrefetchHandle { ids, ready: now };
        if moved > 0 {
            // prefetched chunks install on the destination's flash like
            // any other landing bytes
            wire.ftls.write(node, now, moved);
            self.prefetched.insert((node, digest), handle.clone());
        }
        (src, handle)
    }

    /// Background-prefetch every layer of `layers` that each node of
    /// `candidates` is missing — the autoscaler's warm-the-candidates
    /// primitive: before a scale-out decision commits, the controller
    /// aims this at its top-ranked nodes so a flash crowd boots from
    /// warm peers instead of the registry WAN.
    ///
    /// Per (node, layer) this is exactly [`PoolLayerCache::prefetch`]
    /// (engine-scheduled, background lane, re-timed receipts; resident
    /// and in-flight layers are skipped as no-ops), applied in the
    /// deterministic candidates × layers order.  Returns the bytes
    /// newly put in flight per candidate, so the caller can account
    /// what its prediction moved ahead of time.
    pub fn prefetch_set(
        &mut self,
        wire: &mut WireCtx,
        candidates: &[NodeId],
        layers: &[(u64, u64)],
    ) -> Vec<(NodeId, u64)> {
        let mut moved = Vec::with_capacity(candidates.len());
        for &node in candidates {
            let before = self.prefetch_bytes;
            for &(digest, bytes) in layers {
                if !self.node_has(node, digest) {
                    self.prefetch(wire, node, digest, bytes);
                }
            }
            moved.push((node, self.prefetch_bytes - before));
        }
        moved
    }

    /// All chunks currently held by at least one node, sorted — the
    /// live-chunk set heal invariants are checked over.
    pub fn chunks(&self) -> Vec<ChunkId> {
        let mut v: Vec<ChunkId> = (0..self.chunks.len())
            .filter(|&slot| !self.holder_refs[slot].is_empty())
            .map(|slot| self.chunks.id(slot))
            .collect();
        v.sort_unstable();
        v
    }

    /// Forget everything `node` holds — the presence-map half of node
    /// death.  Every blob-level registration, every mid-pull partial
    /// registration, and every prefetch marker of the node is dropped,
    /// so the derived k-holder counts GC enforces and the sources
    /// [`PoolLayerCache::plan_chunks`] picks can never count the dead
    /// node again.  Iteration is over sorted keys, so two same-seed runs
    /// purge byte-identically.  Returns what was dropped, including the
    /// chunks whose last copy died with the node (healing re-pulls those
    /// from the registry).
    pub fn purge_node(&mut self, node: NodeId) -> PurgeSummary {
        let mut held_before: Vec<ChunkId> = (0..self.chunks.len())
            .filter(|&slot| {
                self.holder_refs[slot]
                    .binary_search_by_key(&node, |&(n, _)| n)
                    .is_ok()
            })
            .map(|slot| self.chunks.id(slot))
            .collect();
        held_before.sort_unstable();
        let mut blobs: BTreeSet<u64> = BTreeSet::new();
        let mut registrations = 0u64;
        for (b, nodes) in &self.registered {
            if nodes.contains(&node) {
                blobs.insert(*b);
                registrations += 1;
            }
        }
        let mut partials = 0u64;
        for (n, b) in self.partial.keys() {
            if *n == node {
                blobs.insert(*b);
                partials += 1;
            }
        }
        for b in blobs {
            self.evict(node, b);
        }
        self.prefetched.retain(|(n, _), _| *n != node);
        PurgeSummary {
            registrations_dropped: registrations,
            partials_dropped: partials,
            orphaned_chunks: held_before
                .into_iter()
                .filter(|&c| {
                    self.chunks
                        .get(c)
                        .is_none_or(|slot| self.holder_refs[slot].is_empty())
                })
                .collect(),
        }
    }

    /// Register a healed chunk copy on `node` through the normal
    /// registration machinery, so derived blob presence and the gc
    /// invariants see it like any other copy: chunks of a described blob
    /// become partial registrations (promoted to full when complete),
    /// implicit single-chunk blobs become blob registrations.
    fn heal_register(&mut self, node: NodeId, chunk: ChunkId) {
        let blob = self
            .chunks
            .get(chunk)
            .and_then(|slot| self.blobs_of[slot].iter().next().copied())
            .unwrap_or(chunk);
        if self.recipes.contains_key(&blob) {
            self.register_chunk(node, blob, chunk);
        } else {
            self.register(node, blob);
        }
    }

    /// One self-healing pass: every chunk held by fewer than `k` healthy
    /// nodes (capped by how many healthy nodes exist) gets copies
    /// scheduled on the fabric's *background* lanes until the invariant
    /// holds again — from the nearest surviving holder, or across the
    /// registry WAN for chunks the pool lost entirely (`orphans` from
    /// [`PoolLayerCache::purge_node`], plus any live chunk whose every
    /// holder is unhealthy).  Targets are the least-loaded healthy
    /// non-holders (by chunk-registration count, ties to the lowest id),
    /// so repeated churn spreads copies instead of piling them on one
    /// node.  Heal traffic yields to foreground serving within one frame
    /// quantum like any background transfer; settle the returned
    /// transfer ids to learn the re-timed landing times.
    pub fn rereplicate_chunks(
        &mut self,
        wire: &mut WireCtx,
        k: usize,
        orphans: &[ChunkId],
    ) -> HealStats {
        let now = wire.now;
        let mut stats = HealStats::default();
        let healthy: Vec<NodeId> = wire.topo.healthy_nodes().map(|n| n.id).collect();
        let want = k.min(healthy.len());
        if want == 0 {
            return stats;
        }
        let mut all: BTreeSet<ChunkId> = (0..self.chunks.len())
            .filter(|&slot| !self.holder_refs[slot].is_empty())
            .map(|slot| self.chunks.id(slot))
            .collect();
        all.extend(orphans.iter().copied());
        for chunk in all {
            let mut healthy_holders: BTreeSet<NodeId> = self
                .chunk_holders_of(chunk)
                .into_iter()
                .filter(|&n| wire.topo.node(n).is_some_and(|pn| pn.healthy))
                .collect();
            if healthy_holders.len() >= want {
                continue;
            }
            stats.chunks_rereplicated += 1;
            if healthy_holders.is_empty() {
                stats.registry_chunks += 1;
            }
            let bytes = self
                .chunks
                .get(chunk)
                .and_then(|slot| self.size_of[slot])
                .unwrap_or(0);
            while healthy_holders.len() < want {
                // the incrementally maintained load index replaces the
                // old per-pass recount; heal_register's new holder entry
                // bumps it, preserving the old manual increment
                let Some(&target) = healthy
                    .iter()
                    .filter(|n| !healthy_holders.contains(n))
                    .min_by_key(|&&n| (self.node_load_of(n), n))
                else {
                    break;
                };
                let from = match self.nearest_chunk_peer(wire.fabric, wire.topo, target, chunk, bytes) {
                    Some((p, _)) => Endpoint::Node(p),
                    None => Endpoint::Registry,
                };
                if bytes > 0 {
                    stats.transfers.push(wire.fabric.schedule(
                        now,
                        from,
                        Endpoint::Node(target),
                        bytes,
                        Priority::Background,
                    ));
                    stats.bytes += bytes;
                    // the healed copy installs on the target's flash
                    wire.ftls.write(target, now, bytes);
                }
                stats.copies_made += 1;
                self.heal_register(target, chunk);
                healthy_holders.insert(target);
            }
        }
        stats
    }

    /// Re-point a per-chunk plan at surviving holders: any chunk planned
    /// from a peer that has since died (or no longer holds the chunk) is
    /// re-planned to the nearest healthy holder, falling back to the
    /// registry — how a mid-flight pull survives its source's death
    /// instead of fetching from a ghost.  Local and registry plans pass
    /// through unchanged.
    pub fn reroute_chunk_plans(
        &self,
        fabric: &Fabric,
        topo: &PoolTopology,
        node: NodeId,
        plans: &[ChunkPlan],
    ) -> Vec<ChunkPlan> {
        plans
            .iter()
            .map(|p| {
                let source = match p.source {
                    FetchSource::Peer(peer)
                        if !topo.node(peer).is_some_and(|n| n.healthy)
                            || !self.node_has_chunk(peer, p.chunk) =>
                    {
                        match self.nearest_chunk_peer(fabric, topo, node, p.chunk, p.bytes) {
                            Some((q, _)) => FetchSource::Peer(q),
                            None => FetchSource::Registry,
                        }
                    }
                    s => s,
                };
                ChunkPlan { source, ..*p }
            })
            .collect()
    }

    /// Whether evicting `node`'s copy of `blob` keeps every chunk of the
    /// blob at >= `k` holders.  A chunk the node also references through
    /// another blob (refcount > 1) survives the eviction, so it never
    /// blocks one.
    fn eviction_keeps_chunks_at_k(&self, blob: u64, node: NodeId, k: usize) -> bool {
        for c in self.recipe_chunk_ids(blob) {
            let Some(slot) = self.chunks.get(c) else {
                continue;
            };
            let holders = &self.holder_refs[slot];
            if let Ok(p) = holders.binary_search_by_key(&node, |&(n, _)| n) {
                if holders[p].1 == 1 && holders.len() - 1 < k {
                    return false;
                }
            }
        }
        true
    }

    /// Pool-wide garbage collection (the placement-side half lives in
    /// the orchestrator): for every blob held by more than `k` nodes,
    /// drop registrations from the most-worn holders first (by
    /// `wear` — max per-block erase count, so flash-tired nodes shed
    /// copies and stop absorbing re-install churn), then the
    /// most-loaded, until `k` remain — remaining ties evict the higher
    /// node id, so the lowest-id holders survive deterministically.
    /// Eviction refuses to drop a node that would leave any *chunk* of
    /// the blob below `k` holders (partial holders count; a chunk the
    /// node also holds via another blob survives regardless).  Blobs at
    /// or below `k` holders are untouched.  Returns the (node, digest)
    /// pairs evicted so callers can reclaim the bytes from each node's
    /// store.
    pub fn gc<L, W>(&mut self, k: usize, load: L, wear: W) -> Vec<(NodeId, u64)>
    where
        L: Fn(NodeId) -> u64,
        W: Fn(NodeId) -> u64,
    {
        let mut digests: Vec<u64> = self.presence.keys().copied().collect();
        digests.sort_unstable();
        let mut evicted = Vec::new();
        for digest in digests {
            loop {
                if self.holders(digest).len() <= k {
                    break;
                }
                // most-worn registration first, then most-loaded; ties
                // evict the higher id
                let mut cands: Vec<NodeId> = self
                    .registered
                    .get(&digest)
                    .map(|s| s.iter().copied().collect())
                    .unwrap_or_default();
                cands.sort_by(|a, b| {
                    wear(*b)
                        .cmp(&wear(*a))
                        .then(load(*b).cmp(&load(*a)))
                        .then(b.cmp(a))
                });
                let Some(&node) = cands
                    .iter()
                    .find(|n| self.eviction_keeps_chunks_at_k(digest, **n, k))
                else {
                    break;
                };
                self.evict(node, digest);
                evicted.push((node, digest));
            }
        }
        self.gc_evictions += evicted.len() as u64;
        evicted
    }

    /// Bytes that never crossed the registry WAN thanks to pool reuse.
    pub fn wan_bytes_saved(&self) -> u64 {
        self.bytes_local + self.bytes_from_peers
    }

    pub fn export_counters(&self, c: &mut Counters) {
        c.add(names::PEER_FETCHES, self.peer_fetches);
        c.add(names::REGISTRY_FETCHES, self.registry_fetches);
        c.add(names::BYTES_FROM_PEERS, self.bytes_from_peers);
        c.add(names::BYTES_FROM_REGISTRY, self.bytes_from_registry);
        c.add(names::BYTES_NOT_TRANSFERRED, self.wan_bytes_saved());
        c.add(names::GC_EVICTIONS, self.gc_evictions);
        c.add(names::CHUNK_FETCHES, self.chunk_fetches);
        c.add(names::CHUNK_BYTES_PEER, self.chunk_bytes_peer);
        c.add(names::CHUNK_BYTES_REGISTRY, self.chunk_bytes_registry);
        c.add(names::PARTIAL_HOLDERS_USED, self.partial_holders_used);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EtherOnConfig, PoolConfig};
    use crate::fabric::LinkClass;
    use crate::pool::devices::FtlBank;

    fn rig(nodes: u32, arrays: u32) -> (PoolTopology, Fabric, FtlBank) {
        let cfg = PoolConfig {
            nodes_per_array: nodes,
            arrays,
            ..Default::default()
        };
        (
            PoolTopology::build(&cfg),
            Fabric::new(&cfg, &EtherOnConfig::default()),
            FtlBank::default(),
        )
    }

    /// A throwaway [`WireCtx`] over a rig's parts, clocked at
    /// `SimTime::ZERO` unless `$at` is given.
    macro_rules! wire {
        ($f:ident, $t:ident, $b:ident) => {
            &mut WireCtx::at(&mut $f, &$t, &mut $b, SimTime::ZERO)
        };
        ($f:ident, $t:ident, $b:ident, $at:expr) => {
            &mut WireCtx::at(&mut $f, &$t, &mut $b, $at)
        };
    }

    #[test]
    fn cold_pool_goes_to_registry_then_peers() {
        let (t, mut f, mut b) = rig(4, 1);
        let mut pc = PoolLayerCache::new();
        let (src, lat) = pc.fetch(wire!(f, t, b), 0, 0xD1, 1 << 20);
        assert_eq!(src, FetchSource::Registry);
        assert!(lat > SimTime::ZERO);
        let (src2, lat2) = pc.fetch(wire!(f, t, b), 1, 0xD1, 1 << 20);
        assert_eq!(src2, FetchSource::Peer(0));
        assert!(lat2 < lat, "intranet beats WAN even queued behind it");
        let (src3, _) = pc.fetch(wire!(f, t, b), 0, 0xD1, 1 << 20);
        assert_eq!(src3, FetchSource::Local);
        assert_eq!(pc.registry_fetches, 1);
        assert_eq!(pc.peer_fetches, 1);
        assert_eq!(pc.local_hits, 1);
        assert_eq!(pc.wan_bytes_saved(), 2 << 20);
        let mut c = Counters::new();
        b.export_counters(&mut c);
        assert!(c.get(names::FTL_HOST_PAGES) > 0, "landed bytes charged the flash ledgers");
    }

    #[test]
    fn prefetch_set_warms_candidates_and_skips_residents() {
        let (t, mut f, mut b) = rig(4, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0xA1);
        pc.register(0, 0xB2);
        pc.register(2, 0xA1); // candidate 2 already holds one layer
        let layers = [(0xA1u64, 1u64 << 20), (0xB2u64, 2u64 << 20)];
        let moved = pc.prefetch_set(wire!(f, t, b), &[1, 2], &layers);
        assert_eq!(
            moved,
            vec![(1, 3 << 20), (2, 2 << 20)],
            "per-candidate bytes put in flight; resident layers skipped"
        );
        assert_eq!(pc.prefetch_bytes, 5 << 20);
        assert!(f.transfers_in_flight() >= 3, "engine-scheduled background transfers");
        f.run_to_idle();
        for n in [1u32, 2] {
            for (d, _) in layers {
                assert!(pc.node_has(n, d), "node {n} warmed with layer {d:#x}");
            }
        }
        // a repeat over the same candidates is a no-op: everything is
        // resident or in flight
        let again = pc.prefetch_set(wire!(f, t, b), &[1, 2], &layers);
        assert_eq!(again, vec![(1, 0), (2, 0)]);
        assert_eq!(pc.prefetch_bytes, 5 << 20);
    }

    #[test]
    fn nearest_peer_prefers_same_array() {
        let (t, f, _) = rig(2, 2); // nodes 0,1 in array 0; 2,3 in array 1
        let mut pc = PoolLayerCache::new();
        pc.register(1, 0xD2); // same array as 0
        pc.register(2, 0xD2); // cross array
        let (peer, _) = pc.nearest_peer(&f, &t, 0, 0xD2, 4096).unwrap();
        assert_eq!(peer, 1);
    }

    #[test]
    fn unhealthy_holders_are_skipped() {
        let (mut t, mut f, mut b) = rig(3, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(1, 0xD3);
        t.node_mut(1).unwrap().healthy = false;
        assert!(pc.nearest_peer(&f, &t, 0, 0xD3, 4096).is_none());
        let (src, _) = pc.plan(wire!(f, t, b), 0, 0xD3, 4096);
        assert_eq!(src, FetchSource::Registry);
    }

    #[test]
    fn evict_forgets_presence() {
        let (t, mut f, mut b) = rig(2, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0xD4);
        assert!(pc.node_has(0, 0xD4));
        pc.evict(0, 0xD4);
        assert!(!pc.node_has(0, 0xD4));
        let (src, _) = pc.plan(wire!(f, t, b), 1, 0xD4, 64);
        assert_eq!(src, FetchSource::Registry);
    }

    #[test]
    fn layers_present_counts_for_placement() {
        let mut pc = PoolLayerCache::new();
        pc.register(0, 1);
        pc.register(0, 2);
        pc.register(1, 2);
        assert_eq!(pc.layers_present(0, &[1, 2, 3]), 2);
        assert_eq!(pc.layers_present(1, &[1, 2, 3]), 1);
        assert_eq!(pc.layers_present(2, &[1, 2, 3]), 0);
    }

    #[test]
    fn concurrent_fetches_on_one_link_contend() {
        let (t, mut f, mut b) = rig(8, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0xEE);
        let bytes = 4 << 20;
        let mut lats = Vec::new();
        for n in 1..=4 {
            let (src, lat) = pc.fetch(wire!(f, t, b), n, 0xEE, bytes);
            assert!(matches!(src, FetchSource::Peer(_)));
            lats.push(lat);
        }
        // each later fetch queues behind the earlier ones on the shared
        // array backplane
        for w in lats.windows(2) {
            assert!(w[1] > w[0], "{lats:?}");
        }
        let ratio = lats[3].as_ns() as f64 / lats[0].as_ns() as f64;
        assert!(ratio > 3.0, "4th fetch should see ~4x latency, got {ratio:.2}x");
    }

    #[test]
    fn prefetch_registers_presence_without_blocking_foreground() {
        let (t, mut f, mut b) = rig(4, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0xAB);
        // large background prefetch toward node 1, granted the wire at t=0
        let (src, handle) = pc.prefetch(wire!(f, t, b), 1, 0xAB, 64 << 20);
        assert_eq!(src, FetchSource::Peer(0));
        f.advance_to(SimTime::ZERO); // grant the background flight
        assert!(pc.node_has(1, 0xAB), "prefetch registers the holder");
        assert_eq!(pc.prefetch_bytes, 64 << 20);
        // a foreground fetch on the same link is delayed by at most one
        // frame quantum
        pc.register(2, 0xCD);
        let (_, lat) = pc.fetch(wire!(f, t, b), 3, 0xCD, 1 << 20);
        let idle = f.estimate(Endpoint::Node(2), Endpoint::Node(3), 1 << 20);
        let mtu = EtherOnConfig::default().mtu;
        let quantum = f.link(LinkClass::Array(0)).unwrap().frame_quantum(mtu);
        assert!(
            lat <= idle + quantum,
            "foreground lat {lat} exceeds idle {idle} + quantum {quantum}"
        );
        // the prefetch eventually lands with a real (settled) receipt
        assert!(handle.settle(&mut f) > SimTime::ZERO);
    }

    #[test]
    fn fetch_of_inflight_prefetch_waits_for_the_tail() {
        let (t, mut f, mut b) = rig(3, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0x33);
        let (_, handle) = pc.prefetch(wire!(f, t, b), 1, 0x33, 16 << 20);
        // fetching before the prefetch lands waits exactly its tail
        let (src, lat) = pc.fetch(wire!(f, t, b), 1, 0x33, 16 << 20);
        assert_eq!(src, FetchSource::Local);
        let finish = handle.settle(&mut f);
        assert_eq!(lat, finish, "boot blocks until the prefetched bytes arrive");
        assert_eq!(
            finish,
            f.estimate(Endpoint::Node(0), Endpoint::Node(1), 16 << 20),
            "an unpreempted engine prefetch lands at the idle-wire estimate"
        );
        // after the tail, the layer is simply resident
        let (_, lat2) = pc.fetch(wire!(f, t, b, finish), 1, 0x33, 16 << 20);
        assert_eq!(lat2, SimTime::ZERO);
    }

    #[test]
    fn prefetch_then_boot_fetch_counts_bytes_once() {
        let (t, mut f, mut b) = rig(3, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0x22);
        // prefetch moves the bytes (counted as a peer fetch) ...
        pc.prefetch(wire!(f, t, b), 1, 0x22, 1 << 20);
        assert_eq!(pc.wan_bytes_saved(), 1 << 20);
        // ... the boot-path local hit must not count them a second time
        let (src, _) = pc.fetch(wire!(f, t, b), 1, 0x22, 1 << 20);
        assert_eq!(src, FetchSource::Local);
        assert_eq!(pc.local_hits, 1);
        assert_eq!(pc.wan_bytes_saved(), 1 << 20, "no double count");
        // a later genuine warm hit is a real save again
        let (_, _) = pc.fetch(wire!(f, t, b), 1, 0x22, 1 << 20);
        assert_eq!(pc.wan_bytes_saved(), 2 << 20);
    }

    #[test]
    fn local_prefetch_is_free_and_uncounted() {
        let (t, mut f, mut b) = rig(2, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0x11);
        let (src, handle) = pc.prefetch(wire!(f, t, b), 0, 0x11, 1 << 20);
        assert_eq!(src, FetchSource::Local);
        assert!(handle.ids().is_empty(), "nothing was scheduled");
        assert_eq!(handle.settle(&mut f), SimTime::ZERO);
        assert_eq!(pc.prefetch_bytes, 0);
        assert_eq!(pc.local_hits, 0, "a redundant prefetch is a no-op, not a hit");
        assert_eq!(pc.wan_bytes_saved(), 0, "nothing moved, nothing saved");
    }

    #[test]
    fn peer_with_inflight_copy_cannot_serve_early() {
        let (mut t, mut f, mut b) = rig(3, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0x55);
        let (_, handle) = pc.prefetch(wire!(f, t, b), 1, 0x55, 16 << 20);
        // only the in-flight copy remains reachable
        t.node_mut(0).unwrap().healthy = false;
        let (src, lat) = pc.fetch(wire!(f, t, b), 2, 0x55, 16 << 20);
        assert_eq!(src, FetchSource::Peer(1));
        let finish = handle.settle(&mut f);
        assert!(
            lat > finish,
            "peer serves only after its own bytes land: {lat} vs {finish}"
        );
    }

    #[test]
    fn evict_clears_prefetch_marker() {
        let (t, mut f, mut b) = rig(3, 1);
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0x44);
        pc.prefetch(wire!(f, t, b), 1, 0x44, 1 << 20);
        pc.evict(1, 0x44);
        // re-fetched for real: the stale marker must not suppress the
        // byte accounting of this genuine warm hit chain
        pc.fetch(wire!(f, t, b), 1, 0x44, 1 << 20); // peer again
        let saved_before = pc.wan_bytes_saved();
        pc.fetch(wire!(f, t, b), 1, 0x44, 1 << 20); // local hit
        assert_eq!(pc.wan_bytes_saved(), saved_before + (1 << 20));
    }

    #[test]
    fn gc_keeps_k_holders_evicting_most_loaded() {
        let mut pc = PoolLayerCache::new();
        for n in 0..4 {
            pc.register(n, 0xF0);
        }
        pc.register(0, 0xF1); // at k holders already: untouched
        pc.register(1, 0xF1);
        let loads: HashMap<NodeId, u64> = [(0, 5), (1, 0), (2, 3), (3, 1)].into();
        let evicted = pc.gc(2, |n| loads.get(&n).copied().unwrap_or(0), |_| 0);
        assert_eq!(evicted.len(), 2);
        assert!(evicted.contains(&(0, 0xF0)), "most-loaded holder dropped");
        assert!(evicted.contains(&(2, 0xF0)), "next-most-loaded dropped");
        assert_eq!(pc.holders(0xF0), vec![1, 3], "k least-loaded holders survive");
        assert_eq!(pc.holders(0xF1), vec![0, 1], "layers at k holders untouched");
        assert_eq!(pc.gc_evictions, 2);
    }

    #[test]
    fn gc_ties_keep_lowest_ids() {
        let mut pc = PoolLayerCache::new();
        for n in 0..5 {
            pc.register(n, 0xF2);
        }
        let evicted = pc.gc(2, |_| 0, |_| 0);
        assert_eq!(evicted.len(), 3);
        assert_eq!(pc.holders(0xF2), vec![0, 1]);
    }

    #[test]
    fn gc_evicts_worn_holders_before_loaded_ones() {
        let mut pc = PoolLayerCache::new();
        for n in 0..4 {
            pc.register(n, 0xF3);
        }
        // node 0 carries the most replicas but node 3 has the most-worn
        // flash: wear outranks load, so 3 sheds its copy first
        let loads: HashMap<NodeId, u64> = [(0, 9), (1, 0), (2, 0), (3, 0)].into();
        let wears: HashMap<NodeId, u64> = [(0, 0), (1, 0), (2, 0), (3, 7)].into();
        let evicted = pc.gc(
            2,
            |n| loads.get(&n).copied().unwrap_or(0),
            |n| wears.get(&n).copied().unwrap_or(0),
        );
        assert_eq!(evicted, vec![(3, 0xF3), (0, 0xF3)], "worn first, then loaded");
        assert_eq!(pc.holders(0xF3), vec![1, 2]);
    }

    #[test]
    fn gc_never_drops_below_k() {
        let mut pc = PoolLayerCache::new();
        for d in [0xA1u64, 0xA2, 0xA3] {
            for n in 0..6 {
                pc.register(n, d);
            }
        }
        pc.gc(3, |n| n as u64, |_| 0);
        for d in [0xA1u64, 0xA2, 0xA3] {
            assert_eq!(pc.holders(d).len(), 3, "invariant: >=k holders per layer");
        }
        // a second pass is a no-op
        assert!(pc.gc(3, |n| n as u64, |_| 0).is_empty());
    }

    // --- chunk-granular behavior --------------------------------------------

    /// A 4-chunk recipe of 1 MiB chunks.
    fn recipe4() -> Vec<(ChunkId, u64)> {
        (0..4u64).map(|i| (0xC000 + i, 1 << 20)).collect()
    }

    #[test]
    fn register_chunk_promotes_to_blob_presence() {
        let mut pc = PoolLayerCache::new();
        assert!(pc.describe_chunks(0xB10B, &recipe4()));
        for (i, (c, _)) in recipe4().iter().enumerate() {
            assert!(!pc.node_has(1, 0xB10B), "not a full holder after {i} chunks");
            pc.register_chunk(1, 0xB10B, *c);
            assert!(pc.node_has_chunk(1, *c));
        }
        assert!(pc.node_has(1, 0xB10B), "all chunks held implies blob presence");
        // and the registration is evictable like a blob-level one
        pc.evict(1, 0xB10B);
        assert!(!pc.node_has(1, 0xB10B));
        assert!(!pc.node_has_chunk(1, 0xC000));
    }

    #[test]
    fn chunked_fetch_moves_only_missing_chunks() {
        let (t, mut f, mut b) = rig(4, 1);
        let mut pc = PoolLayerCache::new();
        let recipe = recipe4();
        assert!(pc.describe_chunks(0xB10B, &recipe));
        pc.register(0, 0xB10B);
        // node 1 already holds half the chunks
        pc.register_chunk(1, 0xB10B, recipe[0].0);
        pc.register_chunk(1, 0xB10B, recipe[1].0);
        let (src, lat) = pc.fetch(wire!(f, t, b), 1, 0xB10B, 4 << 20);
        assert_eq!(src, FetchSource::Peer(0));
        assert!(lat > SimTime::ZERO);
        assert_eq!(pc.chunk_fetches, 2, "only the two missing chunks moved");
        assert_eq!(pc.chunk_bytes_peer, 2 << 20);
        assert_eq!(pc.bytes_from_peers, 2 << 20);
        assert!(pc.node_has(1, 0xB10B));
    }

    #[test]
    fn mixed_fetch_splits_between_partial_peer_and_registry() {
        let (t, mut f, mut b) = rig(4, 1);
        let mut pc = PoolLayerCache::new();
        let recipe = recipe4();
        assert!(pc.describe_chunks(0xB10B, &recipe));
        // node 1 is a *partial* holder of half the chunks; nobody else
        // holds anything
        pc.register_chunk(1, 0xB10B, recipe[0].0);
        pc.register_chunk(1, 0xB10B, recipe[1].0);
        let (psrc, _) = pc.plan(wire!(f, t, b), 2, 0xB10B, 4 << 20);
        assert_eq!(psrc, FetchSource::Mixed);
        let (src, _) = pc.fetch(wire!(f, t, b), 2, 0xB10B, 4 << 20);
        assert_eq!(src, FetchSource::Mixed);
        assert_eq!(pc.chunk_bytes_peer, 2 << 20, "held chunks come over the intranet");
        assert_eq!(pc.chunk_bytes_registry, 2 << 20, "missing chunks cross the WAN");
        assert_eq!(pc.partial_holders_used, 1);
        assert_eq!(pc.peer_fetches, 1);
        assert_eq!(pc.registry_fetches, 1);
    }

    #[test]
    fn chunk_fetch_splits_across_peers_on_disjoint_links() {
        // peers in different arrays each hold half the chunks: the two
        // halves transfer on disjoint array backplanes and overlap
        let (t, mut f, mut b) = rig(2, 2); // nodes 0,1 in array 0; 2,3 in array 1
        let mut pc = PoolLayerCache::new();
        let recipe = recipe4();
        assert!(pc.describe_chunks(0xB10B, &recipe));
        pc.register_chunk(0, 0xB10B, recipe[0].0);
        pc.register_chunk(0, 0xB10B, recipe[1].0);
        pc.register_chunk(3, 0xB10B, recipe[2].0);
        pc.register_chunk(3, 0xB10B, recipe[3].0);
        let (src, lat) = pc.fetch(wire!(f, t, b), 1, 0xB10B, 4 << 20);
        assert_eq!(src, FetchSource::Mixed, "two peers served the layer");
        // node 0 -> 1 is same-array; 3 -> 1 crosses the tray.  Both
        // halves overlap, so the fetch ends with the cross-array half —
        // well under the serialized time of all four chunks on one link.
        let serialized = f
            .estimate(Endpoint::Node(0), Endpoint::Node(1), 4 << 20)
            .max(f.estimate(Endpoint::Node(3), Endpoint::Node(1), 4 << 20));
        let cross = f.estimate(Endpoint::Node(3), Endpoint::Node(1), 2 << 20);
        assert!(
            lat >= cross && lat < serialized,
            "split halves overlap: {lat} (cross-half {cross}, whole-layer {serialized})"
        );
        assert_eq!(pc.chunk_bytes_peer, 4 << 20);
        assert_eq!(pc.partial_holders_used, 2);
    }

    #[test]
    fn gc_shared_chunk_across_blobs_keeps_presence() {
        // regression (ISSUE 5 satellite): a chunk shared by two blobs
        // must survive on a node whose copy of *one* blob is GC'd while
        // the other blob still pins it — blob-level set removal dropped
        // it and undercounted chunk holders
        let mut pc = PoolLayerCache::new();
        let shared = 0xC5;
        assert!(pc.describe_chunks(0xA, &[(shared, 1 << 20), (0xCA, 1 << 20)]));
        assert!(pc.describe_chunks(0xB, &[(shared, 1 << 20), (0xCB, 1 << 20)]));
        for n in 0..4 {
            pc.register(n, 0xA);
        }
        pc.register(2, 0xB);
        pc.register(3, 0xB);
        // loads drive gc to evict nodes 2 and 3 from blob A
        let loads: HashMap<NodeId, u64> = [(0, 0), (1, 0), (2, 9), (3, 8)].into();
        let evicted = pc.gc(2, |n| loads.get(&n).copied().unwrap_or(0), |_| 0);
        assert!(evicted.contains(&(2, 0xA)) && evicted.contains(&(3, 0xA)), "{evicted:?}");
        assert_eq!(pc.holders(0xA), vec![0, 1]);
        // nodes 2 and 3 still hold the shared chunk through blob B
        assert!(pc.node_has_chunk(2, shared), "blob B still pins the shared chunk");
        assert!(pc.node_has_chunk(3, shared));
        assert_eq!(pc.chunk_holders_of(shared), vec![0, 1, 2, 3]);
        assert!(pc.node_has(2, 0xB) && pc.node_has(3, 0xB));
        // and every chunk of both blobs kept >= k holders
        for c in [shared, 0xCA, 0xCB] {
            assert!(pc.chunk_holders_of(c).len() >= 2, "chunk {c:#x} below k");
        }
    }

    #[test]
    fn presence_derives_across_blobs_sharing_chunks() {
        let mut pc = PoolLayerCache::new();
        assert!(pc.describe_chunks(0xA, &[(0xC1, 1 << 20)]));
        pc.register(0, 0xA);
        // a blob described later, fully covered by chunks node 0 already
        // holds, derives immediately
        assert!(pc.describe_chunks(0xB, &[(0xC1, 1 << 20)]));
        assert!(pc.node_has(0, 0xB), "existing chunk holders derive new blobs");
        // a partial registration completing over an already-pinned chunk
        // (refs 1 -> 2, no 0 -> 1 transition) still promotes
        assert!(pc.describe_chunks(0xD, &[(0xC1, 1 << 20), (0xC2, 1 << 20)]));
        pc.register_chunk(1, 0xD, 0xC2);
        pc.register(1, 0xB); // pins c1 on node 1
        pc.register_chunk(1, 0xD, 0xC1);
        assert!(pc.node_has(1, 0xD), "1->2 refcount transition still derives presence");
        assert!(pc.node_has(1, 0xA), "...for every blob the chunk completes");
        // evicting D keeps c1 pinned through B
        pc.evict(1, 0xD);
        assert!(pc.node_has_chunk(1, 0xC1));
        assert!(!pc.node_has_chunk(1, 0xC2), "c2's only ref went with D");
        assert!(pc.node_has(1, 0xA) && pc.node_has(1, 0xB));
        assert!(!pc.node_has(1, 0xD));
    }

    #[test]
    fn gc_counts_derived_holders_through_shared_chunks() {
        let mut pc = PoolLayerCache::new();
        // blobs A and B are the same single chunk under two names, so
        // every holder of the chunk derives presence of BOTH blobs
        assert!(pc.describe_chunks(0xA, &[(0xC1, 1 << 20)]));
        assert!(pc.describe_chunks(0xB, &[(0xC1, 1 << 20)]));
        for n in 0..3 {
            pc.register(n, 0xA);
        }
        pc.register(3, 0xB);
        assert_eq!(pc.holders(0xA), vec![0, 1, 2, 3]);
        assert_eq!(pc.holders(0xB), vec![0, 1, 2, 3]);
        // gc drops *registrations* until the derived holder count hits k
        let evicted = pc.gc(2, |n| n as u64, |_| 0);
        assert_eq!(evicted, vec![(2, 0xA), (1, 0xA)], "most-loaded registrations go first");
        assert_eq!(pc.holders(0xA), vec![0, 3], "node 3 still derives A through B's chunk");
        assert_eq!(pc.holders(0xB), vec![0, 3]);
        assert!(pc.chunk_holders_of(0xC1).len() >= 2, "chunk never drops below k");
    }

    #[test]
    fn describe_after_register_backfills_chunk_presence() {
        let mut pc = PoolLayerCache::new();
        pc.register(0, 0xB10B);
        pc.register(1, 0xB10B);
        assert!(pc.describe_chunks(0xB10B, &recipe4()));
        for (c, _) in recipe4() {
            assert!(pc.node_has_chunk(0, c));
            assert!(pc.node_has_chunk(1, c));
        }
        assert!(pc.node_has(0, 0xB10B) && pc.node_has(1, 0xB10B));
        pc.evict(0, 0xB10B);
        assert!(!pc.node_has_chunk(0, 0xC000));
        assert!(pc.node_has_chunk(1, 0xC000));
    }

    #[test]
    fn conflicting_recipe_keeps_the_first() {
        let mut pc = PoolLayerCache::new();
        assert!(pc.describe_chunks(0xE, &[(0xC1, 1 << 20)]));
        assert!(pc.describe_chunks(0xE, &[(0xC1, 1 << 20)]), "same recipe is idempotent");
        assert!(
            !pc.describe_chunks(0xE, &[(0xC2, 512 << 10), (0xC3, 512 << 10)]),
            "a different chunking is rejected, not merged"
        );
        assert_eq!(pc.chunk_recipe(0xE).unwrap(), &[(0xC1, 1 << 20)]);
    }

    // --- node death, purge, and self-healing --------------------------------

    #[test]
    fn purge_node_forgets_registrations_partials_and_markers() {
        let (t, mut f, mut b) = rig(4, 1);
        let mut pc = PoolLayerCache::new();
        let recipe = recipe4();
        assert!(pc.describe_chunks(0xB10B, &recipe));
        assert!(pc.describe_chunks(0xD, &[(0xDC, 1 << 20), (0xDD, 1 << 20)]));
        pc.register(1, 0xB10B); // full holder
        pc.register(2, 0xB10B); // survivor
        pc.register_chunk(1, 0xD, 0xDC); // mid-pull partial, only copy of 0xDC
        pc.register(1, 0x77); // implicit blob, only copy
        pc.register(2, 0x88);
        pc.prefetch(wire!(f, t, b), 1, 0x88, 1 << 20); // in-flight marker on node 1
        let s = pc.purge_node(1);
        assert_eq!(s.registrations_dropped, 3, "0xB10B + 0x77 + the in-flight 0x88");
        assert_eq!(s.partials_dropped, 1);
        assert_eq!(s.orphaned_chunks, vec![0x77, 0xDC], "last-copy chunks are reported lost");
        assert!(!pc.node_has(1, 0xB10B));
        assert!(!pc.node_has(1, 0x88), "the prefetch-registered copy is gone too");
        for (c, _) in &recipe {
            assert!(!pc.node_has_chunk(1, *c), "no chunk of the dead node survives");
            assert_eq!(pc.chunk_holders_of(*c), vec![2], "the survivor still holds");
        }
        // plan_chunks can never pick the purged node again
        let plans = pc.plan_chunks(&f, &t, 3, 0xB10B, 4 << 20);
        assert!(plans.iter().all(|p| p.source == FetchSource::Peer(2)), "{plans:?}");
        let plans = pc.plan_chunks(&f, &t, 3, 0x88, 1 << 20);
        assert!(plans.iter().all(|p| p.source == FetchSource::Peer(2)), "{plans:?}");
    }

    #[test]
    fn purge_then_gc_never_counts_the_dead_holder() {
        // regression (ISSUE 6 satellite): gc's derived k-holder count
        // must not keep a layer "at k" through a dead node's copy
        let mut pc = PoolLayerCache::new();
        for n in 0..3 {
            pc.register(n, 0xF7);
        }
        pc.purge_node(0);
        assert_eq!(pc.holders(0xF7), vec![1, 2]);
        // at k=2 with only live holders counted, gc must not evict
        assert!(pc.gc(2, |_| 0, |_| 0).is_empty(), "both survivors are load-bearing");
        assert_eq!(pc.holders(0xF7), vec![1, 2]);
    }

    #[test]
    fn rereplicate_restores_chunk_k_from_surviving_peers() {
        let (mut t, mut f, mut b) = rig(4, 1);
        let mut pc = PoolLayerCache::new();
        let recipe = recipe4();
        assert!(pc.describe_chunks(0xB10B, &recipe));
        pc.register(0, 0xB10B);
        pc.register(1, 0xB10B);
        t.node_mut(1).unwrap().healthy = false;
        pc.purge_node(1);
        let stats = pc.rereplicate_chunks(wire!(f, t, b), 2, &[]);
        assert_eq!(stats.chunks_rereplicated, 4, "every chunk fell below k");
        assert_eq!(stats.copies_made, 4);
        assert_eq!(stats.bytes, 4 << 20);
        assert_eq!(stats.registry_chunks, 0, "node 0 still held everything");
        f.run_to_idle();
        for (c, _) in &recipe {
            let holders = pc.chunk_holders_of(*c);
            assert!(holders.len() >= 2, "chunk {c:#x} healed to k: {holders:?}");
            assert!(!holders.contains(&1), "the dead node is not a holder");
        }
        // bytes rode the background lane
        assert!(f.stats.prefetch_bytes >= 4 << 20);
        // a second pass is a no-op: the invariant already holds
        let again = pc.rereplicate_chunks(wire!(f, t, b), 2, &[]);
        assert_eq!(again.copies_made, 0);
    }

    #[test]
    fn rereplicate_repulls_orphaned_chunks_from_the_registry() {
        let (mut t, mut f, mut b) = rig(2, 2);
        let mut pc = PoolLayerCache::new();
        // the whole of array 0 (nodes 0,1) holds the only copies
        pc.fetch(wire!(f, t, b), 0, 0x99, 2 << 20);
        pc.fetch(wire!(f, t, b), 1, 0x99, 2 << 20);
        t.node_mut(0).unwrap().healthy = false;
        t.node_mut(1).unwrap().healthy = false;
        let mut orphans = Vec::new();
        for n in [0, 1] {
            orphans.extend(pc.purge_node(n).orphaned_chunks);
        }
        assert_eq!(orphans, vec![0x99], "array loss orphaned the blob");
        let stats = pc.rereplicate_chunks(wire!(f, t, b), 2, &orphans);
        assert_eq!(stats.registry_chunks, 1, "first copy re-crossed the WAN");
        assert_eq!(stats.copies_made, 2, "then a peer copy restored k");
        assert_eq!(stats.bytes, 4 << 20, "sizes learned from the original fetch");
        f.run_to_idle();
        assert_eq!(pc.chunk_holders_of(0x99), vec![2, 3]);
        assert!(pc.node_has(2, 0x99), "implicit blob presence derives on the target");
    }

    #[test]
    fn rereplicate_spreads_copies_by_load() {
        let (mut t, mut f, mut b) = rig(6, 1);
        let mut pc = PoolLayerCache::new();
        assert!(pc.describe_chunks(0xA, &[(0xC1, 1 << 20)]));
        assert!(pc.describe_chunks(0xB, &[(0xC2, 1 << 20)]));
        pc.register(0, 0xA);
        pc.register(0, 0xB);
        pc.register(1, 0xA);
        pc.register(1, 0xB);
        t.node_mut(1).unwrap().healthy = false;
        pc.purge_node(1);
        let stats = pc.rereplicate_chunks(wire!(f, t, b), 2, &[]);
        assert_eq!(stats.copies_made, 2);
        // least-loaded healthy non-holders get the copies: one each on
        // nodes 2 and 3, not both piled on node 2
        assert_eq!(pc.chunk_holders_of(0xC1), vec![0, 2]);
        assert_eq!(pc.chunk_holders_of(0xC2), vec![0, 3]);
    }

    #[test]
    fn incremental_load_index_matches_recount_after_churn() {
        // regression (ISSUE 7 satellite): the heal loop's spread signal
        // is now maintained incrementally instead of recounted per pass
        // — after arbitrary churn it must equal the from-scratch count
        // of live holder entries, or heal targeting would drift
        let (mut t, mut f, mut b) = rig(6, 1);
        let mut pc = PoolLayerCache::new();
        let recipe = recipe4();
        assert!(pc.describe_chunks(0xB10B, &recipe));
        assert!(pc.describe_chunks(0xA, &[(0xC000, 1 << 20), (0xAA, 1 << 20)]));
        pc.register(0, 0xB10B);
        pc.register(1, 0xB10B);
        pc.register(1, 0xA); // shares chunk 0xC000: refs 1 -> 2 on node 1
        pc.register_chunk(2, 0xB10B, recipe[0].0); // mid-pull partial
        pc.register(3, 0x77); // implicit single-chunk blob
        pc.fetch(wire!(f, t, b), 4, 0x77, 1 << 20);
        pc.evict(1, 0xB10B); // 0xC000 stays pinned on 1 through 0xA
        t.node_mut(0).unwrap().healthy = false;
        pc.purge_node(0);
        pc.rereplicate_chunks(wire!(f, t, b), 2, &[]);
        pc.gc(2, |n| n as u64, |_| 0);
        let mut recount: HashMap<NodeId, u64> = HashMap::new();
        for c in pc.chunks() {
            for n in pc.chunk_holders_of(c) {
                *recount.entry(n).or_insert(0) += 1;
            }
        }
        for n in 0..6 {
            assert_eq!(
                pc.node_load_of(n),
                recount.get(&n).copied().unwrap_or(0),
                "node {n} load index drifted from the holder table"
            );
        }
    }

    #[test]
    fn reroute_chunk_plans_survives_the_source_dying_mid_pull() {
        let (mut t, f, _) = rig(4, 1);
        let mut pc = PoolLayerCache::new();
        let recipe = recipe4();
        assert!(pc.describe_chunks(0xB10B, &recipe));
        pc.register(1, 0xB10B);
        pc.register(2, 0xB10B);
        let plans = pc.plan_chunks(&f, &t, 3, 0xB10B, 4 << 20);
        assert!(plans.iter().all(|p| p.source == FetchSource::Peer(1)), "nearest first");
        // node 1 dies while the pull is mid-flight
        t.node_mut(1).unwrap().healthy = false;
        pc.purge_node(1);
        let rerouted = pc.reroute_chunk_plans(&f, &t, 3, &plans);
        assert!(
            rerouted.iter().all(|p| p.source == FetchSource::Peer(2)),
            "plans re-point at the surviving holder: {rerouted:?}"
        );
        // with no surviving holder the plan falls back to the registry
        t.node_mut(2).unwrap().healthy = false;
        let rerouted = pc.reroute_chunk_plans(&f, &t, 3, &plans);
        assert!(rerouted.iter().all(|p| p.source == FetchSource::Registry), "{rerouted:?}");
    }

    #[test]
    fn duplicate_chunks_in_a_recipe_transfer_once() {
        let (t, mut f, mut b) = rig(3, 1);
        let mut pc = PoolLayerCache::new();
        // the blob repeats one chunk three times: only distinct content
        // moves
        assert!(pc.describe_chunks(0xD0B, &[(0xC9, 1 << 20), (0xC9, 1 << 20), (0xC9, 1 << 20)]));
        pc.register(0, 0xD0B);
        let (src, _) = pc.fetch(wire!(f, t, b), 1, 0xD0B, 3 << 20);
        assert_eq!(src, FetchSource::Peer(0));
        assert_eq!(pc.chunk_fetches, 1, "dedup'd on the wire");
        assert_eq!(pc.bytes_from_peers, 1 << 20);
    }
}
