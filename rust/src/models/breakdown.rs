//! The six latency components of Figure 11 (all values in seconds).

/// Component identifiers in Figure 11's legend order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Component {
    Network,
    KernelCtx,
    LbaSet,
    Storage,
    System,
    Compute,
}

impl Component {
    pub const ALL: [Component; 6] = [
        Component::Network,
        Component::KernelCtx,
        Component::LbaSet,
        Component::Storage,
        Component::System,
        Component::Compute,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Component::Network => "Network",
            Component::KernelCtx => "Kernel-ctx",
            Component::LbaSet => "LBA-set",
            Component::Storage => "Storage",
            Component::System => "System",
            Component::Compute => "Compute",
        }
    }
}

/// Per-component latency (seconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencyBreakdown {
    pub network: f64,
    pub kernel_ctx: f64,
    pub lba_set: f64,
    pub storage: f64,
    pub system: f64,
    pub compute: f64,
}

impl LatencyBreakdown {
    pub fn total(&self) -> f64 {
        self.network + self.kernel_ctx + self.lba_set + self.storage + self.system + self.compute
    }

    pub fn get(&self, c: Component) -> f64 {
        match c {
            Component::Network => self.network,
            Component::KernelCtx => self.kernel_ctx,
            Component::LbaSet => self.lba_set,
            Component::Storage => self.storage,
            Component::System => self.system,
            Component::Compute => self.compute,
        }
    }

    /// Figure 3's coarse split: ISP communication/synchronization.
    pub fn communicate(&self) -> f64 {
        self.kernel_ctx + self.lba_set
    }

    pub fn fraction(&self, c: Component) -> f64 {
        let t = self.total();
        if t == 0.0 {
            0.0
        } else {
            self.get(c) / t
        }
    }

    pub fn scaled(&self, f: f64) -> LatencyBreakdown {
        LatencyBreakdown {
            network: self.network * f,
            kernel_ctx: self.kernel_ctx * f,
            lba_set: self.lba_set * f,
            storage: self.storage * f,
            system: self.system * f,
            compute: self.compute * f,
        }
    }

    pub fn add(&mut self, other: &LatencyBreakdown) {
        self.network += other.network;
        self.kernel_ctx += other.kernel_ctx;
        self.lba_set += other.lba_set;
        self.storage += other.storage;
        self.system += other.system;
        self.compute += other.compute;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> LatencyBreakdown {
        LatencyBreakdown {
            network: 1.0,
            kernel_ctx: 2.0,
            lba_set: 3.0,
            storage: 4.0,
            system: 5.0,
            compute: 6.0,
        }
    }

    #[test]
    fn total_sums_components() {
        assert_eq!(sample().total(), 21.0);
    }

    #[test]
    fn get_matches_fields() {
        let b = sample();
        for (c, want) in Component::ALL.iter().zip([1.0, 2.0, 3.0, 4.0, 5.0, 6.0]) {
            assert_eq!(b.get(*c), want);
        }
    }

    #[test]
    fn communicate_is_ctx_plus_lba() {
        assert_eq!(sample().communicate(), 5.0);
    }

    #[test]
    fn fractions_sum_to_one() {
        let b = sample();
        let sum: f64 = Component::ALL.iter().map(|c| b.fraction(*c)).sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_breakdown_fraction_is_zero() {
        assert_eq!(LatencyBreakdown::default().fraction(Component::Storage), 0.0);
    }

    #[test]
    fn add_and_scale() {
        let mut a = sample();
        a.add(&sample());
        assert_eq!(a.total(), 42.0);
        assert_eq!(a.scaled(0.5).total(), 21.0);
    }
}
