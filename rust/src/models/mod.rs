//! The six data-processing models of the evaluation (DESIGN.md S7):
//! Host, P.ISP-R, P.ISP-V, D-Naive, D-FullOS, D-VirtFW.
//!
//! Each model composes an end-to-end latency for a Table 2 workload from
//! the calibrated unit costs ([`crate::firmware::CostModel`]), split into
//! the six components of Figure 11: Network, Kernel-ctx, LBA-set,
//! Storage, System, Compute.  Figure 3's three-way breakdown maps onto
//! the same components (Communicate = Kernel-ctx + LBA-set).

pub mod breakdown;

use crate::firmware::CostModel;
use crate::workloads::WorkloadSpec;

pub use breakdown::{Component, LatencyBreakdown};

/// Which model — order matches Figure 11's legend.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Host,
    PIspR,
    PIspV,
    DNaive,
    DFullOs,
    DVirtFw,
}

impl ModelKind {
    pub const ALL: [ModelKind; 6] = [
        ModelKind::Host,
        ModelKind::PIspR,
        ModelKind::PIspV,
        ModelKind::DNaive,
        ModelKind::DFullOs,
        ModelKind::DVirtFw,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Host => "Host",
            ModelKind::PIspR => "P.ISP-R",
            ModelKind::PIspV => "P.ISP-V",
            ModelKind::DNaive => "D-Naive",
            ModelKind::DFullOs => "D-FullOS",
            ModelKind::DVirtFw => "D-VirtFW",
        }
    }
}

/// Evaluate `model` on `w`, returning the component breakdown in seconds.
pub fn evaluate(model: ModelKind, w: &WorkloadSpec, c: &CostModel) -> LatencyBreakdown {
    match model {
        ModelKind::Host => host(w, c),
        ModelKind::PIspR => pisp(w, c, true),
        ModelKind::PIspV => pisp(w, c, false),
        ModelKind::DNaive => docker_ssd(w, c, OsKind::FullOsSplit),
        ModelKind::DFullOs => docker_ssd(w, c, OsKind::FullOsUnified),
        ModelKind::DVirtFw => docker_ssd(w, c, OsKind::VirtFw),
    }
}

const NS: f64 = 1e-9;

/// Host (non-ISP baseline): full OS stack, data crosses PCIe to DRAM.
fn host(w: &WorkloadSpec, c: &CostModel) -> LatencyBreakdown {
    let mut b = LatencyBreakdown::default();
    // compute on the host cores
    b.compute = w.io_bytes as f64 * c.t_proc_host_ns_per_byte * NS;
    // system: syscalls + VFS path walks (host dentry cache assumed warm-ish)
    b.system = (w.syscalls as f64 * c.t_sys_host_ns as f64
        + w.path_walks as f64 * c.t_walk_host_ns as f64)
        * NS;
    // storage: flash service + host block stack per I/O + PCIe transfer
    let per_io_bytes = w.io_bytes / w.io_count.max(1);
    let flash =
        w.io_count as f64 * c.flash_io_ns(per_io_bytes, false) * (1.0 - w.write_frac)
            + w.io_count as f64 * c.flash_io_ns(per_io_bytes, true) * w.write_frac;
    let blk = w.io_count as f64 * c.t_blk_host_ns as f64;
    let pcie = CostModel::xfer_ns(w.io_bytes, c.pcie_bw_gbps);
    b.storage = (flash + blk + pcie) * NS;
    // network: host kernel stack
    b.network = w.tcp_packets as f64 * c.t_pkt_host_ns as f64 * NS;
    b
}

/// Programmable ISP (Willow-like RPC / Biscuit-like vendor commands):
/// kernels run near flash, but system-specific calls bounce to the host
/// and file extents require LBA-set handshakes.
fn pisp(w: &WorkloadSpec, c: &CostModel, rpc: bool) -> LatencyBreakdown {
    let mut b = LatencyBreakdown::default();
    let f = c.ssd_compute_factor();
    b.compute = w.io_bytes as f64 * c.t_proc_host_ns_per_byte * f * NS;
    // bare-metal kernels: no OS stack on device; the host-side runtime
    // shim handles residual bookkeeping per file
    b.system = w.files_opened as f64 * c.t_sys_host_ns as f64 * NS;
    // storage near flash: no host block stack, no PCIe crossing
    let per_io_bytes = w.io_bytes / w.io_count.max(1);
    let flash =
        w.io_count as f64 * c.flash_io_ns(per_io_bytes, false) * (1.0 - w.write_frac)
            + w.io_count as f64 * c.flash_io_ns(per_io_bytes, true) * w.write_frac;
    b.storage = flash * NS;
    // kernel-ctx: every syscall-like service the offloaded kernel needs is
    // a round trip to the host runtime (RPC or vendor command)
    let per_bounce = if rpc { c.t_ctx_rpc_ns } else { c.t_ctx_vendor_ns };
    b.kernel_ctx = w.syscalls as f64 * per_bounce as f64 * NS;
    // LBA-set: per newly-opened file + per-I/O extent bookkeeping
    b.lba_set =
        (w.files_opened as f64 * c.t_lba_per_file_ns as f64
            + w.io_count as f64 * c.t_lba_per_io_ns as f64)
            * NS;
    // network responses still ride the host stack (R additionally pays an
    // RPC response per packet batch, folded into t_ctx_rpc)
    b.network = w.tcp_packets as f64 * c.t_pkt_host_ns as f64 * NS;
    b
}

enum OsKind {
    /// D-Naive: full Linux on a separate processor complex.
    FullOsSplit,
    /// D-FullOS: full Linux sharing the controller complex.
    FullOsUnified,
    /// D-VirtFW: Virtual-FW emulation.
    VirtFw,
}

/// Containerized DockerSSD variants: autonomous execution (no Kernel-ctx,
/// no LBA-set thanks to λFS + rootfs pre-packaging), differing in OS stack.
fn docker_ssd(w: &WorkloadSpec, c: &CostModel, os: OsKind) -> LatencyBreakdown {
    let mut b = LatencyBreakdown::default();
    let f = c.ssd_compute_factor();
    b.compute = w.io_bytes as f64 * c.t_proc_host_ns_per_byte * f * NS;

    let per_io_bytes = w.io_bytes / w.io_count.max(1);
    let flash =
        w.io_count as f64 * c.flash_io_ns(per_io_bytes, false) * (1.0 - w.write_frac)
            + w.io_count as f64 * c.flash_io_ns(per_io_bytes, true) * w.write_frac;

    match os {
        OsKind::VirtFw => {
            // emulated syscalls + λFS walks with the I/O-node cache
            b.system = (w.syscalls as f64 * c.t_sys_emul_ns as f64
                + w.path_walks as f64 * c.t_walk_fw_ns as f64)
                * NS;
            // λFS direct flash path
            b.storage = flash * NS;
        }
        OsKind::FullOsUnified => {
            // full Linux on the slow cores: syscalls + VFS walks + block layer
            b.system = (w.syscalls as f64 * c.t_sys_fullos_ssd_ns as f64
                + w.path_walks as f64 * (c.t_walk_host_ns as f64 * f))
                * NS;
            b.storage = (flash + w.io_count as f64 * c.t_blk_host_ns as f64 * f) * NS;
        }
        OsKind::FullOsSplit => {
            b.system = (w.syscalls as f64 * c.t_sys_fullos_ssd_ns as f64
                + w.path_walks as f64 * (c.t_walk_host_ns as f64 * f))
                * NS;
            // plus every byte crosses the ISP-complex <-> controller link
            let complex = CostModel::xfer_ns(w.io_bytes, c.complex_link_gbps)
                + w.io_count as f64 * c.t_complex_per_io_ns as f64;
            b.storage =
                (flash + w.io_count as f64 * c.t_blk_host_ns as f64 * f + complex) * NS;
        }
    }
    // Ether-oN network path for client traffic
    b.network = w.tcp_packets as f64 * c.t_pkt_ethon_ns as f64 * NS;
    b
}

/// Figure 11 row: every model evaluated on `w`, normalized to D-VirtFW.
pub fn fig11_row(w: &WorkloadSpec, c: &CostModel) -> Vec<(ModelKind, LatencyBreakdown, f64)> {
    let base = evaluate(ModelKind::DVirtFw, w, c).total();
    ModelKind::ALL
        .iter()
        .map(|&m| {
            let b = evaluate(m, w, c);
            let norm = b.total() / base;
            (m, b, norm)
        })
        .collect()
}

/// Geometric mean of per-workload ratios model/base — the paper's "NxM
/// better" aggregates.
pub fn geomean_ratio(model: ModelKind, base: ModelKind, c: &CostModel) -> f64 {
    let ws = crate::workloads::all_workloads();
    let mut log_sum = 0.0;
    for w in &ws {
        let m = evaluate(model, w, c).total();
        let b = evaluate(base, w, c).total();
        log_sum += (m / b).ln();
    }
    (log_sum / ws.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::all_workloads;

    fn c() -> CostModel {
        CostModel::calibrated()
    }

    #[test]
    fn all_models_produce_positive_latency() {
        for w in all_workloads() {
            for m in ModelKind::ALL {
                let t = evaluate(m, &w, &c()).total();
                assert!(t > 0.0, "{} on {}", m.name(), w.full_name());
            }
        }
    }

    #[test]
    fn host_has_no_isp_communication() {
        for w in all_workloads() {
            let b = evaluate(ModelKind::Host, &w, &c());
            assert_eq!(b.kernel_ctx, 0.0);
            assert_eq!(b.lba_set, 0.0);
        }
    }

    #[test]
    fn dockerssd_variants_have_no_communication_overhead() {
        for w in all_workloads() {
            for m in [ModelKind::DNaive, ModelKind::DFullOs, ModelKind::DVirtFw] {
                let b = evaluate(m, &w, &c());
                assert_eq!(b.kernel_ctx, 0.0, "{}", m.name());
                assert_eq!(b.lba_set, 0.0);
            }
        }
    }

    #[test]
    fn pisp_storage_is_half_of_host_storage() {
        // paper: "P.ISP reduces Storage latency by 50% compared to Host"
        let ws = all_workloads();
        let mut ratio_sum = 0.0;
        for w in &ws {
            let h = evaluate(ModelKind::Host, w, &c()).storage;
            let p = evaluate(ModelKind::PIspR, w, &c()).storage;
            ratio_sum += p / h;
        }
        let mean = ratio_sum / ws.len() as f64;
        assert!((0.35..0.70).contains(&mean), "P.ISP/Host storage {mean:.2}");
    }

    #[test]
    fn pisp_v_faster_than_r() {
        let r = geomean_ratio(ModelKind::PIspV, ModelKind::PIspR, &c());
        assert!(r < 1.0, "V/R = {r:.3}");
        // paper: 13.7% lower latency
        assert!((0.78..0.97).contains(&r), "V/R = {r:.3}");
    }

    #[test]
    fn dvirtfw_beats_every_other_model() {
        for m in [
            ModelKind::Host,
            ModelKind::PIspR,
            ModelKind::PIspV,
            ModelKind::DNaive,
            ModelKind::DFullOs,
        ] {
            let r = geomean_ratio(m, ModelKind::DVirtFw, &c());
            assert!(r > 1.0, "{} / D-VirtFW = {r:.3}", m.name());
        }
    }

    #[test]
    fn fig11_normalization_base_is_one() {
        let w = &all_workloads()[0];
        let row = fig11_row(w, &c());
        let dv = row.iter().find(|(m, _, _)| *m == ModelKind::DVirtFw).unwrap();
        assert!((dv.2 - 1.0).abs() < 1e-12);
    }
}
