//! Shared driver for the serving case study, used by `repro serve` and
//! the `llm_pool_serving` example: spin up N pool-node engines (real PJRT
//! execution of the AOT artifacts), push batched requests through the
//! coordinator, and report latency/throughput.

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::{serve, InferenceRequest};
use crate::runtime::{Engine, Manifest};
use crate::util::Rng;

/// Run the serving demo.  Returns Err if artifacts are missing.
pub fn run_serve(artifacts: &str, nodes: usize, n_requests: usize, tokens: usize) -> Result<()> {
    let dir = PathBuf::from(artifacts);
    let manifest = Manifest::load(&dir)?;
    let c = manifest.config.clone();
    println!(
        "model: {} params, {} layers, d_model {}, batch {}, prompt {}, max_seq {}",
        c.param_count, c.n_layers, c.d_model, c.batch, c.prompt_len, c.max_seq
    );
    println!("pool: {nodes} DockerSSD nodes (PJRT CPU engines)");

    // deterministic synthetic prompts over the model's vocab
    let mut rng = Rng::new(42);
    let requests: Vec<InferenceRequest> = (0..n_requests as u64)
        .map(|id| InferenceRequest {
            id,
            prompt: (0..c.prompt_len)
                .map(|_| rng.below(c.vocab as u64) as i32)
                .collect(),
            max_new_tokens: tokens,
        })
        .collect();

    let factories: Vec<_> = (0..nodes)
        .map(|_| {
            let dir = dir.clone();
            move || Engine::load(&dir)
        })
        .collect();

    let kv_bytes = (manifest.kv_cache_elems() * 2 * 4) as u64;
    let report = serve(factories, requests, c.batch, c.prompt_len, kv_bytes * 4);

    println!("\nresults:");
    for r in report.responses.iter().take(4) {
        println!("  req {} via node {}: {:?}", r.id, r.node, &r.tokens);
    }
    if report.responses.len() > 4 {
        println!("  ... ({} total)", report.responses.len());
    }
    println!(
        "\n{} requests, {} batches ({} padded rows), {} tokens in {:?}",
        report.responses.len(),
        report.batches,
        report.padded_rows,
        report.tokens_out,
        report.wall
    );
    println!(
        "throughput {:.1} tok/s, mean batch latency {:?}",
        report.throughput_tok_s(),
        report.mean_latency()
    );
    Ok(())
}
