//! Shared driver for the serving case study, used by `repro serve` and
//! the `llm_pool_serving` example: spin up N pool-node engines (real PJRT
//! execution of the AOT artifacts), push batched requests through the
//! simulated-time coordinator on a [`PoolSim`] clock, and report
//! simulated latency/throughput.

use std::path::PathBuf;

use anyhow::Result;

use crate::config::SystemConfig;
use crate::coordinator::{serve, InferenceRequest, KvManager, ServeParams};
use crate::metrics::Counters;
use crate::runtime::{Engine, Manifest};
use crate::sim::PoolSim;
use crate::util::{Rng, SimTime};

/// Run the serving demo.  Returns Err if artifacts are missing.
pub fn run_serve(artifacts: &str, nodes: usize, n_requests: usize, tokens: usize) -> Result<()> {
    let dir = PathBuf::from(artifacts);
    let manifest = Manifest::load(&dir)?;
    let c = manifest.config.clone();
    println!(
        "model: {} params, {} layers, d_model {}, batch {}, prompt {}, max_seq {}",
        c.param_count, c.n_layers, c.d_model, c.batch, c.prompt_len, c.max_seq
    );
    println!("pool: {nodes} DockerSSD nodes (PJRT CPU engines, simulated-time coordinator)");

    // deterministic synthetic prompts over the model's vocab, arriving
    // across a simulated 5ms window
    let mut rng = Rng::new(42);
    let requests: Vec<(SimTime, InferenceRequest)> = (0..n_requests as u64)
        .map(|id| {
            (
                SimTime::us(rng.below(5_000)),
                InferenceRequest {
                    id,
                    prompt: (0..c.prompt_len)
                        .map(|_| rng.below(c.vocab as u64) as i32)
                        .collect(),
                    max_new_tokens: tokens,
                },
            )
        })
        .collect();

    let factories: Vec<_> = (0..nodes)
        .map(|_| {
            let dir = dir.clone();
            move || Engine::load(&dir)
        })
        .collect();

    let cfg = SystemConfig::default();
    // per-token KV from the artifact's model config (K+V f32 vectors per
    // layer); node capacity still spans four full-context batches
    let kv_bytes = (manifest.kv_cache_elems() * 2 * 4) as u64;
    let params = ServeParams {
        batch_width: c.batch,
        prompt_len: c.prompt_len,
        kv_capacity_per_node: kv_bytes * 4,
        kv_bytes_per_token: KvManager::kv_bytes_per_token(c.n_layers as u64, c.d_model as u64, 4),
        ..ServeParams::from_config(&cfg.serve)
    };
    let mut sim = PoolSim::new(&cfg);
    let report = serve(&mut sim, factories, requests, &params);

    println!("\nresults:");
    for r in report.responses.iter().take(4) {
        println!("  req {} via node {}: {:?}", r.id, r.node, &r.tokens);
    }
    if report.responses.len() > 4 {
        println!("  ... ({} total)", report.responses.len());
    }
    println!(
        "\n{} requests, {} batches ({} padded rows), {} tokens in {} simulated",
        report.responses.len(),
        report.batches,
        report.padded_rows,
        report.tokens_out,
        report.makespan
    );
    println!(
        "throughput {:.1} tok/s (simulated), mean batch latency {}",
        report.throughput_tok_s(),
        report.mean_latency()
    );
    let mut counters = Counters::new();
    report.export_counters(&mut counters);
    sim.export_counters(&mut counters);
    for (k, v) in counters.iter() {
        println!("  {k} = {v}");
    }
    Ok(())
}
