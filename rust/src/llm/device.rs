//! Device profiles for the disaggregation scenarios (paper: hosts with
//! 3.8GHz CPU + 64GB DRAM vs DockerSSDs with 2.2GHz frontend + 400GB
//! flash addressable "as local memory").
//!
//! The decisive differences:
//!   * compute: DockerSSD ~0.58x host (frequency + IPC),
//!   * KV path: host-with-cache reads KV through Linux swap (page faults,
//!     copies, cache pollution) at a small fraction of raw PCIe speed;
//!     DockerSSD reads flash directly at full internal channel bandwidth.

/// Hardware profile of one inference device (host or DockerSSD).
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Effective FLOP/s for memory-bound per-token decode ops.
    pub flops_decode: f64,
    /// Peak FLOP/s for large batched GEMMs (NoCache recompute).
    pub flops_gemm: f64,
    /// Main-memory bandwidth for weight streaming (B/s).
    pub mem_bw: f64,
    /// Bandwidth of the KV-cache path (B/s) — DRAM, swap, or flash.
    pub kv_bw: f64,
    /// Memory capacity available for weights + KV (bytes).
    pub mem_capacity: f64,
    /// Inter-device link bandwidth (B/s).
    pub link_bw: f64,
    /// Per-message link latency (s).
    pub link_latency_s: f64,
    /// Bytes per weight parameter (fp16).
    pub weight_bytes_per_param: f64,
    /// Bytes per KV element (fp16).
    pub kv_bytes_per_elem: f64,
}

const GB: f64 = 1e9;

impl DeviceProfile {
    /// Host without KV cache: 64GB DRAM holds weight shards + activations.
    ///
    /// `flops_decode` is the *effective* per-token decode throughput with
    /// weight streaming overlapped (Calculon-style); fitted so the
    /// Fig 13a crossover for lamda-137B lands near seq 256.
    pub fn host_nocache() -> Self {
        DeviceProfile {
            name: "host-nocache",
            flops_decode: 127e9,
            flops_gemm: 127e9,
            mem_bw: 25.6 * GB,
            kv_bw: 25.6 * GB, // unused (no KV)
            mem_capacity: 64.0 * GB,
            link_bw: 3.2 * GB,
            link_latency_s: 5e-6,
            weight_bytes_per_param: 2.0,
            kv_bytes_per_elem: 2.0,
        }
    }

    /// Host with KV cache: DRAM + 400GB SSD via Linux swap.  The KV path
    /// suffers page faults, copies, and cache pollution — a fraction of
    /// raw device speed.
    pub fn host_cache() -> Self {
        DeviceProfile {
            name: "host-cache",
            mem_capacity: (64.0 + 400.0) * GB,
            kv_bw: 0.40 * GB, // swap-effective bandwidth
            ..Self::host_nocache()
        }
    }

    /// DockerSSD: slower cores (2.2 vs 3.8 GHz — the paper's "roughly 60%
    /// of host performance"), flash addressed as local memory at full
    /// internal channel bandwidth.
    pub fn dockerssd() -> Self {
        let host = Self::host_nocache();
        let slow = 2.2 / 3.8; // frequency ratio
        DeviceProfile {
            name: "dockerssd",
            flops_decode: host.flops_decode * slow,
            flops_gemm: host.flops_gemm * slow,
            mem_bw: 12.8 * GB, // internal DRAM
            kv_bw: 4.0 * GB,   // internal channel aggregate, direct
            mem_capacity: 400.0 * GB,
            link_bw: 3.2 * GB,
            link_latency_s: 5e-6,
            weight_bytes_per_param: 2.0,
            kv_bytes_per_elem: 2.0,
        }
    }

    /// DockerSSD without using flash for KV (D-NoCache): same silicon,
    /// KV disabled; only the 2GB internal DRAM is usable, but NoCache
    /// needs no KV anyway.
    pub fn dockerssd_nocache() -> Self {
        DeviceProfile {
            name: "dockerssd-nocache",
            ..Self::dockerssd()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dockerssd_compute_is_roughly_60pct_of_host() {
        let h = DeviceProfile::host_nocache();
        let d = DeviceProfile::dockerssd();
        let ratio = d.flops_decode / h.flops_decode;
        assert!((0.5..0.65).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn swap_kv_path_is_order_of_magnitude_slower_than_flash_direct() {
        let h = DeviceProfile::host_cache();
        let d = DeviceProfile::dockerssd();
        let ratio = d.kv_bw / h.kv_bw;
        // this ratio bounds the long-sequence speedup (paper: ~9.5x)
        assert!((8.0..11.0).contains(&ratio), "kv bw ratio {ratio}");
    }

    #[test]
    fn cache_profiles_have_capacity_for_kv() {
        assert!(DeviceProfile::host_cache().mem_capacity > DeviceProfile::host_nocache().mem_capacity);
        assert!(DeviceProfile::dockerssd().mem_capacity >= 400.0 * 1e9);
    }

    #[test]
    fn gemm_path_at_least_as_fast_as_decode_path() {
        let h = DeviceProfile::host_nocache();
        assert!(h.flops_gemm >= h.flops_decode);
    }
}
