//! Parallelism search: enumerate (dp, tp, pp) factorizations of the node
//! count, filter by memory feasibility, pick the fastest (the paper's
//! "identifying the optimal configuration by selecting the scenario with
//! the shortest execution time").

use super::device::DeviceProfile;
use super::models::LlmConfig;
use super::{bytes_per_device, sequence_time, InferenceTime};

/// A (dp, tp, pp) assignment over dp*tp*pp devices.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Parallelism {
    pub dp: u32,
    pub tp: u32,
    pub pp: u32,
}

impl Parallelism {
    pub fn devices(&self) -> u32 {
        self.dp * self.tp * self.pp
    }

    pub fn label(&self) -> String {
        format!("dp{}/tp{}/pp{}", self.dp, self.tp, self.pp)
    }

    /// The dominant axis (Figure 12a reports which kind wins).
    pub fn dominant(&self) -> ParallelKind {
        if self.tp >= self.pp && self.tp >= self.dp {
            ParallelKind::Tensor
        } else if self.pp >= self.dp {
            ParallelKind::Pipeline
        } else {
            ParallelKind::Data
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParallelKind {
    Data,
    Tensor,
    Pipeline,
}

impl ParallelKind {
    pub fn name(&self) -> &'static str {
        match self {
            ParallelKind::Data => "data",
            ParallelKind::Tensor => "tensor",
            ParallelKind::Pipeline => "pipeline",
        }
    }
}

/// All (dp, tp, pp) triples with dp*tp*pp == n (n a power of two here).
pub fn factorizations(n: u32) -> Vec<Parallelism> {
    let mut out = Vec::new();
    let mut dp = 1;
    while dp <= n {
        if n % dp == 0 {
            let rest = n / dp;
            let mut tp = 1;
            while tp <= rest {
                if rest % tp == 0 {
                    out.push(Parallelism {
                        dp,
                        tp,
                        pp: rest / tp,
                    });
                }
                tp += 1;
            }
        }
        dp += 1;
    }
    out
}

/// Search result.
#[derive(Clone, Debug)]
pub struct OptimalChoice {
    pub par: Parallelism,
    pub time: InferenceTime,
}

/// Find the fastest feasible parallelism for a scenario.  `batch` is the
/// *global* batch; dp must divide it.
pub fn find_optimal(
    llm: &LlmConfig,
    dev: &DeviceProfile,
    nodes: u32,
    seq: u64,
    batch: u64,
    kv_cache: bool,
) -> Option<OptimalChoice> {
    let mut best: Option<OptimalChoice> = None;
    for par in factorizations(nodes) {
        if par.dp as u64 > batch {
            continue;
        }
        if bytes_per_device(llm, dev, par, seq, batch, kv_cache) > dev.mem_capacity {
            continue;
        }
        let t = sequence_time(llm, dev, par, seq, batch, kv_cache);
        if best.as_ref().is_none_or(|b| t.total() < b.time.total()) {
            best = Some(OptimalChoice { par, time: t });
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::models::all_llms;

    #[test]
    fn factorizations_cover_power_of_two() {
        let f = factorizations(8);
        assert!(f.contains(&Parallelism { dp: 1, tp: 8, pp: 1 }));
        assert!(f.contains(&Parallelism { dp: 2, tp: 2, pp: 2 }));
        assert!(f.contains(&Parallelism { dp: 8, tp: 1, pp: 1 }));
        for p in &f {
            assert_eq!(p.devices(), 8);
        }
    }

    #[test]
    fn dominant_axis_classification() {
        assert_eq!(Parallelism { dp: 1, tp: 8, pp: 2 }.dominant(), ParallelKind::Tensor);
        assert_eq!(Parallelism { dp: 2, tp: 1, pp: 8 }.dominant(), ParallelKind::Pipeline);
        assert_eq!(Parallelism { dp: 8, tp: 1, pp: 1 }.dominant(), ParallelKind::Data);
    }

    #[test]
    fn optimal_respects_memory_feasibility() {
        let m = all_llms().into_iter().find(|m| m.name == "megatron-1T").unwrap();
        let dev = DeviceProfile::host_nocache(); // 64GB/node
        // 1T params fp16 = 2TB; 16 nodes x 64GB = 1TB -> infeasible at any split
        assert!(find_optimal(&m, &dev, 16, 1024, 1, false).is_none());
        // 64 nodes x 64GB = 4TB -> feasible
        assert!(find_optimal(&m, &dev, 64, 1024, 1, false).is_some());
    }

    #[test]
    fn dp_cannot_exceed_batch() {
        let m = all_llms().remove(0);
        let dev = DeviceProfile::host_cache();
        let best = find_optimal(&m, &dev, 16, 1024, 1, true).unwrap();
        assert_eq!(best.par.dp, 1, "batch 1 forbids data parallelism");
    }

    #[test]
    fn cached_decode_prefers_tensor_parallelism() {
        // Fig 12a: with KV cache, tensor parallelism wins
        let m = all_llms().into_iter().find(|m| m.name == "gpt3-175B").unwrap();
        for dev in [DeviceProfile::host_cache(), DeviceProfile::dockerssd()] {
            let best = find_optimal(&m, &dev, 32, 32_768, 1, true).unwrap();
            assert_eq!(
                best.par.dominant(),
                ParallelKind::Tensor,
                "{}: {}",
                dev.name,
                best.par.label()
            );
        }
    }

    #[test]
    fn nocache_prefers_pipeline_parallelism() {
        // Fig 12a: heavy per-layer recompute -> pipeline parallelism
        let m = all_llms().into_iter().find(|m| m.name == "gpt3-175B").unwrap();
        for dev in [DeviceProfile::host_nocache(), DeviceProfile::dockerssd_nocache()] {
            let best = find_optimal(&m, &dev, 32, 32_768, 1, false).unwrap();
            assert_eq!(
                best.par.dominant(),
                ParallelKind::Pipeline,
                "{}: {}",
                dev.name,
                best.par.label()
            );
        }
    }
}
