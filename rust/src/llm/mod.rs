//! Analytic distributed-LLM-inference simulator (DESIGN.md S10).
//!
//! Rebuilds the paper's Calculon-derived methodology: an analytical model
//! of per-token compute and memory time for eight LLMs, extended (as the
//! authors did) with a KV-cache model, evaluated under data/tensor/
//! pipeline parallelism across 16-128 devices, picking the
//! fastest configuration per scenario.  Drives Figures 12 and 13.

pub mod device;
pub mod disagg;
pub mod models;
pub mod parallelism;

pub use device::DeviceProfile;
pub use disagg::{DisaggModel, ScenarioResult};
pub use models::{all_llms, LlmConfig};
pub use parallelism::{Parallelism, ParallelKind};

/// Breakdown of per-sequence inference time (seconds): Compute (matrix/
/// vector math) vs Memory (reading inputs + KV + writing outputs) —
/// Figure 12b's two components.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct InferenceTime {
    pub compute: f64,
    pub memory: f64,
    /// Inter-device communication (folded into Compute in Fig 12b's
    /// two-way split, but tracked separately here).
    pub comm: f64,
}

impl InferenceTime {
    pub fn total(&self) -> f64 {
        self.compute + self.memory + self.comm
    }
}

/// Per-token inference cost for one (model, device, parallelism, cache)
/// scenario.  `seq` is the sequence length the KV cache has reached; the
/// per-token cost is evaluated at the *average* prefix length seq/2 and
/// multiplied by `seq` by callers integrating over a generation.
///
/// Modeling choices (DESIGN.md §4):
/// * Dense per-token FLOPs = 2 x 12 L d^2 (analytic dense params), which
///   keeps inter-model ratios consistent with layer geometry.  Weight
///   reads overlap compute and are folded into the device's effective
///   decode throughput (`flops_decode`), as in Calculon-style models.
/// * Without a KV cache, attention at step i needs K/V for all i prefix
///   positions, and recovering them requires re-running the *full
///   forward* over the prefix (K/V at layer l depend on hidden states at
///   layer l).  That recompute is a big batched computation: it runs at
///   `flops_gemm` and pipelines across pp stages in sequence chunks of
///   `RECOMPUTE_CHUNK` positions, paying the classic (pp-1)/chunks
///   pipeline-fill bubble.
/// * With a KV cache, the prefix K/V (2 x d x 2B per layer-position) is
///   read through the device's KV path — DRAM for hosts without cache
///   pressure, DRAM+swap for H-Cache, flash-as-local for D-Cache.  This
///   is exactly where the disaggregation models differ.
/// * Tensor parallelism: 2 all-reduces per layer; pipeline parallelism:
///   per-boundary activation hop.  With a KV cache, decode is a serial
///   per-token dependency chain, so PP divides only memory capacity, not
///   latency.
pub const RECOMPUTE_CHUNK: f64 = 64.0;

pub fn time_per_token(
    llm: &LlmConfig,
    dev: &DeviceProfile,
    par: Parallelism,
    seq: u64,
    batch: u64,
    kv_cache: bool,
) -> InferenceTime {
    let d = llm.d_model as f64;
    let l = llm.layers as f64;
    let b_local = (batch as f64 / par.dp as f64).max(1.0);
    let prefix = (seq as f64 / 2.0).max(1.0); // average over the generation

    // --- compute ---------------------------------------------------------
    let dense_flops = 2.0 * llm.dense_params() as f64 * b_local;
    let mut t = InferenceTime::default();

    if kv_cache {
        // new token only; model split over tp (PP stages execute serially)
        t.compute = dense_flops / (dev.flops_decode * par.tp as f64);
        // attention score+mix over the prefix is folded into memory time
    } else {
        // full-forward recompute of the prefix, every step
        let recompute = prefix * dense_flops;
        let chunks = (prefix / RECOMPUTE_CHUNK).max(1.0);
        let pp_eff = par.pp as f64 / (1.0 + (par.pp as f64 - 1.0) / chunks);
        t.compute = dense_flops / (dev.flops_decode * par.tp as f64)
            + recompute / (dev.flops_gemm * par.tp as f64 * pp_eff);
    }

    // --- memory ----------------------------------------------------------
    if kv_cache {
        // prefix K/V read through the KV path
        let kv_bytes = l * prefix * 2.0 * d * dev.kv_bytes_per_elem * b_local;
        t.memory = kv_bytes / (par.tp as f64 * dev.kv_bw);
    } else {
        // activations only (weights overlap compute)
        let act_bytes = l * d * 8.0 * b_local;
        t.memory = act_bytes / dev.mem_bw;
    }

    // --- communication -----------------------------------------------------
    if par.tp > 1 {
        // 2 all-reduces per layer; the reduced activations cover every
        // position being processed this step: one token with a KV cache,
        // the whole prefix without one.  This asymmetry is why Fig 12a
        // flips from pipeline- to tensor-parallel once caching is on.
        let positions = if kv_cache { 1.0 } else { prefix };
        let bytes =
            2.0 * l * positions * b_local * d * 2.0 * ((par.tp - 1) as f64 / par.tp as f64);
        // all tp ranks push through a shared PCIe switch whose backplane
        // does not scale with fan-out: effective bandwidth halves per
        // doubling beyond 2 ranks (congestion factor tp/2)
        let congestion = (par.tp as f64 * 0.75).max(1.0);
        t.comm += bytes * congestion / dev.link_bw + 2.0 * l * dev.link_latency_s;
    }
    if par.pp > 1 {
        let bytes = (par.pp - 1) as f64 * b_local * d * 2.0;
        t.comm += bytes / dev.link_bw + (par.pp - 1) as f64 * dev.link_latency_s;
    }
    t
}

/// Memory capacity required per device (bytes) — the feasibility
/// constraint of the parallelism search.
pub fn bytes_per_device(
    llm: &LlmConfig,
    dev: &DeviceProfile,
    par: Parallelism,
    seq: u64,
    batch: u64,
    kv_cache: bool,
) -> f64 {
    let weights = llm.dense_params() as f64 * dev.weight_bytes_per_param
        / (par.tp * par.pp) as f64;
    let kv = if kv_cache {
        llm.layers as f64
            * seq as f64
            * 2.0
            * llm.d_model as f64
            * dev.kv_bytes_per_elem
            * (batch as f64 / par.dp as f64).max(1.0)
            / (par.tp * par.pp) as f64
    } else {
        0.0
    };
    weights + kv
}

/// Time to generate a full sequence of `seq` tokens (seconds).
pub fn sequence_time(
    llm: &LlmConfig,
    dev: &DeviceProfile,
    par: Parallelism,
    seq: u64,
    batch: u64,
    kv_cache: bool,
) -> InferenceTime {
    let per = time_per_token(llm, dev, par, seq, batch, kv_cache);
    InferenceTime {
        compute: per.compute * seq as f64,
        memory: per.memory * seq as f64,
        comm: per.comm * seq as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::device::DeviceProfile;
    use crate::llm::models::all_llms;

    fn gpt3() -> LlmConfig {
        all_llms().into_iter().find(|m| m.name == "gpt3-175B").unwrap()
    }

    #[test]
    fn cache_beats_nocache_at_long_seq() {
        let m = gpt3();
        let dev = DeviceProfile::host_cache();
        let par = Parallelism { dp: 1, tp: 16, pp: 1 };
        let with = sequence_time(&m, &dev, par, 32_768, 1, true).total();
        let par_pp = Parallelism { dp: 1, tp: 1, pp: 16 };
        let without = sequence_time(&m, &DeviceProfile::host_nocache(), par_pp, 32_768, 1, false).total();
        assert!(without / with > 50.0, "cache gain {}", without / with);
    }

    #[test]
    fn time_grows_with_sequence() {
        let m = gpt3();
        let dev = DeviceProfile::dockerssd();
        let par = Parallelism { dp: 1, tp: 8, pp: 1 };
        let t1 = sequence_time(&m, &dev, par, 1024, 1, true).total();
        let t2 = sequence_time(&m, &dev, par, 4096, 1, true).total();
        assert!(t2 > t1);
    }

    #[test]
    fn memory_capacity_grows_with_kv() {
        let m = gpt3();
        let dev = DeviceProfile::dockerssd();
        let par = Parallelism { dp: 1, tp: 4, pp: 4 };
        let no_kv = bytes_per_device(&m, &dev, par, 32_768, 1, false);
        let kv = bytes_per_device(&m, &dev, par, 32_768, 1, true);
        assert!(kv > no_kv);
        // KV at 32K for a 175B model is substantial
        assert!(kv - no_kv > 1e9);
    }

    #[test]
    fn tp_reduces_per_token_compute() {
        let m = gpt3();
        let dev = DeviceProfile::dockerssd();
        let t1 = time_per_token(&m, &dev, Parallelism { dp: 1, tp: 1, pp: 1 }, 1024, 1, true);
        let t8 = time_per_token(&m, &dev, Parallelism { dp: 1, tp: 8, pp: 1 }, 1024, 1, true);
        assert!(t8.compute < t1.compute);
        assert!(t8.comm > t1.comm, "tp adds all-reduce traffic");
    }

    #[test]
    fn pp_does_not_speed_up_cached_decode() {
        // serial dependency chain: pp divides capacity, not latency
        let m = gpt3();
        let dev = DeviceProfile::dockerssd();
        let t1 = time_per_token(&m, &dev, Parallelism { dp: 1, tp: 1, pp: 1 }, 1024, 1, true);
        let t8 = time_per_token(&m, &dev, Parallelism { dp: 1, tp: 1, pp: 8 }, 1024, 1, true);
        assert!(t8.compute >= t1.compute * 0.99);
    }

    #[test]
    fn pp_divides_nocache_recompute() {
        let m = gpt3();
        let dev = DeviceProfile::host_nocache();
        let t1 = time_per_token(&m, &dev, Parallelism { dp: 1, tp: 1, pp: 1 }, 8192, 1, false);
        let t8 = time_per_token(&m, &dev, Parallelism { dp: 1, tp: 1, pp: 8 }, 8192, 1, false);
        assert!(t8.compute < t1.compute / 4.0);
    }
}
