//! The eight evaluated LLMs (paper: lamda-137B ... megatron-1T), with
//! public layer geometries.  FLOP and KV-cache math uses the analytic
//! dense parameter count 12 L d^2 so inter-model ratios track geometry.

/// One model configuration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LlmConfig {
    pub name: &'static str,
    /// Headline parameter count (for reporting).
    pub headline_params_b: u64,
    pub layers: u32,
    pub d_model: u32,
    pub heads: u32,
}

impl LlmConfig {
    /// Analytic dense transformer parameters: 12 L d^2 (attention 4d^2 +
    /// FFN 8d^2 per layer).
    pub fn dense_params(&self) -> u64 {
        12 * self.layers as u64 * (self.d_model as u64).pow(2)
    }

    /// KV-cache bytes for (seq, batch) at `bytes_per_elem`.
    pub fn kv_bytes(&self, seq: u64, batch: u64, bytes_per_elem: f64) -> f64 {
        self.layers as f64 * seq as f64 * 2.0 * self.d_model as f64 * batch as f64 * bytes_per_elem
    }
}

/// All eight models of Figure 12, in paper order.
pub fn all_llms() -> Vec<LlmConfig> {
    vec![
        LlmConfig { name: "lamda-137B", headline_params_b: 137, layers: 64, d_model: 8192, heads: 128 },
        LlmConfig { name: "gpt3-175B", headline_params_b: 175, layers: 96, d_model: 12288, heads: 96 },
        LlmConfig { name: "jurassic-178B", headline_params_b: 178, layers: 76, d_model: 13824, heads: 96 },
        LlmConfig { name: "pangu-200B", headline_params_b: 200, layers: 64, d_model: 16384, heads: 128 },
        LlmConfig { name: "gopher-280B", headline_params_b: 280, layers: 80, d_model: 16384, heads: 128 },
        LlmConfig { name: "turing-530B", headline_params_b: 530, layers: 105, d_model: 20480, heads: 128 },
        LlmConfig { name: "palm-540B", headline_params_b: 540, layers: 118, d_model: 18432, heads: 48 },
        LlmConfig { name: "megatron-1T", headline_params_b: 1000, layers: 128, d_model: 25600, heads: 160 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_models_in_order() {
        let ms = all_llms();
        assert_eq!(ms.len(), 8);
        assert_eq!(ms[0].name, "lamda-137B");
        assert_eq!(ms[7].name, "megatron-1T");
    }

    #[test]
    fn headline_params_increase_monotonically() {
        let ms = all_llms();
        for pair in ms.windows(2) {
            assert!(pair[1].headline_params_b >= pair[0].headline_params_b);
        }
    }

    #[test]
    fn gpt3_dense_params_near_headline() {
        let gpt3 = all_llms().into_iter().find(|m| m.name == "gpt3-175B").unwrap();
        let dense = gpt3.dense_params() as f64 / 1e9;
        assert!((150.0..200.0).contains(&dense), "gpt3 dense {dense}B");
    }

    #[test]
    fn megatron_dense_params_near_1t() {
        let mt = all_llms().into_iter().find(|m| m.name == "megatron-1T").unwrap();
        let dense = mt.dense_params() as f64 / 1e12;
        assert!((0.8..1.2).contains(&dense), "megatron dense {dense}T");
    }

    #[test]
    fn kv_bytes_scale_linearly() {
        let m = all_llms().remove(0);
        let a = m.kv_bytes(1024, 1, 2.0);
        assert_eq!(m.kv_bytes(2048, 1, 2.0), 2.0 * a);
        assert_eq!(m.kv_bytes(1024, 4, 2.0), 4.0 * a);
    }
}
