//! The four resource-disaggregation scenarios of Figure 12 and the
//! sensitivity sweeps of Figure 13.

use super::device::DeviceProfile;
use super::models::{all_llms, LlmConfig};
use super::parallelism::{find_optimal, OptimalChoice};
use super::InferenceTime;

/// The disaggregation models (paper: H-NoCache, H-Cache, D-NoCache,
/// D-Cache).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DisaggModel {
    HostNoCache,
    HostCache,
    DockerNoCache,
    DockerCache,
}

impl DisaggModel {
    pub const ALL: [DisaggModel; 4] = [
        DisaggModel::HostNoCache,
        DisaggModel::HostCache,
        DisaggModel::DockerNoCache,
        DisaggModel::DockerCache,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            DisaggModel::HostNoCache => "H-NoCache",
            DisaggModel::HostCache => "H-Cache",
            DisaggModel::DockerNoCache => "D-NoCache",
            DisaggModel::DockerCache => "D-Cache",
        }
    }

    pub fn device(&self) -> DeviceProfile {
        match self {
            DisaggModel::HostNoCache => DeviceProfile::host_nocache(),
            DisaggModel::HostCache => DeviceProfile::host_cache(),
            DisaggModel::DockerNoCache => DeviceProfile::dockerssd_nocache(),
            DisaggModel::DockerCache => DeviceProfile::dockerssd(),
        }
    }

    pub fn kv_cache(&self) -> bool {
        matches!(self, DisaggModel::HostCache | DisaggModel::DockerCache)
    }
}

/// One evaluated scenario (Fig 12 cell).
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub model: &'static str,
    pub disagg: DisaggModel,
    pub nodes: u32,
    pub choice: OptimalChoice,
}

impl ScenarioResult {
    pub fn time(&self) -> &InferenceTime {
        &self.choice.time
    }
}

/// Node-pool size per model: the paper scales 16..128 DockerSSDs with
/// model size ("evaluated using storage pools composed of 16 to 128
/// DockerSSDs").  We double nodes every two models.
pub fn nodes_for(model_idx: usize) -> u32 {
    16 << (model_idx / 2).min(3)
}

/// Evaluate one (model, disagg) scenario at the paper's default 32K
/// sequence, batch 1 per data-parallel replica.
pub fn evaluate_scenario(
    llm: &LlmConfig,
    disagg: DisaggModel,
    nodes: u32,
    seq: u64,
    batch: u64,
) -> Option<ScenarioResult> {
    let dev = disagg.device();
    let choice = find_optimal(llm, &dev, nodes, seq, batch, disagg.kv_cache())?;
    Some(ScenarioResult {
        model: llm.name,
        disagg,
        nodes,
        choice,
    })
}

/// Figure 12 sweep: all 8 models x 4 disaggregation scenarios at 32K/1.
pub fn fig12_sweep(seq: u64, batch: u64) -> Vec<ScenarioResult> {
    let mut out = Vec::new();
    for (i, llm) in all_llms().iter().enumerate() {
        let nodes = nodes_for(i);
        for d in DisaggModel::ALL {
            if let Some(r) = evaluate_scenario(llm, d, nodes, seq, batch) {
                out.push(r);
            }
        }
    }
    out
}

/// Geometric-mean ratio of total inference time between two disaggregation
/// models across all 8 LLMs (the paper's aggregate claims).
pub fn aggregate_ratio(a: DisaggModel, b: DisaggModel, seq: u64, batch: u64) -> f64 {
    let mut log_sum = 0.0;
    let mut n = 0;
    for (i, llm) in all_llms().iter().enumerate() {
        let nodes = nodes_for(i);
        let (Some(ra), Some(rb)) = (
            evaluate_scenario(llm, a, nodes, seq, batch),
            evaluate_scenario(llm, b, nodes, seq, batch),
        ) else {
            continue;
        };
        log_sum += (ra.time().total() / rb.time().total()).ln();
        n += 1;
    }
    assert!(n > 0, "no feasible scenario pair");
    (log_sum / n as f64).exp()
}

/// Figure 13a/b: D-Cache speedup over H-Cache across sequence lengths for
/// one model.  Returns (seq, speedup) points.
pub fn seq_sweep(llm: &LlmConfig, nodes: u32, seqs: &[u64], batch: u64) -> Vec<(u64, f64)> {
    seqs.iter()
        .filter_map(|&s| {
            let h = evaluate_scenario(llm, DisaggModel::HostCache, nodes, s, batch)?;
            let d = evaluate_scenario(llm, DisaggModel::DockerCache, nodes, s, batch)?;
            Some((s, h.time().total() / d.time().total()))
        })
        .collect()
}

/// Figure 13c/d: batch-size sweep at fixed sequence length.
pub fn batch_sweep(llm: &LlmConfig, nodes: u32, seq: u64, batches: &[u64]) -> Vec<(u64, f64)> {
    batches
        .iter()
        .filter_map(|&b| {
            let h = evaluate_scenario(llm, DisaggModel::HostCache, nodes, seq, b)?;
            let d = evaluate_scenario(llm, DisaggModel::DockerCache, nodes, seq, b)?;
            Some((b, h.time().total() / d.time().total()))
        })
        .collect()
}

/// The crossover sequence length where D-Cache starts beating H-Cache.
pub fn crossover_seq(llm: &LlmConfig, nodes: u32) -> Option<u64> {
    let seqs: Vec<u64> = (4..=17).map(|p| 1u64 << p).collect();
    for (s, speedup) in seq_sweep(llm, nodes, &seqs, 1) {
        if speedup >= 1.0 {
            return Some(s);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_models_have_names() {
        let names: Vec<&str> = DisaggModel::ALL.iter().map(|d| d.name()).collect();
        assert_eq!(names, vec!["H-NoCache", "H-Cache", "D-NoCache", "D-Cache"]);
    }

    #[test]
    fn node_scaling_16_to_128() {
        assert_eq!(nodes_for(0), 16);
        assert_eq!(nodes_for(2), 32);
        assert_eq!(nodes_for(4), 64);
        assert_eq!(nodes_for(6), 128);
        assert_eq!(nodes_for(7), 128);
    }

    #[test]
    fn fig12_sweep_covers_feasible_scenarios() {
        let rs = fig12_sweep(32_768, 1);
        // 8 models x 4 scenarios, minus any infeasible combinations
        assert!(rs.len() >= 24, "only {} scenarios feasible", rs.len());
    }

    #[test]
    fn cache_dominates_nocache() {
        let r = aggregate_ratio(DisaggModel::HostNoCache, DisaggModel::HostCache, 32_768, 1);
        assert!(r > 10.0, "H-NoCache/H-Cache = {r}");
        let r = aggregate_ratio(DisaggModel::DockerNoCache, DisaggModel::DockerCache, 32_768, 1);
        assert!(r > 10.0, "D-NoCache/D-Cache = {r}");
    }

    #[test]
    fn dcache_beats_hcache_at_32k() {
        let r = aggregate_ratio(DisaggModel::HostCache, DisaggModel::DockerCache, 32_768, 1);
        assert!(r > 1.0, "H-Cache/D-Cache = {r}");
    }

    #[test]
    fn dnocache_slower_than_hnocache() {
        // paper: 1.7x degradation from slower silicon
        let r = aggregate_ratio(DisaggModel::DockerNoCache, DisaggModel::HostNoCache, 32_768, 1);
        assert!((1.2..2.4).contains(&r), "D-NoCache/H-NoCache = {r}");
    }

    #[test]
    fn speedup_grows_with_sequence() {
        let llm = all_llms().remove(0);
        let pts = seq_sweep(&llm, 16, &[256, 1024, 8192, 65_536], 1);
        assert!(pts.len() >= 3);
        for pair in pts.windows(2) {
            assert!(pair[1].1 >= pair[0].1 * 0.95, "{pts:?}");
        }
    }

    #[test]
    fn crossover_exists_for_smallest_model() {
        let llm = all_llms().remove(0);
        let x = crossover_seq(&llm, 16);
        assert!(x.is_some(), "no crossover found");
    }
}
