//! The four resource-disaggregation scenarios of Figure 12 and the
//! sensitivity sweeps of Figure 13.
//!
//! The analytic model (`time_per_token`) prices communication against a
//! private per-device link; [`step_traffic`] + [`pool_step_time`]
//! instead route one decode step's KV/activation movement through the
//! shared [`Fabric`], so collectives contend with layer fetches,
//! dispatch, and other tenants on the same array/tray/uplink queues.

use super::device::DeviceProfile;
use super::models::{all_llms, LlmConfig};
use super::parallelism::{find_optimal, OptimalChoice, Parallelism};
use super::InferenceTime;
use crate::fabric::{Endpoint, Fabric, Priority, TransferId, DEFAULT_QUANTUM, KV_STREAM_CLASS};
use crate::pool::topology::NodeId;
use crate::util::SimTime;

/// The disaggregation models (paper: H-NoCache, H-Cache, D-NoCache,
/// D-Cache).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DisaggModel {
    HostNoCache,
    HostCache,
    DockerNoCache,
    DockerCache,
}

impl DisaggModel {
    pub const ALL: [DisaggModel; 4] = [
        DisaggModel::HostNoCache,
        DisaggModel::HostCache,
        DisaggModel::DockerNoCache,
        DisaggModel::DockerCache,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            DisaggModel::HostNoCache => "H-NoCache",
            DisaggModel::HostCache => "H-Cache",
            DisaggModel::DockerNoCache => "D-NoCache",
            DisaggModel::DockerCache => "D-Cache",
        }
    }

    pub fn device(&self) -> DeviceProfile {
        match self {
            DisaggModel::HostNoCache => DeviceProfile::host_nocache(),
            DisaggModel::HostCache => DeviceProfile::host_cache(),
            DisaggModel::DockerNoCache => DeviceProfile::dockerssd_nocache(),
            DisaggModel::DockerCache => DeviceProfile::dockerssd(),
        }
    }

    pub fn kv_cache(&self) -> bool {
        matches!(self, DisaggModel::HostCache | DisaggModel::DockerCache)
    }
}

/// One evaluated scenario (Fig 12 cell).
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub model: &'static str,
    pub disagg: DisaggModel,
    pub nodes: u32,
    pub choice: OptimalChoice,
}

impl ScenarioResult {
    pub fn time(&self) -> &InferenceTime {
        &self.choice.time
    }
}

/// Node-pool size per model: the paper scales 16..128 DockerSSDs with
/// model size ("evaluated using storage pools composed of 16 to 128
/// DockerSSDs").  We double nodes every two models.
pub fn nodes_for(model_idx: usize) -> u32 {
    16 << (model_idx / 2).min(3)
}

/// Evaluate one (model, disagg) scenario at the paper's default 32K
/// sequence, batch 1 per data-parallel replica.
pub fn evaluate_scenario(
    llm: &LlmConfig,
    disagg: DisaggModel,
    nodes: u32,
    seq: u64,
    batch: u64,
) -> Option<ScenarioResult> {
    let dev = disagg.device();
    let choice = find_optimal(llm, &dev, nodes, seq, batch, disagg.kv_cache())?;
    Some(ScenarioResult {
        model: llm.name,
        disagg,
        nodes,
        choice,
    })
}

/// Figure 12 sweep: all 8 models x 4 disaggregation scenarios at 32K/1.
pub fn fig12_sweep(seq: u64, batch: u64) -> Vec<ScenarioResult> {
    let mut out = Vec::new();
    for (i, llm) in all_llms().iter().enumerate() {
        let nodes = nodes_for(i);
        for d in DisaggModel::ALL {
            if let Some(r) = evaluate_scenario(llm, d, nodes, seq, batch) {
                out.push(r);
            }
        }
    }
    out
}

/// Geometric-mean ratio of total inference time between two disaggregation
/// models across all 8 LLMs (the paper's aggregate claims).
pub fn aggregate_ratio(a: DisaggModel, b: DisaggModel, seq: u64, batch: u64) -> f64 {
    let mut log_sum = 0.0;
    let mut n = 0;
    for (i, llm) in all_llms().iter().enumerate() {
        let nodes = nodes_for(i);
        let (Some(ra), Some(rb)) = (
            evaluate_scenario(llm, a, nodes, seq, batch),
            evaluate_scenario(llm, b, nodes, seq, batch),
        ) else {
            continue;
        };
        log_sum += (ra.time().total() / rb.time().total()).ln();
        n += 1;
    }
    assert!(n > 0, "no feasible scenario pair");
    (log_sum / n as f64).exp()
}

/// Figure 13a/b: D-Cache speedup over H-Cache across sequence lengths for
/// one model.  Returns (seq, speedup) points.
pub fn seq_sweep(llm: &LlmConfig, nodes: u32, seqs: &[u64], batch: u64) -> Vec<(u64, f64)> {
    seqs.iter()
        .filter_map(|&s| {
            let h = evaluate_scenario(llm, DisaggModel::HostCache, nodes, s, batch)?;
            let d = evaluate_scenario(llm, DisaggModel::DockerCache, nodes, s, batch)?;
            Some((s, h.time().total() / d.time().total()))
        })
        .collect()
}

/// Figure 13c/d: batch-size sweep at fixed sequence length.
pub fn batch_sweep(llm: &LlmConfig, nodes: u32, seq: u64, batches: &[u64]) -> Vec<(u64, f64)> {
    batches
        .iter()
        .filter_map(|&b| {
            let h = evaluate_scenario(llm, DisaggModel::HostCache, nodes, seq, b)?;
            let d = evaluate_scenario(llm, DisaggModel::DockerCache, nodes, seq, b)?;
            Some((b, h.time().total() / d.time().total()))
        })
        .collect()
}

/// One decode step's cross-node traffic for a chosen parallelism,
/// assuming global rank `r` lives on pool node `r` (the orchestrator's
/// packed placement): data-parallel replica `k` occupies the node range
/// `[k*tp*pp, (k+1)*tp*pp)` and every replica's traffic is emitted —
/// they all contend on the shared fabric.  Mirrors the analytic comm
/// model of [`crate::llm::time_per_token`]: tensor parallelism is a
/// ring step per all-reduce (2 per layer, folded into one per-rank
/// volume), pipeline parallelism is a per-boundary activation hop.
/// With `host_coordinated` (the H-* scenarios) each replica's step also
/// round-trips the sampled token's activations over the host uplink.
pub fn step_traffic(
    llm: &LlmConfig,
    par: Parallelism,
    seq: u64,
    batch: u64,
    kv_cache: bool,
    host_coordinated: bool,
) -> Vec<(Endpoint, Endpoint, u64)> {
    let d = llm.d_model as f64;
    let l = llm.layers as f64;
    let b_local = (batch as f64 / par.dp as f64).max(1.0);
    let prefix = (seq as f64 / 2.0).max(1.0);
    let group = par.tp * par.pp;
    let mut out = Vec::new();
    for k in 0..par.dp {
        let base = k * group;
        if par.tp > 1 {
            let positions = if kv_cache { 1.0 } else { prefix };
            let per_rank = (2.0 * l * positions * b_local * d * 2.0
                * ((par.tp - 1) as f64 / par.tp as f64)) as u64;
            for r in 0..par.tp {
                let from = (base + r) as NodeId;
                let to = (base + (r + 1) % par.tp) as NodeId;
                out.push((Endpoint::Node(from), Endpoint::Node(to), per_rank));
            }
        }
        if par.pp > 1 {
            let act = (b_local * d * 2.0) as u64;
            for s in 0..par.pp - 1 {
                let from = (base + s * par.tp + par.tp - 1) as NodeId;
                let to = (base + (s + 1) * par.tp) as NodeId;
                out.push((Endpoint::Node(from), Endpoint::Node(to), act));
            }
        }
        if host_coordinated {
            let act = (b_local * d * 2.0) as u64;
            let last = (base + group - 1) as NodeId;
            out.push((Endpoint::Node(last), Endpoint::Host, act));
            out.push((Endpoint::Host, Endpoint::Node(base as NodeId), act));
        }
    }
    out
}

/// Route one decode step's traffic through the shared fabric at `now`;
/// returns the step's communication makespan (last byte landed minus
/// `now`).  The fabric keeps its queue state, so a second tenant issuing
/// its step at the same instant sees the congestion the first created.
pub fn pool_step_time(
    fabric: &mut Fabric,
    now: SimTime,
    traffic: &[(Endpoint, Endpoint, u64)],
) -> SimTime {
    let mut finish = now;
    for &(from, to, bytes) in traffic {
        let r = fabric.transfer(now, from, to, bytes, Priority::Foreground);
        finish = finish.max(r.finish);
    }
    finish.saturating_sub(now)
}

/// Schedule one decode step's traffic on the fabric's *event-driven
/// engine* (see [`Fabric::schedule`]) instead of resolving it
/// synchronously: the step's transfers become arrival events on the
/// shared clock, interleaving — and being re-timed — against docker
/// pulls, KV migrations, and background layer prefetch already in
/// flight on the same wires.  Resolve the receipts after
/// [`Fabric::advance_to`]/[`Fabric::run_to_idle`].
pub fn schedule_step(
    fabric: &mut Fabric,
    now: SimTime,
    traffic: &[(Endpoint, Endpoint, u64)],
) -> Vec<TransferId> {
    traffic
        .iter()
        .map(|&(from, to, bytes)| fabric.schedule(now, from, to, bytes, Priority::Foreground))
        .collect()
}

/// One prefill→decode KV handoff priced on the shared fabric, as seen
/// by the decode side.  All times are makespans from the issue instant.
#[derive(Clone, Debug)]
pub struct HandoffReceipt {
    pub bytes: u64,
    /// Chunk quanta the handoff was pipelined into.
    pub quanta: u64,
    /// Last KV byte landed.
    pub wire: SimTime,
    /// Decode consuming quantum `i` while quantum `i+1` is in flight —
    /// the pipelined shape ([`crate::fabric::StreamReceipt::pipelined_finish`]).
    pub effective: SimTime,
    /// The unpipelined shape: decode starts only after the last byte.
    pub serial: SimTime,
}

impl HandoffReceipt {
    /// How much the pipeline shrank the handoff+decode critical path.
    pub fn speedup(&self) -> f64 {
        self.serial.as_ns() as f64 / self.effective.as_ns().max(1) as f64
    }
}

/// The prefill→decode KV handoff of one disaggregated generation turn:
/// replica `k`'s prompt KV moves from its last prefill rank
/// (`base + group - 1`, the rank that finished the prefix — the same
/// packed-placement simplification as [`step_traffic`]) to its first
/// decode rank (`base`).  For the D-* scenarios that is one direct
/// node-to-node leg; with `host_coordinated` (the H-* scenarios) the KV
/// round-trips through the host instead, paying the uplink twice.
pub fn handoff_traffic(
    llm: &LlmConfig,
    par: Parallelism,
    seq: u64,
    batch: u64,
    host_coordinated: bool,
) -> Vec<(Endpoint, Endpoint, u64)> {
    let b_local = ((batch as f64 / par.dp as f64).max(1.0)) as u64;
    let group = par.tp * par.pp;
    let mut out = Vec::new();
    for k in 0..par.dp {
        let base = k * group;
        let last = (base + group - 1) as NodeId;
        let bytes = llm.kv_bytes(seq, b_local, 2.0) as u64;
        if host_coordinated {
            out.push((Endpoint::Node(last), Endpoint::Host, bytes));
            out.push((Endpoint::Host, Endpoint::Node(base as NodeId), bytes));
        } else {
            out.push((Endpoint::Node(last), Endpoint::Node(base as NodeId), bytes));
        }
    }
    out
}

/// Carry each handoff leg as a pipelined stream of [`DEFAULT_QUANTUM`]
/// chunk quanta on the [`KV_STREAM_CLASS`] WFQ class, and price the
/// decode side both ways: `effective` overlaps decoding quantum `i`
/// with the fetch of quantum `i+1` (`decode_step` of compute per
/// quantum), `serial` waits for the last byte.  The overlap between the
/// two is the step-time reduction the fig12/13 extension reports.
pub fn stream_handoffs(
    fabric: &mut Fabric,
    now: SimTime,
    traffic: &[(Endpoint, Endpoint, u64)],
    decode_step: SimTime,
) -> Vec<HandoffReceipt> {
    traffic
        .iter()
        .map(|&(from, to, bytes)| {
            let h = fabric.stream(now, from, to, bytes, DEFAULT_QUANTUM, KV_STREAM_CLASS);
            let r = fabric.settle_stream(&h);
            HandoffReceipt {
                bytes,
                quanta: r.quanta,
                wire: r.finish.saturating_sub(now),
                effective: r.pipelined_finish(decode_step).saturating_sub(now),
                serial: r.serial_finish(decode_step).saturating_sub(now),
            }
        })
        .collect()
}

/// Re-price a scenario's communication on the shared fabric: compute
/// and memory come from the analytic model, but `comm` becomes the time
/// the fabric actually granted one step's traffic (scaled to the full
/// generation).  Under contention this is strictly slower than the
/// idle-wire analytic figure — the gap *is* the congestion.
pub fn pool_adjusted_time(
    fabric: &mut Fabric,
    r: &ScenarioResult,
    llm: &LlmConfig,
    seq: u64,
    batch: u64,
) -> InferenceTime {
    let host = matches!(r.disagg, DisaggModel::HostNoCache | DisaggModel::HostCache);
    let traffic = step_traffic(llm, r.choice.par, seq, batch, r.disagg.kv_cache(), host);
    let step = pool_step_time(fabric, SimTime::ZERO, &traffic);
    InferenceTime {
        compute: r.time().compute,
        memory: r.time().memory,
        comm: step.as_secs_f64() * seq as f64,
    }
}

/// The crossover sequence length where D-Cache starts beating H-Cache.
pub fn crossover_seq(llm: &LlmConfig, nodes: u32) -> Option<u64> {
    let seqs: Vec<u64> = (4..=17).map(|p| 1u64 << p).collect();
    for (s, speedup) in seq_sweep(llm, nodes, &seqs, 1) {
        if speedup >= 1.0 {
            return Some(s);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_models_have_names() {
        let names: Vec<&str> = DisaggModel::ALL.iter().map(|d| d.name()).collect();
        assert_eq!(names, vec!["H-NoCache", "H-Cache", "D-NoCache", "D-Cache"]);
    }

    #[test]
    fn node_scaling_16_to_128() {
        assert_eq!(nodes_for(0), 16);
        assert_eq!(nodes_for(2), 32);
        assert_eq!(nodes_for(4), 64);
        assert_eq!(nodes_for(6), 128);
        assert_eq!(nodes_for(7), 128);
    }

    #[test]
    fn fig12_sweep_covers_feasible_scenarios() {
        let rs = fig12_sweep(32_768, 1);
        // 8 models x 4 scenarios, minus any infeasible combinations
        assert!(rs.len() >= 24, "only {} scenarios feasible", rs.len());
    }

    #[test]
    fn cache_dominates_nocache() {
        let r = aggregate_ratio(DisaggModel::HostNoCache, DisaggModel::HostCache, 32_768, 1);
        assert!(r > 10.0, "H-NoCache/H-Cache = {r}");
        let r = aggregate_ratio(DisaggModel::DockerNoCache, DisaggModel::DockerCache, 32_768, 1);
        assert!(r > 10.0, "D-NoCache/D-Cache = {r}");
    }

    #[test]
    fn dcache_beats_hcache_at_32k() {
        let r = aggregate_ratio(DisaggModel::HostCache, DisaggModel::DockerCache, 32_768, 1);
        assert!(r > 1.0, "H-Cache/D-Cache = {r}");
    }

    #[test]
    fn dnocache_slower_than_hnocache() {
        // paper: 1.7x degradation from slower silicon
        let r = aggregate_ratio(DisaggModel::DockerNoCache, DisaggModel::HostNoCache, 32_768, 1);
        assert!((1.2..2.4).contains(&r), "D-NoCache/H-NoCache = {r}");
    }

    #[test]
    fn speedup_grows_with_sequence() {
        let llm = all_llms().remove(0);
        let pts = seq_sweep(&llm, 16, &[256, 1024, 8192, 65_536], 1);
        assert!(pts.len() >= 3);
        for pair in pts.windows(2) {
            assert!(pair[1].1 >= pair[0].1 * 0.95, "{pts:?}");
        }
    }

    #[test]
    fn crossover_exists_for_smallest_model() {
        let llm = all_llms().remove(0);
        let x = crossover_seq(&llm, 16);
        assert!(x.is_some(), "no crossover found");
    }

    fn fabric16() -> Fabric {
        use crate::config::{EtherOnConfig, PoolConfig};
        Fabric::new(
            &PoolConfig {
                nodes_per_array: 16,
                arrays: 1,
                ..Default::default()
            },
            &EtherOnConfig::default(),
        )
    }

    #[test]
    fn serial_parallelism_moves_no_bytes() {
        let llm = all_llms().remove(0);
        let par = Parallelism { dp: 1, tp: 1, pp: 1 };
        assert!(step_traffic(&llm, par, 1024, 1, true, false).is_empty());
    }

    #[test]
    fn data_parallel_replicas_all_emit_traffic() {
        let llm = all_llms().remove(0);
        let par = Parallelism { dp: 4, tp: 2, pp: 1 };
        let traffic = step_traffic(&llm, par, 1024, 4, true, false);
        assert_eq!(traffic.len(), 8, "4 replicas x 2-rank rings");
        // replica 3's ring lives on nodes 6 and 7, not on replica 0's
        assert!(traffic.iter().any(|(f, _, _)| *f == Endpoint::Node(6)));
        assert!(traffic.iter().any(|(f, _, _)| *f == Endpoint::Node(7)));
    }

    #[test]
    fn tensor_parallel_steps_contend_between_tenants() {
        let llm = all_llms().remove(0);
        let par = Parallelism { dp: 1, tp: 8, pp: 1 };
        let traffic = step_traffic(&llm, par, 32_768, 1, true, false);
        assert_eq!(traffic.len(), 8, "one ring send per tp rank");
        let mut f = fabric16();
        let alone = pool_step_time(&mut f, SimTime::ZERO, &traffic);
        assert!(alone > SimTime::ZERO);
        // a second tenant issuing the same step at the same instant
        // queues behind the first on the shared array backplane
        let contended = pool_step_time(&mut f, SimTime::ZERO, &traffic);
        assert!(contended > alone, "{contended} !> {alone}");
    }

    #[test]
    fn host_coordinated_steps_cross_the_host_uplink() {
        use crate::metrics::{names, Counters};
        let llm = all_llms().remove(0);
        let par = Parallelism { dp: 1, tp: 4, pp: 1 };
        let traffic = step_traffic(&llm, par, 1024, 1, true, true);
        let mut f = fabric16();
        pool_step_time(&mut f, SimTime::ZERO, &traffic);
        let mut c = Counters::new();
        f.export_counters(&mut c);
        assert!(c.get(names::FABRIC_BYTES_HOST_UPLINK) > 0);
        assert!(c.get(names::FABRIC_BYTES_ARRAY) > 0);
    }

    #[test]
    fn pipelined_handoff_overlaps_decode_with_fetch() {
        use crate::metrics::{names, Counters};
        let llm = all_llms().remove(0);
        let par = Parallelism { dp: 1, tp: 4, pp: 1 };
        // a 64-token prefix of the 137B model is ~128MiB of KV —
        // hundreds of chunk quanta
        let traffic = handoff_traffic(&llm, par, 64, 1, false);
        assert_eq!(traffic.len(), 1, "one direct leg per replica");
        let mut f = fabric16();
        let rs = stream_handoffs(&mut f, SimTime::ZERO, &traffic, SimTime::us(50));
        let r = &rs[0];
        assert!(r.quanta > 1);
        assert!(r.wire > SimTime::ZERO);
        assert!(r.effective < r.serial, "pipelining must shrink the critical path");
        assert!(r.speedup() > 1.0);
        let mut c = Counters::new();
        f.export_counters(&mut c);
        assert_eq!(c.get(names::FABRIC_BYTES_HOST_UPLINK), 0, "D-* handoff stays in the pool");
        assert_eq!(c.get(names::FABRIC_BYTES_P2P), r.bytes);
        assert_eq!(c.get(names::FABRIC_STREAM_QUANTA), r.quanta);
    }

    #[test]
    fn host_coordinated_handoff_pays_the_uplink_twice() {
        use crate::metrics::{names, Counters};
        let llm = all_llms().remove(0);
        let par = Parallelism { dp: 2, tp: 2, pp: 1 };
        let traffic = handoff_traffic(&llm, par, 64, 2, true);
        assert_eq!(traffic.len(), 4, "two replicas x two host legs each");
        let mut f = fabric16();
        let rs = stream_handoffs(&mut f, SimTime::ZERO, &traffic, SimTime::us(50));
        let total: u64 = rs.iter().map(|r| r.bytes).sum();
        let mut c = Counters::new();
        f.export_counters(&mut c);
        assert_eq!(c.get(names::FABRIC_BYTES_HOST_UPLINK), total, "KV rides the uplink twice");
        assert_eq!(c.get(names::FABRIC_BYTES_P2P), 0, "host legs are not peer streams");
    }

    #[test]
    fn scheduled_step_retimes_an_inflight_prefetch() {
        let llm = all_llms().remove(0);
        let par = Parallelism { dp: 1, tp: 8, pp: 1 };
        let traffic = step_traffic(&llm, par, 32_768, 1, true, false);
        // alone on an idle engine
        let mut fa = fabric16();
        let ids = schedule_step(&mut fa, SimTime::ZERO, &traffic);
        fa.run_to_idle();
        let alone: SimTime = ids.iter().map(|&i| fa.receipt_of(i).unwrap().finish).max().unwrap();
        // behind a large background layer prefetch on the same array
        let mut fb = fabric16();
        let optimistic = fb.estimate(Endpoint::Node(8), Endpoint::Node(9), 64 << 20);
        let bg = fb.schedule(
            SimTime::ZERO,
            Endpoint::Node(8),
            Endpoint::Node(9),
            64 << 20,
            Priority::Background,
        );
        let ids = schedule_step(&mut fb, SimTime::us(100), &traffic);
        fb.run_to_idle();
        let mixed: SimTime = ids.iter().map(|&i| fb.receipt_of(i).unwrap().finish).max().unwrap();
        assert!(mixed > alone, "sharing the wire cannot be free: {mixed} vs {alone}");
        assert!(
            fb.receipt_of(bg).unwrap().finish > optimistic,
            "the collective step re-times the prefetch instead of leaving its receipt optimistic"
        );
        assert!(fb.stats.retimed_transfers >= 1);
    }

    #[test]
    fn pool_adjustment_only_reprices_comm() {
        let llm = all_llms().remove(0);
        let r = evaluate_scenario(&llm, DisaggModel::DockerCache, 16, 32_768, 1).unwrap();
        let mut f = fabric16();
        let adjusted = pool_adjusted_time(&mut f, &r, &llm, 32_768, 1);
        assert_eq!(adjusted.compute, r.time().compute);
        assert_eq!(adjusted.memory, r.time().memory);
        assert!(adjusted.comm >= 0.0);
    }
}
