//! Shared primitives: simulated time, deterministic PRNG, byte helpers.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// Simulated time in nanoseconds. All substrate latencies compose in this
/// unit; `as_secs_f64` converts for reporting.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default, Hash)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn ns(n: u64) -> Self {
        SimTime(n)
    }
    pub fn us(n: u64) -> Self {
        SimTime(n * 1_000)
    }
    pub fn ms(n: u64) -> Self {
        SimTime(n * 1_000_000)
    }
    pub fn secs_f64(s: f64) -> Self {
        SimTime((s * 1e9) as u64)
    }
    pub fn as_ns(self) -> u64 {
        self.0
    }
    pub fn as_us_f64(self) -> f64 {
        self.0 as f64 / 1e3
    }
    pub fn as_ms_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }
    pub fn saturating_sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }
    pub fn max(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.max(rhs.0))
    }
    pub fn scale(self, f: f64) -> SimTime {
        SimTime((self.0 as f64 * f) as u64)
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 - rhs.0)
    }
}

impl fmt::Debug for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 1_000_000_000 {
            write!(f, "{:.3}s", self.as_secs_f64())
        } else if self.0 >= 1_000_000 {
            write!(f, "{:.3}ms", self.as_ms_f64())
        } else if self.0 >= 1_000 {
            write!(f, "{:.3}us", self.as_us_f64())
        } else {
            write!(f, "{}ns", self.0)
        }
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

/// SplitMix64: tiny, fast, deterministic PRNG for workload generation.
/// (We avoid the `rand` crate to keep the dependency graph small; the
/// simulator needs reproducibility, not cryptographic quality.)
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`. `bound` must be nonzero.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Skewed pick in `[0, n)` — hot keys for cache behaviour.
    pub fn zipf(&mut self, n: u64, skew: f64) -> u64 {
        let u = self.f64().max(1e-12);
        let x = (n as f64) * u.powf(skew.max(1.0));
        (x as u64).min(n - 1)
    }
}

/// FNV-1a 64-bit hash — content digests for docker blobs.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Human-readable byte size.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut i = 0;
    while v >= 1024.0 && i < UNITS.len() - 1 {
        v /= 1024.0;
        i += 1;
    }
    if i == 0 {
        format!("{}{}", n, UNITS[0])
    } else {
        format!("{:.1}{}", v, UNITS[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simtime_units_compose() {
        assert_eq!(SimTime::us(1), SimTime::ns(1000));
        assert_eq!(SimTime::ms(1), SimTime::us(1000));
        assert_eq!(SimTime::ms(2) + SimTime::us(500), SimTime::us(2500));
        assert!((SimTime::secs_f64(1.5).as_secs_f64() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn simtime_saturating_sub() {
        assert_eq!(SimTime::ns(5).saturating_sub(SimTime::ns(10)), SimTime::ZERO);
        assert_eq!(SimTime::ns(10).saturating_sub(SimTime::ns(4)), SimTime::ns(6));
    }

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn rng_f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn zipf_is_skewed_toward_zero() {
        let mut r = Rng::new(11);
        let mut low = 0u64;
        let n = 100_000;
        for _ in 0..n {
            if r.zipf(1000, 2.0) < 100 {
                low += 1;
            }
        }
        assert!(low > n / 5, "low={low}");
    }

    #[test]
    fn fnv_distinguishes_content() {
        assert_ne!(fnv1a(b"hello"), fnv1a(b"world"));
        assert_eq!(fnv1a(b"same"), fnv1a(b"same"));
    }

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(512), "512B");
        assert_eq!(human_bytes(2048), "2.0KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0MiB");
    }
}
