//! Page-mapped flash translation layer with greedy garbage collection.
//!
//! LBA-page (LPN) -> physical page (PPA) mapping, channel-striped write
//! allocation for parallelism, per-block valid-page bookkeeping, and a
//! greedy (min-valid) GC victim policy — the standard composition the
//! paper's SimpleSSD backend implements.

use crate::config::SsdConfig;
use crate::sim::BusyResource;
use crate::util::SimTime;

/// Physical page address.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Ppa {
    pub channel: u32,
    pub package: u32,
    pub block: u32,
    pub page: u32,
}

impl Ppa {
    pub fn package_index(&self, cfg: &SsdConfig) -> usize {
        (self.channel * cfg.packages_per_channel + self.package) as usize
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct FtlStats {
    pub maps: u64,
    pub remaps: u64,
    pub gc_runs: u64,
    pub gc_relocated_pages: u64,
    /// Pages programmed on behalf of the host (the WAF denominator);
    /// GC relocations go through [`Ftl::map_relocate`] and stay out.
    pub host_pages: u64,
    pub erases: u64,
    /// Highest erase count across all blocks — the wear hotspot.
    pub wear_max: u64,
}

/// What one [`Ftl::write`] cost: the flash economics of a host write,
/// including any GC it forced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WriteReceipt {
    /// Host pages programmed.
    pub pages: u64,
    /// Valid pages GC relocated to make room (the WAF surcharge).
    pub relocated_pages: u64,
    /// Blocks erased by the GC cycles this write triggered.
    pub erased_blocks: u64,
    /// When the device resource frees up.
    pub done: SimTime,
}

/// Per-block state.
#[derive(Clone, Debug)]
struct BlockState {
    /// lpn stored in each page slot (None = free or invalidated).
    slots: Vec<Option<u64>>,
    /// next free page slot (append-only within a block).
    write_ptr: u32,
    valid: u32,
    erased: bool,
    /// Program/erase cycles endured — the block's wear.
    erase_cycles: u32,
}

impl BlockState {
    fn new(pages: u32) -> Self {
        BlockState {
            slots: vec![None; pages as usize],
            write_ptr: 0,
            valid: 0,
            erased: true,
            erase_cycles: 0,
        }
    }

    fn full(&self) -> bool {
        self.write_ptr as usize >= self.slots.len()
    }
}

/// The FTL proper.
pub struct Ftl {
    cfg: SsdConfig,
    /// LPN -> PPA map (sparse).
    map: std::collections::HashMap<u64, Ppa>,
    /// [package][block] state.
    blocks: Vec<Vec<BlockState>>,
    /// Active (open) block per package for write striping.
    open_block: Vec<Option<u32>>,
    /// Round-robin write pointer over packages.
    next_pkg: usize,
    /// Incrementally-maintained count of fresh (erased, unopened) blocks —
    /// O(1) needs_gc() instead of scanning ~100K block states per write
    /// (EXPERIMENTS.md §Perf, L3 iteration 1).
    free_count: usize,
    pub stats: FtlStats,
}

impl Ftl {
    pub fn new(cfg: &SsdConfig) -> Self {
        let npkg = cfg.total_packages() as usize;
        Ftl {
            blocks: (0..npkg)
                .map(|_| {
                    (0..cfg.blocks_per_package)
                        .map(|_| BlockState::new(cfg.pages_per_block))
                        .collect()
                })
                .collect(),
            open_block: vec![None; npkg],
            next_pkg: 0,
            free_count: npkg * cfg.blocks_per_package as usize,
            map: Default::default(),
            cfg: cfg.clone(),
            stats: FtlStats::default(),
        }
    }

    fn pkg_to_ppa(&self, pkg: usize, block: u32, page: u32) -> Ppa {
        let per = self.cfg.packages_per_channel;
        Ppa {
            channel: pkg as u32 / per,
            package: pkg as u32 % per,
            block,
            page,
        }
    }

    /// Total free (erased, unopened) blocks across packages (O(1)).
    pub fn free_blocks(&self) -> usize {
        self.free_count
    }

    #[cfg(test)]
    fn free_blocks_scan(&self) -> usize {
        self.blocks
            .iter()
            .flatten()
            .filter(|b| b.erased && b.write_ptr == 0)
            .count()
    }

    pub fn total_blocks(&self) -> usize {
        self.blocks.iter().map(|p| p.len()).sum()
    }

    pub fn needs_gc(&self) -> bool {
        (self.free_blocks() as f64) < self.cfg.gc_threshold * self.total_blocks() as f64
    }

    /// Translate an LPN, mapping it (as if on first write) when absent.
    pub fn translate_or_map(&mut self, lpn: u64) -> Ppa {
        if let Some(&ppa) = self.map.get(&lpn) {
            return ppa;
        }
        self.map_write(lpn)
    }

    /// Allocate a fresh physical page for (over)writing `lpn` on behalf
    /// of the host, invalidating any previous mapping.  Counted in
    /// `stats.host_pages` (the WAF denominator).
    pub fn map_write(&mut self, lpn: u64) -> Ppa {
        self.stats.host_pages += 1;
        self.remap(lpn)
    }

    /// Allocate a fresh physical page for a GC relocation of `lpn`: the
    /// same striping as [`Self::map_write`] but *not* host traffic, so
    /// WAF = (host + relocated) / host stays honest.
    pub fn map_relocate(&mut self, lpn: u64) -> Ppa {
        self.remap(lpn)
    }

    /// Invalidate `lpn`'s old page and append it to an open block,
    /// round-robin striping across packages to keep channels parallel.
    fn remap(&mut self, lpn: u64) -> Ppa {
        // invalidate old location
        if let Some(old) = self.map.remove(&lpn) {
            let pkg = old.package_index(&self.cfg);
            let b = &mut self.blocks[pkg][old.block as usize];
            if b.slots[old.page as usize] == Some(lpn) {
                b.slots[old.page as usize] = None;
                b.valid -= 1;
            }
            self.stats.remaps += 1;
        } else {
            self.stats.maps += 1;
        }

        let npkg = self.blocks.len();
        for _ in 0..npkg {
            let pkg = self.next_pkg;
            self.next_pkg = (self.next_pkg + 1) % npkg;
            if let Some(ppa) = self.try_append(pkg, lpn) {
                self.map.insert(lpn, ppa);
                return ppa;
            }
        }
        panic!("FTL out of space: no package has a writable block (GC starvation)");
    }

    /// Try appending to `pkg`'s open block, opening a new one if needed.
    fn try_append(&mut self, pkg: usize, lpn: u64) -> Option<Ppa> {
        // close the open block if full
        if let Some(ob) = self.open_block[pkg] {
            if self.blocks[pkg][ob as usize].full() {
                self.open_block[pkg] = None;
            }
        }
        if self.open_block[pkg].is_none() {
            let fresh = self.blocks[pkg]
                .iter()
                .position(|b| b.erased && b.write_ptr == 0)?;
            self.open_block[pkg] = Some(fresh as u32);
            self.blocks[pkg][fresh].erased = false;
            self.free_count -= 1;
        }
        let ob = self.open_block[pkg].unwrap();
        let block = &mut self.blocks[pkg][ob as usize];
        let page = block.write_ptr;
        block.slots[page as usize] = Some(lpn);
        block.write_ptr += 1;
        block.valid += 1;
        Some(self.pkg_to_ppa(pkg, ob, page))
    }

    /// Greedy victim selection: the *closed* block with the fewest valid
    /// pages.  Returns (victim ppa, valid LPNs to relocate).
    pub fn pick_gc_victim(&mut self) -> Option<(Ppa, Vec<u64>)> {
        let mut best: Option<(usize, usize, u32)> = None; // (pkg, block, valid)
        for (pkg, blocks) in self.blocks.iter().enumerate() {
            for (bi, b) in blocks.iter().enumerate() {
                let open = self.open_block[pkg] == Some(bi as u32);
                if b.erased || open || !b.full() {
                    continue;
                }
                if best.is_none_or(|(_, _, v)| b.valid < v) {
                    best = Some((pkg, bi, b.valid));
                }
            }
        }
        let (pkg, bi, _) = best?;
        self.stats.gc_runs += 1;
        let valid: Vec<u64> = self.blocks[pkg][bi]
            .slots
            .iter()
            .flatten()
            .copied()
            .collect();
        self.stats.gc_relocated_pages += valid.len() as u64;
        Some((self.pkg_to_ppa(pkg, bi as u32, 0), valid))
    }

    /// Mark a GC'd block erased (called after relocation completes).
    /// Reset in place so the block's erase-cycle wear survives the cycle.
    pub fn finish_gc(&mut self, victim: Ppa) {
        let pkg = victim.package_index(&self.cfg);
        let b = &mut self.blocks[pkg][victim.block as usize];
        // relocated LPNs were remapped by map_relocate; drop stragglers
        b.slots.iter_mut().for_each(|s| *s = None);
        b.write_ptr = 0;
        b.valid = 0;
        b.erased = true;
        b.erase_cycles += 1;
        self.stats.erases += 1;
        self.stats.wear_max = self.stats.wear_max.max(b.erase_cycles as u64);
        self.free_count += 1;
        if self.open_block[pkg] == Some(victim.block) {
            self.open_block[pkg] = None;
        }
    }

    /// Write amplification factor in fixed-point milli-units (1000 =
    /// 1.0x): (host pages + GC-relocated pages) / host pages.  The
    /// numerator includes the denominator, so this is >= 1000 always.
    pub fn waf_milli(&self) -> u64 {
        if self.stats.host_pages == 0 {
            return 1000;
        }
        (self.stats.host_pages + self.stats.gc_relocated_pages) * 1000 / self.stats.host_pages
    }

    /// Price `pages` host page-writes starting at `lpn` on the device
    /// resource `busy`: each page programs once, and any GC a page
    /// forces adds its relocation reads/programs plus the block erase.
    pub fn write(&mut self, busy: &mut BusyResource, at: SimTime, lpn: u64, pages: u64) -> WriteReceipt {
        let mut relocated = 0u64;
        let mut erased = 0u64;
        for i in 0..pages {
            if self.needs_gc() {
                if let Some((victim, valid)) = self.pick_gc_victim() {
                    relocated += valid.len() as u64;
                    for l in valid {
                        self.map_relocate(l);
                    }
                    self.finish_gc(victim);
                    erased += 1;
                }
            }
            self.map_write(lpn + i);
        }
        let dur = SimTime::us(self.cfg.program_us * pages)
            + SimTime::us((self.cfg.read_us + self.cfg.program_us) * relocated)
            + SimTime::us(self.cfg.erase_us * erased);
        let done = busy.occupy(at, dur);
        WriteReceipt {
            pages,
            relocated_pages: relocated,
            erased_blocks: erased,
            done,
        }
    }

    pub fn mapped_pages(&self) -> usize {
        self.map.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SsdConfig {
        SsdConfig {
            channels: 2,
            packages_per_channel: 2,
            blocks_per_package: 8,
            pages_per_block: 16,
            ..Default::default()
        }
    }

    #[test]
    fn read_after_write_maps_to_same_ppa() {
        let mut ftl = Ftl::new(&cfg());
        let w = ftl.map_write(7);
        assert_eq!(ftl.translate_or_map(7), w);
    }

    #[test]
    fn overwrite_moves_and_invalidates() {
        let mut ftl = Ftl::new(&cfg());
        let a = ftl.map_write(7);
        let b = ftl.map_write(7);
        assert_ne!(a, b);
        assert_eq!(ftl.translate_or_map(7), b);
        assert_eq!(ftl.stats.remaps, 1);
        assert_eq!(ftl.mapped_pages(), 1);
    }

    #[test]
    fn writes_stripe_across_packages() {
        let mut ftl = Ftl::new(&cfg());
        let ppas: Vec<Ppa> = (0..4).map(|l| ftl.map_write(l)).collect();
        let pkgs: std::collections::HashSet<usize> =
            ppas.iter().map(|p| p.package_index(&cfg())).collect();
        assert_eq!(pkgs.len(), 4, "4 writes should hit 4 distinct packages");
    }

    #[test]
    fn gc_victim_is_min_valid_closed_block() {
        let c = cfg();
        let mut ftl = Ftl::new(&c);
        // fill two blocks' worth in one package pattern, then invalidate most of one
        let total = (c.pages_per_block * 8) as u64;
        for l in 0..total {
            ftl.map_write(l);
        }
        // overwrite most LPNs that landed in early blocks
        for l in 0..total / 2 {
            ftl.map_write(l);
        }
        let (victim, valid) = ftl.pick_gc_victim().expect("victim exists");
        // victim must be a closed block with minimal valid count
        assert!(valid.len() < c.pages_per_block as usize);
        ftl.finish_gc(victim);
        assert!(ftl.free_blocks() > 0);
    }

    #[test]
    fn free_count_matches_scan_through_gc_cycles() {
        let c = cfg();
        let mut ftl = Ftl::new(&c);
        assert_eq!(ftl.free_blocks(), ftl.free_blocks_scan());
        let total = (c.pages_per_block * 20) as u64;
        for l in 0..total {
            ftl.map_write(l % 97);
            if ftl.needs_gc() {
                if let Some((victim, valid)) = ftl.pick_gc_victim() {
                    for lpn in valid {
                        ftl.map_write(lpn);
                    }
                    ftl.finish_gc(victim);
                }
            }
            assert_eq!(ftl.free_blocks(), ftl.free_blocks_scan());
        }
    }

    #[test]
    fn gc_threshold_detection() {
        let c = cfg();
        let mut ftl = Ftl::new(&c);
        assert!(!ftl.needs_gc());
        // consume nearly all blocks
        let total_pages = (c.pages_per_block * c.blocks_per_package * 4) as u64;
        for l in 0..(total_pages as f64 * 0.97) as u64 {
            ftl.map_write(l);
        }
        assert!(ftl.needs_gc());
    }

    #[test]
    #[should_panic(expected = "FTL out of space")]
    fn exhaustion_without_gc_panics() {
        let c = cfg();
        let mut ftl = Ftl::new(&c);
        let total_pages = (c.pages_per_block * c.blocks_per_package * 4) as u64;
        for l in 0..total_pages + 1 {
            ftl.map_write(l); // never overwrites, never GCs
        }
    }

    #[test]
    fn write_receipt_prices_pages_and_gc() {
        let c = cfg();
        let mut ftl = Ftl::new(&c);
        let mut busy = BusyResource::default();
        // idle device: a clean write costs exactly pages x program time
        let r = ftl.write(&mut busy, SimTime::ZERO, 0, 4);
        assert_eq!(r.pages, 4);
        assert_eq!((r.relocated_pages, r.erased_blocks), (0, 0));
        assert_eq!(r.done, SimTime::us(c.program_us * 4));
        assert_eq!(ftl.stats.host_pages, 4);
        assert_eq!(ftl.waf_milli(), 1000);
        // churn a small LPN window until GC kicks in and shows up in WAF
        let mut t = r.done;
        for round in 0..64u64 {
            let rr = ftl.write(&mut busy, t, (round % 7) * 16, 16);
            assert!(rr.done >= t, "device time must advance");
            t = rr.done;
        }
        assert!(ftl.stats.gc_runs > 0, "churn must force GC");
        assert!(ftl.waf_milli() > 1000, "relocations must amplify writes");
        assert!(ftl.stats.wear_max >= 1, "an erase must register as wear");
        assert_eq!(ftl.stats.erases, ftl.stats.gc_runs);
    }

    #[test]
    fn relocations_stay_out_of_host_pages() {
        let mut ftl = Ftl::new(&cfg());
        ftl.map_write(1);
        ftl.map_relocate(1);
        assert_eq!(ftl.stats.host_pages, 1);
        assert_eq!(ftl.stats.remaps, 1, "relocation still remaps the LPN");
    }

    #[test]
    fn wear_survives_gc_reset_and_never_decreases() {
        let c = cfg();
        let mut ftl = Ftl::new(&c);
        let mut busy = BusyResource::default();
        let mut prev_wear = 0;
        let mut t = SimTime::ZERO;
        for round in 0..96u64 {
            let r = ftl.write(&mut busy, t, (round % 5) * 16, 16);
            t = r.done;
            assert!(ftl.stats.wear_max >= prev_wear, "wear went backwards");
            prev_wear = ftl.stats.wear_max;
        }
        assert!(prev_wear >= 2, "repeated GC must accumulate wear in place");
    }
}
