//! SSD backend simulator (DESIGN.md S3): multi-channel MLC flash timing,
//! page-mapped FTL with garbage collection, and the internal cache layer
//! (ICL) — the substrate under both the host block path and λFS.
//!
//! Substitution note (DESIGN.md §4): the paper's backend is two DDR4
//! controllers emulating flash with SimpleSSD's multi-channel timing
//! model, cross-validated against their FPGA prototype.  We rebuild the
//! same timing composition as a discrete-event model: per-package cell
//! latencies, per-channel transfer serialization, GC write amplification.

pub mod ftl;
pub mod icl;

use crate::config::SsdConfig;
use crate::nvme::BlockBackend;
use crate::sim::BusyResource;
use crate::util::SimTime;

pub use ftl::{Ftl, FtlStats, Ppa, WriteReceipt};
pub use icl::{Icl, IclStats};

/// Physical flash array: channels x packages with busy-time serialization.
pub struct FlashArray {
    cfg: SsdConfig,
    channels: Vec<BusyResource>,
    packages: Vec<BusyResource>,
    pub reads: u64,
    pub programs: u64,
    pub erases: u64,
}

impl FlashArray {
    pub fn new(cfg: &SsdConfig) -> Self {
        FlashArray {
            channels: vec![BusyResource::default(); cfg.channels as usize],
            packages: vec![BusyResource::default(); cfg.total_packages() as usize],
            cfg: cfg.clone(),
            reads: 0,
            programs: 0,
            erases: 0,
        }
    }

    fn xfer_time(&self) -> SimTime {
        let ns = self.cfg.page_bytes as f64 / (self.cfg.channel_mbps * 1e6) * 1e9;
        SimTime::ns(ns as u64)
    }

    /// Read one page at `ppa`: cell sense on the package, then transfer on
    /// the channel.  Returns completion time.
    pub fn read_page(&mut self, at: SimTime, ppa: Ppa) -> SimTime {
        self.reads += 1;
        let xfer = self.xfer_time();
        let pkg = &mut self.packages[ppa.package_index(&self.cfg)];
        let sensed = pkg.occupy(at, SimTime::us(self.cfg.read_us));
        let ch = &mut self.channels[ppa.channel as usize];
        ch.occupy(sensed, xfer)
    }

    /// Program one page: transfer on the channel, then cell program.
    pub fn program_page(&mut self, at: SimTime, ppa: Ppa) -> SimTime {
        self.programs += 1;
        let xfer = self.xfer_time();
        let ch = &mut self.channels[ppa.channel as usize];
        let transferred = ch.occupy(at, xfer);
        let pkg = &mut self.packages[ppa.package_index(&self.cfg)];
        pkg.occupy(transferred, SimTime::us(self.cfg.program_us))
    }

    /// Erase the block containing `ppa`.
    pub fn erase_block(&mut self, at: SimTime, ppa: Ppa) -> SimTime {
        self.erases += 1;
        let pkg = &mut self.packages[ppa.package_index(&self.cfg)];
        pkg.occupy(at, SimTime::us(self.cfg.erase_us))
    }

    pub fn channel_utilization(&self, horizon: SimTime) -> f64 {
        if self.channels.is_empty() {
            return 0.0;
        }
        self.channels.iter().map(|c| c.utilization(horizon)).sum::<f64>()
            / self.channels.len() as f64
    }
}

/// Full SSD device: ICL in front of FTL in front of the flash array, plus
/// a sparse real-data page store so filesystem contents round-trip.
pub struct SsdDevice {
    pub cfg: SsdConfig,
    pub icl: Icl,
    pub ftl: Ftl,
    pub flash: FlashArray,
    /// Sparse page data (page index -> bytes); only written pages stored.
    data: std::collections::HashMap<u64, Vec<u8>>,
    pub io_reads: u64,
    pub io_writes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub last_completion: SimTime,
}

impl SsdDevice {
    pub fn new(cfg: SsdConfig) -> Self {
        let dram_pages = (cfg.dram_gib * (1 << 30)) / cfg.page_bytes as u64;
        let icl_pages = ((dram_pages as f64) * cfg.icl_fraction) as u64;
        SsdDevice {
            icl: Icl::new(icl_pages.max(64), 8),
            ftl: Ftl::new(&cfg),
            flash: FlashArray::new(&cfg),
            cfg,
            data: Default::default(),
            io_reads: 0,
            io_writes: 0,
            bytes_read: 0,
            bytes_written: 0,
            last_completion: SimTime::ZERO,
        }
    }

    fn lba_to_page(&self, lba512: u64) -> u64 {
        lba512 * 512 / self.cfg.page_bytes as u64
    }

    /// Read `pages` flash pages starting at page index `page`, through the ICL.
    pub fn read_pages(&mut self, at: SimTime, page: u64, pages: u64) -> SimTime {
        let mut done = at;
        for p in page..page + pages {
            let t = if self.icl.access(p, false) {
                // ICL hit: internal DRAM latency only
                at + SimTime::ns(600)
            } else {
                let ppa = self.ftl.translate_or_map(p);
                let t = self.flash.read_page(at, ppa);
                // fill may evict a dirty page -> background program
                if let Some(victim) = self.icl.fill(p, false) {
                    let vppa = self.ftl.map_write(victim);
                    self.flash.program_page(t, vppa);
                }
                t
            };
            done = done.max(t);
        }
        self.last_completion = self.last_completion.max(done);
        done
    }

    /// Write `pages` flash pages via write-back ICL.
    pub fn write_pages(&mut self, at: SimTime, page: u64, pages: u64) -> SimTime {
        let mut done = at;
        for p in page..page + pages {
            // write-back: absorb into ICL; dirty eviction programs flash
            self.icl.access(p, true);
            if let Some(victim) = self.icl.fill(p, true) {
                let ppa = self.ftl.map_write(victim);
                let t = self.flash.program_page(at, ppa);
                done = done.max(t);
            } else {
                done = done.max(at + SimTime::ns(800)); // DRAM absorb
            }
            // GC if the FTL ran low on free blocks
            if self.ftl.needs_gc() {
                done = done.max(self.run_gc(done));
            }
        }
        self.last_completion = self.last_completion.max(done);
        done
    }

    /// One GC pass: pick the emptiest victim block, relocate valid pages,
    /// erase.  Returns completion time.
    fn run_gc(&mut self, at: SimTime) -> SimTime {
        let Some((victim_ppa, valid)) = self.ftl.pick_gc_victim() else {
            return at;
        };
        let mut t = at;
        for lpn in valid {
            let src = self.ftl.translate_or_map(lpn);
            t = self.flash.read_page(t, src);
            let dst = self.ftl.map_relocate(lpn);
            t = self.flash.program_page(t, dst);
        }
        let t = self.flash.erase_block(t, victim_ppa);
        self.ftl.finish_gc(victim_ppa);
        t
    }

    /// Store/retrieve real bytes (used by λFS and docker blobs).
    pub fn store_data(&mut self, page: u64, bytes: &[u8]) {
        for (i, chunk) in bytes.chunks(self.cfg.page_bytes as usize).enumerate() {
            self.data.insert(page + i as u64, chunk.to_vec());
        }
    }

    pub fn load_data(&self, page: u64, len: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(len);
        let mut p = page;
        while out.len() < len {
            match self.data.get(&p) {
                Some(bytes) => out.extend_from_slice(bytes),
                None => out.extend(std::iter::repeat(0u8).take(self.cfg.page_bytes as usize)),
            }
            p += 1;
        }
        out.truncate(len);
        out
    }
}

impl BlockBackend for SsdDevice {
    fn read(&mut self, at: SimTime, lba: u64, blocks: u64) -> (SimTime, Vec<u8>) {
        self.io_reads += 1;
        self.bytes_read += blocks * 512;
        let page = self.lba_to_page(lba);
        let pages = (blocks * 512).div_ceil(self.cfg.page_bytes as u64).max(1);
        let done = self.read_pages(at, page, pages);
        let data = self.load_data(page, (blocks * 512) as usize);
        (done, data)
    }

    fn write(&mut self, at: SimTime, lba: u64, data: &[u8]) -> SimTime {
        self.io_writes += 1;
        self.bytes_written += data.len() as u64;
        let page = self.lba_to_page(lba);
        let pages = (data.len() as u64).div_ceil(self.cfg.page_bytes as u64).max(1);
        self.store_data(page, data);
        self.write_pages(at, page, pages)
    }

    fn flush(&mut self, at: SimTime) -> SimTime {
        // flush dirty ICL pages
        let dirty = self.icl.drain_dirty();
        let mut t = at;
        for lpn in dirty {
            let ppa = self.ftl.map_write(lpn);
            t = self.flash.program_page(t, ppa);
        }
        self.last_completion = self.last_completion.max(t);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> SsdConfig {
        SsdConfig {
            channels: 4,
            packages_per_channel: 2,
            blocks_per_package: 16,
            pages_per_block: 32,
            dram_gib: 1,
            icl_fraction: 0.001, // tiny cache to exercise evictions
            ..Default::default()
        }
    }

    #[test]
    fn read_miss_slower_than_hit() {
        let mut dev = SsdDevice::new(small_cfg());
        let t_miss = dev.read_pages(SimTime::ZERO, 42, 1);
        let t_hit = dev.read_pages(t_miss, 42, 1) - t_miss;
        assert!(t_hit < SimTime::us(2), "hit took {t_hit}");
        assert!(t_miss >= SimTime::us(dev.cfg.read_us), "miss took {t_miss}");
    }

    #[test]
    fn write_data_round_trips() {
        let mut dev = SsdDevice::new(small_cfg());
        let payload: Vec<u8> = (0..9000u32).map(|i| (i % 251) as u8).collect();
        dev.write(SimTime::ZERO, 100, &payload);
        let (_, back) = dev.read(SimTime::ZERO, 100, (payload.len() as u64 + 511) / 512);
        assert_eq!(&back[..payload.len()], &payload[..]);
    }

    #[test]
    fn channel_parallelism_beats_serial() {
        // N pages striped across channels must finish faster than N x single latency
        let cfg = small_cfg();
        let mut dev = SsdDevice::new(cfg.clone());
        let n = 16u64;
        // force distinct mappings by writing first
        for p in 0..n {
            dev.ftl.map_write(p);
        }
        dev.icl = Icl::new(64, 8); // cold cache
        let done = (0..n)
            .map(|p| dev.flash.read_page(SimTime::ZERO, dev.ftl.translate_or_map(p)))
            .max()
            .unwrap();
        let serial = SimTime::us(cfg.read_us * n);
        assert!(
            done < serial,
            "parallel {done} !< serial {serial}"
        );
    }

    #[test]
    fn sustained_writes_trigger_gc() {
        let mut dev = SsdDevice::new(small_cfg());
        // device has 4*2*16*32 = 4096 pages; the working set (600 pages)
        // exceeds the tiny ICL, so dirty evictions continually consume
        // fresh flash pages until GC must reclaim.
        let mut t = SimTime::ZERO;
        for round in 0..40u64 {
            for p in 0..600u64 {
                t = dev.write_pages(t, p, 1);
            }
            let _ = round;
        }
        assert!(dev.flash.erases > 0, "GC never ran");
        // GC must keep free blocks above zero
        assert!(dev.ftl.free_blocks() > 0);
    }

    #[test]
    fn flush_programs_dirty_pages() {
        let mut dev = SsdDevice::new(small_cfg());
        dev.write_pages(SimTime::ZERO, 0, 4);
        let programs_before = dev.flash.programs;
        dev.flush(SimTime::ZERO);
        assert!(dev.flash.programs > programs_before);
        // second flush is a no-op
        let after = dev.flash.programs;
        dev.flush(SimTime::ZERO);
        assert_eq!(dev.flash.programs, after);
    }

    #[test]
    fn block_backend_lba_mapping() {
        let mut dev = SsdDevice::new(small_cfg());
        let done = dev.write(SimTime::ZERO, 8, &vec![7u8; 512]);
        assert!(done > SimTime::ZERO);
        let (_, data) = dev.read(SimTime::ZERO, 8, 1);
        assert_eq!(data[0], 7);
        assert_eq!(data.len(), 512);
    }
}
