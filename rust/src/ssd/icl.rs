//! ICL — internal cache layer: set-associative write-back DRAM cache in
//! front of the FTL ("the ICL relocates data to internal DRAM,
//! functioning as a memory cache").

#[derive(Clone, Copy, Debug, Default)]
pub struct IclStats {
    pub hits: u64,
    pub misses: u64,
    pub dirty_evictions: u64,
}

#[derive(Clone, Copy, Debug)]
struct Line {
    lpn: u64,
    dirty: bool,
    /// LRU stamp (bigger = more recent).
    stamp: u64,
}

/// Set-associative cache keyed by logical page number.
pub struct Icl {
    sets: Vec<Vec<Line>>,
    ways: usize,
    tick: u64,
    pub stats: IclStats,
}

impl Icl {
    /// `capacity_pages` total lines across `ways`-way sets.
    pub fn new(capacity_pages: u64, ways: usize) -> Self {
        let nsets = ((capacity_pages as usize) / ways).max(1);
        Icl {
            sets: vec![Vec::with_capacity(ways); nsets],
            ways,
            tick: 0,
            stats: IclStats::default(),
        }
    }

    fn set_of(&self, lpn: u64) -> usize {
        // multiplicative hash spreads sequential LPNs across sets
        (lpn.wrapping_mul(0x9E3779B97F4A7C15) >> 32) as usize % self.sets.len()
    }

    /// Probe the cache. Returns true on hit (updating LRU and dirtiness).
    pub fn access(&mut self, lpn: u64, write: bool) -> bool {
        self.tick += 1;
        let tick = self.tick;
        let set = self.set_of(lpn);
        if let Some(line) = self.sets[set].iter_mut().find(|l| l.lpn == lpn) {
            line.stamp = tick;
            line.dirty |= write;
            self.stats.hits += 1;
            true
        } else {
            self.stats.misses += 1;
            false
        }
    }

    /// Insert `lpn` after a miss.  Returns the evicted dirty LPN, if any
    /// (the caller must program it to flash).
    pub fn fill(&mut self, lpn: u64, dirty: bool) -> Option<u64> {
        self.tick += 1;
        let tick = self.tick;
        let set_idx = self.set_of(lpn);
        let ways = self.ways;
        let set = &mut self.sets[set_idx];
        if let Some(line) = set.iter_mut().find(|l| l.lpn == lpn) {
            line.dirty |= dirty;
            line.stamp = tick;
            return None;
        }
        if set.len() < ways {
            set.push(Line {
                lpn,
                dirty,
                stamp: tick,
            });
            return None;
        }
        // evict LRU
        let (idx, _) = set
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| l.stamp)
            .expect("set non-empty");
        let victim = set[idx];
        set[idx] = Line {
            lpn,
            dirty,
            stamp: tick,
        };
        if victim.dirty {
            self.stats.dirty_evictions += 1;
            Some(victim.lpn)
        } else {
            None
        }
    }

    /// Remove and return all dirty LPNs (flush path).
    pub fn drain_dirty(&mut self) -> Vec<u64> {
        let mut out = Vec::new();
        for set in &mut self.sets {
            for line in set.iter_mut() {
                if line.dirty {
                    line.dirty = false;
                    out.push(line.lpn);
                }
            }
        }
        out
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.stats.hits + self.stats.misses;
        if total == 0 {
            0.0
        } else {
            self.stats.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_fill() {
        let mut icl = Icl::new(64, 8);
        assert!(!icl.access(42, false));
        icl.fill(42, false);
        assert!(icl.access(42, false));
    }

    #[test]
    fn lru_eviction_within_set() {
        let mut icl = Icl::new(8, 8); // single set of 8 ways (8/8 = 1 set)
        for lpn in 0..8 {
            icl.fill(lpn, false);
        }
        // touch 0..7 except 3 -> 3 becomes LRU
        for lpn in [0u64, 1, 2, 4, 5, 6, 7] {
            icl.access(lpn, false);
        }
        icl.fill(100, false);
        assert!(!icl.access(3, false), "LRU line should be gone");
        assert!(icl.access(100, false));
    }

    #[test]
    fn dirty_eviction_returned() {
        let mut icl = Icl::new(8, 8);
        for lpn in 0..8 {
            icl.fill(lpn, true);
        }
        let evicted = icl.fill(99, false);
        assert!(evicted.is_some());
        assert_eq!(icl.stats.dirty_evictions, 1);
    }

    #[test]
    fn clean_eviction_returns_none() {
        let mut icl = Icl::new(8, 8);
        for lpn in 0..8 {
            icl.fill(lpn, false);
        }
        assert_eq!(icl.fill(99, false), None);
    }

    #[test]
    fn drain_dirty_then_clean() {
        let mut icl = Icl::new(64, 8);
        icl.fill(1, true);
        icl.fill(2, false);
        icl.fill(3, true);
        let mut dirty = icl.drain_dirty();
        dirty.sort();
        assert_eq!(dirty, vec![1, 3]);
        assert!(icl.drain_dirty().is_empty());
    }

    #[test]
    fn double_fill_updates_not_duplicates() {
        let mut icl = Icl::new(64, 8);
        icl.fill(5, false);
        icl.fill(5, true); // now dirty
        let dirty = icl.drain_dirty();
        assert_eq!(dirty, vec![5]);
    }

    #[test]
    fn hit_rate_tracks() {
        let mut icl = Icl::new(64, 8);
        icl.fill(1, false);
        icl.access(1, false);
        icl.access(1, false);
        icl.access(2, false); // miss
        assert!((icl.hit_rate() - 2.0 / 3.0).abs() < 1e-9);
    }
}
