//! The deterministic serve-smoke scenario, shared by `repro serve
//! --workload ...` and the CI golden gate.
//!
//! `ci/serve_smoke.sh` runs the `repro` binary and greps the
//! `serve.*`/`fabric.*`/`sim.*` counter lines; the tier-1 test
//! `rust/tests/golden.rs` re-derives the *same* lines in-process through
//! [`run`] + [`counter_lines`].  Because both arms call this one module
//! with the same inputs, the committed golden at
//! `ci/golden/serve_smoke.txt` is pinned twice: the binary replay must
//! match it byte-for-byte, and the in-process replay must regenerate it
//! (seed or refresh it with `UPDATE_GOLDEN=1 cargo test --test golden`).

use crate::chaos::{ChaosInjector, ChaosOutcome, ChaosSchedule};
use crate::config::SystemConfig;
use crate::coordinator::{serve, serve_with_hook, EchoExecutor, ServeParams, ServeReport};
use crate::layerstore::PoolLayerCache;
use crate::metrics::{Counters, Table};
use crate::pool::{
    AutoScaleOutcome, AutoScaleParams, AutoScaler, BootStormReport, DeploymentSpec, NodeId,
    Orchestrator, PoolTopology, RestartPolicy, WireCtx,
};
use crate::sim::PoolSim;
use crate::util::SimTime;
use crate::workloads::{all_workloads, trace_arrivals, workload_named, ArrivalParams};

/// The chunk-holder invariant chaos runs heal back to.
pub const CHAOS_HEAL_K: usize = 2;

/// Inputs of one trace-replay serve run (the `repro serve` CLI knobs
/// that matter for a workload replay).
#[derive(Clone, Debug)]
pub struct SmokeParams {
    /// A Table 2 row name (`workloads::workload_named`).
    pub workload: String,
    /// Number of EchoExecutor serving nodes.
    pub nodes: usize,
    /// Trace scale divisor ([`ArrivalParams::scale`]).
    pub scale: u64,
    pub seed: u64,
    /// Replicas booted on the same clock; 0 disables the storm.
    pub boot_storm: u32,
    /// Seed of a [`ChaosSchedule`] to replay while serving; `None`
    /// (the CI smoke path) serves undisturbed.
    pub chaos: Option<u64>,
    /// Run the serve loop under the [`AutoScaler`] (mutually exclusive
    /// with `chaos`: both hooks want ownership of the pool state).
    pub autoscale: bool,
    /// Warm scale-out candidates ahead of the commit
    /// ([`AutoScaleParams::predictive`]); implies `autoscale`.
    pub predictive: bool,
}

impl SmokeParams {
    /// The CI smoke scenario: `repro serve --workload nginx-filedown
    /// --nodes 4 --scale 2000 --seed 42 --boot-storm 2`.
    pub fn ci() -> Self {
        SmokeParams {
            workload: "nginx-filedown".into(),
            nodes: 4,
            scale: 2000,
            seed: 42,
            boot_storm: 2,
            chaos: None,
            autoscale: false,
            predictive: false,
        }
    }
}

/// Shape summary of the generated arrival stream, for CLI reporting.
#[derive(Clone, Copy, Debug)]
pub struct ArrivalSummary {
    pub requests: usize,
    pub read_requests: u64,
    pub write_requests: u64,
    pub span: SimTime,
}

/// Everything one smoke run produced.
pub struct SmokeOutcome {
    pub report: ServeReport,
    /// `serve.*` + `fabric.*` + `sim.*` counters, with the fabric engine
    /// drained first so in-flight prefetches are fully accounted.
    pub counters: Counters,
    pub storm: Option<BootStormReport>,
    /// The chaos run's reports plus the healed pool state, when a
    /// `--chaos` seed was set — invariant checks read the pool from
    /// here.
    pub chaos: Option<ChaosOutcome>,
    /// The autoscaled run's report plus the scaled pool state, when
    /// `--autoscale` was set.
    pub autoscale: Option<AutoScaleOutcome>,
    pub arrivals: ArrivalSummary,
    pub workload_name: String,
}

/// Synthetic "llm-worker" image the boot storm deploys: four 24 MiB
/// layers, sized so a cold registry pull visibly occupies the host
/// uplink while requests are being dispatched.
pub fn boot_storm_layers() -> Vec<(u64, u64)> {
    (0..4u64).map(|i| (0x11A9_E500 + i, 24 << 20)).collect()
}

/// Run the trace-replay serve scenario deterministically: Table 2
/// arrivals through `coordinator::serve` on one `PoolSim` clock, with an
/// optional boot storm contending on the same fabric.  Two calls with
/// the same params produce byte-identical counters.  `Err` carries the
/// valid workload names when `workload` is unknown.
pub fn run(p: &SmokeParams) -> Result<SmokeOutcome, String> {
    let Some(spec) = workload_named(&p.workload) else {
        let rows: Vec<String> = all_workloads().iter().map(|w| w.full_name()).collect();
        return Err(format!(
            "unknown workload {:?}; Table 2 rows:\n  {}",
            p.workload,
            rows.join("\n  ")
        ));
    };
    let autoscaled = p.autoscale || p.predictive;
    if autoscaled && p.chaos.is_some() {
        return Err(
            "--autoscale and --chaos are mutually exclusive: each hook owns the pool state for the run"
                .into(),
        );
    }
    let cfg = SystemConfig::default();
    let mut params = ServeParams::from_config(&cfg.serve);
    let ap = ArrivalParams {
        scale: p.scale,
        ..Default::default()
    };
    // don't clip prompt-heavy (write) requests to the storm default
    params.prompt_len = ap.engine_prompt_len();
    let arr = trace_arrivals(&spec, p.seed, &ap);
    let arrivals = ArrivalSummary {
        requests: arr.requests.len(),
        read_requests: arr.read_requests,
        write_requests: arr.write_requests,
        span: arr.span,
    };

    let mut sim = PoolSim::new(&cfg);
    let topo = PoolTopology::build(&cfg.pool);
    let mut orch = Orchestrator::new();
    let mut cache = PoolLayerCache::new();
    if p.chaos.is_some() {
        // the heal invariant needs live content even without a storm:
        // pre-warm the storm image onto the first k nodes at t=0, so
        // every chunk starts at exactly the invariant the healing loop
        // must restore
        let warm: Vec<NodeId> = topo
            .healthy_nodes()
            .take(CHAOS_HEAL_K)
            .map(|n| n.id)
            .collect();
        for node in warm {
            for (d, b) in boot_storm_layers() {
                cache.fetch(
                    &mut WireCtx::at(&mut sim.fabric, &topo, &mut sim.ftls, SimTime::ZERO),
                    node,
                    d,
                    b,
                );
            }
        }
    }
    let storm = if p.boot_storm > 0 {
        let spec = DeploymentSpec {
            name: "storm".into(),
            image: "llm-worker".into(),
            replicas: p.boot_storm,
            restart: RestartPolicy::OnFailure,
        };
        let rep = orch
            .boot_storm_sim(&mut sim, &topo, &spec, &mut cache, &boot_storm_layers())
            .map_err(|e| format!("boot storm placement: {e}"))?;
        Some(rep)
    } else {
        None
    };

    let factories: Vec<_> = (0..p.nodes)
        .map(|_| || Ok::<_, anyhow::Error>(EchoExecutor))
        .collect();
    let (report, chaos, autoscale) = if let Some(chaos_seed) = p.chaos {
        let schedule = ChaosSchedule::generate(chaos_seed, &topo, arr.span);
        let mut inj = ChaosInjector::new(
            schedule,
            topo,
            orch,
            cache,
            CHAOS_HEAL_K,
            RestartPolicy::OnFailure,
        );
        inj.arm(&mut sim);
        let report = serve_with_hook(&mut sim, factories, arr.requests, &params, &mut inj);
        (report, Some(inj.finish(&mut sim)), None)
    } else if autoscaled {
        // the autoscaler manages a deployment mirroring the serving
        // fleet; its image is warm exactly where it already runs, so
        // scale-outs must move layers (predictively or at commit)
        let placed = orch
            .deploy(
                &topo,
                &DeploymentSpec {
                    name: "svc".into(),
                    image: "llm-worker".into(),
                    replicas: p.nodes as u32,
                    restart: RestartPolicy::OnFailure,
                },
            )
            .map_err(|e| format!("autoscale deploy: {e}"))?;
        for &node in &placed {
            for (d, _) in boot_storm_layers() {
                cache.register(node, d);
            }
        }
        let mut scaler = AutoScaler::new(
            topo,
            orch,
            cache,
            "svc",
            boot_storm_layers(),
            AutoScaleParams {
                predictive: p.predictive,
                ..Default::default()
            },
        );
        scaler.arm(&mut sim);
        let report = serve_with_hook(&mut sim, factories, arr.requests, &params, &mut scaler);
        (report, None, Some(scaler.finish(&mut sim)))
    } else {
        (serve(&mut sim, factories, arr.requests, &params), None, None)
    };
    // settle engine-scheduled background prefetches so the exported
    // fabric counters cover the whole storm, re-timed receipts included
    sim.fabric.run_to_idle();
    let mut counters = Counters::new();
    report.export_counters(&mut counters);
    sim.export_counters(&mut counters);
    if let Some(out) = &chaos {
        out.report.export_counters(&mut counters);
        out.heal.export_counters(&mut counters);
    }
    if let Some(out) = &autoscale {
        out.report.export_counters(&mut counters);
    }
    Ok(SmokeOutcome {
        report,
        counters,
        storm,
        chaos,
        autoscale,
        arrivals,
        workload_name: spec.full_name(),
    })
}

/// Render counters exactly as `repro serve` prints them (a two-column
/// `counter value` table), keeping only the deterministic
/// `serve.*`/`fabric.*`/`sim.*`/`chaos.*`/`heal.*` rows — the same
/// filter `ci/serve_smoke.sh` applies with grep, so this string is
/// directly comparable to the smoke job's `counters_a.txt` and to the
/// committed golden.
pub fn counter_lines(c: &Counters) -> String {
    let mut t = Table::new(vec!["counter", "value"]);
    for (k, v) in c.iter() {
        t.row(vec![k.to_string(), format!("{v}")]);
    }
    t.render()
        .lines()
        .filter(|l| {
            l.starts_with("serve.")
                || l.starts_with("fabric.")
                || l.starts_with("sim.")
                || l.starts_with("chaos.")
                || l.starts_with("heal.")
        })
        .map(|l| format!("{l}\n"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_workload_lists_rows() {
        let err = run(&SmokeParams {
            workload: "no-such-row".into(),
            ..SmokeParams::ci()
        })
        .unwrap_err();
        assert!(err.contains("no-such-row"));
        assert!(err.contains("nginx-filedown"), "error lists the valid rows");
    }

    #[test]
    fn chaos_smoke_is_deterministic_and_heals_back_to_k() {
        let p = SmokeParams {
            chaos: Some(7),
            ..SmokeParams::ci()
        };
        let a = run(&p).unwrap();
        let b = run(&p).unwrap();
        assert_eq!(
            a.counters, b.counters,
            "same chaos seed must replay byte-identically"
        );
        assert_eq!(counter_lines(&a.counters), counter_lines(&b.counters));
        let out = a.chaos.expect("chaos run carries its outcome");
        assert!(out.report.faults_injected > 0, "the schedule actually fired");
        assert!(
            out.healed_to_k(CHAOS_HEAL_K),
            "every live chunk is back to >=k holders after the run"
        );
        assert_eq!(
            a.report.responses.len(),
            a.arrivals.requests,
            "churn never loses a request"
        );
        assert!(
            a.counters.get(crate::metrics::names::CHAOS_AVAILABILITY_PPM) > 0,
            "availability is reported"
        );
    }

    #[test]
    fn chaos_off_leaves_the_ci_golden_path_untouched() {
        let a = run(&SmokeParams::ci()).unwrap();
        assert!(a.chaos.is_none());
        let lines = counter_lines(&a.counters);
        assert!(!lines.contains("chaos."), "no chaos rows without a seed");
        assert!(!lines.contains("heal."), "no heal rows without a seed");
        // the FTL ledger is exported for every run, but its rows stay
        // off the pinned golden: the grep filter passes them through
        // untouched (inert), exactly like layerstore.* rows
        assert!(a.counters.get(crate::metrics::names::FTL_WAF) >= 1000);
        assert!(!lines.contains("ftl."), "ftl rows never enter the golden");
    }

    #[test]
    fn autoscale_smoke_is_deterministic_and_stays_off_the_golden() {
        let p = SmokeParams {
            autoscale: true,
            predictive: true,
            boot_storm: 0,
            ..SmokeParams::ci()
        };
        let a = run(&p).unwrap();
        let b = run(&p).unwrap();
        assert_eq!(
            a.counters, b.counters,
            "same-seed autoscaled replays must match byte-for-byte"
        );
        let out = a.autoscale.expect("autoscaled run carries its outcome");
        assert!(out.report.ticks > 0, "the controller actually ticked");
        assert_eq!(
            a.report.responses.len(),
            a.arrivals.requests,
            "autoscaling never loses a request"
        );
        // autoscale.* rows are exported but sit outside the grep
        // prefixes, so the committed golden never changes
        assert!(a.counters.get(crate::metrics::names::AUTOSCALE_TICKS) > 0);
        let lines = counter_lines(&a.counters);
        assert!(!lines.contains("autoscale."), "autoscale rows never enter the golden");
    }

    #[test]
    fn autoscale_and_chaos_are_mutually_exclusive() {
        let err = run(&SmokeParams {
            autoscale: true,
            chaos: Some(7),
            ..SmokeParams::ci()
        })
        .unwrap_err();
        assert!(err.contains("mutually exclusive"));
    }

    #[test]
    fn smoke_reports_host_bytes_and_stream_counters() {
        // the CI scenario serves under the default streamed wire policy,
        // so the new stream/host-traffic counters must ride the existing
        // serve./fabric. grep prefixes of ci/serve_smoke.sh untouched
        let a = run(&SmokeParams::ci()).unwrap();
        let lines = counter_lines(&a.counters);
        assert!(lines.contains("serve.host_bytes_per_token"));
        assert!(lines.contains("fabric.bytes_p2p"));
        assert!(lines.contains("fabric.stream_quanta"));
        assert!(lines.contains("fabric.stream_overlap_ns"));
        assert!(
            a.counters.get(crate::metrics::names::SERVE_HOST_BYTES_PER_TOKEN) > 0,
            "responses alone put host bytes on every served token"
        );
    }

    #[test]
    fn counter_lines_filters_to_deterministic_counters() {
        let mut c = Counters::new();
        c.add(crate::metrics::names::SERVE_RESPONSES, 7);
        c.add(crate::metrics::names::FABRIC_BYTES_WAN, 9);
        c.add(crate::metrics::names::BYTES_WRITTEN, 3); // layerstore.*: filtered out
        let lines = counter_lines(&c);
        assert!(lines.contains("serve.responses"));
        assert!(lines.contains("fabric.bytes_wan"));
        assert!(!lines.contains("layerstore."));
        assert!(lines.ends_with('\n'));
    }
}
