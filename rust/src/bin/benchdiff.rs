//! Cross-PR bench differ: compare fresh `BENCH_*.json` records against
//! the committed baselines and exit nonzero on any >tolerance
//! regression — the CI gate that keeps the bench trajectory monotone.
//!
//! Usage: `benchdiff <baseline-dir> <fresh-dir> [tolerance]`
//! (tolerance is a fraction; default 0.10 = 10%).
//!
//! A fresh file or record with no committed baseline is reported as new
//! and not compared — commit it under the baseline dir to start
//! tracking it.

use std::path::Path;
use std::process::ExitCode;

use dockerssd::benchkit::{diff, parse_records};
use dockerssd::metrics::Table;

fn load(path: &Path) -> Result<Vec<dockerssd::benchkit::BenchRecord>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    parse_records(&text).map_err(|e| format!("{}: {e}", path.display()))
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.len() < 2 {
        eprintln!("usage: benchdiff <baseline-dir> <fresh-dir> [tolerance]");
        return ExitCode::from(2);
    }
    let baseline_dir = Path::new(&args[0]);
    let fresh_dir = Path::new(&args[1]);
    let tolerance: f64 = args
        .get(2)
        .map(|s| s.parse().expect("tolerance must be a fraction like 0.10"))
        .unwrap_or(0.10);

    let mut fresh_files: Vec<_> = match std::fs::read_dir(fresh_dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
            .collect(),
        Err(e) => {
            eprintln!("cannot read {}: {e}", fresh_dir.display());
            return ExitCode::from(2);
        }
    };
    fresh_files.sort();
    if fresh_files.is_empty() {
        eprintln!(
            "no BENCH_*.json in {} — run the benches first (cargo bench)",
            fresh_dir.display()
        );
        return ExitCode::from(2);
    }

    let mut regressions = 0usize;
    let mut compared = 0usize;
    for name in &fresh_files {
        let base_path = baseline_dir.join(name);
        if !base_path.exists() {
            println!(
                "{name}: no committed baseline — new bench, commit it to {} to track",
                baseline_dir.display()
            );
            continue;
        }
        let (base, fresh) = match (load(&base_path), load(&fresh_dir.join(name))) {
            (Ok(b), Ok(f)) => (b, f),
            (Err(e), _) | (_, Err(e)) => {
                eprintln!("{e}");
                return ExitCode::from(2);
            }
        };
        let deltas = diff(&base, &fresh, tolerance);
        if deltas.is_empty() {
            println!("{name}: no overlapping records with the baseline");
            continue;
        }
        let mut t = Table::new(vec!["bench", "metric", "baseline", "fresh", "gain", "verdict"]);
        for d in &deltas {
            compared += 1;
            if d.regression {
                regressions += 1;
            }
            t.row(vec![
                d.name.clone(),
                d.metric.clone(),
                format!("{:.4}", d.base),
                format!("{:.4}", d.fresh),
                format!("{:+.1}%", d.gain * 100.0),
                if d.regression { "REGRESSION".into() } else { "ok".to_string() },
            ]);
        }
        println!("{name} (tolerance {:.0}%):\n{}", tolerance * 100.0, t.render());
    }
    println!("{compared} records compared, {regressions} regression(s)");
    if regressions > 0 {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
