//! Link classes and per-link bandwidth queues.
//!
//! Every byte that crosses the pool is serialized onto one or more of
//! four contention domains, keyed by the PCIe-switch/tray topology of
//! Figure 8a.  A [`LinkQueue`] is a busy-until bandwidth queue: a
//! transfer granted the wire at `begin` occupies it for its wire time,
//! and the next transfer on the same link starts no earlier — which is
//! exactly how N concurrent same-link transfers come to take ~N times
//! one transfer's time while cross-link transfers overlap freely.

use crate::util::SimTime;

/// One contention domain in the pool fabric.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LinkClass {
    /// The PCIe-switch backplane shared by one array of DockerSSDs.
    Array(u32),
    /// The switch tray integrating the arrays into a cluster.
    Tray,
    /// The host's uplink into the tray.
    HostUplink,
    /// The WAN beyond the host, out to the container registry.
    RegistryWan,
}

impl LinkClass {
    /// Intranet links carry Ether-oN frames (TransmitFrame/ReceiveFrame
    /// NVMe commands); the host uplink and WAN are ordinary networking.
    pub fn is_intranet(&self) -> bool {
        matches!(self, LinkClass::Array(_) | LinkClass::Tray)
    }

    pub fn name(&self) -> &'static str {
        match self {
            LinkClass::Array(_) => "array",
            LinkClass::Tray => "tray",
            LinkClass::HostUplink => "host_uplink",
            LinkClass::RegistryWan => "registry_wan",
        }
    }
}

/// Transfer priority class.
///
/// Two tiers exist.  The *foreground tier* ([`Priority::Foreground`] and
/// the weighted [`Priority::Tenant`] classes) holds the wire it is
/// granted; on the event-driven engine, concurrent foreground-tier
/// tenants share a contended link in proportion to their weights
/// (weighted fair queuing at transfer granularity) instead of strictly
/// serializing.  The *background tier* only gets the wire when the
/// foreground tier leaves it idle, and yields within one MTU frame
/// quantum when foreground traffic arrives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    /// Latency-sensitive traffic: boot-blocking layer fetches, request
    /// dispatch, KV migration, collective steps.  Equivalent to a
    /// weight-1 tenant class.
    Foreground,
    /// Best-effort traffic that yields the wire to foreground within one
    /// frame quantum: placement-time layer prefetch.
    Background,
    /// A weighted per-tenant QoS class: foreground-tier traffic that
    /// shares a contended wire with other tenants in proportion to
    /// `weight` (>= 1).  The synchronous busy-until path treats it as
    /// plain foreground; the event-driven engine schedules it by weight.
    Tenant { id: u8, weight: u8 },
}

impl Priority {
    pub fn is_background(self) -> bool {
        matches!(self, Priority::Background)
    }

    /// The WFQ class this transfer is accounted under.
    pub(crate) fn class_key(self) -> u16 {
        match self {
            Priority::Foreground => 0,
            Priority::Tenant { id, .. } => 1 + id as u16,
            Priority::Background => u16::MAX,
        }
    }

    /// Weighted share of a contended link (foreground tier only).
    pub fn weight(self) -> u64 {
        match self {
            Priority::Tenant { weight, .. } => weight.max(1) as u64,
            _ => 1,
        }
    }
}

/// Busy-until bandwidth queue for one link.
#[derive(Clone, Debug)]
pub struct LinkQueue {
    /// Link bandwidth (GB/s == bytes/ns).
    pub gbps: f64,
    /// The wire is granted to foreground transfers until this instant.
    pub(crate) fg_busy_until: SimTime,
    /// The wire is granted to background transfers until this instant.
    pub(crate) bg_busy_until: SimTime,
    /// Total bytes serialized onto this link.
    pub bytes: u64,
    /// Transfers that crossed this link.
    pub transfers: u64,
    /// Accumulated time transfers spent waiting for the wire.
    pub queue_wait: SimTime,
}

impl LinkQueue {
    pub fn new(gbps: f64) -> Self {
        LinkQueue {
            gbps,
            fg_busy_until: SimTime::ZERO,
            bg_busy_until: SimTime::ZERO,
            bytes: 0,
            transfers: 0,
            queue_wait: SimTime::ZERO,
        }
    }

    /// Time `bytes` occupy this link's wire.
    pub fn wire_time(&self, bytes: u64) -> SimTime {
        SimTime::ns((bytes as f64 / self.gbps) as u64)
    }

    /// Time one MTU frame occupies the wire — the granularity at which a
    /// background transfer can be preempted by foreground traffic.
    pub fn frame_quantum(&self, mtu: u32) -> SimTime {
        self.wire_time(mtu as u64)
    }

    /// Grant the wire to a transfer: occupy `[begin, begin + wire)` in
    /// the priority lane and account the bytes.  A foreground grant that
    /// preempts an in-flight background transfer pushes the background
    /// lane out by its own wire time (the preempted transfer resumes
    /// afterwards).  Queue wait is charged by the fabric to the one
    /// bottleneck link that delayed the transfer, not here.
    pub(crate) fn occupy(&mut self, pri: Priority, begin: SimTime, bytes: u64) {
        let wire = self.wire_time(bytes);
        if pri.is_background() {
            self.bg_busy_until = begin + wire;
        } else {
            self.fg_busy_until = begin + wire;
            if self.bg_busy_until > begin {
                self.bg_busy_until += wire;
            }
        }
        self.bytes += bytes;
        self.transfers += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intranet_classification() {
        assert!(LinkClass::Array(0).is_intranet());
        assert!(LinkClass::Tray.is_intranet());
        assert!(!LinkClass::HostUplink.is_intranet());
        assert!(!LinkClass::RegistryWan.is_intranet());
    }

    #[test]
    fn wire_time_scales_with_bytes_and_bandwidth() {
        let q = LinkQueue::new(3.2);
        assert!(q.wire_time(1 << 20) > q.wire_time(1 << 10));
        let fast = LinkQueue::new(32.0);
        assert!(fast.wire_time(1 << 20) < q.wire_time(1 << 20));
    }

    #[test]
    fn occupy_serializes_and_accounts() {
        let mut q = LinkQueue::new(1.0); // 1 B/ns
        q.occupy(Priority::Foreground, SimTime::ZERO, 1000);
        assert_eq!(q.fg_busy_until, SimTime::ns(1000));
        q.occupy(Priority::Foreground, q.fg_busy_until, 1000);
        assert_eq!(q.fg_busy_until, SimTime::ns(2000));
        assert_eq!(q.bytes, 2000);
        assert_eq!(q.transfers, 2);
    }

    #[test]
    fn tenant_classes_are_foreground_tier() {
        let t = Priority::Tenant { id: 3, weight: 4 };
        assert!(!t.is_background());
        assert_eq!(t.weight(), 4);
        assert_eq!(Priority::Tenant { id: 0, weight: 0 }.weight(), 1, "weight floor");
        assert_eq!(Priority::Foreground.weight(), 1);
        assert_ne!(t.class_key(), Priority::Foreground.class_key());
        // a tenant occupies the foreground lane on the sync path
        let mut q = LinkQueue::new(1.0);
        q.occupy(t, SimTime::ZERO, 500);
        assert_eq!(q.fg_busy_until, SimTime::ns(500));
        assert_eq!(q.bg_busy_until, SimTime::ZERO);
    }

    #[test]
    fn foreground_preemption_pushes_background_out() {
        let mut q = LinkQueue::new(1.0);
        q.occupy(Priority::Background, SimTime::ZERO, 4000);
        assert_eq!(q.bg_busy_until, SimTime::ns(4000));
        // foreground grabs the wire at t=1000 for 2000ns
        q.occupy(Priority::Foreground, SimTime::ns(1000), 2000);
        assert_eq!(q.fg_busy_until, SimTime::ns(3000));
        assert_eq!(q.bg_busy_until, SimTime::ns(6000), "preempted prefetch resumes after");
    }
}
