//! Event-driven transfer scheduling: the fabric's re-timing engine.
//!
//! [`Fabric::transfer`] prices a transfer the moment it is called, which
//! is exact for foreground traffic issued in time order but leaves a
//! background transfer's receipt *optimistic* — foreground traffic
//! arriving later preempts the wire, yet the receipt already returned
//! cannot be extended (the ROADMAP retro-causality item).  The engine
//! closes that hole by making completion an *event* instead of a return
//! value:
//!
//! * [`Fabric::schedule`] enqueues an arrival event on the engine's
//!   [`EventQueue`] and returns a [`TransferId`];
//! * the engine pops arrival / wire-release / frame-quantum-preemption
//!   events in deterministic time order, granting each link to one
//!   transfer at a time;
//! * a foreground-tier arrival preempts an in-flight background transfer
//!   at the next MTU frame-quantum boundary; the background transfer's
//!   already-served bytes are kept, its remainder re-queues, and its
//!   receipt — only available once it actually finishes — is strictly
//!   later than the optimistic figure (`fabric.retimed_transfers`
//!   counts these);
//! * concurrent foreground-tier tenants ([`Priority::Tenant`]) share a
//!   contended link in proportion to their weights via start-time
//!   weighted fair queuing at transfer granularity, replacing the two
//!   hardcoded lanes' strict serialization.
//!
//! The engine shares the per-link byte/wait/transfer accounting and the
//! `fg_busy_until`/`bg_busy_until` lane mirrors with the synchronous
//! path, so planning estimates and sync transfers see engine traffic and
//! vice versa.

use super::link::{LinkClass, Priority};
use super::{Endpoint, Fabric, TransferReceipt};
use crate::sim::EventQueue;
use crate::util::SimTime;

/// Handle to a transfer scheduled on the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TransferId(pub u64);

const EV_ARRIVE: u64 = 1;
const EV_RELEASE: u64 = 2;
const EV_PREEMPT: u64 = 3;
const EV_RETRY: u64 = 4;

fn tag(kind: u64, gen: u64, id: u64) -> u64 {
    (kind << 60) | ((gen & 0xF_FFFF) << 40) | (id & 0xFF_FFFF_FFFF)
}

fn untag(t: u64) -> (u64, u64, u64) {
    (t >> 60, (t >> 40) & 0xF_FFFF, t & 0xFF_FFFF_FFFF)
}

/// One scheduled transfer's engine state.
struct Flight {
    path: Vec<LinkClass>,
    hops: u64,
    pri: Priority,
    bytes: u64,
    /// Bytes not yet served by a completed or in-progress grant.
    remaining: u64,
    issued: SimTime,
    /// First wire grant (receipt `begin`).
    begin: Option<SimTime>,
    grant_begin: SimTime,
    grant_end: SimTime,
    active: bool,
    /// Bumped on every grant and preemption; release/preempt events
    /// carry the generation they were scheduled under so stale ones are
    /// ignored after a re-time.
    gen: u64,
    preempt_scheduled: bool,
    retry_at: Option<SimTime>,
    blocked_on: Option<LinkClass>,
    retimed: bool,
    done: Option<TransferReceipt>,
}

/// The engine's queues and bookkeeping, embedded in [`Fabric`].
///
/// Transfer ids are handed out sequentially and flights are never
/// removed (receipts stay queryable), so the flight table is a flat
/// slab indexed by id.  Link holders and per-class virtual times are
/// dense vectors indexed by [`Fabric::link_idx`] slot / WFQ class key —
/// no tree walks on the grant path.
#[derive(Default)]
pub(crate) struct Engine {
    pub(crate) queue: EventQueue,
    flights: Vec<Flight>,
    /// Arrival-ordered ids not currently granted the wire.
    waiting: Vec<u64>,
    /// Which flight currently holds each link, by dense link slot
    /// (grown lazily to the highest slot touched).
    holders: Vec<Option<u64>>,
    /// Per-QoS-class virtual time for weighted fair queuing, by class
    /// key (foreground 0, tenants 1..=256; background never enters).
    class_vtime: Vec<u128>,
    global_vtime: u128,
    next_id: u64,
    /// Reusable candidate buffers for `pick_grantable`, so the grant
    /// loop does not allocate per evaluation.
    scratch_fg: Vec<(u128, usize)>,
    scratch_bg: Vec<usize>,
}

impl Fabric {
    fn holder_of(&self, slot: usize) -> Option<u64> {
        self.engine.holders.get(slot).copied().flatten()
    }

    fn set_holder(&mut self, slot: usize, id: u64) {
        if slot >= self.engine.holders.len() {
            self.engine.holders.resize(slot + 1, None);
        }
        self.engine.holders[slot] = Some(id);
    }

    /// Release `slot` if `id` is the one holding it.
    fn clear_holder(&mut self, slot: usize, id: u64) {
        if let Some(h) = self.engine.holders.get_mut(slot) {
            if *h == Some(id) {
                *h = None;
            }
        }
    }

    fn class_vtime_of(&self, key: u16) -> u128 {
        self.engine.class_vtime.get(key as usize).copied().unwrap_or(0)
    }

    fn set_class_vtime(&mut self, key: u16, v: u128) {
        let idx = key as usize;
        if idx >= self.engine.class_vtime.len() {
            self.engine.class_vtime.resize(idx + 1, 0);
        }
        self.engine.class_vtime[idx] = v;
    }

    /// The dense link slot of a class on a scheduled flight's path.
    fn slot_of(&self, c: LinkClass) -> usize {
        self.link_idx(c).expect("path links interned at schedule")
    }
    /// Schedule a transfer on the event-driven engine.  `now` is clamped
    /// to the engine clock (counted under `sim.clamped_events`); the
    /// receipt becomes available from [`Fabric::receipt_of`] once the
    /// clock has passed the transfer's (possibly re-timed) finish.
    pub fn schedule(
        &mut self,
        now: SimTime,
        from: Endpoint,
        to: Endpoint,
        bytes: u64,
        pri: Priority,
    ) -> TransferId {
        let id = self.engine.next_id;
        self.engine.next_id += 1;
        let (path, hops) = self.path(from, to);
        for &c in &path {
            self.ensure_link(c);
        }
        let at = now.max(self.engine.queue.now());
        let mut flight = Flight {
            path,
            hops,
            pri,
            bytes,
            remaining: bytes,
            issued: at,
            begin: None,
            grant_begin: SimTime::ZERO,
            grant_end: SimTime::ZERO,
            active: false,
            gen: 0,
            preempt_scheduled: false,
            retry_at: None,
            blocked_on: None,
            retimed: false,
            done: None,
        };
        if flight.path.is_empty() {
            // same endpoint: nothing crosses the fabric
            flight.done = Some(TransferReceipt {
                issued: at,
                begin: at,
                finish: at,
                bytes,
                frames: 0,
            });
            debug_assert_eq!(self.engine.flights.len() as u64, id);
            self.engine.flights.push(flight);
            return TransferId(id);
        }
        debug_assert_eq!(self.engine.flights.len() as u64, id);
        self.engine.flights.push(flight);
        self.engine.queue.schedule_at(now, tag(EV_ARRIVE, 0, id));
        TransferId(id)
    }

    /// The engine clock.
    pub fn engine_now(&self) -> SimTime {
        self.engine.queue.now()
    }

    /// Engine transfers not yet completed.
    pub fn transfers_in_flight(&self) -> usize {
        self.engine.flights.iter().filter(|f| f.done.is_none()).count()
    }

    pub(crate) fn engine_clamped_events(&self) -> u64 {
        self.engine.queue.clamped()
    }

    /// The receipt of an engine transfer, once it has completed.
    pub fn receipt_of(&self, id: TransferId) -> Option<TransferReceipt> {
        self.engine.flights.get(id.0 as usize).and_then(|f| f.done)
    }

    /// Process engine events, in deterministic time order, until the
    /// transfer `id` completes, then return its (possibly re-timed)
    /// receipt.  This is how a caller waits on one scheduled transfer
    /// without draining unrelated future events past the point it needs:
    /// the engine clock advances exactly as far as this flight's finish.
    /// Returns `None` for an id the engine never saw.
    pub fn settle(&mut self, id: TransferId) -> Option<TransferReceipt> {
        self.engine.flights.get(id.0 as usize)?;
        loop {
            if let Some(r) = self.receipt_of(id) {
                return Some(r);
            }
            let ev = self
                .engine
                .queue
                .pop()
                .expect("an incomplete flight always has a pending release/retry event");
            self.engine_event(ev.at, ev.tag);
        }
    }

    /// Process engine events up to (and including) `t`, then advance the
    /// engine clock to `t`.
    pub fn advance_to(&mut self, t: SimTime) {
        while self.engine.queue.peek_at().is_some_and(|at| at <= t) {
            let ev = self.engine.queue.pop().expect("peeked");
            self.engine_event(ev.at, ev.tag);
        }
        self.engine.queue.advance_to(t);
    }

    /// Drain every pending engine event; returns the clock afterwards.
    pub fn run_to_idle(&mut self) -> SimTime {
        while let Some(ev) = self.engine.queue.pop() {
            self.engine_event(ev.at, ev.tag);
        }
        self.engine.queue.now()
    }

    fn engine_event(&mut self, now: SimTime, t: u64) {
        let (kind, gen, id) = untag(t);
        match kind {
            EV_ARRIVE => {
                self.engine.waiting.push(id);
                self.try_grant(now);
            }
            EV_RELEASE => {
                let live = self
                    .engine
                    .flights
                    .get(id as usize)
                    .is_some_and(|f| f.active && f.gen == gen);
                if live {
                    self.finish_flight(now, id);
                    self.try_grant(now);
                }
            }
            EV_PREEMPT => {
                let live = self
                    .engine
                    .flights
                    .get(id as usize)
                    .is_some_and(|f| f.active && f.gen == gen && now < f.grant_end);
                if live {
                    self.preempt_flight(now, id);
                    self.try_grant(now);
                }
            }
            EV_RETRY => {
                if let Some(f) = self.engine.flights.get_mut(id as usize) {
                    f.retry_at = None;
                }
                self.try_grant(now);
            }
            _ => unreachable!("unknown engine event kind {kind}"),
        }
    }

    /// Grant the wire to every transfer that can start right now.
    fn try_grant(&mut self, now: SimTime) {
        loop {
            let Some(pos) = self.pick_grantable(now) else { break };
            let id = self.engine.waiting.remove(pos);
            self.grant(now, id);
        }
    }

    /// The waiting-queue position of the next transfer to grant:
    /// foreground tier in weighted-fair order first, then background in
    /// arrival order.  Side effects on the blocked: preemption and retry
    /// events get scheduled here.
    fn pick_grantable(&mut self, now: SimTime) -> Option<usize> {
        let mut fg = std::mem::take(&mut self.engine.scratch_fg);
        let mut bg = std::mem::take(&mut self.engine.scratch_bg);
        fg.clear();
        bg.clear();
        for (pos, id) in self.engine.waiting.iter().enumerate() {
            let f = &self.engine.flights[*id as usize];
            if f.pri.is_background() {
                bg.push(pos);
            } else {
                let v = self
                    .class_vtime_of(f.pri.class_key())
                    .max(self.engine.global_vtime);
                fg.push((v, pos));
            }
        }
        fg.sort();
        let mut found = None;
        for &(_, pos) in &fg {
            let id = self.engine.waiting[pos];
            if self.can_grant(now, id) {
                found = Some(pos);
                break;
            }
        }
        if found.is_none() {
            for &pos in &bg {
                let id = self.engine.waiting[pos];
                if self.can_grant(now, id) {
                    found = Some(pos);
                    break;
                }
            }
        }
        self.engine.scratch_fg = fg;
        self.engine.scratch_bg = bg;
        found
    }

    /// Whether `id` can take every link on its path right now.  When it
    /// cannot: remembers the blocking link (for queue-wait attribution),
    /// schedules a frame-quantum preemption for each background holder
    /// in the way of a foreground-tier candidate, and schedules a retry
    /// at the sync lanes' availability time when no engine holder is
    /// involved.
    fn can_grant(&mut self, now: SimTime, id: u64) -> bool {
        let (path_len, fg_tier) = {
            let f = &self.engine.flights[id as usize];
            (f.path.len(), !f.pri.is_background())
        };
        let mut ok = true;
        let mut blocked: Option<LinkClass> = None;
        let mut retry: Option<SimTime> = None;
        let mut preempts: Vec<(u64, SimTime)> = Vec::new();
        for i in 0..path_len {
            let c = self.engine.flights[id as usize].path[i];
            let slot = self.slot_of(c);
            if let Some(holder) = self.holder_of(slot) {
                ok = false;
                blocked = Some(c);
                let hf = &self.engine.flights[holder as usize];
                if fg_tier && hf.pri.is_background() && !hf.preempt_scheduled {
                    let quantum = self.links[slot].frame_quantum(self.mtu);
                    preempts.push((holder, hf.grant_end.min(now + quantum)));
                }
                continue;
            }
            // No engine holder: respect the synchronous lanes' occupancy.
            // Foreground tier waits only on the foreground lane — a
            // *sync* background occupancy would yield within one frame
            // quantum anyway, and engine background holders are handled
            // above by real preemption.  Background tier queues behind
            // everything.
            let q = &self.links[slot];
            let avail = if fg_tier {
                now.max(q.fg_busy_until)
            } else {
                now.max(q.fg_busy_until).max(q.bg_busy_until)
            };
            if avail > now {
                ok = false;
                blocked = Some(c);
                retry = Some(retry.map_or(avail, |r: SimTime| r.max(avail)));
            }
        }
        for (holder, cut) in preempts {
            let hf = self
                .engine
                .flights
                .get_mut(holder as usize)
                .expect("holder exists");
            hf.preempt_scheduled = true;
            let gen = hf.gen;
            self.engine.queue.schedule_at(cut, tag(EV_PREEMPT, gen, holder));
        }
        if !ok {
            let f = self
                .engine
                .flights
                .get_mut(id as usize)
                .expect("candidate exists");
            f.blocked_on = blocked;
            if let Some(at) = retry {
                if f.retry_at.is_none_or(|r| r > at) {
                    f.retry_at = Some(at);
                    self.engine.queue.schedule_at(at, tag(EV_RETRY, 0, id));
                }
            }
        }
        ok
    }

    fn grant(&mut self, now: SimTime, id: u64) {
        let (path_len, pri, remaining, first) = {
            let f = &self.engine.flights[id as usize];
            (f.path.len(), f.pri, f.remaining, f.begin.is_none())
        };
        let mut wire = SimTime::ZERO;
        for i in 0..path_len {
            let c = self.engine.flights[id as usize].path[i];
            wire += self.links[self.slot_of(c)].wire_time(remaining);
        }
        let end = now + wire;
        {
            let f = self
                .engine
                .flights
                .get_mut(id as usize)
                .expect("granted flight exists");
            if first {
                f.begin = Some(now);
            }
            f.grant_begin = now;
            f.grant_end = end;
            f.active = true;
            f.gen += 1;
            f.retry_at = None;
            f.preempt_scheduled = false;
            let gen = f.gen;
            self.engine.queue.schedule_at(end, tag(EV_RELEASE, gen, id));
        }
        for i in 0..path_len {
            let c = self.engine.flights[id as usize].path[i];
            let slot = self.slot_of(c);
            self.set_holder(slot, id);
            let q = &mut self.links[slot];
            if first {
                q.transfers += 1;
            }
            // keep the sync lanes coherent with engine occupancy
            if pri.is_background() {
                q.bg_busy_until = q.bg_busy_until.max(end);
            } else {
                q.fg_busy_until = q.fg_busy_until.max(end);
            }
        }
        if !pri.is_background() {
            // start-time WFQ: the class pays remaining/weight virtual time
            let key = pri.class_key();
            let start = self.class_vtime_of(key).max(self.engine.global_vtime);
            self.set_class_vtime(key, start + (remaining as u128) * 256 / pri.weight() as u128);
            self.engine.global_vtime = start;
        }
    }

    /// A foreground-tier arrival caught an in-flight background transfer:
    /// cut it at the frame-quantum boundary, keep the bytes served so
    /// far, and re-queue the remainder at the front of the line.  Its
    /// eventual receipt is strictly later than the optimistic figure —
    /// this is the re-timing the synchronous path cannot do.
    fn preempt_flight(&mut self, now: SimTime, id: u64) {
        let (path_len, served, old_grant_end) = {
            let f = self
                .engine
                .flights
                .get_mut(id as usize)
                .expect("preempted flight exists");
            let span = f.grant_end.saturating_sub(f.grant_begin).as_ns().max(1);
            let elapsed = now.saturating_sub(f.grant_begin).as_ns();
            let s = ((f.remaining as u128 * elapsed as u128) / span as u128) as u64;
            let served = s.min(f.remaining.saturating_sub(1));
            let old_grant_end = f.grant_end;
            f.remaining -= served;
            f.active = false;
            f.gen += 1; // invalidates the pending release event
            f.preempt_scheduled = false;
            f.retimed = true;
            (f.path.len(), served, old_grant_end)
        };
        for i in 0..path_len {
            let c = self.engine.flights[id as usize].path[i];
            let slot = self.slot_of(c);
            self.clear_holder(slot, id);
            let q = &mut self.links[slot];
            q.bytes += served;
            // roll back exactly our own lane extension so sync callers
            // don't see a phantom background occupancy
            if q.bg_busy_until == old_grant_end {
                q.bg_busy_until = now;
            }
        }
        // the preempted transfer resumes ahead of queued background work
        self.engine.waiting.insert(0, id);
    }

    fn finish_flight(&mut self, now: SimTime, id: u64) {
        let mtu = self.mtu;
        let switch_hop_ns = self.switch_hop_ns;
        let (path_len, served, receipt, pri, retimed) = {
            let f = self
                .engine
                .flights
                .get_mut(id as usize)
                .expect("finished flight exists");
            f.active = false;
            let served = f.remaining;
            f.remaining = 0;
            let begin = f.begin.unwrap_or(f.issued);
            let intranet = f.path.iter().any(|c| c.is_intranet());
            let frames = if intranet {
                f.bytes.div_ceil(mtu as u64).max(1)
            } else {
                0
            };
            let receipt = TransferReceipt {
                issued: f.issued,
                begin,
                finish: now + SimTime::ns(f.hops * switch_hop_ns),
                bytes: f.bytes,
                frames,
            };
            f.done = Some(receipt);
            (f.path.len(), served, receipt, f.pri, f.retimed)
        };
        for i in 0..path_len {
            let c = self.engine.flights[id as usize].path[i];
            let slot = self.slot_of(c);
            self.clear_holder(slot, id);
            self.links[slot].bytes += served;
        }
        let wait = receipt.begin.saturating_sub(receipt.issued);
        if wait > SimTime::ZERO {
            let f = &self.engine.flights[id as usize];
            let blocked = f.blocked_on.or_else(|| f.path.first().copied());
            if let Some(b) = blocked {
                let slot = self.slot_of(b);
                self.links[slot].queue_wait += wait;
            }
        }
        if receipt.frames > 0 {
            self.ether.charge_fabric(receipt.frames);
        }
        if retimed {
            self.stats.retimed_transfers += 1;
        }
        if pri.is_background() {
            self.stats.transfers_bg += 1;
            self.stats.prefetch_bytes += receipt.bytes;
            if receipt.begin == receipt.issued && !retimed {
                self.stats.prefetch_bytes_hidden += receipt.bytes;
            }
        } else {
            self.stats.transfers_fg += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EtherOnConfig, PoolConfig};
    use crate::metrics::{names, Counters};

    fn fabric(nodes_per_array: u32, arrays: u32) -> Fabric {
        Fabric::new(
            &PoolConfig {
                nodes_per_array,
                arrays,
                ..Default::default()
            },
            &EtherOnConfig::default(),
        )
    }

    #[test]
    fn idle_engine_matches_the_estimate() {
        let mut f = fabric(4, 1);
        let est = f.estimate(Endpoint::Node(0), Endpoint::Node(1), 1 << 20);
        let id = f.schedule(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            1 << 20,
            Priority::Foreground,
        );
        assert!(f.receipt_of(id).is_none(), "not complete until the clock passes it");
        f.run_to_idle();
        let r = f.receipt_of(id).unwrap();
        assert_eq!(r.finish, est, "uncontended engine transfer == idle-wire estimate");
        assert_eq!(r.queue_wait(), SimTime::ZERO);
        assert_eq!(r.frames, (1u64 << 20).div_ceil(1500));
    }

    #[test]
    fn same_link_transfers_serialize_in_arrival_order() {
        let mut f = fabric(8, 1);
        let single = f.estimate(Endpoint::Node(0), Endpoint::Node(1), 4 << 20);
        let ids: Vec<TransferId> = (1..=4)
            .map(|i| {
                f.schedule(
                    SimTime::ZERO,
                    Endpoint::Node(0),
                    Endpoint::Node(i),
                    4 << 20,
                    Priority::Foreground,
                )
            })
            .collect();
        f.run_to_idle();
        let finishes: Vec<SimTime> = ids.iter().map(|&i| f.receipt_of(i).unwrap().finish).collect();
        for w in finishes.windows(2) {
            assert!(w[1] > w[0], "{finishes:?}");
        }
        let ratio = finishes[3].as_ns() as f64 / single.as_ns() as f64;
        assert!((3.5..4.5).contains(&ratio), "4 same-link transfers ~4x one: {ratio:.2}");
    }

    #[test]
    fn disjoint_links_overlap_on_the_engine() {
        let mut f = fabric(2, 4);
        let ids: Vec<TransferId> = (0..4)
            .map(|a| {
                f.schedule(
                    SimTime::ZERO,
                    Endpoint::Node(2 * a),
                    Endpoint::Node(2 * a + 1),
                    4 << 20,
                    Priority::Foreground,
                )
            })
            .collect();
        f.run_to_idle();
        let single = f.estimate(Endpoint::Node(0), Endpoint::Node(1), 4 << 20);
        for id in ids {
            assert_eq!(f.receipt_of(id).unwrap().finish, single);
        }
        assert_eq!(f.total_queue_wait(), SimTime::ZERO);
    }

    #[test]
    fn preempted_background_is_retimed_not_optimistic() {
        let mut f = fabric(4, 1);
        let bytes = 64 << 20;
        let optimistic = f.estimate(Endpoint::Node(0), Endpoint::Node(1), bytes);
        let bg = f.schedule(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            bytes,
            Priority::Background,
        );
        // a foreground burst lands mid-flight on the same backplane
        let fg_at = SimTime::ms(2);
        let fg = f.schedule(
            fg_at,
            Endpoint::Node(2),
            Endpoint::Node(3),
            8 << 20,
            Priority::Foreground,
        );
        f.run_to_idle();
        let rb = f.receipt_of(bg).unwrap();
        let rf = f.receipt_of(fg).unwrap();
        assert!(
            rb.finish > optimistic,
            "preempted prefetch must be re-timed: {} !> {optimistic}",
            rb.finish
        );
        // the foreground transfer waited at most one frame quantum
        let quantum = f.link(LinkClass::Array(0)).unwrap().frame_quantum(1500);
        assert!(rf.queue_wait() <= quantum, "fg waited {}", rf.queue_wait());
        assert_eq!(f.stats.retimed_transfers, 1);
        assert_eq!(f.stats.prefetch_bytes_hidden, 0, "a re-timed prefetch was not hidden");
        let mut c = Counters::new();
        f.export_counters(&mut c);
        assert_eq!(c.get(names::FABRIC_RETIMED_TRANSFERS), 1);
        // byte conservation across the preemption split
        assert_eq!(
            c.get(names::FABRIC_BYTES_ARRAY),
            bytes + (8 << 20),
            "served + resumed bytes add up"
        );
    }

    #[test]
    fn unpreempted_background_keeps_its_optimistic_finish() {
        let mut f = fabric(4, 1);
        let optimistic = f.estimate(Endpoint::Node(0), Endpoint::Node(1), 1 << 20);
        let bg = f.schedule(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            1 << 20,
            Priority::Background,
        );
        f.run_to_idle();
        assert_eq!(f.receipt_of(bg).unwrap().finish, optimistic);
        assert_eq!(f.stats.retimed_transfers, 0);
        assert_eq!(f.stats.prefetch_bytes_hidden, 1 << 20);
    }

    #[test]
    fn weighted_tenant_finishes_its_backlog_sooner() {
        // tenant A (weight 3) and tenant B (weight 1) each offer 6 equal
        // transfers at t=0 on one link: A's last finish lands earlier
        let mut f = fabric(4, 1);
        let heavy = Priority::Tenant { id: 0, weight: 3 };
        let light = Priority::Tenant { id: 1, weight: 1 };
        let mut a_ids = Vec::new();
        let mut b_ids = Vec::new();
        for _ in 0..6 {
            let a = f.schedule(SimTime::ZERO, Endpoint::Node(0), Endpoint::Node(1), 1 << 20, heavy);
            let b = f.schedule(SimTime::ZERO, Endpoint::Node(2), Endpoint::Node(3), 1 << 20, light);
            a_ids.push(a);
            b_ids.push(b);
        }
        f.run_to_idle();
        let last = |ids: &[TransferId], f: &Fabric| {
            ids.iter().map(|&i| f.receipt_of(i).unwrap().finish).max().unwrap()
        };
        let a_done = last(&a_ids, &f);
        let b_done = last(&b_ids, &f);
        assert!(
            a_done < b_done,
            "weight-3 tenant backlog ({a_done}) should clear before weight-1 ({b_done})"
        );
    }

    #[test]
    fn advance_to_resolves_only_the_past() {
        let mut f = fabric(4, 1);
        let id = f.schedule(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            32 << 20,
            Priority::Foreground,
        );
        let est = f.estimate(Endpoint::Node(0), Endpoint::Node(1), 32 << 20);
        f.advance_to(SimTime::us(1));
        assert!(f.receipt_of(id).is_none(), "still in flight at 1us");
        assert_eq!(f.transfers_in_flight(), 1);
        f.advance_to(est + SimTime::us(1));
        assert!(f.receipt_of(id).is_some());
        assert_eq!(f.transfers_in_flight(), 0);
        assert_eq!(f.engine_now(), est + SimTime::us(1));
    }

    #[test]
    fn settle_resolves_one_flight_without_draining_the_future() {
        let mut f = fabric(4, 1);
        let a = f.schedule(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            4 << 20,
            Priority::Foreground,
        );
        // a far-future transfer must not be dragged in by settling `a`
        let b = f.schedule(
            SimTime::ms(50),
            Endpoint::Node(2),
            Endpoint::Node(3),
            4 << 20,
            Priority::Foreground,
        );
        let ra = f.settle(a).expect("scheduled flight settles");
        assert_eq!(
            ra.finish,
            f.estimate(Endpoint::Node(0), Endpoint::Node(1), 4 << 20),
            "uncontended settle matches the idle-wire estimate"
        );
        assert!(f.receipt_of(b).is_none(), "future flight stays in flight");
        assert!(f.engine_now() < SimTime::ms(50), "clock advanced only as far as needed");
        assert!(f.settle(b).unwrap().finish > ra.finish);
        assert!(f.settle(TransferId(9999)).is_none(), "unknown id is None, not a hang");
        // settling twice is idempotent
        assert_eq!(f.settle(a), Some(ra));
    }

    #[test]
    fn same_endpoint_schedule_is_free() {
        let mut f = fabric(4, 1);
        let id = f.schedule(
            SimTime::us(3),
            Endpoint::Host,
            Endpoint::Host,
            1 << 20,
            Priority::Foreground,
        );
        let r = f.receipt_of(id).unwrap();
        assert_eq!(r.latency(), SimTime::ZERO);
    }

    #[test]
    fn engine_and_sync_traffic_share_the_lanes() {
        let mut f = fabric(4, 1);
        // sync foreground transfer occupies the backplane first
        let sync = f.transfer(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            8 << 20,
            Priority::Foreground,
        );
        // an engine transfer scheduled at t=0 must queue behind it
        let id = f.schedule(
            SimTime::ZERO,
            Endpoint::Node(2),
            Endpoint::Node(3),
            1 << 20,
            Priority::Foreground,
        );
        f.run_to_idle();
        let r = f.receipt_of(id).unwrap();
        assert!(
            r.begin >= sync.finish.saturating_sub(SimTime::ns(300)),
            "engine transfer overlapped a sync grant: {} vs {}",
            r.begin,
            sync.finish
        );
        // and the reverse: sync sees engine occupancy through the lanes
        let id2 = f.schedule(
            f.engine_now(),
            Endpoint::Node(0),
            Endpoint::Node(1),
            8 << 20,
            Priority::Foreground,
        );
        let now = f.engine_now();
        f.advance_to(now + SimTime::us(1)); // grant it
        let sync2 = f.transfer(
            now + SimTime::us(1),
            Endpoint::Node(2),
            Endpoint::Node(3),
            1 << 20,
            Priority::Foreground,
        );
        f.run_to_idle();
        let r2 = f.receipt_of(id2).unwrap();
        assert!(sync2.begin >= r2.finish.saturating_sub(SimTime::ns(300)));
    }
}
