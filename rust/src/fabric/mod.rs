//! Pool-wide message fabric: every cross-node and host/WAN byte in the
//! system is routed through [`Fabric::transfer`].
//!
//! The paper's headline claims rest on Ethernet over NVMe being the
//! *shared* medium for all pool traffic, so the fabric models the wire
//! instead of letting each subsystem assume an idle one.  A transfer
//! between two endpoints crosses an ordered path of [`LinkClass`]
//! contention domains (same-array switch backplane, cross-array tray,
//! host uplink, registry WAN); each domain is a busy-until bandwidth
//! queue, so overlapping transfers on a shared link serialize while
//! transfers on disjoint links overlap.
//!
//! Traffic paths by subsystem:
//!
//! * `layerstore::PoolLayerCache` — peer layer fetches cross `Array`
//!   (and `Tray` when cross-array); registry pulls cross `RegistryWan`
//!   + `HostUplink` + `Array`.
//! * `pool::Orchestrator` — placement scoring uses [`Fabric::estimate`];
//!   placement kicks off `Background` prefetches for missing layers.
//! * `llm::disagg` — tensor-parallel all-reduce and pipeline boundary
//!   hops cross `Array`/`Tray`; host-coordinated models also cross
//!   `HostUplink` per step; the D-* prefill→decode KV handoff is a
//!   pipelined device-to-device [`stream`] over `Array` (+ `Tray`).
//! * `coordinator` — request dispatch (control + live prompt ingress)
//!   and response control cross `HostUplink` + `Array`; KV migrations
//!   and session handoff are node-to-node [`stream`]s that never touch
//!   the uplink (`fabric.bytes_p2p`).
//!
//! Two scheduling tiers exist per link: the foreground tier
//! ([`Priority::Foreground`] plus weighted [`Priority::Tenant`] QoS
//! classes) and `Background` (prefetch).  A background transfer holds
//! the wire for at most one MTU frame quantum once foreground traffic
//! arrives, then yields and resumes after — so prefetch can never delay
//! a foreground fetch by more than one frame time per link.
//!
//! Two ways to put bytes on the wire:
//!
//! * [`Fabric::transfer`] — synchronous busy-until arithmetic.  Exact
//!   for foreground traffic issued in nondecreasing time order (which is
//!   how every event-loop caller issues it); for a background transfer
//!   later preempted by foreground traffic the receipt it already
//!   returned is an optimistic lower bound.
//! * [`Fabric::schedule`] + [`Fabric::advance_to`]/[`Fabric::run_to_idle`]/
//!   [`Fabric::settle`] — the event-driven engine (see [`sched`]):
//!   transfers become arrival/release/preemption events at frame-quantum
//!   granularity on a [`crate::sim::EventQueue`], a preempted background
//!   transfer is *re-timed* instead of keeping its optimistic receipt,
//!   and concurrent foreground-tier tenants share a contended link by
//!   weight.  `settle` resolves one scheduled transfer without draining
//!   unrelated future events — how the layerstore waits on an in-flight
//!   chunk prefetch.  This closes the ROADMAP retro-causality item, and
//!   since the chunk-granular layerstore refactor every
//!   [`crate::layerstore::PoolLayerCache::prefetch`] rides it.
//!
//! Intranet traffic (`Array`/`Tray` links) is frame-accounted against
//! the Ether-oN driver path: each transfer is chopped into MTU frames
//! and charged to [`EtherOnStats`] as TransmitFrame/ReceiveFrame pairs.

pub mod link;
pub mod sched;
pub mod stream;

pub use link::{LinkClass, LinkQueue, Priority};
pub use sched::TransferId;
pub use stream::{StreamHandle, StreamReceipt, DEFAULT_QUANTUM, KV_STREAM_CLASS};

use std::collections::BTreeMap;

use crate::config::{EtherOnConfig, PoolConfig, SystemConfig};
use crate::etheron::EtherOnStats;
use crate::metrics::{names, Counters};
use crate::pool::topology::NodeId;
use crate::util::SimTime;

/// A transfer endpoint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// A DockerSSD in the pool.
    Node(NodeId),
    /// The host hanging off the switch tray.
    Host,
    /// The container registry beyond the host (a "user-defined
    /// location" across the WAN).
    Registry,
}

/// What the fabric granted one transfer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransferReceipt {
    /// When the transfer was requested.
    pub issued: SimTime,
    /// When the last contended link granted the wire.
    pub begin: SimTime,
    /// When the final byte arrived.
    pub finish: SimTime,
    pub bytes: u64,
    /// MTU frames charged to the Ether-oN path (0 for non-intranet paths).
    pub frames: u64,
}

impl TransferReceipt {
    /// A zero-byte, zero-latency receipt (local hit: nothing crossed the
    /// fabric).
    pub fn immediate(now: SimTime) -> Self {
        TransferReceipt {
            issued: now,
            begin: now,
            finish: now,
            bytes: 0,
            frames: 0,
        }
    }

    /// End-to-end latency the requester observed.
    pub fn latency(&self) -> SimTime {
        self.finish.saturating_sub(self.issued)
    }

    /// Time spent queued behind other traffic before the wire was granted.
    pub fn queue_wait(&self) -> SimTime {
        self.begin.saturating_sub(self.issued)
    }
}

/// Fabric-wide accounting beyond the per-link queues.
#[derive(Clone, Copy, Debug, Default)]
pub struct FabricStats {
    pub transfers_fg: u64,
    pub transfers_bg: u64,
    /// Bytes moved by background prefetch.
    pub prefetch_bytes: u64,
    /// Prefetch bytes that started with zero queue wait — fully hidden
    /// behind otherwise-idle links.
    pub prefetch_bytes_hidden: u64,
    /// Engine transfers whose completion was re-timed by a preemption.
    pub retimed_transfers: u64,
    /// Times a link entered a degraded-bandwidth window (a flap).
    pub link_flaps: u64,
    /// Total time links spent degraded, accumulated as windows close.
    pub brownout_ns: u64,
    /// Bytes streamed device-to-device (both endpoints pool nodes).
    pub bytes_p2p: u64,
    /// Chunk quanta issued by [`stream`] pipelines.
    pub stream_quanta: u64,
    /// Consumer head start settled streams exposed (see
    /// [`StreamReceipt::overlap`]).
    pub stream_overlap_ns: u64,
}

/// The pool fabric: link queues indexed by a dense per-class slot
/// (`Array(0..arrays)`, then `Tray`, `HostUplink`, `RegistryWan`) so the
/// hot transfer path never hashes or walks a tree to find a link.
pub struct Fabric {
    nodes_per_array: u32,
    total_nodes: u32,
    /// Arrays in the pool — the dense index stride: `Array(i)` lives at
    /// slot `i`, the three fixed classes right after.
    arrays: u32,
    switch_hop_ns: u64,
    mtu: u32,
    link_gbps: f64,
    tray_gbps: f64,
    host_gbps: f64,
    wan_gbps: f64,
    links: Vec<LinkQueue>,
    /// Whether each slot's link has ever carried (or been offered)
    /// traffic.  Un-ensured links stay invisible to [`Fabric::link`] and
    /// counter export, exactly like the absent map entries they replace.
    ensured: Vec<bool>,
    /// Slot index back to its class, for counter export.
    classes: Vec<LinkClass>,
    /// Out-of-topology classes (an `Array(x)` beyond the configured
    /// arrays) interned past the fixed slots — never on the hot path.
    exotic: BTreeMap<LinkClass, usize>,
    /// Links currently in a degraded-bandwidth window: when the window
    /// opened and the full-rate bandwidth to restore on close.
    brownouts: BTreeMap<LinkClass, (SimTime, f64)>,
    /// Reusable path buffer so `transfer` does not allocate per call.
    path_scratch: Vec<LinkClass>,
    pub stats: FabricStats,
    /// Frame-level accounting charged to the Ether-oN driver path for
    /// intranet traffic.
    pub ether: EtherOnStats,
    /// The event-driven transfer scheduler (see [`sched`]).
    pub(crate) engine: sched::Engine,
}

impl Fabric {
    pub fn new(pool: &PoolConfig, etheron: &EtherOnConfig) -> Self {
        let nodes_per_array = pool.nodes_per_array.max(1);
        let total_nodes = pool.total_nodes();
        let arrays = total_nodes.div_ceil(nodes_per_array);
        let mut classes: Vec<LinkClass> = (0..arrays).map(LinkClass::Array).collect();
        classes.extend([LinkClass::Tray, LinkClass::HostUplink, LinkClass::RegistryWan]);
        let mut f = Fabric {
            nodes_per_array,
            total_nodes,
            arrays,
            switch_hop_ns: pool.switch_hop_ns,
            mtu: etheron.mtu.max(1),
            link_gbps: pool.link_gbps,
            tray_gbps: pool.tray_gbps,
            host_gbps: pool.host_gbps,
            wan_gbps: pool.wan_gbps,
            links: Vec::new(),
            ensured: vec![false; classes.len()],
            classes,
            exotic: BTreeMap::new(),
            brownouts: BTreeMap::new(),
            path_scratch: Vec::new(),
            stats: FabricStats::default(),
            ether: EtherOnStats::default(),
            engine: sched::Engine::default(),
        };
        f.links = f.classes.iter().map(|&c| LinkQueue::new(f.gbps_of(c))).collect();
        f
    }

    pub fn of(cfg: &SystemConfig) -> Self {
        Self::new(&cfg.pool, &cfg.etheron)
    }

    fn gbps_of(&self, class: LinkClass) -> f64 {
        match class {
            LinkClass::Array(_) => self.link_gbps,
            LinkClass::Tray => self.tray_gbps,
            LinkClass::HostUplink => self.host_gbps,
            LinkClass::RegistryWan => self.wan_gbps,
        }
    }

    /// The dense slot of `class`, if it is part of the topology (or has
    /// been interned as an exotic class).
    pub(crate) fn link_idx(&self, class: LinkClass) -> Option<usize> {
        let a = self.arrays as usize;
        match class {
            LinkClass::Array(x) if (x as usize) < a => Some(x as usize),
            LinkClass::Tray => Some(a),
            LinkClass::HostUplink => Some(a + 1),
            LinkClass::RegistryWan => Some(a + 2),
            LinkClass::Array(_) => self.exotic.get(&class).copied(),
        }
    }

    /// The dense slot of `class`, interning an out-of-topology class on
    /// first sight.
    fn intern_link(&mut self, class: LinkClass) -> usize {
        if let Some(idx) = self.link_idx(class) {
            return idx;
        }
        let idx = self.links.len();
        self.links.push(LinkQueue::new(self.gbps_of(class)));
        self.ensured.push(false);
        self.classes.push(class);
        self.exotic.insert(class, idx);
        idx
    }

    fn ensure_link(&mut self, class: LinkClass) -> usize {
        let idx = self.intern_link(class);
        self.ensured[idx] = true;
        idx
    }

    /// The array a node sits behind, if the id names a real node.
    ///
    /// NOTE: this mapping and `node_path` below mirror the layout rules
    /// of [`crate::pool::topology::PoolTopology`] (`build`/`hops`),
    /// including the worst-case fallback for unknown ids — change them
    /// together.
    fn array_of(&self, n: NodeId) -> Option<u32> {
        (n < self.total_nodes).then_some(n / self.nodes_per_array)
    }

    fn node_path_into(&self, a: NodeId, b: NodeId, out: &mut Vec<LinkClass>) -> u64 {
        if a == b {
            return 0;
        }
        match (self.array_of(a), self.array_of(b)) {
            (Some(x), Some(y)) if x == y => {
                out.push(LinkClass::Array(x));
                1
            }
            (Some(x), Some(y)) => {
                out.extend([LinkClass::Array(x), LinkClass::Tray, LinkClass::Array(y)]);
                3
            }
            // Unknown endpoint: assume the worst-case cross-array path so
            // an out-of-range node id is never a free transfer.
            (Some(x), None) | (None, Some(x)) => {
                out.extend([LinkClass::Array(x), LinkClass::Tray]);
                3
            }
            (None, None) => {
                out.push(LinkClass::Tray);
                3
            }
        }
    }

    /// Fill `out` with the ordered link classes a transfer crosses and
    /// return the switch-hop count — the allocation-free core of
    /// [`Fabric::path`] the hot transfer path uses with a scratch buffer.
    fn path_into(&self, from: Endpoint, to: Endpoint, out: &mut Vec<LinkClass>) -> u64 {
        out.clear();
        match (from, to) {
            (Endpoint::Node(a), Endpoint::Node(b)) => self.node_path_into(a, b, out),
            (Endpoint::Host, Endpoint::Node(n)) | (Endpoint::Node(n), Endpoint::Host) => {
                out.push(LinkClass::HostUplink);
                match self.array_of(n) {
                    Some(arr) => out.push(LinkClass::Array(arr)),
                    // unknown node: worst case, route through the tray
                    None => out.push(LinkClass::Tray),
                }
                2
            }
            (Endpoint::Registry, Endpoint::Node(n)) | (Endpoint::Node(n), Endpoint::Registry) => {
                out.push(LinkClass::RegistryWan);
                out.push(LinkClass::HostUplink);
                match self.array_of(n) {
                    Some(arr) => out.push(LinkClass::Array(arr)),
                    None => out.push(LinkClass::Tray),
                }
                2
            }
            (Endpoint::Host, Endpoint::Registry) | (Endpoint::Registry, Endpoint::Host) => {
                out.extend([LinkClass::RegistryWan, LinkClass::HostUplink]);
                1
            }
            (Endpoint::Host, Endpoint::Host) | (Endpoint::Registry, Endpoint::Registry) => 0,
        }
    }

    /// The ordered link classes a transfer crosses, plus the switch-hop
    /// count charged per-hop latency.
    pub fn path(&self, from: Endpoint, to: Endpoint) -> (Vec<LinkClass>, u64) {
        let mut links = Vec::new();
        let hops = self.path_into(from, to, &mut links);
        (links, hops)
    }

    /// Idle-wire latency: per-hop switch latency plus store-and-forward
    /// wire time on each link class, ignoring queue occupancy.  This is
    /// the *planning* cost (placement scoring, fetch-source choice);
    /// [`Fabric::transfer`] is the only way to observe — and create —
    /// contention.
    pub fn estimate(&self, from: Endpoint, to: Endpoint, bytes: u64) -> SimTime {
        let (links, hops) = self.path(from, to);
        let mut t = SimTime::ns(hops * self.switch_hop_ns);
        for c in links {
            t += SimTime::ns((bytes as f64 / self.gbps_of(c)) as u64);
        }
        t
    }

    /// Idle-wire cost of moving `bytes` one same-array hop — the unit
    /// the orchestrator uses to weigh queued replicas against missing
    /// layers.
    pub fn unit_cost(&self, bytes: u64) -> SimTime {
        SimTime::ns(self.switch_hop_ns + (bytes as f64 / self.link_gbps) as u64)
    }

    /// Move `bytes` from `from` to `to`, contending with every transfer
    /// already granted the shared links.  Returns when the wire was
    /// granted and when the last byte landed.
    pub fn transfer(
        &mut self,
        now: SimTime,
        from: Endpoint,
        to: Endpoint,
        bytes: u64,
        pri: Priority,
    ) -> TransferReceipt {
        let mut path = std::mem::take(&mut self.path_scratch);
        let hops = self.path_into(from, to, &mut path);
        if path.is_empty() {
            self.path_scratch = path;
            return TransferReceipt {
                issued: now,
                begin: now,
                finish: now,
                bytes,
                frames: 0,
            };
        }
        // resolve each class to its dense slot once, up front
        let mut idxs = [0usize; 4];
        for (i, &c) in path.iter().enumerate() {
            idxs[i] = self.ensure_link(c);
        }
        let slots = &idxs[..path.len()];

        // wire grant: wait for earlier traffic on every shared link,
        // remembering which link the grant ultimately waited on
        let mut begin = now;
        let mut bottleneck: Option<usize> = None;
        if pri.is_background() {
            for &li in slots {
                let q = &self.links[li];
                let avail = q.fg_busy_until.max(q.bg_busy_until);
                if avail > begin {
                    begin = avail;
                    bottleneck = Some(li);
                }
            }
        } else {
            for &li in slots {
                let avail = self.links[li].fg_busy_until;
                if avail > begin {
                    begin = avail;
                    bottleneck = Some(li);
                }
            }
            // an in-flight background transfer finishes its current
            // frame quantum, then yields the wire
            let fg_begin = begin;
            for &li in slots {
                let q = &self.links[li];
                if q.bg_busy_until > begin {
                    let capped = q.bg_busy_until.min(fg_begin + q.frame_quantum(self.mtu));
                    if capped > begin {
                        begin = capped;
                        bottleneck = Some(li);
                    }
                }
            }
        }

        // occupy each link for this transfer's serialization time; the
        // queue wait is charged once, to the link that caused it
        let mut wire = SimTime::ZERO;
        let mut intranet = false;
        for (i, &li) in slots.iter().enumerate() {
            let q = &mut self.links[li];
            wire += q.wire_time(bytes);
            q.occupy(pri, begin, bytes);
            intranet |= path[i].is_intranet();
        }
        let wait = begin.saturating_sub(now);
        if wait > SimTime::ZERO {
            if let Some(b) = bottleneck {
                self.links[b].queue_wait += wait;
            }
        }
        let finish = begin + SimTime::ns(hops * self.switch_hop_ns) + wire;
        self.path_scratch = path;

        let frames = if intranet {
            let f = bytes.div_ceil(self.mtu as u64).max(1);
            self.ether.charge_fabric(f);
            f
        } else {
            0
        };
        if pri.is_background() {
            self.stats.transfers_bg += 1;
            self.stats.prefetch_bytes += bytes;
            if begin == now {
                self.stats.prefetch_bytes_hidden += bytes;
            }
        } else {
            self.stats.transfers_fg += 1;
        }

        TransferReceipt {
            issued: now,
            begin,
            finish,
            bytes,
            frames,
        }
    }

    /// Open a degraded-bandwidth window on `class`: the link keeps
    /// `keep_pct`% of its configured bandwidth until [`Fabric::end_brownout`].
    /// Both the synchronous path and the event-driven engine price wire
    /// time from the live link bandwidth at grant time, so every grant
    /// inside the window pays the degraded rate; [`Fabric::estimate`]
    /// stays on the configured rate — planning is deliberately blind to
    /// transient brownouts, the same way placement scoring ignores
    /// queue occupancy.  Re-opening an already-degraded link closes the
    /// prior window first, so each call counts as one flap.
    pub fn begin_brownout(&mut self, now: SimTime, class: LinkClass, keep_pct: u32) {
        self.end_brownout(now, class);
        let idx = self.ensure_link(class);
        let base = self.gbps_of(class);
        let keep = keep_pct.clamp(1, 100);
        self.links[idx].gbps = base * keep as f64 / 100.0;
        self.brownouts.insert(class, (now, base));
        self.stats.link_flaps += 1;
    }

    /// Close the degraded-bandwidth window on `class`, restoring the
    /// configured bandwidth and accumulating the window's duration into
    /// `fabric.brownout_ns`.  A link with no open window is a no-op.
    pub fn end_brownout(&mut self, now: SimTime, class: LinkClass) {
        if let Some((since, base)) = self.brownouts.remove(&class) {
            self.stats.brownout_ns += now.saturating_sub(since).as_ns();
            let idx = self.link_idx(class).expect("degraded link exists");
            self.links[idx].gbps = base;
        }
    }

    /// Whether `class` is currently inside a degraded-bandwidth window.
    pub fn brownout_active(&self, class: LinkClass) -> bool {
        self.brownouts.contains_key(&class)
    }

    /// Per-link state, for tests and reporting.  Only links that have
    /// carried (or been offered) traffic are visible, matching the old
    /// lazily-populated map.
    pub fn link(&self, class: LinkClass) -> Option<&LinkQueue> {
        let idx = self.link_idx(class)?;
        self.ensured[idx].then(|| &self.links[idx])
    }

    /// Total queue-wait accumulated across all links.
    pub fn total_queue_wait(&self) -> SimTime {
        let mut t = SimTime::ZERO;
        for (idx, q) in self.links.iter().enumerate() {
            if self.ensured[idx] {
                t += q.queue_wait;
            }
        }
        t
    }

    pub fn export_counters(&self, c: &mut Counters) {
        for (idx, q) in self.links.iter().enumerate() {
            if !self.ensured[idx] {
                continue;
            }
            let key = match self.classes[idx] {
                LinkClass::Array(_) => names::FABRIC_BYTES_ARRAY,
                LinkClass::Tray => names::FABRIC_BYTES_TRAY,
                LinkClass::HostUplink => names::FABRIC_BYTES_HOST_UPLINK,
                LinkClass::RegistryWan => names::FABRIC_BYTES_WAN,
            };
            c.add(key, q.bytes);
            c.add(names::FABRIC_QUEUE_WAIT_NS, q.queue_wait.as_ns());
        }
        c.add(names::FABRIC_TRANSFERS, self.stats.transfers_fg + self.stats.transfers_bg);
        c.add(names::FABRIC_FRAMES, self.ether.tx_frames);
        c.add(names::FABRIC_PREFETCH_BYTES, self.stats.prefetch_bytes);
        c.add(names::FABRIC_PREFETCH_HIDDEN, self.stats.prefetch_bytes_hidden);
        c.add(names::FABRIC_RETIMED_TRANSFERS, self.stats.retimed_transfers);
        c.add(names::FABRIC_LINK_FLAPS, self.stats.link_flaps);
        c.add(names::FABRIC_BROWNOUT_NS, self.stats.brownout_ns);
        c.add(names::FABRIC_BYTES_P2P, self.stats.bytes_p2p);
        c.add(names::FABRIC_STREAM_QUANTA, self.stats.stream_quanta);
        c.add(names::FABRIC_STREAM_OVERLAP_NS, self.stats.stream_overlap_ns);
        c.add(names::SIM_CLAMPED_EVENTS, self.engine_clamped_events());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric(nodes_per_array: u32, arrays: u32) -> Fabric {
        Fabric::new(
            &PoolConfig {
                nodes_per_array,
                arrays,
                ..Default::default()
            },
            &EtherOnConfig::default(),
        )
    }

    #[test]
    fn paths_follow_topology() {
        let f = fabric(4, 2);
        let (p, h) = f.path(Endpoint::Node(0), Endpoint::Node(1));
        assert_eq!(p, vec![LinkClass::Array(0)]);
        assert_eq!(h, 1);
        let (p, h) = f.path(Endpoint::Node(0), Endpoint::Node(5));
        assert_eq!(p, vec![LinkClass::Array(0), LinkClass::Tray, LinkClass::Array(1)]);
        assert_eq!(h, 3);
        let (p, _) = f.path(Endpoint::Host, Endpoint::Node(6));
        assert_eq!(p, vec![LinkClass::HostUplink, LinkClass::Array(1)]);
        let (p, _) = f.path(Endpoint::Registry, Endpoint::Node(0));
        assert_eq!(
            p,
            vec![LinkClass::RegistryWan, LinkClass::HostUplink, LinkClass::Array(0)]
        );
    }

    #[test]
    fn unknown_node_pays_worst_case_not_zero() {
        let f = fabric(4, 1);
        let known = f.estimate(Endpoint::Node(0), Endpoint::Node(1), 4096);
        let unknown = f.estimate(Endpoint::Node(0), Endpoint::Node(999), 4096);
        assert!(unknown > known, "out-of-range node must not be a free transfer");
        assert!(f.estimate(Endpoint::Host, Endpoint::Node(999), 4096) > SimTime::ZERO);
    }

    #[test]
    fn same_endpoint_is_free() {
        let mut f = fabric(4, 1);
        assert_eq!(f.estimate(Endpoint::Node(2), Endpoint::Node(2), 1 << 20), SimTime::ZERO);
        let r = f.transfer(
            SimTime::us(5),
            Endpoint::Host,
            Endpoint::Host,
            1 << 20,
            Priority::Foreground,
        );
        assert_eq!(r.latency(), SimTime::ZERO);
    }

    #[test]
    fn registry_dearer_than_peer() {
        let f = fabric(4, 1);
        let peer = f.estimate(Endpoint::Node(1), Endpoint::Node(0), 1 << 20);
        let wan = f.estimate(Endpoint::Registry, Endpoint::Node(0), 1 << 20);
        assert!(wan > peer.scale(4.0), "WAN {wan} vs peer {peer}");
    }

    #[test]
    fn shared_link_serializes_disjoint_links_overlap() {
        let bytes = 8 << 20;
        let n = 4u32;
        // shared: node 0 feeds nodes 1..=4 over one array backplane
        let mut f = fabric(8, 1);
        let single = f.estimate(Endpoint::Node(0), Endpoint::Node(1), bytes);
        let mut shared = SimTime::ZERO;
        for i in 1..=n {
            let r = f.transfer(
                SimTime::ZERO,
                Endpoint::Node(0),
                Endpoint::Node(i),
                bytes,
                Priority::Foreground,
            );
            shared = shared.max(r.finish);
        }
        // disjoint: one pair per array
        let mut f2 = fabric(2, n);
        let mut disjoint = SimTime::ZERO;
        for a in 0..n {
            let r = f2.transfer(
                SimTime::ZERO,
                Endpoint::Node(2 * a),
                Endpoint::Node(2 * a + 1),
                bytes,
                Priority::Foreground,
            );
            disjoint = disjoint.max(r.finish);
        }
        let ratio = shared.as_ns() as f64 / single.as_ns() as f64;
        assert!((3.5..4.5).contains(&ratio), "shared/single = {ratio}");
        assert!(disjoint.as_ns() as f64 / single.as_ns() as f64 <= 1.1);
        assert!(f.total_queue_wait() > SimTime::ZERO);
        assert_eq!(f2.total_queue_wait(), SimTime::ZERO);
    }

    #[test]
    fn background_yields_within_one_frame_quantum() {
        let mut f = fabric(4, 1);
        // a large prefetch is mid-flight on the array link
        f.transfer(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            64 << 20,
            Priority::Background,
        );
        let quantum = f
            .link(LinkClass::Array(0))
            .unwrap()
            .frame_quantum(EtherOnConfig::default().mtu);
        let r = f.transfer(
            SimTime::ZERO,
            Endpoint::Node(2),
            Endpoint::Node(3),
            1 << 20,
            Priority::Foreground,
        );
        assert!(
            r.queue_wait() <= quantum,
            "foreground waited {} > one frame quantum {}",
            r.queue_wait(),
            quantum
        );
    }

    #[test]
    fn background_queues_behind_everything() {
        let mut f = fabric(4, 1);
        let fg = f.transfer(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            8 << 20,
            Priority::Foreground,
        );
        let bg = f.transfer(
            SimTime::ZERO,
            Endpoint::Node(2),
            Endpoint::Node(3),
            1 << 20,
            Priority::Background,
        );
        assert!(bg.begin >= fg.finish.saturating_sub(SimTime::ns(3 * 300)));
        assert_eq!(f.stats.transfers_bg, 1);
        assert_eq!(f.stats.prefetch_bytes, 1 << 20);
        assert_eq!(f.stats.prefetch_bytes_hidden, 0, "queued prefetch is not hidden");
    }

    #[test]
    fn brownout_degrades_live_wire_time_then_restores() {
        let mut f = fabric(4, 1);
        let healthy = f.transfer(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            8 << 20,
            Priority::Foreground,
        );
        // a 10%-bandwidth window makes the same transfer ~10x slower
        let t1 = f.link(LinkClass::Array(0)).unwrap().fg_busy_until;
        f.begin_brownout(t1, LinkClass::Array(0), 10);
        assert!(f.brownout_active(LinkClass::Array(0)));
        let degraded = f.transfer(t1, Endpoint::Node(0), Endpoint::Node(1), 8 << 20, Priority::Foreground);
        let ratio = degraded.latency().as_ns() as f64 / healthy.latency().as_ns() as f64;
        assert!((8.0..12.0).contains(&ratio), "degraded/healthy = {ratio:.2}");
        // restore: bandwidth and latency come back, duration accumulates
        let t2 = degraded.finish;
        f.end_brownout(t2, LinkClass::Array(0));
        assert!(!f.brownout_active(LinkClass::Array(0)));
        let restored = f.transfer(t2, Endpoint::Node(0), Endpoint::Node(1), 8 << 20, Priority::Foreground);
        assert_eq!(restored.latency(), healthy.latency());
        assert_eq!(f.stats.link_flaps, 1);
        assert_eq!(f.stats.brownout_ns, (t2 - t1).as_ns());
    }

    #[test]
    fn reopened_brownout_counts_two_flaps_and_splits_the_window() {
        let mut f = fabric(4, 1);
        f.begin_brownout(SimTime::ms(1), LinkClass::Tray, 50);
        f.begin_brownout(SimTime::ms(3), LinkClass::Tray, 20);
        f.end_brownout(SimTime::ms(6), LinkClass::Tray);
        f.end_brownout(SimTime::ms(9), LinkClass::Tray); // no window: no-op
        assert_eq!(f.stats.link_flaps, 2);
        assert_eq!(f.stats.brownout_ns, SimTime::ms(5).as_ns());
        // bandwidth restored to the configured rate, not 50% of it
        let idle = Fabric::new(&PoolConfig::default(), &EtherOnConfig::default());
        assert_eq!(f.link(LinkClass::Tray).unwrap().gbps, idle.gbps_of(LinkClass::Tray));
        let mut c = Counters::new();
        f.export_counters(&mut c);
        assert_eq!(c.get(names::FABRIC_LINK_FLAPS), 2);
        assert_eq!(c.get(names::FABRIC_BROWNOUT_NS), SimTime::ms(5).as_ns());
    }

    #[test]
    fn brownout_prices_engine_grants_too() {
        let mut f = fabric(4, 1);
        let quiet = f.estimate(Endpoint::Node(0), Endpoint::Node(1), 8 << 20);
        f.begin_brownout(SimTime::ZERO, LinkClass::Array(0), 10);
        let id = f.schedule(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            8 << 20,
            Priority::Foreground,
        );
        f.run_to_idle();
        let r = f.receipt_of(id).unwrap();
        assert!(
            r.finish > quiet.scale(5.0),
            "engine grant inside the window pays the degraded rate: {} vs {quiet}",
            r.finish
        );
    }

    #[test]
    fn intranet_traffic_charges_etheron_frames() {
        let mut f = fabric(4, 1);
        let r = f.transfer(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            150_000,
            Priority::Foreground,
        );
        assert_eq!(r.frames, 100); // 150_000 / mtu 1500
        assert_eq!(f.ether.tx_frames, 100);
        assert_eq!(f.ether.rx_frames, 100);
    }

    #[test]
    fn counters_export_under_canonical_names() {
        let mut f = fabric(4, 2);
        f.transfer(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(7),
            1 << 20,
            Priority::Foreground,
        );
        f.transfer(
            SimTime::ZERO,
            Endpoint::Registry,
            Endpoint::Node(0),
            1 << 10,
            Priority::Background,
        );
        let mut c = Counters::new();
        f.export_counters(&mut c);
        assert!(c.get(names::FABRIC_BYTES_ARRAY) >= 2 << 20, "both array hops counted");
        assert_eq!(c.get(names::FABRIC_BYTES_TRAY), 1 << 20);
        assert_eq!(c.get(names::FABRIC_BYTES_WAN), 1 << 10);
        assert_eq!(c.get(names::FABRIC_BYTES_HOST_UPLINK), 1 << 10);
        assert_eq!(c.get(names::FABRIC_TRANSFERS), 2);
        assert_eq!(c.get(names::FABRIC_PREFETCH_BYTES), 1 << 10);
        assert!(c.get(names::FABRIC_FRAMES) > 0);
    }
}
