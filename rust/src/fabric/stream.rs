//! Device-to-device streams: a logical transfer carried as a pipeline
//! of fixed-size chunk quanta on the event-driven engine.
//!
//! A monolithic [`Fabric::transfer`] delivers nothing until its last
//! byte lands, and while granted it holds the wire against every other
//! foreground transfer.  A stream splits the same bytes into
//! [`StreamHandle::quanta`] chunk quanta, each scheduled with
//! [`Fabric::schedule`], so:
//!
//! * the consumer can start on quantum `i` while quantum `i+1` is still
//!   on the wire ([`StreamReceipt::pipelined_finish`] prices exactly
//!   that overlap — the disaggregated prefill→decode KV handoff in
//!   [`crate::llm::disagg`] rides it);
//! * quanta are granted through the engine's per-tenant WFQ classes, so
//!   a long KV stream shares a contended backplane with dispatch
//!   traffic by weight instead of holding it for the whole transfer.
//!
//! A stream never finishes *earlier* than the equivalent monolithic
//! transfer (same bytes, same wire; quantization only adds boundaries —
//! the property suite pins this), but everything already delivered is
//! usable while the tail is still in flight, and that head start is
//! what `fabric.stream_overlap_ns` accounts.
//!
//! Both endpoints in the pool ⇒ the bytes count as `fabric.bytes_p2p`:
//! device-to-device traffic that never touched the host uplink.

use super::sched::TransferId;
use super::{Endpoint, Fabric, Priority, TransferReceipt};
use crate::util::SimTime;

/// Default chunk quantum: 256 KiB, a few hundred MTU frames — small
/// enough to pipeline KV-sized transfers, large enough that per-quantum
/// switch-hop latency stays noise.
pub const DEFAULT_QUANTUM: u64 = 256 << 10;

/// The WFQ class KV streams ride: device-to-device session/KV traffic
/// shares contended links with request dispatch by weight instead of
/// serializing a whole migration ahead of it.
pub const KV_STREAM_CLASS: Priority = Priority::Tenant { id: 200, weight: 4 };

/// An in-flight stream: the quanta of one logical transfer, in issue
/// order.  Resolve it with [`Fabric::settle_stream`].
#[derive(Clone, Debug)]
pub struct StreamHandle {
    pub from: Endpoint,
    pub to: Endpoint,
    pub bytes: u64,
    /// Chunk size the bytes were split at (last quantum carries the
    /// remainder).
    pub quantum: u64,
    pub issued: SimTime,
    ids: Vec<TransferId>,
}

impl StreamHandle {
    /// Chunk quanta this stream was split into.
    pub fn quanta(&self) -> u64 {
        self.ids.len() as u64
    }

    /// The engine transfer ids of the quanta, in issue order.
    pub fn quantum_ids(&self) -> &[TransferId] {
        &self.ids
    }
}

/// What the fabric granted a settled stream.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StreamReceipt {
    /// When the stream was requested.
    pub issued: SimTime,
    /// When the first quantum was granted the wire.
    pub begin: SimTime,
    /// When the last quantum's final byte arrived.
    pub finish: SimTime,
    pub bytes: u64,
    pub quanta: u64,
    /// MTU frames charged to the Ether-oN path across all quanta.
    pub frames: u64,
    /// Consumer head start the pipeline exposed: Σ over non-final
    /// quanta of (stream finish − quantum finish).  A monolithic
    /// transfer — one quantum — exposes zero.
    pub overlap: SimTime,
    /// Per-quantum arrival times, in issue order (nondecreasing: quanta
    /// of one stream serialize on their shared path).
    pub quantum_finishes: Vec<SimTime>,
}

impl StreamReceipt {
    /// End-to-end latency of the whole stream.
    pub fn latency(&self) -> SimTime {
        self.finish.saturating_sub(self.issued)
    }

    /// Completion time for a consumer that spends `decode` per quantum
    /// and processes quantum `i` while quantum `i+1` is on the wire:
    /// the classic two-stage pipeline `done_i = max(arrive_i,
    /// done_{i-1}) + decode`.  Always ≤ [`StreamReceipt::serial_finish`].
    pub fn pipelined_finish(&self, decode: SimTime) -> SimTime {
        let mut done = self.issued;
        for &at in &self.quantum_finishes {
            done = done.max(at) + decode;
        }
        done
    }

    /// Completion time for the monolithic shape: all decode work starts
    /// only after the last byte lands.
    pub fn serial_finish(&self, decode: SimTime) -> SimTime {
        self.finish + SimTime::ns(decode.as_ns() * self.quanta)
    }

    /// The stream summarized as a single transfer receipt (first grant,
    /// last byte), for callers that account streams and monolithic
    /// transfers uniformly.
    pub fn summary(&self) -> TransferReceipt {
        TransferReceipt {
            issued: self.issued,
            begin: self.begin,
            finish: self.finish,
            bytes: self.bytes,
            frames: self.frames,
        }
    }
}

impl Fabric {
    /// Open a stream: split `bytes` into `quantum`-sized chunks and
    /// schedule every quantum on the engine at `now` under `pri`.  The
    /// quanta serialize among themselves (same path, same class) but
    /// interleave with other tenants' traffic in WFQ order — the wire is
    /// never held for more than one quantum at a time.
    ///
    /// `fabric.bytes_p2p` accrues when both endpoints are pool nodes;
    /// `fabric.stream_quanta` counts the quanta issued.
    pub fn stream(
        &mut self,
        now: SimTime,
        from: Endpoint,
        to: Endpoint,
        bytes: u64,
        quantum: u64,
        pri: Priority,
    ) -> StreamHandle {
        let quantum = quantum.max(1);
        let n = bytes.div_ceil(quantum).max(1);
        let mut ids = Vec::with_capacity(n as usize);
        let mut left = bytes;
        for _ in 0..n {
            let chunk = left.min(quantum);
            ids.push(self.schedule(now, from, to, chunk, pri));
            left -= chunk;
        }
        debug_assert_eq!(left, 0);
        self.stats.stream_quanta += n;
        if matches!((from, to), (Endpoint::Node(a), Endpoint::Node(b)) if a != b) {
            self.stats.bytes_p2p += bytes;
        }
        StreamHandle {
            from,
            to,
            bytes,
            quantum,
            issued: now,
            ids,
        }
    }

    /// Settle every quantum of `handle` (advancing the engine only as
    /// far as the last quantum's finish) and account the pipeline
    /// overlap under `fabric.stream_overlap_ns`.
    pub fn settle_stream(&mut self, handle: &StreamHandle) -> StreamReceipt {
        let mut finishes = Vec::with_capacity(handle.ids.len());
        let mut begin = SimTime::ZERO;
        let mut finish = handle.issued;
        let mut frames = 0;
        for (i, &id) in handle.ids.iter().enumerate() {
            let r = self.settle(id).expect("stream quantum was scheduled");
            if i == 0 {
                begin = r.begin;
            }
            finish = finish.max(r.finish);
            frames += r.frames;
            finishes.push(r.finish);
        }
        let mut overlap = SimTime::ZERO;
        for &at in finishes.iter().take(finishes.len().saturating_sub(1)) {
            overlap += finish.saturating_sub(at);
        }
        self.stats.stream_overlap_ns += overlap.as_ns();
        StreamReceipt {
            issued: handle.issued,
            begin,
            finish,
            bytes: handle.bytes,
            quanta: handle.quanta(),
            frames,
            overlap,
            quantum_finishes: finishes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EtherOnConfig, PoolConfig};
    use crate::metrics::{names, Counters};

    fn fabric(nodes_per_array: u32, arrays: u32) -> Fabric {
        Fabric::new(
            &PoolConfig {
                nodes_per_array,
                arrays,
                ..Default::default()
            },
            &EtherOnConfig::default(),
        )
    }

    #[test]
    fn single_quantum_stream_matches_monolithic() {
        let mut a = fabric(4, 1);
        let mut b = fabric(4, 1);
        let bytes = 100 << 10;
        let mono = b.schedule(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            bytes,
            Priority::Foreground,
        );
        b.run_to_idle();
        let h = a.stream(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            bytes,
            DEFAULT_QUANTUM,
            Priority::Foreground,
        );
        let r = a.settle_stream(&h);
        assert_eq!(r.quanta, 1);
        assert_eq!(r.overlap, SimTime::ZERO, "one quantum exposes no head start");
        assert_eq!(r.finish, b.receipt_of(mono).unwrap().finish);
    }

    #[test]
    fn uncontended_stream_finishes_with_the_monolithic_transfer() {
        let mut a = fabric(4, 2);
        let mut b = fabric(4, 2);
        let bytes = 8 << 20;
        let quantum = 512 << 10;
        let mono = b.schedule(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(5), // cross-array: 3-link path
            bytes,
            Priority::Foreground,
        );
        b.run_to_idle();
        let mono_finish = b.receipt_of(mono).unwrap().finish;
        let h = a.stream(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(5),
            bytes,
            quantum,
            Priority::Foreground,
        );
        let r = a.settle_stream(&h);
        assert_eq!(r.quanta, bytes.div_ceil(quantum));
        // no earlier than the monolithic wire (modulo per-quantum ns
        // truncation of wire_time), and within per-quantum hop tails of it
        let trunc = SimTime::ns(3 * r.quanta);
        assert!(
            r.finish + trunc >= mono_finish,
            "stream must not beat the wire: {} vs {mono_finish}",
            r.finish
        );
        let tails = SimTime::ns(3 * 300 * r.quanta);
        assert!(
            r.finish <= mono_finish + tails,
            "uncontended stream should track the monolithic finish: {} vs {mono_finish}",
            r.finish
        );
        // every delivered quantum is a head start over the monolithic shape
        assert!(r.overlap > SimTime::ZERO);
        assert!(r.quantum_finishes.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn pipelined_consumption_beats_the_serial_shape() {
        let mut f = fabric(4, 1);
        let h = f.stream(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            4 << 20,
            256 << 10,
            KV_STREAM_CLASS,
        );
        let r = f.settle_stream(&h);
        assert!(r.quanta > 1);
        let decode = SimTime::us(50);
        let pipelined = r.pipelined_finish(decode);
        let serial = r.serial_finish(decode);
        assert!(
            pipelined < serial,
            "decode under the next fetch must shrink completion: {pipelined} vs {serial}"
        );
        // the pipeline can never finish before the wire or the decode work
        assert!(pipelined >= r.finish + decode);
        assert!(pipelined >= SimTime::ns(decode.as_ns() * r.quanta));
    }

    #[test]
    fn stream_counters_account_p2p_quanta_and_overlap() {
        let mut f = fabric(4, 1);
        let h = f.stream(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            1 << 20,
            256 << 10,
            KV_STREAM_CLASS,
        );
        let r = f.settle_stream(&h);
        // ingress is not device-to-device
        let hi = f.stream(
            f.engine_now(),
            Endpoint::Host,
            Endpoint::Node(2),
            1 << 20,
            256 << 10,
            Priority::Foreground,
        );
        let ri = f.settle_stream(&hi);
        let mut c = Counters::new();
        f.export_counters(&mut c);
        assert_eq!(c.get(names::FABRIC_BYTES_P2P), 1 << 20);
        assert_eq!(c.get(names::FABRIC_STREAM_QUANTA), 8);
        assert_eq!(
            c.get(names::FABRIC_STREAM_OVERLAP_NS),
            (r.overlap + ri.overlap).as_ns()
        );
        assert!(r.overlap > SimTime::ZERO);
    }

    #[test]
    fn stream_quanta_share_the_wire_with_a_competing_tenant() {
        // a monolithic foreground transfer issued first would hold the
        // link end-to-end; stream quanta let the competing tenant's
        // transfer through long before the stream's own tail
        let mut f = fabric(4, 1);
        let h = f.stream(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            16 << 20,
            256 << 10,
            KV_STREAM_CLASS,
        );
        let rival = f.schedule(
            SimTime::ZERO,
            Endpoint::Node(2),
            Endpoint::Node(3),
            256 << 10,
            Priority::Tenant { id: 7, weight: 4 },
        );
        let r = f.settle_stream(&h);
        let rv = f.receipt_of(rival).unwrap();
        assert!(
            rv.finish < r.finish.scale(0.5),
            "rival should interleave early: {} vs stream {}",
            rv.finish,
            r.finish
        );
    }

    #[test]
    fn same_endpoint_stream_is_free() {
        let mut f = fabric(4, 1);
        let h = f.stream(
            SimTime::us(7),
            Endpoint::Node(2),
            Endpoint::Node(2),
            1 << 20,
            64 << 10,
            Priority::Foreground,
        );
        let r = f.settle_stream(&h);
        assert_eq!(r.latency(), SimTime::ZERO);
        assert_eq!(f.stats.bytes_p2p, 0, "nothing crossed the fabric");
        let z = f.stream(
            SimTime::us(7),
            Endpoint::Node(0),
            Endpoint::Node(1),
            0,
            64 << 10,
            Priority::Foreground,
        );
        assert_eq!(z.quanta(), 1, "zero-byte stream still yields a receipt");
        assert_eq!(f.settle_stream(&z).bytes, 0);
    }
}
