//! Byte-level Ethernet / IPv4 / TCP codecs for the Ether-oN intranet.
//!
//! Real wire formats (not structs-over-the-wire): the Ether-oN driver
//! copies an sk_buff — headers, payload, checksum — into a 4KB kernel page,
//! so the encode/decode here round-trips through `Vec<u8>` exactly as the
//! NVMe command payload would.

use std::net::Ipv4Addr;

pub const ETH_HEADER_LEN: usize = 14;
pub const IPV4_HEADER_LEN: usize = 20;
pub const TCP_HEADER_LEN: usize = 20;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MacAddr(pub [u8; 6]);

impl MacAddr {
    pub const BROADCAST: MacAddr = MacAddr([0xFF; 6]);

    /// Deterministic locally-administered MAC for a pool node id.
    pub fn for_node(id: u32) -> MacAddr {
        let b = id.to_be_bytes();
        MacAddr([0x02, 0xD5, b[0], b[1], b[2], b[3]])
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EtherType {
    Ipv4,
    Arp,
    Other(u16),
}

impl EtherType {
    fn to_u16(self) -> u16 {
        match self {
            EtherType::Ipv4 => 0x0800,
            EtherType::Arp => 0x0806,
            EtherType::Other(v) => v,
        }
    }
    fn from_u16(v: u16) -> Self {
        match v {
            0x0800 => EtherType::Ipv4,
            0x0806 => EtherType::Arp,
            other => EtherType::Other(other),
        }
    }
}

/// An Ethernet II frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EthFrame {
    pub dst: MacAddr,
    pub src: MacAddr,
    pub ethertype: EtherType,
    pub payload: Vec<u8>,
}

impl EthFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(ETH_HEADER_LEN + self.payload.len());
        out.extend_from_slice(&self.dst.0);
        out.extend_from_slice(&self.src.0);
        out.extend_from_slice(&self.ethertype.to_u16().to_be_bytes());
        out.extend_from_slice(&self.payload);
        out
    }

    pub fn decode(bytes: &[u8]) -> Option<EthFrame> {
        if bytes.len() < ETH_HEADER_LEN {
            return None;
        }
        let mut dst = [0u8; 6];
        let mut src = [0u8; 6];
        dst.copy_from_slice(&bytes[0..6]);
        src.copy_from_slice(&bytes[6..12]);
        let et = u16::from_be_bytes([bytes[12], bytes[13]]);
        Some(EthFrame {
            dst: MacAddr(dst),
            src: MacAddr(src),
            ethertype: EtherType::from_u16(et),
            payload: bytes[ETH_HEADER_LEN..].to_vec(),
        })
    }
}

/// RFC 1071 internet checksum.
pub fn internet_checksum(bytes: &[u8]) -> u16 {
    let mut sum: u32 = 0;
    let mut chunks = bytes.chunks_exact(2);
    for c in &mut chunks {
        sum += u16::from_be_bytes([c[0], c[1]]) as u32;
    }
    if let [last] = chunks.remainder() {
        sum += (*last as u32) << 8;
    }
    while sum >> 16 != 0 {
        sum = (sum & 0xFFFF) + (sum >> 16);
    }
    !(sum as u16)
}

/// Minimal IPv4 packet (no options, no fragmentation — the Ether-oN
/// intranet is a single hop with a fixed MTU).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Ipv4Packet {
    pub src: Ipv4Addr,
    pub dst: Ipv4Addr,
    pub protocol: u8,
    pub payload: Vec<u8>,
}

pub const IPPROTO_TCP: u8 = 6;
pub const IPPROTO_UDP: u8 = 17;

impl Ipv4Packet {
    pub fn encode(&self) -> Vec<u8> {
        let total = (IPV4_HEADER_LEN + self.payload.len()) as u16;
        let mut h = vec![0u8; IPV4_HEADER_LEN];
        h[0] = 0x45; // v4, IHL=5
        h[2..4].copy_from_slice(&total.to_be_bytes());
        h[8] = 64; // TTL
        h[9] = self.protocol;
        h[12..16].copy_from_slice(&self.src.octets());
        h[16..20].copy_from_slice(&self.dst.octets());
        let csum = internet_checksum(&h);
        h[10..12].copy_from_slice(&csum.to_be_bytes());
        h.extend_from_slice(&self.payload);
        h
    }

    pub fn decode(bytes: &[u8]) -> Option<Ipv4Packet> {
        if bytes.len() < IPV4_HEADER_LEN || bytes[0] >> 4 != 4 {
            return None;
        }
        let ihl = ((bytes[0] & 0x0F) as usize) * 4;
        let total = u16::from_be_bytes([bytes[2], bytes[3]]) as usize;
        if bytes.len() < total || total < ihl {
            return None;
        }
        // verify header checksum
        if internet_checksum(&bytes[..ihl]) != 0 {
            return None;
        }
        Some(Ipv4Packet {
            src: Ipv4Addr::new(bytes[12], bytes[13], bytes[14], bytes[15]),
            dst: Ipv4Addr::new(bytes[16], bytes[17], bytes[18], bytes[19]),
            protocol: bytes[9],
            payload: bytes[ihl..total].to_vec(),
        })
    }
}

/// TCP header flags.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TcpFlags {
    pub syn: bool,
    pub ack: bool,
    pub fin: bool,
    pub rst: bool,
    pub psh: bool,
}

impl TcpFlags {
    pub const SYN: TcpFlags = TcpFlags {
        syn: true,
        ack: false,
        fin: false,
        rst: false,
        psh: false,
    };
    pub const SYN_ACK: TcpFlags = TcpFlags {
        syn: true,
        ack: true,
        fin: false,
        rst: false,
        psh: false,
    };
    pub const ACK: TcpFlags = TcpFlags {
        syn: false,
        ack: true,
        fin: false,
        rst: false,
        psh: false,
    };
    pub const FIN_ACK: TcpFlags = TcpFlags {
        syn: false,
        ack: true,
        fin: true,
        rst: false,
        psh: false,
    };
    pub const RST: TcpFlags = TcpFlags {
        syn: false,
        ack: false,
        fin: false,
        rst: true,
        psh: false,
    };

    fn to_byte(self) -> u8 {
        (self.fin as u8)
            | (self.syn as u8) << 1
            | (self.rst as u8) << 2
            | (self.psh as u8) << 3
            | (self.ack as u8) << 4
    }

    fn from_byte(b: u8) -> Self {
        TcpFlags {
            fin: b & 1 != 0,
            syn: b & 2 != 0,
            rst: b & 4 != 0,
            psh: b & 8 != 0,
            ack: b & 16 != 0,
        }
    }
}

/// A TCP segment (no options; fixed 20-byte header).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TcpSegment {
    pub src_port: u16,
    pub dst_port: u16,
    pub seq: u32,
    pub ack: u32,
    pub flags: TcpFlags,
    pub window: u16,
    pub payload: Vec<u8>,
}

impl TcpSegment {
    pub fn encode(&self) -> Vec<u8> {
        let mut h = vec![0u8; TCP_HEADER_LEN];
        h[0..2].copy_from_slice(&self.src_port.to_be_bytes());
        h[2..4].copy_from_slice(&self.dst_port.to_be_bytes());
        h[4..8].copy_from_slice(&self.seq.to_be_bytes());
        h[8..12].copy_from_slice(&self.ack.to_be_bytes());
        h[12] = 5 << 4; // data offset = 5 words
        h[13] = self.flags.to_byte();
        h[14..16].copy_from_slice(&self.window.to_be_bytes());
        h.extend_from_slice(&self.payload);
        let csum = internet_checksum(&h);
        h[16..18].copy_from_slice(&csum.to_be_bytes());
        h
    }

    pub fn decode(bytes: &[u8]) -> Option<TcpSegment> {
        if bytes.len() < TCP_HEADER_LEN {
            return None;
        }
        let off = ((bytes[12] >> 4) as usize) * 4;
        if bytes.len() < off {
            return None;
        }
        Some(TcpSegment {
            src_port: u16::from_be_bytes([bytes[0], bytes[1]]),
            dst_port: u16::from_be_bytes([bytes[2], bytes[3]]),
            seq: u32::from_be_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]),
            ack: u32::from_be_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]),
            flags: TcpFlags::from_byte(bytes[13]),
            window: u16::from_be_bytes([bytes[14], bytes[15]]),
            payload: bytes[off..].to_vec(),
        })
    }
}

/// Build a full Ethernet frame carrying a TCP segment over IPv4.
pub fn tcp_frame(
    src_mac: MacAddr,
    dst_mac: MacAddr,
    src_ip: Ipv4Addr,
    dst_ip: Ipv4Addr,
    seg: &TcpSegment,
) -> EthFrame {
    let ip = Ipv4Packet {
        src: src_ip,
        dst: dst_ip,
        protocol: IPPROTO_TCP,
        payload: seg.encode(),
    };
    EthFrame {
        dst: dst_mac,
        src: src_mac,
        ethertype: EtherType::Ipv4,
        payload: ip.encode(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eth_frame_round_trip() {
        let f = EthFrame {
            dst: MacAddr::for_node(1),
            src: MacAddr::for_node(2),
            ethertype: EtherType::Ipv4,
            payload: vec![1, 2, 3, 4, 5],
        };
        assert_eq!(EthFrame::decode(&f.encode()), Some(f));
    }

    #[test]
    fn eth_decode_rejects_short() {
        assert_eq!(EthFrame::decode(&[0u8; 10]), None);
    }

    #[test]
    fn ipv4_round_trip_and_checksum() {
        let p = Ipv4Packet {
            src: Ipv4Addr::new(10, 77, 0, 1),
            dst: Ipv4Addr::new(10, 77, 0, 2),
            protocol: IPPROTO_TCP,
            payload: b"hello".to_vec(),
        };
        let enc = p.encode();
        assert_eq!(Ipv4Packet::decode(&enc), Some(p));
        // corrupt a byte -> checksum fails
        let mut bad = enc.clone();
        bad[15] ^= 0xFF;
        assert_eq!(Ipv4Packet::decode(&bad), None);
    }

    #[test]
    fn tcp_segment_round_trip() {
        let s = TcpSegment {
            src_port: 2375,
            dst_port: 49152,
            seq: 1000,
            ack: 2000,
            flags: TcpFlags::SYN_ACK,
            window: 65535,
            payload: b"GET /containers/json HTTP/1.1\r\n".to_vec(),
        };
        assert_eq!(TcpSegment::decode(&s.encode()), Some(s));
    }

    #[test]
    fn full_stack_frame_round_trip() {
        let seg = TcpSegment {
            src_port: 1,
            dst_port: 2,
            seq: 7,
            ack: 8,
            flags: TcpFlags::ACK,
            window: 1024,
            payload: vec![0xAA; 100],
        };
        let f = tcp_frame(
            MacAddr::for_node(0),
            MacAddr::for_node(1),
            Ipv4Addr::new(10, 77, 0, 1),
            Ipv4Addr::new(10, 77, 0, 2),
            &seg,
        );
        let f2 = EthFrame::decode(&f.encode()).unwrap();
        let ip = Ipv4Packet::decode(&f2.payload).unwrap();
        assert_eq!(ip.protocol, IPPROTO_TCP);
        let seg2 = TcpSegment::decode(&ip.payload).unwrap();
        assert_eq!(seg2, seg);
    }

    #[test]
    fn node_macs_are_unique_and_local() {
        let a = MacAddr::for_node(1);
        let b = MacAddr::for_node(2);
        assert_ne!(a, b);
        assert_eq!(a.0[0] & 0x02, 0x02); // locally administered bit
    }

    #[test]
    fn checksum_of_zeroes_is_ffff() {
        assert_eq!(internet_checksum(&[0, 0, 0, 0]), 0xFFFF);
    }
}
