//! Host-side Ether-oN kernel driver (paper Figure 6a).
//!
//! Creates a virtual network adapter bound to one DockerSSD: the TX path
//! copies each Ethernet frame (sk_buff) into a 4KB-aligned kernel page and
//! submits a `TransmitFrame` NVMe command; the RX path keeps
//! `upcalls_per_sq` pre-posted `ReceiveFrame` commands outstanding and
//! re-arms each slot immediately after a completion delivers a frame —
//! the asynchronous upcall mechanism.

use crate::config::EtherOnConfig;
use crate::nvme::{
    BlockBackend, FrameSink, NvmeCommand, NvmeController, PcieFunction, QueuePair, Status,
};
use crate::util::SimTime;

use super::frame::EthFrame;

/// Driver statistics surfaced to the metrics layer.
#[derive(Clone, Copy, Debug, Default)]
pub struct EtherOnStats {
    pub tx_frames: u64,
    pub rx_frames: u64,
    pub tx_dropped_backpressure: u64,
    pub rearm_count: u64,
}

impl EtherOnStats {
    /// Frame-level accounting for fabric-routed intranet traffic:
    /// `frames` MTU frames crossed the TX path (TransmitFrame commands)
    /// on the sender and the RX upcall path (ReceiveFrame completions)
    /// on the receiver.
    pub fn charge_fabric(&mut self, frames: u64) {
        self.tx_frames += frames;
        self.rx_frames += frames;
    }
}

/// The host-side driver state for one adapter.
pub struct EtherOnDriver {
    cfg: EtherOnConfig,
    next_cid: u16,
    /// Kernel pages allocated for upcall slots (addresses simulated).
    next_page: u64,
    pub stats: EtherOnStats,
}

impl EtherOnDriver {
    pub fn new(cfg: EtherOnConfig) -> Self {
        EtherOnDriver {
            cfg,
            next_cid: 1,
            next_page: 0x1000_0000,
            stats: EtherOnStats::default(),
        }
    }

    fn alloc_cid(&mut self) -> u16 {
        let cid = self.next_cid;
        self.next_cid = self.next_cid.wrapping_add(1).max(1);
        cid
    }

    fn alloc_page(&mut self) -> u64 {
        let p = self.next_page;
        self.next_page += self.cfg.frame_page_bytes as u64;
        p
    }

    /// Kernel-init step: pre-submit the upcall pool (4 ReceiveFrame
    /// commands per SQ in the paper's tuning).
    pub fn arm_upcalls(&mut self, qp: &mut QueuePair) -> usize {
        let mut armed = 0;
        for _ in 0..self.cfg.upcalls_per_sq {
            let cid = self.alloc_cid();
            let page = self.alloc_page();
            if qp.sq.submit(NvmeCommand::receive_frame(cid, page)).is_ok() {
                armed += 1;
            }
        }
        armed
    }

    /// TX path: frame -> 4KB page -> TransmitFrame command.
    /// Errors if the frame exceeds the page or the SQ is full.
    pub fn transmit(&mut self, qp: &mut QueuePair, frame: &EthFrame) -> Result<(), ()> {
        let bytes = frame.encode();
        if bytes.len() > self.cfg.frame_page_bytes as usize {
            return Err(()); // would require multi-page PRP list; MTU forbids it
        }
        let cid = self.alloc_cid();
        let page = self.alloc_page();
        match qp.sq.submit(NvmeCommand::transmit_frame(cid, page, bytes)) {
            Ok(()) => {
                self.stats.tx_frames += 1;
                Ok(())
            }
            Err(_) => {
                self.stats.tx_dropped_backpressure += 1;
                Err(())
            }
        }
    }

    /// RX path: reap completions; upcall completions (carrying payload)
    /// are decoded into frames and their slot is immediately re-armed.
    pub fn poll_rx(&mut self, qp: &mut QueuePair) -> Vec<EthFrame> {
        let mut frames = Vec::new();
        while let Some(c) = qp.cq.reap() {
            if c.status != Status::Success || c.data.is_empty() {
                continue; // TX completions and errors carry no frame
            }
            if let Some(f) = EthFrame::decode(&c.data) {
                frames.push(f);
                self.stats.rx_frames += 1;
                // Re-arm: submit a fresh ReceiveFrame to keep the pool full.
                let cid = self.alloc_cid();
                let page = self.alloc_page();
                if qp.sq.submit(NvmeCommand::receive_frame(cid, page)).is_ok() {
                    self.stats.rearm_count += 1;
                }
            }
        }
        frames
    }

    /// Full tick: service the device then poll completions.  Convenience
    /// wrapper used by tests and the pool node loop.
    pub fn tick<B: BlockBackend, F: FrameSink>(
        &mut self,
        at: SimTime,
        qp: &mut QueuePair,
        ctl: &mut NvmeController,
        backend: &mut B,
        sink: &mut F,
    ) -> Vec<EthFrame> {
        ctl.service_queue(at, qp, PcieFunction::Host, backend, sink);
        self.poll_rx(qp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::etheron::frame::{EtherType, MacAddr};
    use crate::nvme::NvmeSubsystem;

    struct NullBackend;
    impl BlockBackend for NullBackend {
        fn read(&mut self, at: SimTime, _lba: u64, blocks: u64) -> (SimTime, Vec<u8>) {
            (at, vec![0; blocks as usize * 512])
        }
        fn write(&mut self, at: SimTime, _lba: u64, _data: &[u8]) -> SimTime {
            at
        }
        fn flush(&mut self, at: SimTime) -> SimTime {
            at
        }
    }

    /// Frame sink that records delivered frames.
    struct RecordSink(Vec<Vec<u8>>);
    impl FrameSink for RecordSink {
        fn deliver(&mut self, _at: SimTime, frame: &[u8]) -> SimTime {
            self.0.push(frame.to_vec());
            SimTime::us(2)
        }
    }

    fn frame(n: u8) -> EthFrame {
        EthFrame {
            dst: MacAddr::for_node(1),
            src: MacAddr::for_node(0),
            ethertype: EtherType::Ipv4,
            payload: vec![n; 64],
        }
    }

    fn setup() -> (EtherOnDriver, QueuePair, NvmeController) {
        let drv = EtherOnDriver::new(EtherOnConfig::default());
        let qp = QueuePair::new(1, 64);
        let ctl = NvmeController::new(NvmeSubsystem::standard(10_000, 0.3));
        (drv, qp, ctl)
    }

    #[test]
    fn arm_then_device_holds_slots() {
        let (mut drv, mut qp, mut ctl) = setup();
        assert_eq!(drv.arm_upcalls(&mut qp), 4);
        let mut be = NullBackend;
        let mut sink = RecordSink(Vec::new());
        ctl.service_queue(SimTime::ZERO, &mut qp, PcieFunction::Host, &mut be, &mut sink);
        assert_eq!(ctl.upcall_slots_free(), 4);
        assert!(qp.cq.is_empty());
    }

    #[test]
    fn tx_reaches_device_sink() {
        let (mut drv, mut qp, mut ctl) = setup();
        drv.transmit(&mut qp, &frame(7)).unwrap();
        let mut be = NullBackend;
        let mut sink = RecordSink(Vec::new());
        let frames = drv.tick(SimTime::ZERO, &mut qp, &mut ctl, &mut be, &mut sink);
        assert!(frames.is_empty()); // TX produces no RX
        assert_eq!(sink.0.len(), 1);
        assert_eq!(EthFrame::decode(&sink.0[0]).unwrap().payload[0], 7);
        assert_eq!(drv.stats.tx_frames, 1);
    }

    #[test]
    fn upcall_delivers_frame_and_rearms() {
        let (mut drv, mut qp, mut ctl) = setup();
        drv.arm_upcalls(&mut qp);
        let mut be = NullBackend;
        let mut sink = RecordSink(Vec::new());
        ctl.service_queue(SimTime::ZERO, &mut qp, PcieFunction::Host, &mut be, &mut sink);

        // device sends a frame up
        assert!(ctl.upcall(&mut qp, frame(9).encode()));
        let frames = drv.poll_rx(&mut qp);
        assert_eq!(frames.len(), 1);
        assert_eq!(frames[0].payload[0], 9);
        assert_eq!(drv.stats.rx_frames, 1);
        assert_eq!(drv.stats.rearm_count, 1);

        // the re-armed slot becomes available after the next service pass
        ctl.service_queue(SimTime::ZERO, &mut qp, PcieFunction::Host, &mut be, &mut sink);
        assert_eq!(ctl.upcall_slots_free(), 4);
    }

    #[test]
    fn sustained_upcall_stream_never_starves() {
        let (mut drv, mut qp, mut ctl) = setup();
        drv.arm_upcalls(&mut qp);
        let mut be = NullBackend;
        let mut sink = RecordSink(Vec::new());
        let mut received = 0;
        for round in 0..100u64 {
            ctl.service_queue(SimTime::ns(round), &mut qp, PcieFunction::Host, &mut be, &mut sink);
            // device emits up to 3 frames per round (< 4 slots)
            for i in 0..3 {
                assert!(
                    ctl.upcall(&mut qp, frame((round + i) as u8).encode()),
                    "slot starvation at round {round}"
                );
            }
            received += drv.poll_rx(&mut qp).len();
        }
        assert_eq!(received, 300);
    }

    #[test]
    fn oversized_frame_rejected() {
        let (mut drv, mut qp, _) = setup();
        let mut f = frame(1);
        f.payload = vec![0; 5000]; // > 4KB page
        assert!(drv.transmit(&mut qp, &f).is_err());
    }

    #[test]
    fn sq_full_counts_backpressure() {
        let mut drv = EtherOnDriver::new(EtherOnConfig::default());
        let mut qp = QueuePair::new(1, 2);
        drv.transmit(&mut qp, &frame(1)).unwrap();
        drv.transmit(&mut qp, &frame(2)).unwrap();
        assert!(drv.transmit(&mut qp, &frame(3)).is_err());
        assert_eq!(drv.stats.tx_dropped_backpressure, 1);
    }
}
