//! Ether-oN: Ethernet over NVMe (DESIGN.md S2, paper "ETHERNET OVER NVME").
//!
//! Overlays socket-based networking onto the NVMe protocol: the host-side
//! kernel driver exposes a virtual network adapter whose TX path wraps
//! Ethernet frames into `TransmitFrame` (0xE0) vendor commands, and whose
//! RX path is a pool of pre-posted `ReceiveFrame` (0xE1) commands the
//! device completes asynchronously (the paper's upcall mechanism, sized at
//! 4 slots per SQ).

pub mod driver;
pub mod frame;
pub mod tcp;

pub use driver::{EtherOnDriver, EtherOnStats};
pub use frame::{EthFrame, EtherType, Ipv4Packet, MacAddr, TcpFlags, TcpSegment};
pub use tcp::{TcpConn, TcpState, TcpStack};
