//! TCP finite state machine — the paper's network handler "employs a TCP
//! finite state machine to track socket communication states".
//!
//! This is a deliberately compact TCP: three-way handshake, in-order data
//! with cumulative ACKs, FIN teardown, RST abort.  It is used on both ends
//! of the Ether-oN intranet (host sockets and Virtual-FW's network
//! handler), which is a lossless single-hop PCIe path, so retransmission
//! timers are out of scope; state correctness and packet accounting are in
//! scope because Figure 11's Network component counts them.

use std::collections::{HashMap, VecDeque};
use std::net::Ipv4Addr;

use super::frame::{TcpFlags, TcpSegment};

/// RFC 793 state set (subset reachable on a lossless link).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TcpState {
    Listen,
    SynSent,
    SynReceived,
    Established,
    FinWait1,
    FinWait2,
    CloseWait,
    LastAck,
    Closed,
}

/// One connection endpoint.
#[derive(Debug)]
pub struct TcpConn {
    pub state: TcpState,
    pub local_port: u16,
    pub remote_port: u16,
    pub remote_ip: Ipv4Addr,
    pub snd_nxt: u32,
    pub rcv_nxt: u32,
    /// Data received in order, ready for the application.
    pub rx_buf: VecDeque<u8>,
    pub segments_sent: u64,
    pub segments_received: u64,
}

impl TcpConn {
    fn new(local_port: u16, remote_ip: Ipv4Addr, remote_port: u16, state: TcpState) -> Self {
        TcpConn {
            state,
            local_port,
            remote_port,
            remote_ip,
            snd_nxt: 0,
            rcv_nxt: 0,
            rx_buf: VecDeque::new(),
            segments_sent: 0,
            segments_received: 0,
        }
    }

    fn seg(&mut self, flags: TcpFlags, payload: Vec<u8>) -> TcpSegment {
        let seg = TcpSegment {
            src_port: self.local_port,
            dst_port: self.remote_port,
            seq: self.snd_nxt,
            ack: self.rcv_nxt,
            flags,
            window: 65535,
            payload,
        };
        self.segments_sent += 1;
        seg
    }
}

/// Connection key: (local port, remote ip, remote port).
pub type ConnKey = (u16, Ipv4Addr, u16);

/// A TCP endpoint stack: listening ports + connection table.
/// `process` consumes an incoming segment and returns segments to emit.
#[derive(Default)]
pub struct TcpStack {
    listening: Vec<u16>,
    pub conns: HashMap<ConnKey, TcpConn>,
    pub total_segments: u64,
}

impl TcpStack {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn listen(&mut self, port: u16) {
        if !self.listening.contains(&port) {
            self.listening.push(port);
        }
    }

    /// Active open: emit SYN.
    pub fn connect(&mut self, local_port: u16, remote_ip: Ipv4Addr, remote_port: u16) -> TcpSegment {
        let mut conn = TcpConn::new(local_port, remote_ip, remote_port, TcpState::SynSent);
        let syn = conn.seg(TcpFlags::SYN, Vec::new());
        conn.snd_nxt = conn.snd_nxt.wrapping_add(1); // SYN consumes a seq
        self.total_segments += 1;
        self.conns.insert((local_port, remote_ip, remote_port), conn);
        syn
    }

    /// Send application data on an established connection.
    pub fn send(&mut self, key: ConnKey, data: Vec<u8>) -> Option<TcpSegment> {
        let conn = self.conns.get_mut(&key)?;
        if conn.state != TcpState::Established {
            return None;
        }
        let len = data.len() as u32;
        let mut flags = TcpFlags::ACK;
        flags.psh = true;
        let seg = conn.seg(flags, data);
        conn.snd_nxt = conn.snd_nxt.wrapping_add(len);
        self.total_segments += 1;
        Some(seg)
    }

    /// Application close: emit FIN.
    pub fn close(&mut self, key: ConnKey) -> Option<TcpSegment> {
        let conn = self.conns.get_mut(&key)?;
        let seg = match conn.state {
            TcpState::Established => {
                conn.state = TcpState::FinWait1;
                let s = conn.seg(TcpFlags::FIN_ACK, Vec::new());
                conn.snd_nxt = conn.snd_nxt.wrapping_add(1);
                s
            }
            TcpState::CloseWait => {
                conn.state = TcpState::LastAck;
                let s = conn.seg(TcpFlags::FIN_ACK, Vec::new());
                conn.snd_nxt = conn.snd_nxt.wrapping_add(1);
                s
            }
            _ => return None,
        };
        self.total_segments += 1;
        Some(seg)
    }

    /// Process one incoming segment from `src_ip`; returns replies to emit.
    pub fn process(&mut self, src_ip: Ipv4Addr, seg: &TcpSegment) -> Vec<TcpSegment> {
        self.total_segments += 1;
        let key: ConnKey = (seg.dst_port, src_ip, seg.src_port);
        let mut out = Vec::new();

        if let Some(conn) = self.conns.get_mut(&key) {
            conn.segments_received += 1;
            if seg.flags.rst {
                conn.state = TcpState::Closed;
                return out;
            }
            match conn.state {
                TcpState::SynSent if seg.flags.syn && seg.flags.ack => {
                    conn.rcv_nxt = seg.seq.wrapping_add(1);
                    conn.state = TcpState::Established;
                    out.push(conn.seg(TcpFlags::ACK, Vec::new()));
                }
                TcpState::SynReceived if seg.flags.ack && !seg.flags.syn => {
                    conn.state = TcpState::Established;
                    // data may ride on the handshake ACK
                    if !seg.payload.is_empty() {
                        conn.rcv_nxt = conn.rcv_nxt.wrapping_add(seg.payload.len() as u32);
                        conn.rx_buf.extend(seg.payload.iter().copied());
                        out.push(conn.seg(TcpFlags::ACK, Vec::new()));
                    }
                }
                TcpState::Established => {
                    if seg.flags.fin {
                        conn.rcv_nxt = seg
                            .seq
                            .wrapping_add(seg.payload.len() as u32)
                            .wrapping_add(1);
                        conn.state = TcpState::CloseWait;
                        out.push(conn.seg(TcpFlags::ACK, Vec::new()));
                    } else if !seg.payload.is_empty() {
                        if seg.seq == conn.rcv_nxt {
                            conn.rcv_nxt = conn.rcv_nxt.wrapping_add(seg.payload.len() as u32);
                            conn.rx_buf.extend(seg.payload.iter().copied());
                        }
                        // cumulative ACK either way (dup data re-ACKed)
                        out.push(conn.seg(TcpFlags::ACK, Vec::new()));
                    }
                }
                TcpState::FinWait1 if seg.flags.ack => {
                    if seg.flags.fin {
                        conn.rcv_nxt = seg.seq.wrapping_add(1);
                        conn.state = TcpState::Closed; // TIME_WAIT elided
                        out.push(conn.seg(TcpFlags::ACK, Vec::new()));
                    } else {
                        conn.state = TcpState::FinWait2;
                    }
                }
                TcpState::FinWait2 if seg.flags.fin => {
                    conn.rcv_nxt = seg.seq.wrapping_add(1);
                    conn.state = TcpState::Closed;
                    out.push(conn.seg(TcpFlags::ACK, Vec::new()));
                }
                TcpState::LastAck if seg.flags.ack => {
                    conn.state = TcpState::Closed;
                }
                _ => {}
            }
            self.total_segments += out.len() as u64;
            return out;
        }

        // No connection: passive open on a listening port?
        if seg.flags.syn && !seg.flags.ack && self.listening.contains(&seg.dst_port) {
            let mut conn = TcpConn::new(seg.dst_port, src_ip, seg.src_port, TcpState::SynReceived);
            conn.rcv_nxt = seg.seq.wrapping_add(1);
            conn.segments_received = 1;
            let syn_ack = {
                let s = conn.seg(TcpFlags::SYN_ACK, Vec::new());
                conn.snd_nxt = conn.snd_nxt.wrapping_add(1);
                s
            };
            self.conns.insert(key, conn);
            self.total_segments += 1;
            out.push(syn_ack);
            return out;
        }

        // Otherwise: RST.
        let rst = TcpSegment {
            src_port: seg.dst_port,
            dst_port: seg.src_port,
            seq: 0,
            ack: seg.seq.wrapping_add(1),
            flags: TcpFlags::RST,
            window: 0,
            payload: Vec::new(),
        };
        self.total_segments += 1;
        out.push(rst);
        out
    }

    /// Drain application data received on a connection.
    pub fn recv(&mut self, key: ConnKey) -> Vec<u8> {
        self.conns
            .get_mut(&key)
            .map(|c| c.rx_buf.drain(..).collect())
            .unwrap_or_default()
    }

    pub fn state_of(&self, key: ConnKey) -> Option<TcpState> {
        self.conns.get(&key).map(|c| c.state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CLIENT_IP: Ipv4Addr = Ipv4Addr::new(10, 77, 0, 1);
    const SERVER_IP: Ipv4Addr = Ipv4Addr::new(10, 77, 0, 2);

    /// Run a full handshake between two stacks; returns (client, server, keys).
    fn establish() -> (TcpStack, TcpStack, ConnKey, ConnKey) {
        let mut client = TcpStack::new();
        let mut server = TcpStack::new();
        server.listen(2375); // mini-docker's HTTP port

        let syn = client.connect(49152, SERVER_IP, 2375);
        let syn_ack = server.process(CLIENT_IP, &syn);
        assert_eq!(syn_ack.len(), 1);
        let ack = client.process(SERVER_IP, &syn_ack[0]);
        assert_eq!(ack.len(), 1);
        server.process(CLIENT_IP, &ack[0]);

        let ckey = (49152, SERVER_IP, 2375);
        let skey = (2375, CLIENT_IP, 49152);
        assert_eq!(client.state_of(ckey), Some(TcpState::Established));
        assert_eq!(server.state_of(skey), Some(TcpState::Established));
        (client, server, ckey, skey)
    }

    #[test]
    fn three_way_handshake() {
        establish();
    }

    #[test]
    fn data_transfer_and_ack() {
        let (mut client, mut server, ckey, skey) = establish();
        let seg = client.send(ckey, b"GET /v1/containers HTTP/1.1\r\n".to_vec()).unwrap();
        let replies = server.process(CLIENT_IP, &seg);
        assert_eq!(replies.len(), 1); // pure ACK
        assert!(replies[0].flags.ack);
        assert_eq!(server.recv(skey), b"GET /v1/containers HTTP/1.1\r\n".to_vec());
        client.process(SERVER_IP, &replies[0]);
        // server can answer
        let resp = server.send(skey, b"HTTP/1.1 200 OK\r\n".to_vec()).unwrap();
        client.process(SERVER_IP, &resp);
        assert_eq!(client.recv(ckey), b"HTTP/1.1 200 OK\r\n".to_vec());
    }

    #[test]
    fn duplicate_segment_not_double_delivered() {
        let (mut client, mut server, ckey, skey) = establish();
        let seg = client.send(ckey, b"abc".to_vec()).unwrap();
        server.process(CLIENT_IP, &seg);
        server.process(CLIENT_IP, &seg); // replay
        assert_eq!(server.recv(skey), b"abc".to_vec());
        assert!(server.recv(skey).is_empty());
    }

    #[test]
    fn graceful_close_both_sides() {
        let (mut client, mut server, ckey, skey) = establish();
        let fin = client.close(ckey).unwrap();
        let ack = server.process(CLIENT_IP, &fin);
        client.process(SERVER_IP, &ack[0]);
        assert_eq!(client.state_of(ckey), Some(TcpState::FinWait2));
        assert_eq!(server.state_of(skey), Some(TcpState::CloseWait));
        let fin2 = server.close(skey).unwrap();
        let last_ack = client.process(SERVER_IP, &fin2);
        server.process(CLIENT_IP, &last_ack[0]);
        assert_eq!(client.state_of(ckey), Some(TcpState::Closed));
        assert_eq!(server.state_of(skey), Some(TcpState::Closed));
    }

    #[test]
    fn syn_to_closed_port_gets_rst() {
        let mut server = TcpStack::new();
        let mut client = TcpStack::new();
        let syn = client.connect(1000, SERVER_IP, 81);
        let replies = server.process(CLIENT_IP, &syn);
        assert_eq!(replies.len(), 1);
        assert!(replies[0].flags.rst);
        client.process(SERVER_IP, &replies[0]);
        assert_eq!(client.state_of((1000, SERVER_IP, 81)), Some(TcpState::Closed));
    }

    #[test]
    fn send_on_unestablished_conn_refused() {
        let mut client = TcpStack::new();
        client.connect(1000, SERVER_IP, 80); // still SynSent
        assert!(client.send((1000, SERVER_IP, 80), b"x".to_vec()).is_none());
    }

    #[test]
    fn segment_counters_track_traffic() {
        let (client, server, _, _) = establish();
        // SYN + SYN-ACK + ACK observed across both stacks
        assert!(client.total_segments >= 2);
        assert!(server.total_segments >= 2);
    }
}
