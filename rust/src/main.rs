//! `repro` — the DockerSSD leader CLI.
//!
//! Subcommands regenerate every table and figure of the paper's
//! evaluation (DESIGN.md §3) and drive the serving case study:
//!
//! ```text
//! repro table2            # Table 2: workload characteristics
//! repro fig3              # Fig 3: Host vs P.ISP breakdown
//! repro fig10             # Fig 10: firmware image sizes
//! repro fig11             # Fig 11: 6 models x 13 workloads
//! repro fig12a            # Fig 12a: optimal parallelism per scenario
//! repro fig12b            # Fig 12b: compute/memory breakdown + ratios
//! repro fig13ab           # Fig 13a/b: sequence-length sensitivity
//! repro fig13cd           # Fig 13c/d: batch-size sensitivity
//! repro docker-demo       # pull/run/logs lifecycle on the simulated SSD
//! repro serve [--nodes N --requests R --tokens T --seed S]
//!             [--workload ROW --scale K --boot-storm B --chaos S]
//!             [--autoscale [--predictive]]
//!                         # simulated-time pool serving (PoolSim): a
//!                         # uniform-random storm, or a Table-2 trace
//!                         # replay (--workload mariadb-tpch4) optionally
//!                         # contending with B replica boots on the same
//!                         # clock; --chaos S replays a seeded fault
//!                         # schedule (node deaths, array loss, link
//!                         # brownouts, registry stalls) against the
//!                         # replay and reports availability + healing;
//!                         # --autoscale runs the replay under the
//!                         # queue-depth autoscaler, --predictive warms
//!                         # scale-out candidates' layers ahead of the
//!                         # commit; with --features pjrt also
//!                         # [--artifacts DIR] for real PJRT generation
//! repro config            # print the default config as JSON
//! ```
//!
//! (CLI parsing is hand-rolled: clap is unavailable offline, DESIGN.md §4.)

use dockerssd::config::SystemConfig;
use dockerssd::docker::{MiniDocker, Registry};
use dockerssd::firmware::{fw_image, linux_image, CostModel, VirtualFw};
use dockerssd::lambdafs::LambdaFs;
use dockerssd::llm::disagg::{
    aggregate_ratio, batch_sweep, crossover_seq, fig12_sweep, seq_sweep, DisaggModel,
};
use dockerssd::llm::all_llms;
use dockerssd::metrics::Table;
use dockerssd::models::{evaluate, fig11_row, geomean_ratio, Component, ModelKind};
use dockerssd::pool::WireRig;
use dockerssd::ssd::SsdDevice;
use dockerssd::util::{human_bytes, SimTime};
use dockerssd::workloads::all_workloads;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "table2" => table2(),
        "fig3" => fig3(),
        "fig10" => fig10(),
        "fig11" => fig11(),
        "fig12a" => fig12a(),
        "fig12b" => fig12b(),
        "fig13ab" => fig13ab(),
        "fig13cd" => fig13cd(),
        "docker-demo" => docker_demo(),
        "serve" => serve_cmd(&args[1..]),
        "config" => println!("{}", SystemConfig::default().to_json().dump()),
        _ => {
            eprintln!("usage: repro <table2|fig3|fig10|fig11|fig12a|fig12b|fig13ab|fig13cd|docker-demo|serve|config>");
            std::process::exit(if cmd == "help" { 0 } else { 2 });
        }
    }
}

fn table2() {
    let mut t = Table::new(vec![
        "workload", "io_size", "io_count", "syscalls", "path_walks", "files", "tcp_pkts",
        "exec_s",
    ]);
    for w in all_workloads() {
        t.row(vec![
            w.full_name(),
            human_bytes(w.io_bytes),
            format!("{}", w.io_count),
            format!("{}", w.syscalls),
            format!("{}", w.path_walks),
            format!("{}", w.files_opened),
            format!("{}", w.tcp_packets),
            format!("{}", w.exec_time_s),
        ]);
    }
    println!("Table 2: workload characteristics\n{}", t.render());
}

fn fig3() {
    let c = CostModel::calibrated();
    let mut t = Table::new(vec!["workload", "Host total", "Host Storage%", "P.ISP total", "P.ISP Communicate%", "P.ISP/Host"]);
    let (mut sf, mut cf, mut rr) = (0.0, 0.0, 0.0);
    let ws = all_workloads();
    for w in &ws {
        let h = evaluate(ModelKind::Host, w, &c);
        let p = evaluate(ModelKind::PIspR, w, &c);
        sf += h.fraction(Component::Storage);
        cf += p.communicate() / p.total();
        rr += p.total() / h.total();
        t.row(vec![
            w.full_name(),
            format!("{:.2}s", h.total()),
            format!("{:.0}%", 100.0 * h.fraction(Component::Storage)),
            format!("{:.2}s", p.total()),
            format!("{:.0}%", 100.0 * p.communicate() / p.total()),
            format!("{:.2}x", p.total() / h.total()),
        ]);
    }
    let n = ws.len() as f64;
    println!("Figure 3: performance impact analysis\n{}", t.render());
    println!(
        "mean: Host Storage {:.0}% (paper 38%) | P.ISP Communicate {:.0}% (paper 43%) | P.ISP/Host {:.2}x (paper 1.4x)",
        100.0 * sf / n,
        100.0 * cf / n,
        rr / n
    );
}

fn fig10() {
    let (linux, fw) = (linux_image(), fw_image());
    let mut t = Table::new(vec!["image", "component", "size"]);
    for c in &linux.components {
        t.row(vec![linux.name, c.name, &human_bytes(c.bytes)]);
    }
    for c in &fw.components {
        t.row(vec![fw.name, c.name, &human_bytes(c.bytes)]);
    }
    println!("Figure 10: image size\n{}", t.render());
    println!(
        "totals: {} = {}, {} = {} -> reduction {:.1}x (paper 83.4x)",
        linux.name,
        human_bytes(linux.total_bytes()),
        fw.name,
        human_bytes(fw.total_bytes()),
        linux.total_bytes() as f64 / fw.total_bytes() as f64
    );
}

fn fig11() {
    let c = CostModel::calibrated();
    let mut t = Table::new(vec![
        "workload", "Host", "P.ISP-R", "P.ISP-V", "D-Naive", "D-FullOS", "D-VirtFW",
    ]);
    for w in all_workloads() {
        let row = fig11_row(&w, &c);
        let mut cells = vec![w.full_name()];
        for (_, _, norm) in &row {
            cells.push(format!("{:.2}", norm));
        }
        t.row(cells);
    }
    println!("Figure 11: latency normalized to D-VirtFW\n{}", t.render());
    println!("aggregate geomean vs D-VirtFW (paper targets):");
    for (m, target) in [
        (ModelKind::Host, 1.3),
        (ModelKind::PIspR, 1.6),
        (ModelKind::PIspV, 1.6),
        (ModelKind::DNaive, 1.8),
        (ModelKind::DFullOs, 1.6),
    ] {
        println!(
            "  {:<9} {:.2}x (paper ~{:.1}x)",
            m.name(),
            geomean_ratio(m, ModelKind::DVirtFw, &c),
            target
        );
    }
    // component view for one representative workload
    let w = &all_workloads()[0];
    println!("\ncomponent breakdown, {} (seconds):", w.full_name());
    let mut t = Table::new(vec!["model", "Network", "Kernel-ctx", "LBA-set", "Storage", "System", "Compute"]);
    for m in ModelKind::ALL {
        let b = evaluate(m, w, &c);
        t.row(vec![
            m.name().to_string(),
            format!("{:.3}", b.network),
            format!("{:.3}", b.kernel_ctx),
            format!("{:.3}", b.lba_set),
            format!("{:.3}", b.storage),
            format!("{:.3}", b.system),
            format!("{:.3}", b.compute),
        ]);
    }
    println!("{}", t.render());
}

fn fig12a() {
    let mut t = Table::new(vec!["model", "nodes", "H-NoCache", "H-Cache", "D-NoCache", "D-Cache"]);
    let rs = fig12_sweep(32_768, 1);
    for (i, llm) in all_llms().iter().enumerate() {
        let nodes = dockerssd::llm::disagg::nodes_for(i);
        let mut cells = vec![llm.name.to_string(), format!("{nodes}")];
        for d in DisaggModel::ALL {
            let cell = rs
                .iter()
                .find(|r| r.model == llm.name && r.disagg == d)
                .map(|r| format!("{} ({})", r.choice.par.dominant().name(), r.choice.par.label()))
                .unwrap_or_else(|| "infeasible".into());
            cells.push(cell);
        }
        t.row(cells);
    }
    println!("Figure 12a: optimal parallelism (32K seq, batch 1)\n{}", t.render());
    println!("paper: NoCache -> pipeline parallelism; Cache -> tensor parallelism");
}

fn fig12b() {
    let mut t = Table::new(vec!["model", "scenario", "compute_s", "memory_s", "comm_s", "total_s"]);
    for r in fig12_sweep(32_768, 1) {
        t.row(vec![
            r.model.to_string(),
            r.disagg.name().to_string(),
            format!("{:.1}", r.time().compute),
            format!("{:.1}", r.time().memory),
            format!("{:.1}", r.time().comm),
            format!("{:.1}", r.time().total()),
        ]);
    }
    println!("Figure 12b: inference time breakdown (32K seq)\n{}", t.render());
    println!("aggregate ratios (paper targets):");
    println!(
        "  H-NoCache/H-Cache = {:.0}x (paper 421x)",
        aggregate_ratio(DisaggModel::HostNoCache, DisaggModel::HostCache, 32_768, 1)
    );
    println!(
        "  D-NoCache/D-Cache = {:.0}x (paper 4.6Kx)",
        aggregate_ratio(DisaggModel::DockerNoCache, DisaggModel::DockerCache, 32_768, 1)
    );
    println!(
        "  H-Cache/D-Cache   = {:.1}x (paper 7.9x)",
        aggregate_ratio(DisaggModel::HostCache, DisaggModel::DockerCache, 32_768, 1)
    );
    println!(
        "  D-NoCache/H-NoCache = {:.1}x (paper 1.7x)",
        aggregate_ratio(DisaggModel::DockerNoCache, DisaggModel::HostNoCache, 32_768, 1)
    );
    println!(
        "  H-NoCache/D-Cache = {:.0}x (paper 3.2Kx)",
        aggregate_ratio(DisaggModel::HostNoCache, DisaggModel::DockerCache, 32_768, 1)
    );
}

fn fig13ab() {
    let llms = all_llms();
    let lamda = &llms[0];
    let megatron = &llms[7];
    let seqs: Vec<u64> = (6..=17).map(|p| 1u64 << p).collect();
    for (llm, nodes, paper_x) in [(lamda, 16u32, 256u64), (megatron, 128u32, 1024u64)] {
        let mut t = Table::new(vec!["seq", "D-Cache speedup over H-Cache"]);
        for (s, sp) in seq_sweep(llm, nodes, &seqs, 1) {
            t.row(vec![format!("{s}"), format!("{:.2}x", sp)]);
        }
        println!("Figure 13a/b: {} on {} nodes\n{}", llm.name, nodes, t.render());
        println!(
            "crossover: {:?} (paper {}); speedup converges toward ~9.5x at long sequences\n",
            crossover_seq(llm, nodes),
            paper_x
        );
    }
}

fn fig13cd() {
    let llms = all_llms();
    let batches = [1u64, 2, 4, 8, 16, 32, 64, 128, 256, 512];
    for (llm, nodes) in [(&llms[0], 16u32), (&llms[7], 128u32)] {
        let mut t = Table::new(vec!["batch", "D-Cache speedup over H-Cache"]);
        for (b, sp) in batch_sweep(llm, nodes, 512, &batches) {
            t.row(vec![format!("{b}"), format!("{:.2}x", sp)]);
        }
        println!("Figure 13c/d: {} on {} nodes (seq 512)\n{}", llm.name, nodes, t.render());
    }
    println!("paper: modest improvement, max ~1.3x for lamda and megatron");
}

fn docker_demo() {
    let cfg = SystemConfig::default();
    let mut dev = SsdDevice::new(cfg.ssd.clone());
    let mut fs = LambdaFs::over_device(&dev);
    let mut fw = VirtualFw::new(&cfg.ssd);
    let reg = Registry::with_benchmark_images();
    let mut md = MiniDocker::new();
    let mut rig = WireRig::new(&cfg.pool, &cfg.etheron);

    println!("# docker pull mariadb (over the pool fabric + Ether-oN into λFS)");
    let r = md
        .pull(&mut fw, &mut fs, &mut dev, &reg, &mut rig.ctx(SimTime::ZERO), 0, "mariadb")
        .unwrap();
    println!("{} (simulated {:?})", r.output, r.done);

    println!("# docker run mariadb");
    let r2 = md.run(&mut fw, &mut fs, &mut dev, r.done, "mariadb").unwrap();
    let id = r2.output.clone();
    println!("container {} started (simulated {:?})", id, r2.done);

    md.log_line(&mut fs, &mut dev, r2.done, &id, "query: SELECT ... 42 rows").unwrap();
    println!("# docker logs {id}");
    let logs = md.logs(&mut fs, &mut dev, r2.done, &id).unwrap();
    print!("{}", logs.output);

    println!("# docker ps");
    print!("{}", md.ps().output);

    md.stop(&mut fw, &mut fs, &mut dev, r2.done, &id).unwrap();
    md.rm(&mut fs, r2.done, &id).unwrap();
    println!("stopped + removed; fw syscalls emulated: {}", fw.syscalls.total());
}

/// Without the `pjrt` feature the serving loop still runs end-to-end in
/// simulated time (PoolSim clock + shared fabric), with the
/// deterministic `EchoExecutor` standing in for real PJRT engines.
///
/// With `--workload <row>` the whole replay runs through
/// `dockerssd::smoke::run` — the *same* module the tier-1 golden test
/// re-derives `ci/golden/serve_smoke.txt` from, so the binary and the
/// in-process test cannot drift apart.  `--boot-storm B` boots B
/// replicas of a synthetic model image on the same clock, so
/// docker-pull and prefetch bytes contend with dispatch and response
/// traffic on the shared wires.  Everything is deterministic: the CI
/// smoke job diffs the counter table of two same-seed runs (and the
/// committed golden) byte-for-byte.
#[cfg(not(feature = "pjrt"))]
fn serve_cmd(rest: &[String]) {
    use dockerssd::coordinator::{serve, EchoExecutor, InferenceRequest, ServeParams, ServeReport};
    use dockerssd::layerstore::PoolLayerCache;
    use dockerssd::metrics::{Counters, Table};
    use dockerssd::pool::{DeploymentSpec, Orchestrator, PoolTopology, RestartPolicy};
    use dockerssd::sim::PoolSim;
    use dockerssd::smoke::{self, SmokeParams};
    use dockerssd::util::Rng;

    /// The tail every serve run prints: response summary, per-node wire
    /// bytes, and the deterministic counter table the smoke job greps.
    fn print_report(report: &ServeReport, c: &Counters) {
        println!(
            "\n{} responses, {} batches ({} padded rows), {} prompt tokens in / {} tokens out \
             in {} simulated",
            report.responses.len(),
            report.batches,
            report.padded_rows,
            report.prompt_tokens,
            report.tokens_out,
            report.makespan
        );
        println!(
            "throughput {:.1} tok/s (simulated), mean latency {}, p99 {}",
            report.throughput_tok_s(),
            report.mean_latency(),
            report.latency.quantile(0.99)
        );
        let mut t = Table::new(vec!["node", "wire_bytes"]);
        for (n, bytes) in report.node_wire_bytes.iter().enumerate() {
            t.row(vec![format!("{n}"), format!("{bytes}")]);
        }
        println!("\nper-node dispatch+response traffic\n{}", t.render());
        let mut t = Table::new(vec!["counter", "value"]);
        for (k, v) in c.iter() {
            t.row(vec![k.to_string(), format!("{v}")]);
        }
        println!("\n{}", t.render());
    }

    let value_of = |i: usize, flag: &str| -> String {
        rest.get(i + 1).cloned().unwrap_or_else(|| {
            eprintln!("{flag} needs a value");
            std::process::exit(2);
        })
    };
    let cfg = SystemConfig::default();
    let mut nodes = 0usize;
    let mut requests = 32usize;
    let mut tokens = 0usize;
    let mut storm_flags = false;
    let mut seed = 42u64;
    let mut workload = cfg.serve.workload.clone();
    let mut scale = cfg.serve.trace_scale;
    let mut boot_storm = cfg.serve.boot_storm;
    let mut chaos: Option<u64> = None;
    let mut autoscale = false;
    let mut predictive = false;
    let mut i = 0;
    while i < rest.len() {
        match rest[i].as_str() {
            "--nodes" => {
                nodes = value_of(i, "--nodes").parse().expect("--nodes N");
                i += 2;
            }
            "--requests" => {
                requests = value_of(i, "--requests").parse().expect("--requests R");
                storm_flags = true;
                i += 2;
            }
            "--tokens" => {
                tokens = value_of(i, "--tokens").parse().expect("--tokens T");
                storm_flags = true;
                i += 2;
            }
            "--seed" => {
                seed = value_of(i, "--seed").parse().expect("--seed S");
                i += 2;
            }
            "--workload" => {
                workload = value_of(i, "--workload");
                i += 2;
            }
            "--scale" => {
                scale = value_of(i, "--scale").parse().expect("--scale K");
                i += 2;
            }
            "--boot-storm" => {
                boot_storm = value_of(i, "--boot-storm").parse().expect("--boot-storm B");
                i += 2;
            }
            "--chaos" => {
                chaos = Some(value_of(i, "--chaos").parse().expect("--chaos S"));
                i += 2;
            }
            "--autoscale" => {
                autoscale = true;
                i += 1;
            }
            "--predictive" => {
                predictive = true;
                i += 1;
            }
            other => {
                eprintln!("unknown flag {other}");
                std::process::exit(2);
            }
        }
    }
    let nodes = if nodes == 0 { cfg.serve.nodes as usize } else { nodes };
    let tokens = if tokens == 0 { cfg.serve.max_new_tokens as usize } else { tokens };

    if !workload.is_empty() {
        // request count and shapes come from the trace, not the CLI knobs
        if storm_flags {
            eprintln!("note: --requests/--tokens are ignored for a trace replay");
        }
        // the whole replay is the shared smoke scenario — identical code
        // path to the tier-1 golden re-derivation test
        let p = SmokeParams {
            workload,
            nodes,
            scale,
            seed,
            boot_storm,
            chaos,
            autoscale,
            predictive,
        };
        let out = match smoke::run(&p) {
            Ok(out) => out,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        };
        println!(
            "trace replay {}: {} requests ({} read-shaped, {} write-shaped) arriving over {}, \
             {} nodes, seed {seed}, scale {scale}",
            out.workload_name,
            out.arrivals.requests,
            out.arrivals.read_requests,
            out.arrivals.write_requests,
            out.arrivals.span,
            nodes
        );
        if let Some(rep) = &out.storm {
            println!(
                "boot storm: {} replicas placed, {} registry pulls (foreground) + {} peer \
                 prefetches (background); pulls land at {}",
                rep.placed.len(),
                rep.registry_pulls,
                rep.peer_prefetches,
                rep.pulls_done
            );
        }
        if let Some(ch) = &out.chaos {
            let invariant = if ch.healed_to_k(smoke::CHAOS_HEAL_K) {
                "held"
            } else {
                "VIOLATED"
            };
            println!(
                "chaos seed {}: {} faults ({} node deaths, {} array losses, {} brownouts, \
                 {} registry stalls); availability {:.4}%, p99 under churn {}",
                ch.report.seed,
                ch.report.faults_injected,
                ch.report.node_deaths,
                ch.report.array_losses,
                ch.report.link_brownouts,
                ch.report.registry_stalls,
                100.0 * ch.report.availability_fraction(),
                out.report.latency.quantile(0.99)
            );
            println!(
                "healing: {} chunks re-replicated ({} copies, {} bytes, {} hidden behind \
                 foreground), {} registry re-pulls, {} replicas restarted, {} nodes purged; \
                 k>={} invariant {}",
                ch.heal.chunks_rereplicated,
                ch.heal.copies_made,
                ch.heal.bytes,
                ch.heal.bytes_hidden,
                ch.heal.registry_chunks,
                ch.heal.replicas_restarted,
                ch.heal.dead_nodes_purged,
                smoke::CHAOS_HEAL_K,
                invariant
            );
        }
        if let Some(asc) = &out.autoscale {
            println!(
                "autoscale: {} ticks, {} scale-outs ({} warm, {} cold), {} scale-ins; \
                 cold-start p99 {}, {} prefetch bytes hidden behind the commit",
                asc.report.ticks,
                asc.report.scale_outs,
                asc.report.warm_boots,
                asc.report.cold_boots,
                asc.report.scale_ins,
                asc.report.coldstart_p99(),
                asc.report.prefetch_hidden_bytes
            );
        }
        print_report(&out.report, &out.counters);
        return;
    }

    let params = ServeParams::from_config(&cfg.serve);
    let mut sim = PoolSim::new(&cfg);
    if chaos.is_some() {
        eprintln!("note: --chaos only applies to a trace replay (--workload ROW); ignored");
    }
    if autoscale || predictive {
        eprintln!("note: --autoscale only applies to a trace replay (--workload ROW); ignored");
    }
    println!(
        "simulated serve storm: {nodes} nodes, {requests} requests x {tokens} tokens, seed {seed}"
    );
    let mut rng = Rng::new(seed);
    let reqs: Vec<(SimTime, InferenceRequest)> = (0..requests as u64)
        .map(|id| {
            (
                SimTime::us(rng.below(5_000)),
                InferenceRequest {
                    id,
                    prompt: (0..params.prompt_len).map(|_| rng.below(32_000) as i32).collect(),
                    max_new_tokens: tokens,
                },
            )
        })
        .collect();

    if boot_storm > 0 {
        let topo = PoolTopology::build(&cfg.pool);
        let mut orch = Orchestrator::new();
        let mut cache = PoolLayerCache::new();
        let layers = smoke::boot_storm_layers();
        let spec = DeploymentSpec {
            name: "storm".into(),
            image: "llm-worker".into(),
            replicas: boot_storm,
            restart: RestartPolicy::OnFailure,
        };
        let rep = orch
            .boot_storm_sim(&mut sim, &topo, &spec, &mut cache, &layers)
            .expect("boot storm placement");
        println!(
            "boot storm: {} replicas placed, {} registry pulls (foreground) + {} peer prefetches \
             (background); pulls land at {}",
            rep.placed.len(),
            rep.registry_pulls,
            rep.peer_prefetches,
            rep.pulls_done
        );
    }

    let factories: Vec<_> = (0..nodes)
        .map(|_| || Ok::<_, anyhow::Error>(EchoExecutor))
        .collect();
    let report = serve(&mut sim, factories, reqs, &params);
    // drain engine-scheduled background prefetches before exporting, so
    // fabric.* counters account every storm byte (re-timed or not)
    sim.fabric.run_to_idle();
    let mut c = Counters::new();
    report.export_counters(&mut c);
    sim.export_counters(&mut c);
    print_report(&report, &c);
}

#[cfg(feature = "pjrt")]
fn serve_cmd(rest: &[String]) {
    let value_of = |i: usize, flag: &str| -> String {
        rest.get(i + 1).cloned().unwrap_or_else(|| {
            eprintln!("{flag} needs a value");
            std::process::exit(2);
        })
    };
    let mut nodes = 2usize;
    let mut requests = 8usize;
    let mut tokens = 16usize;
    let mut artifacts = "artifacts".to_string();
    let mut i = 0;
    while i < rest.len() {
        match rest[i].as_str() {
            "--nodes" => {
                nodes = value_of(i, "--nodes").parse().expect("--nodes N");
                i += 2;
            }
            "--requests" => {
                requests = value_of(i, "--requests").parse().expect("--requests R");
                i += 2;
            }
            "--tokens" => {
                tokens = value_of(i, "--tokens").parse().expect("--tokens T");
                i += 2;
            }
            "--artifacts" => {
                artifacts = value_of(i, "--artifacts");
                i += 2;
            }
            other => {
                eprintln!("unknown flag {other}");
                std::process::exit(2);
            }
        }
    }
    match dockerssd::examples_support::run_serve(&artifacts, nodes, requests, tokens) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("serve failed: {e:#}");
            std::process::exit(1);
        }
    }
}
