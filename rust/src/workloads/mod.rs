//! Workload suite (DESIGN.md S8): the six benchmarks / 13 workloads of
//! Table 2, as both (a) characteristic vectors driving the latency models
//! of Figure 3/11 and (b) deterministic operation-trace generators that
//! exercise the substrates (λFS, SSD, TCP) with real operations.

pub mod spec;
pub mod trace;

pub use spec::{all_workloads, Benchmark, WorkloadSpec};
pub use trace::{Op, TraceGenerator};
