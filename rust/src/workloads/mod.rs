//! Workload suite (DESIGN.md S8): the six benchmarks / 13 workloads of
//! Table 2, as (a) characteristic vectors driving the latency models of
//! Figure 3/11, (b) deterministic operation-trace generators that
//! exercise the substrates (λFS, SSD, TCP) with real operations, and
//! (c) trace-driven arrival streams feeding `coordinator::serve` with
//! per-request shapes at the row's measured I/O rate.

pub mod arrivals;
pub mod spec;
pub mod trace;

pub use arrivals::{trace_arrivals, ArrivalParams, TraceArrivals};
pub use spec::{all_workloads, workload_named, Benchmark, WorkloadSpec};
pub use trace::{Op, TraceGenerator};
