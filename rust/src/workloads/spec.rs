//! Table 2 — workload characteristics, transcribed from the paper.
//!
//! Each row records the I/O volume, request count, syscall count, path
//! walks, files opened, TCP packets, and the paper's measured execution
//! time.  The six data-processing models consume these counts; `repro
//! table2` prints the table back (experiment E2).

/// The six benchmark programs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Benchmark {
    /// DLRM embedding lookups + sparse-feature aggregation.
    Embed,
    /// MariaDB running TPC-H.
    MariaDb,
    /// RocksDB Get/Put over >100K keys.
    RocksDb,
    /// Text mining over >20K documents (grep/wc-like).
    Pattern,
    /// Nginx static web + video streaming.
    Nginx,
    /// vsftpd bulk image upload.
    Vsftpd,
}

impl Benchmark {
    pub fn name(&self) -> &'static str {
        match self {
            Benchmark::Embed => "embed",
            Benchmark::MariaDb => "mariadb",
            Benchmark::RocksDb => "rocksdb",
            Benchmark::Pattern => "pattern",
            Benchmark::Nginx => "nginx",
            Benchmark::Vsftpd => "vsftpd",
        }
    }
}

/// One Table 2 row.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub benchmark: Benchmark,
    pub name: &'static str,
    /// Total I/O volume in bytes.
    pub io_bytes: u64,
    /// I/O request count.
    pub io_count: u64,
    /// System calls issued.
    pub syscalls: u64,
    /// Path-walk operations.
    pub path_walks: u64,
    /// Distinct files opened.
    pub files_opened: u64,
    /// TCP packets exchanged.
    pub tcp_packets: u64,
    /// Paper-reported end-to-end execution time (seconds, Host reference).
    pub exec_time_s: f64,
    /// Fraction of I/O volume that is writes (derived from workload type).
    pub write_frac: f64,
}

impl WorkloadSpec {
    pub fn full_name(&self) -> String {
        format!("{}-{}", self.benchmark.name(), self.name)
    }

    /// Mean bytes per I/O request.
    pub fn bytes_per_io(&self) -> f64 {
        self.io_bytes as f64 / self.io_count.max(1) as f64
    }
}

/// Look up a Table 2 row by its `full_name` ("mariadb-tpch4") or, when
/// unambiguous, by its bare row name ("tpch4").  The CLI, benches, and
/// CI smoke scenario all resolve `--workload` through this.
pub fn workload_named(name: &str) -> Option<WorkloadSpec> {
    let ws = all_workloads();
    if let Some(w) = ws.iter().find(|w| w.full_name() == name) {
        return Some(w.clone());
    }
    let mut hits = ws.iter().filter(|w| w.name == name);
    match (hits.next(), hits.next()) {
        (Some(w), None) => Some(w.clone()),
        _ => None,
    }
}

const GB: f64 = 1_073_741_824.0;

fn gb(x: f64) -> u64 {
    (x * GB) as u64
}

/// All 13 workloads of Table 2, in paper order.
pub fn all_workloads() -> Vec<WorkloadSpec> {
    use Benchmark::*;
    vec![
        WorkloadSpec {
            benchmark: Embed,
            name: "rm1",
            io_bytes: gb(1.3),
            io_count: 317_000,
            syscalls: 1_300_000,
            path_walks: 9_000,
            files_opened: 260,
            tcp_packets: 0,
            exec_time_s: 8.0,
            write_frac: 0.02,
        },
        WorkloadSpec {
            benchmark: Embed,
            name: "rm2",
            io_bytes: gb(5.8),
            io_count: 1_400_000,
            syscalls: 1_700_000,
            path_walks: 9_000,
            files_opened: 320,
            tcp_packets: 0,
            exec_time_s: 24.0,
            write_frac: 0.02,
        },
        WorkloadSpec {
            benchmark: MariaDb,
            name: "tpch4",
            io_bytes: gb(17.1),
            io_count: 1_100_000,
            syscalls: 1_100_000,
            path_walks: 37_000,
            files_opened: 250,
            tcp_packets: 160,
            exec_time_s: 25.0,
            write_frac: 0.05,
        },
        WorkloadSpec {
            benchmark: MariaDb,
            name: "tpch11",
            io_bytes: gb(6.2),
            io_count: 400_000,
            syscalls: 361_000,
            path_walks: 38_000,
            files_opened: 260,
            tcp_packets: 190,
            exec_time_s: 8.0,
            write_frac: 0.05,
        },
        WorkloadSpec {
            benchmark: RocksDb,
            name: "read",
            io_bytes: gb(4.1),
            io_count: 431_000,
            syscalls: 1_100_000,
            path_walks: 9_000,
            files_opened: 1_200,
            tcp_packets: 0,
            exec_time_s: 14.0,
            write_frac: 0.0,
        },
        WorkloadSpec {
            benchmark: RocksDb,
            name: "write",
            io_bytes: gb(18.5),
            io_count: 24_000,
            syscalls: 285_000,
            path_walks: 9_000,
            files_opened: 3_600,
            tcp_packets: 0,
            exec_time_s: 24.0,
            write_frac: 0.9,
        },
        WorkloadSpec {
            benchmark: Pattern,
            name: "find",
            io_bytes: gb(2.4),
            io_count: 381_000,
            syscalls: 1_800_000,
            path_walks: 359_000,
            files_opened: 352_000,
            tcp_packets: 0,
            exec_time_s: 11.0,
            write_frac: 0.0,
        },
        WorkloadSpec {
            benchmark: Pattern,
            name: "line",
            io_bytes: gb(1.7),
            io_count: 262_000,
            syscalls: 1_700_000,
            path_walks: 476_000,
            files_opened: 235_000,
            tcp_packets: 0,
            exec_time_s: 11.0,
            write_frac: 0.0,
        },
        WorkloadSpec {
            benchmark: Pattern,
            name: "word",
            io_bytes: gb(2.1),
            io_count: 340_000,
            syscalls: 2_200_000,
            path_walks: 618_000,
            files_opened: 307_000,
            tcp_packets: 0,
            exec_time_s: 10.0,
            write_frac: 0.0,
        },
        WorkloadSpec {
            benchmark: Nginx,
            name: "web0",
            io_bytes: gb(7.5),
            io_count: 126_000,
            syscalls: 665_000,
            path_walks: 126_000,
            files_opened: 4_400,
            tcp_packets: 543_000, // paper: 543M is a typo-scale outlier; clamp to rate-consistent 543K
            exec_time_s: 9.0,
            write_frac: 0.0,
        },
        WorkloadSpec {
            benchmark: Nginx,
            name: "web1",
            io_bytes: gb(0.9),
            io_count: 50_000,
            syscalls: 344_000,
            path_walks: 109_000,
            files_opened: 2_000,
            tcp_packets: 154_000,
            exec_time_s: 3.0,
            write_frac: 0.0,
        },
        WorkloadSpec {
            benchmark: Nginx,
            name: "filedown",
            io_bytes: gb(13.5),
            io_count: 109_000,
            syscalls: 30_000,
            path_walks: 1_000,
            files_opened: 40,
            tcp_packets: 155_000,
            exec_time_s: 6.0,
            write_frac: 0.0,
        },
        WorkloadSpec {
            benchmark: Vsftpd,
            name: "fileup",
            io_bytes: gb(12.1),
            io_count: 93_000,
            syscalls: 5_400_000,
            path_walks: 127_000,
            files_opened: 115_000,
            tcp_packets: 1_200_000,
            exec_time_s: 2.0, // paper reports 2s; dominated by upload bandwidth
            write_frac: 1.0,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thirteen_workloads() {
        assert_eq!(all_workloads().len(), 13);
    }

    #[test]
    fn names_match_table2() {
        let names: Vec<String> = all_workloads().iter().map(|w| w.full_name()).collect();
        assert_eq!(
            names,
            vec![
                "embed-rm1",
                "embed-rm2",
                "mariadb-tpch4",
                "mariadb-tpch11",
                "rocksdb-read",
                "rocksdb-write",
                "pattern-find",
                "pattern-line",
                "pattern-word",
                "nginx-web0",
                "nginx-web1",
                "nginx-filedown",
                "vsftpd-fileup",
            ]
        );
    }

    #[test]
    fn counts_are_positive_and_sane() {
        for w in all_workloads() {
            assert!(w.io_bytes > 0, "{}", w.full_name());
            assert!(w.io_count > 0);
            assert!(w.syscalls > 0);
            assert!(w.exec_time_s > 0.0);
            assert!((0.0..=1.0).contains(&w.write_frac));
            // Table 2's I/O sizes are KB..MB per request
            let bpio = w.bytes_per_io();
            assert!(bpio > 100.0 && bpio < 1_000_000_000.0, "{}: {bpio}", w.full_name());
        }
    }

    #[test]
    fn workload_lookup_by_full_or_row_name() {
        assert_eq!(workload_named("mariadb-tpch4").unwrap().name, "tpch4");
        assert_eq!(workload_named("tpch4").unwrap().benchmark, Benchmark::MariaDb);
        assert_eq!(workload_named("filedown").unwrap().benchmark, Benchmark::Nginx);
        assert!(workload_named("no-such-row").is_none());
        // "rm1" is unique, but a benchmark name alone is not a row
        assert!(workload_named("rm1").is_some());
        assert!(workload_named("nginx").is_none());
    }

    #[test]
    fn rm2_is_larger_than_rm1() {
        let ws = all_workloads();
        assert!(ws[1].io_bytes > ws[0].io_bytes);
        assert!(ws[1].io_count > ws[0].io_count);
    }

    #[test]
    fn pattern_workloads_are_path_walk_heavy() {
        // the paper's motivation for I/O-node caching
        for w in all_workloads().iter().filter(|w| w.benchmark == Benchmark::Pattern) {
            assert!(w.path_walks > 300_000, "{}", w.full_name());
            assert!(w.files_opened > 200_000);
        }
    }

    #[test]
    fn network_workloads_have_tcp_traffic() {
        for w in all_workloads() {
            let networked = matches!(w.benchmark, Benchmark::Nginx | Benchmark::Vsftpd | Benchmark::MariaDb);
            assert_eq!(w.tcp_packets > 0, networked, "{}", w.full_name());
        }
    }
}
