//! Trace-driven arrival processes: a Table 2 workload row replayed as a
//! timestamped inference-request stream for `coordinator::serve`.
//!
//! This closes the serve-side half of the trace story (ROADMAP serve
//! follow-ons): instead of uniform-random arrival seeds, the Op mix of a
//! [`TraceGenerator`] trace maps onto per-request shapes — a read op
//! becomes an *output-heavy* request (the data flows device → host as
//! generated tokens), a write op becomes a *prompt-heavy* request (the
//! data flows host → device as prompt tokens) — and requests arrive at
//! the row's measured I/O rate (mean inter-arrival `exec_time_s /
//! io_count`, which is invariant under trace scaling), so an
//! I/O-intensive row stresses the host uplink and array backplanes the
//! way Table 2 says it should.
//!
//! Everything is deterministic for a given seed: two calls with the same
//! `(spec, seed, params)` produce identical request streams, which is
//! what lets `repro serve --workload <row>` be a byte-comparable CI
//! smoke scenario.

use super::spec::WorkloadSpec;
use super::trace::{Op, TraceGenerator};
use crate::coordinator::InferenceRequest;
use crate::util::{Rng, SimTime};

/// Tunables of the trace → request mapping.
#[derive(Clone, Debug)]
pub struct ArrivalParams {
    /// Trace scale factor: the replay carries `io_count / scale` requests
    /// (the op *mix* and the arrival *rate* are preserved; only the span
    /// shrinks).
    pub scale: u64,
    /// Bytes of workload I/O one prompt/output token stands for.
    pub bytes_per_token: u64,
    /// Token floor: the query side of a read, the ack side of a write.
    pub min_tokens: usize,
    /// Token ceiling, so one huge I/O cannot dwarf the whole replay.
    pub max_tokens: usize,
}

impl Default for ArrivalParams {
    fn default() -> Self {
        ArrivalParams {
            scale: 10_000,
            bytes_per_token: 4096,
            min_tokens: 4,
            max_tokens: 256,
        }
    }
}

impl ArrivalParams {
    fn tokens_of(&self, bytes: u64) -> usize {
        ((bytes / self.bytes_per_token.max(1)) as usize).clamp(self.min_tokens, self.max_tokens)
    }

    /// The engine prompt length a serve loop replaying this stream
    /// should use.  The batcher clips prompts to the engine's
    /// `prompt_len`, so anything smaller than `max_tokens` silently
    /// truncates write-heavy payloads — erasing exactly the
    /// prompt/output asymmetry the trace mapping exists to model.  The
    /// CLI, benches, and tests all feed this into their `ServeParams`.
    pub fn engine_prompt_len(&self) -> usize {
        self.max_tokens
    }
}

/// A workload row rendered as an arrival stream, plus the shape counts
/// the CLI and benches report.
#[derive(Debug)]
pub struct TraceArrivals {
    pub requests: Vec<(SimTime, InferenceRequest)>,
    /// Requests derived from read ops (short prompt, long output).
    pub read_requests: u64,
    /// Requests derived from write ops (long prompt, short output).
    pub write_requests: u64,
    /// Arrival time of the last request.
    pub span: SimTime,
}

/// Convert a Table 2 row into timestamped [`InferenceRequest`]s.
///
/// Each I/O op of the scaled trace becomes one request; its prompt and
/// output lengths derive from the op's byte count (so `rocksdb-write`
/// yields prompt-heavy traffic and `nginx-filedown` output-heavy
/// traffic), and consecutive requests are spaced by the row's mean I/O
/// inter-arrival time with deterministic ±50% jitter.  Non-I/O ops
/// (syscalls, path walks, TCP packets) shape the *trace*, not the
/// request stream — their costs live in the analytic models.
pub fn trace_arrivals(spec: &WorkloadSpec, seed: u64, params: &ArrivalParams) -> TraceArrivals {
    let ops = TraceGenerator::new(spec.clone(), seed, params.scale).generate();
    // independent stream so arrival jitter never perturbs the trace mix
    let mut rng = Rng::new(seed.wrapping_add(0x5EED));
    let inter = SimTime::secs_f64(spec.exec_time_s / spec.io_count.max(1) as f64);

    let mut requests = Vec::new();
    let mut at = SimTime::ZERO;
    let (mut reads, mut writes) = (0u64, 0u64);
    for op in &ops {
        let (prompt_tokens, new_tokens) = match op {
            // data flows device → host: the response carries it
            Op::Read { bytes, .. } => {
                reads += 1;
                (params.min_tokens, params.tokens_of(*bytes))
            }
            // data flows host → device: the prompt carries it
            Op::Write { bytes, .. } => {
                writes += 1;
                (params.tokens_of(*bytes), params.min_tokens)
            }
            _ => continue,
        };
        at += inter.scale(0.5 + rng.f64());
        let prompt: Vec<i32> = (0..prompt_tokens).map(|_| rng.below(32_000) as i32).collect();
        requests.push((
            at,
            InferenceRequest {
                id: requests.len() as u64,
                prompt,
                max_new_tokens: new_tokens,
            },
        ));
    }
    TraceArrivals {
        span: at,
        requests,
        read_requests: reads,
        write_requests: writes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::spec::{all_workloads, workload_named};

    #[test]
    fn every_table2_row_yields_requests() {
        for spec in all_workloads() {
            let arr = trace_arrivals(&spec, 7, &ArrivalParams::default());
            assert!(!arr.requests.is_empty(), "{}", spec.full_name());
            assert_eq!(
                arr.read_requests + arr.write_requests,
                arr.requests.len() as u64,
                "{}",
                spec.full_name()
            );
            for (i, (_, req)) in arr.requests.iter().enumerate() {
                assert_eq!(req.id, i as u64, "ids are sequential");
                assert!(!req.prompt.is_empty());
                assert!(req.max_new_tokens > 0);
            }
        }
    }

    #[test]
    fn same_seed_same_stream() {
        let spec = workload_named("mariadb-tpch4").unwrap();
        let a = trace_arrivals(&spec, 42, &ArrivalParams::default());
        let b = trace_arrivals(&spec, 42, &ArrivalParams::default());
        assert_eq!(a.requests, b.requests);
        let c = trace_arrivals(&spec, 43, &ArrivalParams::default());
        assert_ne!(a.requests, c.requests, "different seeds must differ");
    }

    #[test]
    fn arrivals_are_nondecreasing_at_the_rows_io_rate() {
        let spec = workload_named("nginx-filedown").unwrap();
        let p = ArrivalParams {
            scale: 2_000,
            ..Default::default()
        };
        let arr = trace_arrivals(&spec, 11, &p);
        let mut prev = SimTime::ZERO;
        for (at, _) in &arr.requests {
            assert!(*at >= prev, "arrivals must be time-ordered");
            prev = *at;
        }
        // rate faithfulness: the span tracks exec_time_s / scale (the
        // jitter is ±50% around the mean, so the sum concentrates)
        let want = spec.exec_time_s / p.scale as f64;
        let got = arr.span.as_secs_f64();
        assert!(
            got > 0.5 * want && got < 1.5 * want,
            "span {got}s vs expected ~{want}s"
        );
    }

    #[test]
    fn write_heavy_rows_are_prompt_heavy() {
        let spec = workload_named("rocksdb-write").unwrap(); // write_frac 0.9
        // scale 100 keeps enough requests for the ratio to concentrate
        let arr = trace_arrivals(
            &spec,
            3,
            &ArrivalParams {
                scale: 100,
                ..Default::default()
            },
        );
        assert!(
            arr.write_requests as f64 > 0.8 * arr.requests.len() as f64,
            "write row must produce mostly prompt-heavy requests"
        );
        // a write carries its bytes in the prompt
        let heavy = arr
            .requests
            .iter()
            .filter(|(_, r)| r.prompt.len() > r.max_new_tokens)
            .count();
        assert!(heavy as f64 > 0.8 * arr.requests.len() as f64);
    }

    #[test]
    fn read_only_rows_are_output_heavy() {
        let spec = workload_named("pattern-find").unwrap(); // write_frac 0
        let arr = trace_arrivals(&spec, 3, &ArrivalParams::default());
        assert_eq!(arr.write_requests, 0);
        assert!(arr
            .requests
            .iter()
            .all(|(_, r)| r.max_new_tokens >= r.prompt.len()));
    }

    #[test]
    fn token_counts_respect_bounds() {
        for spec in all_workloads() {
            let p = ArrivalParams::default();
            let arr = trace_arrivals(&spec, 5, &p);
            for (_, r) in &arr.requests {
                assert!((p.min_tokens..=p.max_tokens).contains(&r.prompt.len()));
                assert!((p.min_tokens..=p.max_tokens).contains(&r.max_new_tokens));
            }
        }
    }
}
