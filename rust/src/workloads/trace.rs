//! Deterministic operation-trace generation from a WorkloadSpec.
//!
//! A trace is a scaled-down, statistically faithful stream of operations
//! (reads, writes, opens, path walks, syscalls, TCP packets) whose *mix*
//! matches the Table 2 row.  The integration tests and the `isp_workloads`
//! example replay traces against the real substrates (λFS + SSD + TCP
//! stacks) instead of trusting the analytic models blindly.

use crate::util::Rng;

use super::spec::WorkloadSpec;

/// One operation in a replayable trace.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    /// Open (and path-walk) a file by index.
    Open { file: u64 },
    /// Read `bytes` from open file `file`.
    Read { file: u64, bytes: u64 },
    /// Write `bytes` to open file `file`.
    Write { file: u64, bytes: u64 },
    /// A non-I/O syscall (thread/memory/lock management).
    Syscall,
    /// One TCP packet exchanged with a client.
    TcpPacket { bytes: u64 },
    /// Pure computation over `bytes` of data already read.
    Compute { bytes: u64 },
}

/// Generates a bounded trace whose operation mix mirrors the spec.
pub struct TraceGenerator {
    spec: WorkloadSpec,
    rng: Rng,
    /// Scale factor: ops in the trace = ceil(count / scale).
    scale: u64,
}

impl TraceGenerator {
    /// `scale` shrinks Table 2 counts so traces replay in milliseconds;
    /// the mix (ratios between op kinds) is preserved.
    pub fn new(spec: WorkloadSpec, seed: u64, scale: u64) -> Self {
        TraceGenerator {
            spec,
            rng: Rng::new(seed),
            scale: scale.max(1),
        }
    }

    fn scaled(&self, n: u64) -> u64 {
        n.div_ceil(self.scale)
    }

    /// Produce the full trace (deterministic for a given seed).
    pub fn generate(&mut self) -> Vec<Op> {
        let s = &self.spec;
        let n_io = self.scaled(s.io_count);
        let n_sys = self.scaled(s.syscalls);
        let n_open = self.scaled(s.files_opened).max(1);
        let n_tcp = self.scaled(s.tcp_packets);
        let bytes_per_io = (s.io_bytes / s.io_count.max(1)).max(512);

        let mut ops = Vec::with_capacity((n_io + n_sys + n_open + n_tcp) as usize);

        // interleave deterministically: each "tick" may emit several kinds
        let total_ticks = n_io.max(n_sys).max(n_open).max(n_tcp).max(1);
        let mut emitted_io = 0;
        let mut emitted_sys = 0;
        let mut emitted_open = 0;
        let mut emitted_tcp = 0;
        for tick in 0..total_ticks {
            // proportional emission keeps the mix constant through the trace
            while emitted_open * total_ticks <= tick * n_open && emitted_open < n_open {
                ops.push(Op::Open {
                    file: self.rng.below(n_open.max(1)),
                });
                emitted_open += 1;
            }
            while emitted_io * total_ticks <= tick * n_io && emitted_io < n_io {
                let file = self.rng.below(n_open.max(1));
                let jitter = self.rng.range(bytes_per_io / 2, bytes_per_io * 3 / 2 + 1);
                if self.rng.chance(s.write_frac) {
                    ops.push(Op::Write { file, bytes: jitter });
                } else {
                    ops.push(Op::Read { file, bytes: jitter });
                }
                ops.push(Op::Compute { bytes: jitter });
                emitted_io += 1;
            }
            while emitted_sys * total_ticks <= tick * n_sys && emitted_sys < n_sys {
                ops.push(Op::Syscall);
                emitted_sys += 1;
            }
            while emitted_tcp * total_ticks <= tick * n_tcp && emitted_tcp < n_tcp {
                ops.push(Op::TcpPacket {
                    bytes: self.rng.range(64, 1460),
                });
                emitted_tcp += 1;
            }
        }
        ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::spec::all_workloads;

    fn counts(ops: &[Op]) -> (u64, u64, u64, u64, u64) {
        let (mut io, mut sys, mut open, mut tcp, mut wr) = (0, 0, 0, 0, 0);
        for op in ops {
            match op {
                Op::Read { .. } => io += 1,
                Op::Write { .. } => {
                    io += 1;
                    wr += 1;
                }
                Op::Syscall => sys += 1,
                Op::Open { .. } => open += 1,
                Op::TcpPacket { .. } => tcp += 1,
                Op::Compute { .. } => {}
            }
        }
        (io, sys, open, tcp, wr)
    }

    #[test]
    fn deterministic_for_seed() {
        let spec = all_workloads()[0].clone();
        let a = TraceGenerator::new(spec.clone(), 42, 1000).generate();
        let b = TraceGenerator::new(spec, 42, 1000).generate();
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let spec = all_workloads()[0].clone();
        let a = TraceGenerator::new(spec.clone(), 1, 1000).generate();
        let b = TraceGenerator::new(spec, 2, 1000).generate();
        assert_ne!(a, b);
    }

    #[test]
    fn mix_matches_spec_ratios() {
        let spec = all_workloads()[2].clone(); // mariadb-tpch4
        let ops = TraceGenerator::new(spec.clone(), 7, 100).generate();
        let (io, sys, open, _tcp, _) = counts(&ops);
        let want_io_sys = spec.io_count as f64 / spec.syscalls as f64;
        let got_io_sys = io as f64 / sys as f64;
        assert!(
            (want_io_sys - got_io_sys).abs() / want_io_sys < 0.05,
            "io/sys ratio {got_io_sys} vs {want_io_sys}"
        );
        assert!(open > 0);
    }

    #[test]
    fn write_heavy_workload_emits_writes() {
        let spec = all_workloads()[5].clone(); // rocksdb-write (write_frac 0.9)
        let ops = TraceGenerator::new(spec, 3, 100).generate();
        let (io, _, _, _, wr) = counts(&ops);
        assert!(wr as f64 > 0.8 * io as f64, "writes {wr}/{io}");
    }

    #[test]
    fn read_only_workload_has_no_writes() {
        let spec = all_workloads()[6].clone(); // pattern-find
        let ops = TraceGenerator::new(spec, 3, 1000).generate();
        let (_, _, _, _, wr) = counts(&ops);
        assert_eq!(wr, 0);
    }

    #[test]
    fn every_table2_row_generates_nonempty_trace() {
        for spec in all_workloads() {
            let ops = TraceGenerator::new(spec.clone(), 11, 10_000).generate();
            assert!(!ops.is_empty(), "{}", spec.full_name());
        }
    }
}
