//! Request batcher: packs incoming requests into the engine's fixed
//! batch width, on the pool's simulated clock.
//!
//! The AOT executables have a static [batch, prompt_len] signature, so a
//! batch launches when full, or once the oldest pending request has
//! waited `max_wait` of *simulated* time (the partial batch is padded by
//! repeating the last request's prompt; padding rows are dropped from
//! responses).  There is no wallclock anywhere: the serve loop feeds
//! `now` in from its event queue, which is what makes two same-seed
//! runs form byte-identical batches.

use std::collections::VecDeque;

use super::InferenceRequest;
use crate::util::SimTime;

/// A formed batch: `live` of the `prompts.len()` rows carry real requests.
#[derive(Clone, Debug)]
pub struct Batch {
    pub requests: Vec<InferenceRequest>,
    pub prompts: Vec<Vec<i32>>,
    pub live: usize,
    pub max_new_tokens: usize,
}

impl Batch {
    /// KV-context tokens the live rows pin on a node: each request's
    /// clipped prompt plus its *own* generation budget (padding rows
    /// write no KV, and a short request never pays for the batch-wide
    /// `max_new_tokens`).  Multiplied by a model's per-token KV bytes
    /// this is the batch's per-request-sized KV reservation.
    pub fn kv_tokens(&self, prompt_cap: usize) -> u64 {
        self.requests
            .iter()
            .map(|r| (r.prompt.len().min(prompt_cap) + r.max_new_tokens) as u64)
            .sum()
    }
}

/// The batching queue.
pub struct Batcher {
    width: usize,
    prompt_len: usize,
    max_wait: SimTime,
    queue: VecDeque<(InferenceRequest, SimTime)>,
    pub batches_formed: u64,
    pub requests_seen: u64,
    pub padded_rows: u64,
}

impl Batcher {
    pub fn new(width: usize, prompt_len: usize, max_wait: SimTime) -> Self {
        assert!(width > 0);
        Batcher {
            width,
            prompt_len,
            max_wait,
            queue: VecDeque::new(),
            batches_formed: 0,
            requests_seen: 0,
            padded_rows: 0,
        }
    }

    pub fn push(&mut self, req: InferenceRequest, now: SimTime) {
        self.requests_seen += 1;
        self.queue.push_back((req, now));
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Arrival time of the oldest pending request — its `+ max_wait` is
    /// when a partial batch becomes launchable.
    pub fn oldest_arrival(&self) -> Option<SimTime> {
        self.queue.front().map(|(_, t)| *t)
    }

    /// Normalize a prompt to exactly `prompt_len` tokens (left-truncate,
    /// right-pad with token 0).
    fn fit(&self, prompt: &[i32]) -> Vec<i32> {
        let mut p: Vec<i32> = if prompt.len() > self.prompt_len {
            prompt[prompt.len() - self.prompt_len..].to_vec()
        } else {
            prompt.to_vec()
        };
        p.resize(self.prompt_len, 0);
        p
    }

    /// Try to form a batch at simulated time `now`: full-width
    /// immediately, partial only once the oldest request has waited
    /// `max_wait` (or `force` is set).
    pub fn form(&mut self, now: SimTime, force: bool) -> Option<Batch> {
        let oldest = self.oldest_arrival()?;
        if self.queue.len() < self.width && !force && now.saturating_sub(oldest) < self.max_wait {
            return None;
        }
        let take = self.queue.len().min(self.width);
        let requests: Vec<InferenceRequest> =
            self.queue.drain(..take).map(|(r, _)| r).collect();
        let mut prompts: Vec<Vec<i32>> = requests.iter().map(|r| self.fit(&r.prompt)).collect();
        let live = prompts.len();
        // pad to full width by repeating the last prompt
        while prompts.len() < self.width {
            prompts.push(prompts.last().unwrap().clone());
            self.padded_rows += 1;
        }
        let max_new_tokens = requests.iter().map(|r| r.max_new_tokens).max().unwrap_or(1);
        self.batches_formed += 1;
        Some(Batch {
            requests,
            prompts,
            live,
            max_new_tokens,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, len: usize) -> InferenceRequest {
        InferenceRequest {
            id,
            prompt: (0..len as i32).collect(),
            max_new_tokens: 4,
        }
    }

    #[test]
    fn full_batch_forms_immediately() {
        let mut b = Batcher::new(4, 8, SimTime::ms(100));
        for i in 0..4 {
            b.push(req(i, 8), SimTime::ZERO);
        }
        let batch = b.form(SimTime::ZERO, false).expect("full batch");
        assert_eq!(batch.live, 4);
        assert_eq!(batch.prompts.len(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits_unless_forced() {
        let mut b = Batcher::new(4, 8, SimTime::ms(100));
        b.push(req(1, 8), SimTime::ZERO);
        assert!(b.form(SimTime::ZERO, false).is_none(), "should wait for more requests");
        let batch = b.form(SimTime::ZERO, true).expect("forced partial");
        assert_eq!(batch.live, 1);
        assert_eq!(batch.prompts.len(), 4, "padded to width");
        assert_eq!(b.padded_rows, 3);
    }

    #[test]
    fn partial_batch_fires_after_simulated_timeout() {
        let mut b = Batcher::new(4, 8, SimTime::us(50));
        b.push(req(1, 8), SimTime::us(10));
        assert_eq!(b.oldest_arrival(), Some(SimTime::us(10)));
        assert!(b.form(SimTime::us(59), false).is_none(), "one tick short of the window");
        assert!(b.form(SimTime::us(60), false).is_some(), "window elapsed in simulated time");
    }

    #[test]
    fn prompts_are_fit_to_length() {
        let mut b = Batcher::new(2, 8, SimTime::ZERO);
        b.push(req(1, 3), SimTime::ZERO); // short -> padded
        b.push(req(2, 20), SimTime::ZERO); // long -> left-truncated (keep the tail)
        let batch = b.form(SimTime::ZERO, true).unwrap();
        assert_eq!(batch.prompts[0].len(), 8);
        assert_eq!(&batch.prompts[0][3..], &[0, 0, 0, 0, 0]);
        assert_eq!(batch.prompts[1], (12..20).collect::<Vec<i32>>());
    }

    #[test]
    fn conservation_every_request_in_exactly_one_batch() {
        let mut b = Batcher::new(4, 8, SimTime::ZERO);
        for i in 0..10 {
            b.push(req(i, 8), SimTime::ZERO);
        }
        let mut seen = Vec::new();
        while let Some(batch) = b.form(SimTime::ZERO, true) {
            for r in &batch.requests {
                seen.push(r.id);
            }
        }
        seen.sort();
        assert_eq!(seen, (0..10).collect::<Vec<u64>>());
        assert_eq!(b.batches_formed, 3);
    }

    #[test]
    fn kv_tokens_count_live_rows_per_request() {
        let mut b = Batcher::new(4, 8, SimTime::ZERO);
        b.push(req(1, 3), SimTime::ZERO); // 3 prompt + 4 new
        b.push(req(2, 20), SimTime::ZERO); // clipped to 8 + 4 new
        let batch = b.form(SimTime::ZERO, true).unwrap();
        assert_eq!(batch.kv_tokens(8), (3 + 4) + (8 + 4));
        assert_eq!(batch.prompts.len(), 4, "padding rows exist but pin no KV");
    }

    #[test]
    fn queue_order_is_fifo() {
        let mut b = Batcher::new(2, 4, SimTime::ZERO);
        for i in 0..4 {
            b.push(req(i, 4), SimTime::us(i));
        }
        let first = b.form(SimTime::us(4), false).unwrap();
        assert_eq!(first.requests[0].id, 0);
        assert_eq!(first.requests[1].id, 1);
    }
}
