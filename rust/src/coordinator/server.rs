//! The serving loop, event-driven on the pool's shared simulated clock.
//!
//! Lifecycle of one request: an *arrival event* pushes it into the
//! batcher; a full batch (or a partial one whose window expired) is
//! dispatched to the least-loaded node with KV headroom via
//! [`Router::dispatch_to`] — its prompt bytes cross the host uplink and
//! the node's array backplane on the shared [`crate::fabric::Fabric`],
//! contending with everything else on the wire; batch execution
//! occupies the node's
//! [`crate::sim::BusyResource`] compute; a *done event* collects the
//! generated tokens, charges the response bytes back over the fabric,
//! and converts the batch's KV reservation into a resident *session*.
//! KV is sized *per request* from the model config's per-token footprint
//! ([`ServeParams::kv_need`]): a prompt-heavy row of a Table 2 trace
//! (see `workloads::arrivals`) pins more resident KV than a short query,
//! so capacity pressure tracks the request mix instead of a flat
//! per-batch constant.
//! Session KV migrates between nodes ([`KvManager::migrate`], real
//! fabric traffic) when residency skews, and is evicted to admit new
//! batches under capacity pressure — the Figure 12 capacity story.
//!
//! What rides the host uplink is a policy ([`WirePolicy`]).  The
//! historical shape ([`WirePolicy::Hairpin`]) ships the *padded* AOT
//! batch host → node and hairpins every completion end-to-end through
//! the host; the default ([`WirePolicy::Streamed`]) sends only live
//! clipped prompt tokens plus a fixed batch-control header (padding is
//! materialized at the node), completes via the control/payload split
//! ([`Router::complete_split`]), and moves session KV between nodes as
//! pipelined device-to-device streams — the uplink carries control and
//! ingress bytes only, summarized per run as
//! `serve.host_bytes_per_token`.
//!
//! Determinism: the only clock is the [`PoolSim`] event queue.  There is
//! no `std::time::Instant`, no `thread::sleep`, and no thread scheduling
//! anywhere in this path, so two runs with the same seed produce
//! byte-identical schedules, latencies, and `serve.*`/`fabric.*`
//! counters.

use std::collections::{BTreeMap, VecDeque};

use super::batcher::{Batch, Batcher};
use super::kv_manager::KvManager;
use super::router::Router;
use super::{InferenceRequest, InferenceResponse};
use crate::config::ServeConfig;
use crate::metrics::{names, Counters, LatencyHistogram};
use crate::sim::{tag, tag_kind, tag_payload, PoolSim};
use crate::util::SimTime;

/// Anything that can run a full batch to completion.  Implemented by
/// `runtime::Engine` (real PJRT execution), [`EchoExecutor`] (the
/// deterministic offline stand-in), and mock executors in tests.
///
/// Executors produce *token content* only; batch timing comes from
/// [`ServeParams`] compute costs on the simulated clock.
pub trait BatchExecutor {
    /// Generate `new_tokens` tokens for every prompt row.
    fn run_batch(&mut self, prompts: &[Vec<i32>], new_tokens: usize) -> anyhow::Result<Vec<Vec<i32>>>;
    /// KV bytes this executor pins per batch while running.
    fn kv_bytes(&self) -> u64;
}

#[cfg(feature = "pjrt")]
impl BatchExecutor for crate::runtime::Engine {
    fn run_batch(&mut self, prompts: &[Vec<i32>], new_tokens: usize) -> anyhow::Result<Vec<Vec<i32>>> {
        self.generate(prompts, new_tokens)
    }

    fn kv_bytes(&self) -> u64 {
        (self.manifest.kv_cache_elems() * 2 * 4) as u64 // K+V, f32
    }
}

/// Deterministic offline executor: row `r` "generates" `prompt[0] + i`
/// for token `i`.  Lets the full serving loop (and the `repro serve`
/// CLI) run without the PJRT runtime.
pub struct EchoExecutor;

impl BatchExecutor for EchoExecutor {
    fn run_batch(&mut self, prompts: &[Vec<i32>], new_tokens: usize) -> anyhow::Result<Vec<Vec<i32>>> {
        Ok(prompts
            .iter()
            .map(|p| {
                let base = p.first().copied().unwrap_or(0);
                (0..new_tokens as i32).map(|i| base + i).collect()
            })
            .collect())
    }

    fn kv_bytes(&self) -> u64 {
        1024
    }
}

/// Fixed batch-control header the host still sends per dispatch under
/// [`WirePolicy::Streamed`]: batch shape, per-row generation budgets,
/// padding spec — everything a node needs to materialize the padded AOT
/// batch locally instead of receiving the padding over the wire.
pub const BATCH_CONTROL_BYTES: u64 = 64;

/// How serve-loop traffic rides the fabric.
///
/// Both policies serve identical token content on the identical
/// simulated clock discipline; they differ only in which bytes are put
/// on which links — which is exactly what the host-uplink regression
/// tests and the `d2d_stream` bench A/B.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WirePolicy {
    /// The pre-stream shape: the padded AOT batch crosses the host
    /// uplink on dispatch, completions hairpin end-to-end through the
    /// host ([`Router::complete_costed`]), and KV migrations move as
    /// one monolithic foreground transfer
    /// ([`KvManager::migrate_monolithic`]).
    Hairpin,
    /// Device-to-device streaming: dispatch carries live clipped prompt
    /// tokens plus [`BATCH_CONTROL_BYTES`] (padding is materialized at
    /// the node), completions split control from payload
    /// ([`Router::complete_split`]) so only token ids ride the uplink,
    /// and KV migrations pipeline as chunk quanta on the
    /// [`crate::fabric::KV_STREAM_CLASS`] WFQ class
    /// ([`KvManager::migrate`]).
    #[default]
    Streamed,
}

/// Tunables of the simulated serving loop.
#[derive(Clone, Debug)]
pub struct ServeParams {
    pub batch_width: usize,
    pub prompt_len: usize,
    /// Simulated window a partial batch waits before launching.
    pub batch_window: SimTime,
    pub kv_capacity_per_node: u64,
    /// KV bytes one token of context pins on a node, derived from the
    /// model config ([`KvManager::kv_bytes_per_token`]).  A batch's
    /// reservation is sized *per request*: the sum over its live rows of
    /// (clipped prompt + that row's generation budget) tokens, times
    /// this — not one flat per-batch figure.
    pub kv_bytes_per_token: u64,
    /// Simulated prefill compute per batch.
    pub prefill_compute: SimTime,
    /// Simulated decode compute per generated token (batch-wide step).
    pub token_compute: SimTime,
    /// Wire bytes per token id, for dispatch/response fabric traffic.
    pub bytes_per_token: u64,
    /// Which bytes ride which links ([`WirePolicy::Streamed`] by
    /// default; [`WirePolicy::Hairpin`] is the pre-stream baseline).
    pub wire: WirePolicy,
}

impl Default for ServeParams {
    fn default() -> Self {
        ServeParams {
            batch_width: 4,
            prompt_len: 32,
            batch_window: SimTime::us(2000),
            kv_capacity_per_node: u64::MAX,
            kv_bytes_per_token: 4096,
            prefill_compute: SimTime::us(500),
            token_compute: SimTime::us(50),
            bytes_per_token: 4,
            wire: WirePolicy::Streamed,
        }
    }
}

impl ServeParams {
    pub fn from_config(c: &ServeConfig) -> Self {
        let kv_bytes_per_token = if c.kv_model.is_empty() {
            4096
        } else {
            match crate::llm::all_llms().into_iter().find(|m| m.name == c.kv_model) {
                Some(m) => KvManager::kv_bytes_per_token(m.layers as u64, m.d_model as u64, 2),
                None => {
                    eprintln!(
                        "unknown serve.kv_model {:?}; using the default per-token KV",
                        c.kv_model
                    );
                    4096
                }
            }
        };
        ServeParams {
            batch_width: c.batch_width.max(1) as usize,
            prompt_len: c.prompt_len.max(1) as usize,
            batch_window: SimTime::us(c.batch_timeout_us),
            kv_capacity_per_node: if c.kv_capacity_mib == 0 {
                u64::MAX
            } else {
                c.kv_capacity_mib << 20
            },
            kv_bytes_per_token,
            prefill_compute: SimTime::us(c.prefill_compute_us),
            token_compute: SimTime::us(c.token_compute_us),
            bytes_per_token: 4,
            wire: match c.wire.as_str() {
                "hairpin" => WirePolicy::Hairpin,
                "streamed" | "" => WirePolicy::Streamed,
                other => {
                    eprintln!("unknown serve.wire {other:?}; using \"streamed\"");
                    WirePolicy::Streamed
                }
            },
        }
    }

    /// Per-request-sized KV reservation for `batch` (at least 1 byte, so
    /// capacity accounting always has something to conserve).
    pub fn kv_need(&self, batch: &Batch) -> u64 {
        (self.kv_bytes_per_token * batch.kv_tokens(self.prompt_len)).max(1)
    }
}

/// Final report from a serving run, all in simulated time.
#[derive(Debug)]
pub struct ServeReport {
    pub responses: Vec<InferenceResponse>,
    /// First arrival event to last byte landed.
    pub makespan: SimTime,
    pub requests: u64,
    pub batches: u64,
    pub padded_rows: u64,
    /// Total generated tokens across live rows.
    pub tokens_out: u64,
    /// Live prompt tokens dispatched (clipped to the engine prompt
    /// length; padding rows excluded).
    pub prompt_tokens: u64,
    /// KV bytes reserved across all batches, per-request sized.
    pub kv_reserved_bytes: u64,
    pub failed_batches: u64,
    pub kv_migrations: u64,
    pub kv_evictions: u64,
    pub latency: LatencyHistogram,
    /// Dispatch + response wire bytes per node, from the router.
    pub node_wire_bytes: Vec<u64>,
    /// Bytes that actually crossed the host uplink (dispatch control +
    /// prompt ingress + response control) — the numerator of
    /// `serve.host_bytes_per_token`.  Under [`WirePolicy::Streamed`]
    /// this excludes padding and in-pool KV moves; under
    /// [`WirePolicy::Hairpin`] it is the full historical hairpin.
    pub host_bytes: u64,
}

impl ServeReport {
    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_out as f64 / self.makespan.as_secs_f64().max(1e-9)
    }

    pub fn mean_latency(&self) -> SimTime {
        self.latency.mean()
    }

    /// Export the canonical `serve.*` counters; with the fabric's
    /// export, this is the byte-comparable fingerprint of a run.
    pub fn export_counters(&self, c: &mut Counters) {
        c.add(names::SERVE_REQUESTS, self.requests);
        c.add(names::SERVE_RESPONSES, self.responses.len() as u64);
        c.add(names::SERVE_BATCHES, self.batches);
        c.add(names::SERVE_PADDED_ROWS, self.padded_rows);
        c.add(names::SERVE_TOKENS_OUT, self.tokens_out);
        c.add(names::SERVE_PROMPT_TOKENS, self.prompt_tokens);
        c.add(names::SERVE_KV_RESERVED_BYTES, self.kv_reserved_bytes);
        c.add(names::SERVE_FAILED_BATCHES, self.failed_batches);
        c.add(names::SERVE_KV_MIGRATIONS, self.kv_migrations);
        c.add(names::SERVE_KV_EVICTIONS, self.kv_evictions);
        c.add(names::SERVE_MAKESPAN_NS, self.makespan.as_ns());
        c.add(names::SERVE_LATENCY_MEAN_NS, self.latency.mean().as_ns());
        c.add(names::SERVE_LATENCY_P99_NS, self.latency.quantile(0.99).as_ns());
        c.add(names::SERVE_HOST_BYTES_PER_TOKEN, self.host_bytes_per_token());
    }

    /// Host-uplink bytes per generated token — the per-run figure the
    /// Table 2 host-traffic comparison pins (floor-divided; byte-exact
    /// across same-seed runs).
    pub fn host_bytes_per_token(&self) -> u64 {
        self.host_bytes / self.tokens_out.max(1)
    }
}

const EV_ARRIVE: u8 = 1;
const EV_DEADLINE: u8 = 2;
const EV_DONE: u8 = 3;

struct InFlight {
    batch: Batch,
    node: u32,
    reserved: bool,
    /// Per-request-sized KV bytes this batch reserved (and leaves
    /// resident as a session).
    kv_bytes: u64,
}

/// A completed batch whose KV stays resident on `node` until migrated
/// or evicted — sized from its requests, not a flat per-batch figure.
struct Session {
    node: u32,
    bytes: u64,
}

struct ServeLoop<'p, E> {
    params: &'p ServeParams,
    batcher: Batcher,
    router: Router,
    kv: KvManager,
    exes: Vec<Option<E>>,
    inflight: Vec<Option<InFlight>>,
    /// Done-event slots available for reuse, so `inflight` stays sized
    /// to the in-flight high-water mark instead of growing per batch
    /// over a million-request storm.
    free_slots: Vec<usize>,
    /// Batches currently in flight (`inflight` entries that are `Some`).
    inflight_active: usize,
    blocked: VecDeque<Batch>,
    /// Resident sessions, oldest first.
    sessions: VecDeque<Session>,
    /// Whether the skew rebalance may run on the next `try_dispatch`.
    /// Disarmed when a placement fails (so a blocked batch retried
    /// across many deadline events doesn't re-trigger a migration —
    /// real wire traffic plus a destination-FTL charge — per retry) and
    /// re-armed on any state change that could alter the outcome: a
    /// successful dispatch, a batch completion, a new arrival.
    rebalance_armed: bool,
    arrivals: BTreeMap<u64, SimTime>,
    responses: Vec<InferenceResponse>,
    latency: LatencyHistogram,
    tokens_out: u64,
    prompt_tokens: u64,
    kv_reserved_bytes: u64,
    failed_batches: u64,
    kv_migrations: u64,
    kv_evictions: u64,
    host_bytes: u64,
    end: SimTime,
}

impl<E: BatchExecutor> ServeLoop<'_, E> {
    fn nodes(&self) -> u32 {
        self.router.nodes() as u32
    }

    /// The loop's instantaneous load signal (see [`QueuePressure`]).
    fn pressure(&self, now: SimTime) -> QueuePressure {
        QueuePressure {
            queued: self.batcher.pending(),
            blocked: self.blocked.len(),
            inflight: self.inflight_active,
            oldest_wait: self
                .batcher
                .oldest_arrival()
                .map(|at| now.saturating_sub(at))
                .unwrap_or(SimTime::ZERO),
        }
    }

    /// Dispatch everything dispatchable at `now`: blocked batches first
    /// (FIFO), then newly formable ones.
    fn pump(&mut self, sim: &mut PoolSim, now: SimTime) {
        while let Some(batch) = self.blocked.pop_front() {
            match self.try_dispatch(sim, now, batch) {
                Ok(()) => {}
                Err(batch) => {
                    self.blocked.push_front(batch);
                    break;
                }
            }
        }
        while self.blocked.is_empty() {
            let Some(batch) = self.batcher.form(now, false) else { break };
            if let Err(batch) = self.try_dispatch(sim, now, batch) {
                self.blocked.push_back(batch);
            }
        }
        // capacity valve: a pool that cannot fit even one batch anywhere
        // (capacity below the batch's per-request KV need) must still
        // make progress
        if !self.blocked.is_empty() && self.inflight_active == 0 {
            let batch = self.blocked.pop_front().expect("checked non-empty");
            let node = (0..self.nodes())
                .min_by_key(|n| (self.router.outstanding_of(*n), *n))
                .expect("at least one node");
            self.dispatch_on(sim, now, node, batch);
        }
    }

    fn try_dispatch(&mut self, sim: &mut PoolSim, now: SimTime, batch: Batch) -> Result<(), Batch> {
        let need = self.params.kv_need(&batch);
        let n = self.nodes();
        // KV-pressure rebalance: when residency skews by two of this
        // batch's reservations or more, the oldest migratable session on
        // the fullest node moves to the emptiest over the fabric before
        // placement
        let hi = (0..n).rev().max_by_key(|i| self.kv.used_of(*i)).expect("nodes > 0");
        let lo = (0..n).min_by_key(|i| self.kv.used_of(*i)).expect("nodes > 0");
        if self.rebalance_armed
            && hi != lo
            && self.kv.used_of(hi) >= self.kv.used_of(lo) + 2 * need
        {
            if let Some(pos) = self
                .sessions
                .iter()
                .position(|s| s.node == hi && self.kv.fits(lo, s.bytes))
            {
                let sess = self.sessions.remove(pos).expect("position is in range");
                let moved = match self.params.wire {
                    WirePolicy::Streamed => {
                        self.kv
                            .migrate(&mut sim.fabric, &mut sim.ftls, now, hi, lo, sess.bytes)
                    }
                    WirePolicy::Hairpin => self.kv.migrate_monolithic(
                        &mut sim.fabric,
                        &mut sim.ftls,
                        now,
                        hi,
                        lo,
                        sess.bytes,
                    ),
                };
                if moved.is_some() {
                    self.sessions.push_front(Session { node: lo, bytes: sess.bytes });
                    self.kv_migrations += 1;
                }
            }
        }
        let pick = |kv: &KvManager, router: &Router| {
            (0..n)
                .filter(|i| kv.fits(*i, need))
                .min_by_key(|i| (router.outstanding_of(*i), *i))
        };
        // a waiting batch outranks idle sessions: evict oldest-first
        // until the batch fits somewhere (sessions vary in size now, so
        // one eviction is not always enough) — but only among sessions
        // whose release can actually move some node toward fitting: a
        // session on a node whose *non-session* residency already rules
        // the batch out is never sacrificed (killing it destroys
        // resident state, and its already-spilled FTL pages, without
        // unblocking anything).  And never evict for a batch no amount
        // of evicting can fit (the capacity valve in `pump` handles
        // that case).
        let node = loop {
            if let Some(node) = pick(&self.kv, &self.router) {
                break node;
            }
            if !self.kv.fits_empty(need) {
                self.rebalance_armed = false;
                return Err(batch);
            }
            let mut resident = vec![0u64; n as usize];
            for s in &self.sessions {
                resident[s.node as usize] += s.bytes;
            }
            let Some(pos) = self.sessions.iter().position(|s| {
                self.kv
                    .fits_after_release(s.node, resident[s.node as usize], need)
            }) else {
                self.rebalance_armed = false;
                return Err(batch);
            };
            let victim = self.sessions.remove(pos).expect("position is in range");
            self.kv.release(victim.node, victim.bytes);
            self.kv_evictions += 1;
        };
        self.dispatch_on(sim, now, node, batch);
        Ok(())
    }

    fn dispatch_on(&mut self, sim: &mut PoolSim, now: SimTime, node: u32, batch: Batch) {
        let live_prompt_tokens = batch
            .requests
            .iter()
            .map(|r| r.prompt.len().min(self.params.prompt_len) as u64)
            .sum::<u64>();
        // the AOT batch shape is static either way; Hairpin ships the
        // padding over the wire, Streamed sends live tokens plus a
        // fixed control header and materializes the padding at the node
        let prompt_bytes = match self.params.wire {
            WirePolicy::Hairpin => {
                (batch.prompts.len() * self.params.prompt_len) as u64 * self.params.bytes_per_token
            }
            WirePolicy::Streamed => {
                live_prompt_tokens * self.params.bytes_per_token + BATCH_CONTROL_BYTES
            }
        };
        self.prompt_tokens += live_prompt_tokens;
        self.host_bytes += prompt_bytes.max(1);
        let receipt = self
            .router
            .dispatch_to(&mut sim.fabric, now, node, prompt_bytes.max(1));
        let kv_bytes = self.params.kv_need(&batch);
        let reserved = self.kv.reserve(node, kv_bytes);
        if reserved {
            self.kv_reserved_bytes += kv_bytes;
        }
        let compute = self.params.prefill_compute
            + SimTime::ns(self.params.token_compute.as_ns() * batch.max_new_tokens as u64);
        let done_at = sim.compute_mut(node).occupy(receipt.finish, compute);
        let slot = match self.free_slots.pop() {
            Some(s) => s,
            None => {
                self.inflight.push(None);
                self.inflight.len() - 1
            }
        };
        self.inflight[slot] = Some(InFlight { batch, node, reserved, kv_bytes });
        self.inflight_active += 1;
        // residency moved: a placement that failed before may succeed
        // (or skew differently) now
        self.rebalance_armed = true;
        sim.queue.schedule_at(done_at, tag(EV_DONE, slot as u64));
        self.end = self.end.max(done_at);
    }

    fn on_done(&mut self, sim: &mut PoolSim, now: SimTime, slot: usize) {
        let InFlight { batch, node, reserved, kv_bytes } =
            self.inflight[slot].take().expect("each done event fires once");
        self.inflight_active -= 1;
        self.free_slots.push(slot);
        self.rebalance_armed = true;
        let result = match self.exes[node as usize].as_mut() {
            Some(exe) => exe.run_batch(&batch.prompts, batch.max_new_tokens),
            None => Err(anyhow::anyhow!("engine unavailable")),
        };
        // each live row ships its own generation budget back, not the
        // batch-wide maximum
        let resp_bytes = batch
            .requests
            .iter()
            .map(|r| r.max_new_tokens as u64)
            .sum::<u64>()
            * self.params.bytes_per_token;
        // token ids ARE the host-bound control; the batch's KV is the
        // in-pool payload and stays resident on the node (it moves
        // later, if at all, as a migration stream) — under Streamed the
        // split makes that explicit instead of hairpinning everything
        let receipt = match self.params.wire {
            WirePolicy::Hairpin => {
                self.router
                    .complete_costed(&mut sim.fabric, now, node, resp_bytes.max(1))
            }
            WirePolicy::Streamed => self.router.complete_split(
                &mut sim.fabric,
                now,
                node,
                resp_bytes.max(1),
                0,
                None,
            ),
        };
        self.host_bytes += resp_bytes.max(1);
        self.end = self.end.max(receipt.finish);
        if reserved {
            // the batch's KV stays resident as a session until migrated
            // or evicted — and resident KV is flash it *programs*: the
            // spill charges the node's FTL write ledger (async, on the
            // device's own flush lane, so serve latency is untouched)
            sim.ftls.write(node, now, kv_bytes);
            self.sessions.push_back(Session { node, bytes: kv_bytes });
        }
        match result {
            Ok(rows) => {
                for (i, req) in batch.requests.iter().enumerate() {
                    let tokens = rows.get(i).cloned().unwrap_or_default();
                    let want = req.max_new_tokens.min(tokens.len());
                    let tokens = tokens[..want].to_vec();
                    self.tokens_out += tokens.len() as u64;
                    let arrived = self.arrivals.get(&req.id).copied().unwrap_or(now);
                    let latency = receipt.finish.saturating_sub(arrived);
                    self.latency.record(latency);
                    self.responses.push(InferenceResponse {
                        id: req.id,
                        tokens,
                        node,
                        latency,
                    });
                }
            }
            Err(e) => {
                eprintln!("batch failed on node {node}: {e:#}");
                self.failed_batches += 1;
            }
        }
    }
}

/// Instantaneous serve-loop load, exported to the hook on every foreign
/// event — the per-tick queue-depth signal an autoscaler
/// ([`crate::pool::AutoScaler`]) decides on.  All fields are derived
/// from deterministic loop state, so two same-seed runs hand identical
/// pressure sequences to their hooks.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueuePressure {
    /// Requests sitting in the batcher, not yet formed into a batch.
    pub queued: usize,
    /// Formed batches no node could currently admit.
    pub blocked: usize,
    /// Batches executing on some node right now.
    pub inflight: usize,
    /// How long the oldest unformed request has been waiting.
    pub oldest_wait: SimTime,
}

impl QueuePressure {
    /// Work that has arrived but not yet launched — the depth signal a
    /// scaling controller thresholds on.
    pub fn depth(&self) -> usize {
        self.queued + self.blocked
    }

    /// Nothing queued, nothing blocked, nothing running.
    pub fn idle(&self) -> bool {
        self.depth() == 0 && self.inflight == 0
    }
}

/// Observer for event-queue entries the serving loop does not own.
///
/// The serve loop pops *every* event on the shared queue; tag kinds it
/// recognizes (arrivals, deadlines, completions) drive the request
/// lifecycle, and anything else is handed to the run's hook — with
/// mutable access to the whole [`PoolSim`], so the hook can degrade
/// links, fail nodes, or schedule follow-up events of its own while
/// requests are mid-flight.  This is the seam the chaos engine
/// ([`crate::chaos`]) and the autoscaler ([`crate::pool::autoscale`])
/// inject through.
pub trait ServeHook {
    /// One foreign event, after its pop advanced the clock to `now`.
    fn on_event(&mut self, sim: &mut PoolSim, now: SimTime, tag: u64);

    /// [`ServeHook::on_event`], plus the loop's instantaneous
    /// [`QueuePressure`].  Default delegates to `on_event` so pressure-
    /// blind hooks (chaos injection) need not change; the serve loop
    /// always calls *this* entry point.
    fn on_event_with_pressure(
        &mut self,
        sim: &mut PoolSim,
        now: SimTime,
        tag: u64,
        _pressure: QueuePressure,
    ) {
        self.on_event(sim, now, tag);
    }
}

/// What [`serve`] runs with: foreign events still advance the clock,
/// nothing else (the pre-hook behavior, verbatim).
struct NoHook;

impl ServeHook for NoHook {
    fn on_event(&mut self, _sim: &mut PoolSim, _now: SimTime, _tag: u64) {}
}

/// Serve `requests` (each tagged with its simulated arrival time) over
/// one node per entry of `factories`, on `sim`'s shared clock and
/// fabric.  Drains `sim.queue`; returns once every request completed.
///
/// The loop owns the queue for the duration of the call: events with a
/// tag kind it does not recognize are popped (their time still advances
/// the clock) and otherwise ignored, so schedule foreign work either
/// before (and pop it yourself, as `Orchestrator::deploy_sim` callers
/// do) or after serving — or use [`serve_with_hook`] to be called back
/// on each one.
pub fn serve<E, F>(
    sim: &mut PoolSim,
    factories: Vec<F>,
    requests: Vec<(SimTime, InferenceRequest)>,
    params: &ServeParams,
) -> ServeReport
where
    E: BatchExecutor,
    F: FnOnce() -> anyhow::Result<E>,
{
    serve_with_hook(sim, factories, requests, params, &mut NoHook)
}

/// [`serve`], with a [`ServeHook`] receiving every foreign event on the
/// queue as the run replays — fault injection and healing interleave
/// with serving on the one clock instead of running at a private t=0.
pub fn serve_with_hook<E, F>(
    sim: &mut PoolSim,
    factories: Vec<F>,
    requests: Vec<(SimTime, InferenceRequest)>,
    params: &ServeParams,
    hook: &mut dyn ServeHook,
) -> ServeReport
where
    E: BatchExecutor,
    F: FnOnce() -> anyhow::Result<E>,
{
    let nodes = factories.len();
    assert!(nodes > 0, "need at least one node");
    let start = sim.now();

    let mut exes: Vec<Option<E>> = Vec::with_capacity(nodes);
    for (node, factory) in factories.into_iter().enumerate() {
        match factory() {
            Ok(e) => exes.push(Some(e)),
            Err(e) => {
                eprintln!("node {node}: engine init failed: {e:#}");
                exes.push(None);
            }
        }
    }

    for (i, (at, _)) in requests.iter().enumerate() {
        sim.queue.schedule_at((*at).max(start), tag(EV_ARRIVE, i as u64));
    }

    let mut lp = ServeLoop {
        params,
        batcher: Batcher::new(params.batch_width, params.prompt_len, params.batch_window),
        router: Router::new(nodes),
        kv: KvManager::new(nodes, params.kv_capacity_per_node),
        exes,
        inflight: Vec::new(),
        free_slots: Vec::new(),
        inflight_active: 0,
        blocked: VecDeque::new(),
        sessions: VecDeque::new(),
        rebalance_armed: true,
        arrivals: BTreeMap::new(),
        responses: Vec::new(),
        latency: LatencyHistogram::new(),
        tokens_out: 0,
        prompt_tokens: 0,
        kv_reserved_bytes: 0,
        failed_batches: 0,
        kv_migrations: 0,
        kv_evictions: 0,
        host_bytes: 0,
        end: start,
    };

    while let Some(ev) = sim.queue.pop() {
        let now = ev.at;
        match tag_kind(ev.tag) {
            EV_ARRIVE => {
                let req = requests[tag_payload(ev.tag) as usize].1.clone();
                lp.arrivals.insert(req.id, now);
                lp.batcher.push(req, now);
                lp.rebalance_armed = true;
                // the partial-batch window: by this instant the request
                // must have launched or launch now
                sim.queue
                    .schedule_at(now + params.batch_window, tag(EV_DEADLINE, 0));
                lp.pump(sim, now);
            }
            EV_DEADLINE => lp.pump(sim, now),
            EV_DONE => {
                lp.on_done(sim, now, tag_payload(ev.tag) as usize);
                lp.pump(sim, now);
            }
            // a foreign event kind on the shared queue: not ours to
            // interpret — the pop advanced the clock; the hook decides
            // what (if anything) it means, with the loop's live load
            // signal alongside
            _ => {
                let pressure = lp.pressure(now);
                hook.on_event_with_pressure(sim, now, ev.tag, pressure);
                lp.pump(sim, now);
            }
        }
    }

    ServeReport {
        responses: lp.responses,
        makespan: lp.end.saturating_sub(start),
        requests: lp.batcher.requests_seen,
        batches: lp.batcher.batches_formed,
        padded_rows: lp.batcher.padded_rows,
        tokens_out: lp.tokens_out,
        prompt_tokens: lp.prompt_tokens,
        kv_reserved_bytes: lp.kv_reserved_bytes,
        failed_batches: lp.failed_batches,
        kv_migrations: lp.kv_migrations,
        kv_evictions: lp.kv_evictions,
        latency: lp.latency,
        node_wire_bytes: (0..nodes as u32).map(|n| lp.router.wire_bytes_of(n)).collect(),
        host_bytes: lp.host_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EtherOnConfig, PoolConfig};

    fn sim(nodes: u32) -> PoolSim {
        PoolSim::with_pool(
            &PoolConfig {
                nodes_per_array: nodes.max(4),
                arrays: 1,
                ..Default::default()
            },
            &EtherOnConfig::default(),
        )
    }

    fn reqs(n: u64) -> Vec<(SimTime, InferenceRequest)> {
        (0..n)
            .map(|id| {
                (
                    SimTime::us(id * 10),
                    InferenceRequest {
                        id,
                        prompt: vec![id as i32 * 100; 8],
                        max_new_tokens: 3,
                    },
                )
            })
            .collect()
    }

    fn mk() -> impl FnOnce() -> anyhow::Result<EchoExecutor> {
        || Ok(EchoExecutor)
    }

    fn params() -> ServeParams {
        ServeParams {
            batch_width: 4,
            prompt_len: 8,
            batch_window: SimTime::us(100),
            ..Default::default()
        }
    }

    #[test]
    fn all_requests_complete_exactly_once() {
        let mut s = sim(2);
        let report = serve(&mut s, vec![mk(), mk()], reqs(10), &params());
        let mut ids: Vec<u64> = report.responses.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
        assert_eq!(report.requests, 10);
        assert!(s.queue.is_empty(), "serve drains the queue");
    }

    #[test]
    fn responses_carry_request_specific_tokens() {
        let mut s = sim(1);
        let report = serve(&mut s, vec![mk()], reqs(4), &params());
        for r in &report.responses {
            assert_eq!(
                r.tokens,
                vec![r.id as i32 * 100, r.id as i32 * 100 + 1, r.id as i32 * 100 + 2]
            );
        }
    }

    #[test]
    fn work_spreads_across_nodes() {
        let mut s = sim(2);
        let mut p = params();
        p.batch_width = 2;
        let report = serve(&mut s, vec![mk(), mk()], reqs(16), &p);
        let nodes: std::collections::HashSet<u32> =
            report.responses.iter().map(|r| r.node).collect();
        assert_eq!(nodes.len(), 2, "both nodes should serve");
    }

    #[test]
    fn throughput_and_latency_are_simulated() {
        let mut s = sim(1);
        let mut rs = reqs(4);
        for (at, _) in rs.iter_mut() {
            *at = SimTime::ZERO; // one full batch at t=0
        }
        let p = params();
        let report = serve(&mut s, vec![mk()], rs, &p);
        assert_eq!(report.tokens_out, 12);
        assert_eq!(report.batches, 1);
        // compute = prefill + 3 tokens; latency adds dispatch + response wire
        let compute = p.prefill_compute + SimTime::ns(p.token_compute.as_ns() * 3);
        assert!(report.mean_latency() >= compute);
        assert!(report.makespan >= compute);
        assert!(report.throughput_tok_s() > 0.0);
        let mut c = Counters::new();
        report.export_counters(&mut c);
        assert_eq!(c.get(names::SERVE_TOKENS_OUT), 12);
        assert_eq!(c.get(names::SERVE_RESPONSES), 4);
        assert!(c.get(names::SERVE_MAKESPAN_NS) > 0);
    }

    #[test]
    fn partial_batches_are_padded_not_lost() {
        let mut s = sim(1);
        let report = serve(&mut s, vec![mk()], reqs(5), &params());
        assert_eq!(report.responses.len(), 5);
        assert!(report.padded_rows >= 3, "second batch padded");
    }

    #[test]
    fn dispatch_and_response_bytes_cross_the_fabric() {
        let mut s = sim(2);
        let report = serve(&mut s, vec![mk(), mk()], reqs(8), &params());
        assert_eq!(report.responses.len(), 8);
        let mut c = Counters::new();
        s.fabric.export_counters(&mut c);
        assert!(c.get(names::FABRIC_BYTES_HOST_UPLINK) > 0, "dispatch + response on the wire");
        assert!(c.get(names::FABRIC_BYTES_ARRAY) > 0);
    }

    #[test]
    fn streamed_wire_cuts_host_uplink_vs_hairpin() {
        // same requests, same clock discipline, two wire policies: the
        // streamed shape must serve identical tokens while shipping a
        // small fraction of the hairpin's host-uplink bytes (8 live
        // prompt tokens + a 64B header vs a padded 256-token row)
        let run = |wire: WirePolicy| {
            let mut s = sim(2);
            let mut p = params();
            p.prompt_len = 256;
            p.wire = wire;
            let report = serve(&mut s, vec![mk(), mk()], reqs(12), &p);
            let mut c = Counters::new();
            s.fabric.export_counters(&mut c);
            (report, c)
        };
        let (hr, hc) = run(WirePolicy::Hairpin);
        let (sr, sc) = run(WirePolicy::Streamed);
        assert_eq!(sr.tokens_out, hr.tokens_out, "wire policy never changes content");
        assert_eq!(sr.responses.len(), hr.responses.len());
        assert!(
            hc.get(names::FABRIC_BYTES_HOST_UPLINK)
                > 3 * sc.get(names::FABRIC_BYTES_HOST_UPLINK),
            "padding off the uplink: hairpin {} vs streamed {}",
            hc.get(names::FABRIC_BYTES_HOST_UPLINK),
            sc.get(names::FABRIC_BYTES_HOST_UPLINK)
        );
        assert!(hr.host_bytes > 3 * sr.host_bytes);
        assert!(sr.host_bytes_per_token() < hr.host_bytes_per_token());
        let mut c = Counters::new();
        sr.export_counters(&mut c);
        assert_eq!(c.get(names::SERVE_HOST_BYTES_PER_TOKEN), sr.host_bytes_per_token());
    }

    #[test]
    fn same_seed_runs_are_byte_identical() {
        let run = || {
            let mut s = sim(2);
            let report = serve(&mut s, vec![mk(), mk()], reqs(12), &params());
            let mut c = Counters::new();
            report.export_counters(&mut c);
            s.export_counters(&mut c);
            let lats: Vec<(u64, SimTime)> =
                report.responses.iter().map(|r| (r.id, r.latency)).collect();
            (c, lats)
        };
        let (c1, l1) = run();
        let (c2, l2) = run();
        assert_eq!(c1, c2, "serve.* and fabric.* counters must match byte-for-byte");
        assert_eq!(l1, l2, "per-request simulated latencies must match");
    }

    #[test]
    fn hook_sees_foreign_events_at_their_scheduled_time() {
        struct Spy(Vec<(SimTime, u64)>);
        impl ServeHook for Spy {
            fn on_event(&mut self, sim: &mut PoolSim, now: SimTime, tag: u64) {
                // a hook may mutate the sim: schedule a follow-up once
                if tag_payload(tag) == 1 {
                    sim.queue.schedule_at(now + SimTime::us(5), crate::sim::tag(9, 2));
                }
                self.0.push((now, tag));
            }
        }
        let mut s = sim(1);
        s.queue.schedule_at(SimTime::us(30), crate::sim::tag(9, 1));
        let mut spy = Spy(Vec::new());
        let report = serve_with_hook(&mut s, vec![mk()], reqs(4), &params(), &mut spy);
        assert_eq!(report.responses.len(), 4, "serving is undisturbed");
        assert_eq!(
            spy.0,
            vec![
                (SimTime::us(30), crate::sim::tag(9, 1)),
                (SimTime::us(35), crate::sim::tag(9, 2)),
            ],
            "every foreign event reaches the hook, including hook-scheduled ones"
        );
    }

    #[test]
    fn failed_engine_counts_failed_batches() {
        let mut s = sim(1);
        let bad = || Err::<EchoExecutor, _>(anyhow::anyhow!("no engine"));
        let report = serve(&mut s, vec![bad], reqs(4), &params());
        assert!(report.responses.is_empty());
        assert!(report.failed_batches >= 1);
    }

    #[test]
    fn kv_pressure_migrates_sessions() {
        // node 0's one long request leaves a big resident session
        // ((8+400) tokens of KV) while node 1 clears short ones (9
        // tokens each); once the big session exists, the skew triggers a
        // migration toward the emptier node
        let mut s = sim(2);
        let p = ServeParams {
            batch_width: 1,
            prompt_len: 8,
            batch_window: SimTime::us(10),
            token_compute: SimTime::us(50),
            ..Default::default()
        };
        let mut rs = vec![(
            SimTime::ZERO,
            InferenceRequest { id: 0, prompt: vec![1; 8], max_new_tokens: 400 },
        )];
        // the long batch computes for ~20.5ms; later short requests land
        // both before and after its session forms
        for k in 1..=4u64 {
            rs.push((
                SimTime::us(k * 7000),
                InferenceRequest { id: k, prompt: vec![1; 8], max_new_tokens: 1 },
            ));
        }
        let report = serve(&mut s, vec![mk(), mk()], rs, &p);
        assert_eq!(report.responses.len(), 5);
        assert!(
            report.kv_migrations >= 1,
            "session skew should trigger a migration: {report:?}"
        );
    }

    #[test]
    fn kv_capacity_evicts_sessions_to_admit_batches() {
        let mut s = sim(1);
        let p = ServeParams {
            batch_width: 1,
            prompt_len: 8,
            batch_window: SimTime::us(10),
            // exactly one (8 prompt + 1 new)-token batch resident
            kv_capacity_per_node: 9 * 4096,
            ..Default::default()
        };
        let rs: Vec<_> = (0..3u64)
            .map(|id| {
                (
                    SimTime::us(id * 5000),
                    InferenceRequest { id, prompt: vec![1; 8], max_new_tokens: 1 },
                )
            })
            .collect();
        let report = serve(&mut s, vec![mk()], rs, &p);
        assert_eq!(report.responses.len(), 3, "capacity pressure must not drop requests");
        assert!(report.kv_evictions >= 1, "old sessions evicted for new batches: {report:?}");
    }

    /// A bare loop over `nodes` echo executors, for driving
    /// `try_dispatch` against hand-built residency states.
    fn mk_loop(params: &ServeParams, nodes: usize) -> ServeLoop<'_, EchoExecutor> {
        ServeLoop {
            params,
            batcher: Batcher::new(params.batch_width, params.prompt_len, params.batch_window),
            router: Router::new(nodes),
            kv: KvManager::new(nodes, params.kv_capacity_per_node),
            exes: (0..nodes).map(|_| Some(EchoExecutor)).collect(),
            inflight: Vec::new(),
            free_slots: Vec::new(),
            inflight_active: 0,
            blocked: VecDeque::new(),
            sessions: VecDeque::new(),
            rebalance_armed: true,
            arrivals: BTreeMap::new(),
            responses: Vec::new(),
            latency: LatencyHistogram::new(),
            tokens_out: 0,
            prompt_tokens: 0,
            kv_reserved_bytes: 0,
            failed_batches: 0,
            kv_migrations: 0,
            kv_evictions: 0,
            host_bytes: 0,
            end: SimTime::ZERO,
        }
    }

    /// One single-request batch whose KV need is `prompt + new` tokens
    /// (at `kv_bytes_per_token: 1`, need == token count).
    fn one_batch(prompt_len: usize, new_tokens: usize) -> Batch {
        let mut b = Batcher::new(1, prompt_len, SimTime::ZERO);
        b.push(
            InferenceRequest {
                id: 0,
                prompt: vec![1; prompt_len],
                max_new_tokens: new_tokens,
            },
            SimTime::ZERO,
        );
        b.form(SimTime::ZERO, true).expect("one request forms one batch")
    }

    #[test]
    fn eviction_spares_sessions_on_nodes_it_cannot_help() {
        // node 0's residency is dominated by a non-session (in-flight)
        // reservation: even releasing its only session cannot admit the
        // batch there, so that session must survive — only node 1's
        // sessions (whose release does admit the batch) are sacrificed
        let p = ServeParams {
            batch_width: 1,
            prompt_len: 10,
            batch_window: SimTime::ZERO,
            kv_capacity_per_node: 100,
            kv_bytes_per_token: 1,
            ..Default::default()
        };
        let mut s = sim(2);
        let mut lp = mk_loop(&p, 2);
        assert!(lp.kv.reserve(0, 90), "node 0: in-flight reservation");
        assert!(lp.kv.reserve(0, 5));
        lp.sessions.push_back(Session { node: 0, bytes: 5 }); // globally oldest
        assert!(lp.kv.reserve(1, 60));
        assert!(lp.kv.reserve(1, 30));
        lp.sessions.push_back(Session { node: 1, bytes: 60 });
        lp.sessions.push_back(Session { node: 1, bytes: 30 });
        let batch = one_batch(10, 40);
        assert_eq!(p.kv_need(&batch), 50);
        assert!(lp.try_dispatch(&mut s, SimTime::ZERO, batch).is_ok());
        assert_eq!(lp.kv_evictions, 1, "one node-1 eviction admits the batch");
        assert!(
            lp.sessions.iter().any(|sess| sess.node == 0 && sess.bytes == 5),
            "the node-0 session survives: evicting it could never have helped"
        );
        assert_eq!(lp.kv.used_of(0), 95, "node 0 residency untouched");
        assert_eq!(lp.kv.used_of(1), 30 + 50, "node 1: survivor session + new reservation");
    }

    #[test]
    fn blocked_batch_retries_do_not_thrash_migrations_or_evictions() {
        // a batch no node can place, retried across many deadline-event
        // pumps with no intervening state change, must not re-run the
        // skew rebalance (each migration is real wire traffic plus a
        // destination-FTL charge) and must not grind down resident
        // sessions whose release cannot help
        let p = ServeParams {
            batch_width: 1,
            prompt_len: 10,
            batch_window: SimTime::ZERO,
            kv_capacity_per_node: 1000,
            kv_bytes_per_token: 1,
            ..Default::default()
        };
        let mut s = sim(2);
        let mut lp = mk_loop(&p, 2);
        assert!(lp.kv.reserve(0, 990), "node 0: in-flight reservation");
        assert!(lp.kv.reserve(0, 8));
        lp.sessions.push_back(Session { node: 0, bytes: 8 });
        assert!(lp.kv.reserve(1, 960), "node 1: in-flight reservation");
        let mut batch = one_batch(10, 40); // need 50: nowhere fits
        assert!(lp.rebalance_armed);
        for retry in 0..50 {
            batch = lp
                .try_dispatch(&mut s, SimTime::us(retry), batch)
                .expect_err("no node can admit the batch");
            assert!(!lp.rebalance_armed, "placement failure disarms the rebalance");
        }
        assert_eq!(lp.kv_migrations, 0, "bounded: no migration per retry");
        assert_eq!(lp.kv_evictions, 0, "no futile evictions either");
        assert_eq!(lp.sessions.len(), 1, "resident session survives every retry");
        assert_eq!(lp.kv.used_of(0), 998);
        assert_eq!(lp.kv.used_of(1), 960);
        // a completion frees node 1 and re-arms the rebalance (as
        // `on_done` does); the *next* attempt may migrate — once
        lp.kv.release(1, 960);
        lp.rebalance_armed = true;
        assert!(lp.try_dispatch(&mut s, SimTime::us(50), batch).is_ok());
        assert_eq!(lp.kv_migrations, 1, "one state change, one migration");
    }

    #[test]
    fn capacity_valve_serves_unfittable_batches_without_spill() {
        // per-node capacity below any batch's KV need: every dispatch is
        // forced through the pump valve — each request still served
        // exactly once, with no reservation, no resident session, and no
        // FTL spill
        let mut s = sim(2);
        let p = ServeParams {
            batch_width: 4,
            prompt_len: 8,
            batch_window: SimTime::us(100),
            kv_capacity_per_node: 1000, // < one token's 4096 bytes
            ..Default::default()
        };
        let report = serve(&mut s, vec![mk(), mk()], reqs(6), &p);
        let mut ids: Vec<u64> = report.responses.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..6).collect::<Vec<u64>>(), "every request served exactly once");
        assert!(s.queue.is_empty(), "serve drains the queue");
        assert_eq!(report.kv_reserved_bytes, 0, "reservation never succeeds");
        assert_eq!(report.kv_evictions, 0, "nothing resident to evict");
        assert_eq!(report.kv_migrations, 0);
        let mut c = Counters::new();
        s.ftls.export_counters(&mut c);
        assert_eq!(c.get(names::FTL_HOST_PAGES), 0, "no KV spill ever programs flash");
    }

    #[test]
    fn kv_reservations_are_sized_per_request() {
        let mut s = sim(1);
        let p = ServeParams {
            batch_width: 2,
            prompt_len: 8,
            batch_window: SimTime::us(10),
            kv_bytes_per_token: 1000,
            ..Default::default()
        };
        // one prompt-heavy and one output-heavy request in one batch
        let rs = vec![
            (
                SimTime::ZERO,
                InferenceRequest { id: 0, prompt: vec![1; 8], max_new_tokens: 2 },
            ),
            (
                SimTime::ZERO,
                InferenceRequest { id: 1, prompt: vec![1; 3], max_new_tokens: 5 },
            ),
        ];
        let report = serve(&mut s, vec![mk()], rs, &p);
        assert_eq!(report.responses.len(), 2);
        // (8 + 2) + (3 + 5) tokens of context at 1000 B/token — not a
        // flat per-batch figure
        assert_eq!(report.kv_reserved_bytes, 18_000);
        assert_eq!(report.prompt_tokens, 11, "live clipped prompt tokens only");
        let mut c = Counters::new();
        report.export_counters(&mut c);
        assert_eq!(c.get(names::SERVE_KV_RESERVED_BYTES), 18_000);
        assert_eq!(c.get(names::SERVE_PROMPT_TOKENS), 11);
        assert!(report.node_wire_bytes[0] > 0, "per-node wire split exposed");
    }
}
