//! The serving loop: leader thread batches + routes, per-node worker
//! threads execute batches on their engines, a collector aggregates
//! responses and latency statistics.

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use super::batcher::{Batch, Batcher};
use super::kv_manager::KvManager;
use super::router::Router;
use super::{InferenceRequest, InferenceResponse};

/// Anything that can run a full batch to completion.  Implemented by
/// `runtime::Engine` (real PJRT execution) and by mock executors in tests.
///
/// Executors are *not* required to be `Send`: PJRT handles hold raw
/// pointers, so each worker thread constructs its own executor via the
/// factory passed to [`serve`].
pub trait BatchExecutor {
    /// Generate `new_tokens` tokens for every prompt row.
    fn run_batch(&mut self, prompts: &[Vec<i32>], new_tokens: usize) -> anyhow::Result<Vec<Vec<i32>>>;
    /// KV bytes this executor pins per batch while running.
    fn kv_bytes(&self) -> u64;
}

#[cfg(feature = "pjrt")]
impl BatchExecutor for crate::runtime::Engine {
    fn run_batch(&mut self, prompts: &[Vec<i32>], new_tokens: usize) -> anyhow::Result<Vec<Vec<i32>>> {
        self.generate(prompts, new_tokens)
    }

    fn kv_bytes(&self) -> u64 {
        (self.manifest.kv_cache_elems() * 2 * 4) as u64 // K+V, f32
    }
}

/// Final report from a serving run.
#[derive(Debug)]
pub struct ServeReport {
    pub responses: Vec<InferenceResponse>,
    pub wall: Duration,
    pub batches: u64,
    pub padded_rows: u64,
    /// Total generated tokens across live rows.
    pub tokens_out: u64,
}

impl ServeReport {
    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_out as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn mean_latency(&self) -> Duration {
        if self.responses.is_empty() {
            return Duration::ZERO;
        }
        let total: Duration = self.responses.iter().map(|r| r.latency).sum();
        total / self.responses.len() as u32
    }
}

/// Serve `requests` over one node per entry of `factories`, batching to
/// `batch_width` x `prompt_len`.  Each worker thread constructs its own
/// executor (PJRT handles are not `Send`).  Blocks until all requests
/// complete.
pub fn serve<E, F>(
    factories: Vec<F>,
    requests: Vec<InferenceRequest>,
    batch_width: usize,
    prompt_len: usize,
    kv_capacity_per_node: u64,
) -> ServeReport
where
    E: BatchExecutor,
    F: FnOnce() -> anyhow::Result<E> + Send + 'static,
{
    let nodes = factories.len();
    assert!(nodes > 0, "need at least one node");
    let start = Instant::now();

    let mut batcher = Batcher::new(batch_width, prompt_len, Duration::from_millis(2));
    let mut router = Router::new(nodes);
    let mut kv = KvManager::new(nodes, kv_capacity_per_node);

    // worker threads: one per node, each building its engine in-thread
    let mut senders = Vec::new();
    let (resp_tx, resp_rx) = mpsc::channel::<(u32, Batch, anyhow::Result<Vec<Vec<i32>>>, Duration)>();
    let mut handles = Vec::new();
    for (node_id, factory) in factories.into_iter().enumerate() {
        let (tx, rx) = mpsc::channel::<Batch>();
        senders.push(tx);
        let resp_tx = resp_tx.clone();
        handles.push(thread::spawn(move || {
            let mut exe = match factory() {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("node {node_id}: engine init failed: {e:#}");
                    while let Ok(batch) = rx.recv() {
                        let _ = resp_tx.send((
                            node_id as u32,
                            batch,
                            Err(anyhow::anyhow!("engine unavailable")),
                            Duration::ZERO,
                        ));
                    }
                    return;
                }
            };
            while let Ok(batch) = rx.recv() {
                let t0 = Instant::now();
                let result = exe.run_batch(&batch.prompts, batch.max_new_tokens);
                let _ = resp_tx.send((node_id as u32, batch, result, t0.elapsed()));
            }
        }));
    }
    drop(resp_tx);

    // leader loop: enqueue everything, dispatch, collect
    for r in requests {
        batcher.push(r);
    }
    let mut in_flight = 0u64;
    let mut responses = Vec::new();
    let mut tokens_out = 0u64;

    loop {
        // dispatch as many batches as we can form
        while let Some(batch) = batcher.form(in_flight == 0 || batcher.pending() > 0) {
            let node = router.pick();
            let bytes = KvManager::kv_bytes(1, 1, 1, 1, 1, 1).max(1); // placeholder granularity
            let _ = bytes;
            kv.reserve(node, 1); // one batch-slot unit; capacity enforced upstream
            senders[node as usize]
                .send(batch)
                .expect("worker alive");
            in_flight += 1;
        }
        if in_flight == 0 && batcher.pending() == 0 {
            break;
        }
        // collect one completion
        let (node, batch, result, lat) = resp_rx.recv().expect("workers alive");
        router.complete(node);
        kv.release(node, 1);
        in_flight -= 1;
        match result {
            Ok(rows) => {
                for (i, req) in batch.requests.iter().enumerate() {
                    let tokens = rows.get(i).cloned().unwrap_or_default();
                    let want = req.max_new_tokens.min(tokens.len());
                    let tokens = tokens[..want].to_vec();
                    tokens_out += tokens.len() as u64;
                    responses.push(InferenceResponse {
                        id: req.id,
                        tokens,
                        node,
                        latency: lat,
                    });
                }
            }
            Err(e) => {
                eprintln!("batch failed on node {node}: {e:#}");
            }
        }
    }

    drop(senders);
    for h in handles {
        let _ = h.join();
    }

    ServeReport {
        responses,
        wall: start.elapsed(),
        batches: batcher.batches_formed,
        padded_rows: batcher.padded_rows,
        tokens_out,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mock executor: echoes prompt[0] + i as "generated" tokens.
    struct MockExe {
        delay: Duration,
    }

    impl BatchExecutor for MockExe {
        fn run_batch(&mut self, prompts: &[Vec<i32>], new_tokens: usize) -> anyhow::Result<Vec<Vec<i32>>> {
            thread::sleep(self.delay);
            Ok(prompts
                .iter()
                .map(|p| (0..new_tokens as i32).map(|i| p[0] + i).collect())
                .collect())
        }

        fn kv_bytes(&self) -> u64 {
            1024
        }
    }

    fn reqs(n: u64) -> Vec<InferenceRequest> {
        (0..n)
            .map(|id| InferenceRequest {
                id,
                prompt: vec![id as i32 * 100; 8],
                max_new_tokens: 3,
            })
            .collect()
    }

    fn mk(delay_ms: u64) -> impl FnOnce() -> anyhow::Result<MockExe> + Send + 'static {
        move || Ok(MockExe { delay: Duration::from_millis(delay_ms) })
    }

    #[test]
    fn all_requests_complete_exactly_once() {
        let report = serve(vec![mk(0), mk(0)], reqs(10), 4, 8, u64::MAX);
        let mut ids: Vec<u64> = report.responses.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn responses_carry_request_specific_tokens() {
        let report = serve(vec![mk(0)], reqs(4), 4, 8, u64::MAX);
        for r in &report.responses {
            assert_eq!(r.tokens, vec![r.id as i32 * 100, r.id as i32 * 100 + 1, r.id as i32 * 100 + 2]);
        }
    }

    #[test]
    fn work_spreads_across_nodes() {
        let report = serve(vec![mk(5), mk(5)], reqs(16), 2, 8, u64::MAX);
        let nodes: std::collections::HashSet<u32> =
            report.responses.iter().map(|r| r.node).collect();
        assert_eq!(nodes.len(), 2, "both nodes should serve");
    }

    #[test]
    fn throughput_and_latency_reported() {
        let report = serve(vec![mk(1)], reqs(4), 4, 8, u64::MAX);
        assert_eq!(report.tokens_out, 12);
        assert!(report.throughput_tok_s() > 0.0);
        assert!(report.mean_latency() >= Duration::from_millis(1));
        assert_eq!(report.batches, 1);
    }

    #[test]
    fn partial_batches_are_padded_not_lost() {
        let report = serve(vec![mk(0)], reqs(5), 4, 8, u64::MAX);
        assert_eq!(report.responses.len(), 5);
        assert!(report.padded_rows >= 3, "second batch padded");
    }
}
