//! Serving coordinator (DESIGN.md S12): the host-side leader that routes
//! inference requests across the computing-enabled storage pool, batches
//! them to the AOT engine's fixed batch width, and accounts per-node KV
//! residency against flash capacity.
//!
//! Offline-build note (DESIGN.md §4): tokio is unavailable in this
//! environment, so the server uses std threads + channels; the design
//! (leader dispatch queue, per-node workers, response collector) is the
//! same shape a tokio runtime would host.

pub mod batcher;
pub mod kv_manager;
pub mod router;
pub mod server;

pub use batcher::{Batch, Batcher};
pub use kv_manager::KvManager;
pub use router::Router;
pub use server::{serve, BatchExecutor, ServeReport};

/// One inference request entering the system.
#[derive(Clone, Debug, PartialEq)]
pub struct InferenceRequest {
    pub id: u64,
    /// Prompt token ids (will be clipped/padded to the engine prompt_len).
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// A finished request.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Which pool node served it.
    pub node: u32,
    /// Wallclock latency of the whole batch this request rode in.
    pub latency: std::time::Duration,
}
