//! Serving coordinator (DESIGN.md S12): the host-side leader that routes
//! inference requests across the computing-enabled storage pool, batches
//! them to the AOT engine's fixed batch width, and accounts per-node KV
//! residency against flash capacity.
//!
//! Since ISSUE 3 the whole loop runs on the pool's *simulated* clock
//! ([`crate::sim::PoolSim`]): request arrivals, batch windows, dispatch
//! and response transfers (over the shared [`crate::fabric::Fabric`]),
//! per-node compute occupancy, and KV migrations are all events on one
//! deterministic queue — no wallclock threads, no `Instant`, no sleeps.
//! Two runs with the same seed produce byte-identical `serve.*` and
//! `fabric.*` counters, and serving traffic contends with docker pulls,
//! layer prefetch, and LLM collectives on the same wires.
//!
//! Since ISSUE 4 the arrival process can be a Table 2 trace replay
//! (`workloads::arrivals`: per-request prompt/output shapes at the
//! row's measured I/O rate) and KV is sized per request from the model
//! config ([`ServeParams::kv_need`]) instead of per batch.

pub mod batcher;
pub mod kv_manager;
pub mod router;
pub mod server;

pub use batcher::{Batch, Batcher};
pub use kv_manager::KvManager;
pub use router::Router;
pub use server::{
    serve, serve_with_hook, BatchExecutor, EchoExecutor, QueuePressure, ServeHook, ServeParams,
    ServeReport, WirePolicy, BATCH_CONTROL_BYTES,
};

use crate::util::SimTime;

/// One inference request entering the system.
#[derive(Clone, Debug, PartialEq)]
pub struct InferenceRequest {
    pub id: u64,
    /// Prompt token ids (will be clipped/padded to the engine prompt_len).
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// A finished request.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Which pool node served it.
    pub node: u32,
    /// Simulated end-to-end latency: arrival event to the last response
    /// byte landing at the host over the fabric.
    pub latency: SimTime,
}
