//! KV-cache residency accounting: each pool node keeps its KV cache on
//! its own flash ("access flash memory as local memory"); the manager
//! tracks per-node residency against capacity and refuses placements
//! that would not fit — the capacity story behind Figure 12.
//!
//! Moving resident KV between nodes (rebalancing, draining a node) is
//! real node-to-node traffic: [`KvManager::migrate`] carries it as a
//! pipelined device-to-device stream ([`Fabric::stream`], riding the
//! [`KV_STREAM_CLASS`] WFQ class) so migrations contend with layer
//! fetches and collective steps on the same links without ever holding
//! a wire for the whole move — and without touching the host uplink.
//! [`KvManager::migrate_monolithic`] keeps the pre-stream shape (one
//! synchronous foreground transfer) as the A/B baseline the benches and
//! the host-uplink regression test compare against.

use crate::fabric::{Endpoint, Fabric, Priority, TransferReceipt, DEFAULT_QUANTUM, KV_STREAM_CLASS};
use crate::pool::devices::FtlBank;
use crate::util::SimTime;

/// Per-node KV accounting (bytes).
pub struct KvManager {
    capacity: u64,
    used: Vec<u64>,
    pub admitted: u64,
    pub rejected: u64,
}

impl KvManager {
    pub fn new(nodes: usize, capacity_bytes: u64) -> Self {
        KvManager {
            capacity: capacity_bytes,
            used: vec![0; nodes],
            admitted: 0,
            rejected: 0,
        }
    }

    /// KV bytes for one batch slot of a model config.
    pub fn kv_bytes(n_layers: usize, n_heads: usize, max_seq: usize, head_dim: usize,
                    batch: usize, bytes_per_elem: usize) -> u64 {
        (n_layers * batch * n_heads * max_seq * head_dim * 2 * bytes_per_elem) as u64
    }

    /// KV bytes one token of context pins for a model geometry: a K and
    /// a V vector of `d_model` elements per layer.  A request's resident
    /// KV is this times its (clipped prompt + generation budget) tokens
    /// — the per-request sizing `coordinator::serve` reserves with,
    /// consistent with [`crate::llm::LlmConfig::kv_bytes`] at seq 1.
    pub fn kv_bytes_per_token(n_layers: u64, d_model: u64, bytes_per_elem: u64) -> u64 {
        n_layers * 2 * d_model * bytes_per_elem
    }

    /// Whether `node` has headroom for `bytes` more of resident KV.
    pub fn fits(&self, node: u32, bytes: u64) -> bool {
        self.used[node as usize]
            .checked_add(bytes)
            .is_some_and(|u| u <= self.capacity)
    }

    /// Whether `bytes` could fit on a completely empty node — the
    /// feasibility bound eviction policies check before sacrificing
    /// resident sessions for a reservation no amount of evicting can
    /// satisfy.
    pub fn fits_empty(&self, bytes: u64) -> bool {
        bytes <= self.capacity
    }

    /// Whether `node` would have headroom for `bytes` after releasing
    /// `release` of its current residency — the feasibility check an
    /// eviction policy runs *before* sacrificing sessions: if even
    /// releasing everything evictable on a node cannot admit the
    /// reservation, killing sessions there destroys state without
    /// unblocking anything.
    pub fn fits_after_release(&self, node: u32, release: u64, bytes: u64) -> bool {
        self.used[node as usize]
            .saturating_sub(release)
            .checked_add(bytes)
            .is_some_and(|u| u <= self.capacity)
    }

    /// Try to reserve `bytes` on `node`.
    pub fn reserve(&mut self, node: u32, bytes: u64) -> bool {
        let u = &mut self.used[node as usize];
        if *u + bytes > self.capacity {
            self.rejected += 1;
            return false;
        }
        *u += bytes;
        self.admitted += 1;
        true
    }

    pub fn release(&mut self, node: u32, bytes: u64) {
        let u = &mut self.used[node as usize];
        *u = u.saturating_sub(bytes);
    }

    /// Move `bytes` of resident KV from `from` to `to` as a pipelined
    /// device-to-device stream of [`DEFAULT_QUANTUM`] chunk quanta on
    /// the [`KV_STREAM_CLASS`] WFQ class.  Fails (returning `None`,
    /// with the rejection counted) if `from` doesn't hold that much or
    /// `to` lacks capacity; residency accounting moves with the bytes
    /// on success.  A same-node "move" is a free no-op (the destination
    /// never needs transient headroom for bytes it already holds).
    ///
    /// KV that lands on `to` re-programs its flash: the moved bytes are
    /// charged to the destination's FTL ledger (`ftls`) on its
    /// write-back lane, so rebalancing churn shows up as pool-level WAF
    /// and wear without touching the stream's wire timing.
    #[allow(clippy::too_many_arguments)]
    pub fn migrate(
        &mut self,
        fabric: &mut Fabric,
        ftls: &mut FtlBank,
        now: SimTime,
        from: u32,
        to: u32,
        bytes: u64,
    ) -> Option<TransferReceipt> {
        if !self.book_move(from, to, bytes)? {
            // same-node "move": nothing crosses the wire, nothing
            // reprograms flash — an explicit zero-byte receipt, not a
            // zero-priced fabric transfer (the fabric never hears about
            // it, so every fabric.* counter stays untouched)
            return Some(TransferReceipt::immediate(now));
        }
        ftls.write(to, now, bytes);
        let handle = fabric.stream(
            now,
            Endpoint::Node(from),
            Endpoint::Node(to),
            bytes,
            DEFAULT_QUANTUM,
            KV_STREAM_CLASS,
        );
        Some(fabric.settle_stream(&handle).summary())
    }

    /// The pre-stream migration shape: one synchronous foreground
    /// transfer holding the node-to-node path end-to-end.  Identical
    /// residency semantics to [`KvManager::migrate`]; kept as the
    /// baseline the d2d-stream bench and the host-uplink regression
    /// test run against.
    #[allow(clippy::too_many_arguments)]
    pub fn migrate_monolithic(
        &mut self,
        fabric: &mut Fabric,
        ftls: &mut FtlBank,
        now: SimTime,
        from: u32,
        to: u32,
        bytes: u64,
    ) -> Option<TransferReceipt> {
        if !self.book_move(from, to, bytes)? {
            // same free same-node no-op as the streamed path: the
            // fabric is never consulted
            return Some(TransferReceipt::immediate(now));
        }
        ftls.write(to, now, bytes);
        Some(fabric.transfer(
            now,
            Endpoint::Node(from),
            Endpoint::Node(to),
            bytes,
            Priority::Foreground,
        ))
    }

    /// Shared residency bookkeeping for a migration: `None` refuses the
    /// move (counted), `Some(false)` is the free same-node case, and
    /// `Some(true)` means the accounting moved and the bytes must cross
    /// the wire.
    fn book_move(&mut self, from: u32, to: u32, bytes: u64) -> Option<bool> {
        if self.used_of(from) < bytes {
            self.rejected += 1;
            return None;
        }
        if from == to {
            return Some(false);
        }
        if !self.reserve(to, bytes) {
            return None;
        }
        self.release(from, bytes);
        Some(true)
    }

    pub fn used_of(&self, node: u32) -> u64 {
        self.used[node as usize]
    }

    pub fn utilization(&self, node: u32) -> f64 {
        self.used[node as usize] as f64 / self.capacity as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_bytes_formula() {
        // 4 layers, 8 heads, 256 seq, 32 head_dim, batch 4, f32
        let b = KvManager::kv_bytes(4, 8, 256, 32, 4, 4);
        assert_eq!(b, 4 * 4 * 8 * 256 * 32 * 2 * 4);
    }

    #[test]
    fn kv_bytes_per_token_matches_model_geometry() {
        // lamda-137B at f16: 64 layers x 2 x 8192 x 2B
        let per_token = KvManager::kv_bytes_per_token(64, 8192, 2);
        assert_eq!(per_token, 64 * 2 * 8192 * 2);
        let llm = crate::llm::all_llms().remove(0);
        assert_eq!(
            per_token as f64,
            llm.kv_bytes(1, 1, 2.0),
            "per-token sizing agrees with the analytic LLM KV model"
        );
    }

    #[test]
    fn reserve_until_capacity() {
        let mut kv = KvManager::new(2, 1000);
        assert!(kv.fits(0, 600));
        assert!(kv.reserve(0, 600));
        assert!(!kv.fits(0, 600));
        assert!(!kv.reserve(0, 600), "over capacity");
        assert!(kv.reserve(1, 600), "other node unaffected");
        assert_eq!(kv.admitted, 2);
        assert_eq!(kv.rejected, 1);
        // unbounded capacity never overflows the headroom check
        let kv = KvManager::new(1, u64::MAX);
        assert!(kv.fits(0, u64::MAX));
        // feasibility bound: what an empty node could ever hold
        let kv = KvManager::new(1, 1000);
        assert!(kv.fits_empty(1000));
        assert!(!kv.fits_empty(1001));
    }

    #[test]
    fn release_frees_space() {
        let mut kv = KvManager::new(1, 1000);
        kv.reserve(0, 800);
        kv.release(0, 800);
        assert!(kv.reserve(0, 900));
        assert_eq!(kv.used_of(0), 900);
    }

    #[test]
    fn utilization_fraction() {
        let mut kv = KvManager::new(1, 1000);
        kv.reserve(0, 250);
        assert!((kv.utilization(0) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn migrate_moves_residency_over_the_fabric() {
        use crate::config::{EtherOnConfig, PoolConfig};

        let mut f = Fabric::new(
            &PoolConfig {
                nodes_per_array: 4,
                arrays: 1,
                ..Default::default()
            },
            &EtherOnConfig::default(),
        );
        let mut bank = FtlBank::default();
        let mut kv = KvManager::new(4, 1000);
        kv.reserve(0, 800);
        let r = kv.migrate(&mut f, &mut bank, SimTime::ZERO, 0, 1, 500).unwrap();
        assert!(r.finish > SimTime::ZERO, "migration pays wire time");
        assert_eq!(kv.used_of(0), 300);
        assert_eq!(kv.used_of(1), 500);
        // not resident: refused and counted
        assert!(kv.migrate(&mut f, &mut bank, SimTime::ZERO, 2, 3, 100).is_none());
        // destination over capacity: refused
        kv.reserve(3, 900);
        assert!(kv.migrate(&mut f, &mut bank, SimTime::ZERO, 1, 3, 400).is_none());
        assert_eq!(kv.used_of(1), 500, "failed migration leaves residency intact");
        assert_eq!(kv.rejected, 2);
        // a same-node move is a free no-op, not a capacity rejection
        let r = kv.migrate(&mut f, &mut bank, SimTime::ZERO, 0, 0, 300).unwrap();
        assert_eq!(r.latency(), SimTime::ZERO);
        assert_eq!(kv.used_of(0), 300);
        assert_eq!(kv.rejected, 2);
        // only the landed move charged flash: node 1's ledger saw the
        // bytes, the refused and same-node moves charged nothing
        assert_eq!(bank.wear_max_of(3), 0);
        assert!(bank.waf_milli_of(1) >= 1000);
    }

    #[test]
    fn same_node_migrate_never_touches_the_fabric() {
        use crate::config::{EtherOnConfig, PoolConfig};
        use crate::metrics::Counters;

        let mut f = Fabric::new(
            &PoolConfig {
                nodes_per_array: 4,
                arrays: 1,
                ..Default::default()
            },
            &EtherOnConfig::default(),
        );
        let mut bank = FtlBank::default();
        let mut kv = KvManager::new(4, 1000);
        kv.reserve(2, 600);
        let mut before = Counters::new();
        f.export_counters(&mut before);
        // both migration shapes: the same-node case is an explicit
        // zero-length receipt, not a from==to transfer priced at zero
        let r = kv.migrate(&mut f, &mut bank, SimTime::ms(1), 2, 2, 600).unwrap();
        assert_eq!(r.bytes, 0);
        assert_eq!(r.latency(), SimTime::ZERO);
        assert_eq!(r.finish, SimTime::ms(1));
        let m = kv
            .migrate_monolithic(&mut f, &mut bank, SimTime::ms(2), 2, 2, 600)
            .unwrap();
        assert_eq!(m.bytes, 0);
        assert_eq!(m.latency(), SimTime::ZERO);
        let mut after = Counters::new();
        f.export_counters(&mut after);
        assert_eq!(before, after, "same-node moves leave every fabric.* counter untouched");
        // residency untouched, nothing charged to flash
        assert_eq!(kv.used_of(2), 600);
        assert_eq!(kv.rejected, 0);
        assert_eq!(bank.wear_max_of(2), 0);
    }

    #[test]
    fn migration_streams_stay_off_the_host_uplink() {
        use crate::config::{EtherOnConfig, PoolConfig};
        use crate::metrics::{names, Counters};

        let mut f = Fabric::new(
            &PoolConfig {
                nodes_per_array: 4,
                arrays: 2,
                ..Default::default()
            },
            &EtherOnConfig::default(),
        );
        let bytes = 3 * DEFAULT_QUANTUM + 1; // forces a multi-quantum stream
        let mut bank = FtlBank::default();
        let mut kv = KvManager::new(8, u64::MAX);
        kv.reserve(0, bytes);
        // cross-array: Array(0) + Tray + Array(1), never HostUplink
        let r = kv.migrate(&mut f, &mut bank, SimTime::ZERO, 0, 5, bytes).unwrap();
        assert_eq!(r.bytes, bytes);
        let mut c = Counters::new();
        f.export_counters(&mut c);
        assert_eq!(c.get(names::FABRIC_BYTES_HOST_UPLINK), 0);
        assert_eq!(c.get(names::FABRIC_BYTES_P2P), bytes);
        assert_eq!(c.get(names::FABRIC_STREAM_QUANTA), 4);
        assert!(c.get(names::FABRIC_STREAM_OVERLAP_NS) > 0);

        // the monolithic baseline books residency identically and puts
        // the same bytes on the same links, just as one grant
        let mut f2 = Fabric::new(
            &PoolConfig {
                nodes_per_array: 4,
                arrays: 2,
                ..Default::default()
            },
            &EtherOnConfig::default(),
        );
        let mut bank2 = FtlBank::default();
        let mut kv2 = KvManager::new(8, u64::MAX);
        kv2.reserve(0, bytes);
        let m = kv2
            .migrate_monolithic(&mut f2, &mut bank2, SimTime::ZERO, 0, 5, bytes)
            .unwrap();
        assert_eq!(m.bytes, bytes);
        assert_eq!(kv2.used_of(5), kv.used_of(5));
        let mut c2 = Counters::new();
        f2.export_counters(&mut c2);
        assert_eq!(c2.get(names::FABRIC_BYTES_HOST_UPLINK), 0);
        assert_eq!(c2.get(names::FABRIC_BYTES_ARRAY), c.get(names::FABRIC_BYTES_ARRAY));
        assert_eq!(c2.get(names::FABRIC_BYTES_P2P), 0, "monolithic path is not a stream");
        // the stream tracks the monolithic wire: no earlier (modulo
        // per-quantum truncation), within per-quantum hop tails
        assert!(r.finish + SimTime::ns(3 * 4) >= m.finish);
        assert!(r.finish <= m.finish + SimTime::ns(3 * 300 * 4));
    }
}
