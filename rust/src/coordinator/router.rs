//! Request router: spreads batches across pool nodes, least-outstanding
//! first (the vllm-router-style policy, simplified to the pool's
//! homogeneous nodes).

/// Router over `n` nodes tracking outstanding batches per node.
pub struct Router {
    outstanding: Vec<u64>,
    dispatched: Vec<u64>,
    /// Rotating cursor so ties round-robin instead of piling on node 0.
    cursor: usize,
}

impl Router {
    pub fn new(nodes: usize) -> Self {
        assert!(nodes > 0);
        Router {
            outstanding: vec![0; nodes],
            dispatched: vec![0; nodes],
            cursor: 0,
        }
    }

    pub fn nodes(&self) -> usize {
        self.outstanding.len()
    }

    /// Pick the node with the fewest outstanding batches; ties resolve
    /// round-robin starting from the rotating cursor.
    pub fn pick(&mut self) -> u32 {
        let n = self.outstanding.len();
        let min = *self.outstanding.iter().min().unwrap();
        let mut idx = self.cursor % n;
        for off in 0..n {
            let cand = (self.cursor + off) % n;
            if self.outstanding[cand] == min {
                idx = cand;
                break;
            }
        }
        self.cursor = (idx + 1) % n;
        self.outstanding[idx] += 1;
        self.dispatched[idx] += 1;
        idx as u32
    }

    /// A node finished a batch.
    pub fn complete(&mut self, node: u32) {
        let o = &mut self.outstanding[node as usize];
        *o = o.saturating_sub(1);
    }

    pub fn outstanding_of(&self, node: u32) -> u64 {
        self.outstanding[node as usize]
    }

    pub fn dispatched_of(&self, node: u32) -> u64 {
        self.dispatched[node as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robins_when_balanced() {
        let mut r = Router::new(3);
        assert_eq!(r.pick(), 0);
        assert_eq!(r.pick(), 1);
        assert_eq!(r.pick(), 2);
        assert_eq!(r.pick(), 0);
    }

    #[test]
    fn prefers_idle_node() {
        let mut r = Router::new(2);
        r.pick(); // node 0 busy
        r.pick(); // node 1 busy
        r.complete(1);
        assert_eq!(r.pick(), 1, "node 1 went idle first");
    }

    #[test]
    fn dispatch_counts_balanced_over_many_batches() {
        let mut r = Router::new(4);
        for _ in 0..400 {
            let n = r.pick();
            r.complete(n);
        }
        for n in 0..4 {
            assert_eq!(r.dispatched_of(n), 100);
        }
    }

    #[test]
    fn complete_is_saturating() {
        let mut r = Router::new(1);
        r.complete(0); // no underflow
        assert_eq!(r.outstanding_of(0), 0);
    }
}
