//! Request router: spreads batches across pool nodes, least-outstanding
//! first (the vllm-router-style policy, simplified to the pool's
//! homogeneous nodes).
//!
//! Dispatch is not free: the leader's prompt bytes cross the host
//! uplink and the target node's array backplane, contending with every
//! other transfer in flight.  [`Router::dispatch`] and
//! [`Router::complete_costed`] charge that traffic to the shared
//! [`Fabric`].
//!
//! Response accounting distinguishes *control* from *payload*.  An
//! audit of the serve response path found every completed batch charged
//! end-to-end over `HostUplink` even when the bulky part of the result
//! — the session's KV, which stays resident in the pool — never had a
//! reason to leave it: in-pool payloads were double-riding the uplink
//! on top of their real device-to-device move.
//! [`Router::complete_split`] fixes the split: only the host-bound
//! control bytes (token ids, batch header) cross `HostUplink`, while an
//! in-pool payload streams device-to-device over `Array` (+ `Tray`).
//! [`Router::complete_costed`] keeps the old conflated shape for
//! callers whose response really is all host-bound, and as the A/B
//! baseline for the host-uplink regression tests.

use crate::fabric::{Endpoint, Fabric, Priority, TransferReceipt, DEFAULT_QUANTUM, KV_STREAM_CLASS};
use crate::util::SimTime;

/// Router over `n` nodes tracking outstanding batches per node.
pub struct Router {
    outstanding: Vec<u64>,
    dispatched: Vec<u64>,
    /// Prompt + response bytes this router charged to the fabric, per
    /// node — the per-node wire-traffic split the serve report exposes.
    wire_bytes: Vec<u64>,
    /// Rotating cursor so ties round-robin instead of piling on node 0.
    cursor: usize,
}

impl Router {
    pub fn new(nodes: usize) -> Self {
        assert!(nodes > 0);
        Router {
            outstanding: vec![0; nodes],
            dispatched: vec![0; nodes],
            wire_bytes: vec![0; nodes],
            cursor: 0,
        }
    }

    pub fn nodes(&self) -> usize {
        self.outstanding.len()
    }

    /// Pick the node with the fewest outstanding batches; ties resolve
    /// round-robin starting from the rotating cursor.
    pub fn pick(&mut self) -> u32 {
        let n = self.outstanding.len();
        let min = *self.outstanding.iter().min().unwrap();
        let mut idx = self.cursor % n;
        for off in 0..n {
            let cand = (self.cursor + off) % n;
            if self.outstanding[cand] == min {
                idx = cand;
                break;
            }
        }
        self.cursor = (idx + 1) % n;
        self.outstanding[idx] += 1;
        self.dispatched[idx] += 1;
        idx as u32
    }

    /// Pick a node and charge the batch's prompt bytes host -> node over
    /// the shared fabric (dispatch is foreground traffic).  Returns the
    /// chosen node and the fabric's receipt — `receipt.finish` is when
    /// the node can start computing.
    pub fn dispatch(
        &mut self,
        fabric: &mut Fabric,
        now: SimTime,
        prompt_bytes: u64,
    ) -> (u32, TransferReceipt) {
        let node = self.pick();
        self.wire_bytes[node as usize] += prompt_bytes;
        let receipt = fabric.transfer(
            now,
            Endpoint::Host,
            Endpoint::Node(node),
            prompt_bytes,
            Priority::Foreground,
        );
        (node, receipt)
    }

    /// Assign a batch to an externally chosen node (capacity-aware
    /// callers like the serve loop filter candidates by KV headroom
    /// first, then account the choice here).
    pub fn assign(&mut self, node: u32) {
        self.outstanding[node as usize] += 1;
        self.dispatched[node as usize] += 1;
    }

    /// Like [`Router::dispatch`], but for an externally chosen node:
    /// account the assignment and charge the batch's prompt bytes
    /// host -> node over the shared fabric.
    pub fn dispatch_to(
        &mut self,
        fabric: &mut Fabric,
        now: SimTime,
        node: u32,
        prompt_bytes: u64,
    ) -> TransferReceipt {
        self.assign(node);
        self.wire_bytes[node as usize] += prompt_bytes;
        fabric.transfer(
            now,
            Endpoint::Host,
            Endpoint::Node(node),
            prompt_bytes,
            Priority::Foreground,
        )
    }

    /// A node finished a batch.
    pub fn complete(&mut self, node: u32) {
        let o = &mut self.outstanding[node as usize];
        *o = o.saturating_sub(1);
    }

    /// A node finished a batch: release its slot and charge the response
    /// bytes node -> host over the shared fabric.
    ///
    /// This conflates control and payload — everything crosses
    /// `HostUplink`.  Use [`Router::complete_split`] when part of the
    /// response (session KV, handoff state) stays in the pool.
    pub fn complete_costed(
        &mut self,
        fabric: &mut Fabric,
        now: SimTime,
        node: u32,
        response_bytes: u64,
    ) -> TransferReceipt {
        self.complete(node);
        self.wire_bytes[node as usize] += response_bytes;
        fabric.transfer(
            now,
            Endpoint::Node(node),
            Endpoint::Host,
            response_bytes,
            Priority::Foreground,
        )
    }

    /// A node finished a batch whose response splits into host-bound
    /// *control* bytes (token ids, batch header — crosses `HostUplink`)
    /// and an in-pool *payload* (session KV / handoff state).  The
    /// payload streams device-to-device to `payload_to` over
    /// `Array` (+ `Tray`) quanta — `None` (or the node itself) means it
    /// stays resident where it was computed, costing no wire at all.
    /// Either way the payload never touches the host uplink.
    ///
    /// Returns the control receipt: `finish` is when the host saw the
    /// batch complete.
    pub fn complete_split(
        &mut self,
        fabric: &mut Fabric,
        now: SimTime,
        node: u32,
        control_bytes: u64,
        payload_bytes: u64,
        payload_to: Option<u32>,
    ) -> TransferReceipt {
        self.complete(node);
        self.wire_bytes[node as usize] += control_bytes + payload_bytes;
        let control = fabric.transfer(
            now,
            Endpoint::Node(node),
            Endpoint::Host,
            control_bytes,
            Priority::Foreground,
        );
        if payload_bytes > 0 {
            if let Some(peer) = payload_to {
                let h = fabric.stream(
                    now,
                    Endpoint::Node(node),
                    Endpoint::Node(peer),
                    payload_bytes,
                    DEFAULT_QUANTUM,
                    KV_STREAM_CLASS,
                );
                fabric.settle_stream(&h);
            }
        }
        control
    }

    pub fn outstanding_of(&self, node: u32) -> u64 {
        self.outstanding[node as usize]
    }

    pub fn dispatched_of(&self, node: u32) -> u64 {
        self.dispatched[node as usize]
    }

    /// Total dispatch + response bytes charged for `node`.
    pub fn wire_bytes_of(&self, node: u32) -> u64 {
        self.wire_bytes[node as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robins_when_balanced() {
        let mut r = Router::new(3);
        assert_eq!(r.pick(), 0);
        assert_eq!(r.pick(), 1);
        assert_eq!(r.pick(), 2);
        assert_eq!(r.pick(), 0);
    }

    #[test]
    fn prefers_idle_node() {
        let mut r = Router::new(2);
        r.pick(); // node 0 busy
        r.pick(); // node 1 busy
        r.complete(1);
        assert_eq!(r.pick(), 1, "node 1 went idle first");
    }

    #[test]
    fn dispatch_counts_balanced_over_many_batches() {
        let mut r = Router::new(4);
        for _ in 0..400 {
            let n = r.pick();
            r.complete(n);
        }
        for n in 0..4 {
            assert_eq!(r.dispatched_of(n), 100);
        }
    }

    #[test]
    fn assign_and_dispatch_to_account_like_pick() {
        use crate::config::{EtherOnConfig, PoolConfig};

        let mut r = Router::new(3);
        r.assign(2);
        assert_eq!(r.outstanding_of(2), 1);
        assert_eq!(r.dispatched_of(2), 1);
        let mut f = Fabric::new(
            &PoolConfig {
                nodes_per_array: 4,
                arrays: 1,
                ..Default::default()
            },
            &EtherOnConfig::default(),
        );
        let rc = r.dispatch_to(&mut f, SimTime::ZERO, 1, 1 << 20);
        assert!(rc.finish > SimTime::ZERO, "dispatch pays the uplink");
        assert_eq!(r.outstanding_of(1), 1);
        assert_eq!(r.wire_bytes_of(1), 1 << 20);
        r.complete_costed(&mut f, rc.finish, 1, 1 << 10);
        assert_eq!(r.wire_bytes_of(1), (1 << 20) + (1 << 10), "responses counted too");
        assert_eq!(r.wire_bytes_of(0), 0);
    }

    #[test]
    fn complete_is_saturating() {
        let mut r = Router::new(1);
        r.complete(0); // no underflow
        assert_eq!(r.outstanding_of(0), 0);
    }

    #[test]
    fn dispatch_charges_the_host_uplink() {
        use crate::config::{EtherOnConfig, PoolConfig};
        use crate::metrics::{names, Counters};

        let mut f = Fabric::new(
            &PoolConfig {
                nodes_per_array: 4,
                arrays: 1,
                ..Default::default()
            },
            &EtherOnConfig::default(),
        );
        let mut r = Router::new(4);
        let (n0, rc0) = r.dispatch(&mut f, SimTime::ZERO, 1 << 20);
        assert_eq!(n0, 0);
        assert!(rc0.finish > SimTime::ZERO);
        // a second dispatch at the same instant queues behind the first
        // on the shared host uplink
        let (n1, rc1) = r.dispatch(&mut f, SimTime::ZERO, 1 << 20);
        assert_eq!(n1, 1);
        assert!(rc1.queue_wait() > SimTime::ZERO, "uplink is shared");
        r.complete_costed(&mut f, rc0.finish, n0, 1 << 10);
        assert_eq!(r.outstanding_of(n0), 0);
        let mut c = Counters::new();
        f.export_counters(&mut c);
        assert_eq!(c.get(names::FABRIC_BYTES_HOST_UPLINK), (2 << 20) + (1 << 10));
    }

    #[test]
    fn in_pool_payloads_never_cross_the_host_uplink() {
        // regression for the response-path audit: complete_costed used
        // to be the only completion primitive, so a response whose bulk
        // stays in the pool (session KV handed to a peer) was charged
        // end-to-end over HostUplink on top of its real device-to-device
        // move — double-riding the uplink
        use crate::config::{EtherOnConfig, PoolConfig};
        use crate::metrics::{names, Counters};

        let pool = PoolConfig {
            nodes_per_array: 4,
            arrays: 1,
            ..Default::default()
        };
        let (control, payload) = (1 << 10, 8 << 20);

        // old shape: everything hairpins through the host
        let mut f_old = Fabric::new(&pool, &EtherOnConfig::default());
        let mut r_old = Router::new(4);
        r_old.assign(0);
        r_old.complete_costed(&mut f_old, SimTime::ZERO, 0, control + payload);
        let mut c_old = Counters::new();
        f_old.export_counters(&mut c_old);
        assert_eq!(c_old.get(names::FABRIC_BYTES_HOST_UPLINK), control + payload);

        // split shape: control to the host, payload streamed to a peer
        let mut f = Fabric::new(&pool, &EtherOnConfig::default());
        let mut r = Router::new(4);
        r.assign(0);
        let rc = r.complete_split(&mut f, SimTime::ZERO, 0, control, payload, Some(2));
        assert_eq!(r.outstanding_of(0), 0);
        assert!(rc.finish > SimTime::ZERO, "control still pays the uplink");
        let mut c = Counters::new();
        f.export_counters(&mut c);
        assert_eq!(
            c.get(names::FABRIC_BYTES_HOST_UPLINK),
            control,
            "payload bytes must stay off the uplink"
        );
        assert_eq!(c.get(names::FABRIC_BYTES_P2P), payload);
        assert!(c.get(names::FABRIC_STREAM_QUANTA) > 1, "payload moved as stream quanta");
        // per-node wire accounting still sees the whole response
        assert_eq!(r.wire_bytes_of(0), control + payload);

        // payload staying resident costs no wire at all
        let mut f2 = Fabric::new(&pool, &EtherOnConfig::default());
        let mut r2 = Router::new(4);
        r2.assign(1);
        r2.complete_split(&mut f2, SimTime::ZERO, 1, control, payload, None);
        let mut c2 = Counters::new();
        f2.export_counters(&mut c2);
        assert_eq!(c2.get(names::FABRIC_BYTES_HOST_UPLINK), control);
        assert_eq!(c2.get(names::FABRIC_BYTES_ARRAY), control, "only the control's array hop");
        assert_eq!(c2.get(names::FABRIC_BYTES_P2P), 0);
    }
}
