//! Image blobs and manifests.
//!
//! Blobs are the binary objects Docker moves around; the manifest stores
//! metadata for the application launch (entry script + layer digests).
//! Manifests serialize as JSON, matching the files mini-docker keeps
//! under `/images/manifest/`.

use crate::json::{parse, Json};
use crate::util::{fnv1a, Rng};

/// A content-addressed binary object.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Blob {
    pub digest: u64,
    pub bytes: Vec<u8>,
}

impl Blob {
    /// Build a blob from raw content; the digest is FNV-1a over the bytes.
    pub fn from_bytes(bytes: Vec<u8>) -> Blob {
        Blob {
            digest: fnv1a(&bytes),
            bytes,
        }
    }

    /// Deterministic synthetic layer of `size` bytes (seeded by content id).
    pub fn synthetic(seed: u64, size: usize) -> Blob {
        let mut rng = Rng::new(seed);
        let mut bytes = Vec::with_capacity(size);
        while bytes.len() < size {
            bytes.extend_from_slice(&rng.next_u64().to_le_bytes());
        }
        bytes.truncate(size);
        Blob::from_bytes(bytes)
    }

    pub fn verify(&self) -> bool {
        fnv1a(&self.bytes) == self.digest
    }

    /// Digests of this blob's layerstore chunks at the given chunk size —
    /// what the content-addressed store will index it as.
    pub fn chunk_digests(&self, chunk_bytes: usize) -> Vec<u64> {
        assert!(chunk_bytes > 0);
        self.bytes.chunks(chunk_bytes).map(fnv1a).collect()
    }
}

/// Image manifest: "details about the target application, such as its
/// entry script and required image layers for rootfs".
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ImageManifest {
    pub name: String,
    pub tag: String,
    pub entry: String,
    /// Layer digests, bottom-most first.
    pub layers: Vec<u64>,
}

impl ImageManifest {
    /// Canonical `name:tag` reference, as the registry keys it.
    pub fn reference(&self) -> String {
        format!("{}:{}", self.name, self.tag)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("tag", Json::str(self.tag.clone())),
            ("entry", Json::str(self.entry.clone())),
            (
                "layers",
                Json::Arr(
                    self.layers
                        .iter()
                        .map(|d| Json::str(format!("{:016x}", d)))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json_str(text: &str) -> Option<ImageManifest> {
        let v = parse(text).ok()?;
        let layers = v
            .get("layers")?
            .as_arr()?
            .iter()
            .map(|l| u64::from_str_radix(l.as_str()?, 16).ok())
            .collect::<Option<Vec<u64>>>()?;
        Some(ImageManifest {
            name: v.get("name")?.as_str()?.to_string(),
            tag: v.get("tag")?.as_str()?.to_string(),
            entry: v.get("entry")?.as_str()?.to_string(),
            layers,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blob_digest_verifies() {
        let b = Blob::from_bytes(b"layer-content".to_vec());
        assert!(b.verify());
        let mut tampered = b.clone();
        tampered.bytes[0] ^= 1;
        assert!(!tampered.verify());
    }

    #[test]
    fn synthetic_blobs_deterministic_and_sized() {
        let a = Blob::synthetic(5, 10_000);
        let b = Blob::synthetic(5, 10_000);
        let c = Blob::synthetic(6, 10_000);
        assert_eq!(a, b);
        assert_ne!(a.digest, c.digest);
        assert_eq!(a.bytes.len(), 10_000);
        assert!(a.verify());
    }

    #[test]
    fn chunk_digests_partition_content() {
        let b = Blob::synthetic(9, 10_000);
        let digests = b.chunk_digests(4096);
        assert_eq!(digests.len(), 3);
        assert_eq!(digests[0], fnv1a(&b.bytes[..4096]));
        assert_eq!(digests[2], fnv1a(&b.bytes[8192..]));
    }

    #[test]
    fn manifest_reference_is_name_tag() {
        let m = ImageManifest {
            name: "nginx".into(),
            tag: "v3".into(),
            entry: "e".into(),
            layers: vec![],
        };
        assert_eq!(m.reference(), "nginx:v3");
    }

    #[test]
    fn manifest_json_round_trip() {
        let m = ImageManifest {
            name: "nginx".into(),
            tag: "latest".into(),
            entry: "nginx -g 'daemon off;'".into(),
            layers: vec![0xDEADBEEF, 42],
        };
        let text = m.to_json().dump();
        let back = ImageManifest::from_json_str(&text).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(ImageManifest::from_json_str("{}").is_none());
        assert!(ImageManifest::from_json_str("not json").is_none());
        assert!(ImageManifest::from_json_str(
            r#"{"name":"x","tag":"y","entry":"z","layers":["nothex!"]}"#
        )
        .is_none());
    }
}
