//! Host-side image registry — the "user-defined location" docker pull
//! retrieves blobs from (paper Figure 2b step 1).

use std::collections::BTreeMap;

use super::image::{Blob, ImageManifest};

/// An in-memory registry of published images, keyed by `name:tag`.
/// (Keying by name alone silently overwrote older tags and made `fetch`
/// ignore the tag entirely — `publish("app", "v2", ...)` clobbered v1.)
/// Sorted map, so listing order can never leak hash-iteration
/// nondeterminism into anything derived from it.
#[derive(Default)]
pub struct Registry {
    images: BTreeMap<String, (ImageManifest, Vec<Blob>)>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Canonical reference: an untagged name means `:latest`, as docker
    /// resolves it.
    fn key(reference: &str) -> String {
        if reference.contains(':') {
            reference.to_string()
        } else {
            format!("{reference}:latest")
        }
    }

    /// Publish an image with synthetic layers of the given sizes.
    pub fn publish(
        &mut self,
        name: &str,
        tag: &str,
        entry: &str,
        layer_sizes: &[usize],
        seed: u64,
    ) {
        let blobs: Vec<Blob> = layer_sizes
            .iter()
            .enumerate()
            .map(|(i, &sz)| Blob::synthetic(seed.wrapping_add(i as u64), sz))
            .collect();
        let manifest = ImageManifest {
            name: name.to_string(),
            tag: tag.to_string(),
            entry: entry.to_string(),
            layers: blobs.iter().map(|b| b.digest).collect(),
        };
        self.images
            .insert(format!("{name}:{tag}"), (manifest, blobs));
    }

    /// Fetch manifest + blobs for a `name[:tag]` reference (a `docker
    /// pull` round trip); an untagged reference resolves to `:latest`.
    pub fn fetch(&self, reference: &str) -> Option<(&ImageManifest, &[Blob])> {
        self.images
            .get(&Self::key(reference))
            .map(|(m, b)| (m, b.as_slice()))
    }

    /// All published `name:tag` references, in sorted order.
    pub fn list(&self) -> Vec<&str> {
        self.images.keys().map(String::as_str).collect()
    }

    /// Publish the paper's six benchmark images with plausible layer sizes.
    pub fn with_benchmark_images() -> Registry {
        let mut r = Registry::new();
        r.publish("embed", "latest", "dlrm-embed --tables=/data/emb", &[256 << 10, 64 << 10], 11);
        r.publish("mariadb", "latest", "mariadbd --datadir=/data", &[512 << 10, 128 << 10, 64 << 10], 12);
        r.publish("rocksdb", "latest", "rocksdb-bench --db=/data/kv", &[256 << 10, 32 << 10], 13);
        r.publish("pattern", "latest", "grep -rc needle /data/docs", &[128 << 10], 14);
        r.publish("nginx", "latest", "nginx -g 'daemon off;'", &[384 << 10, 96 << 10], 15);
        r.publish("vsftpd", "latest", "vsftpd /etc/vsftpd.conf", &[192 << 10], 16);
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_then_fetch() {
        let mut r = Registry::new();
        r.publish("app", "v1", "/bin/app", &[1000, 2000], 3);
        let (m, blobs) = r.fetch("app:v1").unwrap();
        assert_eq!(m.name, "app");
        assert_eq!(m.layers.len(), 2);
        assert_eq!(blobs.len(), 2);
        assert_eq!(blobs[0].bytes.len(), 1000);
        assert!(blobs.iter().all(|b| b.verify()));
        // manifest digests match blob digests
        assert_eq!(m.layers, blobs.iter().map(|b| b.digest).collect::<Vec<_>>());
    }

    #[test]
    fn fetch_missing_is_none() {
        assert!(Registry::new().fetch("ghost").is_none());
    }

    #[test]
    fn tags_do_not_clobber_each_other() {
        // regression: keying by name alone meant publishing v2 silently
        // overwrote v1 and fetch ignored the tag
        let mut r = Registry::new();
        r.publish("app", "v1", "/bin/app --v1", &[1000], 3);
        r.publish("app", "v2", "/bin/app --v2", &[2000, 500], 4);
        let (m1, b1) = r.fetch("app:v1").unwrap();
        let (m2, b2) = r.fetch("app:v2").unwrap();
        assert_eq!(m1.tag, "v1");
        assert_eq!(m1.entry, "/bin/app --v1");
        assert_eq!(b1.len(), 1);
        assert_eq!(m2.tag, "v2");
        assert_eq!(b2.len(), 2);
        assert_ne!(m1.layers, m2.layers);
    }

    #[test]
    fn untagged_reference_resolves_to_latest() {
        let mut r = Registry::new();
        r.publish("app", "v1", "/bin/app --v1", &[1000], 3);
        r.publish("app", "latest", "/bin/app", &[4000], 5);
        let (m, _) = r.fetch("app").unwrap();
        assert_eq!(m.tag, "latest");
        // a name with no :latest published does not resolve untagged
        r.publish("tool", "v9", "/bin/tool", &[100], 6);
        assert!(r.fetch("tool").is_none());
        assert!(r.fetch("tool:v9").is_some());
    }

    #[test]
    fn listing_order_is_stable_and_sorted() {
        // regression (ISSUE 7 satellite): the registry used to iterate a
        // HashMap, so two runs could list images in different orders —
        // any consumer deriving state from the listing would diverge
        let mut r = Registry::new();
        r.publish("zeta", "v1", "/bin/z", &[100], 1);
        r.publish("alpha", "v2", "/bin/a", &[100], 2);
        r.publish("alpha", "v1", "/bin/a", &[100], 3);
        r.publish("mid", "latest", "/bin/m", &[100], 4);
        assert_eq!(r.list(), vec!["alpha:v1", "alpha:v2", "mid:latest", "zeta:v1"]);
        let bench = Registry::with_benchmark_images();
        assert_eq!(
            bench.list(),
            vec![
                "embed:latest",
                "mariadb:latest",
                "nginx:latest",
                "pattern:latest",
                "rocksdb:latest",
                "vsftpd:latest"
            ]
        );
    }

    #[test]
    fn benchmark_images_cover_table2_programs() {
        let r = Registry::with_benchmark_images();
        for name in ["embed", "mariadb", "rocksdb", "pattern", "nginx", "vsftpd"] {
            assert!(r.fetch(name).is_some(), "{name}");
        }
    }
}
