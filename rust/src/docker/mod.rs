//! mini-docker (DESIGN.md S6, paper "Firmware-level container
//! environment"): the streamlined Docker implementation inside Virtual-FW
//! supporting 11 of Docker's 106 commands (Table 1b), image blobs +
//! manifests stored in λFS under `/images`, and container state +
//! logs under `/containers/<id>/`.

pub mod container;
pub mod image;
pub mod registry;

use std::collections::HashMap;

use crate::fabric::{Endpoint, Priority};
use crate::firmware::{Syscall, VirtualFw};
use crate::lambdafs::{LambdaFs, LockSide};
use crate::layerstore::{CowStore, LayerId, LayerStore, PoolLayerCache};
use crate::pool::devices::WireCtx;
use crate::pool::topology::NodeId;
use crate::ssd::SsdDevice;
use crate::util::{fnv1a, SimTime};

pub use container::{Container, ContainerState};
pub use image::{Blob, ImageManifest};
pub use registry::Registry;

/// The 11 supported commands (Table 1b).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DockerCmd {
    Pull(String),
    Rmi(String),
    Create(String),
    Run(String),
    Start(String),
    Stop(String),
    Restart(String),
    Kill(String),
    Rm(String),
    Logs(String),
    Ps,
}

impl DockerCmd {
    /// Parse an HTTP REST request line the way dockerd's API would
    /// (docker-cli speaks HTTP to mini-docker over Ether-oN).
    pub fn from_http(request_line: &str) -> Option<DockerCmd> {
        let mut parts = request_line.split_whitespace();
        let method = parts.next()?;
        let path = parts.next()?;
        let segs: Vec<&str> = path.trim_matches('/').split('/').collect();
        match (method, segs.as_slice()) {
            ("POST", ["images", name, "pull"]) => Some(DockerCmd::Pull(name.to_string())),
            ("DELETE", ["images", name]) => Some(DockerCmd::Rmi(name.to_string())),
            ("POST", ["containers", "create", image]) => {
                Some(DockerCmd::Create(image.to_string()))
            }
            ("POST", ["containers", id, "start"]) => Some(DockerCmd::Start(id.to_string())),
            ("POST", ["containers", id, "stop"]) => Some(DockerCmd::Stop(id.to_string())),
            ("POST", ["containers", id, "restart"]) => Some(DockerCmd::Restart(id.to_string())),
            ("POST", ["containers", id, "kill"]) => Some(DockerCmd::Kill(id.to_string())),
            ("POST", ["containers", image, "run"]) => Some(DockerCmd::Run(image.to_string())),
            ("DELETE", ["containers", id]) => Some(DockerCmd::Rm(id.to_string())),
            ("GET", ["containers", id, "logs"]) => Some(DockerCmd::Logs(id.to_string())),
            ("GET", ["containers", "json"]) => Some(DockerCmd::Ps),
            _ => None,
        }
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum DockerError {
    NoSuchImage,
    NoSuchContainer,
    BadState(&'static str),
    Fs(crate::lambdafs::FsError),
    OutOfMemory,
}

impl From<crate::lambdafs::FsError> for DockerError {
    fn from(e: crate::lambdafs::FsError) -> Self {
        DockerError::Fs(e)
    }
}

/// Response to a command, with the simulated completion time.
#[derive(Debug)]
pub struct CmdResult {
    pub output: String,
    pub done: SimTime,
}

/// The firmware-level container engine.
pub struct MiniDocker {
    containers: Vec<Container>,
    next_id: u64,
    /// Default memory footprint charged per container (bytes).
    pub container_mem_bytes: u64,
    /// Copy-on-write writable layers for store-backed containers.
    pub cow: CowStore,
    /// container id -> its writable layer (store-backed containers only).
    cow_layers: HashMap<String, LayerId>,
}

impl Default for MiniDocker {
    fn default() -> Self {
        Self::new()
    }
}

impl MiniDocker {
    pub fn new() -> Self {
        MiniDocker {
            containers: Vec::new(),
            next_id: 1,
            container_mem_bytes: 64 << 20,
            cow: CowStore::new(),
            cow_layers: HashMap::new(),
        }
    }

    pub fn containers(&self) -> &[Container] {
        &self.containers
    }

    fn find(&mut self, id: &str) -> Result<&mut Container, DockerError> {
        self.containers
            .iter_mut()
            .find(|c| c.id == id)
            .ok_or(DockerError::NoSuchContainer)
    }

    /// Canonical manifest key for a pull reference: docker treats `app`
    /// and `app:latest` as the same image, so `:latest` is stripped and
    /// both resolve to one `/images/manifest/<key>` file.
    fn manifest_key(reference: &str) -> &str {
        reference.strip_suffix(":latest").unwrap_or(reference)
    }

    /// `docker pull`: fetch blobs + manifest from the registry and store
    /// them in λFS (`/images/blobs/<digest>`, `/images/manifest/<name>`).
    ///
    /// Every registry byte crosses the shared pool fabric
    /// (RegistryWan + HostUplink + the node's Array backplane) before
    /// the device-side Ether-oN frame costs are charged — so concurrent
    /// pulls contend on the WAN/uplink with each other and with serving
    /// traffic, and `fabric.bytes_wan` counts them.  The landed blob
    /// bytes are charged to the node's FTL ledger (`wire.ftls`) —
    /// whole-blob pulls re-program every byte, which is what the
    /// dedup'd [`Self::pull_via_store`] path avoids.
    #[allow(clippy::too_many_arguments)]
    pub fn pull(
        &mut self,
        fw: &mut VirtualFw,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        reg: &Registry,
        wire: &mut WireCtx,
        node: NodeId,
        image: &str,
    ) -> Result<CmdResult, DockerError> {
        let (manifest, blobs) = reg.fetch(image).ok_or(DockerError::NoSuchImage)?;
        let mut done = wire.now;
        let mut landed = 0u64;
        // each blob crosses the pool fabric, arrives as Ether-oN frames,
        // then lands in λFS
        for blob in blobs {
            let hop = wire.fabric.transfer(
                done,
                Endpoint::Registry,
                Endpoint::Node(node),
                blob.bytes.len() as u64,
                Priority::Foreground,
            );
            done = hop.finish;
            let frames = (blob.bytes.len() as u64).div_ceil(1448).max(1);
            done += SimTime::ns(frames * fw.costs.t_pkt_ethon_ns);
            let path = format!("/images/blobs/{:016x}", blob.digest);
            let r = fs.write_file(dev, done, &path, &blob.bytes, LockSide::Isp)?;
            done = r.done;
            landed += blob.bytes.len() as u64;
        }
        if landed > 0 {
            wire.ftls.write(node, wire.now, landed);
        }
        // keyed by the canonical reference, so tagged pulls resolve on create
        let mpath = format!("/images/manifest/{}", Self::manifest_key(image));
        let r = fs.write_file(dev, done, &mpath, manifest.to_json().dump().as_bytes(), LockSide::Isp)?;
        done = r.done;
        Ok(CmdResult {
            output: format!("Pulled {} ({} layers)", image, manifest.layers.len()),
            done,
        })
    }

    /// `docker pull` through the content-addressed layerstore: layers
    /// already resident (from any image, any prior pull) are metadata
    /// hits — no fabric traffic, no Ether-oN frames, no flash programs.
    /// Only missing layers cross the registry WAN on the shared
    /// [`crate::fabric::Fabric`], and they land dedup'd via the
    /// firmware's install handler.
    ///
    /// With `pool` set, the pull advertises chunk-level presence to the
    /// pool cache *as the chunks land*: each missing layer is described
    /// to the [`PoolLayerCache`], its bytes cross the wire chunk by
    /// chunk, and every landed chunk is registered immediately — so a
    /// peer can fetch the front of a layer from this node while its tail
    /// is still crossing the WAN (mid-pull peer serving).  Resident
    /// layers register as full holders.
    #[allow(clippy::too_many_arguments)]
    pub fn pull_via_store(
        &mut self,
        fw: &mut VirtualFw,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        reg: &Registry,
        store: &mut LayerStore,
        wire: &mut WireCtx,
        node: NodeId,
        image: &str,
        pool: Option<&mut PoolLayerCache>,
    ) -> Result<CmdResult, DockerError> {
        let (manifest, blobs) = reg.fetch(image).ok_or(DockerError::NoSuchImage)?;
        let mpath = format!("/images/manifest/{}", Self::manifest_key(image));
        // invariant: an image's layers hold exactly one blob ref while its
        // manifest file exists, so rmi_with_store can release them 1:1 —
        // a warm re-pull of an already-installed image refs nothing
        let repull = fs.walk(&mpath).is_ok();
        let mut pool = pool;
        let mut done = wire.now;
        let mut fetched_bytes = 0u64;
        let mut reused = 0usize;
        for blob in blobs {
            if store.has_blob(blob.digest) {
                reused += 1;
                if let Some(p) = pool.as_deref_mut() {
                    if let Some(recipe) = store.blob_chunk_recipe(blob.digest) {
                        if !recipe.is_empty() {
                            // a conflicting recipe (another node chunked
                            // differently) keeps the pool's first; the
                            // blob-level registration below is correct
                            // under either recipe
                            let _ = p.describe_chunks(blob.digest, &recipe);
                        }
                    }
                    p.register(node, blob.digest);
                }
                if repull {
                    continue;
                }
            } else {
                // only missing layers cross the fabric and arrive as
                // Ether-oN frames
                // chunk-granular wire only when the pool accepted this
                // node's chunking of the layer (a conflicting recipe from
                // a different chunk size keeps the pool's first and falls
                // back to a blob-granular transfer + registration)
                let mut chunked = false;
                if let Some(p) = pool.as_deref_mut() {
                    if !blob.bytes.is_empty() {
                        let recipe: Vec<(u64, u64)> = blob
                            .bytes
                            .chunks(store.chunk_bytes())
                            .map(|c| (fnv1a(c), c.len() as u64))
                            .collect();
                        if p.describe_chunks(blob.digest, &recipe) {
                            // register each chunk as it lands so peers
                            // can serve it mid-pull
                            for &(chunk, len) in &recipe {
                                let hop = wire.fabric.transfer(
                                    done,
                                    Endpoint::Registry,
                                    Endpoint::Node(node),
                                    len,
                                    Priority::Foreground,
                                );
                                done = hop.finish;
                                p.register_chunk(node, blob.digest, chunk);
                            }
                            chunked = true;
                        }
                    }
                }
                if !chunked {
                    let hop = wire.fabric.transfer(
                        done,
                        Endpoint::Registry,
                        Endpoint::Node(node),
                        blob.bytes.len() as u64,
                        Priority::Foreground,
                    );
                    done = hop.finish;
                    // empty or conflicting-recipe layers still land:
                    // keep presence consistent with the warm path
                    if let Some(p) = pool.as_deref_mut() {
                        p.register(node, blob.digest);
                    }
                }
                let frames = (blob.bytes.len() as u64).div_ceil(1448).max(1);
                done += SimTime::ns(frames * fw.costs.t_pkt_ethon_ns);
                fetched_bytes += blob.bytes.len() as u64;
            }
            // the install handler owns store-hit vs install accounting
            let r = fw.install.install_blob(fs, dev, store, done, &blob.bytes)?;
            done = r.done;
        }
        // only the wire-landed bytes program flash: reused (dedup'd)
        // layers cost this node zero programs — the whole point of the
        // store-backed pull, now visible in ftl.* instead of implicit
        if fetched_bytes > 0 {
            wire.ftls.write(node, wire.now, fetched_bytes);
        }
        let r = fs.write_file(dev, done, &mpath, manifest.to_json().dump().as_bytes(), LockSide::Isp)?;
        done = r.done;
        Ok(CmdResult {
            output: format!(
                "Pulled {} ({} layers, {} reused, {} bytes fetched)",
                image,
                manifest.layers.len(),
                reused,
                fetched_bytes
            ),
            done,
        })
    }

    /// `docker create` on the layerstore path: instead of copying every
    /// layer blob into the rootfs (the seed's overlay materialization),
    /// mount a copy-on-write writable layer that *shares* the image
    /// chunks — container boot moves metadata, not bytes.  The image
    /// must have been pulled via the store, and the container must be
    /// removed with [`Self::rm_with_store`] (plain `rm` cannot release
    /// the writable layer's chunk references).
    pub fn create_cow(
        &mut self,
        fw: &mut VirtualFw,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        store: &mut LayerStore,
        at: SimTime,
        image: &str,
    ) -> Result<CmdResult, DockerError> {
        let manifest = self.load_manifest(fs, dev, at, image)?;
        if manifest.layers.iter().any(|l| !store.has_blob(*l)) {
            return Err(DockerError::NoSuchImage);
        }
        let id = format!("c{:04}", self.next_id);
        self.next_id += 1;
        let root = format!("/containers/{id}/rootfs");
        fs.mkdir_p(&root, crate::nvme::namespace::PRIVATE_NS)
            .map_err(DockerError::Fs)?;
        let layer = self
            .cow
            .fork_from_blobs(store, &manifest.layers)
            .expect("layers checked present");
        // merged-view marker carries the entry script, as in create()
        let r = fs.write_file(
            dev,
            at,
            &format!("{root}/merged"),
            manifest.entry.as_bytes(),
            LockSide::Isp,
        )?;
        let done = r.done;
        fw.syscall(Syscall::Mkdir);
        self.cow_layers.insert(id.clone(), layer);
        self.containers
            .push(Container::new(&id, image, &manifest.entry, &root));
        Ok(CmdResult { output: id, done })
    }

    /// `docker run` on the layerstore path: create_cow + start.
    pub fn run_cow(
        &mut self,
        fw: &mut VirtualFw,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        store: &mut LayerStore,
        at: SimTime,
        image: &str,
    ) -> Result<CmdResult, DockerError> {
        let created = self.create_cow(fw, fs, dev, store, at, image)?;
        let id = created.output.clone();
        let started = self.start(fw, fs, dev, created.done, &id)?;
        Ok(CmdResult {
            output: id,
            done: started.done,
        })
    }

    /// The writable layer backing a store-backed container.
    pub fn cow_layer_of(&self, id: &str) -> Option<LayerId> {
        self.cow_layers.get(id).copied()
    }

    /// `docker rm` for store-backed containers: also releases the
    /// container's writable layer (reclaiming unshared chunks).
    pub fn rm_with_store(
        &mut self,
        fs: &mut LambdaFs,
        store: &mut LayerStore,
        at: SimTime,
        id: &str,
    ) -> Result<CmdResult, DockerError> {
        let result = self.rm(fs, at, id)?;
        if let Some(layer) = self.cow_layers.remove(id) {
            self.cow.drop_layer(store, fs, layer)?;
        }
        Ok(result)
    }

    /// `docker rmi`: remove manifest + blobs.
    pub fn rmi(
        &mut self,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        image: &str,
    ) -> Result<CmdResult, DockerError> {
        let manifest = self.load_manifest(fs, dev, at, image)?;
        for layer in &manifest.layers {
            let _ = fs.unlink(&format!("/images/blobs/{:016x}", layer));
        }
        fs.unlink(&format!("/images/manifest/{}", Self::manifest_key(image)))?;
        Ok(CmdResult {
            output: format!("Untagged {image}"),
            done: at,
        })
    }

    /// `docker rmi` for store-pulled images: drops the blob-level
    /// references the pull took, reclaiming chunks no other image or
    /// writable layer still shares.
    pub fn rmi_with_store(
        &mut self,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        store: &mut LayerStore,
        at: SimTime,
        image: &str,
    ) -> Result<CmdResult, DockerError> {
        let manifest = self.load_manifest(fs, dev, at, image)?;
        for layer in &manifest.layers {
            store.unref_blob(fs, *layer)?;
        }
        fs.unlink(&format!("/images/manifest/{}", Self::manifest_key(image)))?;
        Ok(CmdResult {
            output: format!("Untagged {image}"),
            done: at,
        })
    }

    fn load_manifest(
        &self,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        image: &str,
    ) -> Result<ImageManifest, DockerError> {
        let path = format!("/images/manifest/{}", Self::manifest_key(image));
        let r = fs
            .read_file(dev, at, &path, LockSide::Isp)
            .map_err(|_| DockerError::NoSuchImage)?;
        let text = String::from_utf8_lossy(&r.value);
        ImageManifest::from_json_str(&text).ok_or(DockerError::NoSuchImage)
    }

    /// `docker create`: unpack layers into a rootfs (overlay merge: lower
    /// dirs from blobs + writable upper), recording the container.
    pub fn create(
        &mut self,
        fw: &mut VirtualFw,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        image: &str,
    ) -> Result<CmdResult, DockerError> {
        let manifest = self.load_manifest(fs, dev, at, image)?;
        let id = format!("c{:04}", self.next_id);
        self.next_id += 1;
        let root = format!("/containers/{id}/rootfs");
        fs.mkdir_p(&root, crate::nvme::namespace::PRIVATE_NS)
            .map_err(DockerError::Fs)?;
        let mut done = at;
        // overlay: lower directories materialize from each layer blob
        for (i, layer) in manifest.layers.iter().enumerate() {
            let blob = fs
                .read_file(dev, done, &format!("/images/blobs/{:016x}", layer), LockSide::Isp)?;
            done = blob.done;
            let r = fs.write_file(
                dev,
                done,
                &format!("{root}/lower{i}"),
                &blob.value,
                LockSide::Isp,
            )?;
            done = r.done;
        }
        // writable upper dir + merged view marker
        fs.mkdir_p(&format!("{root}/upper"), crate::nvme::namespace::PRIVATE_NS)
            .map_err(DockerError::Fs)?;
        let r = fs.write_file(
            dev,
            done,
            &format!("{root}/merged"),
            manifest.entry.as_bytes(),
            LockSide::Isp,
        )?;
        done = r.done;
        fw.syscall(Syscall::Mkdir);
        self.containers
            .push(Container::new(&id, image, &manifest.entry, &root));
        Ok(CmdResult { output: id, done })
    }

    /// `docker start`: fork the ISP process and mark Running.
    pub fn start(
        &mut self,
        fw: &mut VirtualFw,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        id: &str,
    ) -> Result<CmdResult, DockerError> {
        let mem = self.container_mem_bytes;
        let c = self.find(id)?;
        if c.state == ContainerState::Running {
            return Err(DockerError::BadState("already running"));
        }
        let entry = c.entry.clone();
        let log_path = c.log_path();
        let pid = fw.thread.spawn(mem).ok_or(DockerError::OutOfMemory)?;
        fw.syscall(Syscall::Fork);
        let c = self.find(id)?;
        c.state = ContainerState::Running;
        c.pid = Some(pid);
        let r = fs.append_file(
            dev,
            at,
            &log_path,
            format!("[{}] started: {}\n", id, entry).as_bytes(),
            LockSide::Isp,
        )?;
        Ok(CmdResult {
            output: format!("Started {id} (pid {pid})"),
            done: r.done,
        })
    }

    /// `docker run` = create + start.
    pub fn run(
        &mut self,
        fw: &mut VirtualFw,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        image: &str,
    ) -> Result<CmdResult, DockerError> {
        let created = self.create(fw, fs, dev, at, image)?;
        let id = created.output.clone();
        let started = self.start(fw, fs, dev, created.done, &id)?;
        Ok(CmdResult {
            output: id,
            done: started.done,
        })
    }

    /// `docker stop`: graceful exit (code 0).
    pub fn stop(
        &mut self,
        fw: &mut VirtualFw,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        id: &str,
    ) -> Result<CmdResult, DockerError> {
        let mem_pages = self.container_mem_bytes.div_ceil(4096);
        let c = self.find(id)?;
        if c.state != ContainerState::Running {
            return Err(DockerError::BadState("not running"));
        }
        let pid = c.pid.take().expect("running container has pid");
        let log_path = c.log_path();
        c.state = ContainerState::Exited(0);
        fw.thread.exit(pid, 0);
        fw.thread.reap(pid, mem_pages);
        fw.syscall(Syscall::Exit);
        let r = fs.append_file(dev, at, &log_path, format!("[{id}] stopped\n").as_bytes(), LockSide::Isp)?;
        Ok(CmdResult {
            output: format!("Stopped {id}"),
            done: r.done,
        })
    }

    /// `docker kill`: SIGKILL semantics (code 137).
    pub fn kill(
        &mut self,
        fw: &mut VirtualFw,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        id: &str,
    ) -> Result<CmdResult, DockerError> {
        let mem_pages = self.container_mem_bytes.div_ceil(4096);
        let c = self.find(id)?;
        if c.state != ContainerState::Running {
            return Err(DockerError::BadState("not running"));
        }
        let pid = c.pid.take().expect("running container has pid");
        let log_path = c.log_path();
        c.state = ContainerState::Killed;
        fw.thread.exit(pid, 137);
        fw.thread.reap(pid, mem_pages);
        let r = fs.append_file(dev, at, &log_path, format!("[{id}] killed\n").as_bytes(), LockSide::Isp)?;
        Ok(CmdResult {
            output: format!("Killed {id}"),
            done: r.done,
        })
    }

    /// `docker restart` = stop (if running) + start.
    pub fn restart(
        &mut self,
        fw: &mut VirtualFw,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        id: &str,
    ) -> Result<CmdResult, DockerError> {
        let state = self.find(id)?.state.clone();
        let mut now = at;
        if state == ContainerState::Running {
            now = self.stop(fw, fs, dev, now, id)?.done;
        }
        self.start(fw, fs, dev, now, id)
    }

    /// `docker rm`: remove a non-running container and its rootfs.
    pub fn rm(
        &mut self,
        fs: &mut LambdaFs,
        at: SimTime,
        id: &str,
    ) -> Result<CmdResult, DockerError> {
        let c = self.find(id)?;
        if c.state == ContainerState::Running {
            return Err(DockerError::BadState("running; stop or kill first"));
        }
        let root = c.rootfs.clone();
        if let Ok(entries) = fs.list(&root) {
            for e in entries {
                let _ = fs.unlink(&format!("{root}/{e}"));
            }
        }
        let _ = fs.unlink(&format!("/containers/{id}/log"));
        self.containers.retain(|c| c.id != id);
        Ok(CmdResult {
            output: format!("Removed {id}"),
            done: at,
        })
    }

    /// `docker logs`: read `/containers/<id>/log` (transferable to the
    /// host via Ether-oN for real-time analysis).
    pub fn logs(
        &mut self,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        id: &str,
    ) -> Result<CmdResult, DockerError> {
        let c = self.find(id)?;
        let path = c.log_path();
        let r = fs.read_file(dev, at, &path, LockSide::Isp)?;
        Ok(CmdResult {
            output: String::from_utf8_lossy(&r.value).into_owned(),
            done: r.done,
        })
    }

    /// `docker ps`: one line per container.
    pub fn ps(&self) -> CmdResult {
        let mut out = String::from("CONTAINER ID  IMAGE  STATUS\n");
        for c in &self.containers {
            out.push_str(&format!("{}  {}  {:?}\n", c.id, c.image, c.state));
        }
        CmdResult {
            output: out,
            done: SimTime::ZERO,
        }
    }

    /// Append a line to a container's log (stdout capture).
    pub fn log_line(
        &mut self,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        id: &str,
        line: &str,
    ) -> Result<SimTime, DockerError> {
        let c = self.find(id)?;
        let path = c.log_path();
        let r = fs.append_file(dev, at, &path, format!("{line}\n").as_bytes(), LockSide::Isp)?;
        Ok(r.done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EtherOnConfig, PoolConfig, SsdConfig};
    use crate::pool::WireRig;

    fn setup() -> (MiniDocker, VirtualFw, LambdaFs, SsdDevice, Registry, WireRig) {
        let cfg = SsdConfig::default();
        let dev = SsdDevice::new(cfg.clone());
        let fs = LambdaFs::over_device(&dev);
        let fw = VirtualFw::new(&cfg);
        let mut reg = Registry::new();
        reg.publish("mariadb", "latest", "mariadbd --datadir=/data", &[64 << 10, 32 << 10], 7);
        let rig = WireRig::new(&PoolConfig::default(), &EtherOnConfig::default());
        (MiniDocker::new(), fw, fs, dev, reg, rig)
    }

    #[test]
    fn pull_stores_blobs_and_manifest() {
        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        let r = md
            .pull(&mut fw, &mut fs, &mut dev, &reg, &mut fab.ctx(SimTime::ZERO), 0, "mariadb")
            .unwrap();
        assert!(r.done > SimTime::ZERO);
        let blobs = fs.list("/images/blobs").unwrap();
        assert_eq!(blobs.len(), 2);
        assert!(fs.walk("/images/manifest/mariadb").is_ok());
    }

    #[test]
    fn pull_charges_the_registry_wan_on_the_fabric() {
        use crate::metrics::{names, Counters};

        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        let r1 = md
            .pull(&mut fw, &mut fs, &mut dev, &reg, &mut fab.ctx(SimTime::ZERO), 0, "mariadb")
            .unwrap();
        let mut c = Counters::new();
        fab.fabric.export_counters(&mut c);
        assert_eq!(
            c.get(names::FABRIC_BYTES_WAN),
            96 << 10,
            "docker pulls are no longer invisible to fabric.bytes_wan"
        );
        assert_eq!(c.get(names::FABRIC_BYTES_HOST_UPLINK), 96 << 10);
        // a second concurrent pull (same instant, other node) queues on
        // the shared WAN/uplink instead of seeing an idle wire
        let mut md2 = MiniDocker::new();
        let mut dev2 = SsdDevice::new(SsdConfig::default());
        let mut fs2 = LambdaFs::over_device(&dev2);
        let mut fw2 = VirtualFw::new(&SsdConfig::default());
        let r2 = md2
            .pull(&mut fw2, &mut fs2, &mut dev2, &reg, &mut fab.ctx(SimTime::ZERO), 1, "mariadb")
            .unwrap();
        assert!(
            r2.done > r1.done,
            "concurrent pulls must contend: {} !> {}",
            r2.done,
            r1.done
        );
    }

    #[test]
    fn pull_via_store_warm_repull_moves_no_wan_bytes() {
        use crate::metrics::{names, Counters};

        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        let mut store = LayerStore::default();
        md.pull_via_store(
            &mut fw, &mut fs, &mut dev, &reg, &mut store, &mut fab.ctx(SimTime::ZERO), 0,
            "mariadb", None,
        )
        .unwrap();
        let mut c = Counters::new();
        fab.fabric.export_counters(&mut c);
        assert_eq!(c.get(names::FABRIC_BYTES_WAN), 96 << 10, "cold pull crosses the WAN");
        // warm re-pull: every layer is a store hit; no fabric traffic
        md.pull_via_store(
            &mut fw, &mut fs, &mut dev, &reg, &mut store, &mut fab.ctx(SimTime::ZERO), 0,
            "mariadb", None,
        )
        .unwrap();
        let mut c2 = Counters::new();
        fab.fabric.export_counters(&mut c2);
        assert_eq!(c2.get(names::FABRIC_BYTES_WAN), 96 << 10, "no new WAN bytes");
    }

    #[test]
    fn pull_unknown_image_fails() {
        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        assert_eq!(
            md.pull(&mut fw, &mut fs, &mut dev, &reg, &mut fab.ctx(SimTime::ZERO), 0, "nope")
                .unwrap_err(),
            DockerError::NoSuchImage
        );
    }

    #[test]
    fn full_lifecycle_pull_run_logs_stop_rm() {
        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        md.pull(&mut fw, &mut fs, &mut dev, &reg, &mut fab.ctx(SimTime::ZERO), 0, "mariadb").unwrap();
        let r = md.run(&mut fw, &mut fs, &mut dev, SimTime::ZERO, "mariadb").unwrap();
        let id = r.output.clone();
        assert_eq!(md.containers()[0].state, ContainerState::Running);
        assert_eq!(fw.thread.running(), 1);

        md.log_line(&mut fs, &mut dev, r.done, &id, "query ok").unwrap();
        let logs = md.logs(&mut fs, &mut dev, r.done, &id).unwrap();
        assert!(logs.output.contains("started"));
        assert!(logs.output.contains("query ok"));

        md.stop(&mut fw, &mut fs, &mut dev, r.done, &id).unwrap();
        assert_eq!(md.containers()[0].state, ContainerState::Exited(0));
        assert_eq!(fw.thread.running(), 0);

        md.rm(&mut fs, r.done, &id).unwrap();
        assert!(md.containers().is_empty());
    }

    #[test]
    fn cannot_rm_running_container() {
        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        md.pull(&mut fw, &mut fs, &mut dev, &reg, &mut fab.ctx(SimTime::ZERO), 0, "mariadb").unwrap();
        let id = md.run(&mut fw, &mut fs, &mut dev, SimTime::ZERO, "mariadb").unwrap().output;
        assert!(matches!(
            md.rm(&mut fs, SimTime::ZERO, &id).unwrap_err(),
            DockerError::BadState(_)
        ));
    }

    #[test]
    fn kill_sets_killed_and_restart_revives() {
        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        md.pull(&mut fw, &mut fs, &mut dev, &reg, &mut fab.ctx(SimTime::ZERO), 0, "mariadb").unwrap();
        let id = md.run(&mut fw, &mut fs, &mut dev, SimTime::ZERO, "mariadb").unwrap().output;
        md.kill(&mut fw, &mut fs, &mut dev, SimTime::ZERO, &id).unwrap();
        assert_eq!(md.containers()[0].state, ContainerState::Killed);
        md.restart(&mut fw, &mut fs, &mut dev, SimTime::ZERO, &id).unwrap();
        assert_eq!(md.containers()[0].state, ContainerState::Running);
    }

    #[test]
    fn rmi_removes_image_files() {
        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        md.pull(&mut fw, &mut fs, &mut dev, &reg, &mut fab.ctx(SimTime::ZERO), 0, "mariadb").unwrap();
        md.rmi(&mut fs, &mut dev, SimTime::ZERO, "mariadb").unwrap();
        assert!(fs.walk("/images/manifest/mariadb").is_err());
        assert!(fs.list("/images/blobs").unwrap().is_empty());
    }

    #[test]
    fn ps_lists_containers() {
        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        md.pull(&mut fw, &mut fs, &mut dev, &reg, &mut fab.ctx(SimTime::ZERO), 0, "mariadb").unwrap();
        md.run(&mut fw, &mut fs, &mut dev, SimTime::ZERO, "mariadb").unwrap();
        let out = md.ps().output;
        assert!(out.contains("c0001"));
        assert!(out.contains("mariadb"));
    }

    #[test]
    fn http_command_parsing() {
        assert_eq!(
            DockerCmd::from_http("POST /images/mariadb/pull HTTP/1.1"),
            Some(DockerCmd::Pull("mariadb".into()))
        );
        assert_eq!(
            DockerCmd::from_http("POST /containers/c0001/start HTTP/1.1"),
            Some(DockerCmd::Start("c0001".into()))
        );
        assert_eq!(
            DockerCmd::from_http("GET /containers/json HTTP/1.1"),
            Some(DockerCmd::Ps)
        );
        assert_eq!(
            DockerCmd::from_http("DELETE /containers/c0001 HTTP/1.1"),
            Some(DockerCmd::Rm("c0001".into()))
        );
        assert_eq!(DockerCmd::from_http("PATCH /nope HTTP/1.1"), None);
    }

    #[test]
    fn pull_via_store_dedups_second_pull() {
        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        let mut store = LayerStore::default();
        let r1 = md
            .pull_via_store(
                &mut fw, &mut fs, &mut dev, &reg, &mut store, &mut fab.ctx(SimTime::ZERO), 0,
                "mariadb", None,
            )
            .unwrap();
        assert!(r1.done > SimTime::ZERO);
        let (manifest, _) = reg.fetch("mariadb").unwrap();
        assert!(manifest.layers.iter().all(|l| store.has_blob(*l)));
        let written = store.stats.bytes_written;
        assert_eq!(written, (64 << 10) + (32 << 10));
        // second pull of the same image: zero bytes fetched or written,
        // and no extra blob refs (refs mirror "manifest present")
        let r2 = md
            .pull_via_store(
                &mut fw, &mut fs, &mut dev, &reg, &mut store, &mut fab.ctx(r1.done), 0,
                "mariadb", None,
            )
            .unwrap();
        assert_eq!(store.stats.bytes_written, written);
        assert!(r2.output.contains("2 reused"));
        assert!(r2.output.contains("0 bytes fetched"));
        assert!(manifest.layers.iter().all(|l| store.blob_refs(*l) == 1));
    }

    #[test]
    fn pull_via_store_records_chunk_presence_as_chunks_land() {
        let cfg = SsdConfig::default();
        let mut dev = SsdDevice::new(cfg.clone());
        let mut fs = LambdaFs::over_device(&dev);
        let mut fw = VirtualFw::new(&cfg);
        let mut md = MiniDocker::new();
        let mut store = LayerStore::default();
        let mut fab = WireRig::new(&PoolConfig::default(), &EtherOnConfig::default());
        let mut pool = PoolLayerCache::new();
        // a 160KiB layer chunks into 64 + 64 + 32 KiB at the default size
        let mut reg = Registry::new();
        reg.publish("big", "latest", "big --serve", &[160 << 10], 21);
        md.pull_via_store(
            &mut fw, &mut fs, &mut dev, &reg, &mut store, &mut fab.ctx(SimTime::ZERO), 0, "big",
            Some(&mut pool),
        )
        .unwrap();
        let (_, blobs) = reg.fetch("big").unwrap();
        let blob = &blobs[0];
        assert!(pool.node_has(0, blob.digest), "full holder after the pull");
        let recipe: Vec<(u64, u64)> = pool.chunk_recipe(blob.digest).unwrap().to_vec();
        assert_eq!(recipe.len(), 3);
        assert_eq!(recipe.iter().map(|(_, b)| *b).sum::<u64>(), 160 << 10);
        for (c, _) in &recipe {
            assert!(pool.node_has_chunk(0, *c), "chunk {c:016x} registered as it landed");
        }
        // the pool recipe matches the store's own chunking
        assert_eq!(recipe, store.blob_chunk_recipe(blob.digest).unwrap());
        // a warm pull on another node registers it as a second full holder
        // without re-crossing the WAN
        let mut dev2 = SsdDevice::new(cfg.clone());
        let mut fs2 = LambdaFs::over_device(&dev2);
        let mut fw2 = VirtualFw::new(&cfg);
        let mut md2 = MiniDocker::new();
        md2.pull_via_store(
            &mut fw2, &mut fs2, &mut dev2, &reg, &mut store, &mut fab.ctx(SimTime::ZERO), 1, "big",
            Some(&mut pool),
        )
        .unwrap();
        assert!(pool.node_has(1, blob.digest));
        assert_eq!(pool.chunk_holders_of(recipe[0].0), vec![0, 1]);
    }

    #[test]
    fn rmi_with_store_reclaims_image_chunks() {
        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        let mut store = LayerStore::default();
        md.pull_via_store(
            &mut fw, &mut fs, &mut dev, &reg, &mut store, &mut fab.ctx(SimTime::ZERO), 0,
            "mariadb", None,
        )
        .unwrap();
        // re-pull must not leak a second reference (rmi releases once)
        md.pull_via_store(
            &mut fw, &mut fs, &mut dev, &reg, &mut store, &mut fab.ctx(SimTime::ZERO), 0,
            "mariadb", None,
        )
        .unwrap();
        assert!(store.unique_bytes() > 0);
        md.rmi_with_store(&mut fs, &mut dev, &mut store, SimTime::ZERO, "mariadb")
            .unwrap();
        assert_eq!(store.unique_bytes(), 0, "image chunks reclaimed");
        assert!(fs.list("/images/chunks").unwrap().is_empty());
        assert!(fs.walk("/images/manifest/mariadb").is_err());
    }

    #[test]
    fn rmi_with_store_keeps_chunks_live_containers_share() {
        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        let mut store = LayerStore::default();
        md.pull_via_store(
            &mut fw, &mut fs, &mut dev, &reg, &mut store, &mut fab.ctx(SimTime::ZERO), 0,
            "mariadb", None,
        )
        .unwrap();
        let id = md
            .run_cow(&mut fw, &mut fs, &mut dev, &mut store, SimTime::ZERO, "mariadb")
            .unwrap()
            .output;
        md.rmi_with_store(&mut fs, &mut dev, &mut store, SimTime::ZERO, "mariadb")
            .unwrap();
        // the running container's writable layer still pins the chunks
        assert_eq!(store.unique_bytes(), 96 << 10);
        let layer = md.cow_layer_of(&id).unwrap();
        let r = md.cow.read(&mut store, &mut fs, &mut dev, SimTime::ZERO, layer).unwrap();
        assert_eq!(r.value.len(), 96 << 10);
        md.stop(&mut fw, &mut fs, &mut dev, SimTime::ZERO, &id).unwrap();
        md.rm_with_store(&mut fs, &mut store, SimTime::ZERO, &id).unwrap();
        assert_eq!(store.unique_bytes(), 0);
    }

    #[test]
    fn tagged_and_untagged_references_are_one_image() {
        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        // pull with the explicit :latest tag, create with the bare name
        md.pull(&mut fw, &mut fs, &mut dev, &reg, &mut fab.ctx(SimTime::ZERO), 0, "mariadb:latest")
            .unwrap();
        let id = md.create(&mut fw, &mut fs, &mut dev, SimTime::ZERO, "mariadb").unwrap().output;
        assert_eq!(md.containers()[0].id, id);
        // one manifest file, not two
        assert_eq!(fs.list("/images/manifest").unwrap(), vec!["mariadb".to_string()]);
    }

    #[test]
    fn create_cow_mounts_writable_layer_without_copying() {
        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        let mut store = LayerStore::default();
        md.pull_via_store(
            &mut fw, &mut fs, &mut dev, &reg, &mut store, &mut fab.ctx(SimTime::ZERO), 0,
            "mariadb", None,
        )
        .unwrap();
        let unique = store.unique_bytes();
        let r = md
            .run_cow(&mut fw, &mut fs, &mut dev, &mut store, SimTime::ZERO, "mariadb")
            .unwrap();
        let id = r.output.clone();
        assert_eq!(md.containers()[0].state, ContainerState::Running);
        assert_eq!(store.unique_bytes(), unique, "boot copies no layer bytes");
        let layer = md.cow_layer_of(&id).expect("store-backed container");
        assert_eq!(md.cow.len_of(layer), Some((64 << 10) + (32 << 10)));
        // rootfs holds only the merged marker — lower dirs stay shared chunks
        let root = format!("/containers/{id}/rootfs");
        assert_eq!(fs.list(&root).unwrap(), vec!["merged".to_string()]);
    }

    #[test]
    fn rm_with_store_releases_the_writable_layer() {
        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        let mut store = LayerStore::default();
        md.pull_via_store(
            &mut fw, &mut fs, &mut dev, &reg, &mut store, &mut fab.ctx(SimTime::ZERO), 0,
            "mariadb", None,
        )
        .unwrap();
        let id = md
            .run_cow(&mut fw, &mut fs, &mut dev, &mut store, SimTime::ZERO, "mariadb")
            .unwrap()
            .output;
        // dirty one chunk so the layer owns private content
        let layer = md.cow_layer_of(&id).unwrap();
        md.cow
            .write_at(&mut store, &mut fs, &mut dev, SimTime::ZERO, layer, 0, &[0xAB; 128])
            .unwrap();
        assert!(store.unique_bytes() > (96 << 10) as u64);
        md.stop(&mut fw, &mut fs, &mut dev, SimTime::ZERO, &id).unwrap();
        md.rm_with_store(&mut fs, &mut store, SimTime::ZERO, &id).unwrap();
        assert_eq!(md.cow.layer_count(), 0);
        assert_eq!(md.cow_layer_of(&id), None);
        assert_eq!(store.unique_bytes(), 96 << 10, "private CoW chunk reclaimed");
    }

    #[test]
    fn create_cow_requires_store_resident_image() {
        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        let mut store = LayerStore::default();
        // classic pull: blobs land as files, not in the store
        md.pull(&mut fw, &mut fs, &mut dev, &reg, &mut fab.ctx(SimTime::ZERO), 0, "mariadb").unwrap();
        assert_eq!(
            md.create_cow(&mut fw, &mut fs, &mut dev, &mut store, SimTime::ZERO, "mariadb")
                .unwrap_err(),
            DockerError::NoSuchImage
        );
    }

    #[test]
    fn create_materializes_overlay_rootfs() {
        let (mut md, mut fw, mut fs, mut dev, reg, mut fab) = setup();
        md.pull(&mut fw, &mut fs, &mut dev, &reg, &mut fab.ctx(SimTime::ZERO), 0, "mariadb").unwrap();
        let id = md.create(&mut fw, &mut fs, &mut dev, SimTime::ZERO, "mariadb").unwrap().output;
        let root = format!("/containers/{id}/rootfs");
        let entries = fs.list(&root).unwrap();
        assert!(entries.contains(&"lower0".to_string()));
        assert!(entries.contains(&"lower1".to_string()));
        assert!(entries.contains(&"upper".to_string()));
        assert!(entries.contains(&"merged".to_string()));
        let merged = fs
            .read_file(&mut dev, SimTime::ZERO, &format!("{root}/merged"), LockSide::Isp)
            .unwrap();
        assert_eq!(merged.value, b"mariadbd --datadir=/data".to_vec());
    }
}
