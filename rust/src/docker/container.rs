//! ISP-container state (paper "Container life cycle management").

/// Lifecycle states reachable through the 11 mini-docker commands.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ContainerState {
    Created,
    Running,
    Exited(i32),
    Killed,
}

/// One ISP-container.
#[derive(Clone, Debug)]
pub struct Container {
    pub id: String,
    pub image: String,
    /// Entry script from the image manifest.
    pub entry: String,
    /// λFS path of the merged rootfs.
    pub rootfs: String,
    pub state: ContainerState,
    /// ISP process id while running.
    pub pid: Option<u32>,
}

impl Container {
    pub fn new(id: &str, image: &str, entry: &str, rootfs: &str) -> Self {
        Container {
            id: id.to_string(),
            image: image.to_string(),
            entry: entry.to_string(),
            rootfs: rootfs.to_string(),
            state: ContainerState::Created,
            pid: None,
        }
    }

    /// Log file location: `/containers/<id>/log` (the paper logs under
    /// the container directory for host-side retrieval).
    pub fn log_path(&self) -> String {
        format!("/containers/{}/log", self.id)
    }

    pub fn is_running(&self) -> bool {
        self.state == ContainerState::Running
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_container_is_created_state() {
        let c = Container::new("c0001", "nginx", "/entry", "/containers/c0001/rootfs");
        assert_eq!(c.state, ContainerState::Created);
        assert!(!c.is_running());
        assert_eq!(c.pid, None);
    }

    #[test]
    fn log_path_under_container_dir() {
        let c = Container::new("c0042", "embed", "/entry", "/containers/c0042/rootfs");
        assert_eq!(c.log_path(), "/containers/c0042/log");
    }
}
