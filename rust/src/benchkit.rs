//! Minimal benchmark harness (in-crate substitute for criterion — this
//! build environment is offline; DESIGN.md §4).
//!
//! Each `[[bench]]` target is a `harness = false` binary that calls
//! [`bench`] for measured hot paths and prints paper-table rows via
//! [`crate::metrics::Table`].  Measurement: warmup iterations, then
//! timed batches until `min_time`, reporting mean/min/max per iteration.

use std::time::{Duration, Instant};

/// One benchmark's measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64().max(1e-12)
    }
}

/// Measure `f`, printing a criterion-style line.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // warmup
    for _ in 0..3 {
        f();
    }
    let min_time = Duration::from_millis(300);
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < min_time || samples.len() < 10 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    let total: Duration = samples.iter().sum();
    let mean = total / samples.len() as u32;
    let min = *samples.iter().min().unwrap();
    let max = *samples.iter().max().unwrap();
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len() as u64,
        mean,
        min,
        max,
    };
    println!(
        "bench {:<44} {:>12?}/iter  (min {:?}, max {:?}, n={})",
        r.name, r.mean, r.min, r.max, r.iters
    );
    r
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench("noop-ish", || {
            x = x.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters >= 10);
        assert!(r.min <= r.mean && r.mean <= r.max);
    }
}
