//! Minimal benchmark harness (in-crate substitute for criterion — this
//! build environment is offline; DESIGN.md §4).
//!
//! Each `[[bench]]` target is a `harness = false` binary that calls
//! [`bench`] for measured hot paths and prints paper-table rows via
//! [`crate::metrics::Table`].  Measurement: warmup iterations, then
//! timed batches until `min_time`, reporting mean/min/max per iteration.

use std::time::{Duration, Instant};

use crate::json::Json;

/// One benchmark's measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64().max(1e-12)
    }
}

/// Measure `f`, printing a criterion-style line.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // warmup
    for _ in 0..3 {
        f();
    }
    let min_time = Duration::from_millis(300);
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < min_time || samples.len() < 10 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    let total: Duration = samples.iter().sum();
    let mean = total / samples.len() as u32;
    let min = *samples.iter().min().unwrap();
    let max = *samples.iter().max().unwrap();
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len() as u64,
        mean,
        min,
        max,
    };
    println!(
        "bench {:<44} {:>12?}/iter  (min {:?}, max {:?}, n={})",
        r.name, r.mean, r.min, r.max, r.iters
    );
    r
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// One machine-readable benchmark datapoint, so perf is tracked across
/// PRs: every bench binary appends records and dumps them to a
/// `BENCH_<name>.json` file next to the human-readable tables.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    pub name: String,
    pub metric: String,
    pub value: f64,
}

impl BenchRecord {
    pub fn new(name: impl Into<String>, metric: impl Into<String>, value: f64) -> Self {
        BenchRecord {
            name: name.into(),
            metric: metric.into(),
            value,
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("metric", Json::str(self.metric.clone())),
            ("value", Json::Num(self.value)),
        ])
    }
}

/// Write `records` as a JSON array to `path` (and say so on stdout).
pub fn emit_json(path: &str, records: &[BenchRecord]) -> std::io::Result<()> {
    let doc = Json::Arr(records.iter().map(BenchRecord::to_json).collect());
    std::fs::write(path, doc.dump())?;
    println!("wrote {} records to {path}", records.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench("noop-ish", || {
            x = x.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters >= 10);
        assert!(r.min <= r.mean && r.mean <= r.max);
    }

    #[test]
    fn bench_records_round_trip_as_json() {
        use crate::json::parse;

        let recs = vec![
            BenchRecord::new("boot_storm", "makespan_ms", 12.5),
            BenchRecord::new("boot_storm", "queue_wait_ms", 3.25),
        ];
        let doc = Json::Arr(recs.iter().map(BenchRecord::to_json).collect());
        let back = parse(&doc.dump()).unwrap();
        let arr = back.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("boot_storm"));
        assert_eq!(arr[0].get("metric").unwrap().as_str(), Some("makespan_ms"));
        assert_eq!(arr[1].get("value").unwrap().as_f64(), Some(3.25));
    }
}
