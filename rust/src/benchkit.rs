//! Minimal benchmark harness (in-crate substitute for criterion — this
//! build environment is offline; DESIGN.md §4).
//!
//! Each `[[bench]]` target is a `harness = false` binary that calls
//! [`bench`] for measured hot paths and prints paper-table rows via
//! [`crate::metrics::Table`].  Measurement: warmup iterations, then
//! timed batches until `min_time`, reporting mean/min/max per iteration.

use std::time::{Duration, Instant};

use crate::json::Json;

/// One benchmark's measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64().max(1e-12)
    }
}

/// Measure `f`, printing a criterion-style line.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // warmup
    for _ in 0..3 {
        f();
    }
    let min_time = Duration::from_millis(300);
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < min_time || samples.len() < 10 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    let total: Duration = samples.iter().sum();
    let mean = total / samples.len() as u32;
    let min = *samples.iter().min().unwrap();
    let max = *samples.iter().max().unwrap();
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len() as u64,
        mean,
        min,
        max,
    };
    println!(
        "bench {:<44} {:>12?}/iter  (min {:?}, max {:?}, n={})",
        r.name, r.mean, r.min, r.max, r.iters
    );
    r
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// One machine-readable benchmark datapoint, so perf is tracked across
/// PRs: every bench binary appends records and dumps them to a
/// `BENCH_<name>.json` file next to the human-readable tables.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    pub name: String,
    pub metric: String,
    pub value: f64,
}

impl BenchRecord {
    pub fn new(name: impl Into<String>, metric: impl Into<String>, value: f64) -> Self {
        BenchRecord {
            name: name.into(),
            metric: metric.into(),
            value,
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("metric", Json::str(self.metric.clone())),
            ("value", Json::Num(self.value)),
        ])
    }
}

/// Write `records` as a JSON array to `path` (and say so on stdout).
pub fn emit_json(path: &str, records: &[BenchRecord]) -> std::io::Result<()> {
    let doc = Json::Arr(records.iter().map(BenchRecord::to_json).collect());
    std::fs::write(path, doc.dump())?;
    println!("wrote {} records to {path}", records.len());
    Ok(())
}

/// Parse a `BENCH_*.json` file back into records.
pub fn parse_records(text: &str) -> Result<Vec<BenchRecord>, String> {
    let root = crate::json::parse(text)?;
    let arr = root.as_arr().ok_or("expected a JSON array of records")?;
    arr.iter()
        .map(|o| {
            Ok(BenchRecord::new(
                o.get("name").and_then(Json::as_str).ok_or("record missing name")?,
                o.get("metric").and_then(Json::as_str).ok_or("record missing metric")?,
                o.get("value").and_then(Json::as_f64).ok_or("record missing value")?,
            ))
        })
        .collect()
}

/// Direction heuristic for [`diff`]: durations and waits regress when
/// they grow; everything else (throughput, reduction factors, hidden
/// bytes) regresses when it shrinks.  Markers are matched as whole
/// `_`-separated segments, never bare substrings — `retimed_transfers`
/// is a count (no `ns`/`time` segment), not a duration.
pub fn lower_is_better(metric: &str) -> bool {
    metric
        .split('_')
        .any(|seg| matches!(seg, "ms" | "ns" | "us" | "time" | "wait" | "latency"))
}

/// One (name, metric) pair compared across PRs.
#[derive(Clone, Debug)]
pub struct BenchDelta {
    pub name: String,
    pub metric: String,
    pub base: f64,
    pub fresh: f64,
    /// Signed fractional change, positive = improvement (direction via
    /// [`lower_is_better`]).
    pub gain: f64,
    /// The bad direction moved more than the tolerance.
    pub regression: bool,
}

/// Compare fresh records against a committed baseline: every (name,
/// metric) pair present in both is scored; a move of more than
/// `tolerance` (fraction, e.g. 0.10) in the bad direction is flagged as
/// a regression.  Fresh records with no baseline are skipped — they are
/// new benches, recorded but not compared.
pub fn diff(base: &[BenchRecord], fresh: &[BenchRecord], tolerance: f64) -> Vec<BenchDelta> {
    let mut out = Vec::new();
    for f in fresh {
        let Some(b) = base.iter().find(|b| b.name == f.name && b.metric == f.metric) else {
            continue;
        };
        if b.value.abs() < 1e-12 {
            continue; // a zero baseline has no meaningful ratio
        }
        let change = (f.value - b.value) / b.value;
        let gain = if lower_is_better(&f.metric) { -change } else { change };
        out.push(BenchDelta {
            name: f.name.clone(),
            metric: f.metric.clone(),
            base: b.value,
            fresh: f.value,
            gain,
            regression: gain < -tolerance,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench("noop-ish", || {
            x = x.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters >= 10);
        assert!(r.min <= r.mean && r.mean <= r.max);
    }

    #[test]
    fn parse_records_round_trips_emit_json_format() {
        let recs = vec![
            BenchRecord::new("boot", "makespan_ms", 12.5),
            BenchRecord::new("boot", "wan_reduction", 4.0),
        ];
        let doc = Json::Arr(recs.iter().map(BenchRecord::to_json).collect());
        let back = parse_records(&doc.dump()).unwrap();
        assert_eq!(back, recs);
        assert!(parse_records("[{\"name\": \"x\"}]").is_err(), "missing fields rejected");
    }

    #[test]
    fn diff_flags_regressions_in_the_bad_direction_only() {
        let base = vec![
            BenchRecord::new("boot", "makespan_ms", 100.0),
            BenchRecord::new("boot", "wan_reduction", 4.0),
            BenchRecord::new("mix", "congestion_factor", 2.0),
        ];
        // makespan (lower-better) +20% = regression; reduction
        // (higher-better) -50% = regression; new bench skipped
        let fresh = vec![
            BenchRecord::new("boot", "makespan_ms", 120.0),
            BenchRecord::new("boot", "wan_reduction", 2.0),
            BenchRecord::new("new_bench", "ops", 1.0),
        ];
        let deltas = diff(&base, &fresh, 0.10);
        assert_eq!(deltas.len(), 2, "unmatched records are skipped");
        assert!(deltas.iter().all(|d| d.regression));
        // improvements and small moves pass
        let ok = vec![
            BenchRecord::new("boot", "makespan_ms", 95.0),
            BenchRecord::new("boot", "wan_reduction", 4.1),
        ];
        assert!(diff(&base, &ok, 0.10).iter().all(|d| !d.regression && d.gain > 0.0));
        let small = vec![BenchRecord::new("boot", "makespan_ms", 105.0)];
        assert!(!diff(&base, &small, 0.10)[0].regression, "within tolerance");
    }

    #[test]
    fn bench_records_round_trip_as_json() {
        use crate::json::parse;

        let recs = vec![
            BenchRecord::new("boot_storm", "makespan_ms", 12.5),
            BenchRecord::new("boot_storm", "queue_wait_ms", 3.25),
        ];
        let doc = Json::Arr(recs.iter().map(BenchRecord::to_json).collect());
        let back = parse(&doc.dump()).unwrap();
        let arr = back.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("boot_storm"));
        assert_eq!(arr[0].get("metric").unwrap().as_str(), Some("makespan_ms"));
        assert_eq!(arr[1].get("value").unwrap().as_f64(), Some(3.25));
    }
}
