//! Metrics: counters, latency histograms, and table rendering for the
//! benchmark harness output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::SimTime;

/// Log-bucketed latency histogram (2 buckets per octave, ns domain).
#[derive(Clone, Debug, Default)]
pub struct LatencyHistogram {
    buckets: BTreeMap<u32, u64>,
    count: u64,
    sum_ns: u128,
    min_ns: u64,
    max_ns: u64,
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            min_ns: u64::MAX,
            ..Default::default()
        }
    }

    fn bucket_of(ns: u64) -> u32 {
        if ns <= 1 {
            return 0;
        }
        let lg = 63 - ns.leading_zeros();
        let half = if ns & (1 << lg.saturating_sub(1)) != 0 && lg > 0 {
            1
        } else {
            0
        };
        lg * 2 + half
    }

    pub fn record(&mut self, t: SimTime) {
        let ns = t.as_ns();
        *self.buckets.entry(Self::bucket_of(ns)).or_insert(0) += 1;
        self.count += 1;
        self.sum_ns += ns as u128;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> SimTime {
        if self.count == 0 {
            return SimTime::ZERO;
        }
        SimTime::ns((self.sum_ns / self.count as u128) as u64)
    }

    pub fn min(&self) -> SimTime {
        if self.count == 0 {
            SimTime::ZERO
        } else {
            SimTime::ns(self.min_ns)
        }
    }

    pub fn max(&self) -> SimTime {
        SimTime::ns(self.max_ns)
    }

    /// Approximate quantile from bucket boundaries (upper bound of bucket).
    pub fn quantile(&self, q: f64) -> SimTime {
        if self.count == 0 {
            return SimTime::ZERO;
        }
        let target = ((self.count as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (&b, &c) in &self.buckets {
            seen += c;
            if seen >= target {
                let lg = b / 2;
                let base = 1u64 << lg;
                let upper = if b % 2 == 1 { base + base / 2 } else { base };
                return SimTime::ns(upper.max(1));
            }
        }
        SimTime::ns(self.max_ns)
    }
}

/// Canonical counter names for the [`crate::layerstore`] subsystem, so
/// every exporter (store, CoW layers, pool cache, benches) lands on the
/// same keys and tables can be joined across nodes.
pub mod names {
    /// Chunk references satisfied without programming flash.
    pub const DEDUP_HITS: &str = "layerstore.dedup_hits";
    pub const CHUNKS_WRITTEN: &str = "layerstore.chunks_written";
    pub const BYTES_WRITTEN: &str = "layerstore.bytes_written";
    /// Bytes avoided by chunk- or blob-level dedup.
    pub const BYTES_DEDUPED: &str = "layerstore.bytes_deduped";
    pub const CHUNKS_RECLAIMED: &str = "layerstore.chunks_reclaimed";
    /// Writes that had to copy a shared chunk first.
    pub const COW_BREAKS: &str = "layerstore.cow_breaks";
    pub const COW_CHUNK_WRITES: &str = "layerstore.cow_chunk_writes";
    /// Layer fetches served by a peer DockerSSD over the intranet.
    pub const PEER_FETCHES: &str = "layerstore.peer_fetches";
    pub const REGISTRY_FETCHES: &str = "layerstore.registry_fetches";
    pub const BYTES_FROM_PEERS: &str = "layerstore.bytes_from_peers";
    pub const BYTES_FROM_REGISTRY: &str = "layerstore.bytes_from_registry";
    /// Bytes that never crossed the registry WAN thanks to pool reuse.
    pub const BYTES_NOT_TRANSFERRED: &str = "layerstore.bytes_not_transferred";
    /// Layers dropped by pool-wide GC.
    pub const GC_EVICTIONS: &str = "layerstore.gc_evictions";
    /// Chunk-granular transfers issued by the pool cache (fetch and
    /// prefetch; one per chunk actually moved, local chunks excluded).
    pub const CHUNK_FETCHES: &str = "layerstore.chunk_fetches";
    /// Chunk bytes served by peer DockerSSDs over the intranet.
    pub const CHUNK_BYTES_PEER: &str = "layerstore.chunk_bytes_peer";
    /// Chunk bytes that had to cross the registry WAN (no peer held them).
    pub const CHUNK_BYTES_REGISTRY: &str = "layerstore.chunk_bytes_registry";
    /// Distinct *partial* holders (nodes holding some but not all of a
    /// layer's chunks) that served chunks to a fetch.
    pub const PARTIAL_HOLDERS_USED: &str = "layerstore.partial_holders_used";

    // Canonical names for the [`crate::fabric`] subsystem: bytes
    // serialized per link class, queueing delay, and prefetch volume.
    pub const FABRIC_BYTES_ARRAY: &str = "fabric.bytes_array";
    pub const FABRIC_BYTES_TRAY: &str = "fabric.bytes_tray";
    pub const FABRIC_BYTES_HOST_UPLINK: &str = "fabric.bytes_host_uplink";
    pub const FABRIC_BYTES_WAN: &str = "fabric.bytes_wan";
    /// Total time transfers spent waiting for a contended wire.
    pub const FABRIC_QUEUE_WAIT_NS: &str = "fabric.queue_wait_ns";
    pub const FABRIC_TRANSFERS: &str = "fabric.transfers";
    /// MTU frames charged to the Ether-oN driver path.
    pub const FABRIC_FRAMES: &str = "fabric.frames";
    /// Bytes moved by background prefetch.
    pub const FABRIC_PREFETCH_BYTES: &str = "fabric.prefetch_bytes";
    /// Prefetch bytes that never waited behind foreground traffic.
    pub const FABRIC_PREFETCH_HIDDEN: &str = "fabric.prefetch_bytes_hidden";
    /// Transfers the event-driven engine re-timed after a preemption
    /// (the receipt is strictly later than the optimistic busy-until
    /// figure would have been).
    pub const FABRIC_RETIMED_TRANSFERS: &str = "fabric.retimed_transfers";
    /// Times a link entered a degraded-bandwidth window (a flap).
    pub const FABRIC_LINK_FLAPS: &str = "fabric.link_flaps";
    /// Total time any link spent in a degraded-bandwidth window.
    pub const FABRIC_BROWNOUT_NS: &str = "fabric.brownout_ns";
    /// Bytes that moved device-to-device (both stream endpoints in the
    /// pool) — traffic that never touched the host uplink.
    pub const FABRIC_BYTES_P2P: &str = "fabric.bytes_p2p";
    /// Chunk quanta issued by `fabric::stream` pipelines.
    pub const FABRIC_STREAM_QUANTA: &str = "fabric.stream_quanta";
    /// Consumer head start exposed by stream pipelining: for each settled
    /// stream, the sum over its non-final quanta of (stream finish −
    /// quantum finish).  A monolithic transfer exposes zero.
    pub const FABRIC_STREAM_OVERLAP_NS: &str = "fabric.stream_overlap_ns";

    // Canonical names for the [`crate::sim`] event core.
    /// Events whose requested firing time was in the past and got
    /// clamped to the queue's `now`.
    pub const SIM_CLAMPED_EVENTS: &str = "sim.clamped_events";
    pub const SIM_EVENTS_PROCESSED: &str = "sim.events_processed";

    // Canonical names for the [`crate::coordinator`] serving loop, so a
    // serve storm's schedule is comparable byte-for-byte across runs.
    pub const SERVE_REQUESTS: &str = "serve.requests";
    pub const SERVE_RESPONSES: &str = "serve.responses";
    pub const SERVE_BATCHES: &str = "serve.batches";
    pub const SERVE_PADDED_ROWS: &str = "serve.padded_rows";
    pub const SERVE_TOKENS_OUT: &str = "serve.tokens_out";
    /// Live (non-padding) prompt tokens dispatched to nodes.
    pub const SERVE_PROMPT_TOKENS: &str = "serve.prompt_tokens";
    /// KV bytes reserved across all batches, sized per request from the
    /// model's per-token KV footprint.
    pub const SERVE_KV_RESERVED_BYTES: &str = "serve.kv_reserved_bytes";
    pub const SERVE_FAILED_BATCHES: &str = "serve.failed_batches";
    /// Resident session KV moved between nodes to relieve pressure.
    pub const SERVE_KV_MIGRATIONS: &str = "serve.kv_migrations";
    /// Resident session KV dropped to admit a waiting batch.
    pub const SERVE_KV_EVICTIONS: &str = "serve.kv_evictions";
    pub const SERVE_MAKESPAN_NS: &str = "serve.makespan_ns";
    pub const SERVE_LATENCY_MEAN_NS: &str = "serve.latency_mean_ns";
    pub const SERVE_LATENCY_P99_NS: &str = "serve.latency_p99_ns";
    /// Host-uplink bytes the serve loop charged (ingress prompts +
    /// response control) divided by tokens served — the headline
    /// device-to-device streaming metric.
    pub const SERVE_HOST_BYTES_PER_TOKEN: &str = "serve.host_bytes_per_token";

    // Canonical names for the [`crate::chaos`] fault-injection engine
    // and the self-healing loop it drives.  Chaos counters describe the
    // *injected* schedule (what went wrong, when, how often); heal
    // counters describe the repair traffic that brought the pool back
    // to the chunk-level >=k-holder invariant.
    pub const CHAOS_FAULTS_INJECTED: &str = "chaos.faults_injected";
    pub const CHAOS_NODE_DEATHS: &str = "chaos.node_deaths";
    pub const CHAOS_ARRAY_LOSSES: &str = "chaos.array_losses";
    pub const CHAOS_LINK_BROWNOUTS: &str = "chaos.link_brownouts";
    pub const CHAOS_REGISTRY_STALLS: &str = "chaos.registry_stalls";
    /// Time-weighted healthy-node fraction over the serve window, in
    /// parts per million (integer so two same-seed runs compare
    /// byte-identically).
    pub const CHAOS_AVAILABILITY_PPM: &str = "chaos.availability_ppm";
    /// Distinct chunks that fell below k healthy holders and were healed.
    pub const HEAL_CHUNKS_REREPLICATED: &str = "heal.chunks_rereplicated";
    /// Replica copies created by the heal loop (one per transfer).
    pub const HEAL_COPIES_MADE: &str = "heal.copies_made";
    /// Bytes the heal loop moved over background lanes.
    pub const HEAL_BYTES: &str = "heal.bytes";
    /// Heal bytes that never waited behind foreground traffic.
    pub const HEAL_BYTES_HIDDEN: &str = "heal.bytes_hidden";
    /// Chunks no surviving peer held — re-pulled across the registry WAN.
    pub const HEAL_REGISTRY_CHUNKS: &str = "heal.registry_chunks";
    /// Replicas re-placed off dead nodes via `replica_failed`.
    pub const HEAL_REPLICAS_RESTARTED: &str = "heal.replicas_restarted";
    /// Dead nodes whose load entries and chunk registrations were purged.
    pub const HEAL_DEAD_NODES_PURGED: &str = "heal.dead_nodes_purged";

    // Canonical names for the [`crate::ssd::ftl`] write-path economics
    // surfaced pool-wide through `pool::FtlBank`.  Deliberately outside
    // the `serve.`/`fabric.`/`sim.`/`chaos.`/`heal.` grep prefixes of
    // ci/serve_smoke.sh, so exporting them changes no committed golden.
    /// Pool-wide write amplification factor in fixed-point milli-units
    /// (1000 = 1.0x): (host pages + GC-relocated pages) / host pages.
    pub const FTL_WAF: &str = "ftl.waf";
    /// Highest per-block erase count across every node's flash.
    pub const FTL_WEAR_MAX: &str = "ftl.wear_max";
    /// Valid pages GC moved to reclaim blocks (the WAF surcharge).
    pub const FTL_GC_RELOCATED: &str = "ftl.gc_relocated_pages";
    /// Pages programmed on behalf of hosts (the WAF denominator).
    pub const FTL_HOST_PAGES: &str = "ftl.host_pages";
    /// Blocks erased across the pool.
    pub const FTL_ERASES: &str = "ftl.erases";

    // Canonical names for the [`crate::pool::autoscale`] controller.
    // Like `ftl.*`, deliberately outside the grep prefixes of
    // ci/serve_smoke.sh — and only exported when the autoscaler runs —
    // so the committed golden never changes while the feature is off.
    /// Controller ticks that fired on the shared clock.
    pub const AUTOSCALE_TICKS: &str = "autoscale.ticks";
    /// Scale-out decisions committed (one replica each).
    pub const AUTOSCALE_SCALE_OUTS: &str = "autoscale.scale_outs";
    /// Scale-in decisions committed (one replica retired each).
    pub const AUTOSCALE_SCALE_INS: &str = "autoscale.scale_ins";
    /// Scale-outs whose node was missing layers at commit time.
    pub const AUTOSCALE_COLD_BOOTS: &str = "autoscale.cold_boots";
    /// Scale-outs whose node already held (or had in flight) every
    /// layer at commit time.
    pub const AUTOSCALE_WARM_BOOTS: &str = "autoscale.warm_boots";
    /// Layer bytes the predictive controller put in flight toward
    /// candidates *before* their scale-out committed.
    pub const AUTOSCALE_PREFETCH_HIDDEN_BYTES: &str = "autoscale.prefetch_hidden_bytes";
    /// p99 of replica cold-start (commit to boot-ready), nanoseconds.
    pub const AUTOSCALE_COLDSTART_P99_NS: &str = "autoscale.coldstart_p99_ns";
}

/// Named counters for substrate statistics.  `PartialEq` so two runs'
/// exports can be compared byte-for-byte (the determinism gate).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    map: BTreeMap<&'static str, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn inc(&mut self, name: &'static str) {
        self.add(name, 1);
    }
    pub fn add(&mut self, name: &'static str, n: u64) {
        *self.map.entry(name).or_insert(0) += n;
    }
    pub fn get(&self, name: &str) -> u64 {
        self.map.get(name).copied().unwrap_or(0)
    }
    pub fn iter(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.map.iter().map(|(k, v)| (*k, *v))
    }
}

/// Fixed-width text table, used by the `repro` CLI to print the paper's
/// tables/figures as rows.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                let _ = write!(line, "{:<width$}", c, width = widths[i]);
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basic_stats() {
        let mut h = LatencyHistogram::new();
        for ns in [100u64, 200, 300, 400] {
            h.record(SimTime::ns(ns));
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.mean(), SimTime::ns(250));
        assert_eq!(h.min(), SimTime::ns(100));
        assert_eq!(h.max(), SimTime::ns(400));
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(SimTime::ns(i * 10));
        }
        let p50 = h.quantile(0.5).as_ns();
        let p99 = h.quantile(0.99).as_ns();
        assert!(p50 <= p99);
        assert!(p50 >= 2_500 && p50 <= 10_000, "p50={p50}");
    }

    #[test]
    fn empty_histogram_is_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean(), SimTime::ZERO);
        assert_eq!(h.quantile(0.99), SimTime::ZERO);
    }

    #[test]
    fn counters_accumulate() {
        let mut c = Counters::new();
        c.inc("reads");
        c.add("reads", 4);
        c.inc("writes");
        assert_eq!(c.get("reads"), 5);
        assert_eq!(c.get("writes"), 1);
        assert_eq!(c.get("absent"), 0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["short", "1"]);
        t.row(vec!["a-much-longer-name", "23456"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[3].contains("23456"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }
}
