//! NVMe namespaces and the two-PCIe-function layout of λFS (Figure 4b).
//!
//! The NVMe subsystem partitions the media into a *private* namespace
//! (Virtual-FW only: image layers, container rootfs) and a *sharable*
//! namespace (host + ISP containers).  Two PCIe functions expose them:
//! the host-facing function sees only the sharable namespace; the
//! Virtual-FW-facing function sees both.

/// Namespace identifier (NSID 0 is invalid per spec).
pub type NamespaceId = u32;

pub const PRIVATE_NS: NamespaceId = 1;
pub const SHARABLE_NS: NamespaceId = 2;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Namespace {
    pub id: NamespaceId,
    /// Capacity in logical blocks (512B units).
    pub lba_count: u64,
    /// Visible to the host-facing PCIe function?
    pub host_visible: bool,
}

impl Namespace {
    pub fn contains(&self, slba: u64, blocks: u64) -> bool {
        slba.checked_add(blocks).is_some_and(|end| end <= self.lba_count)
    }
}

/// The NVMe subsystem: namespace table + visibility rules per function.
#[derive(Clone, Debug)]
pub struct NvmeSubsystem {
    namespaces: Vec<Namespace>,
}

impl NvmeSubsystem {
    /// Standard DockerSSD split: `private_frac` of capacity goes to the
    /// private namespace.
    pub fn standard(total_lbas: u64, private_frac: f64) -> Self {
        assert!((0.0..1.0).contains(&private_frac));
        let private = (total_lbas as f64 * private_frac) as u64;
        NvmeSubsystem {
            namespaces: vec![
                Namespace {
                    id: PRIVATE_NS,
                    lba_count: private,
                    host_visible: false,
                },
                Namespace {
                    id: SHARABLE_NS,
                    lba_count: total_lbas - private,
                    host_visible: true,
                },
            ],
        }
    }

    pub fn get(&self, id: NamespaceId) -> Option<&Namespace> {
        self.namespaces.iter().find(|n| n.id == id)
    }

    /// Namespaces visible through a PCIe function.
    pub fn visible(&self, from_host: bool) -> Vec<&Namespace> {
        self.namespaces
            .iter()
            .filter(|n| !from_host || n.host_visible)
            .collect()
    }

    /// Access check: is `nsid` reachable from this function at all?
    pub fn check_access(&self, nsid: NamespaceId, from_host: bool) -> bool {
        self.get(nsid).is_some_and(|n| !from_host || n.host_visible)
    }

    /// Base offset of a namespace in the flat device LBA space (namespaces
    /// are laid out consecutively in id order).
    pub fn lba_base(&self, nsid: NamespaceId) -> Option<u64> {
        let mut base = 0;
        for n in &self.namespaces {
            if n.id == nsid {
                return Some(base);
            }
            base += n.lba_count;
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_split_partitions_capacity() {
        let s = NvmeSubsystem::standard(1000, 0.3);
        assert_eq!(s.get(PRIVATE_NS).unwrap().lba_count, 300);
        assert_eq!(s.get(SHARABLE_NS).unwrap().lba_count, 700);
    }

    #[test]
    fn host_function_sees_only_sharable() {
        let s = NvmeSubsystem::standard(1000, 0.3);
        let host_view = s.visible(true);
        assert_eq!(host_view.len(), 1);
        assert_eq!(host_view[0].id, SHARABLE_NS);
        let fw_view = s.visible(false);
        assert_eq!(fw_view.len(), 2);
    }

    #[test]
    fn private_ns_denied_to_host() {
        let s = NvmeSubsystem::standard(1000, 0.3);
        assert!(!s.check_access(PRIVATE_NS, true));
        assert!(s.check_access(PRIVATE_NS, false));
        assert!(s.check_access(SHARABLE_NS, true));
        assert!(!s.check_access(99, false)); // unknown nsid
    }

    #[test]
    fn namespace_bounds_check() {
        let n = Namespace {
            id: 1,
            lba_count: 100,
            host_visible: true,
        };
        assert!(n.contains(0, 100));
        assert!(!n.contains(1, 100));
        assert!(!n.contains(u64::MAX, 2)); // overflow safe
    }

    #[test]
    fn lba_bases_are_consecutive() {
        let s = NvmeSubsystem::standard(1000, 0.3);
        assert_eq!(s.lba_base(PRIVATE_NS), Some(0));
        assert_eq!(s.lba_base(SHARABLE_NS), Some(300));
        assert_eq!(s.lba_base(42), None);
    }
}
