//! NVMe command and completion encoding.
//!
//! Commands carry the fields the paper's Figure 6b cares about: opcode,
//! command id, namespace id, PRP1/PRP2 data pointers, and the LBA/length
//! command dwords.  Ether-oN reuses the standard layout with
//! vendor-specific opcodes 0xE0 (transmit frame) / 0xE1 (receive frame).

/// Command identifier, unique per submission queue.
pub type CID = u16;

/// NVMe opcodes used by DockerSSD.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Opcode {
    /// NVM read (0x02).
    Read,
    /// NVM write (0x01).
    Write,
    /// NVM flush (0x00).
    Flush,
    /// Admin identify (0x06).
    Identify,
    /// Ether-oN vendor-specific: host -> SSD Ethernet frame (0xE0).
    TransmitFrame,
    /// Ether-oN vendor-specific: pre-posted upcall slot the SSD completes
    /// to deliver an SSD -> host Ethernet frame (0xE1).
    ReceiveFrame,
}

impl Opcode {
    pub fn to_byte(self) -> u8 {
        match self {
            Opcode::Flush => 0x00,
            Opcode::Write => 0x01,
            Opcode::Read => 0x02,
            Opcode::Identify => 0x06,
            Opcode::TransmitFrame => 0xE0,
            Opcode::ReceiveFrame => 0xE1,
        }
    }

    pub fn from_byte(b: u8) -> Option<Opcode> {
        Some(match b {
            0x00 => Opcode::Flush,
            0x01 => Opcode::Write,
            0x02 => Opcode::Read,
            0x06 => Opcode::Identify,
            0xE0 => Opcode::TransmitFrame,
            0xE1 => Opcode::ReceiveFrame,
            _ => return None,
        })
    }

    pub fn is_vendor(self) -> bool {
        matches!(self, Opcode::TransmitFrame | Opcode::ReceiveFrame)
    }

    pub fn is_io(self) -> bool {
        matches!(self, Opcode::Read | Opcode::Write | Opcode::Flush)
    }
}

/// One submission-queue entry.  `data` stands in for the host kernel page
/// the PRP points to (we carry the bytes inline instead of simulating
/// host-physical addressing).
#[derive(Clone, Debug)]
pub struct NvmeCommand {
    pub cid: CID,
    pub opcode: Opcode,
    pub nsid: u32,
    /// PRP1: 4KB-aligned host page address (simulated).
    pub prp1: u64,
    /// Starting LBA for I/O commands (CDW10/11).
    pub slba: u64,
    /// Number of logical blocks, 0's-based per spec (CDW12).
    pub nlb: u16,
    /// Payload carried by the PRP page (frame bytes for vendor commands,
    /// write data for writes).
    pub data: Vec<u8>,
}

impl NvmeCommand {
    pub fn read(cid: CID, nsid: u32, slba: u64, nlb: u16) -> Self {
        NvmeCommand {
            cid,
            opcode: Opcode::Read,
            nsid,
            prp1: 0,
            slba,
            nlb,
            data: Vec::new(),
        }
    }

    pub fn write(cid: CID, nsid: u32, slba: u64, data: Vec<u8>) -> Self {
        let nlb = ((data.len().max(1) + 511) / 512 - 1) as u16;
        NvmeCommand {
            cid,
            opcode: Opcode::Write,
            nsid,
            prp1: 0,
            slba,
            nlb,
            data,
        }
    }

    /// Ether-oN transmit: the sk_buff copied into a 4KB-aligned kernel page.
    pub fn transmit_frame(cid: CID, page_addr: u64, frame: Vec<u8>) -> Self {
        NvmeCommand {
            cid,
            opcode: Opcode::TransmitFrame,
            nsid: 0,
            prp1: page_addr,
            slba: 0,
            nlb: 0,
            data: frame,
        }
    }

    /// Ether-oN receive: pre-posted with an empty page the device fills.
    pub fn receive_frame(cid: CID, page_addr: u64) -> Self {
        NvmeCommand {
            cid,
            opcode: Opcode::ReceiveFrame,
            nsid: 0,
            prp1: page_addr,
            slba: 0,
            nlb: 0,
            data: Vec::new(),
        }
    }
}

/// Completion status codes (subset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    Success,
    InvalidOpcode,
    InvalidNamespace,
    LbaOutOfRange,
    AccessDenied,
}

/// One completion-queue entry; `data` carries read/upcall payloads back.
#[derive(Clone, Debug)]
pub struct Completion {
    pub cid: CID,
    pub status: Status,
    pub data: Vec<u8>,
}

impl Completion {
    pub fn ok(cid: CID) -> Self {
        Completion {
            cid,
            status: Status::Success,
            data: Vec::new(),
        }
    }

    pub fn ok_with(cid: CID, data: Vec<u8>) -> Self {
        Completion {
            cid,
            status: Status::Success,
            data,
        }
    }

    pub fn err(cid: CID, status: Status) -> Self {
        Completion {
            cid,
            status,
            data: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opcode_bytes_round_trip() {
        for op in [
            Opcode::Read,
            Opcode::Write,
            Opcode::Flush,
            Opcode::Identify,
            Opcode::TransmitFrame,
            Opcode::ReceiveFrame,
        ] {
            assert_eq!(Opcode::from_byte(op.to_byte()), Some(op));
        }
        assert_eq!(Opcode::from_byte(0x7F), None);
    }

    #[test]
    fn vendor_opcodes_in_reserved_range() {
        // the paper reserves 0xE0-0xE1 for Ether-oN
        assert_eq!(Opcode::TransmitFrame.to_byte(), 0xE0);
        assert_eq!(Opcode::ReceiveFrame.to_byte(), 0xE1);
        assert!(Opcode::TransmitFrame.is_vendor());
        assert!(!Opcode::Read.is_vendor());
    }

    #[test]
    fn write_nlb_is_zeros_based_512b_units() {
        let cmd = NvmeCommand::write(1, 1, 0, vec![0u8; 4096]);
        assert_eq!(cmd.nlb, 7); // 8 blocks, 0's based
        let small = NvmeCommand::write(2, 1, 0, vec![0u8; 100]);
        assert_eq!(small.nlb, 0);
    }
}
