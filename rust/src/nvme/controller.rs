//! NVMe controller: fetches commands from SQs, enforces per-function
//! namespace visibility, dispatches block I/O to the backend and
//! vendor frames to the firmware, posts completions + MSI.
//!
//! The controller is generic over two traits so the substrate wiring stays
//! acyclic: [`BlockBackend`] (implemented by `ssd::SsdDevice`) and
//! [`FrameSink`] (implemented by the Virtual-FW network handler).

use crate::util::SimTime;

use super::command::{Completion, NvmeCommand, Opcode, Status};
use super::namespace::NvmeSubsystem;
use super::queue::QueuePair;

/// Backend block service: returns the simulated completion latency.
pub trait BlockBackend {
    fn read(&mut self, at: SimTime, lba: u64, blocks: u64) -> (SimTime, Vec<u8>);
    fn write(&mut self, at: SimTime, lba: u64, data: &[u8]) -> SimTime;
    fn flush(&mut self, at: SimTime) -> SimTime;
}

/// Destination for Ether-oN transmit frames (the device-side network stack).
pub trait FrameSink {
    /// Deliver a host->SSD frame; returns processing latency.
    fn deliver(&mut self, at: SimTime, frame: &[u8]) -> SimTime;
}

/// Which PCIe function a queue pair is attached to (Figure 4b).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PcieFunction {
    /// Host-facing: sharable namespace only.
    Host,
    /// Virtual-FW-facing: private + sharable.
    VirtualFw,
}

impl PcieFunction {
    pub fn is_host(self) -> bool {
        matches!(self, PcieFunction::Host)
    }
}

/// Fixed protocol-level costs (PCIe round trip, doorbell MMIO, MSI).
#[derive(Clone, Copy, Debug)]
pub struct NvmeCosts {
    pub fetch_ns: u64,
    pub completion_ns: u64,
    pub msi_ns: u64,
}

impl Default for NvmeCosts {
    fn default() -> Self {
        NvmeCosts {
            fetch_ns: 400,
            completion_ns: 300,
            msi_ns: 900,
        }
    }
}

/// Control logic for one queue pair.
pub struct NvmeController {
    pub subsystem: NvmeSubsystem,
    pub costs: NvmeCosts,
    /// Upcall slots: pre-posted ReceiveFrame commands held by the device
    /// until an ISP container sends a frame toward the host.
    upcall_slots: Vec<NvmeCommand>,
    pub stats_io: u64,
    pub stats_frames: u64,
    pub stats_upcalls: u64,
}

impl NvmeController {
    pub fn new(subsystem: NvmeSubsystem) -> Self {
        NvmeController {
            subsystem,
            costs: NvmeCosts::default(),
            upcall_slots: Vec::new(),
            stats_io: 0,
            stats_frames: 0,
            stats_upcalls: 0,
        }
    }

    pub fn upcall_slots_free(&self) -> usize {
        self.upcall_slots.len()
    }

    /// Process every pending command in `qp`, using `backend` for block I/O
    /// and `sink` for Ether-oN frames.  Returns the time the last
    /// completion was posted.
    pub fn service_queue<B: BlockBackend, F: FrameSink>(
        &mut self,
        at: SimTime,
        qp: &mut QueuePair,
        function: PcieFunction,
        backend: &mut B,
        sink: &mut F,
    ) -> SimTime {
        let mut now = at;
        while let Some(cmd) = qp.sq.fetch() {
            now += SimTime::ns(self.costs.fetch_ns);
            let completion_time;
            let completion = match cmd.opcode {
                Opcode::Read => {
                    if !self.subsystem.check_access(cmd.nsid, function.is_host()) {
                        completion_time = now;
                        Completion::err(cmd.cid, Status::AccessDenied)
                    } else {
                        let ns = self.subsystem.get(cmd.nsid).unwrap();
                        let blocks = cmd.nlb as u64 + 1;
                        if !ns.contains(cmd.slba, blocks) {
                            completion_time = now;
                            Completion::err(cmd.cid, Status::LbaOutOfRange)
                        } else {
                            let base = self.subsystem.lba_base(cmd.nsid).unwrap();
                            let (done, data) = backend.read(now, base + cmd.slba, blocks);
                            self.stats_io += 1;
                            completion_time = done;
                            Completion::ok_with(cmd.cid, data)
                        }
                    }
                }
                Opcode::Write => {
                    if !self.subsystem.check_access(cmd.nsid, function.is_host()) {
                        completion_time = now;
                        Completion::err(cmd.cid, Status::AccessDenied)
                    } else {
                        let ns = self.subsystem.get(cmd.nsid).unwrap();
                        let blocks = cmd.nlb as u64 + 1;
                        if !ns.contains(cmd.slba, blocks) {
                            completion_time = now;
                            Completion::err(cmd.cid, Status::LbaOutOfRange)
                        } else {
                            let base = self.subsystem.lba_base(cmd.nsid).unwrap();
                            let done = backend.write(now, base + cmd.slba, &cmd.data);
                            self.stats_io += 1;
                            completion_time = done;
                            Completion::ok(cmd.cid)
                        }
                    }
                }
                Opcode::Flush => {
                    let done = backend.flush(now);
                    self.stats_io += 1;
                    completion_time = done;
                    Completion::ok(cmd.cid)
                }
                Opcode::Identify => {
                    let visible = self.subsystem.visible(function.is_host());
                    let mut data = Vec::new();
                    for ns in visible {
                        data.extend_from_slice(&ns.id.to_le_bytes());
                        data.extend_from_slice(&ns.lba_count.to_le_bytes());
                    }
                    completion_time = now;
                    Completion::ok_with(cmd.cid, data)
                }
                Opcode::TransmitFrame => {
                    let done = now + sink.deliver(now, &cmd.data);
                    self.stats_frames += 1;
                    completion_time = done;
                    Completion::ok(cmd.cid)
                }
                Opcode::ReceiveFrame => {
                    // Held open: the device keeps the slot until an
                    // ISP-container emits a frame toward the host.
                    self.upcall_slots.push(cmd);
                    continue;
                }
            };
            now = completion_time + SimTime::ns(self.costs.completion_ns + self.costs.msi_ns);
            // CQ full would stall the device; treat as fatal in the model.
            qp.cq.post(completion).expect("completion queue overflow");
        }
        now
    }

    /// Device-side upcall: complete a held ReceiveFrame slot with `frame`.
    /// Returns false when no slot is available (the SSD must wait — this is
    /// exactly the flow-control the paper sizes at 4 slots/SQ).
    pub fn upcall(&mut self, qp: &mut QueuePair, frame: Vec<u8>) -> bool {
        let Some(slot) = self.upcall_slots.pop() else {
            return false;
        };
        self.stats_upcalls += 1;
        qp.cq
            .post(Completion::ok_with(slot.cid, frame))
            .expect("completion queue overflow");
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nvme::namespace::{NvmeSubsystem, PRIVATE_NS, SHARABLE_NS};

    struct MemBackend {
        store: std::collections::HashMap<u64, Vec<u8>>,
        lat: SimTime,
    }

    impl MemBackend {
        fn new() -> Self {
            MemBackend {
                store: Default::default(),
                lat: SimTime::us(10),
            }
        }
    }

    impl BlockBackend for MemBackend {
        fn read(&mut self, at: SimTime, lba: u64, blocks: u64) -> (SimTime, Vec<u8>) {
            let mut out = Vec::new();
            for b in 0..blocks {
                out.extend(
                    self.store
                        .get(&(lba + b))
                        .cloned()
                        .unwrap_or_else(|| vec![0u8; 512]),
                );
            }
            (at + self.lat, out)
        }
        fn write(&mut self, at: SimTime, lba: u64, data: &[u8]) -> SimTime {
            for (i, chunk) in data.chunks(512).enumerate() {
                self.store.insert(lba + i as u64, chunk.to_vec());
            }
            at + self.lat
        }
        fn flush(&mut self, at: SimTime) -> SimTime {
            at
        }
    }

    struct NullSink(u64);
    impl FrameSink for NullSink {
        fn deliver(&mut self, _at: SimTime, _frame: &[u8]) -> SimTime {
            self.0 += 1;
            SimTime::us(1)
        }
    }

    fn setup() -> (NvmeController, QueuePair, MemBackend, NullSink) {
        let sub = NvmeSubsystem::standard(10_000, 0.3);
        (
            NvmeController::new(sub),
            QueuePair::new(1, 16),
            MemBackend::new(),
            NullSink(0),
        )
    }

    #[test]
    fn write_then_read_round_trips() {
        let (mut ctl, mut qp, mut be, mut sink) = setup();
        let payload = vec![0xAB; 1024];
        qp.sq
            .submit(NvmeCommand::write(1, SHARABLE_NS, 10, payload.clone()))
            .unwrap();
        qp.sq.submit(NvmeCommand::read(2, SHARABLE_NS, 10, 1)).unwrap();
        ctl.service_queue(SimTime::ZERO, &mut qp, PcieFunction::Host, &mut be, &mut sink);
        let w = qp.cq.reap().unwrap();
        assert_eq!(w.status, Status::Success);
        let r = qp.cq.reap().unwrap();
        assert_eq!(r.status, Status::Success);
        assert_eq!(&r.data[..1024], &payload[..]);
    }

    #[test]
    fn host_cannot_touch_private_namespace() {
        let (mut ctl, mut qp, mut be, mut sink) = setup();
        qp.sq.submit(NvmeCommand::read(1, PRIVATE_NS, 0, 0)).unwrap();
        ctl.service_queue(SimTime::ZERO, &mut qp, PcieFunction::Host, &mut be, &mut sink);
        assert_eq!(qp.cq.reap().unwrap().status, Status::AccessDenied);
        // but the Virtual-FW function can
        qp.sq.submit(NvmeCommand::read(2, PRIVATE_NS, 0, 0)).unwrap();
        ctl.service_queue(SimTime::ZERO, &mut qp, PcieFunction::VirtualFw, &mut be, &mut sink);
        assert_eq!(qp.cq.reap().unwrap().status, Status::Success);
    }

    #[test]
    fn lba_out_of_range_rejected() {
        let (mut ctl, mut qp, mut be, mut sink) = setup();
        qp.sq
            .submit(NvmeCommand::read(1, SHARABLE_NS, 6_999, 1))
            .unwrap();
        ctl.service_queue(SimTime::ZERO, &mut qp, PcieFunction::Host, &mut be, &mut sink);
        assert_eq!(qp.cq.reap().unwrap().status, Status::LbaOutOfRange);
    }

    #[test]
    fn transmit_frame_reaches_sink() {
        let (mut ctl, mut qp, mut be, mut sink) = setup();
        qp.sq
            .submit(NvmeCommand::transmit_frame(5, 0x1000, vec![1, 2, 3]))
            .unwrap();
        ctl.service_queue(SimTime::ZERO, &mut qp, PcieFunction::Host, &mut be, &mut sink);
        assert_eq!(sink.0, 1);
        assert_eq!(qp.cq.reap().unwrap().status, Status::Success);
    }

    #[test]
    fn receive_frames_are_held_then_completed_by_upcall() {
        let (mut ctl, mut qp, mut be, mut sink) = setup();
        // pre-post 4 upcall slots, as the Ether-oN driver does at init
        for cid in 10..14 {
            qp.sq
                .submit(NvmeCommand::receive_frame(cid, 0x2000))
                .unwrap();
        }
        ctl.service_queue(SimTime::ZERO, &mut qp, PcieFunction::Host, &mut be, &mut sink);
        assert!(qp.cq.is_empty(), "receive frames must not complete eagerly");
        assert_eq!(ctl.upcall_slots_free(), 4);

        assert!(ctl.upcall(&mut qp, vec![9, 9]));
        let c = qp.cq.reap().unwrap();
        assert_eq!(c.data, vec![9, 9]);
        assert_eq!(ctl.upcall_slots_free(), 3);
    }

    #[test]
    fn upcall_without_slots_is_backpressured() {
        let (mut ctl, mut qp, _, _) = setup();
        assert!(!ctl.upcall(&mut qp, vec![1]));
    }

    #[test]
    fn namespace_isolation_lba_bases_do_not_alias() {
        // writes to private and sharable at the same relative LBA must not collide
        let (mut ctl, mut qp, mut be, mut sink) = setup();
        qp.sq
            .submit(NvmeCommand::write(1, PRIVATE_NS, 5, vec![0x11; 512]))
            .unwrap();
        qp.sq
            .submit(NvmeCommand::write(2, SHARABLE_NS, 5, vec![0x22; 512]))
            .unwrap();
        qp.sq.submit(NvmeCommand::read(3, PRIVATE_NS, 5, 0)).unwrap();
        qp.sq.submit(NvmeCommand::read(4, SHARABLE_NS, 5, 0)).unwrap();
        ctl.service_queue(
            SimTime::ZERO,
            &mut qp,
            PcieFunction::VirtualFw,
            &mut be,
            &mut sink,
        );
        qp.cq.reap();
        qp.cq.reap();
        assert_eq!(qp.cq.reap().unwrap().data[0], 0x11);
        assert_eq!(qp.cq.reap().unwrap().data[0], 0x22);
    }
}
