//! NVMe subsystem simulation (DESIGN.md S1).
//!
//! Models exactly the protocol surface DockerSSD builds on: paired
//! submission/completion queues with doorbells, PRP-addressed 4KB pages,
//! MSI completion signalling, namespaces exposed through two PCIe
//! functions (host-facing: sharable-NS only; Virtual-FW-facing: private +
//! sharable), and the two vendor-specific opcodes (0xE0/0xE1) Ether-oN
//! adds for transmit/receive frames.

pub mod command;
pub mod controller;
pub mod namespace;
pub mod queue;

pub use command::{Completion, NvmeCommand, Opcode, Status, CID};
pub use controller::{BlockBackend, FrameSink, NvmeController, PcieFunction};
pub use namespace::{Namespace, NamespaceId, NvmeSubsystem};
pub use queue::{CompletionQueue, QueuePair, SubmissionQueue};
