//! Submission/completion queue rings with doorbells.
//!
//! Ring semantics follow the spec closely enough to expose the properties
//! the paper relies on: bounded depth (backpressure for Ether-oN upcalls),
//! FIFO fetch order, head/tail doorbells, and MSI-style completion
//! notification (modeled as a counter the driver polls).

use std::collections::VecDeque;

use super::command::{Completion, NvmeCommand};

/// Fixed-depth submission queue.  The host writes entries at the tail and
/// rings the tail doorbell; the controller fetches from the head.
#[derive(Debug)]
pub struct SubmissionQueue {
    depth: usize,
    ring: VecDeque<NvmeCommand>,
    /// Tail doorbell writes observed (for stats/debug).
    pub doorbell_writes: u64,
}

impl SubmissionQueue {
    pub fn new(depth: usize) -> Self {
        assert!(depth >= 2, "spec requires depth >= 2");
        SubmissionQueue {
            depth,
            ring: VecDeque::with_capacity(depth),
            doorbell_writes: 0,
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.ring.len() == self.depth
    }

    /// Submit an entry and ring the doorbell. Errors when the ring is full
    /// (the driver must back off — this is the backpressure path).
    pub fn submit(&mut self, cmd: NvmeCommand) -> Result<(), NvmeCommand> {
        if self.is_full() {
            return Err(cmd);
        }
        self.ring.push_back(cmd);
        self.doorbell_writes += 1;
        Ok(())
    }

    /// Controller-side fetch from the head.
    pub fn fetch(&mut self) -> Option<NvmeCommand> {
        self.ring.pop_front()
    }
}

/// Fixed-depth completion queue with an MSI counter.
#[derive(Debug)]
pub struct CompletionQueue {
    depth: usize,
    ring: VecDeque<Completion>,
    /// Message-signaled interrupts raised (one per posted completion).
    pub msi_count: u64,
}

impl CompletionQueue {
    pub fn new(depth: usize) -> Self {
        CompletionQueue {
            depth,
            ring: VecDeque::with_capacity(depth),
            msi_count: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.ring.len() == self.depth
    }

    /// Controller posts a completion and raises MSI.
    pub fn post(&mut self, c: Completion) -> Result<(), Completion> {
        if self.is_full() {
            return Err(c);
        }
        self.ring.push_back(c);
        self.msi_count += 1;
        Ok(())
    }

    /// Driver reaps the next completion (head doorbell implied).
    pub fn reap(&mut self) -> Option<Completion> {
        self.ring.pop_front()
    }
}

/// A paired SQ/CQ as created per core by the NVMe driver.
#[derive(Debug)]
pub struct QueuePair {
    pub sq: SubmissionQueue,
    pub cq: CompletionQueue,
    pub id: u16,
}

impl QueuePair {
    pub fn new(id: u16, depth: usize) -> Self {
        QueuePair {
            sq: SubmissionQueue::new(depth),
            cq: CompletionQueue::new(depth),
            id,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nvme::command::{NvmeCommand, Status};

    #[test]
    fn sq_is_fifo() {
        let mut sq = SubmissionQueue::new(8);
        for i in 0..5u16 {
            sq.submit(NvmeCommand::read(i, 1, i as u64, 0)).unwrap();
        }
        for i in 0..5u16 {
            assert_eq!(sq.fetch().unwrap().cid, i);
        }
        assert!(sq.fetch().is_none());
    }

    #[test]
    fn sq_full_applies_backpressure() {
        let mut sq = SubmissionQueue::new(2);
        sq.submit(NvmeCommand::read(0, 1, 0, 0)).unwrap();
        sq.submit(NvmeCommand::read(1, 1, 0, 0)).unwrap();
        let rejected = sq.submit(NvmeCommand::read(2, 1, 0, 0));
        assert!(rejected.is_err());
        assert_eq!(rejected.unwrap_err().cid, 2);
        // draining frees a slot
        sq.fetch();
        assert!(sq.submit(NvmeCommand::read(3, 1, 0, 0)).is_ok());
    }

    #[test]
    fn doorbell_counts_submissions() {
        let mut sq = SubmissionQueue::new(4);
        for i in 0..3u16 {
            sq.submit(NvmeCommand::read(i, 1, 0, 0)).unwrap();
        }
        assert_eq!(sq.doorbell_writes, 3);
    }

    #[test]
    fn cq_raises_msi_per_completion() {
        let mut cq = CompletionQueue::new(4);
        cq.post(Completion::ok(7)).unwrap();
        cq.post(Completion::err(8, Status::LbaOutOfRange)).unwrap();
        assert_eq!(cq.msi_count, 2);
        assert_eq!(cq.reap().unwrap().cid, 7);
        let c = cq.reap().unwrap();
        assert_eq!(c.cid, 8);
        assert_eq!(c.status, Status::LbaOutOfRange);
    }

    #[test]
    #[should_panic]
    fn sq_depth_must_be_at_least_two() {
        SubmissionQueue::new(1);
    }
}
