//! The pool-wide simulation core: one deterministic event-driven clock
//! shared by the SSD backend, NVMe controller, firmware timing models,
//! the message fabric, and the serving coordinator.
//!
//! The simulator is synchronous and deterministic: events are (time, seq,
//! tag) tuples popped in order; components advance per-resource
//! `busy_until` clocks.  Tags are opaque u64s interpreted by the caller —
//! substrates that need richer payloads keep a side table keyed by tag.
//! The [`tag`]/[`tag_kind`]/[`tag_payload`] helpers carve a one-byte
//! kind out of the tag space for callers multiplexing several event
//! kinds on one queue (the serve loop does).
//!
//! [`PoolSim`] bundles the three pool-wide resources every timing
//! consumer shares: the event queue (the clock), the contention-aware
//! [`Fabric`], and one [`BusyResource`] of compute per DockerSSD.  A
//! subsystem that prices time against anything else in the pool takes a
//! `&mut PoolSim` (or its fabric) instead of keeping a private clock —
//! that is what makes two runs with the same seed produce byte-identical
//! schedules.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::config::{EtherOnConfig, PoolConfig, SystemConfig};
use crate::fabric::Fabric;
use crate::metrics::{names, Counters};
use crate::pool::devices::FtlBank;
use crate::util::SimTime;

/// A scheduled event: fires at `at`, carries an opaque `tag`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub at: SimTime,
    pub seq: u64,
    pub tag: u64,
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap by (time, insertion seq) via Reverse at the queue level
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Pack a one-byte event kind and a 56-bit payload into an event tag.
pub fn tag(kind: u8, payload: u64) -> u64 {
    ((kind as u64) << 56) | (payload & ((1 << 56) - 1))
}

/// The kind byte of a tag built by [`tag`].
pub fn tag_kind(t: u64) -> u8 {
    (t >> 56) as u8
}

/// The payload bits of a tag built by [`tag`].
pub fn tag_payload(t: u64) -> u64 {
    t & ((1 << 56) - 1)
}

/// Nanoseconds covered by one calendar bucket (as a shift amount).
const BUCKET_BITS: u32 = 12; // 4096 ns
/// Ring size; together with [`BUCKET_BITS`] this spans ~4.2 ms.
const NUM_BUCKETS: usize = 1024;
/// Nanoseconds covered by one bucket.
const BUCKET_QUANTUM: u64 = 1 << BUCKET_BITS;
/// Nanoseconds covered by the whole ring.
const RING_SPAN: u64 = (NUM_BUCKETS as u64) << BUCKET_BITS;

/// Deterministic event queue with a monotonically advancing clock.
///
/// Implemented as a calendar queue: a ring of [`NUM_BUCKETS`] buckets of
/// [`BUCKET_QUANTUM`] ns each, with a [`BinaryHeap`] overflow for events
/// beyond the ring's horizon.  Each bucket keeps its events sorted
/// ascending by `(at, seq)` (inserts are `partition_point` + usually a
/// tail push, pops are `pop_front`), which preserves the exact total
/// order the old single-heap implementation produced — FIFO within a
/// timestamp, globally ordered by time.  Overflow events migrate into
/// the ring as the ring's base advances past their quantum, so outside
/// of `pop` the invariant holds: every overflow event fires at or after
/// `base + RING_SPAN`, strictly later than every ring event.
pub struct EventQueue {
    buckets: Vec<VecDeque<Event>>,
    /// Ring index of the bucket whose quantum starts at `base`.
    cursor: usize,
    /// Quantum-aligned lower bound (ns) of the bucket at `cursor`.
    /// Advances only as pops drain buckets — deliberately decoupled from
    /// `now`, which `advance_to` can move without touching the ring.
    base: u64,
    /// Events currently in the ring (across all buckets).
    ring_len: usize,
    /// Events at or beyond `base + RING_SPAN`.
    overflow: BinaryHeap<Reverse<Event>>,
    now: SimTime,
    next_seq: u64,
    processed: u64,
    clamped: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        EventQueue {
            buckets: vec![VecDeque::new(); NUM_BUCKETS],
            cursor: 0,
            base: 0,
            ring_len: 0,
            overflow: BinaryHeap::new(),
            now: SimTime::ZERO,
            next_seq: 0,
            processed: 0,
            clamped: 0,
        }
    }
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn len(&self) -> usize {
        self.ring_len + self.overflow.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Events whose requested time was in the past and got clamped to
    /// `now` (see [`EventQueue::schedule_at`]).
    pub fn clamped(&self) -> u64 {
        self.clamped
    }

    /// Schedule `tag` to fire `delay` after the current time.
    pub fn schedule_in(&mut self, delay: SimTime, tag: u64) {
        self.schedule_at(self.now + delay, tag);
    }

    /// Schedule `tag` at an absolute time.  Scheduling into the past
    /// cannot be honored on a monotonic clock; rather than corrupting
    /// event order (or silently relying on a debug-only assert), the
    /// event is clamped to `now` and counted in
    /// [`EventQueue::clamped`] / the `sim.clamped_events` counter.
    pub fn schedule_at(&mut self, at: SimTime, tag: u64) {
        let at = if at < self.now {
            self.clamped += 1;
            self.now
        } else {
            at
        };
        let ev = Event {
            at,
            seq: self.next_seq,
            tag,
        };
        self.next_seq += 1;
        self.insert(ev);
    }

    /// Place an event into its calendar bucket (or the overflow heap).
    fn insert(&mut self, ev: Event) {
        let at_ns = ev.at.as_ns();
        if at_ns >= self.base + RING_SPAN {
            self.overflow.push(Reverse(ev));
            return;
        }
        // `at_ns >= base` always holds: unclamped events fire at or
        // after `now >= base`, clamped ones exactly at `now`, and
        // migrated overflow events at or after their old horizon.
        // Within [base, base + RING_SPAN) each quantum owns one slot,
        // so absolute slot indexing cannot alias two quanta.
        let slot = ((at_ns >> BUCKET_BITS) as usize) % NUM_BUCKETS;
        let bucket = &mut self.buckets[slot];
        let key = (ev.at, ev.seq);
        if bucket.back().is_none_or(|b| (b.at, b.seq) < key) {
            bucket.push_back(ev);
        } else {
            let i = bucket.partition_point(|e| (e.at, e.seq) < key);
            bucket.insert(i, ev);
        }
        self.ring_len += 1;
    }

    /// Move overflow events whose quantum now falls inside the ring's
    /// horizon into their buckets.
    fn migrate_overflow(&mut self) {
        let horizon = self.base + RING_SPAN;
        while let Some(Reverse(ev)) = self.overflow.peek() {
            if ev.at.as_ns() >= horizon {
                break;
            }
            let Reverse(ev) = self.overflow.pop().unwrap();
            self.insert(ev);
        }
    }

    /// The firing time of the next event without popping it.
    pub fn peek_at(&self) -> Option<SimTime> {
        if self.ring_len == 0 {
            return self.overflow.peek().map(|Reverse(ev)| ev.at);
        }
        // Ring events always fire before overflow events (the horizon
        // invariant), and the first nonempty bucket from the cursor
        // holds the earliest quantum; its front is the (at, seq) min.
        let mut slot = self.cursor;
        loop {
            if let Some(ev) = self.buckets[slot].front() {
                return Some(ev.at);
            }
            slot = (slot + 1) % NUM_BUCKETS;
        }
    }

    /// Pop the next event, advancing the clock to its time.
    pub fn pop(&mut self) -> Option<Event> {
        if self.ring_len == 0 {
            let Reverse(next) = self.overflow.peek()?;
            // The ring is idle: rebase it onto the earliest overflow
            // quantum, then pull that quantum's events in.
            let at_ns = next.at.as_ns();
            self.base = (at_ns >> BUCKET_BITS) << BUCKET_BITS;
            self.cursor = ((at_ns >> BUCKET_BITS) as usize) % NUM_BUCKETS;
        }
        self.migrate_overflow();
        while self.buckets[self.cursor].is_empty() {
            self.cursor = (self.cursor + 1) % NUM_BUCKETS;
            self.base += BUCKET_QUANTUM;
            // Advancing the horizon may make far-future events eligible.
            self.migrate_overflow();
        }
        let ev = self.buckets[self.cursor].pop_front().unwrap();
        self.ring_len -= 1;
        debug_assert!(ev.at >= self.now);
        self.now = ev.at;
        self.processed += 1;
        Some(ev)
    }

    /// Advance the clock directly (for components that compute latencies
    /// analytically rather than via events).
    pub fn advance_to(&mut self, t: SimTime) {
        if t > self.now {
            self.now = t;
        }
    }

    pub fn export_counters(&self, c: &mut Counters) {
        c.add(names::SIM_CLAMPED_EVENTS, self.clamped);
        c.add(names::SIM_EVENTS_PROCESSED, self.processed);
    }
}

/// A resource that serializes work: requests queue behind `busy_until`.
/// Models a flash channel, an embedded core, a PCIe link, ...
#[derive(Clone, Copy, Debug, Default)]
pub struct BusyResource {
    pub busy_until: SimTime,
    pub busy_total: SimTime,
    pub served: u64,
}

impl BusyResource {
    /// Occupy the resource for `dur` starting no earlier than `at`.
    /// Returns the completion time.
    pub fn occupy(&mut self, at: SimTime, dur: SimTime) -> SimTime {
        let start = at.max(self.busy_until);
        let end = start + dur;
        self.busy_until = end;
        self.busy_total += dur;
        self.served += 1;
        end
    }

    /// Utilization over `[0, horizon]`.
    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon == SimTime::ZERO {
            return 0.0;
        }
        self.busy_total.as_ns() as f64 / horizon.as_ns() as f64
    }
}

/// The pool-wide simulation: one clock (the event queue), the shared
/// message fabric, and one compute resource per DockerSSD.
///
/// Everything that used to live in a private time domain — the fabric's
/// busy-until arithmetic, `coordinator::serve`'s wallclock threads,
/// `MiniDocker::pull`'s device-only packet costs — now prices its time
/// against this one structure, so cross-subsystem contention (a docker
/// pull delaying an LLM collective, a KV migration queuing behind a
/// layer prefetch) is visible instead of assumed away.
pub struct PoolSim {
    /// The clock: every event in the pool pops from here in time order.
    pub queue: EventQueue,
    /// The shared wire: every cross-node/host/WAN byte crosses it.
    pub fabric: Fabric,
    /// Per-node flash-write ledgers: every byte class that lands on a
    /// node's device charges its FTL here (`ftl.*` counters).
    pub ftls: FtlBank,
    /// Per-node compute (batch execution, ISP work).
    compute: Vec<BusyResource>,
}

impl PoolSim {
    pub fn new(cfg: &SystemConfig) -> Self {
        Self::with_pool(&cfg.pool, &cfg.etheron)
    }

    pub fn with_pool(pool: &PoolConfig, etheron: &EtherOnConfig) -> Self {
        PoolSim {
            queue: EventQueue::new(),
            fabric: Fabric::new(pool, etheron),
            ftls: FtlBank::default(),
            compute: vec![BusyResource::default(); pool.total_nodes() as usize],
        }
    }

    pub fn now(&self) -> SimTime {
        self.queue.now()
    }

    pub fn nodes(&self) -> usize {
        self.compute.len()
    }

    /// Node `node`'s compute resource, growing the pool if a caller
    /// serves from more nodes than the config declared.
    pub fn compute_mut(&mut self, node: u32) -> &mut BusyResource {
        let idx = node as usize;
        if idx >= self.compute.len() {
            self.compute.resize(idx + 1, BusyResource::default());
        }
        &mut self.compute[idx]
    }

    pub fn compute(&self, node: u32) -> Option<&BusyResource> {
        self.compute.get(node as usize)
    }

    pub fn export_counters(&self, c: &mut Counters) {
        self.queue.export_counters(c);
        self.fabric.export_counters(c);
        self.ftls.export_counters(c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::ns(30), 3);
        q.schedule_at(SimTime::ns(10), 1);
        q.schedule_at(SimTime::ns(20), 2);
        assert_eq!(q.peek_at(), Some(SimTime::ns(10)));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|e| e.tag).collect();
        assert_eq!(order, vec![1, 2, 3]);
        assert_eq!(q.now(), SimTime::ns(30));
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for tag in 0..10 {
            q.schedule_at(SimTime::ns(5), tag);
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|e| e.tag).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn schedule_in_is_relative_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::ns(100), 1);
        q.pop();
        q.schedule_in(SimTime::ns(50), 2);
        let e = q.pop().unwrap();
        assert_eq!(e.at, SimTime::ns(150));
    }

    #[test]
    fn past_scheduling_clamps_to_now_and_counts() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::ns(100), 1);
        q.pop();
        assert_eq!(q.now(), SimTime::ns(100));
        q.schedule_at(SimTime::ns(40), 2); // in the past: clamped
        assert_eq!(q.clamped(), 1);
        let e = q.pop().unwrap();
        assert_eq!(e.at, SimTime::ns(100), "clamped to now, not reordered");
        let mut c = Counters::new();
        q.export_counters(&mut c);
        assert_eq!(c.get(names::SIM_CLAMPED_EVENTS), 1);
    }

    #[test]
    fn far_future_events_overflow_and_pop_in_order() {
        let mut q = EventQueue::new();
        // Beyond the ~4.2ms ring horizon: lands in the overflow heap.
        q.schedule_at(SimTime::ms(50), 4);
        q.schedule_at(SimTime::ns(10), 1);
        q.schedule_at(SimTime::ms(5), 3);
        q.schedule_at(SimTime::ns(20), 2);
        assert_eq!(q.len(), 4);
        assert_eq!(q.peek_at(), Some(SimTime::ns(10)));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|e| e.tag).collect();
        assert_eq!(order, vec![1, 2, 3, 4]);
        assert_eq!(q.now(), SimTime::ms(50));
        assert_eq!(q.processed(), 4);
    }

    #[test]
    fn ring_wraps_across_many_horizons() {
        let mut q = EventQueue::new();
        // 40 events 1ms apart cover ~10 ring spans; schedule reversed.
        for i in (0..40u64).rev() {
            q.schedule_at(SimTime::ms(i), i);
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|e| e.tag).collect();
        assert_eq!(order, (0..40).collect::<Vec<_>>());
        // the ring rebases cleanly for a burst after a long idle gap
        q.schedule_at(SimTime::ms(400), 100);
        q.schedule_at(SimTime::ms(400), 101);
        assert_eq!(q.peek_at(), Some(SimTime::ms(400)));
        assert_eq!(q.pop().unwrap().tag, 100);
        assert_eq!(q.pop().unwrap().tag, 101);
    }

    #[test]
    fn insertion_into_partially_drained_bucket_keeps_fifo() {
        let mut q = EventQueue::new();
        for tag in 0..5 {
            q.schedule_at(SimTime::ns(5), tag);
        }
        assert_eq!(q.pop().unwrap().tag, 0);
        assert_eq!(q.pop().unwrap().tag, 1);
        // same timestamp, scheduled mid-drain: fires after the rest
        q.schedule_at(SimTime::ns(5), 99);
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|e| e.tag).collect();
        assert_eq!(order, vec![2, 3, 4, 99]);
    }

    #[test]
    fn dense_random_schedule_pops_in_total_order() {
        let mut q = EventQueue::new();
        let mut state = 0x2545_F491_4F6C_DD1Du64;
        for _ in 0..5000 {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            // cluster within ~20ms so ring, overflow and wrap all engage
            q.schedule_at(SimTime::ns(state % 20_000_000), state % 1000);
        }
        let popped: Vec<Event> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(popped.len(), 5000);
        for w in popped.windows(2) {
            assert!((w[0].at, w[0].seq) < (w[1].at, w[1].seq), "total (time, seq) order");
        }
    }

    #[test]
    fn tag_helpers_round_trip() {
        let t = tag(7, 0x00AB_CDEF_1234);
        assert_eq!(tag_kind(t), 7);
        assert_eq!(tag_payload(t), 0x00AB_CDEF_1234);
        assert_eq!(tag_kind(tag(255, 0)), 255);
    }

    #[test]
    fn busy_resource_serializes() {
        let mut r = BusyResource::default();
        let e1 = r.occupy(SimTime::ns(0), SimTime::ns(100));
        assert_eq!(e1, SimTime::ns(100));
        // arrives at t=50 but the resource is busy until 100
        let e2 = r.occupy(SimTime::ns(50), SimTime::ns(100));
        assert_eq!(e2, SimTime::ns(200));
        // arrives after idle period
        let e3 = r.occupy(SimTime::ns(500), SimTime::ns(10));
        assert_eq!(e3, SimTime::ns(510));
        assert_eq!(r.served, 3);
        assert_eq!(r.busy_total, SimTime::ns(210));
    }

    #[test]
    fn utilization_fraction() {
        let mut r = BusyResource::default();
        r.occupy(SimTime::ZERO, SimTime::ns(250));
        assert!((r.utilization(SimTime::ns(1000)) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn pool_sim_bundles_clock_fabric_compute() {
        let cfg = SystemConfig::default();
        let mut sim = PoolSim::new(&cfg);
        assert_eq!(sim.nodes(), 16);
        assert_eq!(sim.now(), SimTime::ZERO);
        let end = sim.compute_mut(3).occupy(SimTime::us(1), SimTime::us(4));
        assert_eq!(end, SimTime::us(5));
        // compute grows on demand for oversized serving setups
        sim.compute_mut(40).occupy(SimTime::ZERO, SimTime::us(1));
        assert!(sim.nodes() >= 41);
        // the fabric rides the same struct
        use crate::fabric::{Endpoint, Priority};
        let r = sim.fabric.transfer(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            4096,
            Priority::Foreground,
        );
        assert!(r.finish > SimTime::ZERO);
        let mut c = Counters::new();
        sim.export_counters(&mut c);
        assert!(c.get(names::FABRIC_TRANSFERS) == 1);
    }
}
