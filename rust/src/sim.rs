//! Minimal discrete-event simulation core shared by the SSD backend, NVMe
//! controller, and firmware timing models.
//!
//! The simulator is synchronous and deterministic: events are (time, seq,
//! tag) tuples popped in order; components advance per-resource
//! `busy_until` clocks.  Tags are opaque u64s interpreted by the caller —
//! substrates that need richer payloads keep a side table keyed by tag.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::util::SimTime;

/// A scheduled event: fires at `at`, carries an opaque `tag`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub at: SimTime,
    pub seq: u64,
    pub tag: u64,
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap by (time, insertion seq) via Reverse at the queue level
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic event queue with a monotonically advancing clock.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Event>>,
    now: SimTime,
    next_seq: u64,
    processed: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `tag` to fire `delay` after the current time.
    pub fn schedule_in(&mut self, delay: SimTime, tag: u64) {
        self.schedule_at(self.now + delay, tag);
    }

    /// Schedule `tag` at an absolute time (must not be in the past).
    pub fn schedule_at(&mut self, at: SimTime, tag: u64) {
        debug_assert!(at >= self.now, "scheduling into the past");
        let ev = Event {
            at,
            seq: self.next_seq,
            tag,
        };
        self.next_seq += 1;
        self.heap.push(Reverse(ev));
    }

    /// Pop the next event, advancing the clock to its time.
    pub fn pop(&mut self) -> Option<Event> {
        let Reverse(ev) = self.heap.pop()?;
        debug_assert!(ev.at >= self.now);
        self.now = ev.at;
        self.processed += 1;
        Some(ev)
    }

    /// Advance the clock directly (for components that compute latencies
    /// analytically rather than via events).
    pub fn advance_to(&mut self, t: SimTime) {
        if t > self.now {
            self.now = t;
        }
    }
}

/// A resource that serializes work: requests queue behind `busy_until`.
/// Models a flash channel, an embedded core, a PCIe link, ...
#[derive(Clone, Copy, Debug, Default)]
pub struct BusyResource {
    pub busy_until: SimTime,
    pub busy_total: SimTime,
    pub served: u64,
}

impl BusyResource {
    /// Occupy the resource for `dur` starting no earlier than `at`.
    /// Returns the completion time.
    pub fn occupy(&mut self, at: SimTime, dur: SimTime) -> SimTime {
        let start = at.max(self.busy_until);
        let end = start + dur;
        self.busy_until = end;
        self.busy_total += dur;
        self.served += 1;
        end
    }

    /// Utilization over `[0, horizon]`.
    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon == SimTime::ZERO {
            return 0.0;
        }
        self.busy_total.as_ns() as f64 / horizon.as_ns() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::ns(30), 3);
        q.schedule_at(SimTime::ns(10), 1);
        q.schedule_at(SimTime::ns(20), 2);
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|e| e.tag).collect();
        assert_eq!(order, vec![1, 2, 3]);
        assert_eq!(q.now(), SimTime::ns(30));
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for tag in 0..10 {
            q.schedule_at(SimTime::ns(5), tag);
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|e| e.tag).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn schedule_in_is_relative_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::ns(100), 1);
        q.pop();
        q.schedule_in(SimTime::ns(50), 2);
        let e = q.pop().unwrap();
        assert_eq!(e.at, SimTime::ns(150));
    }

    #[test]
    fn busy_resource_serializes() {
        let mut r = BusyResource::default();
        let e1 = r.occupy(SimTime::ns(0), SimTime::ns(100));
        assert_eq!(e1, SimTime::ns(100));
        // arrives at t=50 but the resource is busy until 100
        let e2 = r.occupy(SimTime::ns(50), SimTime::ns(100));
        assert_eq!(e2, SimTime::ns(200));
        // arrives after idle period
        let e3 = r.occupy(SimTime::ns(500), SimTime::ns(10));
        assert_eq!(e3, SimTime::ns(510));
        assert_eq!(r.served, 3);
        assert_eq!(r.busy_total, SimTime::ns(210));
    }

    #[test]
    fn utilization_fraction() {
        let mut r = BusyResource::default();
        r.occupy(SimTime::ZERO, SimTime::ns(250));
        assert!((r.utilization(SimTime::ns(1000)) - 0.25).abs() < 1e-9);
    }
}
