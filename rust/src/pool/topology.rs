//! Pool topology: arrays of DockerSSDs behind PCIe switches, integrated
//! into a cluster by a switch tray (Figure 8a).  Ether-oN assigns each
//! node an IP on the intranet regardless of PCIe position.

use std::net::Ipv4Addr;

use crate::config::PoolConfig;
use crate::etheron::MacAddr;

pub type NodeId = u32;

/// One DockerSSD node in the pool.
#[derive(Clone, Debug)]
pub struct PoolNode {
    pub id: NodeId,
    pub array: u32,
    pub ip: Ipv4Addr,
    pub mac: MacAddr,
    pub healthy: bool,
}

/// The cluster topology.
pub struct PoolTopology {
    cfg: PoolConfig,
    nodes: Vec<PoolNode>,
}

impl PoolTopology {
    /// Build the paper's layout: `arrays` PCIe switches with
    /// `nodes_per_array` DockerSSDs each; IPs assigned 10.77.<array>.<idx>.
    pub fn build(cfg: &PoolConfig) -> Self {
        let mut nodes = Vec::new();
        for a in 0..cfg.arrays {
            for i in 0..cfg.nodes_per_array {
                let id = a * cfg.nodes_per_array + i;
                nodes.push(PoolNode {
                    id,
                    array: a,
                    ip: Ipv4Addr::new(10, 77, a as u8, (i + 1) as u8),
                    mac: MacAddr::for_node(id),
                    healthy: true,
                });
            }
        }
        PoolTopology {
            cfg: cfg.clone(),
            nodes,
        }
    }

    pub fn nodes(&self) -> &[PoolNode] {
        &self.nodes
    }

    pub fn node(&self, id: NodeId) -> Option<&PoolNode> {
        self.nodes.get(id as usize)
    }

    pub fn node_mut(&mut self, id: NodeId) -> Option<&mut PoolNode> {
        self.nodes.get_mut(id as usize)
    }

    pub fn healthy_nodes(&self) -> impl Iterator<Item = &PoolNode> {
        self.nodes.iter().filter(|n| n.healthy)
    }

    /// PCIe hop count between two endpoints: same array = 1 switch; cross
    /// array = 2 switches + the tray.  An id that names no node falls
    /// back to the worst-case cross-array path — an out-of-range NodeId
    /// must never look like a free transfer.
    ///
    /// Transfer *time* is not computed here: all wire arithmetic lives
    /// in [`crate::fabric::Fabric`], which owns the shared link queues
    /// and mirrors these layout rules in its `path` computation —
    /// change them together.
    pub fn hops(&self, a: NodeId, b: NodeId) -> u32 {
        match (self.node(a), self.node(b)) {
            (Some(x), Some(y)) if x.array == y.array => 1,
            _ => 3,
        }
    }

    /// Host -> node hop count (host hangs off the tray: 2 hops to any node).
    pub fn host_hops(&self, _n: NodeId) -> u32 {
        2
    }

    pub fn config(&self) -> &PoolConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(nodes: u32, arrays: u32) -> PoolConfig {
        PoolConfig {
            nodes_per_array: nodes,
            arrays,
            ..Default::default()
        }
    }

    #[test]
    fn builds_requested_node_count() {
        let t = PoolTopology::build(&cfg(16, 2));
        assert_eq!(t.nodes().len(), 32);
    }

    #[test]
    fn ips_and_macs_unique() {
        let t = PoolTopology::build(&cfg(16, 4));
        let mut ips: Vec<_> = t.nodes().iter().map(|n| n.ip).collect();
        let mut macs: Vec<_> = t.nodes().iter().map(|n| n.mac).collect();
        ips.sort();
        ips.dedup();
        macs.sort_by_key(|m| m.0);
        macs.dedup();
        assert_eq!(ips.len(), 64);
        assert_eq!(macs.len(), 64);
    }

    #[test]
    fn intra_array_fewer_hops_than_cross_array() {
        let t = PoolTopology::build(&cfg(4, 2));
        assert_eq!(t.hops(0, 1), 1);
        assert_eq!(t.hops(0, 5), 3);
    }

    #[test]
    fn unknown_node_hops_fall_back_to_worst_case() {
        // regression: an out-of-range NodeId used to yield 0 hops and
        // therefore free transfers
        let t = PoolTopology::build(&cfg(4, 2));
        assert_eq!(t.hops(0, 999), 3);
        assert_eq!(t.hops(999, 0), 3);
        assert_eq!(t.hops(998, 999), 3);
    }

    #[test]
    fn health_filtering() {
        let mut t = PoolTopology::build(&cfg(4, 1));
        t.node_mut(2).unwrap().healthy = false;
        assert_eq!(t.healthy_nodes().count(), 3);
    }
}
