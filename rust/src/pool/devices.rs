//! Pool-level device-write economics and the bundled wire context.
//!
//! [`FtlBank`] keeps one scaled-down [`Ftl`] ledger per pool node so
//! every byte class that *lands* on a node — CoW layer mutations, chunk
//! installs on fetch/prefetch, KV session spill — prices its flash
//! programs, GC relocation, and erase wear somewhere pool-visible
//! (`ftl.waf`, `ftl.wear_max`, ...).  The bank is an economics model,
//! not a latency model: writes occupy the bank's own per-node
//! [`BusyResource`] (a write-back flush lane), so charging a fetch
//! never perturbs fabric receipts or the serve schedule.  Node-local
//! [`crate::ssd::SsdDevice`]s remain the latency model for host I/O.
//!
//! [`WireCtx`] bundles the `(fabric, topo, ftls, now)` borrow set that
//! every cross-node byte-mover used to take as a bare parameter sprawl
//! (`PoolLayerCache::{plan, fetch, prefetch}`, `MiniDocker::pull`).

use crate::config::{EtherOnConfig, PoolConfig, SsdConfig};
use crate::fabric::Fabric;
use crate::metrics::{names, Counters};
use crate::pool::topology::PoolTopology;
use crate::sim::BusyResource;
use crate::ssd::{Ftl, WriteReceipt};
use crate::util::SimTime;

/// Scaled model geometry for the per-node ledgers: 128 blocks of 32
/// pages at 64 KiB per page (256 MiB logical per node, ~tens of KB of
/// simulator memory) instead of the full multi-TB device geometry, so
/// a thousand-node pool can carry a bank without the per-4KiB-page
/// mapping cost.  Timing knobs (program/read/erase us, gc_threshold)
/// are inherited from the base config.
fn model_cfg(base: &SsdConfig) -> SsdConfig {
    SsdConfig {
        channels: 2,
        packages_per_channel: 2,
        blocks_per_package: 32,
        pages_per_block: 32,
        page_bytes: 64 << 10,
        ..base.clone()
    }
}

/// Per-node FTL ledgers for the whole pool, grown on demand.
pub struct FtlBank {
    cfg: SsdConfig,
    ftls: Vec<Ftl>,
    busy: Vec<BusyResource>,
    /// Per-node wrapping write cursor over the logical span, so
    /// sustained traffic overwrites old LPNs and exercises GC.
    cursor: Vec<u64>,
}

impl Default for FtlBank {
    fn default() -> Self {
        FtlBank::new(&SsdConfig::default())
    }
}

impl FtlBank {
    pub fn new(base: &SsdConfig) -> Self {
        FtlBank {
            cfg: model_cfg(base),
            ftls: Vec::new(),
            busy: Vec::new(),
            cursor: Vec::new(),
        }
    }

    /// Logical LPN span each node's cursor wraps over: 3/4 of the
    /// physical pages, leaving over-provisioning headroom for GC.
    pub fn logical_span(&self) -> u64 {
        let pages = self.cfg.total_packages() as u64
            * self.cfg.blocks_per_package as u64
            * self.cfg.pages_per_block as u64;
        pages * 3 / 4
    }

    fn ensure(&mut self, node: u32) {
        while self.ftls.len() <= node as usize {
            self.ftls.push(Ftl::new(&self.cfg));
            self.busy.push(BusyResource::default());
            self.cursor.push(0);
        }
    }

    /// Charge `bytes` landing on `node` at `at`: pages program through
    /// the node's ledger (forcing GC as it fills), and the cost lands on
    /// the node's write-back flush lane — never on the caller's clock.
    pub fn write(&mut self, node: u32, at: SimTime, bytes: u64) -> WriteReceipt {
        self.ensure(node);
        let n = node as usize;
        let pages = bytes.div_ceil(self.cfg.page_bytes as u64).max(1);
        let span = self.logical_span();
        let lpn = self.cursor[n] % span;
        let receipt = if lpn + pages <= span {
            self.ftls[n].write(&mut self.busy[n], at, lpn, pages)
        } else {
            // the write straddles the span end: wrap onto LPN 0
            let head = span - lpn;
            let a = self.ftls[n].write(&mut self.busy[n], at, lpn, head);
            let b = self.ftls[n].write(&mut self.busy[n], a.done, 0, pages - head);
            WriteReceipt {
                pages,
                relocated_pages: a.relocated_pages + b.relocated_pages,
                erased_blocks: a.erased_blocks + b.erased_blocks,
                done: b.done,
            }
        };
        self.cursor[n] = (lpn + pages) % span;
        receipt
    }

    /// `node`'s write amplification in milli-units (1000 = 1.0x for a
    /// node the bank has never charged).
    pub fn waf_milli_of(&self, node: u32) -> u64 {
        self.ftls.get(node as usize).map_or(1000, Ftl::waf_milli)
    }

    /// `node`'s highest per-block erase count (0 for an uncharged node).
    pub fn wear_max_of(&self, node: u32) -> u64 {
        self.ftls.get(node as usize).map_or(0, |f| f.stats.wear_max)
    }

    /// Export pool-wide flash economics under the canonical `ftl.*`
    /// names: sums over nodes, except `ftl.waf` (recomputed from the
    /// pooled page counts) and `ftl.wear_max` (the pool-wide max).
    pub fn export_counters(&self, c: &mut Counters) {
        let mut host = 0u64;
        let mut reloc = 0u64;
        let mut erases = 0u64;
        let mut wear = 0u64;
        for f in &self.ftls {
            host += f.stats.host_pages;
            reloc += f.stats.gc_relocated_pages;
            erases += f.stats.erases;
            wear = wear.max(f.stats.wear_max);
        }
        let waf = if host == 0 { 1000 } else { (host + reloc) * 1000 / host };
        c.add(names::FTL_WAF, waf);
        c.add(names::FTL_WEAR_MAX, wear);
        c.add(names::FTL_GC_RELOCATED, reloc);
        c.add(names::FTL_HOST_PAGES, host);
        c.add(names::FTL_ERASES, erases);
    }
}

/// The borrow set every cross-node byte-mover needs: the shared wire,
/// the pool shape, the write-economics bank, and the caller's clock.
/// Replaces the `(fabric, topo, now)` parameter sprawl — see
/// [`crate::layerstore::PoolLayerCache`] and
/// [`crate::docker::MiniDocker`].
pub struct WireCtx<'a> {
    pub fabric: &'a mut Fabric,
    pub topo: &'a PoolTopology,
    pub ftls: &'a mut FtlBank,
    pub now: SimTime,
}

impl<'a> WireCtx<'a> {
    pub fn at(
        fabric: &'a mut Fabric,
        topo: &'a PoolTopology,
        ftls: &'a mut FtlBank,
        now: SimTime,
    ) -> Self {
        WireCtx { fabric, topo, ftls, now }
    }
}

/// Owns a fabric + topology + bank triple and lends out [`WireCtx`]s —
/// the standalone-caller convenience (tests, benches, examples) for
/// code that has no [`crate::sim::PoolSim`] to borrow the pieces from.
pub struct WireRig {
    pub fabric: Fabric,
    pub topo: PoolTopology,
    pub ftls: FtlBank,
}

impl WireRig {
    pub fn new(pool: &PoolConfig, etheron: &EtherOnConfig) -> Self {
        WireRig {
            fabric: Fabric::new(pool, etheron),
            topo: PoolTopology::build(pool),
            ftls: FtlBank::default(),
        }
    }

    pub fn ctx(&mut self, now: SimTime) -> WireCtx<'_> {
        WireCtx::at(&mut self.fabric, &self.topo, &mut self.ftls, now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_grows_on_demand_and_prices_bytes() {
        let mut bank = FtlBank::default();
        assert_eq!(bank.waf_milli_of(9), 1000, "uncharged node reads as 1.0x");
        let r = bank.write(9, SimTime::ZERO, 200 << 10);
        assert_eq!(r.pages, 4, "200 KiB = 4 x 64 KiB model pages");
        assert!(r.done > SimTime::ZERO);
        assert_eq!(bank.wear_max_of(3), 0, "other nodes untouched");
    }

    #[test]
    fn churn_forces_gc_and_waf_above_one() {
        let mut bank = FtlBank::default();
        // 3 logical spans' worth of traffic must wrap, overwrite, and GC
        let span_bytes = bank.logical_span() * (64 << 10);
        let mut t = SimTime::ZERO;
        let mut written = 0u64;
        while written < 3 * span_bytes {
            let r = bank.write(0, t, 4 << 20);
            t = r.done;
            written += 4 << 20;
        }
        assert!(bank.waf_milli_of(0) > 1000, "sustained churn must amplify");
        assert!(bank.wear_max_of(0) >= 1);
        let mut c = Counters::new();
        bank.export_counters(&mut c);
        assert!(c.get(names::FTL_WAF) > 1000);
        assert!(c.get(names::FTL_GC_RELOCATED) > 0);
        assert!(c.get(names::FTL_ERASES) > 0);
        assert!(c.get(names::FTL_HOST_PAGES) >= 3 * bank.logical_span());
    }

    #[test]
    fn same_traffic_same_ledger() {
        let run = || {
            let mut bank = FtlBank::default();
            let mut t = SimTime::ZERO;
            for i in 0..200u64 {
                let r = bank.write((i % 3) as u32, t, (i + 1) * 100_000);
                t = r.done;
            }
            let mut c = Counters::new();
            bank.export_counters(&mut c);
            c
        };
        assert_eq!(run(), run(), "the ledger must replay byte-identically");
    }

    #[test]
    fn wire_rig_lends_a_ctx() {
        let mut rig = WireRig::new(&PoolConfig::default(), &EtherOnConfig::default());
        let ctx = rig.ctx(SimTime::us(5));
        assert_eq!(ctx.now, SimTime::us(5));
        assert!(!ctx.topo.nodes().is_empty());
    }
}
