//! Computing-enabled storage pool (DESIGN.md S9, paper "RESOURCE
//! DISAGGREGATION"): DockerSSDs disaggregated from their hosts behind
//! PCIe switches, each with its own IP, orchestrated like a
//! docker-compose/Kubernetes deployment.

pub mod devices;
pub mod orchestrator;
pub mod topology;

pub use devices::{FtlBank, WireCtx, WireRig};
pub use orchestrator::{BootStormReport, DeploymentSpec, Orchestrator, RestartPolicy};
pub use topology::{NodeId, PoolNode, PoolTopology};
