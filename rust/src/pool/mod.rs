//! Computing-enabled storage pool (DESIGN.md S9, paper "RESOURCE
//! DISAGGREGATION"): DockerSSDs disaggregated from their hosts behind
//! PCIe switches, each with its own IP, orchestrated like a
//! docker-compose/Kubernetes deployment.

pub mod autoscale;
pub mod devices;
pub mod orchestrator;
pub mod topology;

pub use autoscale::{
    boot_storm_coldstart_baseline, flash_crowd, AutoScaleOutcome, AutoScaleParams,
    AutoScaleReport, AutoScaler, FlashCrowdOutcome, EV_AUTOSCALE_TICK,
};
pub use devices::{FtlBank, WireCtx, WireRig};
pub use orchestrator::{BootStormReport, DeploymentSpec, Orchestrator, RestartPolicy};
pub use topology::{NodeId, PoolNode, PoolTopology};
