//! Container orchestration over the pool — the docker-compose/Kubernetes
//! role in the paper's distributed-inference deployment: place container
//! replicas on healthy nodes, monitor them through mini-docker logs,
//! restart per policy.

use super::devices::{FtlBank, WireCtx};
use super::topology::{NodeId, PoolTopology};
use crate::layerstore::{FetchSource, PoolLayerCache};
use crate::sim::PoolSim;
use crate::util::SimTime;

/// Restart policy (compose-like).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RestartPolicy {
    Never,
    OnFailure,
    Always,
}

/// A deployment request: run `replicas` containers of `image` across the
/// pool.
#[derive(Clone, Debug)]
pub struct DeploymentSpec {
    pub name: String,
    pub image: String,
    pub replicas: u32,
    pub restart: RestartPolicy,
}

/// What a [`Orchestrator::boot_storm_sim`] deployment put on the wire.
#[derive(Clone, Debug, Default)]
pub struct BootStormReport {
    pub placed: Vec<NodeId>,
    /// Layers pulled from the registry in the foreground (pool-cold).
    pub registry_pulls: u64,
    /// Layers prefetched from a peer on the background lane (pool-warm).
    pub peer_prefetches: u64,
    /// When the last foreground pull byte lands.
    pub pulls_done: SimTime,
}

/// One placed replica.
#[derive(Clone, Debug, PartialEq)]
pub struct Placement {
    pub deployment: String,
    pub replica: u32,
    pub node: NodeId,
    pub running: bool,
    pub restarts: u32,
}

/// The orchestrator state.
#[derive(Default)]
pub struct Orchestrator {
    placements: Vec<Placement>,
    /// Replicas per node, dense by node id; a missing slot reads as 0,
    /// same as the absent-entry convention of the old map.
    load: Vec<u32>,
}

impl Orchestrator {
    pub fn new() -> Self {
        Self::default()
    }

    fn bump_load(&mut self, node: NodeId) {
        let i = node as usize;
        if self.load.len() <= i {
            self.load.resize(i + 1, 0);
        }
        self.load[i] += 1;
    }

    /// Place replicas on the least-loaded healthy nodes (spread strategy).
    /// Fails if there are no healthy nodes.
    pub fn deploy(&mut self, topo: &PoolTopology, spec: &DeploymentSpec) -> Result<Vec<NodeId>, String> {
        let mut healthy: Vec<NodeId> = topo.healthy_nodes().map(|n| n.id).collect();
        if healthy.is_empty() {
            return Err("no healthy nodes".into());
        }
        let mut placed = Vec::new();
        for r in 0..spec.replicas {
            healthy.sort_by_key(|id| (self.load_of(*id), *id));
            let node = healthy[0];
            self.bump_load(node);
            self.placements.push(Placement {
                deployment: spec.name.clone(),
                replica: r,
                node,
                running: true,
                restarts: 0,
            });
            placed.push(node);
        }
        Ok(placed)
    }

    /// Layer-locality-aware placement: score each healthy node by the
    /// fabric's idle-wire estimate of fetching its missing layers, plus
    /// a load-balancing term (`load × unit_cost(image_bytes)`, so one
    /// queued replica costs as much as one full warm pull), and place on
    /// the cheapest — ties broken by least load, then lowest id.  A
    /// replica landing on a warm node boots from the local layerstore
    /// instead of pulling across the pool — the placement-side half of
    /// the dedup story.
    ///
    /// Each placement immediately kicks off *background prefetches* for
    /// the layers the chosen node is missing: the per-chunk transfers
    /// are scheduled on the fabric's event-driven engine
    /// ([`crate::fabric::Fabric::schedule`], background lane), so they
    /// start moving while the container is still being created, yield
    /// the wire to any foreground traffic within one frame quantum, and
    /// — unlike the old synchronous path — get *re-timed* receipts when
    /// preempted (`fabric.retimed_transfers`).  By boot time the layers
    /// are (being) resident, so the boot-path fetch is a local hit that
    /// settles the in-flight tail.
    ///
    /// `layers` is the image's (blob digest, bytes) list.  `wire` bundles
    /// the pool's fabric, topology, FTL bank, and clock
    /// ([`WireCtx`]): placement *reads* the bank — a node whose flash is
    /// amplifying (WAF above 1.0x) pays a wear surcharge proportional to
    /// its excess, so replicas drift away from worn devices — and the
    /// prefetches it kicks off *charge* the bank at the chosen node.
    pub fn deploy_with_layers(
        &mut self,
        wire: &mut WireCtx,
        spec: &DeploymentSpec,
        cache: &mut PoolLayerCache,
        layers: &[(u64, u64)],
    ) -> Result<Vec<NodeId>, String> {
        let healthy: Vec<NodeId> = wire.topo.healthy_nodes().map(|n| n.id).collect();
        if healthy.is_empty() {
            return Err("no healthy nodes".into());
        }
        // one queued replica costs as much as one full warm pull of the
        // image, layer by layer (hop latency included, so a fully-cold
        // node and a once-queued warm node tie and load breaks the tie)
        let queued_cost: SimTime = layers
            .iter()
            .fold(SimTime::ZERO, |acc, (_, b)| acc + wire.fabric.unit_cost(*b));
        let mut placed = Vec::new();
        for r in 0..spec.replicas {
            // single pass; the key is unique (it ends in the node id),
            // so the minimum is deterministic
            let node = *healthy
                .iter()
                .min_by_key(|id| {
                    let load = self.load_of(**id) as u64;
                    let missing: SimTime = layers
                        .iter()
                        .filter(|(d, _)| !cache.node_has(**id, *d))
                        .fold(SimTime::ZERO, |acc, (d, b)| {
                            acc + cache.plan(wire, **id, *d, *b).1
                        });
                    // flash-wear surcharge: WAF of 1.0x (or an uncharged
                    // node) adds zero, so a fresh pool scores exactly as
                    // it did before the bank existed
                    let waf_excess = wire.ftls.waf_milli_of(**id).saturating_sub(1000);
                    (
                        missing
                            + queued_cost.scale(load as f64)
                            + queued_cost.scale(waf_excess as f64 / 1000.0),
                        load,
                        **id,
                    )
                })
                .expect("healthy is non-empty");
            self.bump_load(node);
            self.placements.push(Placement {
                deployment: spec.name.clone(),
                replica: r,
                node,
                running: true,
                restarts: 0,
            });
            placed.push(node);
            // overlap layer transfer with container create: background
            // prefetch for every layer the node is missing
            for (d, b) in layers {
                if !cache.node_has(node, *d) {
                    cache.prefetch(wire, node, *d, *b);
                }
            }
        }
        Ok(placed)
    }

    /// [`Orchestrator::deploy_with_layers`] on the pool's shared clock:
    /// `now` comes from the [`PoolSim`] event queue and the placement's
    /// background prefetches land on its fabric, so deployment traffic
    /// shares the timeline with serving, docker pulls, and collectives
    /// instead of living at a private t=0.
    pub fn deploy_sim(
        &mut self,
        sim: &mut PoolSim,
        topo: &PoolTopology,
        spec: &DeploymentSpec,
        cache: &mut PoolLayerCache,
        layers: &[(u64, u64)],
    ) -> Result<Vec<NodeId>, String> {
        let now = sim.now();
        let mut wire = WireCtx {
            fabric: &mut sim.fabric,
            topo,
            ftls: &mut sim.ftls,
            now,
        };
        self.deploy_with_layers(&mut wire, spec, cache, layers)
    }

    /// A replica boot storm on the pool's shared clock — the
    /// interference generator for serve-while-deploy experiments
    /// (`repro serve --boot-storm N`).  Replicas are placed with the
    /// spread strategy, then each replica's missing layers start moving
    /// at the clock's `now`:
    ///
    /// * a layer *no* pool node holds is pulled from the registry in the
    ///   **foreground** — the [`crate::docker::MiniDocker::pull`] wire
    ///   path (RegistryWan + HostUplink + Array), so the pull visibly
    ///   contends with serve dispatch/response traffic on the host
    ///   uplink;
    /// * a layer some node already holds is prefetched from the nearest
    ///   peer on the **background** lane, yielding the wire to
    ///   foreground traffic within one frame quantum.
    ///
    /// Both kinds land in `cache`, so a later storm of the same image is
    /// pool-warm.  Serving alongside reads the contention off the shared
    /// fabric's `fabric.queue_wait_ns` / `serve.latency_p99_ns`.
    pub fn boot_storm_sim(
        &mut self,
        sim: &mut PoolSim,
        topo: &PoolTopology,
        spec: &DeploymentSpec,
        cache: &mut PoolLayerCache,
        layers: &[(u64, u64)],
    ) -> Result<BootStormReport, String> {
        let now = sim.now();
        let placed = self.deploy(topo, spec)?;
        let mut report = BootStormReport {
            placed: placed.clone(),
            pulls_done: now,
            ..Default::default()
        };
        let mut wire = WireCtx {
            fabric: &mut sim.fabric,
            topo,
            ftls: &mut sim.ftls,
            now,
        };
        for &node in &placed {
            for &(digest, bytes) in layers {
                let plans = cache.plan_chunks(wire.fabric, wire.topo, node, digest, bytes);
                let missing = plans.iter().any(|p| p.source != FetchSource::Local);
                let wan = plans.iter().any(|p| p.source == FetchSource::Registry);
                if !missing {
                    continue;
                }
                if wan {
                    // any chunk no pool node holds boots like a cold
                    // pull: fetch foreground (peer-held chunks still ride
                    // the intranet; only the missing ones cross the WAN)
                    let (_, latency) = cache.fetch(&mut wire, node, digest, bytes);
                    report.registry_pulls += 1;
                    report.pulls_done = report.pulls_done.max(now + latency);
                } else {
                    // every chunk is pool-warm (one peer or several):
                    // background prefetch
                    cache.prefetch(&mut wire, node, digest, bytes);
                    report.peer_prefetches += 1;
                }
            }
        }
        Ok(report)
    }

    /// Run pool-wide layer GC with this orchestrator's replica counts as
    /// the load signal and the FTL bank's wear ledger as the tiebreaker
    /// override: layers held by more than `k` nodes are dropped from the
    /// most-*worn* holders first, then the most-loaded (see
    /// [`PoolLayerCache::gc`]) — spare copies come off the devices
    /// closest to wear-out.
    pub fn gc_pool(
        &self,
        cache: &mut PoolLayerCache,
        ftls: &FtlBank,
        k: usize,
    ) -> Vec<(NodeId, u64)> {
        cache.gc(k, |n| self.load_of(n) as u64, |n| ftls.wear_max_of(n))
    }

    pub fn placements(&self, deployment: &str) -> Vec<&Placement> {
        self.placements
            .iter()
            .filter(|p| p.deployment == deployment)
            .collect()
    }

    pub fn load_of(&self, node: NodeId) -> u32 {
        self.load.get(node as usize).copied().unwrap_or(0)
    }

    /// A replica died (container exited / node fault).  Applies the
    /// restart policy; returns true if it was restarted (possibly moved).
    pub fn replica_failed(
        &mut self,
        topo: &PoolTopology,
        deployment: &str,
        replica: u32,
        policy: RestartPolicy,
    ) -> bool {
        let Some(idx) = self
            .placements
            .iter()
            .position(|p| p.deployment == deployment && p.replica == replica)
        else {
            return false;
        };
        let node = self.placements[idx].node;
        self.placements[idx].running = false;
        if policy == RestartPolicy::Never {
            return false;
        }
        // restart on the same node if healthy, else move to least-loaded
        let target = if topo.node(node).is_some_and(|n| n.healthy) {
            node
        } else {
            // drop the dead node's load share so spread/locality scoring
            // and gc never see a ghost holder (saturating: a double
            // fault must not underflow)
            if let Some(l) = self.load.get_mut(node as usize) {
                *l = l.saturating_sub(1);
            }
            let mut healthy: Vec<NodeId> = topo.healthy_nodes().map(|n| n.id).collect();
            if healthy.is_empty() {
                return false;
            }
            healthy.sort_by_key(|id| (self.load_of(*id), *id));
            let t = healthy[0];
            self.bump_load(t);
            t
        };
        let p = &mut self.placements[idx];
        p.node = target;
        p.running = true;
        p.restarts += 1;
        true
    }

    /// A whole node died.  Every replica it ran fails at once and is
    /// re-placed per `policy` (the caller marks the node unhealthy in
    /// `topo` *first*, so [`Orchestrator::replica_failed`] moves each one
    /// to a surviving node), then the node's residual load entry is
    /// purged so no future placement decision counts a dead node.
    ///
    /// Returns the `(deployment, replica)` pairs that were re-placed —
    /// the chaos heal loop's restart ledger.
    pub fn node_failed(
        &mut self,
        topo: &PoolTopology,
        node: NodeId,
        policy: RestartPolicy,
    ) -> Vec<(String, u32)> {
        let doomed: Vec<(String, u32)> = self
            .placements
            .iter()
            .filter(|p| p.node == node && p.running)
            .map(|p| (p.deployment.clone(), p.replica))
            .collect();
        let mut moved = Vec::new();
        for (dep, r) in doomed {
            if self.replica_failed(topo, &dep, r, policy) {
                moved.push((dep, r));
            }
        }
        if let Some(l) = self.load.get_mut(node as usize) {
            *l = 0;
        }
        moved
    }

    /// Replicas running per deployment (health summary the host monitors
    /// via mini-docker logs).
    pub fn running_count(&self, deployment: &str) -> u32 {
        self.placements
            .iter()
            .filter(|p| p.deployment == deployment && p.running)
            .count() as u32
    }

    /// Rank the healthy nodes as scale-out candidates for `deployment`,
    /// cheapest boot first: the same scoring key as
    /// [`Orchestrator::deploy_with_layers`] (idle-wire estimate of the
    /// node's missing layers, plus one warm-pull-equivalent per queued
    /// replica, plus the flash-wear surcharge), over the nodes *not*
    /// already running one of the deployment's replicas.  Pure scoring —
    /// no placement, no wire traffic, no flash charge — so the
    /// predictive autoscaler can call it every hot tick to aim its
    /// background prefetch before the scale-out decision commits.
    pub fn rank_candidates(
        &self,
        wire: &WireCtx,
        deployment: &str,
        cache: &PoolLayerCache,
        layers: &[(u64, u64)],
    ) -> Vec<NodeId> {
        let hosting: std::collections::BTreeSet<NodeId> = self
            .placements
            .iter()
            .filter(|p| p.deployment == deployment && p.running)
            .map(|p| p.node)
            .collect();
        let queued_cost: SimTime = layers
            .iter()
            .fold(SimTime::ZERO, |acc, (_, b)| acc + wire.fabric.unit_cost(*b));
        let mut scored: Vec<((SimTime, u64, NodeId), NodeId)> = wire
            .topo
            .healthy_nodes()
            .map(|n| n.id)
            .filter(|id| !hosting.contains(id))
            .map(|id| {
                let load = self.load_of(id) as u64;
                let missing: SimTime = layers
                    .iter()
                    .filter(|(d, _)| !cache.node_has(id, *d))
                    .fold(SimTime::ZERO, |acc, (d, b)| acc + cache.plan(wire, id, *d, *b).1);
                let waf_excess = wire.ftls.waf_milli_of(id).saturating_sub(1000);
                (
                    (
                        missing
                            + queued_cost.scale(load as f64)
                            + queued_cost.scale(waf_excess as f64 / 1000.0),
                        load,
                        id,
                    ),
                    id,
                )
            })
            .collect();
        // the key ends in the node id, so the order is total and
        // deterministic
        scored.sort_by_key(|(key, _)| *key);
        scored.into_iter().map(|(_, id)| id).collect()
    }

    /// Commit one scale-out: place a new replica of `deployment` on
    /// `node` (typically the head of [`Orchestrator::rank_candidates`])
    /// and return its replica index — always one past the highest index
    /// the deployment has ever used, so retired replicas are never
    /// reincarnated under the same identity.
    pub fn scale_out_on(&mut self, deployment: &str, node: NodeId) -> u32 {
        let replica = self
            .placements
            .iter()
            .filter(|p| p.deployment == deployment)
            .map(|p| p.replica + 1)
            .max()
            .unwrap_or(0);
        self.bump_load(node);
        self.placements.push(Placement {
            deployment: deployment.to_string(),
            replica,
            node,
            running: true,
            restarts: 0,
        });
        replica
    }

    /// Retire the highest-index running replica of `deployment` — LIFO,
    /// so scale-in unwinds scale-out.  The placement stays on the books
    /// (not running) for the restart ledger; the node's load share is
    /// dropped so spread and locality scoring stop counting it.  Returns
    /// the retired `(replica, node)`, or `None` when nothing is running.
    pub fn scale_in(&mut self, deployment: &str) -> Option<(u32, NodeId)> {
        let idx = self
            .placements
            .iter()
            .enumerate()
            .filter(|(_, p)| p.deployment == deployment && p.running)
            .max_by_key(|(_, p)| p.replica)
            .map(|(i, _)| i)?;
        let (replica, node) = (self.placements[idx].replica, self.placements[idx].node);
        self.placements[idx].running = false;
        if let Some(l) = self.load.get_mut(node as usize) {
            *l = l.saturating_sub(1);
        }
        Some((replica, node))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EtherOnConfig, PoolConfig};
    use crate::fabric::Fabric;
    use crate::layerstore::FetchSource;

    fn topo(n: u32) -> PoolTopology {
        PoolTopology::build(&PoolConfig {
            nodes_per_array: n,
            arrays: 1,
            ..Default::default()
        })
    }

    fn fabric(n: u32) -> Fabric {
        Fabric::new(
            &PoolConfig {
                nodes_per_array: n,
                arrays: 1,
                ..Default::default()
            },
            &EtherOnConfig::default(),
        )
    }

    fn spec(name: &str, replicas: u32) -> DeploymentSpec {
        DeploymentSpec {
            name: name.into(),
            image: "llm-worker".into(),
            replicas,
            restart: RestartPolicy::OnFailure,
        }
    }

    #[test]
    fn deploy_spreads_across_nodes() {
        let t = topo(4);
        let mut orch = Orchestrator::new();
        let placed = orch.deploy(&t, &spec("infer", 4)).unwrap();
        let mut sorted = placed.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 4, "replicas should spread: {placed:?}");
    }

    #[test]
    fn deploy_balances_load_with_more_replicas_than_nodes() {
        let t = topo(4);
        let mut orch = Orchestrator::new();
        orch.deploy(&t, &spec("infer", 8)).unwrap();
        for n in 0..4 {
            assert_eq!(orch.load_of(n), 2, "node {n}");
        }
    }

    #[test]
    fn deploy_avoids_unhealthy_nodes() {
        let mut t = topo(4);
        t.node_mut(0).unwrap().healthy = false;
        let mut orch = Orchestrator::new();
        let placed = orch.deploy(&t, &spec("infer", 3)).unwrap();
        assert!(!placed.contains(&0));
    }

    #[test]
    fn deploy_fails_with_no_healthy_nodes() {
        let mut t = topo(2);
        t.node_mut(0).unwrap().healthy = false;
        t.node_mut(1).unwrap().healthy = false;
        let mut orch = Orchestrator::new();
        assert!(orch.deploy(&t, &spec("infer", 1)).is_err());
    }

    #[test]
    fn layer_locality_prefers_warm_nodes() {
        let t = topo(4);
        let mut f = fabric(4);
        let mut orch = Orchestrator::new();
        let mut cache = PoolLayerCache::new();
        // node 2 already holds both layers, node 1 holds one
        cache.register(2, 0xA);
        cache.register(2, 0xB);
        cache.register(1, 0xA);
        let layers = [(0xA, 1000u64), (0xB, 2000u64)];
        let mut bank = FtlBank::default();
        let placed = orch
            .deploy_with_layers(
                &mut WireCtx::at(&mut f, &t, &mut bank, SimTime::ZERO),
                &spec("infer", 3),
                &mut cache,
                &layers,
            )
            .unwrap();
        assert_eq!(placed[0], 2, "fully warm node first");
        assert_eq!(placed[1], 1, "partially warm node next: fetching 2000B beats one queued replica");
        // replica 3: warm-but-loaded nodes cost one queued replica, the
        // cold idle node costs one full image fetch — a tie by
        // construction, and lower load wins it
        assert_eq!(placed[2], 0);
        assert_eq!(
            cache.prefetch_bytes,
            2000 + 3000,
            "replica 2's missing layer + replica 3's full image were prefetched"
        );
    }

    #[test]
    fn layer_locality_falls_back_to_load_spread_when_cold() {
        let t = topo(4);
        let mut f = fabric(4);
        let mut orch = Orchestrator::new();
        let mut cache = PoolLayerCache::new();
        let layers = [(0xA, 1000u64)];
        let mut bank = FtlBank::default();
        let placed = orch
            .deploy_with_layers(
                &mut WireCtx::at(&mut f, &t, &mut bank, SimTime::ZERO),
                &spec("infer", 4),
                &mut cache,
                &layers,
            )
            .unwrap();
        let mut sorted = placed.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 4, "cold pool still spreads: {placed:?}");
    }

    #[test]
    fn placement_penalizes_worn_flash() {
        let t = topo(4);
        let mut f = fabric(4);
        let mut orch = Orchestrator::new();
        let mut cache = PoolLayerCache::new();
        // churn node 0's flash until its WAF exceeds 1.0x; every other
        // node is untouched and otherwise ties with node 0 (all cold,
        // load 0), so without the wear surcharge the id tiebreak would
        // put the first replica on node 0
        let mut bank = FtlBank::default();
        let span_bytes = bank.logical_span() * (64 << 10);
        let mut now = SimTime::ZERO;
        let mut written = 0u64;
        while written < 3 * span_bytes {
            let r = bank.write(0, now, 4 << 20);
            now = r.done;
            written += 4 << 20;
        }
        assert!(bank.waf_milli_of(0) > 1000);
        let placed = orch
            .deploy_with_layers(
                &mut WireCtx::at(&mut f, &t, &mut bank, SimTime::ZERO),
                &spec("infer", 1),
                &mut cache,
                &[(0xA, 1000u64)],
            )
            .unwrap();
        assert_eq!(placed, vec![1], "the wear surcharge breaks the cold tie off node 0");
    }

    #[test]
    fn layer_locality_skips_unhealthy_holders() {
        let mut t = topo(3);
        let mut f = fabric(3);
        let mut cache = PoolLayerCache::new();
        cache.register(0, 0xA);
        t.node_mut(0).unwrap().healthy = false;
        let mut orch = Orchestrator::new();
        let mut bank = FtlBank::default();
        let placed = orch
            .deploy_with_layers(
                &mut WireCtx::at(&mut f, &t, &mut bank, SimTime::ZERO),
                &spec("infer", 2),
                &mut cache,
                &[(0xA, 512)],
            )
            .unwrap();
        assert!(!placed.contains(&0));
    }

    #[test]
    fn placement_prefetch_makes_boot_fetch_local() {
        let t = topo(4);
        let mut f = fabric(4);
        let mut orch = Orchestrator::new();
        let mut cache = PoolLayerCache::new();
        let layers = [(0xA, 4096u64), (0xB, 8192u64)];
        let mut bank = FtlBank::default();
        let placed = orch
            .deploy_with_layers(
                &mut WireCtx::at(&mut f, &t, &mut bank, SimTime::ZERO),
                &spec("infer", 2),
                &mut cache,
                &layers,
            )
            .unwrap();
        assert_eq!(cache.prefetch_bytes, 2 * (4096 + 8192), "both replicas prefetched");
        assert!(f.transfers_in_flight() >= 1, "prefetch is scheduled on the engine");
        f.run_to_idle();
        assert!(f.stats.transfers_bg >= 4, "prefetch rides the background lane");
        assert!(bank.wear_max_of(placed[0]) <= 1, "prefetched layers charge the bank lightly");
        // the boot-path fetch rides the prefetch: it hits locally and at
        // most waits for the in-flight tail, never re-transfers
        for nid in placed {
            for (d, b) in layers {
                let (src, lat) =
                    cache.fetch(&mut WireCtx::at(&mut f, &t, &mut bank, SimTime::ZERO), nid, d, b);
                assert_eq!(src, FetchSource::Local);
                let (src2, lat2) =
                    cache.fetch(&mut WireCtx::at(&mut f, &t, &mut bank, lat), nid, d, b);
                assert_eq!(src2, FetchSource::Local);
                assert_eq!(lat2, SimTime::ZERO, "resident once the tail has landed");
            }
        }
    }

    #[test]
    fn deploy_sim_rides_the_shared_clock() {
        use crate::config::SystemConfig;

        let cfg = SystemConfig::default();
        let mut sim = crate::sim::PoolSim::new(&cfg);
        // the pool clock has already advanced when placement happens
        sim.queue.schedule_at(SimTime::us(500), 0);
        sim.queue.pop();
        let t = topo(16);
        let mut orch = Orchestrator::new();
        let mut cache = PoolLayerCache::new();
        cache.register(0, 0xA);
        let placed = orch
            .deploy_sim(&mut sim, &t, &spec("infer", 2), &mut cache, &[(0xA, 1 << 20)])
            .unwrap();
        assert_eq!(placed.len(), 2);
        // prefetch traffic landed on the shared fabric's engine at the
        // clock's now; drain it to observe the completed-transfer stats
        sim.fabric.run_to_idle();
        assert!(sim.fabric.stats.transfers_bg >= 1);
        assert!(sim.fabric.stats.prefetch_bytes >= 1 << 20);
    }

    #[test]
    fn boot_storm_pulls_cold_layers_then_prefetches_warm_ones() {
        use crate::config::SystemConfig;
        use crate::metrics::{names, Counters};

        let cfg = SystemConfig::default();
        let mut sim = crate::sim::PoolSim::new(&cfg);
        let t = topo(16);
        let mut orch = Orchestrator::new();
        let mut cache = PoolLayerCache::new();
        let layers = [(0xAA, 4u64 << 20), (0xBB, 2u64 << 20)];
        let rep = orch
            .boot_storm_sim(&mut sim, &t, &spec("infer", 3), &mut cache, &layers)
            .unwrap();
        assert_eq!(rep.placed.len(), 3);
        assert_eq!(rep.registry_pulls, 2, "the first replica cold-pulls each layer once");
        assert_eq!(rep.peer_prefetches, 4, "later replicas prefetch from the pool");
        assert!(rep.pulls_done > SimTime::ZERO, "pulls pay real wire time");
        sim.fabric.run_to_idle(); // drain the engine-scheduled prefetches
        let mut c = Counters::new();
        sim.export_counters(&mut c);
        assert_eq!(c.get(names::FABRIC_BYTES_WAN), 6 << 20, "cold pulls cross the WAN once");
        assert!(
            c.get(names::FABRIC_BYTES_HOST_UPLINK) >= 6 << 20,
            "pulls occupy the host uplink foreground"
        );
        assert!(sim.fabric.stats.transfers_bg >= 4, "warm copies ride the background lane");
        // a second storm of the same image is fully pool-warm: no new
        // WAN bytes
        let rep2 = orch
            .boot_storm_sim(&mut sim, &t, &spec("again", 2), &mut cache, &layers)
            .unwrap();
        assert_eq!(rep2.registry_pulls, 0);
        let mut c2 = Counters::new();
        sim.export_counters(&mut c2);
        assert_eq!(c2.get(names::FABRIC_BYTES_WAN), 6 << 20);
    }

    #[test]
    fn gc_pool_uses_replica_load() {
        let t = topo(4);
        let mut orch = Orchestrator::new();
        orch.deploy(&t, &spec("infer", 4)).unwrap();
        orch.deploy(&t, &spec("extra", 1)).unwrap(); // node 0 now loaded 2
        let mut cache = PoolLayerCache::new();
        for n in 0..4 {
            cache.register(n, 0xD);
        }
        let evicted = orch.gc_pool(&mut cache, &FtlBank::default(), 2);
        assert_eq!(evicted.len(), 2);
        assert!(
            evicted.contains(&(0, 0xD)),
            "most-loaded node evicted first: {evicted:?}"
        );
        assert_eq!(cache.holders(0xD).len(), 2);
    }

    #[test]
    fn failed_replica_restarts_in_place() {
        let t = topo(2);
        let mut orch = Orchestrator::new();
        orch.deploy(&t, &spec("infer", 2)).unwrap();
        assert!(orch.replica_failed(&t, "infer", 0, RestartPolicy::OnFailure));
        let p = orch.placements("infer");
        assert_eq!(p[0].restarts, 1);
        assert!(p[0].running);
    }

    #[test]
    fn failed_replica_moves_off_unhealthy_node() {
        let mut t = topo(2);
        let mut orch = Orchestrator::new();
        orch.deploy(&t, &spec("infer", 1)).unwrap();
        let original = orch.placements("infer")[0].node;
        t.node_mut(original).unwrap().healthy = false;
        assert!(orch.replica_failed(&t, "infer", 0, RestartPolicy::Always));
        let moved = orch.placements("infer")[0].node;
        assert_ne!(moved, original);
    }

    #[test]
    fn node_failure_replaces_every_replica_and_purges_its_load() {
        let mut t = topo(3);
        let mut orch = Orchestrator::new();
        orch.deploy(&t, &spec("infer", 3)).unwrap();
        orch.deploy(&t, &spec("web", 3)).unwrap(); // two replicas per node
        t.node_mut(1).unwrap().healthy = false;
        let moved = orch.node_failed(&t, 1, RestartPolicy::OnFailure);
        assert_eq!(moved.len(), 2, "both of node 1's replicas re-placed: {moved:?}");
        // regression (ISSUE 6 satellite): no residual load entry on the
        // dead node — gc_pool's load signal and spread scoring must
        // never count a dead holder
        assert_eq!(orch.load_of(1), 0);
        assert_eq!(orch.load_of(0) + orch.load_of(2), 6, "survivors absorb the work");
        assert_eq!(orch.running_count("infer"), 3);
        assert_eq!(orch.running_count("web"), 3);
        assert!(orch.placements("infer").iter().all(|p| p.node != 1));
        assert!(orch.placements("web").iter().all(|p| p.node != 1));
    }

    #[test]
    fn repeated_node_failure_reports_are_idempotent() {
        let mut t = topo(2);
        let mut orch = Orchestrator::new();
        orch.deploy(&t, &spec("infer", 2)).unwrap();
        t.node_mut(0).unwrap().healthy = false;
        assert_eq!(orch.node_failed(&t, 0, RestartPolicy::OnFailure).len(), 1);
        // a second report of the same dead node is a no-op, not an
        // underflow panic on the (already purged) load entry
        assert!(orch.node_failed(&t, 0, RestartPolicy::OnFailure).is_empty());
        assert_eq!(orch.load_of(0), 0);
        assert_eq!(orch.load_of(1), 2);
        assert_eq!(orch.running_count("infer"), 2);
    }

    #[test]
    fn node_failure_with_no_survivors_leaves_replicas_down() {
        let mut t = topo(1);
        let mut orch = Orchestrator::new();
        orch.deploy(&t, &spec("infer", 2)).unwrap();
        t.node_mut(0).unwrap().healthy = false;
        assert!(orch.node_failed(&t, 0, RestartPolicy::OnFailure).is_empty());
        assert_eq!(orch.running_count("infer"), 0);
        assert_eq!(orch.load_of(0), 0, "the dead node's load is still purged");
    }

    #[test]
    fn rank_candidates_scores_like_deploy_and_skips_hosts() {
        let t = topo(4);
        let mut f = fabric(4);
        let mut orch = Orchestrator::new();
        let mut cache = PoolLayerCache::new();
        // node 2 fully warm, node 1 half warm, 0 and 3 cold
        cache.register(2, 0xA);
        cache.register(2, 0xB);
        cache.register(1, 0xA);
        let layers = [(0xA, 1000u64), (0xB, 2000u64)];
        let mut bank = FtlBank::default();
        let wire = WireCtx::at(&mut f, &t, &mut bank, SimTime::ZERO);
        let ranked = orch.rank_candidates(&wire, "infer", &cache, &layers);
        assert_eq!(ranked, vec![2, 1, 0, 3], "warmest first, then id tiebreak");
        // a node already hosting a running replica leaves the ranking
        orch.scale_out_on("infer", 2);
        let ranked = orch.rank_candidates(&wire, "infer", &cache, &layers);
        assert_eq!(ranked, vec![1, 0, 3]);
        // pure scoring: no traffic, no prefetch, no flash charge
        assert_eq!(cache.prefetch_bytes, 0);
        assert_eq!(f.transfers_in_flight(), 0);
    }

    #[test]
    fn scale_out_and_in_unwind_lifo_with_fresh_replica_ids() {
        let t = topo(4);
        let mut orch = Orchestrator::new();
        orch.deploy(&t, &spec("infer", 2)).unwrap();
        let r2 = orch.scale_out_on("infer", 3);
        assert_eq!(r2, 2, "next free replica index");
        assert_eq!(orch.running_count("infer"), 3);
        assert_eq!(orch.load_of(3), 1);
        // LIFO retire: the newest replica drains first
        assert_eq!(orch.scale_in("infer"), Some((2, 3)));
        assert_eq!(orch.running_count("infer"), 2);
        assert_eq!(orch.load_of(3), 0, "retired replica's load share dropped");
        // a later scale-out never reincarnates a retired replica id
        assert_eq!(orch.scale_out_on("infer", 3), 3);
        assert_eq!(orch.scale_in("infer"), Some((3, 3)));
        assert_eq!(orch.scale_in("infer"), Some((1, orch.placements("infer")[1].node)));
        assert_eq!(orch.scale_in("infer"), Some((0, orch.placements("infer")[0].node)));
        assert_eq!(orch.scale_in("infer"), None, "nothing left running");
        assert_eq!(orch.running_count("infer"), 0);
    }

    #[test]
    fn never_policy_leaves_replica_down() {
        let t = topo(2);
        let mut orch = Orchestrator::new();
        orch.deploy(&t, &spec("infer", 2)).unwrap();
        assert!(!orch.replica_failed(&t, "infer", 1, RestartPolicy::Never));
        assert_eq!(orch.running_count("infer"), 1);
    }
}
