//! Deterministic serverless autoscaler with predictive layer prefetch
//! (ROADMAP direction: "Serverless autoscaling with predictive layer
//! prefetch").
//!
//! The controller runs entirely on the shared [`PoolSim`] clock: it
//! schedules its own periodic tick events ([`EV_AUTOSCALE_TICK`]) on
//! `sim.queue` and plugs into the serving loop through the
//! [`ServeHook`] seam, exactly like the chaos engine — every decision
//! is an ordinary event popped in deterministic time order between
//! arrivals, batch completions, and deadlines.  On each tick the serve
//! loop hands over its instantaneous [`QueuePressure`]; the controller
//! thresholds the queue depth:
//!
//! * **scale-out** — `sustain_ticks` consecutive ticks at or above
//!   `high_depth` commit one new replica, placed on the head of
//!   [`Orchestrator::rank_candidates`] (the same boot-cost scoring as
//!   `deploy_with_layers`: missing-layer wire estimate + queued-replica
//!   surcharge + flash-wear surcharge);
//! * **scale-in** — `idle_ticks` consecutive fully-idle ticks retire
//!   the highest-index running replica ([`Orchestrator::scale_in`],
//!   LIFO); when nothing is left running the tick chain ends and the
//!   controller goes quiet.
//!
//! The headline mechanism is **predictive prefetch**: in predictive
//! mode every *hot* tick — before any scale-out commits — aims
//! [`PoolLayerCache::prefetch_set`] at the top-ranked candidates, so
//! their missing layers ride the fabric's *background* lanes
//! (engine-scheduled, re-timed receipts, yielding to foreground serve
//! traffic) while the controller is still deciding.  By the time the
//! hot streak sustains and the scale-out commits, a flash crowd boots
//! from warm peers instead of the registry WAN: the commit-time
//! foreground fetch settles only the in-flight tail.  Cold-start
//! (commit to boot-ready) is recorded per boot; the p99 is the number
//! the PR's bench compares against the reactive controller and the
//! boot-storm baseline ([`boot_storm_coldstart_baseline`]).
//!
//! Chaos interplay: the autoscaler and the chaos injector are both
//! `ServeHook`s and both want ownership of the pool-management triple,
//! so one serve run hosts one or the other (the smoke runner rejects
//! `--autoscale --chaos`).  A node death between runs is already
//! handled at the seams the autoscaler reuses: `rank_candidates` only
//! scores healthy nodes, and a dead candidate's layer registrations are
//! purged before the next ranking.
//!
//! Everything is deterministic for a given seed: two same-seed runs
//! produce byte-identical `autoscale.*` counters, and the counters are
//! outside the `ci/serve_smoke.sh` grep prefixes, so the committed
//! golden never changes while the feature is off.

use std::collections::BTreeMap;

use super::devices::WireCtx;
use super::orchestrator::{DeploymentSpec, Orchestrator, RestartPolicy};
use super::topology::{NodeId, PoolTopology};
use crate::config::SystemConfig;
use crate::coordinator::{
    serve_with_hook, EchoExecutor, QueuePressure, ServeHook, ServeParams, ServeReport,
};
use crate::layerstore::PoolLayerCache;
use crate::metrics::{names, Counters, LatencyHistogram};
use crate::sim::{tag, tag_kind, PoolSim};
use crate::util::SimTime;
use crate::workloads::{trace_arrivals, workload_named, ArrivalParams};

/// Event-tag kind of one controller tick (payload unused).
pub const EV_AUTOSCALE_TICK: u8 = 0xA5;

/// Tunables of the scaling controller.
#[derive(Clone, Copy, Debug)]
pub struct AutoScaleParams {
    /// Controller cadence on the shared clock.
    pub tick: SimTime,
    /// Queue depth (queued + blocked, [`QueuePressure::depth`]) at or
    /// above which a tick counts as *hot*.
    pub high_depth: usize,
    /// Consecutive hot ticks before a scale-out commits.
    pub sustain_ticks: u32,
    /// Consecutive fully-idle ticks before one replica is retired.
    pub idle_ticks: u32,
    /// Replica ceiling for the managed deployment.
    pub max_replicas: u32,
    /// How many ranked candidates predictive prefetch warms per hot
    /// tick (the scale-out hedge set).
    pub candidates: usize,
    /// Warm candidates on the background lane *before* commit; `false`
    /// is the reactive baseline (all layer traffic at commit time).
    pub predictive: bool,
}

impl Default for AutoScaleParams {
    fn default() -> Self {
        AutoScaleParams {
            tick: SimTime::ms(1),
            high_depth: 4,
            sustain_ticks: 3,
            idle_ticks: 8,
            max_replicas: 8,
            candidates: 2,
            predictive: false,
        }
    }
}

/// What one autoscaled run did, exported as `autoscale.*` counters.
#[derive(Clone, Debug, Default)]
pub struct AutoScaleReport {
    pub ticks: u64,
    pub scale_outs: u64,
    pub scale_ins: u64,
    /// Scale-outs whose node was missing at least one layer at commit.
    pub cold_boots: u64,
    /// Scale-outs whose node held (or had in flight) every layer.
    pub warm_boots: u64,
    /// Layer bytes the predictive controller had already put in flight
    /// toward the nodes its scale-outs later committed on.
    pub prefetch_hidden_bytes: u64,
    /// Per-boot cold start: scale-out commit to every layer landed.
    pub coldstart: LatencyHistogram,
}

impl AutoScaleReport {
    /// The headline number: p99 of commit-to-boot-ready.
    pub fn coldstart_p99(&self) -> SimTime {
        self.coldstart.quantile(0.99)
    }

    pub fn export_counters(&self, c: &mut Counters) {
        c.add(names::AUTOSCALE_TICKS, self.ticks);
        c.add(names::AUTOSCALE_SCALE_OUTS, self.scale_outs);
        c.add(names::AUTOSCALE_SCALE_INS, self.scale_ins);
        c.add(names::AUTOSCALE_COLD_BOOTS, self.cold_boots);
        c.add(names::AUTOSCALE_WARM_BOOTS, self.warm_boots);
        c.add(names::AUTOSCALE_PREFETCH_HIDDEN_BYTES, self.prefetch_hidden_bytes);
        c.add(names::AUTOSCALE_COLDSTART_P99_NS, self.coldstart_p99().as_ns());
    }
}

/// Everything a finished autoscaled run hands back: the report plus the
/// pool-management state, returned for invariant checks and continued
/// use (mirrors [`crate::chaos::ChaosOutcome`]).
pub struct AutoScaleOutcome {
    pub report: AutoScaleReport,
    pub topo: PoolTopology,
    pub orch: Orchestrator,
    pub cache: PoolLayerCache,
}

/// See the module docs.  Build with [`AutoScaler::new`], arm on the sim
/// queue, pass as the hook to
/// [`crate::coordinator::serve_with_hook`], then [`AutoScaler::finish`].
pub struct AutoScaler {
    params: AutoScaleParams,
    topo: PoolTopology,
    orch: Orchestrator,
    cache: PoolLayerCache,
    /// The deployment being scaled.
    deployment: String,
    /// The image recipe scale-outs must land: `(digest, bytes)` layers.
    layers: Vec<(u64, u64)>,
    hot_streak: u32,
    idle_streak: u32,
    /// Bytes predictive prefetch put in flight per candidate, credited
    /// to `prefetch_hidden_bytes` if that candidate's scale-out commits.
    warmed: BTreeMap<NodeId, u64>,
    report: AutoScaleReport,
}

impl AutoScaler {
    /// Take ownership of the pool-management state for the run.
    /// `layers` is the deployment image's layer recipe — what a
    /// scale-out must have resident before the replica is boot-ready.
    pub fn new(
        topo: PoolTopology,
        orch: Orchestrator,
        cache: PoolLayerCache,
        deployment: impl Into<String>,
        layers: Vec<(u64, u64)>,
        params: AutoScaleParams,
    ) -> Self {
        AutoScaler {
            params,
            topo,
            orch,
            cache,
            deployment: deployment.into(),
            layers,
            hot_streak: 0,
            idle_streak: 0,
            warmed: BTreeMap::new(),
            report: AutoScaleReport {
                coldstart: LatencyHistogram::new(),
                ..Default::default()
            },
        }
    }

    /// Schedule the first tick.  Each tick re-arms the next one; the
    /// chain self-terminates once the loop is idle and the last replica
    /// has been retired, so no horizon needs to be guessed up front.
    pub fn arm(&mut self, sim: &mut PoolSim) {
        sim.queue
            .schedule_at(sim.now() + self.params.tick, tag(EV_AUTOSCALE_TICK, 0));
    }

    /// Pool state mid-run (the live orchestrator, for assertions).
    pub fn orch(&self) -> &Orchestrator {
        &self.orch
    }

    /// The report accumulated so far.
    pub fn report(&self) -> &AutoScaleReport {
        &self.report
    }

    /// Fold the run into an [`AutoScaleOutcome`], handing the pool state
    /// back.  Background prefetch tails still in flight stay on the
    /// fabric engine; settle them with `sim.fabric.run_to_idle()` before
    /// exporting fabric counters, as every other run path does.
    pub fn finish(self, _sim: &mut PoolSim) -> AutoScaleOutcome {
        AutoScaleOutcome {
            report: self.report,
            topo: self.topo,
            orch: self.orch,
            cache: self.cache,
        }
    }

    /// Every hot tick in predictive mode: warm the top-ranked
    /// candidates' missing layers on the background lane, and remember
    /// how many bytes each candidate got ahead of time.
    fn prefetch_toward_candidates(&mut self, sim: &mut PoolSim, now: SimTime) {
        let mut wire = WireCtx::at(&mut sim.fabric, &self.topo, &mut sim.ftls, now);
        let top: Vec<NodeId> = self
            .orch
            .rank_candidates(&wire, &self.deployment, &self.cache, &self.layers)
            .into_iter()
            .take(self.params.candidates)
            .collect();
        for (node, bytes) in self.cache.prefetch_set(&mut wire, &top, &self.layers) {
            if bytes > 0 {
                *self.warmed.entry(node).or_insert(0) += bytes;
            }
        }
    }

    /// Commit one scale-out on the cheapest-boot candidate: classify
    /// the boot (warm = every layer resident or already in flight),
    /// land the layers foreground — which settles any prefetch tail —
    /// record commit-to-boot-ready, and place the replica.
    fn commit_scale_out(&mut self, sim: &mut PoolSim, now: SimTime) {
        let mut wire = WireCtx::at(&mut sim.fabric, &self.topo, &mut sim.ftls, now);
        let ranked = self
            .orch
            .rank_candidates(&wire, &self.deployment, &self.cache, &self.layers);
        let Some(&node) = ranked.first() else {
            return; // every healthy node already hosts a replica
        };
        let warm = self.layers.iter().all(|&(d, _)| self.cache.node_has(node, d));
        let mut boot_ready = now;
        for &(digest, bytes) in &self.layers {
            let (_, latency) = self.cache.fetch(&mut wire, node, digest, bytes);
            boot_ready = boot_ready.max(now + latency);
        }
        self.report.coldstart.record(boot_ready.saturating_sub(now));
        if warm {
            self.report.warm_boots += 1;
        } else {
            self.report.cold_boots += 1;
        }
        self.report.prefetch_hidden_bytes += self.warmed.remove(&node).unwrap_or(0);
        self.orch.scale_out_on(&self.deployment, node);
        self.report.scale_outs += 1;
    }

    fn on_tick(&mut self, sim: &mut PoolSim, now: SimTime, pressure: QueuePressure) {
        self.report.ticks += 1;
        let mut rearm = true;
        if pressure.depth() >= self.params.high_depth {
            self.idle_streak = 0;
            self.hot_streak += 1;
            if self.params.predictive {
                // warm candidates from the *first* hot tick: the layers
                // are in flight while the streak is still sustaining
                self.prefetch_toward_candidates(sim, now);
            }
            if self.hot_streak >= self.params.sustain_ticks {
                self.hot_streak = 0;
                if self.orch.running_count(&self.deployment) < self.params.max_replicas {
                    self.commit_scale_out(sim, now);
                }
            }
        } else if pressure.idle() {
            self.hot_streak = 0;
            self.idle_streak += 1;
            if self.idle_streak >= self.params.idle_ticks {
                self.idle_streak = 0;
                if self.orch.scale_in(&self.deployment).is_some() {
                    self.report.scale_ins += 1;
                } else {
                    // idle pool, nothing running: the tick chain ends
                    rearm = false;
                }
            }
        } else {
            // partial pressure: neither streak accumulates
            self.hot_streak = 0;
            self.idle_streak = 0;
        }
        if rearm {
            sim.queue
                .schedule_at(now + self.params.tick, tag(EV_AUTOSCALE_TICK, 0));
        }
    }
}

impl ServeHook for AutoScaler {
    /// Pressure-blind delivery (not used by the serve loop, which
    /// always calls the pressure variant): a tick with no load signal
    /// reads as idle.
    fn on_event(&mut self, sim: &mut PoolSim, now: SimTime, tag: u64) {
        self.on_event_with_pressure(sim, now, tag, QueuePressure::default());
    }

    fn on_event_with_pressure(
        &mut self,
        sim: &mut PoolSim,
        now: SimTime,
        tag: u64,
        pressure: QueuePressure,
    ) {
        if tag_kind(tag) == EV_AUTOSCALE_TICK {
            self.on_tick(sim, now, pressure);
        }
    }
}

/// What one [`flash_crowd`] run produced.
pub struct FlashCrowdOutcome {
    pub report: ServeReport,
    pub scale: AutoScaleOutcome,
    /// `serve.*` + `fabric.*` + `sim.*` + `autoscale.*` counters with
    /// the fabric engine drained, for byte-identity comparisons.
    pub counters: Counters,
    /// Requests in the generated arrival stream.
    pub requests: usize,
}

/// The scenario the tier-1 pin test and `benches/autoscale.rs` share: a
/// Table 2 row replayed as a flash crowd against a deliberately
/// under-provisioned serving pool (two replicas on the default
/// 16-node topology, image warm only on the hosts), with the autoscaler
/// ticking on the same clock.  The trace's service backlog keeps the
/// queue depth above the hot threshold for most of the run, so the
/// controller commits at least one scale-out onto a node whose layers
/// must come over the wire — foreground at commit for the reactive
/// controller, background-ahead-of-commit for the predictive one.
///
/// Deterministic for a given `(workload, seed, predictive)`.
pub fn flash_crowd(
    workload: &str,
    seed: u64,
    predictive: bool,
) -> Result<FlashCrowdOutcome, String> {
    const SERVING_NODES: usize = 2;
    let Some(spec) = workload_named(workload) else {
        return Err(format!("unknown workload {workload:?}"));
    };
    let cfg = SystemConfig::default();
    let mut params = ServeParams::from_config(&cfg.serve);
    // scale 500 leaves enough requests that the backlog outlives the
    // controller's sustain window on every Table 2 row
    let ap = ArrivalParams {
        scale: 500,
        ..Default::default()
    };
    params.prompt_len = ap.engine_prompt_len();
    let arr = trace_arrivals(&spec, seed, &ap);
    let requests = arr.requests.len();

    let mut sim = PoolSim::new(&cfg);
    let topo = PoolTopology::build(&cfg.pool);
    let mut orch = Orchestrator::new();
    let mut cache = PoolLayerCache::new();
    let layers = crate::smoke::boot_storm_layers();
    let placed = orch.deploy(
        &topo,
        &DeploymentSpec {
            name: "svc".into(),
            image: "llm-worker".into(),
            replicas: SERVING_NODES as u32,
            restart: RestartPolicy::OnFailure,
        },
    )?;
    // the image is resident exactly where it already runs: scale-out
    // targets must pull it from those peers (or, predictively, have it
    // pushed ahead of the commit)
    for &node in &placed {
        for &(d, _) in &layers {
            cache.register(node, d);
        }
    }
    let mut scaler = AutoScaler::new(
        topo,
        orch,
        cache,
        "svc",
        layers,
        AutoScaleParams {
            // 12 hot ticks at 5ms give predictive prefetch a 55ms lead
            // over the commit — enough for the image to cross the array
            // links ahead of the decision
            tick: SimTime::ms(5),
            high_depth: 4,
            sustain_ticks: 12,
            idle_ticks: 8,
            max_replicas: SERVING_NODES as u32 + 1,
            candidates: 2,
            predictive,
        },
    );
    scaler.arm(&mut sim);
    let factories: Vec<_> = (0..SERVING_NODES)
        .map(|_| || Ok::<_, anyhow::Error>(EchoExecutor))
        .collect();
    let report = serve_with_hook(&mut sim, factories, arr.requests, &params, &mut scaler);
    let scale = scaler.finish(&mut sim);
    sim.fabric.run_to_idle();
    let mut counters = Counters::new();
    report.export_counters(&mut counters);
    sim.export_counters(&mut counters);
    scale.report.export_counters(&mut counters);
    Ok(FlashCrowdOutcome {
        report,
        scale,
        counters,
        requests,
    })
}

/// The PR 4 baseline the autoscaler's cold-start numbers are measured
/// against: a two-replica [`Orchestrator::boot_storm_sim`] of the same
/// image on a cold pool — every layer crosses the registry WAN in the
/// foreground.  Returns when the last pull byte lands (the storm starts
/// at t=0, so this *is* the cold-start makespan).
pub fn boot_storm_coldstart_baseline() -> SimTime {
    let cfg = SystemConfig::default();
    let mut sim = PoolSim::new(&cfg);
    let topo = PoolTopology::build(&cfg.pool);
    let mut orch = Orchestrator::new();
    let mut cache = PoolLayerCache::new();
    let rep = orch
        .boot_storm_sim(
            &mut sim,
            &topo,
            &DeploymentSpec {
                name: "storm".into(),
                image: "llm-worker".into(),
                replicas: 2,
                restart: RestartPolicy::OnFailure,
            },
            &mut cache,
            &crate::smoke::boot_storm_layers(),
        )
        .expect("the default pool has healthy nodes");
    rep.pulls_done
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EtherOnConfig, PoolConfig};

    fn rig(nodes: u32) -> (PoolSim, AutoScaler) {
        let pool = PoolConfig {
            nodes_per_array: nodes,
            arrays: 1,
            ..Default::default()
        };
        let sim = PoolSim::with_pool(&pool, &EtherOnConfig::default());
        let topo = PoolTopology::build(&pool);
        let mut orch = Orchestrator::new();
        let mut cache = PoolLayerCache::new();
        let layers: Vec<(u64, u64)> = (0..4u64).map(|i| (0xA5_00 + i, 8 << 20)).collect();
        let placed = orch
            .deploy(
                &topo,
                &DeploymentSpec {
                    name: "svc".into(),
                    image: "llm-worker".into(),
                    replicas: 2,
                    restart: RestartPolicy::OnFailure,
                },
            )
            .unwrap();
        for &node in &placed {
            for &(d, _) in &layers {
                cache.register(node, d);
            }
        }
        let scaler = AutoScaler::new(
            topo,
            orch,
            cache,
            "svc",
            layers,
            AutoScaleParams {
                tick: SimTime::ms(1),
                high_depth: 2,
                sustain_ticks: 2,
                idle_ticks: 2,
                max_replicas: 4,
                candidates: 1,
                predictive: false,
            },
        );
        (sim, scaler)
    }

    fn hot() -> QueuePressure {
        QueuePressure {
            queued: 8,
            blocked: 0,
            inflight: 2,
            oldest_wait: SimTime::us(500),
        }
    }

    fn tick_at(scaler: &mut AutoScaler, sim: &mut PoolSim, ms: u64, p: QueuePressure) {
        scaler.on_event_with_pressure(sim, SimTime::ms(ms), tag(EV_AUTOSCALE_TICK, 0), p);
    }

    #[test]
    fn sustained_pressure_scales_out_onto_ranked_nodes() {
        let (mut sim, mut scaler) = rig(4);
        tick_at(&mut scaler, &mut sim, 1, hot());
        assert_eq!(scaler.report().scale_outs, 0, "one hot tick does not sustain");
        tick_at(&mut scaler, &mut sim, 2, hot());
        assert_eq!(scaler.report().scale_outs, 1, "second consecutive hot tick commits");
        assert_eq!(scaler.report().cold_boots, 1, "reactive boots are cold");
        assert_eq!(scaler.orch().running_count("svc"), 3);
        // interleaved partial pressure resets the streak
        tick_at(&mut scaler, &mut sim, 3, hot());
        tick_at(
            &mut scaler,
            &mut sim,
            4,
            QueuePressure {
                queued: 1,
                inflight: 1,
                ..Default::default()
            },
        );
        tick_at(&mut scaler, &mut sim, 5, hot());
        assert_eq!(scaler.report().scale_outs, 1, "broken streak must re-sustain");
        tick_at(&mut scaler, &mut sim, 6, hot());
        assert_eq!(scaler.report().scale_outs, 2);
        assert_eq!(scaler.orch().running_count("svc"), 4);
        // at max_replicas further sustained pressure commits nothing
        tick_at(&mut scaler, &mut sim, 7, hot());
        tick_at(&mut scaler, &mut sim, 8, hot());
        assert_eq!(scaler.report().scale_outs, 2, "replica ceiling holds");
        let out = scaler.finish(&mut sim);
        // both scale-outs landed the full image on their nodes
        for node in [2u32, 3] {
            for d in (0..4u64).map(|i| 0xA5_00 + i) {
                assert!(out.cache.node_has(node, d), "node {node} holds {d:#x}");
            }
        }
        assert!(out.report.coldstart.count() == 2);
        assert!(out.report.coldstart_p99() > SimTime::ZERO, "cold boots take wire time");
    }

    #[test]
    fn idle_ticks_scale_the_pool_back_in_and_end_the_chain() {
        let (mut sim, mut scaler) = rig(4);
        // 2 replicas running, idle_ticks = 2: every second idle tick
        // retires one, and the tick after the last retirement stops
        // re-arming the chain
        for ms in 1..=4u64 {
            tick_at(&mut scaler, &mut sim, ms, QueuePressure::default());
        }
        assert_eq!(scaler.report().scale_ins, 2, "both replicas retired LIFO");
        assert_eq!(scaler.orch().running_count("svc"), 0);
        let before = sim.queue.len();
        tick_at(&mut scaler, &mut sim, 5, QueuePressure::default());
        tick_at(&mut scaler, &mut sim, 6, QueuePressure::default());
        // the empty-pool retirement attempt did not schedule a successor
        assert!(
            sim.queue.len() < before + 2,
            "an idle, empty pool must stop re-arming ticks"
        );
        assert_eq!(scaler.report().scale_outs, 0);
    }

    #[test]
    fn predictive_prefetch_turns_the_boot_warm_and_cheaper() {
        let run = |predictive: bool| {
            let (mut sim, mut scaler) = rig(4);
            scaler.params.predictive = predictive;
            tick_at(&mut scaler, &mut sim, 1, hot());
            tick_at(&mut scaler, &mut sim, 2, hot());
            assert_eq!(scaler.report().scale_outs, 1);
            let out = scaler.finish(&mut sim);
            sim.fabric.run_to_idle();
            out
        };
        let reactive = run(false);
        let predictive = run(true);
        assert_eq!(reactive.report.cold_boots, 1);
        assert_eq!(reactive.report.warm_boots, 0);
        assert_eq!(predictive.report.cold_boots, 0);
        assert_eq!(
            predictive.report.warm_boots, 1,
            "the candidate was warm (in flight) at commit"
        );
        assert!(
            predictive.report.prefetch_hidden_bytes >= 32 << 20,
            "all four layers were moving before the commit: {}",
            predictive.report.prefetch_hidden_bytes
        );
        // the commit-time fetch settles only the in-flight tail, which
        // is strictly shorter than moving everything foreground at
        // commit (compare exact maxima, not log-bucketed quantiles)
        assert!(
            predictive.report.coldstart.max() < reactive.report.coldstart.max(),
            "predictive {} !< reactive {}",
            predictive.report.coldstart.max(),
            reactive.report.coldstart.max()
        );
    }

    #[test]
    fn flash_crowd_predictive_beats_reactive_and_the_boot_storm_baseline() {
        let baseline = boot_storm_coldstart_baseline();
        assert!(baseline > SimTime::ZERO);
        for row in ["mariadb-tpch4", "nginx-filedown"] {
            let reactive = flash_crowd(row, 42, false).unwrap();
            let predictive = flash_crowd(row, 42, true).unwrap();
            for (mode, out) in [("reactive", &reactive), ("predictive", &predictive)] {
                assert_eq!(
                    out.report.responses.len(),
                    out.requests,
                    "{row}/{mode}: autoscaling must not lose requests"
                );
                assert!(
                    out.scale.report.scale_outs >= 1,
                    "{row}/{mode}: the flash crowd must trigger a scale-out"
                );
            }
            assert!(
                reactive.scale.report.cold_boots >= 1,
                "{row}: reactive boots pull layers at commit"
            );
            assert!(
                predictive.scale.report.warm_boots >= 1,
                "{row}: predictive boots from warm peers"
            );
            let (p99_p, p99_r) = (
                predictive.scale.report.coldstart_p99(),
                reactive.scale.report.coldstart_p99(),
            );
            assert!(
                p99_p < p99_r,
                "{row}: predictive p99 {p99_p} !< reactive p99 {p99_r}"
            );
            assert!(
                p99_p < baseline,
                "{row}: predictive p99 {p99_p} !< boot-storm baseline {baseline}"
            );
        }
    }

    #[test]
    fn same_seed_flash_crowds_are_byte_identical() {
        let a = flash_crowd("nginx-filedown", 42, true).unwrap();
        let b = flash_crowd("nginx-filedown", 42, true).unwrap();
        assert_eq!(a.counters, b.counters, "same-seed replays must match byte-for-byte");
        assert!(a.counters.get(names::AUTOSCALE_TICKS) > 0);
        let c = flash_crowd("nginx-filedown", 43, true).unwrap();
        assert_ne!(a.counters, c.counters, "different seeds must actually differ");
    }
}
