//! DockerSSD: containerized in-storage processing and computing-enabled SSD
//! disaggregation — a full-system reproduction of the CS.AR 2025 paper.
//!
//! The crate is organized as the paper's stack (DESIGN.md §2):
//!
//! * Substrates: [`nvme`] (queues/commands/namespaces), [`etheron`]
//!   (Ethernet-over-NVMe), [`ssd`] (flash timing + FTL + ICL), [`lambdafs`]
//!   (the λ filesystem), [`firmware`] (Virtual-FW handlers + syscall
//!   emulation), [`docker`] (mini-docker container environment),
//!   [`layerstore`] (content-addressed layer storage: chunk-level dedup,
//!   copy-on-write writable layers, and the pool-wide layer-presence
//!   cache that turns replica boots into peer fetches instead of
//!   registry round trips), [`fabric`] (the pool-wide message fabric:
//!   contention-aware per-link bandwidth queues — with an event-driven
//!   re-timing engine — that every cross-node and host/WAN transfer
//!   routes through).
//! * Simulation core: [`sim`] (the deterministic event queue and
//!   [`sim::PoolSim`], the one clock + fabric + per-node compute bundle
//!   every timing consumer shares).
//! * Evaluation substrates: [`models`] (the six data-processing models),
//!   [`workloads`] (Table 2 generators), [`llm`] (the analytic
//!   distributed-inference simulator), [`pool`] (disaggregated storage pool).
//! * Serving: `runtime` (PJRT artifact execution, behind the `pjrt`
//!   feature — the xla bindings are unavailable offline), [`coordinator`]
//!   (router + batcher + KV manager on the simulated clock, driving real
//!   token generation deterministically), [`smoke`] (the deterministic
//!   trace-replay scenario shared by the `repro serve` CLI and the CI
//!   golden gate).
//! * Robustness: [`chaos`] (seeded deterministic fault injection — node
//!   death, array loss, link brownouts, registry stalls — plus the
//!   self-healing loop that re-places replicas and re-replicates chunks
//!   back to the k-holder invariant over background lanes).

pub mod benchkit;
pub mod chaos;
pub mod config;
pub mod coordinator;
pub mod docker;
pub mod json;
pub mod etheron;
#[cfg(feature = "pjrt")]
pub mod examples_support;
pub mod fabric;
pub mod firmware;
pub mod lambdafs;
pub mod layerstore;
pub mod llm;
pub mod metrics;
pub mod models;
pub mod nvme;
pub mod pool;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sim;
pub mod smoke;
pub mod ssd;
pub mod util;
pub mod workloads;
