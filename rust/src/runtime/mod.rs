//! PJRT runtime (DESIGN.md S11): load the AOT artifacts produced by
//! `make artifacts` (HLO text + weights.bin + manifest.json) and execute
//! them on the PJRT CPU client.  This is the *real* compute path of the
//! serving case study — Python never runs here.
//!
//! Interchange is HLO **text** (see python/compile/aot.py): jax >= 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::json::{parse, Json};

/// Static model configuration from the artifact manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub batch: usize,
    pub prompt_len: usize,
    pub head_dim: usize,
    pub param_count: u64,
}

/// One parameter's location within weights.bin.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_bytes: usize,
    pub size_bytes: usize,
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: ArtifactConfig,
    pub params: Vec<ParamEntry>,
    pub weights_bytes: usize,
    pub dir: PathBuf,
    pub prefill_hlo: String,
    pub decode_hlo: String,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!(
                "reading {}/manifest.json (run `make artifacts`)",
                dir.display()
            )
        })?;
        let v = parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let c = v
            .get("config")
            .ok_or_else(|| anyhow!("manifest missing config"))?;
        let get = |k: &str| -> Result<usize> {
            c.get(k)
                .and_then(Json::as_u64)
                .map(|x| x as usize)
                .ok_or_else(|| anyhow!("config.{k} missing"))
        };
        let config = ArtifactConfig {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
            max_seq: get("max_seq")?,
            batch: get("batch")?,
            prompt_len: get("prompt_len")?,
            head_dim: get("head_dim")?,
            param_count: c.get("param_count").and_then(Json::as_u64).unwrap_or(0),
        };
        let params = v
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing params"))?
            .iter()
            .map(|p| -> Result<ParamEntry> {
                Ok(ParamEntry {
                    name: p.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                    shape: p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .map(|s| s.iter().filter_map(Json::as_u64).map(|x| x as usize).collect())
                        .unwrap_or_default(),
                    offset_bytes: p.get("offset_bytes").and_then(Json::as_u64).unwrap_or(0) as usize,
                    size_bytes: p.get("size_bytes").and_then(Json::as_u64).unwrap_or(0) as usize,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let arts = v
            .get("artifacts")
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        let art = |k: &str| -> Result<String> {
            Ok(arts
                .get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifacts.{k} missing"))?
                .to_string())
        };
        Ok(Manifest {
            config,
            params,
            weights_bytes: v.get("weights_bytes").and_then(Json::as_u64).unwrap_or(0) as usize,
            dir: dir.to_path_buf(),
            prefill_hlo: art("prefill")?,
            decode_hlo: art("decode")?,
        })
    }

    pub fn kv_cache_elems(&self) -> usize {
        let c = &self.config;
        c.n_layers * c.batch * c.n_heads * c.max_seq * c.head_dim
    }
}

/// Loaded weights: one f32 buffer per parameter, in manifest order
/// (the argument-order ABI shared with aot.py).
pub struct Weights {
    pub tensors: Vec<(String, Vec<usize>, Vec<f32>)>,
}

impl Weights {
    pub fn load(m: &Manifest) -> Result<Weights> {
        let blob = std::fs::read(m.dir.join("weights.bin"))
            .with_context(|| "reading weights.bin (run `make artifacts`)")?;
        if blob.len() != m.weights_bytes {
            bail!(
                "weights.bin is {} bytes, manifest says {}",
                blob.len(),
                m.weights_bytes
            );
        }
        let mut tensors = Vec::with_capacity(m.params.len());
        for p in &m.params {
            let end = p.offset_bytes + p.size_bytes;
            if end > blob.len() {
                bail!("param {} overruns weights.bin", p.name);
            }
            let floats: Vec<f32> = blob[p.offset_bytes..end]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            let expect: usize = p.shape.iter().product();
            if floats.len() != expect {
                bail!("param {}: {} floats != shape {:?}", p.name, floats.len(), p.shape);
            }
            tensors.push((p.name.clone(), p.shape.clone(), floats));
        }
        Ok(Weights { tensors })
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|(_, _, t)| t.len()).sum()
    }
}

fn literal_from_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

fn literal_from_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// The per-node inference engine: compiled prefill + decode executables,
/// resident weights, and the KV cache carried between steps.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
    /// Weight literals in PARAM_ORDER.  (A device-resident PjRtBuffer
    /// variant was attempted — §Perf L3 iteration 2 — but xla_extension
    /// 0.5.1 mis-sizes literals decomposed from tuple outputs on
    /// re-upload, so the engine stays on the literal execute path; XLA
    /// compute dominates the step time regardless.)
    weight_literals: Vec<xla::Literal>,
    k_cache: Option<xla::Literal>,
    v_cache: Option<xla::Literal>,
    /// tokens decoded so far (also the cache write position).
    pub pos: usize,
    pub decode_steps: u64,
}

/// One step's result: next-token logits per batch row.
pub struct StepOutput {
    pub logits: Vec<Vec<f32>>,
}

impl StepOutput {
    /// Greedy argmax per row.
    pub fn argmax(&self) -> Vec<i32> {
        self.logits
            .iter()
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect()
    }
}

impl Engine {
    /// Load artifacts from `dir`, compile both executables on the PJRT CPU
    /// client, and upload the weights.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let weights = Weights::load(&manifest)?;
        let client = xla::PjRtClient::cpu()?;

        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = manifest.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let prefill_exe = compile(&manifest.prefill_hlo)?;
        let decode_exe = compile(&manifest.decode_hlo)?;

        let weight_literals = weights
            .tensors
            .iter()
            .map(|(_, shape, data)| literal_from_f32(shape, data))
            .collect::<Result<Vec<_>>>()?;

        Ok(Engine {
            manifest,
            client,
            prefill_exe,
            decode_exe,
            weight_literals,
            k_cache: None,
            v_cache: None,
            pos: 0,
            decode_steps: 0,
        })
    }

    pub fn batch(&self) -> usize {
        self.manifest.config.batch
    }

    pub fn prompt_len(&self) -> usize {
        self.manifest.config.prompt_len
    }

    pub fn max_seq(&self) -> usize {
        self.manifest.config.max_seq
    }

    fn unpack3(&self, result: xla::Literal) -> Result<(StepOutput, xla::Literal, xla::Literal)> {
        let mut elems = result.to_tuple()?;
        if elems.len() != 3 {
            bail!("expected (logits, k, v) tuple, got {} elements", elems.len());
        }
        let v_cache = elems.pop().unwrap();
        let k_cache = elems.pop().unwrap();
        let logits_lit = elems.pop().unwrap();
        let flat = logits_lit.to_vec::<f32>()?;
        let vocab = self.manifest.config.vocab;
        let logits = flat.chunks(vocab).map(|c| c.to_vec()).collect();
        Ok((StepOutput { logits }, k_cache, v_cache))
    }

    /// Run prefill on a [batch, prompt_len] prompt, (re)initializing the
    /// KV cache.  Returns last-position logits.
    pub fn prefill(&mut self, prompt: &[Vec<i32>]) -> Result<StepOutput> {
        let c = &self.manifest.config;
        if prompt.len() != c.batch || prompt.iter().any(|r| r.len() != c.prompt_len) {
            bail!("prompt must be [{} x {}]", c.batch, c.prompt_len);
        }
        let flat: Vec<i32> = prompt.iter().flatten().copied().collect();
        let prompt_lit = literal_from_i32(&[c.batch, c.prompt_len], &flat)?;
        let mut args: Vec<&xla::Literal> = vec![&prompt_lit];
        args.extend(self.weight_literals.iter());
        let result = self.prefill_exe.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (out, k, v) = self.unpack3(result)?;
        self.k_cache = Some(k);
        self.v_cache = Some(v);
        self.pos = c.prompt_len;
        Ok(out)
    }

    /// One autoregressive step: feed `tokens` (the batch's current tokens,
    /// written at cache row `pos`), get next-token logits.
    pub fn decode_step(&mut self, tokens: &[i32]) -> Result<StepOutput> {
        let c = &self.manifest.config;
        if tokens.len() != c.batch {
            bail!("need {} tokens, got {}", c.batch, tokens.len());
        }
        if self.pos >= c.max_seq {
            bail!("KV cache full (max_seq {})", c.max_seq);
        }
        let (Some(k), Some(v)) = (&self.k_cache, &self.v_cache) else {
            bail!("decode before prefill");
        };
        let tok_lit = literal_from_i32(&[c.batch], tokens)?;
        let pos_lit = xla::Literal::scalar(self.pos as i32);
        let mut args: Vec<&xla::Literal> = vec![&tok_lit, &pos_lit, k, v];
        args.extend(self.weight_literals.iter());
        let result = self.decode_exe.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (out, k, v) = self.unpack3(result)?;
        self.k_cache = Some(k);
        self.v_cache = Some(v);
        self.pos += 1;
        self.decode_steps += 1;
        Ok(out)
    }

    /// Generate greedily: prefill the prompt then decode `new_tokens`
    /// steps.  Returns per-row generated token ids.
    pub fn generate(&mut self, prompt: &[Vec<i32>], new_tokens: usize) -> Result<Vec<Vec<i32>>> {
        let out = self.prefill(prompt)?;
        let mut cur = out.argmax();
        let mut gen: Vec<Vec<i32>> = cur.iter().map(|&t| vec![t]).collect();
        for _ in 1..new_tokens {
            if self.pos >= self.max_seq() {
                break;
            }
            let out = self.decode_step(&cur)?;
            cur = out.argmax();
            for (row, &t) in gen.iter_mut().zip(cur.iter()) {
                row.push(t);
            }
        }
        Ok(gen)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        art_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(&art_dir()).unwrap();
        assert_eq!(m.config.d_model % m.config.n_heads, 0);
        assert_eq!(m.params.len(), 16);
        assert!(m.config.param_count > 1_000_000);
    }

    #[test]
    fn weights_load_and_match_manifest() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&art_dir()).unwrap();
        let w = Weights::load(&m).unwrap();
        assert_eq!(w.total_params() as u64, m.config.param_count);
        // layernorm scales initialize to exactly 1.0
        let ln = w.tensors.iter().find(|(n, _, _)| n == "lnf_s").unwrap();
        assert!(ln.2.iter().all(|&x| x == 1.0));
    }
}
