//! The injector: replays a [`ChaosSchedule`] into a live serving run
//! and drives the self-healing loop after every wound.
//!
//! The injector *owns* the pool-management triple — topology,
//! orchestrator, layer cache — for the duration of the run, and plugs
//! into the serving loop as a [`ServeHook`]: every fault is an ordinary
//! event on the [`PoolSim`] queue, popped in deterministic time order
//! between arrivals, batch completions, and deadlines.  When a node
//! dies mid-run the reaction is immediate and on-clock:
//!
//! 1. the topology marks it unhealthy (planning stops picking it),
//! 2. the orchestrator re-places its replicas on survivors
//!    ([`Orchestrator::node_failed`] → `replica_failed` per replica),
//! 3. the layer cache purges its registrations
//!    ([`PoolLayerCache::purge_node`]) so no plan counts a ghost, and
//! 4. a healing pass re-replicates every under-`k` chunk over the
//!    fabric's *background* lanes
//!    ([`PoolLayerCache::rereplicate_chunks`]) — repair traffic
//!    contends with (and yields to) the foreground serving it protects.
//!
//! Brownouts open a degraded-bandwidth window on one link
//! ([`crate::fabric::Fabric::begin_brownout`]) and schedule their own
//! restore event; [`ChaosInjector::finish`] closes anything still open,
//! runs a final heal sweep, settles the heal transfers, and folds the
//! run into a [`ChaosOutcome`].

use std::collections::BTreeMap;

use super::heal::HealReport;
use super::report::{availability_ppm, ChaosReport};
use super::schedule::{ChaosSchedule, FaultKind};
use crate::coordinator::ServeHook;
use crate::fabric::LinkClass;
use crate::layerstore::PoolLayerCache;
use crate::pool::{NodeId, Orchestrator, PoolTopology, RestartPolicy, WireCtx};
use crate::sim::{tag, tag_kind, tag_payload, PoolSim};
use crate::util::SimTime;

/// Event-tag kind of a fault firing (payload: schedule index).
pub const EV_CHAOS_FAULT: u8 = 0xC4;
/// Event-tag kind of a brownout window closing (payload: schedule
/// index of the fault that opened it).
pub const EV_CHAOS_RESTORE: u8 = 0xC5;

/// Everything a finished chaos run hands back: the two reports plus
/// the (healed) pool state, returned to the caller for invariant
/// checks and continued use.
pub struct ChaosOutcome {
    pub report: ChaosReport,
    pub heal: HealReport,
    pub topo: PoolTopology,
    pub orch: Orchestrator,
    pub cache: PoolLayerCache,
}

impl ChaosOutcome {
    /// Post-run invariant: every live chunk is held by at least
    /// `min(k, healthy-nodes)` *healthy* holders.
    pub fn healed_to_k(&self, k: usize) -> bool {
        let healthy = self.topo.healthy_nodes().count();
        let want = k.min(healthy);
        self.cache.chunks().into_iter().all(|c| {
            self.cache
                .chunk_holders_of(c)
                .into_iter()
                .filter(|&n| self.topo.node(n).is_some_and(|pn| pn.healthy))
                .count()
                >= want
        })
    }
}

/// See the module docs.  Build with [`ChaosInjector::new`], arm on the
/// sim queue, pass as the hook to
/// [`crate::coordinator::serve_with_hook`], then [`ChaosInjector::finish`].
pub struct ChaosInjector {
    schedule: ChaosSchedule,
    topo: PoolTopology,
    orch: Orchestrator,
    cache: PoolLayerCache,
    /// The chunk-holder invariant healing restores.
    k: usize,
    policy: RestartPolicy,
    report: ChaosReport,
    heal: HealReport,
    /// Open brownout windows: which fault's restore closes each class.
    active: BTreeMap<LinkClass, u64>,
    /// `(instant, healthy nodes from that instant)` steps.
    timeline: Vec<(SimTime, u32)>,
    start: SimTime,
}

impl ChaosInjector {
    /// Take ownership of the pool-management state for the run.
    /// `k` is the chunk-holder invariant to heal back to; `policy`
    /// governs replica re-placement off dead nodes.
    pub fn new(
        schedule: ChaosSchedule,
        topo: PoolTopology,
        orch: Orchestrator,
        cache: PoolLayerCache,
        k: usize,
        policy: RestartPolicy,
    ) -> Self {
        let report = ChaosReport {
            seed: schedule.seed,
            ..Default::default()
        };
        ChaosInjector {
            schedule,
            topo,
            orch,
            cache,
            k,
            policy,
            report,
            heal: HealReport::default(),
            active: BTreeMap::new(),
            timeline: Vec::new(),
            start: SimTime::ZERO,
        }
    }

    /// Schedule every fault on the sim queue, offset from `sim.now()`.
    pub fn arm(&mut self, sim: &mut PoolSim) {
        self.start = sim.now();
        let healthy = self.topo.healthy_nodes().count() as u32;
        self.timeline.push((self.start, healthy));
        for (i, f) in self.schedule.faults.iter().enumerate() {
            sim.queue.schedule_at(self.start + f.at, tag(EV_CHAOS_FAULT, i as u64));
        }
    }

    /// The faults this run will inject (for logging / verification).
    pub fn schedule(&self) -> &ChaosSchedule {
        &self.schedule
    }

    /// Pool state mid-run (the live topology, for assertions).
    pub fn topo(&self) -> &PoolTopology {
        &self.topo
    }

    fn inject(&mut self, sim: &mut PoolSim, now: SimTime, idx: usize) {
        let Some(fault) = self.schedule.faults.get(idx).copied() else {
            return;
        };
        self.report.faults_injected += 1;
        match fault.kind {
            FaultKind::NodeDeath { node } => {
                self.report.node_deaths += 1;
                self.kill_nodes(sim, now, &[node]);
            }
            FaultKind::ArrayLoss { array } => {
                self.report.array_losses += 1;
                let victims: Vec<NodeId> = self
                    .topo
                    .healthy_nodes()
                    .filter(|n| n.array == array)
                    .map(|n| n.id)
                    .collect();
                self.kill_nodes(sim, now, &victims);
            }
            FaultKind::LinkBrownout {
                class,
                keep_pct,
                duration,
            } => {
                self.report.link_brownouts += 1;
                self.open_window(sim, now, idx, class, keep_pct, duration);
            }
            FaultKind::RegistryStall { keep_pct, duration } => {
                self.report.registry_stalls += 1;
                self.open_window(sim, now, idx, LinkClass::RegistryWan, keep_pct, duration);
            }
        }
    }

    fn open_window(
        &mut self,
        sim: &mut PoolSim,
        now: SimTime,
        idx: usize,
        class: LinkClass,
        keep_pct: u32,
        duration: SimTime,
    ) {
        sim.fabric.begin_brownout(now, class, keep_pct);
        // latest window wins the class; a superseded restore is ignored
        self.active.insert(class, idx as u64);
        sim.queue.schedule_at(now + duration, tag(EV_CHAOS_RESTORE, idx as u64));
    }

    fn close_window(&mut self, sim: &mut PoolSim, now: SimTime, idx: usize) {
        let class = match self.schedule.faults.get(idx).map(|f| f.kind) {
            Some(FaultKind::LinkBrownout { class, .. }) => class,
            Some(FaultKind::RegistryStall { .. }) => LinkClass::RegistryWan,
            _ => return,
        };
        if self.active.get(&class) == Some(&(idx as u64)) {
            sim.fabric.end_brownout(now, class);
            self.active.remove(&class);
        }
    }

    /// Simultaneous death of `nodes` + one reactive healing pass, all at
    /// `now`.  Every victim is marked dead and purged *before* anything
    /// heals, so a correlated loss (whole array) can never re-replicate
    /// out of a node that is dying in the same instant — chunks whose
    /// every copy died re-pull from the registry instead.
    fn kill_nodes(&mut self, sim: &mut PoolSim, now: SimTime, nodes: &[NodeId]) {
        let mut victims = Vec::new();
        for &node in nodes {
            if let Some(n) = self.topo.node_mut(node) {
                if n.healthy {
                    n.healthy = false;
                    victims.push(node);
                }
            }
        }
        if victims.is_empty() {
            return; // unknown or already dead: nothing to do
        }
        let healthy = self.topo.healthy_nodes().count() as u32;
        self.timeline.push((now, healthy));
        let mut orphans = Vec::new();
        for &node in &victims {
            let moved = self.orch.node_failed(&self.topo, node, self.policy);
            self.heal.replicas_restarted += moved.len() as u64;
            let purge = self.cache.purge_node(node);
            self.heal.dead_nodes_purged += 1;
            orphans.extend(purge.orphaned_chunks);
        }
        let stats = self.cache.rereplicate_chunks(
            &mut WireCtx::at(&mut sim.fabric, &self.topo, &mut sim.ftls, now),
            self.k,
            &orphans,
        );
        self.heal.absorb(stats);
    }

    /// Close out the run: end any window still open, run the final heal
    /// sweep (a later death can re-wound chunks an earlier pass fixed),
    /// settle the heal transfers, and integrate availability.
    pub fn finish(mut self, sim: &mut PoolSim) -> ChaosOutcome {
        let now = sim.now();
        let open: Vec<usize> = self.active.values().map(|&i| i as usize).collect();
        for idx in open {
            self.close_window(sim, now, idx);
        }
        let stats = self.cache.rereplicate_chunks(
            &mut WireCtx::at(&mut sim.fabric, &self.topo, &mut sim.ftls, now),
            self.k,
            &[],
        );
        self.heal.absorb(stats);
        self.heal.settle(&mut sim.fabric);
        let cfg = self.topo.config();
        let total = cfg.nodes_per_array * cfg.arrays;
        self.report.availability_ppm =
            availability_ppm(&self.timeline, total, self.start, now.max(self.start));
        ChaosOutcome {
            report: self.report,
            heal: self.heal,
            topo: self.topo,
            orch: self.orch,
            cache: self.cache,
        }
    }
}

impl ServeHook for ChaosInjector {
    fn on_event(&mut self, sim: &mut PoolSim, now: SimTime, tag: u64) {
        match tag_kind(tag) {
            EV_CHAOS_FAULT => self.inject(sim, now, tag_payload(tag) as usize),
            EV_CHAOS_RESTORE => self.close_window(sim, now, tag_payload(tag) as usize),
            _ => {} // someone else's event
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::schedule::Fault;
    use crate::config::{EtherOnConfig, PoolConfig};
    use crate::coordinator::{serve_with_hook, EchoExecutor, InferenceRequest, ServeParams};
    use crate::metrics::Counters;
    use crate::pool::DeploymentSpec;

    fn pool_cfg(nodes: u32, arrays: u32) -> PoolConfig {
        PoolConfig {
            nodes_per_array: nodes,
            arrays,
            ..Default::default()
        }
    }

    /// A 4×1 pool with a described 4-chunk blob at 2 healthy holders
    /// and one replica per node.
    fn rig() -> (PoolSim, PoolTopology, Orchestrator, PoolLayerCache) {
        let cfg = pool_cfg(4, 1);
        let mut sim = PoolSim::with_pool(&cfg, &EtherOnConfig::default());
        let topo = PoolTopology::build(&cfg);
        let mut orch = Orchestrator::new();
        let mut cache = PoolLayerCache::new();
        let recipe: Vec<(u64, u64)> = (0..4u64).map(|i| (0xC40 + i, 1 << 20)).collect();
        assert!(cache.describe_chunks(0xB10B, &recipe));
        for node in [0u32, 1] {
            cache.fetch(
                &mut WireCtx::at(&mut sim.fabric, &topo, &mut sim.ftls, SimTime::ZERO),
                node,
                0xB10B,
                4 << 20,
            );
        }
        orch.deploy(
            &topo,
            &DeploymentSpec {
                name: "infer".into(),
                image: "llm-worker".into(),
                replicas: 4,
                restart: RestartPolicy::OnFailure,
            },
        )
        .unwrap();
        (sim, topo, orch, cache)
    }

    fn reqs(n: u64) -> Vec<(SimTime, InferenceRequest)> {
        (0..n)
            .map(|id| {
                (
                    SimTime::us(id * 200),
                    InferenceRequest {
                        id,
                        prompt: vec![id as i32; 8],
                        max_new_tokens: 3,
                    },
                )
            })
            .collect()
    }

    fn params() -> ServeParams {
        ServeParams {
            batch_width: 4,
            prompt_len: 8,
            batch_window: SimTime::us(100),
            ..Default::default()
        }
    }

    fn mk() -> impl FnOnce() -> anyhow::Result<EchoExecutor> {
        || Ok(EchoExecutor)
    }

    #[test]
    fn node_death_mid_serve_heals_back_to_k_without_losing_requests() {
        let (mut sim, topo, orch, cache) = rig();
        let schedule = ChaosSchedule {
            seed: 0,
            faults: vec![Fault {
                at: SimTime::us(300),
                kind: FaultKind::NodeDeath { node: 1 },
            }],
        };
        let mut inj = ChaosInjector::new(schedule, topo, orch, cache, 2, RestartPolicy::OnFailure);
        inj.arm(&mut sim);
        let report = serve_with_hook(
            &mut sim,
            vec![mk(), mk(), mk(), mk()],
            reqs(12),
            &params(),
            &mut inj,
        );
        assert_eq!(report.responses.len(), 12, "no request is lost to the fault");
        let out = inj.finish(&mut sim);
        assert_eq!(out.report.node_deaths, 1);
        assert!(out.healed_to_k(2), "every chunk back at 2 healthy holders");
        assert!(!out.topo.node(1).unwrap().healthy);
        assert!(out.heal.copies_made >= 4, "node 1's four chunk copies re-replicated");
        assert_eq!(out.heal.dead_nodes_purged, 1);
        assert_eq!(out.heal.replicas_restarted, 1, "node 1's replica moved");
        assert!(out.heal.bytes >= 4 << 20);
        assert!(
            out.report.availability_ppm < 1_000_000,
            "a dead node shows up in availability: {}",
            out.report.availability_ppm
        );
        assert!(sim.fabric.stats.transfers_bg >= 4, "heal rides the background lane");
    }

    #[test]
    fn array_loss_repulls_orphans_across_the_wan() {
        let cfg = pool_cfg(2, 2);
        let mut sim = PoolSim::with_pool(&cfg, &EtherOnConfig::default());
        let topo = PoolTopology::build(&cfg);
        let mut cache = PoolLayerCache::new();
        // both copies live in array 0 (nodes 0 and 1)
        for node in [0u32, 1] {
            cache.fetch(
                &mut WireCtx::at(&mut sim.fabric, &topo, &mut sim.ftls, SimTime::ZERO),
                node,
                0x99,
                2 << 20,
            );
        }
        let schedule = ChaosSchedule {
            seed: 0,
            faults: vec![Fault {
                at: SimTime::us(300),
                kind: FaultKind::ArrayLoss { array: 0 },
            }],
        };
        let mut inj = ChaosInjector::new(
            schedule,
            topo,
            Orchestrator::new(),
            cache,
            2,
            RestartPolicy::OnFailure,
        );
        inj.arm(&mut sim);
        let report = serve_with_hook(&mut sim, vec![mk(), mk()], reqs(6), &params(), &mut inj);
        assert_eq!(report.responses.len(), 6);
        let out = inj.finish(&mut sim);
        assert_eq!(out.report.array_losses, 1);
        assert_eq!(out.heal.dead_nodes_purged, 2);
        assert!(
            out.heal.registry_chunks >= 1,
            "the orphaned blob's first new copy re-crossed the WAN"
        );
        assert!(out.healed_to_k(2));
        assert_eq!(out.cache.chunk_holders_of(0x99), vec![2, 3]);
    }

    #[test]
    fn brownout_windows_open_and_close_on_schedule() {
        let (mut sim, topo, orch, cache) = rig();
        let schedule = ChaosSchedule {
            seed: 0,
            faults: vec![
                Fault {
                    at: SimTime::us(200),
                    kind: FaultKind::LinkBrownout {
                        class: LinkClass::HostUplink,
                        keep_pct: 10,
                        duration: SimTime::us(400),
                    },
                },
                Fault {
                    at: SimTime::us(500),
                    kind: FaultKind::RegistryStall {
                        keep_pct: 20,
                        duration: SimTime::us(300),
                    },
                },
            ],
        };
        let mut inj = ChaosInjector::new(schedule, topo, orch, cache, 2, RestartPolicy::OnFailure);
        inj.arm(&mut sim);
        let report = serve_with_hook(
            &mut sim,
            vec![mk(), mk(), mk(), mk()],
            reqs(10),
            &params(),
            &mut inj,
        );
        assert_eq!(report.responses.len(), 10);
        let out = inj.finish(&mut sim);
        assert_eq!(out.report.link_brownouts, 1);
        assert_eq!(out.report.registry_stalls, 1);
        assert_eq!(sim.fabric.stats.link_flaps, 2);
        assert_eq!(
            sim.fabric.stats.brownout_ns,
            SimTime::us(700).as_ns(),
            "both windows closed at their scheduled width"
        );
        assert!(!sim.fabric.brownout_active(LinkClass::HostUplink));
        assert!(!sim.fabric.brownout_active(LinkClass::RegistryWan));
        assert_eq!(out.report.availability_ppm, 1_000_000, "no node died");
    }

    #[test]
    fn generated_same_seed_runs_are_byte_identical() {
        let run = |seed: u64| {
            let (mut sim, topo, orch, cache) = rig();
            let schedule = ChaosSchedule::generate(seed, &topo, SimTime::ms(3));
            let mut inj =
                ChaosInjector::new(schedule, topo, orch, cache, 2, RestartPolicy::OnFailure);
            inj.arm(&mut sim);
            let report = serve_with_hook(
                &mut sim,
                vec![mk(), mk(), mk(), mk()],
                reqs(12),
                &params(),
                &mut inj,
            );
            let out = inj.finish(&mut sim);
            sim.fabric.run_to_idle();
            let mut c = Counters::new();
            report.export_counters(&mut c);
            sim.export_counters(&mut c);
            out.report.export_counters(&mut c);
            out.heal.export_counters(&mut c);
            (c, out)
        };
        for seed in [7u64, 42, 1984] {
            let (c1, o1) = run(seed);
            let (c2, o2) = run(seed);
            assert_eq!(c1, c2, "seed {seed} replays must match byte-for-byte");
            assert_eq!(o1.report, o2.report);
            assert!(o1.healed_to_k(2), "seed {seed} pool healed");
            assert_eq!(o1.report.faults_injected, o2.report.faults_injected);
        }
        let (ca, _) = run(7);
        let (cb, _) = run(42);
        assert_ne!(ca, cb, "different seeds must actually differ");
    }
}
