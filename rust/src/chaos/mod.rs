//! Chaos engine: deterministic fault injection + pool self-healing on
//! the shared [`crate::sim::PoolSim`] clock (ROADMAP direction 2).
//!
//! The paper's disaggregation claim only holds if the pool survives the
//! failures disaggregation invites — node death, PCIe-switch/array
//! loss, link brownouts, registry-WAN stalls — without losing the
//! chunk-level ≥k-holder invariant GC pins.  This module closes the
//! loop from failure → detection → repair → re-verified invariant:
//!
//! * [`ChaosSchedule`] — a seeded fault schedule, generated entirely
//!   from one seed + the pool shape + a horizon.  Same seed, same
//!   faults, same instants: chaos runs are byte-replayable tests, not
//!   ambient randomness.
//! * [`ChaosInjector`] — replays the schedule into a serving run as a
//!   [`crate::coordinator::ServeHook`]: faults are ordinary events on
//!   the one queue, and each node death immediately triggers replica
//!   re-placement, presence purge, and background re-replication while
//!   requests are still in flight.
//! * [`HealReport`] / [`ChaosReport`] — the repair and injection
//!   ledgers, exported under canonical `heal.*` / `chaos.*` counter
//!   names; availability is integrated as integer ppm so the
//!   determinism gate stays byte-exact.
//!
//! Run one from the CLI:
//!
//! ```sh
//! repro serve --workload nginx-filedown --nodes 8 --chaos 42
//! ```

pub mod heal;
pub mod injector;
pub mod report;
pub mod schedule;

pub use heal::HealReport;
pub use injector::{ChaosInjector, ChaosOutcome, EV_CHAOS_FAULT, EV_CHAOS_RESTORE};
pub use report::{availability_ppm, ChaosReport};
pub use schedule::{ChaosSchedule, Fault, FaultKind};
