//! What a chaos run did to the pool, in canonical counters.

use crate::metrics::{names, Counters};
use crate::util::SimTime;

/// Injection-side summary of one chaos run.  All integers, exported
/// under the canonical `chaos.*` names, so two same-seed runs compare
/// byte-for-byte.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ChaosReport {
    pub seed: u64,
    /// Faults that actually fired (every scheduled fault fires).
    pub faults_injected: u64,
    /// Individual node-death faults (array losses count separately).
    pub node_deaths: u64,
    pub array_losses: u64,
    pub link_brownouts: u64,
    pub registry_stalls: u64,
    /// Time-averaged healthy-node fraction over the run, in parts per
    /// million — integer so the determinism gate stays byte-exact.
    pub availability_ppm: u64,
}

impl ChaosReport {
    pub fn availability_fraction(&self) -> f64 {
        self.availability_ppm as f64 / 1e6
    }

    pub fn export_counters(&self, c: &mut Counters) {
        c.add(names::CHAOS_FAULTS_INJECTED, self.faults_injected);
        c.add(names::CHAOS_NODE_DEATHS, self.node_deaths);
        c.add(names::CHAOS_ARRAY_LOSSES, self.array_losses);
        c.add(names::CHAOS_LINK_BROWNOUTS, self.link_brownouts);
        c.add(names::CHAOS_REGISTRY_STALLS, self.registry_stalls);
        c.add(names::CHAOS_AVAILABILITY_PPM, self.availability_ppm);
    }
}

/// Integrate a healthy-node timeline into parts-per-million
/// availability over `[start, end]`.
///
/// `timeline` holds `(instant, healthy-count-from-that-instant)` steps,
/// first entry at `start`; `total` is the pool size.  All arithmetic is
/// u128 integer, so equal inputs produce equal output bit-for-bit.  An
/// empty window (or pool) reports full availability — nothing was
/// unavailable for any amount of time.
pub fn availability_ppm(
    timeline: &[(SimTime, u32)],
    total: u32,
    start: SimTime,
    end: SimTime,
) -> u64 {
    let span = end.saturating_sub(start).as_ns();
    if span == 0 || total == 0 || timeline.is_empty() {
        return 1_000_000;
    }
    let mut weighted: u128 = 0;
    for (i, &(at, healthy)) in timeline.iter().enumerate() {
        let from = at.max(start).as_ns().min(end.as_ns());
        let to = match timeline.get(i + 1) {
            Some(&(next, _)) => next.max(start).as_ns().min(end.as_ns()),
            None => end.as_ns(),
        };
        weighted += (to.saturating_sub(from)) as u128 * healthy as u128;
    }
    (weighted * 1_000_000 / (span as u128 * total as u128)) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_health_is_a_million_ppm() {
        let tl = [(SimTime::ZERO, 8u32)];
        assert_eq!(availability_ppm(&tl, 8, SimTime::ZERO, SimTime::ms(10)), 1_000_000);
    }

    #[test]
    fn half_dead_for_half_the_run_averages_three_quarters() {
        // 4 of 8 die at the midpoint of a 10ms run
        let tl = [(SimTime::ZERO, 8u32), (SimTime::ms(5), 4)];
        assert_eq!(availability_ppm(&tl, 8, SimTime::ZERO, SimTime::ms(10)), 750_000);
    }

    #[test]
    fn empty_windows_report_full_availability() {
        assert_eq!(availability_ppm(&[], 8, SimTime::ZERO, SimTime::ms(1)), 1_000_000);
        let tl = [(SimTime::ZERO, 8u32)];
        assert_eq!(availability_ppm(&tl, 8, SimTime::ms(3), SimTime::ms(3)), 1_000_000);
    }

    #[test]
    fn counters_export_under_canonical_names() {
        let r = ChaosReport {
            seed: 42,
            faults_injected: 5,
            node_deaths: 2,
            array_losses: 1,
            link_brownouts: 1,
            registry_stalls: 1,
            availability_ppm: 812_500,
        };
        let mut c = Counters::new();
        r.export_counters(&mut c);
        assert_eq!(c.get(names::CHAOS_FAULTS_INJECTED), 5);
        assert_eq!(c.get(names::CHAOS_AVAILABILITY_PPM), 812_500);
    }
}
