//! Seeded fault schedules — the adversarial input a chaos run replays.
//!
//! A schedule is generated *entirely* from one seed plus the pool shape
//! and a time horizon, through the crate's [`Rng`]: the same seed always
//! yields the same faults at the same instants, so a chaos run is as
//! replayable as any other scenario on the [`crate::sim::PoolSim`]
//! clock (the Norost fuzz-harness discipline: adversarial schedules are
//! first-class deterministic tests, not ambient randomness).
//!
//! Generation respects a *kill budget*: fewer than half the pool may
//! die, so the surviving majority can always absorb re-placed replicas
//! and re-replicated chunks.  A death (or whole-array loss) the budget
//! cannot afford degrades to a brownout of that array's backplane — the
//! schedule stays the same length, the pool stays healable.

use std::collections::BTreeSet;

use crate::fabric::LinkClass;
use crate::pool::{NodeId, PoolTopology};
use crate::util::{Rng, SimTime};

/// One injectable fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// A DockerSSD dies, permanently: its replicas re-place, its chunk
    /// registrations purge, its copies re-replicate.
    NodeDeath { node: NodeId },
    /// Every node of one array dies at once (a PCIe-switch/backplane
    /// loss) — the correlated-failure case that forces cross-array and
    /// registry re-replication.
    ArrayLoss { array: u32 },
    /// A link runs at `keep_pct`% of its configured bandwidth for
    /// `duration` — a flap/brownout window priced by the fabric engine.
    LinkBrownout {
        class: LinkClass,
        keep_pct: u32,
        duration: SimTime,
    },
    /// The registry WAN slows to `keep_pct`% for `duration` — cold
    /// pulls and orphan re-pulls crawl while the intranet stays fast.
    RegistryStall { keep_pct: u32, duration: SimTime },
}

/// A fault and the instant it fires on the shared clock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fault {
    pub at: SimTime,
    pub kind: FaultKind,
}

/// A full seeded schedule, sorted by fire time.
#[derive(Clone, Debug)]
pub struct ChaosSchedule {
    pub seed: u64,
    pub faults: Vec<Fault>,
}

impl ChaosSchedule {
    /// How many nodes may die in total: strictly fewer than half the
    /// pool, and never the last node.
    pub fn kill_budget(pool_nodes: usize) -> usize {
        pool_nodes.saturating_sub(1) / 2
    }

    /// Generate the schedule for `seed` over `[5%, 85%]` of `horizon`.
    /// 3–7 faults, roughly 35% node deaths / 30% brownouts / 20%
    /// registry stalls / 15% array losses, kill-budget capped.
    pub fn generate(seed: u64, topo: &PoolTopology, horizon: SimTime) -> Self {
        let mut rng = Rng::new(seed ^ 0xC4A0_5EED);
        let cfg = topo.config();
        let pool: Vec<NodeId> = topo.healthy_nodes().map(|n| n.id).collect();
        let budget = Self::kill_budget(pool.len());
        let mut dead: BTreeSet<NodeId> = BTreeSet::new();
        let horizon_ns = horizon.as_ns().max(1000);
        let n_faults = 3 + rng.below(5);
        let mut faults = Vec::new();
        for _ in 0..n_faults {
            let at = SimTime::ns(rng.range(horizon_ns / 20, horizon_ns * 17 / 20));
            let roll = rng.below(100);
            let kind = if roll < 35 {
                Self::node_death(&mut rng, &pool, &mut dead, budget, cfg.arrays)
            } else if roll < 65 {
                Self::brownout(&mut rng, cfg.arrays, horizon_ns)
            } else if roll < 85 {
                FaultKind::RegistryStall {
                    keep_pct: 10 + rng.below(21) as u32,
                    duration: Self::window(&mut rng, horizon_ns),
                }
            } else {
                Self::array_loss(&mut rng, topo, &mut dead, budget)
            };
            faults.push(Fault { at, kind });
        }
        // stable: equal fire times keep generation order
        faults.sort_by_key(|f| f.at);
        ChaosSchedule { seed, faults }
    }

    /// Nodes this schedule kills (directly or via array loss), sorted.
    pub fn doomed_nodes(&self, topo: &PoolTopology) -> Vec<NodeId> {
        let mut dead = BTreeSet::new();
        for f in &self.faults {
            match f.kind {
                FaultKind::NodeDeath { node } => {
                    dead.insert(node);
                }
                FaultKind::ArrayLoss { array } => {
                    dead.extend(topo.healthy_nodes().filter(|n| n.array == array).map(|n| n.id));
                }
                _ => {}
            }
        }
        dead.into_iter().collect()
    }

    fn window(rng: &mut Rng, horizon_ns: u64) -> SimTime {
        SimTime::ns(rng.range(horizon_ns / 50, horizon_ns / 8))
    }

    fn node_death(
        rng: &mut Rng,
        pool: &[NodeId],
        dead: &mut BTreeSet<NodeId>,
        budget: usize,
        arrays: u32,
    ) -> FaultKind {
        let alive: Vec<NodeId> = pool.iter().copied().filter(|n| !dead.contains(n)).collect();
        if dead.len() >= budget || alive.is_empty() {
            // budget spent: degrade to a short total blackout of a
            // random array instead of losing another node
            return FaultKind::LinkBrownout {
                class: LinkClass::Array(rng.below(arrays.max(1) as u64) as u32),
                keep_pct: 1,
                duration: SimTime::ns(1_000_000),
            };
        }
        let node = alive[rng.below(alive.len() as u64) as usize];
        dead.insert(node);
        FaultKind::NodeDeath { node }
    }

    fn array_loss(
        rng: &mut Rng,
        topo: &PoolTopology,
        dead: &mut BTreeSet<NodeId>,
        budget: usize,
    ) -> FaultKind {
        let arrays = topo.config().arrays.max(1);
        let array = rng.below(arrays as u64) as u32;
        let victims: Vec<NodeId> = topo
            .healthy_nodes()
            .filter(|n| n.array == array && !dead.contains(&n.id))
            .map(|n| n.id)
            .collect();
        if victims.is_empty() || dead.len() + victims.len() > budget {
            // losing the whole array would overrun the kill budget:
            // brown its backplane out hard instead
            return FaultKind::LinkBrownout {
                class: LinkClass::Array(array),
                keep_pct: 1 + rng.below(5) as u32,
                duration: SimTime::ns(2_000_000),
            };
        }
        dead.extend(victims);
        FaultKind::ArrayLoss { array }
    }

    fn brownout(rng: &mut Rng, arrays: u32, horizon_ns: u64) -> FaultKind {
        let class = match rng.below(4) {
            0 => LinkClass::Array(rng.below(arrays.max(1) as u64) as u32),
            1 => LinkClass::Tray,
            _ => LinkClass::HostUplink,
        };
        FaultKind::LinkBrownout {
            class,
            keep_pct: 5 + rng.below(26) as u32,
            duration: Self::window(rng, horizon_ns),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PoolConfig;

    fn topo(nodes: u32, arrays: u32) -> PoolTopology {
        PoolTopology::build(&PoolConfig {
            nodes_per_array: nodes,
            arrays,
            ..Default::default()
        })
    }

    #[test]
    fn same_seed_generates_identical_schedules() {
        let t = topo(4, 2);
        let a = ChaosSchedule::generate(7, &t, SimTime::ms(100));
        let b = ChaosSchedule::generate(7, &t, SimTime::ms(100));
        assert_eq!(a.faults, b.faults);
        assert!(!a.faults.is_empty());
    }

    #[test]
    fn different_seeds_diverge() {
        let t = topo(4, 2);
        let a = ChaosSchedule::generate(1, &t, SimTime::ms(100));
        let b = ChaosSchedule::generate(2, &t, SimTime::ms(100));
        assert_ne!(a.faults, b.faults, "seed must steer the schedule");
    }

    #[test]
    fn schedules_are_sorted_and_inside_the_horizon() {
        let t = topo(8, 2);
        for seed in 0..64 {
            let s = ChaosSchedule::generate(seed, &t, SimTime::ms(50));
            assert!(s.faults.len() >= 3 && s.faults.len() <= 7, "{}", s.faults.len());
            for w in s.faults.windows(2) {
                assert!(w[0].at <= w[1].at);
            }
            for f in &s.faults {
                assert!(f.at >= SimTime::ms(50).scale(0.05) && f.at < SimTime::ms(50));
            }
        }
    }

    #[test]
    fn kill_budget_spares_a_majority_for_every_seed() {
        let t = topo(4, 2); // 8 nodes: at most 3 may die
        for seed in 0..256 {
            let s = ChaosSchedule::generate(seed, &t, SimTime::ms(100));
            let doomed = s.doomed_nodes(&t);
            assert!(
                doomed.len() <= ChaosSchedule::kill_budget(8),
                "seed {seed} kills {doomed:?}"
            );
        }
    }

    #[test]
    fn tiny_pools_never_lose_their_last_node() {
        let t = topo(1, 1);
        for seed in 0..64 {
            let s = ChaosSchedule::generate(seed, &t, SimTime::ms(10));
            assert!(s.doomed_nodes(&t).is_empty(), "seed {seed}");
        }
    }
}
