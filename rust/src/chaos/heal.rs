//! The healing ledger: everything the self-healing loop moved or
//! restarted while chaos was running, rolled up across passes.

use crate::fabric::{Fabric, TransferId};
use crate::layerstore::HealStats;
use crate::metrics::{names, Counters};

/// Repair-side summary of one chaos run, exported under the canonical
/// `heal.*` names.  Accumulates one [`HealStats`] per healing pass
/// (reactive passes at each death, plus the final sweep), then settles
/// the background transfers to learn how many heal bytes were fully
/// hidden behind foreground traffic.
#[derive(Clone, Debug, Default)]
pub struct HealReport {
    pub chunks_rereplicated: u64,
    pub copies_made: u64,
    /// Bytes scheduled on background lanes to restore the k invariant.
    pub bytes: u64,
    /// Heal bytes whose transfer was granted the wire the instant it
    /// was issued — repair traffic foreground serving never waited on.
    pub bytes_hidden: u64,
    /// Chunks whose every copy died: their first new copy re-crossed
    /// the registry WAN.
    pub registry_chunks: u64,
    /// Replicas re-placed off dead nodes via `replica_failed`.
    pub replicas_restarted: u64,
    pub dead_nodes_purged: u64,
    /// In-flight heal transfers, settled by [`HealReport::settle`].
    transfers: Vec<TransferId>,
}

impl HealReport {
    /// Fold one healing pass into the ledger.
    pub fn absorb(&mut self, stats: HealStats) {
        self.chunks_rereplicated += stats.chunks_rereplicated;
        self.copies_made += stats.copies_made;
        self.bytes += stats.bytes;
        self.registry_chunks += stats.registry_chunks;
        self.transfers.extend(stats.transfers);
    }

    /// Settle every heal transfer on the fabric engine; a transfer that
    /// began the instant it was issued never queued behind foreground
    /// traffic, so its bytes count as hidden.
    pub fn settle(&mut self, fabric: &mut Fabric) {
        for id in std::mem::take(&mut self.transfers) {
            if let Some(r) = fabric.settle(id) {
                if r.begin == r.issued {
                    self.bytes_hidden += r.bytes;
                }
            }
        }
    }

    /// Heal transfers not yet settled.
    pub fn in_flight(&self) -> usize {
        self.transfers.len()
    }

    pub fn export_counters(&self, c: &mut Counters) {
        c.add(names::HEAL_CHUNKS_REREPLICATED, self.chunks_rereplicated);
        c.add(names::HEAL_COPIES_MADE, self.copies_made);
        c.add(names::HEAL_BYTES, self.bytes);
        c.add(names::HEAL_BYTES_HIDDEN, self.bytes_hidden);
        c.add(names::HEAL_REGISTRY_CHUNKS, self.registry_chunks);
        c.add(names::HEAL_REPLICAS_RESTARTED, self.replicas_restarted);
        c.add(names::HEAL_DEAD_NODES_PURGED, self.dead_nodes_purged);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EtherOnConfig, PoolConfig};
    use crate::fabric::{Endpoint, Priority};
    use crate::util::SimTime;

    #[test]
    fn absorb_accumulates_and_settle_classifies_hidden_bytes() {
        let mut f = Fabric::new(&PoolConfig::default(), &EtherOnConfig::default());
        // an idle-wire background transfer begins at issue: hidden
        let id = f.schedule(
            SimTime::ZERO,
            Endpoint::Node(0),
            Endpoint::Node(1),
            1 << 20,
            Priority::Background,
        );
        let mut h = HealReport::default();
        h.absorb(HealStats {
            chunks_rereplicated: 1,
            copies_made: 1,
            bytes: 1 << 20,
            registry_chunks: 0,
            transfers: vec![id],
        });
        h.absorb(HealStats {
            chunks_rereplicated: 2,
            copies_made: 3,
            bytes: 64,
            registry_chunks: 1,
            transfers: vec![],
        });
        assert_eq!(h.chunks_rereplicated, 3);
        assert_eq!(h.copies_made, 4);
        assert_eq!(h.in_flight(), 1);
        h.settle(&mut f);
        assert_eq!(h.in_flight(), 0);
        assert_eq!(h.bytes_hidden, 1 << 20, "idle-wire heal bytes are hidden");
        let mut c = Counters::new();
        h.export_counters(&mut c);
        assert_eq!(c.get(names::HEAL_COPIES_MADE), 4);
        assert_eq!(c.get(names::HEAL_BYTES_HIDDEN), 1 << 20);
    }
}
