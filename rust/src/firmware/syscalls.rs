//! System-call emulation table (paper Table 1a): 65 thread-handler calls,
//! 43 I/O-handler calls, 25 network-handler calls — emulated as
//! lightweight function wrappers on bare metal.
//!
//! We enumerate the calls that appear on the hot paths explicitly and
//! carry the remainder of each class as numbered variants so the table's
//! *counts* match the paper (65/43/25 = 133 total).

use std::collections::BTreeMap;

/// Handler classes of Table 1a.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SyscallClass {
    Thread,
    Io,
    Network,
}

/// Emulated system calls.  The named variants are the examples the paper
/// lists; `ThreadN`/`IoN`/`NetN` stand for the remaining emulated calls in
/// each class (process/memory/IPC/lock; file/dir/link/permission; polling/
/// socket/communication).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Syscall {
    // thread handler — process management
    Fork,
    Exit,
    // thread handler — memory management
    Brk,
    Mmap,
    // thread handler — IPC
    Pipe,
    MqOpen,
    // thread handler — lock & signal
    Futex,
    // i/o handler — file/dir
    Openat,
    Mkdir,
    Close,
    // i/o handler — file I/O & link
    Read,
    Write,
    Symlink,
    // i/o handler — permission
    Chmod,
    Chown,
    // network handler — polling
    EpollCreate,
    // network handler — socket
    Socket,
    Bind,
    // network handler — communication
    Sendto,
    Recvfrom,
    /// Remaining thread-class calls (indexed).
    ThreadN(u8),
    /// Remaining io-class calls (indexed).
    IoN(u8),
    /// Remaining network-class calls (indexed).
    NetN(u8),
}

pub const THREAD_SYSCALLS: u32 = 65;
pub const IO_SYSCALLS: u32 = 43;
pub const NET_SYSCALLS: u32 = 25;

/// The emulation table: classification + per-call invocation accounting.
#[derive(Debug, Default)]
pub struct SyscallTable {
    counts: BTreeMap<SyscallClass, u64>,
    total: u64,
}

impl SyscallTable {
    pub fn standard() -> Self {
        Self::default()
    }

    pub fn classify(&self, call: Syscall) -> SyscallClass {
        use Syscall::*;
        match call {
            Fork | Exit | Brk | Mmap | Pipe | MqOpen | Futex | ThreadN(_) => SyscallClass::Thread,
            Openat | Mkdir | Close | Read | Write | Symlink | Chmod | Chown | IoN(_) => {
                SyscallClass::Io
            }
            EpollCreate | Socket | Bind | Sendto | Recvfrom | NetN(_) => SyscallClass::Network,
        }
    }

    pub fn record(&mut self, call: Syscall) {
        *self.counts.entry(self.classify(call)).or_insert(0) += 1;
        self.total += 1;
    }

    pub fn count(&self, class: SyscallClass) -> u64 {
        self.counts.get(&class).copied().unwrap_or(0)
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Number of *emulated* calls per class (Table 1a totals).
    pub fn emulated_calls(class: SyscallClass) -> u32 {
        match class {
            SyscallClass::Thread => THREAD_SYSCALLS,
            SyscallClass::Io => IO_SYSCALLS,
            SyscallClass::Network => NET_SYSCALLS,
        }
    }

    /// Validity check: indexed variants must stay within each class's
    /// emulated-call budget (named variants included).
    pub fn in_table(call: Syscall) -> bool {
        match call {
            Syscall::ThreadN(i) => (i as u32) < THREAD_SYSCALLS - 7,
            Syscall::IoN(i) => (i as u32) < IO_SYSCALLS - 8,
            Syscall::NetN(i) => (i as u32) < NET_SYSCALLS - 5,
            _ => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_totals_match_paper() {
        assert_eq!(THREAD_SYSCALLS + IO_SYSCALLS + NET_SYSCALLS, 133);
        assert_eq!(SyscallTable::emulated_calls(SyscallClass::Thread), 65);
        assert_eq!(SyscallTable::emulated_calls(SyscallClass::Io), 43);
        assert_eq!(SyscallTable::emulated_calls(SyscallClass::Network), 25);
    }

    #[test]
    fn classification_follows_table1a() {
        let t = SyscallTable::standard();
        assert_eq!(t.classify(Syscall::Fork), SyscallClass::Thread);
        assert_eq!(t.classify(Syscall::Futex), SyscallClass::Thread);
        assert_eq!(t.classify(Syscall::Openat), SyscallClass::Io);
        assert_eq!(t.classify(Syscall::Chown), SyscallClass::Io);
        assert_eq!(t.classify(Syscall::EpollCreate), SyscallClass::Network);
        assert_eq!(t.classify(Syscall::Sendto), SyscallClass::Network);
    }

    #[test]
    fn recording_accumulates_by_class() {
        let mut t = SyscallTable::standard();
        t.record(Syscall::Fork);
        t.record(Syscall::Read);
        t.record(Syscall::Write);
        t.record(Syscall::Socket);
        assert_eq!(t.count(SyscallClass::Thread), 1);
        assert_eq!(t.count(SyscallClass::Io), 2);
        assert_eq!(t.count(SyscallClass::Network), 1);
        assert_eq!(t.total(), 4);
    }

    #[test]
    fn indexed_variants_respect_budgets() {
        assert!(SyscallTable::in_table(Syscall::ThreadN(0)));
        assert!(SyscallTable::in_table(Syscall::ThreadN(57)));
        assert!(!SyscallTable::in_table(Syscall::ThreadN(58)));
        assert!(SyscallTable::in_table(Syscall::IoN(34)));
        assert!(!SyscallTable::in_table(Syscall::IoN(35)));
        assert!(SyscallTable::in_table(Syscall::NetN(19)));
        assert!(!SyscallTable::in_table(Syscall::NetN(20)));
    }
}
