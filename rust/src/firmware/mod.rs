//! Virtual-FW (DESIGN.md S5, paper "DOCKER-ENABLED FIRMWARE"): the
//! lightweight firmware stack that brings minimal OS features and a
//! container environment onto the SSD's bare-metal frontend.
//!
//! Composition (Figure 7): three handlers — thread, I/O, network —
//! positioned between HIL and ICL; page-granular FW-pool / ISP-pool DRAM
//! partitions guarded by the MPU; system-call *emulation* as function
//! wrappers (no kernel/user boundary, no context switch on return).

pub mod costs;
pub mod handlers;
pub mod image;
pub mod syscalls;

use crate::config::SsdConfig;
use crate::etheron::TcpStack;
use crate::lambdafs::LambdaFs;
use crate::nvme::FrameSink;
use crate::ssd::SsdDevice;
use crate::util::SimTime;

pub use costs::CostModel;
pub use handlers::{
    InstallHandler, IoHandler, MemPools, NetHandler, PrivilegeMode, ThreadHandler,
};
pub use image::{fw_image, linux_image, FirmwareImage};
pub use syscalls::{Syscall, SyscallClass, SyscallTable};

/// The firmware stack of one DockerSSD.
pub struct VirtualFw {
    pub thread: ThreadHandler,
    pub io: IoHandler,
    pub net: NetHandler,
    /// Image-layer installs, routed into the content-addressed layerstore.
    pub install: InstallHandler,
    pub syscalls: SyscallTable,
    pub costs: CostModel,
    /// Accumulated simulated busy time of the firmware cores.
    pub busy: SimTime,
}

impl VirtualFw {
    pub fn new(cfg: &SsdConfig) -> Self {
        VirtualFw {
            thread: ThreadHandler::new(cfg),
            io: IoHandler::new(),
            net: NetHandler::new(),
            install: InstallHandler::new(),
            syscalls: SyscallTable::standard(),
            costs: CostModel::calibrated(),
            busy: SimTime::ZERO,
        }
    }

    /// Emulate one system call: dispatch to its handler, charge the
    /// function-wrapper cost (not a kernel context switch).
    pub fn syscall(&mut self, call: Syscall) -> SimTime {
        let class = self.syscalls.classify(call);
        let cost = SimTime::ns(self.costs.t_sys_emul_ns);
        self.syscalls.record(call);
        match class {
            SyscallClass::Thread => self.thread.calls += 1,
            SyscallClass::Io => self.io.calls += 1,
            SyscallClass::Network => self.net.calls += 1,
        }
        self.busy += cost;
        cost
    }

    /// ISP-container file read through the I/O handler -> λFS -> flash.
    pub fn isp_read(
        &mut self,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        path: &str,
    ) -> Result<(Vec<u8>, SimTime), crate::lambdafs::FsError> {
        let open_cost = self.syscall(Syscall::Openat);
        let r = self.io.read(fs, dev, at + open_cost, path)?;
        self.syscall(Syscall::Close);
        Ok((r.value, r.done))
    }

    /// ISP-container file write through the I/O handler.
    pub fn isp_write(
        &mut self,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        path: &str,
        data: &[u8],
    ) -> Result<SimTime, crate::lambdafs::FsError> {
        let open_cost = self.syscall(Syscall::Openat);
        let done = self.io.write(fs, dev, at + open_cost, path, data)?;
        self.syscall(Syscall::Close);
        Ok(done)
    }

    pub fn tcp(&mut self) -> &mut TcpStack {
        &mut self.net.tcp
    }
}

/// The firmware is the device-side FrameSink for Ether-oN transmit
/// commands: frames land in the network handler.
impl FrameSink for VirtualFw {
    fn deliver(&mut self, _at: SimTime, frame: &[u8]) -> SimTime {
        self.net.rx_frames += 1;
        self.net.rx_bytes += frame.len() as u64;
        // parse cost + one emulated network syscall
        let cost = SimTime::ns(self.costs.t_frame_parse_ns) + self.syscall(Syscall::Recvfrom);
        cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SsdConfig;
    use crate::lambdafs::LambdaFs;
    use crate::ssd::SsdDevice;

    fn setup() -> (VirtualFw, LambdaFs, SsdDevice) {
        let cfg = SsdConfig::default();
        let dev = SsdDevice::new(cfg.clone());
        let fs = LambdaFs::over_device(&dev);
        (VirtualFw::new(&cfg), fs, dev)
    }

    #[test]
    fn syscall_emulation_is_cheap() {
        let (mut fw, _, _) = setup();
        let cost = fw.syscall(Syscall::Openat);
        // "comparable to function management costs" — far below a full
        // kernel syscall (~1-2us)
        assert!(cost < SimTime::ns(500), "emulated syscall cost {cost}");
    }

    #[test]
    fn syscalls_route_to_handlers() {
        let (mut fw, _, _) = setup();
        fw.syscall(Syscall::Fork);
        fw.syscall(Syscall::Openat);
        fw.syscall(Syscall::Socket);
        fw.syscall(Syscall::Mmap);
        assert_eq!(fw.thread.calls, 2); // Fork + Mmap
        assert_eq!(fw.io.calls, 1);
        assert_eq!(fw.net.calls, 1);
    }

    #[test]
    fn isp_write_then_read_round_trips() {
        let (mut fw, mut fs, mut dev) = setup();
        let done = fw
            .isp_write(&mut fs, &mut dev, SimTime::ZERO, "/data/out.bin", b"result")
            .unwrap();
        assert!(done > SimTime::ZERO);
        let (data, _) = fw.isp_read(&mut fs, &mut dev, done, "/data/out.bin").unwrap();
        assert_eq!(data, b"result");
    }

    #[test]
    fn frame_sink_counts_traffic() {
        let (mut fw, _, _) = setup();
        use crate::nvme::FrameSink;
        fw.deliver(SimTime::ZERO, &[0u8; 128]);
        fw.deliver(SimTime::ZERO, &[0u8; 64]);
        assert_eq!(fw.net.rx_frames, 2);
        assert_eq!(fw.net.rx_bytes, 192);
    }

    #[test]
    fn busy_time_accumulates() {
        let (mut fw, _, _) = setup();
        for _ in 0..100 {
            fw.syscall(Syscall::Read);
        }
        assert!(fw.busy >= SimTime::ns(100 * 50));
    }
}
