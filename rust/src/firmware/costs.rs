//! Calibrated unit-cost model shared by the six data-processing models
//! (Figures 3 and 11).
//!
//! Anchors (paper statements the calibration targets):
//!   * Host: Storage ≈ 38% of end-to-end time (Fig 3).
//!   * P.ISP cuts Storage ~50% but Communicate (Kernel-ctx + LBA-set)
//!     reaches ~43% of its total; ~1.4x Host end-to-end (Fig 3).
//!   * P.ISP-V is 13.7% faster than P.ISP-R (vendor commands vs RPC).
//!   * D-FullOS +9.3% vs P.ISP-V; D-Naive +12.8% vs D-FullOS (Fig 11).
//!   * D-VirtFW: beats Host 1.3x, P.ISP-R/V 1.6x, D-Naive 1.8x,
//!     D-FullOS 1.6x; λFS saves 8.4% (LBA-set), rootfs pre-packaging
//!     saves 30.9% (Kernel-ctx) relative to P.ISP (Fig 11).
//!
//! Single global constants — per-workload variation comes only from the
//! Table 2 characteristic vectors, never from per-workload fitting.
//! EXPERIMENTS.md E1/E4 record achieved vs paper ratios.

/// All unit costs in nanoseconds (or ns per byte where noted).
#[derive(Clone, Debug)]
pub struct CostModel {
    // --- CPU speeds -----------------------------------------------------
    /// Host CPU frequency (GHz), paper testbed.
    pub host_ghz: f64,
    /// SSD frontend frequency (GHz).
    pub ssd_ghz: f64,
    /// Extra slowdown of the embedded in-order cores beyond frequency
    /// (IPC discount vs the host's OoO core).
    pub ssd_ipc_discount: f64,

    // --- compute --------------------------------------------------------
    /// Host data-processing cost per byte touched (ns/B).
    pub t_proc_host_ns_per_byte: f64,

    // --- system (OS) ----------------------------------------------------
    /// Full-OS syscall on the host (trap + kernel work + return), ns.
    pub t_sys_host_ns: u64,
    /// Full-OS syscall on the embedded cores (D-FullOS / D-Naive), ns.
    pub t_sys_fullos_ssd_ns: u64,
    /// Virtual-FW emulated syscall (function wrapper, no kernel boundary), ns.
    pub t_sys_emul_ns: u64,
    /// Host VFS path walk per component, ns.
    pub t_walk_host_ns: u64,
    /// λFS path walk per component (I/O-node cache), ns.
    pub t_walk_fw_ns: u64,

    // --- storage --------------------------------------------------------
    /// MLC page read, us.
    pub t_flash_read_us: u64,
    /// MLC page program, us.
    pub t_flash_prog_us: u64,
    /// Channel-level parallelism divisor (channels kept busy).
    pub channels: u64,
    /// Additional cell-latency overlap from deep NVMe queues (multi-plane
    /// and die interleaving on top of channel striping).
    pub flash_overlap: f64,
    /// Aggregate internal channel bandwidth, GB/s.
    pub ch_bw_gbps: f64,
    /// Host PCIe effective bandwidth, GB/s.
    pub pcie_bw_gbps: f64,
    /// Host block layer + NVMe driver + interrupt cost per I/O, ns.
    pub t_blk_host_ns: u64,

    // --- network ----------------------------------------------------------
    /// Host kernel network stack cost per TCP packet, ns.
    pub t_pkt_host_ns: u64,
    /// Ether-oN cost per packet (NVMe cmd + 4KB page copy), ns.
    pub t_pkt_ethon_ns: u64,
    /// Ether-oN frame parse cost on the device, ns.
    pub t_frame_parse_ns: u64,

    // --- P.ISP communication ----------------------------------------------
    /// P.ISP-R: per offloaded-syscall RPC bounce to the host runtime, ns.
    pub t_ctx_rpc_ns: u64,
    /// P.ISP-V: per bounce via vendor-specific NVMe command, ns.
    pub t_ctx_vendor_ns: u64,
    /// LBA-set handshake per newly-opened file, ns.
    pub t_lba_per_file_ns: u64,
    /// LBA-set bookkeeping per I/O, ns.
    pub t_lba_per_io_ns: u64,

    // --- D-Naive inter-complex transfers -----------------------------------
    /// Bandwidth between ISP processor complex and controller complex, GB/s.
    pub complex_link_gbps: f64,
    /// Per-I/O cost of crossing the complex boundary, ns.
    pub t_complex_per_io_ns: u64,
}

impl CostModel {
    /// The calibrated instance.
    ///
    /// Constants fitted once by randomized search against the anchor
    /// ratios in the module docs, under physical-plausibility constraints
    /// (full-OS syscalls on the 2.2GHz in-order cores cost more than on
    /// the host; λFS walks beat host VFS walks; emulated syscalls stay an
    /// order of magnitude under kernel syscalls; vendor commands beat
    /// RPC).  Achieved ratios are recorded in EXPERIMENTS.md E1/E4.
    pub fn calibrated() -> Self {
        CostModel {
            host_ghz: 3.8,
            ssd_ghz: 2.2,
            ssd_ipc_discount: 1.10,
            t_proc_host_ns_per_byte: 1.04,
            t_sys_host_ns: 3_000,
            t_sys_fullos_ssd_ns: 4_600,
            t_sys_emul_ns: 190,
            t_walk_host_ns: 1_900,
            t_walk_fw_ns: 815,
            t_flash_read_us: 50,
            t_flash_prog_us: 500,
            channels: 12,
            flash_overlap: 4.8,
            ch_bw_gbps: 4.8,
            pcie_bw_gbps: 3.2,
            t_blk_host_ns: 3_700,
            t_pkt_host_ns: 3_000,
            t_pkt_ethon_ns: 2_200,
            t_frame_parse_ns: 350,
            t_ctx_rpc_ns: 5_700,
            t_ctx_vendor_ns: 2_950,
            t_lba_per_file_ns: 26_000,
            t_lba_per_io_ns: 520,
            complex_link_gbps: 1.9,
            t_complex_per_io_ns: 2_150,
        }
    }

    /// Compute slowdown of the SSD frontend vs the host.
    pub fn ssd_compute_factor(&self) -> f64 {
        (self.host_ghz / self.ssd_ghz) * self.ssd_ipc_discount
    }

    /// ns to move `bytes` at `gbps` GB/s.
    pub fn xfer_ns(bytes: u64, gbps: f64) -> f64 {
        bytes as f64 / gbps
    }

    /// Effective flash service time for one I/O of `bytes` bytes on the
    /// device (channel-parallel cell access + channel transfer), ns.
    pub fn flash_io_ns(&self, bytes: u64, is_write: bool) -> f64 {
        let cell_us = if is_write {
            self.t_flash_prog_us
        } else {
            self.t_flash_read_us
        };
        let pages = bytes.div_ceil(4096).max(1);
        // pages spread across channels; cell time further overlapped by
        // die/plane interleaving under deep queues
        let cell_ns = (cell_us * 1_000) as f64 * pages as f64
            / (self.channels as f64 * self.flash_overlap);
        let xfer_ns = Self::xfer_ns(bytes, self.ch_bw_gbps);
        cell_ns + xfer_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_factor_near_paper_sixty_percent() {
        let c = CostModel::calibrated();
        // paper: short sequences run at "roughly 60% of host performance"
        let perf = 1.0 / c.ssd_compute_factor();
        assert!((0.5..0.65).contains(&perf), "ssd relative perf {perf}");
    }

    #[test]
    fn emulated_syscall_is_order_of_magnitude_cheaper() {
        let c = CostModel::calibrated();
        assert!(c.t_sys_emul_ns * 10 <= c.t_sys_host_ns);
        assert!(c.t_sys_emul_ns * 20 <= c.t_sys_fullos_ssd_ns);
    }

    #[test]
    fn vendor_commands_cheaper_than_rpc() {
        let c = CostModel::calibrated();
        assert!(c.t_ctx_vendor_ns < c.t_ctx_rpc_ns);
    }

    #[test]
    fn flash_io_scales_with_size_and_direction() {
        let c = CostModel::calibrated();
        let r4k = c.flash_io_ns(4096, false);
        let r64k = c.flash_io_ns(65536, false);
        let w4k = c.flash_io_ns(4096, true);
        assert!(r64k > r4k);
        assert!(w4k > r4k, "program slower than read");
    }

    #[test]
    fn xfer_math() {
        // 3.2 GB/s == 3.2 B/ns -> 3200 bytes in 1000 ns
        assert!((CostModel::xfer_ns(3200, 3.2) - 1000.0).abs() < 1e-6);
    }
}
