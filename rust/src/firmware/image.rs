//! Firmware image size inventory — Figure 10.
//!
//! The paper reports Virtual-FW shrinking the Linux-based firmware binary
//! by 83.4x, making it fit embedded processors.  We reconstruct both
//! images from component inventories: the Linux stack carries a full
//! kernel (MM, VFS, block layer, net stack, scheduler) plus the Docker
//! userland; Virtual-FW carries only the three handlers, the syscall
//! wrapper table, mini-docker, and λFS.

/// One linked component of a firmware image.
#[derive(Clone, Debug)]
pub struct ImageComponent {
    pub name: &'static str,
    pub bytes: u64,
}

/// A composed firmware image.
#[derive(Clone, Debug)]
pub struct FirmwareImage {
    pub name: &'static str,
    pub components: Vec<ImageComponent>,
}

impl FirmwareImage {
    pub fn total_bytes(&self) -> u64 {
        self.components.iter().map(|c| c.bytes).sum()
    }
}

const KB: u64 = 1024;
const MB: u64 = 1024 * 1024;

/// The D-FullOS image: embedded Linux + container runtime userland.
/// Component sizes follow a defconfig-ish arm64 build plus Docker's
/// static binaries (the paper's baseline).
pub fn linux_image() -> FirmwareImage {
    FirmwareImage {
        name: "linux+docker",
        components: vec![
            ImageComponent { name: "kernel-core (sched/mm/irq)", bytes: 9 * MB },
            ImageComponent { name: "vfs+ext4", bytes: 4 * MB },
            ImageComponent { name: "block-layer+nvme", bytes: 3 * MB },
            ImageComponent { name: "net-stack (tcp/ip)", bytes: 5 * MB },
            ImageComponent { name: "drivers+firmware blobs", bytes: 12 * MB },
            ImageComponent { name: "libc+init userland", bytes: 18 * MB },
            ImageComponent { name: "dockerd", bytes: 68 * MB },
            ImageComponent { name: "containerd", bytes: 48 * MB },
            ImageComponent { name: "runc", bytes: 14 * MB },
            ImageComponent { name: "docker-cli support", bytes: 36 * MB },
        ],
    }
}

/// The Virtual-FW image: handlers + syscall wrappers + mini-docker + λFS
/// on bare metal.
pub fn fw_image() -> FirmwareImage {
    FirmwareImage {
        name: "virtual-fw",
        components: vec![
            ImageComponent { name: "hil+icl+ftl (base fw)", bytes: 640 * KB },
            ImageComponent { name: "thread-handler", bytes: 180 * KB },
            ImageComponent { name: "io-handler+lambda-fs", bytes: 420 * KB },
            ImageComponent { name: "net-handler (tcp fsm)", bytes: 260 * KB },
            ImageComponent { name: "syscall wrappers (133)", bytes: 200 * KB },
            ImageComponent { name: "mini-docker (11 cmds)", bytes: 760 * KB },
            ImageComponent { name: "ether-on device side", bytes: 140 * KB },
        ],
    }
}

/// The headline ratio of Figure 10.
pub fn size_reduction_factor() -> f64 {
    linux_image().total_bytes() as f64 / fw_image().total_bytes() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduction_factor_matches_paper() {
        // paper: 83.4x smaller. we require the same order: 60x..110x
        let f = size_reduction_factor();
        assert!((60.0..110.0).contains(&f), "reduction {f:.1}x");
    }

    #[test]
    fn virtual_fw_fits_embedded_sram_budget() {
        // must fit comfortably in the 2GB frontend DRAM alongside pools;
        // more importantly stays in the single-digit-MB class
        assert!(fw_image().total_bytes() < 4 * MB);
    }

    #[test]
    fn linux_image_dominated_by_docker_userland() {
        let img = linux_image();
        let docker: u64 = img
            .components
            .iter()
            .filter(|c| c.name.contains("docker") || c.name.contains("container") || c.name.contains("runc"))
            .map(|c| c.bytes)
            .sum();
        assert!(docker * 2 > img.total_bytes(), "docker stack should dominate");
    }

    #[test]
    fn component_inventories_nonempty() {
        assert!(linux_image().components.len() >= 8);
        assert!(fw_image().components.len() >= 6);
        for c in fw_image().components {
            assert!(c.bytes > 0);
        }
    }
}
