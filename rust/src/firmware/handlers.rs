//! The three Virtual-FW handlers (Figure 7a) plus the FW-pool / ISP-pool
//! memory partitions guarded by CPU privilege modes.

use std::collections::HashMap;

use crate::config::SsdConfig;
use crate::etheron::TcpStack;
use crate::lambdafs::{FsError, FsResult, LambdaFs, LockSide};
use crate::layerstore::LayerStore;
use crate::ssd::SsdDevice;
use crate::util::{fnv1a, SimTime};

/// CPU execution modes: FW-pool access requires privileged mode, enforced
/// by the memory protection unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrivilegeMode {
    Privileged,
    User,
}

/// Page-granular DRAM partitions: the FW-pool holds handler tables, the
/// ISP-pool holds call arguments and container data.
#[derive(Debug)]
pub struct MemPools {
    page_bytes: u64,
    fw_pages_total: u64,
    isp_pages_total: u64,
    fw_pages_used: u64,
    isp_pages_used: u64,
    pub mpu_faults: u64,
}

impl MemPools {
    pub fn new(page_bytes: u64, fw_pages: u64, isp_pages: u64) -> Self {
        MemPools {
            page_bytes,
            fw_pages_total: fw_pages,
            isp_pages_total: isp_pages,
            fw_pages_used: 0,
            isp_pages_used: 0,
            mpu_faults: 0,
        }
    }

    /// Allocate from the FW pool; MPU-rejected outside privileged mode.
    pub fn alloc_fw(&mut self, mode: PrivilegeMode, bytes: u64) -> Option<u64> {
        if mode != PrivilegeMode::Privileged {
            self.mpu_faults += 1;
            return None;
        }
        let pages = bytes.div_ceil(self.page_bytes).max(1);
        if self.fw_pages_used + pages > self.fw_pages_total {
            return None;
        }
        self.fw_pages_used += pages;
        Some(pages)
    }

    /// Allocate from the ISP pool (either mode — privileged firmware may
    /// access the ISP pool directly, avoiding copies between the pools).
    pub fn alloc_isp(&mut self, bytes: u64) -> Option<u64> {
        let pages = bytes.div_ceil(self.page_bytes).max(1);
        if self.isp_pages_used + pages > self.isp_pages_total {
            return None;
        }
        self.isp_pages_used += pages;
        Some(pages)
    }

    pub fn free_isp(&mut self, pages: u64) {
        self.isp_pages_used = self.isp_pages_used.saturating_sub(pages);
    }

    pub fn isp_pages_free(&self) -> u64 {
        self.isp_pages_total - self.isp_pages_used
    }
}

/// An ISP process (container main thread) tracked by the thread handler.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ProcState {
    Running,
    Exited(i32),
}

/// Thread handler: process table + the memory pools.
pub struct ThreadHandler {
    pub pools: MemPools,
    procs: HashMap<u32, ProcState>,
    next_pid: u32,
    pub calls: u64,
}

impl ThreadHandler {
    pub fn new(cfg: &SsdConfig) -> Self {
        let dram_pages = cfg.dram_gib * (1 << 30) / cfg.page_bytes as u64;
        // FW tables get a fixed 1/16 slice; ISP data the rest (minus ICL).
        let fw = dram_pages / 16;
        let isp = dram_pages - fw - ((dram_pages as f64 * cfg.icl_fraction) as u64);
        ThreadHandler {
            pools: MemPools::new(cfg.page_bytes as u64, fw, isp),
            procs: HashMap::new(),
            next_pid: 100,
            calls: 0,
        }
    }

    /// fork(): create an ISP process, allocating its working pages.
    pub fn spawn(&mut self, mem_bytes: u64) -> Option<u32> {
        self.pools.alloc_isp(mem_bytes)?;
        let pid = self.next_pid;
        self.next_pid += 1;
        self.procs.insert(pid, ProcState::Running);
        Some(pid)
    }

    /// exit(): mark the process exited.
    pub fn exit(&mut self, pid: u32, code: i32) -> bool {
        match self.procs.get_mut(&pid) {
            Some(state) => {
                *state = ProcState::Exited(code);
                true
            }
            None => false,
        }
    }

    pub fn reap(&mut self, pid: u32, mem_pages: u64) -> Option<i32> {
        match self.procs.get(&pid) {
            Some(ProcState::Exited(code)) => {
                let code = *code;
                self.procs.remove(&pid);
                self.pools.free_isp(mem_pages);
                Some(code)
            }
            _ => None,
        }
    }

    pub fn state(&self, pid: u32) -> Option<&ProcState> {
        self.procs.get(&pid)
    }

    pub fn running(&self) -> usize {
        self.procs
            .values()
            .filter(|s| matches!(s, ProcState::Running))
            .count()
    }
}

/// I/O handler: ISP-generated I/O only, straight onto λFS — no host block
/// layer, no NVMe software stack.
#[derive(Default)]
pub struct IoHandler {
    pub calls: u64,
    pub reads: u64,
    pub writes: u64,
}

impl IoHandler {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn read(
        &mut self,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        path: &str,
    ) -> Result<FsResult<Vec<u8>>, FsError> {
        self.reads += 1;
        fs.read_file(dev, at, path, LockSide::Isp)
    }

    pub fn write(
        &mut self,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        at: SimTime,
        path: &str,
        data: &[u8],
    ) -> Result<SimTime, FsError> {
        self.writes += 1;
        Ok(fs.write_file(dev, at, path, data, LockSide::Isp)?.done)
    }
}

/// Install handler: the firmware entry point image-layer installs go
/// through.  Every blob that lands on the device — registry pull, peer
/// fetch — is routed into the content-addressed [`LayerStore`] instead
/// of a private per-node copy, so identical layers are stored once.
#[derive(Default)]
pub struct InstallHandler {
    pub calls: u64,
    /// Installs satisfied by content already in the store.
    pub store_hits: u64,
    /// Blobs whose content actually had to be (partially) written.
    pub blobs_installed: u64,
    pub bytes_installed: u64,
}

impl InstallHandler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install one image layer into the store.  A blob whose content is
    /// already resident is a metadata-only hit (no flash traffic);
    /// otherwise it is chunked into the store, deduplicating against
    /// everything already there.  Returns the blob digest.
    pub fn install_blob(
        &mut self,
        fs: &mut LambdaFs,
        dev: &mut SsdDevice,
        store: &mut LayerStore,
        at: SimTime,
        bytes: &[u8],
    ) -> Result<FsResult<u64>, FsError> {
        self.calls += 1;
        let digest = fnv1a(bytes);
        if store.has_blob(digest) {
            self.store_hits += 1;
            store.ref_blob(digest);
            return Ok(FsResult {
                value: digest,
                done: at,
            });
        }
        self.blobs_installed += 1;
        self.bytes_installed += bytes.len() as u64;
        store.put_blob(fs, dev, at, bytes)
    }
}

/// Network handler: the device-side TCP stack plus frame accounting.
pub struct NetHandler {
    pub tcp: TcpStack,
    pub calls: u64,
    pub rx_frames: u64,
    pub rx_bytes: u64,
    pub tx_frames: u64,
}

impl NetHandler {
    pub fn new() -> Self {
        NetHandler {
            tcp: TcpStack::new(),
            calls: 0,
            rx_frames: 0,
            rx_bytes: 0,
            tx_frames: 0,
        }
    }
}

impl Default for NetHandler {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SsdConfig;

    #[test]
    fn mpu_blocks_user_mode_fw_pool() {
        let mut pools = MemPools::new(4096, 16, 64);
        assert!(pools.alloc_fw(PrivilegeMode::User, 4096).is_none());
        assert_eq!(pools.mpu_faults, 1);
        assert!(pools.alloc_fw(PrivilegeMode::Privileged, 4096).is_some());
    }

    #[test]
    fn isp_pool_open_to_both_modes_no_copy() {
        let mut pools = MemPools::new(4096, 16, 64);
        assert!(pools.alloc_isp(8192).is_some());
        assert_eq!(pools.isp_pages_free(), 62);
    }

    #[test]
    fn pools_are_bounded() {
        let mut pools = MemPools::new(4096, 2, 2);
        assert!(pools.alloc_fw(PrivilegeMode::Privileged, 8192).is_some());
        assert!(pools.alloc_fw(PrivilegeMode::Privileged, 1).is_none());
        assert!(pools.alloc_isp(8192).is_some());
        assert!(pools.alloc_isp(1).is_none());
    }

    #[test]
    fn process_lifecycle() {
        let mut th = ThreadHandler::new(&SsdConfig::default());
        let pid = th.spawn(1 << 20).expect("spawn");
        assert_eq!(th.state(pid), Some(&ProcState::Running));
        assert_eq!(th.running(), 1);
        assert!(th.exit(pid, 0));
        assert_eq!(th.running(), 0);
        assert_eq!(th.reap(pid, 256), Some(0));
        assert_eq!(th.state(pid), None);
    }

    #[test]
    fn exit_unknown_pid_fails() {
        let mut th = ThreadHandler::new(&SsdConfig::default());
        assert!(!th.exit(12345, 0));
        assert_eq!(th.reap(12345, 0), None);
    }

    #[test]
    fn install_routes_through_store_and_dedups() {
        let cfg = SsdConfig::default();
        let mut dev = crate::ssd::SsdDevice::new(cfg.clone());
        let mut fs = crate::lambdafs::LambdaFs::over_device(&dev);
        let mut store = LayerStore::default();
        let mut ih = InstallHandler::new();
        let layer = vec![7u8; 100_000];
        let r1 = ih
            .install_blob(&mut fs, &mut dev, &mut store, SimTime::ZERO, &layer)
            .unwrap();
        assert!(r1.done > SimTime::ZERO);
        assert_eq!(ih.blobs_installed, 1);
        // second replica installing the same layer: pure store hit
        let r2 = ih
            .install_blob(&mut fs, &mut dev, &mut store, r1.done, &layer)
            .unwrap();
        assert_eq!(r2.value, r1.value);
        assert_eq!(r2.done, r1.done, "store hit programs nothing");
        assert_eq!(ih.store_hits, 1);
        assert_eq!(ih.bytes_installed, 100_000);
        assert_eq!(store.blob_refs(r1.value), 2);
    }

    #[test]
    fn reap_frees_memory() {
        let mut th = ThreadHandler::new(&SsdConfig::default());
        let free0 = th.pools.isp_pages_free();
        let pid = th.spawn(4096 * 10).unwrap();
        assert_eq!(th.pools.isp_pages_free(), free0 - 10);
        th.exit(pid, 7);
        assert_eq!(th.reap(pid, 10), Some(7));
        assert_eq!(th.pools.isp_pages_free(), free0);
    }
}
